"""Quickstart: simulate a CXL.mem topology for a training step in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

import repro.configs as cfgs
from repro.core import CXLMemSim, ClassMapPolicy, EpochSchedule, figure1_topology
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.models.phases import build_regions_and_phases
from repro.optim.adamw import AdamWConfig, adamw_init

# 1. pick an architecture from the zoo (reduced config so it runs on CPU)
import dataclasses
cfg = dataclasses.replace(cfgs.get_smoke("qwen3-0.6b"), dtype=jnp.float32)

# 2. build a real jitted train step
opt_cfg = AdamWConfig(lr=1e-3, total_steps=100)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt_state = {"adam": adamw_init(params, opt_cfg), "ef": {}}
step = jax.jit(make_train_step(cfg, opt_cfg))

# 3. describe the memory topology (paper Figure 1) and a placement policy:
#    optimizer state lives in a far CXL pool behind two switches
topo = figure1_topology()
print(topo.describe())
policy = ClassMapPolicy({"opt_state": "cxl_pool2"})

# 4. attach CXLMemSim — the tracer registers every tensor region
regions, phases = build_regions_and_phases(cfg, "train", batch=8, seq=128)
sim = CXLMemSim(topo, policy, epoch=EpochSchedule("layer"), check_capacity=False)
prog = sim.attach(step, phases, regions)

# 5. run real steps; the analyzer prices every epoch against the topology
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 128), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 128), 0, cfg.vocab_size),
}
for i in range(5):
    params, opt_state, metrics = prog.step(params, opt_state, batch)
    print(f"step {i}: loss={float(metrics['loss']):.3f}")

r = prog.report
print(f"\nnative      {r.native_s*1e3:.1f} ms")
print(f"simulated   {r.simulated_s*1e3:.1f} ms  (slowdown {r.slowdown:.2f}x)")
print(
    f"delays      latency {r.latency_s*1e3:.2f} ms | congestion "
    f"{r.congestion_s*1e3:.2f} ms | bandwidth {r.bandwidth_s*1e3:.2f} ms"
)
print("per-pool latency (ns):", dict(zip(topo.flatten().pool_names, r.per_pool_latency_ns)))
