"""Migration policies x device caching — the paper's headline research use
("data migration strategies and caching techniques that were previously
infeasible to evaluate at scale"), on one serving-shaped workload.

Sweeps three tiering configurations (static placement, software migration,
software migration with a demote_pool escape hatch) against three
expander-cache capacities, and prints the simulated slowdown grid.

    PYTHONPATH=src python examples/migration_caching.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    Access,
    CXLMemSim,
    ClassMapPolicy,
    DeviceCacheConfig,
    MigrationConfig,
    MigrationSimulator,
    Phase,
    RegionMap,
    figure1_topology,
)
from repro.core.units import s_to_ms

PAGE = 4096
TOPO = figure1_topology()


def build_workload():
    """A decode-ish step: hot KV pages remote, weights warm local, and a
    large optimizer region that is local-born but never touched while
    serving — the classic budget-pinning cold resident."""
    rm = RegionMap()
    rm.alloc("w", 64 << 20, "param")  # local (unmapped class)
    rm.alloc("opt", 128 << 20, "opt_state")  # local-born, idle during decode
    rm.alloc("kv_hot", 256 * PAGE, "kvcache")  # small, re-read every step
    rm.alloc("kv_cold", 64 << 20, "kvcache")  # long-tail cache, rarely touched
    phases = [
        Phase(
            "decode",
            flops=2e9,
            accesses=(
                Access("w", 16 << 20),
                Access("kv_hot", 64 << 20, True),  # heavy reuse of few pages
                Access("kv_cold", 1 << 20),
            ),
        )
    ]
    return rm, phases


step = jax.jit(lambda x: (x @ x.T).sum())
x = jnp.ones((128, 128))
jax.block_until_ready(step(x))  # compile outside the measured steps

# budget (96 MiB) < w + opt (192 MiB): with the plain policy the idle opt
# region can never leave local DRAM (home == local), so nothing can ever
# promote; demote_pool breaks the dead-end.  1 MiB granules model a daemon
# that batches its copies (page-granular bursts queue 4096 transactions at
# one instant and the STT congestion charge dwarfs the steady-state win).
MIGRATIONS = {
    "static": None,
    "sw-migrate": MigrationConfig(
        mode="software", promote_threshold=8, demote_threshold=2,
        local_budget_bytes=96 << 20, granularity_bytes=1 << 20,
    ),
    "sw+demote_pool": MigrationConfig(
        mode="software", promote_threshold=8, demote_threshold=2,
        local_budget_bytes=96 << 20, granularity_bytes=1 << 20,
        demote_pool="cxl_pool2",
    ),
}
CACHES = {"no cache": 0, "256 MiB": 256 << 20, "1 GiB": 1 << 30}

print(TOPO.describe())
print(f"\n{'policy':>16} | " + " | ".join(f"{c:>18}" for c in CACHES))
for mig_name, mig_cfg in MIGRATIONS.items():
    cells = []
    for cap in CACHES.values():
        rm, phases = build_workload()
        flat = TOPO.flatten()
        migration = (
            MigrationSimulator(mig_cfg, rm, flat) if mig_cfg is not None else None
        )
        sim = CXLMemSim(
            TOPO,
            ClassMapPolicy({"kvcache": "cxl_pool1"}),
            migration=migration,
            cache=DeviceCacheConfig(capacity_bytes=cap, line_bytes=PAGE)
            if cap
            else None,
        )
        prog = sim.attach(step, phases, rm)
        rep = prog.run(10, x)  # enough steps to amortize the one-time copies
        hit = rep.cache_hit_fraction
        # the simulated delay is the quantity migration/caching reshape;
        # wall-clock slowdown also rides on the (noisy, µs-scale) toy step
        delay_ms = s_to_ms(rep.latency_s + rep.congestion_s + rep.bandwidth_s)
        cells.append(
            f"{delay_ms:7.2f} ms"
            + (f" hit {hit:4.0%}" if hit == hit else "         ")
            + (f" p{migration.promotions}" if migration else "   ")
        )
    print(f"{mig_name:>16} | " + " | ".join(f"{c:>20}" for c in cells))

print(
    "\nReading the grid: with the plain policy the idle local-born opt"
    "\nregion pins the 96 MiB budget, so nothing ever promotes (p0) and"
    "\nsw-migrate == static; demote_pool evicts it and the hot KV pages go"
    "\nlocal (p1), cutting the steady-state delay.  The expander cache"
    "\ntrims the *latency* component of whatever stays remote (hit %);"
    "\nMB-sized transactions are bandwidth-dominated here, so its effect"
    "\nis visible but small — benchmarks/migration_scaling.py sweeps the"
    "\nlatency-bound regime where it is decisive."
)
