"""Serving with KV-cache offload: prefill + decode under CXLMemSim.

The canonical CXL.mem serving question (paper §1: "comparison of cache-line
and page memory management"): long-context decode with the KV cache in a
pooled CXL expander — what does each management granularity cost?

    PYTHONPATH=src python examples/serve_offload.py
"""

import dataclasses

import jax
import jax.numpy as jnp

import repro.configs as cfgs
from repro.core import (
    CACHELINE_BYTES,
    PAGE_BYTES,
    CXLMemSim,
    ClassMapPolicy,
    LocalOnlyPolicy,
    two_tier_topology,
)
from repro.models import Model
from repro.models.phases import build_regions_and_phases

B, PROMPT, DECODE, SMAX = 4, 96, 16, 160

cfg = dataclasses.replace(
    cfgs.get_smoke("mistral-large-123b"), dtype=jnp.float32, cache_dtype=jnp.float32
)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

# --- real serving path: prefill then token-by-token decode ------------------ #
prompt = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab_size)
prefill = jax.jit(lambda p, t: model.prefill(p, t, pad_to=SMAX))
decode = jax.jit(model.decode_step)

logits, caches, clen = prefill(params, prompt)
tok = jnp.argmax(logits, -1)[:, None]
decode_step_fn = lambda c, t, n: decode(params, c, t, n)
jax.block_until_ready(decode_step_fn(caches, tok, clen))  # compile once up front

topo = two_tier_topology(cxl_latency_ns=170.0, cxl_bandwidth_gbps=32.0)
results = {}
for name, policy in {
    "local": LocalOnlyPolicy(),
    "kv_offload_cacheline": ClassMapPolicy({"kvcache": "cxl_pool"}, CACHELINE_BYTES),
    "kv_offload_page": ClassMapPolicy({"kvcache": "cxl_pool"}, PAGE_BYTES),
}.items():
    regions, phases = build_regions_and_phases(
        cfg, "decode", batch=B, seq=1, cache_len=SMAX
    )
    sim = CXLMemSim(topo, policy, check_capacity=False)
    prog = sim.attach(decode_step_fn, phases, regions)
    c, t, n = caches, tok, clen
    for _ in range(DECODE):
        lg, c = prog.step(c, t, n)
        t = jnp.argmax(lg, -1)[:, None]
        n = n + 1
    results[name] = prog.report
    print(
        f"{name:22s} native {prog.report.native_s*1e3:7.1f} ms   "
        f"simulated {prog.report.simulated_s*1e3:7.1f} ms   "
        f"slowdown {prog.report.slowdown:.3f}x   "
        f"(lat {prog.report.latency_s*1e3:.2f} ms, bw {prog.report.bandwidth_s*1e3:.2f} ms)"
    )

base = results["local"].native_s
for name in ("kv_offload_cacheline", "kv_offload_page"):
    extra = results[name].simulated_s - results[name].native_s
    print(f"{name}: +{extra / DECODE * 1e3:.3f} ms per decoded token vs all-local")
print("\n(cacheline management touches only the lines the step reads;"
      "\n page management rounds every access up to 4 KiB pages — the paper's"
      "\n cache-line vs page comparison, priced on one topology)")
