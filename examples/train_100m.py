"""End-to-end driver: train a ~100M-param model for a few hundred steps with
the full production stack — data pipeline, AdamW, periodic checkpoints,
restart-on-resume, straggler watch, and CXLMemSim attached.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]

The model is a 12-layer/640-dim dense GQA transformer (~100M params with the
qwen3 tokenizer's vocab scaled down), trained on the synthetic pipeline.
Interrupt it and re-run: it resumes from the newest committed checkpoint.
"""

import argparse

import jax.numpy as jnp

from repro.launch.train import train_loop
from repro.models import ModelConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="dense-100m",
        family="dense",
        n_layers=12,
        d_model=640,
        n_heads=10,
        n_kv_heads=2,
        d_head=64,
        d_ff=2560,
        vocab_size=32768,
        rope_variant="rope",
        dtype=jnp.float32,
        cache_dtype=jnp.float32,
        remat=False,  # small model: no need on CPU
    )
    print(f"params: {cfg.param_counts()['total']/1e6:.1f}M")

    out = train_loop(
        cfg,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=3e-4,
        ckpt_dir=args.ckpt_dir,
        ckpt_interval=50,
        simulate=True,  # CXLMemSim attached: optimizer state in a CXL pool
        log_every=10,
    )
    print(f"\nfinal loss {out['final_loss']:.4f} after {out['steps']} steps "
          f"({out['wall_s']:.0f}s wall, resumed from step {out['start_step']})")
    first, last = out["losses"][0], out["final_loss"]
    print(f"loss moved {first:.3f} -> {last:.3f} ({'OK: decreasing' if last < first else 'WARN'})")
    if "sim" in out:
        s = out["sim"]
        print(
            f"CXLMemSim: simulated slowdown {s['slowdown']:.3f}x "
            f"(latency {s['latency_s']:.3f}s, bandwidth {s['bandwidth_s']:.3f}s "
            f"over {s['epochs']} epochs)"
        )


if __name__ == "__main__":
    main()
