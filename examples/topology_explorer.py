"""Topology explorer: evaluate CXL.mem pool hierarchies *before procurement*
(the paper's stated deployment use case).

Sweeps a grid of candidate topologies (pool count, switch depth, link
bandwidth) against a fixed training workload and reports the simulated
step-time for each — the purchasing decision table.

    PYTHONPATH=src python examples/topology_explorer.py
"""

import dataclasses

import jax.numpy as jnp

import repro.configs as cfgs
from repro.core import (
    ClassMapPolicy,
    EpochAnalyzer,
    Pool,
    Switch,
    Topology,
)
from repro.core.tracer import synthesize_step_trace
from repro.models.phases import build_regions_and_phases


def candidate(n_pools: int, depth: int, bw: float) -> Topology:
    """n_pools expanders behind a switch chain of `depth`."""
    switches = []
    parent = None
    for d in range(depth):
        switches.append(
            Switch(f"sw{d}", latency_ns=70.0, bandwidth_gbps=bw, stt_ns=2.0, parent=parent)
        )
        parent = f"sw{d}"
    pools = [Pool("local_dram", 88.9, 76.8, 96 << 30, is_local=True)]
    for i in range(n_pools):
        pools.append(Pool(f"cxl{i}", 170.0, bw, 256 << 30, parent=parent))
    return Topology(pools=pools, switches=switches)


def main():
    cfg = dataclasses.replace(cfgs.get_smoke("chatglm3-6b"), dtype=jnp.float32)
    regions, phases = build_regions_and_phases(cfg, "train", batch=8, seq=256)

    print("pools,switch_depth,link_GBps,native_ms,delay_ms,slowdown")
    best = None
    for n_pools in (1, 2, 4):
        for depth in (1, 2):
            for bw in (16.0, 32.0, 64.0):
                topo = candidate(n_pools, depth, bw)
                flat = topo.flatten()
                pol = ClassMapPolicy(
                    {"opt_state": "cxl0", "grad": "cxl0" if n_pools == 1 else "cxl1"}
                )
                pol.place(regions, flat)
                traces, native_ns, _ = synthesize_step_trace(
                    phases, regions, granularity_bytes=pol.granularity_bytes
                )
                bd = EpochAnalyzer(flat).analyze(traces[0])
                slow = (native_ns[0] + bd.total_ns) / native_ns[0]
                print(
                    f"{n_pools},{depth},{bw:.0f},{native_ns[0]/1e6:.2f},"
                    f"{bd.total_ns/1e6:.2f},{slow:.3f}"
                )
                if best is None or slow < best[0]:
                    best = (slow, n_pools, depth, bw)
    s, n, d, b = best
    print(
        f"\nbest candidate: {n} pool(s) behind {d} switch level(s) at {b:.0f} GB/s "
        f"-> {s:.3f}x slowdown (buy this one)"
    )


if __name__ == "__main__":
    main()
