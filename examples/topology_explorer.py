"""Topology explorer: evaluate CXL.mem pool hierarchies *before procurement*
(the paper's stated deployment use case).

Sweeps a grid of candidate topologies (pool count, switch depth, link
bandwidth) against a fixed training workload and reports the simulated
step-time for each — the purchasing decision table.

Ported to the batched :class:`~repro.core.ScenarioSuite`: *structural* axes
(pool count, switch depth) pick a base topology per suite; everything
numeric (link bandwidth × placement policy) stacks into ONE device dispatch
per structure — 6 dispatches instead of 18 sequential evaluations — and a
successive-halving refinement then hillclimbs the bandwidth axis around the
grid winner, still one dispatch per round.

    PYTHONPATH=src python examples/topology_explorer.py
"""

import dataclasses

import jax.numpy as jnp

import repro.configs as cfgs
from repro.core import (
    ClassMapPolicy,
    Pool,
    Scenario,
    ScenarioSuite,
    Switch,
    Topology,
    TopologyOverride,
)
from repro.core.units import ns_to_ms
from repro.models.phases import build_regions_and_phases


def candidate(n_pools: int, depth: int, bw: float) -> Topology:
    """n_pools expanders behind a switch chain of `depth`."""
    switches = []
    parent = None
    for d in range(depth):
        switches.append(
            Switch(f"sw{d}", latency_ns=70.0, bandwidth_gbps=bw, stt_ns=2.0, parent=parent)
        )
        parent = f"sw{d}"
    pools = [Pool("local_dram", 88.9, 76.8, 96 << 30, is_local=True)]
    for i in range(n_pools):
        pools.append(Pool(f"cxl{i}", 170.0, bw, 256 << 30, parent=parent))
    return Topology(pools=pools, switches=switches)


def bw_override(topo: Topology, bw: float) -> TopologyOverride:
    """Set every CXL link (switches + expander leaves) to ``bw`` GB/s."""
    return TopologyOverride(
        pools={p.name: {"bandwidth_gbps": bw} for p in topo.pools if not p.is_local},
        switches={s.name: {"bandwidth_gbps": bw} for s in topo.switches},
    )


def main():
    cfg = dataclasses.replace(cfgs.get_smoke("chatglm3-6b"), dtype=jnp.float32)
    regions, phases = build_regions_and_phases(cfg, "train", batch=8, seq=256)

    print("pools,switch_depth,link_GBps,native_ms,delay_ms,slowdown")
    best = None
    best_ctx = None
    for n_pools in (1, 2, 4):
        for depth in (1, 2):
            # one base structure; the bandwidth axis stacks as overrides
            topo = candidate(n_pools, depth, 32.0)
            suite = ScenarioSuite(topo, regions, phases)
            pol = ClassMapPolicy(
                {"opt_state": "cxl0", "grad": "cxl0" if n_pools == 1 else "cxl1"}
            )
            scens = [
                Scenario(policy=pol, topology=bw_override(topo, bw), name=f"{bw:g}GBps")
                for bw in (16.0, 32.0, 64.0)
            ]
            res = suite.run(scens)  # ONE dispatch for the whole bandwidth axis
            native_ms = ns_to_ms(res.native_ns)
            for s, bd, slow in zip(res.scenarios, res.breakdowns, res.slowdowns()):
                bw = float(s.topology.switches["sw0"]["bandwidth_gbps"])
                print(
                    f"{n_pools},{depth},{bw:.0f},{native_ms:.2f},"
                    f"{bd.total_ns/1e6:.2f},{slow:.3f}"
                )
                if best is None or slow < best[0]:
                    best = (float(slow), n_pools, depth, bw)
                    best_ctx = (suite, pol)
    s, n, d, b = best
    print(
        f"\nbest candidate: {n} pool(s) behind {d} switch level(s) at {b:.0f} GB/s "
        f"-> {s:.3f}x slowdown (buy this one)"
    )

    # hillclimb-style refinement of the bandwidth axis around the winner:
    # each round is one stacked dispatch over survivors + their neighbors
    suite, pol = best_ctx
    topo = suite.topology

    def mk(bw: float) -> Scenario:
        return Scenario(policy=pol, topology=bw_override(topo, bw), name=f"{bw:.4g}GBps")

    def refine(sc: Scenario, rnd: int):
        bw = float(sc.topology.switches["sw0"]["bandwidth_gbps"])
        step = 1.0 + 0.25 / (rnd + 1)
        return [mk(bw * step), mk(bw / step)]

    res, idx = suite.successive_halving([mk(b / 1.5), mk(b), mk(b * 1.5)], refine, rounds=2)
    print(
        f"refined: {res.scenarios[idx].label()} -> "
        f"{res.slowdowns()[idx]:.3f}x slowdown "
        f"({suite.dispatch_count} stacked dispatches total)"
    )


if __name__ == "__main__":
    main()
