"""Two-tenant memory pooling on a shared CXL fabric.

The paper's headline scenario: two servers offload their KV caches onto one
shared CXL expander to fix memory stranding.  A quiet serving tenant and a
bulk-traffic tenant co-attach on the same fabric; the session reports each
host's native vs simulated clock plus the fabric-wide contention
decomposition — including what the noisy neighbor costs the quiet one.

Run:  PYTHONPATH=src python examples/fabric_pooling.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    Access,
    ClassMapPolicy,
    CoherencyConfig,
    FabricSession,
    Phase,
    RegionMap,
    Tenant,
    pooled_topology,
)
from repro.core.units import s_to_ms


def make_tenant(name: str, kv_bytes: int, batch: int) -> Tenant:
    """A toy serving step: weights in local DRAM, KV cache on the shared pool."""
    regions = RegionMap()
    regions.alloc("weights", 1 << 28, "param")
    regions.alloc("kv", max(kv_bytes, 1 << 22), "kvcache")
    regions.alloc("activations", 1 << 22, "activation")
    phases = [
        Phase(
            "decode",
            flops=2e10,
            accesses=(
                Access("weights", 1 << 28),
                Access("kv", kv_bytes),  # read the cache...
                Access("kv", kv_bytes // 8, is_write=True),  # ...append to it
                Access("activations", 1 << 22, is_write=True),
            ),
        )
    ]
    step = jax.jit(lambda x: jnp.tanh(x @ x.T).sum())
    x = jnp.ones((batch, 256))
    return Tenant(
        name, phases, regions,
        ClassMapPolicy({"kvcache": "shared_pool"}),
        step_fn=step, step_args=(x,),
    )


def main():
    topo = pooled_topology(n_hosts=2, cxl_bandwidth_gbps=16.0)
    print(topo.describe())

    session = FabricSession(
        topo,
        [
            make_tenant("quiet-serving", kv_bytes=1 << 24, batch=64),
            make_tenant("bulk-tenant", kv_bytes=1 << 28, batch=256),
        ],
        # shared kv-cache class => trace-driven back-invalidation traffic
        coherency=CoherencyConfig(shared_classes=("kvcache",)),
    )
    report = session.run(5)

    print()
    print(f"fabric: {report.rounds} rounds, {report.epochs} epochs, "
          f"BI messages {report.bi_messages:.0f}")
    print(f"  latency    {s_to_ms(report.latency_s):9.3f} ms")
    print(f"  congestion {s_to_ms(report.congestion_s):9.3f} ms")
    print(f"  bandwidth  {s_to_ms(report.bandwidth_s):9.3f} ms")
    print(f"  coherency  {s_to_ms(report.coherency_s):9.3f} ms")
    for hc in report.hosts:
        print(
            f"host {hc.host} ({hc.name}): native {s_to_ms(hc.native_s):.2f} ms, "
            f"simulated {s_to_ms(hc.simulated_s):.2f} ms, "
            f"slowdown {hc.slowdown:.2f}x "
            f"(delay share: lat {s_to_ms(hc.latency_s):.3f} / "
            f"cong {s_to_ms(hc.congestion_s):.3f} / "
            f"bw {s_to_ms(hc.bandwidth_s):.3f} / coh {s_to_ms(hc.coherency_s):.3f} ms)"
        )


if __name__ == "__main__":
    main()
