"""MoE dispatch equivalence + invariants (hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.moe import init_moe, moe_block

E, D, F = 8, 32, 64


@pytest.fixture(scope="module")
def params():
    return init_moe(jax.random.PRNGKey(0), D, F, E)


def _x(seed, B=2, S=64):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, S, D))


def test_dispatch_modes_agree_lossless(params):
    """einsum / scatter / dense all compute the same function when capacity
    is lossless (cf = E/k ⇒ no token ever dropped)."""
    x = _x(1)
    outs = {}
    for mode in ("einsum", "scatter", "dense"):
        outs[mode], aux = moe_block(
            params, x, top_k=2, capacity_factor=float(E) / 2, dispatch=mode,
            group_tokens=64,
        )
    np.testing.assert_allclose(outs["einsum"], outs["scatter"], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(outs["einsum"], outs["dense"], rtol=2e-5, atol=2e-5)


def test_capacity_drops_reduce_output_norm(params):
    """Dropped tokens produce zero output rows -> tiny capacity shrinks norms."""
    x = _x(2)
    full, _ = moe_block(params, x, top_k=2, capacity_factor=4.0, group_tokens=64)
    tiny, _ = moe_block(params, x, top_k=2, capacity_factor=0.1, group_tokens=64)
    assert float(jnp.linalg.norm(tiny)) < float(jnp.linalg.norm(full))


def test_grouping_invariance(params):
    """Group size must not change routing results when capacity is lossless."""
    x = _x(3, B=2, S=128)
    a, _ = moe_block(params, x, top_k=2, capacity_factor=float(E) / 2, group_tokens=64)
    b, _ = moe_block(params, x, top_k=2, capacity_factor=float(E) / 2, group_tokens=256)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_padding_tokens_do_not_crash(params):
    """Token count not divisible by group size exercises the pad path."""
    x = _x(4, B=1, S=100)
    out, aux = moe_block(params, x, top_k=2, capacity_factor=4.0, group_tokens=64)
    assert out.shape == (1, 100, D)
    assert np.isfinite(float(aux))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), k=st.integers(1, 4))
def test_property_aux_loss_bounds(seed, k):
    """Load-balance aux loss ≥ 1 (Cauchy-Schwarz; = 1 at perfect balance)
    and finite."""
    p = init_moe(jax.random.PRNGKey(seed), D, F, E)
    x = _x(seed + 1)
    _, aux = moe_block(p, x, top_k=k, capacity_factor=4.0, group_tokens=64)
    assert np.isfinite(float(aux))
    assert float(aux) >= 0.95  # ≈1 lower bound, slack for fp


def test_gradients_flow(params):
    x = _x(5)

    def loss(p):
        out, aux = moe_block(p, x, top_k=2, capacity_factor=2.0, group_tokens=64)
        return (out ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(params)
    # every expert touched by routing gets gradient signal
    assert float(jnp.abs(g["wi"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0
