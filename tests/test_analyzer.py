"""Timing-analyzer correctness: ref vs JAX vs fine-grained DES + properties."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.analyzer import (
    EpochAnalyzer,
    FineGrainedSimulator,
    analyze_ref,
    serial_queue_ref,
)
from repro.core.events import MemEvents, synthetic_trace
from repro.core.topology import figure1_topology, two_tier_topology

FLAT = figure1_topology().flatten()


def _trace(n=2000, seed=0, burst=0.5, epoch=1e6):
    return synthetic_trace(n, FLAT.n_pools, epoch_ns=epoch, seed=seed, burstiness=burst)


# --------------------------------------------------------------------------- #
# agreement across implementations
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("seed,burst", [(0, 0.0), (1, 0.5), (2, 0.9)])
def test_ref_matches_fine_grained_congestion(seed, burst):
    """Epoch analyzer's congestion == event-by-event DES (stt service mode)."""
    ev = _trace(seed=seed, burst=burst)
    ref = analyze_ref(FLAT, ev)
    des = FineGrainedSimulator(FLAT, bandwidth_mode="stt").simulate(ev)
    assert ref.latency_ns == pytest.approx(des.latency_ns, rel=1e-9)
    assert ref.congestion_ns == pytest.approx(des.congestion_ns, rel=1e-6)
    np.testing.assert_allclose(
        ref.per_switch_congestion_ns, des.per_switch_congestion_ns, rtol=1e-6
    )


@pytest.mark.parametrize("impl", ["inline", "pallas_interpret"])
def test_jax_analyzer_matches_ref(impl):
    ev = _trace(seed=3, burst=0.7)
    ref = analyze_ref(FLAT, ev)
    got = EpochAnalyzer(FLAT, impl=impl).analyze(ev)
    assert got.latency_ns == pytest.approx(ref.latency_ns, rel=1e-4)
    assert got.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-3)
    # windowed bandwidth uses the same window count => close agreement
    assert got.bandwidth_ns == pytest.approx(ref.bandwidth_ns, rel=1e-2, abs=1.0)


def test_epoch_analyzer_bucketing_consistency():
    """Padding to a bigger bucket must not change results."""
    an = EpochAnalyzer(FLAT)
    ev = _trace(n=100)
    a = an.analyze(ev)
    b = an.analyze(ev)  # cached-compile second call
    assert a.total_ns == pytest.approx(b.total_ns)


def test_empty_trace():
    a = analyze_ref(FLAT, MemEvents.empty())
    assert a.total_ns == 0.0
    b = EpochAnalyzer(FLAT).analyze(MemEvents.empty())
    assert b.total_ns == 0.0


# --------------------------------------------------------------------------- #
# semantic properties (paper §3 definitions)
# --------------------------------------------------------------------------- #


def test_local_only_traffic_has_no_delay():
    ev = synthetic_trace(500, 1, epoch_ns=1e5, seed=0)  # all pool 0
    a = analyze_ref(FLAT, ev)
    assert a.total_ns == 0.0


def test_latency_delay_formula():
    """latency = Σ (pool_latency − local_latency) per event (paper §3)."""
    ev = MemEvents.build([10.0, 20.0, 30.0], [1, 2, 0], [64, 64, 64])
    a = analyze_ref(FLAT, ev)
    want = (FLAT.pool_latency_ns[1] - FLAT.local_latency_ns) + (
        FLAT.pool_latency_ns[2] - FLAT.local_latency_ns
    )
    assert a.latency_ns == pytest.approx(want)


def test_congestion_pushes_events_apart():
    """Two simultaneous events through one switch: second waits STT."""
    ev = MemEvents.build([100.0, 100.0], [1, 1], [64, 64])
    a = analyze_ref(FLAT, ev)
    # switch0 stt=2.0, RC stt=0.5: second event waits 2.0 at sw0; at the RC
    # arrivals are then 100.0 and 102.0 — already >0.5 apart, no extra wait
    assert a.congestion_ns == pytest.approx(2.0)


def test_bandwidth_delay_on_saturation():
    """Traffic over BW×window must stretch the window."""
    topo = two_tier_topology(cxl_bandwidth_gbps=1.0)  # 1 byte/ns
    flat = topo.flatten()
    # 100 events × 1 MB in ~1 us: 100 MB over a 1 byte/ns link ~ 1e8 ns needed
    ev = MemEvents.build(
        np.linspace(0, 1000.0, 100), [1] * 100, [1e6] * 100
    )
    a = analyze_ref(flat, ev)
    assert a.bandwidth_ns > 1e7  # must charge roughly bytes/bw


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 300),
    seed=st.integers(0, 10_000),
    burst=st.floats(0.0, 0.95),
)
def test_property_delays_nonnegative_and_monotone(n, seed, burst):
    ev = synthetic_trace(n, FLAT.n_pools, epoch_ns=1e5, seed=seed, burstiness=burst)
    a = analyze_ref(FLAT, ev)
    assert a.latency_ns >= 0 and a.congestion_ns >= 0 and a.bandwidth_ns >= 0
    # doubling every event's bytes can only increase bandwidth delay
    ev2 = MemEvents(ev.t_ns, ev.pool, ev.bytes_ * 2, ev.is_write, ev.region,
                    weight=ev.weight, host=ev.host, qos=ev.qos)
    b = analyze_ref(FLAT, ev2)
    assert b.bandwidth_ns >= a.bandwidth_ns - 1e-9
    # latency delay is independent of bytes
    assert b.latency_ns == pytest.approx(a.latency_ns)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), stt=st.floats(0.1, 50.0))
def test_property_serial_queue_invariants(seed, stt):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 400))
    arr = np.sort(rng.uniform(0, 1e4, n))
    out = serial_queue_ref(arr, stt)
    # never early, FIFO order preserved, spacing >= stt
    assert (out >= arr - 1e-9).all()
    assert (np.diff(out) >= stt - 1e-6).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_higher_latency_pool_costs_more(seed):
    base = two_tier_topology(cxl_latency_ns=150.0)
    slow = two_tier_topology(cxl_latency_ns=400.0)
    ev = synthetic_trace(200, 2, epoch_ns=1e5, seed=seed)
    a = analyze_ref(base.flatten(), ev)
    b = analyze_ref(slow.flatten(), ev)
    assert b.latency_ns >= a.latency_ns


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_event_order_permutation_invariant(seed):
    """Latency/bandwidth totals don't depend on trace array order."""
    ev = synthetic_trace(300, FLAT.n_pools, epoch_ns=1e5, seed=seed)
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(ev.n)
    a = analyze_ref(FLAT, ev)
    b = analyze_ref(FLAT, ev.take(perm))
    assert b.latency_ns == pytest.approx(a.latency_ns)
    assert b.congestion_ns == pytest.approx(a.congestion_ns, rel=1e-9)


def test_sampling_preserves_aggregate_bytes():
    ev = _trace(n=5000, seed=5)
    s = ev.sample(0.25, seed=1)
    assert s.n < ev.n
    assert s.total_bytes == pytest.approx(ev.total_bytes, rel=0.1)
