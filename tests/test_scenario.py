"""Scenario-sweep engine: vectorized-vs-loop policy parity, skeleton/gather
trace split, stacked topology lowering, and batched-vs-sequential oracle
agreement (ISSUE 4)."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    CACHELINE_BYTES,
    PAGE_BYTES,
    ClassMapPolicy,
    DeviceCacheConfig,
    DeviceCacheModel,
    HotnessTieredPolicy,
    InterleavePolicy,
    LocalOnlyPolicy,
    MemEvents,
    RegionArrays,
    RegionMap,
    Scenario,
    ScenarioSuite,
    Topology,
    TopologyOverride,
    analyze_ref,
    assign_batch,
    concat_events,
    figure1_topology,
    flatten_stack,
    skeleton_to_events,
    synthesize_skeleton,
    synthesize_step_trace,
    two_tier_topology,
)
from repro.core.tracer import Access, Phase, phase_duration_ns, TPU_V5E

FLAT = figure1_topology().flatten()
CLASSES = ["param", "grad", "opt_state", "kvcache", "activation"]


def random_regions(rng, n, max_bytes=1 << 22) -> RegionMap:
    rm = RegionMap()
    for i in range(n):
        r = rm.alloc(
            f"r{i}", int(rng.integers(1, max_bytes)), CLASSES[int(rng.integers(0, 5))]
        )
        r.access_count = float(rng.integers(0, 100))
    return rm


def random_policies(rng, rm):
    total = int(sum(r.nbytes for r in rm))
    return [
        LocalOnlyPolicy(),
        ClassMapPolicy({"opt_state": "cxl_pool2", "kvcache": "cxl_pool1"}),
        ClassMapPolicy({}),
        InterleavePolicy(["cxl_pool2", "cxl_pool3"]),
        InterleavePolicy(
            ["cxl_pool3", "cxl_pool1"],
            weights=[float(rng.integers(1, 5)), float(rng.integers(1, 5))],
            classes=["param", "grad"],
        ),
        HotnessTieredPolicy("cxl_pool1", local_budget_bytes=int(rng.integers(1, total + 1))),
        HotnessTieredPolicy(
            "cxl_pool2",
            hotness={f"r{i}": float(rng.integers(0, 50)) for i in range(0, len(rm), 2)},
            local_budget_bytes=total // 3,
        ),
    ]


# --------------------------------------------------------------------------- #
# policy parity: vectorized assign vs the place() loop oracle
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", range(8))
def test_assign_matches_place_randomized(seed):
    rng = np.random.default_rng(seed)
    rm = random_regions(rng, int(rng.integers(1, 60)))
    ra = RegionArrays.from_regions(rm)
    for pol in random_policies(rng, rm):
        vec = pol.assign(ra, FLAT)
        pol.place(rm, FLAT)
        np.testing.assert_array_equal(
            vec, rm.pool_vector(), err_msg=f"seed={seed} policy={pol.describe()}"
        )


def test_hotness_first_fit_boundary():
    """A region that overflows the budget leaves it untouched, so a later
    colder-but-smaller region still lands local (loop and vector agree)."""
    rm = RegionMap()
    rm.alloc("big", 1000, "param")
    rm.alloc("mid", 800, "param")
    rm.alloc("small", 200, "param")
    hot = {"big": 3000.0, "mid": 1600.0, "small": 200.0}  # density 3.0 / 2.0 / 1.0
    pol = HotnessTieredPolicy("cxl_pool1", hotness=hot, local_budget_bytes=1200)
    vec = pol.assign(RegionArrays.from_regions(rm), FLAT)
    pol.place(rm, FLAT)
    np.testing.assert_array_equal(vec, rm.pool_vector())
    assert rm["big"].pool == 0 and rm["small"].pool == 0  # first-fit skipped mid
    assert rm["mid"].pool == FLAT.pool_names.index("cxl_pool1")


@pytest.mark.parametrize("budget_off", [-1, 0, 1])
def test_hotness_exact_budget_boundary(budget_off):
    rng = np.random.default_rng(3)
    rm = random_regions(rng, 20, max_bytes=1 << 12)
    ra = RegionArrays.from_regions(rm)
    # budget exactly at / just around a prefix sum of the density order
    dens_order = np.argsort(
        -(ra.access_count / np.maximum(ra.nbytes, 1)), kind="stable"
    )
    budget = int(ra.nbytes[dens_order[:7]].sum()) + budget_off
    pol = HotnessTieredPolicy("cxl_pool2", local_budget_bytes=budget)
    vec = pol.assign(ra, FLAT)
    pol.place(rm, FLAT)
    np.testing.assert_array_equal(vec, rm.pool_vector())


def test_interleave_ties_follow_declared_pool_order():
    """Equal weights + equal sizes round-robin exactly in declaration order,
    regardless of which pool indices the names map to."""
    rm = RegionMap()
    for i in range(6):
        rm.alloc(f"r{i}", 1 << 20, "param")
    # declared order deliberately NOT pool-index order
    pol = InterleavePolicy(["cxl_pool3", "cxl_pool1", "cxl_pool2"])
    pol.place(rm, FLAT)
    i3 = FLAT.pool_names.index("cxl_pool3")
    i1 = FLAT.pool_names.index("cxl_pool1")
    i2 = FLAT.pool_names.index("cxl_pool2")
    assert rm.pool_vector().tolist() == [i3, i1, i2, i3, i1, i2]
    vec = pol.assign(RegionArrays.from_regions(rm), FLAT)
    np.testing.assert_array_equal(vec, rm.pool_vector())


def test_assign_batch_dedups_repeated_policies():
    rng = np.random.default_rng(0)
    rm = random_regions(rng, 12)
    ra = RegionArrays.from_regions(rm)
    pol = ClassMapPolicy({"opt_state": "cxl_pool2"})
    mat = assign_batch([pol, LocalOnlyPolicy(), pol], ra, FLAT)
    assert mat.shape == (3, len(rm))
    np.testing.assert_array_equal(mat[0], mat[2])
    assert (mat[1] == 0).all()


def test_assign_batch_dedups_granularity_copies():
    """with_granularity copies change the trace granule, never placement,
    so the sequential interleave recurrence must run once, not per copy."""
    rng = np.random.default_rng(1)
    rm = random_regions(rng, 12)
    ra = RegionArrays.from_regions(rm)
    pol = InterleavePolicy(["cxl_pool2", "cxl_pool3"], weights=[1, 2])
    page = pol.with_granularity(PAGE_BYTES)
    calls = []
    orig = InterleavePolicy.assign
    try:
        InterleavePolicy.assign = lambda self, ra, flat: (
            calls.append(1), orig(self, ra, flat))[1]
        mat = assign_batch([pol, page], ra, FLAT)
    finally:
        InterleavePolicy.assign = orig
    np.testing.assert_array_equal(mat[0], mat[1])
    assert len(calls) == 1


# --------------------------------------------------------------------------- #
# tracer skeleton/gather split
# --------------------------------------------------------------------------- #


def legacy_synthesize(phases, regions, granularity_bytes=64.0,
                      max_events_per_access=64, calibration=1.0, epoch_mode="step"):
    """The pre-split per-access loop (the skeleton's executable spec)."""
    per_phase, durs, cur = [], [], 0.0
    for ph in phases:
        dur = phase_duration_ns(ph, TPU_V5E)
        parts = []
        for a in ph.accesses:
            r = regions[a.region]
            b = a.bytes_ * calibration
            n_ev = int(min(max(np.ceil(b / granularity_bytes), 1), max_events_per_access))
            offs = (np.arange(n_ev, dtype=np.float64) + 0.5) / n_ev * dur
            base = 0.0 if epoch_mode == "layer" else cur
            parts.append(MemEvents(  # simlint: ignore[event-columns] -- built from scenario spec fields, not an event trace; exact weight / host-0 is the reference semantics
                t_ns=base + offs,
                pool=np.full((n_ev,), r.pool, np.int32),
                bytes_=np.full((n_ev,), b / n_ev, np.float64),
                is_write=np.full((n_ev,), a.is_write, bool),
                region=np.full((n_ev,), r.rid, np.int32),
            ))
        per_phase.append(concat_events(parts))
        durs.append(dur)
        cur += dur
    if epoch_mode == "layer":
        return per_phase, durs, [p.name for p in phases]
    return [concat_events(per_phase)], [float(sum(durs))], ["step"]


def _workload(seed=0, n_regions=10, n_phases=4):
    rng = np.random.default_rng(seed)
    rm = random_regions(rng, n_regions)
    phases = [
        Phase(
            f"ph{p}",
            float(rng.integers(1e10, 8e10)),
            tuple(
                Access(f"r{int(j)}", float(rng.integers(1e5, 3e6)), bool(rng.random() < 0.4))
                for j in rng.choice(n_regions, size=4, replace=False)
            ),
        )
        for p in range(n_phases)
    ]
    return rm, phases


@pytest.mark.parametrize("mode", ["step", "layer"])
@pytest.mark.parametrize("gran", [64.0, 4096.0])
def test_skeleton_matches_legacy_loop(mode, gran):
    rm, phases = _workload()
    rm["r3"].pool = 2
    rm["r5"].pool = 1
    got_tr, got_n, got_names = synthesize_step_trace(
        phases, rm, granularity_bytes=gran, epoch_mode=mode
    )
    ref_tr, ref_n, ref_names = legacy_synthesize(
        phases, rm, granularity_bytes=gran, epoch_mode=mode
    )
    assert got_names == ref_names and np.allclose(got_n, ref_n)
    assert len(got_tr) == len(ref_tr)
    for a, b in zip(got_tr, ref_tr):
        np.testing.assert_array_equal(a.t_ns, b.t_ns)
        np.testing.assert_array_equal(a.pool, b.pool)
        np.testing.assert_array_equal(a.bytes_, b.bytes_)
        np.testing.assert_array_equal(a.is_write, b.is_write)
        np.testing.assert_array_equal(a.region, b.region)


def test_skeleton_gather_is_placement_independent():
    rm, phases = _workload(seed=1)
    skel = synthesize_skeleton(phases, rm, granularity_bytes=256.0)
    a = skeleton_to_events(skel, np.zeros((len(rm),), np.int32))[0]
    pv = np.arange(len(rm), dtype=np.int32) % FLAT.n_pools
    b = skeleton_to_events(skel, pv)[0]
    np.testing.assert_array_equal(a.t_ns, b.t_ns)  # structure shared
    np.testing.assert_array_equal(b.pool, pv[skel.region])  # only pools move


def test_skeleton_unknown_region_raises():
    rm = RegionMap()
    rm.alloc("w", 100, "param")
    with pytest.raises(KeyError):
        synthesize_skeleton([Phase("p", 1e9, (Access("nope", 10.0),))], rm)


# --------------------------------------------------------------------------- #
# stacked topology lowering
# --------------------------------------------------------------------------- #


def test_flatten_stack_base_row_matches_flatten():
    t = figure1_topology()
    st = flatten_stack(t, [None, None])
    flat = t.flatten()
    np.testing.assert_allclose(st.pool_latency_ns[0], flat.pool_latency_ns)
    np.testing.assert_allclose(st.pool_bandwidth_gbps[1], flat.pool_bandwidth_gbps)
    np.testing.assert_allclose(st.switch_stt_ns[0], flat.switch_stt_ns)
    np.testing.assert_allclose(st.switch_bandwidth_gbps[0], flat.switch_bandwidth_gbps)
    np.testing.assert_allclose(st.local_latency_ns, flat.local_latency_ns)


def test_flatten_stack_member_matches_rebuilt_tree():
    t = figure1_topology()
    ov = TopologyOverride(
        pools={"cxl_pool1": {"latency_ns": 310.0, "bandwidth_gbps": 12.0}},
        switches={"switch1": {"stt_ns": 9.0, "bandwidth_gbps": 10.0, "latency_ns": 95.0}},
        rc_latency_ns=25.0,
        local_dram_latency_ns=70.0,
    )
    st = flatten_stack(t, [None, ov])
    pools = [
        dataclasses.replace(p, latency_ns=310.0, bandwidth_gbps=12.0)
        if p.name == "cxl_pool1" else p
        for p in t.pools
    ]
    sws = [
        dataclasses.replace(s, stt_ns=9.0, bandwidth_gbps=10.0, latency_ns=95.0)
        if s.name == "switch1" else s
        for s in t.switches
    ]
    ref = Topology(
        pools, sws, rc_latency_ns=25.0, rc_bandwidth_gbps=t.rc_bandwidth_gbps,
        rc_stt_ns=t.rc_stt_ns, local_dram_latency_ns=70.0,
    ).flatten()
    m = st.member(1)
    np.testing.assert_allclose(m.pool_latency_ns, ref.pool_latency_ns)
    np.testing.assert_allclose(m.pool_bandwidth_gbps, ref.pool_bandwidth_gbps)
    np.testing.assert_allclose(m.switch_stt_ns, ref.switch_stt_ns)
    np.testing.assert_allclose(m.switch_bandwidth_gbps, ref.switch_bandwidth_gbps)
    assert m.local_latency_ns == 70.0
    np.testing.assert_array_equal(m.route, ref.route)  # structure untouched


def test_flatten_stack_rejects_structural_overrides():
    t = two_tier_topology()
    with pytest.raises(ValueError):
        flatten_stack(t, [TopologyOverride(pools={"nope": {"latency_ns": 1.0}})])
    with pytest.raises(ValueError):
        flatten_stack(t, [TopologyOverride(pools={"cxl_pool": {"capacity_bytes": 1}})])


# --------------------------------------------------------------------------- #
# scenario batch vs sequential analyze_ref
# --------------------------------------------------------------------------- #


def _suite_and_grid(epoch_mode="step"):
    rm, phases = _workload(seed=2, n_regions=14, n_phases=5)
    topo = figure1_topology()
    suite = ScenarioSuite(topo, rm, phases, epoch_mode=epoch_mode)
    total = int(sum(r.nbytes for r in rm))
    policies = {
        "local": LocalOnlyPolicy(),
        "off": ClassMapPolicy({"opt_state": "cxl_pool2", "kvcache": "cxl_pool1"}),
        "il": InterleavePolicy(["cxl_pool2", "cxl_pool3"], weights=[1, 3]),
        "hot": HotnessTieredPolicy("cxl_pool1", local_budget_bytes=total // 2),
    }
    overrides = {
        "base": None,
        "slow": TopologyOverride(
            pools={"cxl_pool2": {"latency_ns": 420.0}},
            switches={"switch1": {"stt_ns": 30.0}},
        ),
        "thin": TopologyOverride(
            switches={"switch0": {"bandwidth_gbps": 1.0}, "switch1": {"bandwidth_gbps": 0.5}}
        ),
    }
    caches = {
        "nc": None,
        "c": DeviceCacheConfig(capacity_bytes=4 << 20, line_bytes=4096, n_sets=64),
    }
    scens = ScenarioSuite.cartesian(
        policies, overrides, caches, granularities=[CACHELINE_BYTES, PAGE_BYTES]
    )
    return rm, phases, suite, scens


@pytest.mark.parametrize("epoch_mode", ["step", "layer"])
def test_sweep_matches_sequential_analyze_ref(epoch_mode):
    rm, phases, suite, scens = _suite_and_grid(epoch_mode)
    res = suite.run(scens)
    assert suite.dispatch_count == 1  # the whole grid: ONE stacked dispatch
    stack = flatten_stack(suite.topology, [s.topology for s in scens])
    for k, s in enumerate(scens):
        flat_k = stack.member(k)
        s.policy.place(rm, suite.base_flat)
        traces, _, _ = synthesize_step_trace(
            phases, rm, granularity_bytes=s.policy.granularity_bytes,
            epoch_mode=epoch_mode,
        )
        model = (
            DeviceCacheModel(s.cache, flat_k, [rm]) if s.cache is not None else None
        )
        ref = None
        for tr in traces:
            span = max(float(tr.t_ns.max()) + 1.0 if tr.n else 0.0, suite.bw_window_ns)
            bww = max(span / suite.n_windows, 1.0)
            scale = model.observe_scale(tr) if model is not None else None
            bd = analyze_ref(
                flat_k, tr, bw_window_ns=bww, lat_scale=scale,
                n_windows=suite.n_windows,
            )
            ref = bd if ref is None else ref + bd
        got = res.breakdowns[k]
        for f in ("latency_ns", "congestion_ns", "bandwidth_ns"):
            a, b = getattr(got, f), getattr(ref, f)
            assert abs(a - b) / max(abs(b), 1.0) <= 1e-4, (
                f"{s.label()} {f}: {a} vs {b}"
            )
        np.testing.assert_allclose(
            got.per_pool_latency_ns, ref.per_pool_latency_ns, rtol=1e-4, atol=1.0
        )


def test_sweep_reuses_compile_cache_across_runs():
    _, _, suite, scens = _suite_and_grid()
    suite.run(scens)
    # the compile cache is process-global for the sweep kernel, so only
    # the *delta* is meaningful: re-running (even reordered) must not
    # trace or compile anything new — no per-scenario recompiles
    before = suite.compile_cache_size()
    suite.run(list(reversed(scens)))
    assert suite.dispatch_count == 2
    assert suite.compile_cache_size() == before


def test_sweep_dedups_cascades():
    """Latency/bandwidth/cache variants share placement+STT => one cascade."""
    rm, phases = _workload(seed=4)
    suite = ScenarioSuite(figure1_topology(), rm, phases)
    pol = ClassMapPolicy({"opt_state": "cxl_pool2"})
    scens = [
        Scenario(policy=pol, topology=TopologyOverride(
            pools={"cxl_pool2": {"latency_ns": float(l)}}))
        for l in (150.0, 250.0, 350.0, 450.0)
    ]
    suite.run(scens)
    assert suite.last_unique_cascades == 1
    # distinct stt rows break the dedup (worst case U == K, still correct)
    scens2 = [
        Scenario(policy=pol, topology=TopologyOverride(
            switches={"switch1": {"stt_ns": float(s)}}))
        for s in (2.0, 4.0, 8.0)
    ]
    res = suite.run(scens2)
    assert suite.last_unique_cascades == 3
    cong = [b.congestion_ns for b in res.breakdowns]
    assert cong[0] <= cong[1] <= cong[2]


def test_sweep_zero_bandwidth_is_unconstrained_not_nan():
    """bw=0 means an unconstrained component in analyze_ref; the stacked
    path must match (0/0 windows previously produced NaN totals that
    poisoned SweepResult.best())."""
    rm, phases = _workload(seed=6)
    suite = ScenarioSuite(figure1_topology(), rm, phases)
    pol = ClassMapPolicy({"opt_state": "cxl_pool2"})
    scens = [
        Scenario(policy=pol, name="base"),
        Scenario(policy=pol, name="bw0", topology=TopologyOverride(
            switches={"switch1": {"bandwidth_gbps": 0.0}})),
    ]
    res = suite.run(scens)
    totals = res.totals_ns()
    assert np.isfinite(totals).all()
    stack = flatten_stack(suite.topology, [s.topology for s in scens])
    pol.place(rm, suite.base_flat)
    traces, _, _ = synthesize_step_trace(phases, rm)
    span = max(float(traces[0].t_ns.max()) + 1.0, suite.bw_window_ns)
    ref = analyze_ref(
        stack.member(1), traces[0],
        bw_window_ns=max(span / suite.n_windows, 1.0), n_windows=suite.n_windows,
    )
    assert res.breakdowns[1].bandwidth_ns == pytest.approx(
        ref.bandwidth_ns, rel=1e-4, abs=1e-3
    )
    assert res.best() is not None  # frontier stays usable


def test_sweep_capacity_frontier():
    rm = RegionMap()
    rm.alloc("huge", int(FLAT.pool_capacity[1]) + 1, "opt_state")
    rm.alloc("w", 1 << 20, "param")
    phases = [Phase("p", 1e10, (Access("huge", 1e6), Access("w", 1e5)))]
    suite = ScenarioSuite(figure1_topology(), rm, phases)
    over = Scenario(policy=ClassMapPolicy({"opt_state": "cxl_pool1"}), name="over")
    ok = Scenario(policy=ClassMapPolicy({"opt_state": "cxl_pool2"}), name="ok")
    res = suite.run([over, ok])
    assert not res.feasible[0] and res.feasible[1]
    assert res.best() == 1  # infeasible scenario excluded from the frontier
    assert res.best(require_feasible=False) == 0  # ...unless asked not to
    assert res.best(max_slowdown=1.0 + 1e-12) is None
    with pytest.raises(ValueError):
        suite.run([over], on_overflow="raise")


def test_successive_halving_improves():
    rm, phases = _workload(seed=5)
    topo = two_tier_topology()
    suite = ScenarioSuite(topo, rm, phases)
    pol = ClassMapPolicy({"opt_state": "cxl_pool"})

    def mk(bw):
        return Scenario(
            policy=pol,
            topology=TopologyOverride(
                switches={"sw": {"bandwidth_gbps": float(bw)}},
                pools={"cxl_pool": {"bandwidth_gbps": float(bw)}},
            ),
            name=f"bw{bw:.4g}",
        )

    def refine(s, rnd):
        bw = float(s.topology.switches["sw"]["bandwidth_gbps"])
        return [mk(bw * 1.3), mk(bw / 1.3)]

    seeds = [mk(b) for b in (4.0, 16.0, 64.0)]
    res0 = suite.run(seeds)
    res, best = suite.successive_halving(seeds, refine, rounds=2)
    assert res.totals_ns()[best] <= res0.totals_ns().min() + 1e-6
    assert suite.dispatch_count == 4  # seed eval + 1 per round + initial run


# --------------------------------------------------------------------------- #
# satellites
# --------------------------------------------------------------------------- #


def test_hillclimb_module_docstring_survives():
    import repro.launch.hillclimb as hc

    assert hc.__doc__ and "hillclimb" in hc.__doc__


def test_with_granularity_copies():
    pol = ClassMapPolicy({"opt_state": "cxl_pool2"})
    page = pol.with_granularity(PAGE_BYTES)
    assert page.granularity_bytes == PAGE_BYTES
    assert pol.granularity_bytes == CACHELINE_BYTES
    assert page.class_to_pool == pol.class_to_pool
