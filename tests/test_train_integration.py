"""Integration: real training loop — loss decreases, checkpoint resume works,
simulator attaches, optimizer/compression compose."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train_loop
from repro.models import Model, ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.compression import ef_compress, init_error_state

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, dtype=jnp.float32, cache_dtype=jnp.float32, remat=False,
)


def test_loss_decreases_over_training():
    out = train_loop(TINY, steps=30, batch=4, seq=32, lr=3e-3, log_every=0)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first, f"loss did not decrease: {first:.3f} -> {last:.3f}"


def test_checkpoint_resume(tmp_path):
    d = str(tmp_path)
    out1 = train_loop(TINY, steps=10, batch=2, seq=16, ckpt_dir=d, ckpt_interval=5, log_every=0)
    out2 = train_loop(TINY, steps=14, batch=2, seq=16, ckpt_dir=d, ckpt_interval=5, log_every=0)
    assert out2["start_step"] == 6  # resumed after the step-5 checkpoint
    assert out2["steps"] == 8


def test_train_with_simulator_attached():
    out = train_loop(TINY, steps=5, batch=2, seq=16, simulate=True, log_every=0)
    assert "sim" in out
    assert out["sim"]["simulated_s"] >= out["sim"]["native_s"]
    assert out["sim"]["epochs"] == 5


def test_adamw_convergence_quadratic():
    """AdamW on a quadratic: ||x - target|| must shrink."""
    cfg = AdamWConfig(
        lr=0.2, weight_decay=0.0, grad_clip=0.0, total_steps=200,
        warmup_steps=1, min_lr_ratio=0.5,
    )
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum((p["x"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 0.05


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(cosine_schedule(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(cosine_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    end = float(cosine_schedule(cfg, jnp.asarray(100)))
    assert end == pytest.approx(0.1, abs=1e-6)


def test_ef_compression_error_feedback():
    """Residual carries quantization error; mean error stays bounded."""
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
    err = init_error_state(grads)
    total_in, total_out = jnp.zeros_like(grads["w"]), jnp.zeros_like(grads["w"])
    for _ in range(10):
        deq, err = ef_compress(grads, err)
        total_in += grads["w"]
        total_out += deq["w"]
    # with error feedback, accumulated dequantized grads track accumulated true
    rel = float(jnp.abs(total_out + err["w"] - total_in).max() / jnp.abs(total_in).max())
    assert rel < 1e-3


def test_train_step_with_compression_runs():
    from repro.launch.steps import make_train_step

    cfg = AdamWConfig(lr=1e-3, total_steps=10)
    model = Model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    opt = {"adam": adamw_init(params, cfg), "ef": init_error_state(params)}
    step = jax.jit(make_train_step(TINY, cfg, compress_grads=True))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    p2, o2, m = step(params, opt, {"tokens": toks, "labels": toks})
    assert np.isfinite(float(m["loss"]))
    # error state is live (non-zero residual somewhere)
    assert any(float(jnp.abs(l).max()) > 0 for l in jax.tree.leaves(o2["ef"]))
