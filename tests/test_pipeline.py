"""Device-resident epoch pipeline: on-device staging sort, donated ring
buffers, AOT dispatch cache, and the packed compact cascade.

Covers the PR's contract end to end:

  * the on-device merge kernels (`two_run_merge`, `staging_sort`) are
    **bitwise** equal to the host stable argsort they replace, pads and
    ties included;
  * `chain_cascade` matches the serial full-width cascade oracle;
  * a pipeline analyzer matches the classic jitted path and the numpy
    oracle on chain-eligible *and* ineligible topologies;
  * donated staging planes are actually consumed (reusing one raises);
  * the AOT executable cache reaches zero lowerings in steady state;
  * `presorted=` lets the oracles skip their re-sort without changing
    results;
  * the async engine's overlapped launch/finish dispatcher returns the
    same numbers as synchronous dispatch.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.analyzer import (
    DispatchStats,
    EpochAnalyzer,
    FineGrainedSimulator,
    analyze_ref,
    plan_chain,
)
from repro.core.engine import AnalysisEngine
from repro.core.events import EventStager, MemEvents, merge_host_traces, synthetic_trace
from repro.core.topology import (
    chained_topology,
    figure1_topology,
    pooled_topology,
    two_tier_topology,
)
from repro.kernels import ref


# --------------------------------------------------------------------------- #
# kernel oracles
# --------------------------------------------------------------------------- #


def _host_stable(keys, *payloads):
    order = np.argsort(keys, kind="stable")
    return (np.asarray(keys)[order],) + tuple(np.asarray(p)[order] for p in payloads)


def test_two_run_merge_bitwise_with_ties_and_pads(rng):
    w0, w1 = 37, 27
    a = np.sort(rng.integers(0, 20, w0)).astype(np.float32)  # many exact ties
    b = np.sort(rng.integers(0, 20, w1)).astype(np.float32)
    a[-5:] = np.inf  # pad tails
    b[-3:] = np.inf
    ids = np.arange(w0 + w1, dtype=np.int32)
    ids[w0 - 5 : w0] = -1
    ids[-3:] = -1
    x = np.concatenate([a, b])
    lead = np.arange(w0 + w1, dtype=np.int32) < w0
    got_x, got_i = ref.two_run_merge(
        jnp.asarray(x), jnp.asarray(lead), jnp.asarray(ids)
    )
    # host oracle: stable argsort of the run-major concatenation resolves
    # ties lower-run-first — exactly two_run_merge's tie contract
    exp_x, exp_i = _host_stable(x, ids)
    np.testing.assert_array_equal(np.asarray(got_x), exp_x)
    np.testing.assert_array_equal(np.asarray(got_i), exp_i)


@pytest.mark.parametrize("caps", [(16,), (16, 16), (8, 16, 4), (8, 8, 8, 8, 8)])
def test_staging_sort_bitwise_vs_host_argsort(rng, caps):
    total = sum(caps)
    xs, ids = [], []
    off = 0
    for c in caps:
        fill = int(rng.integers(0, c + 1))
        run = np.full((c,), np.inf, np.float32)
        run[:fill] = np.sort(rng.integers(0, 12, fill)).astype(np.float32)
        rid = np.full((c,), -1, np.int32)
        rid[:fill] = off + np.arange(fill, dtype=np.int32)
        xs.append(run)
        ids.append(rid)
        off += c
    x = np.concatenate(xs)
    idx = np.concatenate(ids)
    got_x, got_i = ref.staging_sort(jnp.asarray(x), caps, jnp.asarray(idx))
    # -1 pads all carry +inf keys; stable argsort keeps them run-ordered at
    # the tail, matching the merge tree's pad handling
    exp_x, exp_i = _host_stable(x, idx)
    np.testing.assert_array_equal(np.asarray(got_x), exp_x)
    np.testing.assert_array_equal(np.asarray(got_i), exp_i)


def test_staging_sort_vmapped_batch(rng):
    caps = (8, 16, 8)
    B, W = 4, sum(caps)
    x = np.full((B, W), np.inf, np.float32)
    idx = np.full((B, W), -1, np.int32)
    off = 0
    for c in caps:
        for b in range(B):
            fill = int(rng.integers(1, c + 1))
            x[b, off : off + fill] = np.sort(
                rng.uniform(0, 100, fill)
            ).astype(np.float32)
            idx[b, off : off + fill] = off + np.arange(fill, dtype=np.int32)
        off += c
    f = jax.vmap(lambda xx, ii: ref.staging_sort(xx, caps, ii))
    got_x, got_i = f(jnp.asarray(x), jnp.asarray(idx))
    for b in range(B):
        exp_x, exp_i = _host_stable(x[b], idx[b])
        np.testing.assert_array_equal(np.asarray(got_x[b]), exp_x)
        np.testing.assert_array_equal(np.asarray(got_i[b]), exp_i)


def test_chain_cascade_matches_serial_cascade(rng):
    # tie-free times => per-event finals are bitwise identical
    D = 4  # stages, deepest first; stage d's events traverse stages d..D-1
    caps = (8, 8, 16, 8)
    W = sum(caps)
    stts = np.asarray([7.0, 5.0, 3.0, 2.0], np.float32)
    t_pack = np.full((W,), np.inf, np.float32)
    idx = np.full((W,), -1, np.int32)
    entry = np.full((W,), -1, np.int32)
    off = 0
    for d, c in enumerate(caps):
        fill = int(rng.integers(1, c + 1))
        t_pack[off : off + fill] = np.sort(
            rng.uniform(0, 400, fill)
        ).astype(np.float32)
        idx[off : off + fill] = off + np.arange(fill, dtype=np.int32)
        entry[off : off + fill] = d
        off += c
    t_fin, i_fin, dsums = ref.chain_cascade(
        jnp.asarray(t_pack), jnp.asarray(idx), jnp.asarray(stts), caps
    )
    # serial oracle: flatten to one sorted timeline, run the full-width
    # cascade with nested masks (stage s serves every event entering at
    # depth <= s in deepest-first order)
    real = idx >= 0
    order = np.argsort(t_pack[real], kind="stable")
    t_sorted = t_pack[real][order]
    ent_sorted = entry[real][order]
    route_bits = np.zeros_like(ent_sorted)
    for s in range(D):
        route_bits |= np.where(ent_sorted <= s, 1 << s, 0)
    tf, _, ds = ref.serial_queue_cascade(
        jnp.asarray(t_sorted),
        jnp.asarray(route_bits),
        jnp.asarray(stts),
    )
    got = {int(i): float(t) for i, t in zip(np.asarray(i_fin), np.asarray(t_fin)) if i >= 0}
    exp = {
        int(i): float(t)
        for i, t in zip(idx[real][order], np.asarray(tf))
    }
    assert got == exp
    np.testing.assert_allclose(np.asarray(dsums), np.asarray(ds), rtol=1e-6)


# --------------------------------------------------------------------------- #
# staging: ring slots and the packed (zero-argsort) path
# --------------------------------------------------------------------------- #


def _trace(flat, n, seed):
    return synthetic_trace(n, flat.n_pools, seed=seed)


def test_stager_ring_slots_do_not_alias():
    flat = two_tier_topology().flatten()
    st = EventStager(slots=2)
    tr = [_trace(flat, 100, 1)]
    b1 = st.stage(tr, 1, 128)
    b2 = st.stage([_trace(flat, 100, 2)], 1, 128)
    assert b1["t"] is not b2["t"]  # double-buffered: fill never clobbers
    b3 = st.stage([_trace(flat, 100, 3)], 1, 128)
    assert b3["t"] is b1["t"]  # ring of 2 wraps around


def test_stage_packed_segments_are_sorted_runs():
    topo = chained_topology(3)
    flat = topo.flatten()
    plan = plan_chain(flat)
    assert plan is not None
    st = EventStager()
    traces = [_trace(flat, 200, s) for s in range(3)]
    buf, pack, caps = st.stage_packed(
        traces, 4, 256, plan.enter_stage, len(plan.stage_order)
    )
    assert sum(caps) == pack["t"].shape[1]
    off = 0
    for c in caps:
        seg = pack["t"][:, off : off + c]
        assert np.all(seg[:, 1:] >= seg[:, :-1])  # per-depth runs sorted free
        off += c
    # pads: -1 idx iff +inf key
    np.testing.assert_array_equal(pack["idx"] < 0, np.isinf(pack["t"]))


def test_memevents_build_avoids_list_roundtrip(rng):
    n = 200_000
    t = np.sort(rng.uniform(0, 1e6, n))
    pool = rng.integers(0, 3, n)
    by = np.full((n,), 64.0)
    import time as _time

    t0 = _time.perf_counter()
    ev = MemEvents.build(t_ns=t, pool=pool, bytes_=by)
    build_s = _time.perf_counter() - t0
    t0 = _time.perf_counter()
    for a in (t, pool, by):
        a.astype(a.dtype, copy=True)
    copy_s = _time.perf_counter() - t0
    assert ev.n == n
    # staging is O(copy): ndarray inputs must not detour through list()
    assert build_s < max(30 * copy_s, 0.05)
    # generators still work (the slow path is for non-arrays only)
    ev2 = MemEvents.build(
        t_ns=(float(x) for x in t[:10]),
        pool=(int(p) for p in pool[:10]),
        bytes_=(float(b) for b in by[:10]),
    )
    assert ev2.n == 10


# --------------------------------------------------------------------------- #
# pipeline analyzer: parity, donation, AOT steady state
# --------------------------------------------------------------------------- #


TOPOS = {
    "figure1": figure1_topology,
    "two_tier": two_tier_topology,
    "chained": lambda: chained_topology(4),
}


@pytest.mark.parametrize("name", sorted(TOPOS))
def test_pipeline_matches_baseline_and_oracle(name, rng):
    flat = TOPOS[name]().flatten()
    traces = [_trace(flat, 300 + 37 * i, 10 + i) for i in range(3)]
    base = EpochAnalyzer(flat, n_windows=32)
    pipe = EpochAnalyzer(flat, n_windows=32, pipeline=True)
    a = base.analyze_batch(traces)
    b = pipe.analyze_batch(traces)
    np.testing.assert_allclose(b.latency_ns, a.latency_ns, rtol=1e-4)
    np.testing.assert_allclose(b.congestion_ns, a.congestion_ns, rtol=1e-4)
    np.testing.assert_allclose(b.bandwidth_ns, a.bandwidth_ns, rtol=1e-4)
    # numpy float64 oracle: f32 accumulation differences stay under 1e-3
    ref_tot = sum(
        analyze_ref(flat, tr, n_windows=32).total_ns for tr in traces
    )
    np.testing.assert_allclose(b.total_ns, ref_tot, rtol=1e-3)


def test_pipeline_on_chain_ineligible_topology_falls_back(rng):
    # pooled: 2 hosts -> plan_chain refuses; pipeline still runs (AOT'd
    # full-plane graph) and matches the baseline bitwise-ish
    flat = pooled_topology(n_hosts=2).flatten()
    assert plan_chain(flat) is None
    traces = [
        _trace(flat, 256, 3).with_host(0),
        _trace(flat, 256, 4).with_host(1),
    ]
    merged = merge_host_traces(traces)
    base = EpochAnalyzer(flat, n_windows=32)
    pipe = EpochAnalyzer(flat, n_windows=32, pipeline=True)
    a = base.analyze_batch([merged])
    b = pipe.analyze_batch([merged])
    np.testing.assert_allclose(b.total_ns, a.total_ns, rtol=1e-4)
    assert pipe.last_dispatch.donated is False  # no donation off-chain
    assert pipe.last_dispatch.compute_s >= 0.0


def test_plan_chain_eligibility():
    assert plan_chain(chained_topology(4).flatten()) is not None
    assert plan_chain(figure1_topology().flatten()) is not None
    assert plan_chain(pooled_topology(n_hosts=2).flatten()) is None


def test_donated_buffer_is_consumed(rng):
    flat = chained_topology(3).flatten()
    pipe = EpochAnalyzer(flat, n_windows=32, pipeline=True)
    traces = [_trace(flat, 200, 7)]
    pend = pipe.launch_batch(traces)
    bd = pend.finish()
    assert bd.total_ns > 0
    st = pipe.last_dispatch
    assert st.donated, "chain dispatch must donate its staging planes"
    assert st.aot_cache_hit is False  # first dispatch lowers
    # the same shape again: donation again, zero new lowerings
    before = pipe._aot.lowerings
    pipe.launch_batch(traces).finish()
    assert pipe.last_dispatch.donated
    assert pipe.last_dispatch.aot_cache_hit
    assert pipe._aot.lowerings == before


def test_aot_cache_zero_lowerings_steady_state(rng):
    flat = chained_topology(3).flatten()
    pipe = EpochAnalyzer(flat, n_windows=32, pipeline=True)
    warm = [_trace(flat, 180, 99)]
    assert pipe.warmup(warm) is True
    assert pipe.warmup(warm) is False  # already warm
    # a short ramp lets the sticky per-stage caps reach their high-water
    # mark; after that the executable key is fixed
    for i in range(5):
        pipe.analyze_batch([_trace(flat, 150 + 10 * i, 1000 + i)])
    base = pipe._aot.lowerings
    for i in range(50):
        pipe.analyze_batch([_trace(flat, 150 + (i % 50), i)])
    assert pipe._aot.lowerings == base, "steady state must not recompile"
    assert pipe._aot.hits >= 50


def test_warmup_noop_for_non_pipeline():
    flat = two_tier_topology().flatten()
    base = EpochAnalyzer(flat, n_windows=32)
    assert base.warmup([_trace(flat, 64, 0)]) is False


def test_dispatch_stats_timing_fields_populated(rng):
    flat = chained_topology(3).flatten()
    pipe = EpochAnalyzer(flat, n_windows=32, pipeline=True)
    pipe.analyze_batch([_trace(flat, 300, 1)])
    st = pipe.last_dispatch
    assert isinstance(st, DispatchStats)
    assert st.stage_s > 0 and st.transfer_s > 0 and st.compute_s > 0
    assert st.compile_s > 0  # first dispatch carries the lowering
    pipe.analyze_batch([_trace(flat, 300, 2)])
    assert pipe.last_dispatch.compile_s == 0.0  # hits are free


# --------------------------------------------------------------------------- #
# presorted oracles
# --------------------------------------------------------------------------- #


def test_analyze_ref_presorted_parity(rng):
    flat = pooled_topology(n_hosts=2).flatten()
    merged = merge_host_traces(
        [_trace(flat, 300, 1).with_host(0), _trace(flat, 300, 2).with_host(1)]
    )
    a = analyze_ref(flat, merged, n_windows=32)
    b = analyze_ref(flat, merged, n_windows=32, presorted=True)
    assert a.total_ns == b.total_ns
    np.testing.assert_array_equal(
        a.per_switch_congestion_ns, b.per_switch_congestion_ns
    )


def test_fine_simulator_presorted_parity(rng):
    flat = two_tier_topology().flatten()
    tr = _trace(flat, 200, 5).sorted_by_time()
    sim = FineGrainedSimulator(flat)
    a = sim.simulate(tr)
    b = sim.simulate(tr, presorted=True)
    assert a.total_ns == b.total_ns


# --------------------------------------------------------------------------- #
# engine: overlapped launch/finish dispatcher
# --------------------------------------------------------------------------- #


def test_engine_overlapped_pipeline_matches_sync(rng):
    import threading

    flat = chained_topology(3).flatten()
    batches = [[_trace(flat, 200 + 11 * j, 10 * i + j) for j in range(2)] for i in range(5)]
    sync = EpochAnalyzer(flat, n_windows=32)
    expect = [sync.analyze_batch(b) for b in batches]

    eng = AnalysisEngine()
    try:
        pipe = EpochAnalyzer(flat, n_windows=32, pipeline=True)
        h = eng.register(pipe)
        got = {}
        lock = threading.Lock()
        for i, b in enumerate(batches):
            def fold(bd, elapsed, i=i):
                with lock:
                    got[i] = bd
            h.submit(b, None, fold=fold)
        h.flush()
        assert sorted(got) == list(range(5))
        for i in range(5):
            np.testing.assert_allclose(
                got[i].total_ns, expect[i].total_ns, rtol=1e-4
            )
        h.close()
    finally:
        eng.close()
