"""Vocab padding must be loss- and argmax-identical to the unpadded model."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, ModelConfig

BASE = ModelConfig(
    name="vp", family="dense", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab_size=101,  # deliberately odd
    dtype=jnp.float32, cache_dtype=jnp.float32, remat=False,
)


def _tokens(B=2, S=16):
    return jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, BASE.vocab_size)


def test_padded_loss_matches_unpadded():
    cfg_p = dataclasses.replace(BASE, pad_vocab_to_multiple=16)
    m0, mp = Model(BASE), Model(cfg_p)
    p0 = m0.init(jax.random.PRNGKey(0))
    pp = mp.init(jax.random.PRNGKey(0))
    # graft the unpadded weights into the padded model
    pp["embed"] = pp["embed"].at[: BASE.vocab_size].set(p0["embed"])
    toks = _tokens()
    batch = {"tokens": toks, "labels": toks}
    # blocks share init keys only if structures match; rebuild padded blocks
    pp["blocks"] = p0["blocks"]
    pp["final_norm"] = p0["final_norm"]
    l0, _ = m0.loss(p0, batch)
    lp, _ = mp.loss(pp, batch)
    np.testing.assert_allclose(float(l0), float(lp), rtol=1e-6)


def test_padded_argmax_never_selects_pad():
    cfg_p = dataclasses.replace(BASE, pad_vocab_to_multiple=64)
    mp = Model(cfg_p)
    pp = mp.init(jax.random.PRNGKey(0))
    logits, _ = mp.forward(pp, _tokens())
    assert cfg_p.padded_vocab == 128
    pred = jnp.argmax(logits, -1)
    assert int(pred.max()) < BASE.vocab_size


def test_padded_vocab_noop_when_divisible():
    cfg = dataclasses.replace(BASE, vocab_size=128, pad_vocab_to_multiple=16)
    assert cfg.padded_vocab == 128
