"""End-to-end behaviour tests for the paper's system.

The paper's three claims, asserted against our implementation:
  1. CXLMemSim attaches to an unmodified program and prices a user-provided
     topology (attach pipeline works end to end on a real train step);
  2. it is much faster than fine-grained simulation (epoch batching wins);
  3. its epoch-batched delays agree with event-by-event simulation.
"""

import dataclasses
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as cfgs
from repro.core import (
    CXLMemSim,
    ClassMapPolicy,
    EpochSchedule,
    figure1_topology,
)
from repro.core.analyzer import FineGrainedSimulator, analyze_ref
from repro.core.events import synthetic_trace
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.models.phases import build_regions_and_phases
from repro.optim.adamw import AdamWConfig, adamw_init


def test_claim1_attach_prices_topology_on_real_training():
    cfg = dataclasses.replace(
        cfgs.get_smoke("starcoder2-3b"), dtype=jnp.float32, cache_dtype=jnp.float32
    )
    model = Model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=10)
    params = model.init(jax.random.PRNGKey(0))
    opt = {"adam": adamw_init(params, opt_cfg), "ef": {}}
    step = jax.jit(make_train_step(cfg, opt_cfg))
    regions, phases = build_regions_and_phases(cfg, "train", batch=2, seq=64)

    sim = CXLMemSim(
        figure1_topology(),
        ClassMapPolicy({"opt_state": "cxl_pool2", "grad": "cxl_pool1"}),
        epoch=EpochSchedule("layer"),
        check_capacity=False,
    )
    prog = sim.attach(step, phases, regions)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    for _ in range(3):
        params, opt, metrics = prog.step(params, opt, batch)
    r = prog.report
    assert r.steps == 3 and r.epochs == 3 * (cfg.n_groups + 3)  # embed+groups+loss+opt
    assert r.simulated_s > r.native_s  # remote pools must cost something
    assert r.per_pool_latency_ns[2] > 0 or r.per_pool_latency_ns[3] > 0
    assert np.isfinite(float(metrics["loss"]))


def test_claim2_epoch_analyzer_much_faster_than_fine_grained():
    flat = figure1_topology().flatten()
    ev = synthetic_trace(50_000, flat.n_pools, epoch_ns=1e6, seed=0, burstiness=0.5)
    t0 = time.perf_counter()
    analyze_ref(flat, ev)
    epoch_t = time.perf_counter() - t0
    des = FineGrainedSimulator(flat, bandwidth_mode="stt")
    t0 = time.perf_counter()
    des.simulate(ev)
    des_t = time.perf_counter() - t0
    assert des_t / epoch_t > 5, f"epoch speedup only {des_t/epoch_t:.1f}x"


def test_claim3_epoch_matches_event_by_event():
    flat = figure1_topology().flatten()
    for seed in range(3):
        ev = synthetic_trace(5_000, flat.n_pools, epoch_ns=5e5, seed=seed, burstiness=0.8)
        a = analyze_ref(flat, ev)
        b = FineGrainedSimulator(flat, bandwidth_mode="stt").simulate(ev)
        assert a.latency_ns == pytest.approx(b.latency_ns)
        assert a.congestion_ns == pytest.approx(b.congestion_ns, rel=1e-6)


def test_dryrun_compiles_on_a_small_production_mesh():
    """End-to-end dry-run proof at reduced scale: 8 virtual devices (2 data x
    4 model), one arch x one shape, in a subprocess so nothing leaks into
    this process's already-initialized jax.  The 8-device XLA flag itself is
    inherited from conftest's environment."""
    code = r"""
import dataclasses, jax
assert jax.device_count() == 8, jax.devices()
import repro.configs as cfgs
from repro.launch.dryrun import run_cell
mesh = jax.make_mesh((2, 4), ("data", "model"))
rec = run_cell("qwen3-0.6b", "train_4k", mesh, "test_2x4",
               cfg_override=dataclasses.replace(
                   cfgs.get_config("qwen3-0.6b"), n_layers=4))
assert rec["roofline"]["compute_s"] > 0
assert rec["roofline"]["memory_s"] > 0
assert rec["collectives"]["total"] > 0
print("DRYRUN_OK", rec["roofline"]["dominant"])
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": os.path.join(repo, "src"), "JAX_PLATFORMS": "cpu"},
        cwd=repo,
    )
    assert "DRYRUN_OK" in out.stdout, out.stderr[-2000:]
