"""Sharded dispatch + FleetSim: parity, observability, and fleet scheduling.

The conftest boots jax with 8 virtual CPU devices, so every test here runs
against a real multi-device ('data',) mesh.  The contract under test:
sharding the leading scenario/session/rack axis over the mesh changes
*where* planes compute, never *what* they compute — bitwise for the
analyzer paths (identical per-plane program), <=1e-6 relative for the
sweep — and every dispatch reports its device/shard/padding observability.
"""

import warnings

import numpy as np
import pytest

import repro.configs as cfgs
from repro.core.analyzer import DispatchStats, EpochAnalyzer
from repro.core.engine import AnalysisEngine
from repro.core.events import synthetic_trace
from repro.core.fleet import FleetSim, synthetic_tenant
from repro.core.policy import ClassMapPolicy, InterleavePolicy
from repro.core.scenario import Scenario, ScenarioSuite
from repro.core.topology import TopologyOverride, figure1_topology, pooled_topology
from repro.distributed.sharding import (
    pad_to_multiple,
    resolve_data_mesh,
)
from repro.models.phases import build_regions_and_phases


def _session_groups(flat, k, b=3, n=300):
    return [
        [
            synthetic_trace(n, flat.n_pools, epoch_ns=1e6, seed=7 * i + j)
            .with_host(i % flat.n_hosts)
            for j in range(b)
        ]
        for i in range(k)
    ]


# --------------------------------------------------------------------------- #
# mesh resolution / fallback / errors
# --------------------------------------------------------------------------- #


def test_conftest_provides_eight_virtual_devices(data_mesh):
    import jax

    assert jax.device_count() == 8
    assert data_mesh.shape == {"data": 8}


def test_resolve_rejects_mesh_without_data_axis():
    import jax

    mesh = jax.make_mesh((2, 4), ("a", "b"))
    with pytest.raises(ValueError, match="data"):
        resolve_data_mesh(mesh, 8)


def test_resolve_falls_back_when_devices_exceed_rows(data_mesh):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sub, n = resolve_data_mesh(data_mesh, 5)
    assert n == 5 and sub is not None
    assert any("falling back" in str(x.message) for x in w)
    # one row: nothing to shard at all
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        sub1, n1 = resolve_data_mesh(data_mesh, 1)
    assert sub1 is None and n1 == 1


def test_pad_to_multiple():
    assert pad_to_multiple(16, 8) == 16
    assert pad_to_multiple(17, 8) == 24
    assert pad_to_multiple(5, 1) == 5
    assert pad_to_multiple(5, 0) == 5


# --------------------------------------------------------------------------- #
# analyzer: coalesced multi-session dispatch parity (bitwise)
# --------------------------------------------------------------------------- #


def test_analyze_batch_multi_sharded_bitwise_parity(data_mesh):
    flat = pooled_topology(n_hosts=4).flatten()
    groups = _session_groups(flat, 11)  # uneven: bucket(11)=16 -> 2 rows/device
    plain = EpochAnalyzer(flat, n_windows=64)
    sharded = EpochAnalyzer(flat, n_windows=64, mesh=data_mesh)
    a = plain.analyze_batch_multi(groups)
    b = sharded.analyze_batch_multi(groups)
    for x, y in zip(a, b):
        assert x.latency_ns == y.latency_ns
        assert x.congestion_ns == y.congestion_ns
        assert x.bandwidth_ns == y.bandwidth_ns
        np.testing.assert_array_equal(x.per_host_total_ns, y.per_host_total_ns)
    assert sharded.last_dispatch == DispatchStats(
        devices_used=8, shard_rows=2, rows=11, padded_fraction=5 / 16
    )
    assert plain.last_dispatch.devices_used == 1
    assert sharded.sharded_dispatches == 1


def test_analyze_batch_multi_per_call_mesh_overrides_constructor(data_mesh):
    flat = pooled_topology(n_hosts=2).flatten()
    groups = _session_groups(flat, 8, b=2, n=128)
    plain = EpochAnalyzer(flat, n_windows=64)
    a = plain.analyze_batch_multi(groups)
    b = plain.analyze_batch_multi(groups, mesh=data_mesh)
    for x, y in zip(a, b):
        assert x.latency_ns == y.latency_ns
    assert plain.last_dispatch.devices_used == 8
    assert plain.sharded_dispatches == 1


def test_analyze_batch_multi_fewer_rows_than_devices_warns_and_matches(data_mesh):
    flat = pooled_topology(n_hosts=2).flatten()
    groups = _session_groups(flat, 5, b=2, n=128)
    plain = EpochAnalyzer(flat, n_windows=64)
    sharded = EpochAnalyzer(flat, n_windows=64, mesh=data_mesh)
    a = plain.analyze_batch_multi(groups)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        b = sharded.analyze_batch_multi(groups)
    assert any("falling back" in str(x.message) for x in w)
    for x, y in zip(a, b):
        assert x.latency_ns == y.latency_ns
    assert sharded.last_dispatch.devices_used == 5


# --------------------------------------------------------------------------- #
# scenario suite: sweep parity (<=1e-6) + observability in table()
# --------------------------------------------------------------------------- #


def _sweep_fixtures(n_scen=8):
    cfg = cfgs.get_smoke("starcoder2-3b")
    regions, phases = build_regions_and_phases(cfg, "train", batch=2, seq=64)
    scens = []
    for i in range(n_scen):
        lat = 150 + 25 * i
        ov = TopologyOverride(pools={"cxl_pool1": {"latency_ns": lat}})
        pol = (
            ClassMapPolicy({"opt_state": "cxl_pool2", "grad": "cxl_pool1"})
            if i % 2
            else InterleavePolicy(["cxl_pool1", "cxl_pool2"])
        )
        scens.append(Scenario(pol, ov, name=f"s{i}"))
    return figure1_topology(), regions, phases, scens


def test_scenario_sweep_sharded_parity(data_mesh):
    topo, regions, phases, scens = _sweep_fixtures(8)
    plain = ScenarioSuite(topo, regions, phases)
    sharded = ScenarioSuite(topo, regions, phases, mesh=data_mesh)
    ra = plain.run(scens)
    rb = sharded.run(scens)
    for a, b in zip(ra.breakdowns, rb.breakdowns):
        assert b.total_ns == pytest.approx(a.total_ns, rel=1e-6)
        assert b.latency_ns == pytest.approx(a.latency_ns, rel=1e-6)
    assert ra.devices_used == 1 and rb.devices_used == 8
    assert rb.shard_rows == 1 and rb.padded_fraction == 0.0
    row = rb.table()[0]
    assert row["devices_used"] == 8
    assert row["shard_rows"] == 1
    assert row["padded_fraction"] == 0.0


def test_scenario_sweep_uneven_k_falls_back(data_mesh):
    topo, regions, phases, scens = _sweep_fixtures(6)
    plain = ScenarioSuite(topo, regions, phases)
    sharded = ScenarioSuite(topo, regions, phases, mesh=data_mesh)
    ra = plain.run(scens)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rb = sharded.run(scens)
    assert any("falling back" in str(x.message) for x in w)
    for a, b in zip(ra.breakdowns, rb.breakdowns):
        assert b.total_ns == pytest.approx(a.total_ns, rel=1e-6)
    assert rb.devices_used == 6


# --------------------------------------------------------------------------- #
# engine: mesh plumbing + report observability counters
# --------------------------------------------------------------------------- #


def test_engine_mesh_parity_and_handle_stats(data_mesh):
    flat = pooled_topology(n_hosts=4).flatten()
    groups = _session_groups(flat, 4, b=2, n=200)
    ref = EpochAnalyzer(flat, n_windows=64).analyze_batch_multi(groups)
    eng = AnalysisEngine("fleet-test", mesh=data_mesh)
    try:
        handles = [eng.register(EpochAnalyzer(flat, n_windows=64)) for _ in groups]
        futs = [h.submit(g) for h, g in zip(handles, groups)]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # <8 coalesced rows may fall back
            got = [f.result(60) for f in futs]
        for x, y in zip(ref, got):
            assert x.latency_ns == y.latency_ns
            assert x.congestion_ns == y.congestion_ns
            assert x.bandwidth_ns == y.bandwidth_ns
        coalesced = [h for h in handles if h.last_group_size > 1]
        if coalesced:  # timing-dependent, but stats must be coherent
            st = coalesced[0].last_dispatch
            assert st is not None and st.devices_used >= 1
    finally:
        eng.close()


def test_sim_report_summary_carries_dispatch_observability():
    from repro.core.attach import SimReport

    s = SimReport().summary()
    assert s["devices_used"] == 1
    assert s["shard_rows"] == 0
    assert s["padded_waste"] == 0.0
    assert s["coalesced_group_size"] == 1


# --------------------------------------------------------------------------- #
# FleetSim: scheduling, stranding accounting, frontier, sharded parity
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def fleet_tenants():
    return [synthetic_tenant(f"t{i}", seed=i, gib=8.0) for i in range(24)]


def _mini_fleet(**kw):
    kw.setdefault("granularity_bytes", 65536)
    kw.setdefault("max_events_per_access", 16)
    return FleetSim(n_racks=4, hosts_per_rack=4, **kw)


def test_fleet_placement_accounting(fleet_tenants):
    fleet = _mini_fleet()
    placements = fleet.place(fleet_tenants, policy="least_loaded", offload_fraction=1.0)
    assert len(placements) == 24
    for p in placements:
        assert 0 <= p.rack < 4 and 0 <= p.host < 4
        # local + pooled partitions the tenant's demand
        assert p.local_bytes + p.pooled_bytes == pytest.approx(
            p.tenant.demand_bytes()
        )
        # offload_fraction=1.0 moves every offloadable class
        off = sum(
            r.nbytes
            for r in p.tenant.regions.regions
            if r.tensor_class in fleet.offload_classes
        )
        assert p.pooled_bytes == pytest.approx(off)
        # pool_of_region is consistent with the byte split
        pooled = sum(
            r.nbytes
            for r in p.tenant.regions.regions
            if p.pool_of_region[r.rid] == fleet.shared_pool
        )
        assert pooled == pytest.approx(p.pooled_bytes)


def test_fleet_round_robin_spreads_tenants(fleet_tenants):
    fleet = _mini_fleet()
    placements = fleet.place(fleet_tenants[:16], policy="round_robin")
    slots = {(p.rack, p.host) for p in placements}
    assert len(slots) == 16  # 16 tenants over 16 hosts: one each


def test_fleet_rejects_duplicate_names(fleet_tenants):
    with pytest.raises(ValueError, match="unique"):
        _mini_fleet().place([fleet_tenants[0], fleet_tenants[0]])


def test_fleet_overflow_raises_clear_error():
    huge = [synthetic_tenant("huge", seed=1, gib=500.0)]
    with pytest.raises(ValueError, match="local DRAM"):
        FleetSim(n_racks=1, hosts_per_rack=2).place(huge, offload_fraction=0.0)


def test_fleet_simulate_report(fleet_tenants):
    fleet = _mini_fleet()
    rep = fleet.simulate(fleet_tenants, offload_fraction=1.0)
    assert rep.n_hosts == 16 and rep.n_tenants == 24
    assert rep.stranded_recovered_bytes > 0
    assert rep.p99_slowdown() >= rep.mean_slowdown() >= 1.0
    assert rep.tenant_slowdowns().shape == (24,)
    s = rep.summary()
    assert s["stranded_recovered_gb"] > 0
    assert s["devices_used"] == 1


def test_fleet_simulate_sharded_parity(data_mesh, fleet_tenants):
    plain = _mini_fleet()
    sharded = _mini_fleet(mesh=data_mesh)
    a = plain.simulate(fleet_tenants, offload_fraction=1.0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        b = sharded.simulate(fleet_tenants, offload_fraction=1.0)
    assert any("falling back" in str(x.message) for x in w)  # 4 racks < 8 dev
    np.testing.assert_allclose(b.delay_ns, a.delay_ns, rtol=1e-6)
    np.testing.assert_array_equal(b.native_ns, a.native_ns)
    assert b.devices_used == 4
    assert a.devices_used == 1


def test_fleet_frontier_monotone_and_one_dispatch(data_mesh, fleet_tenants):
    plain = _mini_fleet()
    sharded = _mini_fleet(mesh=data_mesh)
    fracs = (0.0, 0.5, 1.0)
    pts = plain.frontier(fleet_tenants, offload_fractions=fracs)
    assert [p.offload_fraction for p in pts] == list(fracs)
    gb = [p.stranded_recovered_gb for p in pts]
    assert gb[0] == 0.0
    assert all(b >= a for a, b in zip(gb, gb[1:]))
    # F*R = 12 planes stacked into ONE dispatch
    n0 = plain.dispatch_count
    plain.frontier(fleet_tenants, offload_fractions=fracs)
    assert plain.dispatch_count == n0 + 1
    # sharded frontier matches plane for plane (K=12 -> fallback submesh)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        pts_m = sharded.frontier(fleet_tenants, offload_fractions=fracs)
    for a, b in zip(pts, pts_m):
        np.testing.assert_allclose(b.report.delay_ns, a.report.delay_ns, rtol=1e-6)
    # frontier end point == standalone simulate at the same fraction
    rep = plain.simulate(fleet_tenants, offload_fraction=1.0)
    np.testing.assert_allclose(pts[-1].report.delay_ns, rep.delay_ns, rtol=1e-6)


def test_fleet_heterogeneous_rack_overrides(fleet_tenants):
    slow = TopologyOverride(pools={"shared_pool": {"latency_ns": 400.0}})
    uniform = _mini_fleet()
    mixed = FleetSim(
        n_racks=4,
        hosts_per_rack=4,
        rack_overrides=[None, None, slow, slow],
        granularity_bytes=65536,
        max_events_per_access=16,
    )
    # round_robin gives identical placements, so rack deltas isolate topology
    a = uniform.simulate(fleet_tenants, policy="round_robin", offload_fraction=1.0)
    b = mixed.simulate(fleet_tenants, policy="round_robin", offload_fraction=1.0)
    np.testing.assert_allclose(b.delay_ns[:2], a.delay_ns[:2], rtol=1e-6)
    assert (b.delay_ns[2:] > a.delay_ns[2:]).all()


def test_fleet_zero_offload_keeps_everything_local(fleet_tenants):
    fleet = _mini_fleet()
    rep = fleet.simulate(fleet_tenants[:8], offload_fraction=0.0)
    assert rep.stranded_recovered_bytes == 0.0
    for p in rep.placements:
        assert (p.pool_of_region == fleet.local_pool).all()
