"""Shared async analysis engine: cross-session coalescing, lifecycle,
dropped-batch accounting, report-race regression, and the attach/fabric
rewiring on top of it (ISSUE 5)."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Access,
    AnalysisEngine,
    CXLMemSim,
    ClassMapPolicy,
    DelayBreakdown,
    DeviceCacheConfig,
    EpochAnalyzer,
    FabricReport,
    FabricSession,
    HostClock,
    MigrationConfig,
    MigrationSimulator,
    Phase,
    RegionMap,
    SimReport,
    Tenant,
    pooled_topology,
    synthetic_trace,
    two_tier_topology,
)
from repro.core.engine import dispatch_key


# --------------------------------------------------------------------------- #
# helpers
# --------------------------------------------------------------------------- #


class _SlowAnalyzer:
    """Non-coalescible stub that parks the dispatcher so later submissions
    from other handles pile up and coalesce."""

    def __init__(self, flat, sleep_s=0.25):
        self.flat = flat
        self.sleep_s = sleep_s

    def simulate(self, tr, lat_scale=None):
        time.sleep(self.sleep_s)
        return DelayBreakdown.zero(
            self.flat.n_pools, self.flat.n_switches, self.flat.n_hosts
        )


class _FlakyAnalyzer(EpochAnalyzer):
    """Raises on one specific analyze_batch call (per-batch failure stub)."""

    def __init__(self, *args, fail_on=2, **kwargs):
        super().__init__(*args, **kwargs)
        self.calls = 0
        self.fail_on = fail_on

    def analyze_batch(self, traces, lat_scales=None, stager=None):
        self.calls += 1
        if self.calls == self.fail_on:
            raise RuntimeError("injected analyzer failure")
        return super().analyze_batch(traces, lat_scales, stager=stager)


def _toy_attach(engine=None, async_mode=True, **sim_kw):
    regions = RegionMap()
    regions.alloc("w", 1 << 22, "param")
    regions.alloc("opt", 1 << 23, "opt_state")
    phases = [
        Phase("fwd", flops=1e8, accesses=(Access("w", 1 << 22),)),
        Phase("opt", flops=1e7, accesses=(Access("opt", 1 << 23, True),)),
    ]
    step = jax.jit(lambda x: (x * x).sum())
    sim = CXLMemSim(
        two_tier_topology(),
        ClassMapPolicy({"opt_state": "cxl_pool"}),
        async_analysis=async_mode,
        engine=engine,
        **sim_kw,
    )
    return sim.attach(step, phases, regions)


def _tenants(n=2, mults=None, step=False):
    out = []
    for i in range(n):
        mult = 1 if mults is None else mults[i]
        rm = RegionMap()
        rm.alloc("w", 1 << 22, "param")
        rm.alloc("kv", 1 << 22, "kvcache")
        phases = [
            Phase(
                "fwd",
                flops=5e8,
                accesses=(
                    Access("w", mult * (1 << 22)),
                    Access("kv", mult * (1 << 22), True),
                ),
            )
        ]
        step_fn = jax.jit(lambda x: (x @ x.T).sum()) if step else None
        args = (jnp.ones((32, 32)),) if step else ()
        out.append(
            Tenant(
                f"t{i}", phases, rm, ClassMapPolicy({"kvcache": "shared_pool"}),
                step_fn=step_fn, step_args=args,
            )
        )
    return out


# --------------------------------------------------------------------------- #
# engine core: futures, coalescing, lifecycle
# --------------------------------------------------------------------------- #


def test_engine_solo_submit_matches_sync_bitwise():
    """A solo submission runs the exact analyze_batch path: identical bits."""
    flat = pooled_topology(n_hosts=1).flatten()
    an = EpochAnalyzer(flat)
    traces = [synthetic_trace(700, flat.n_pools, seed=3, burstiness=0.6)]
    ref = an.analyze_batch(traces)
    with AnalysisEngine() as eng:
        h = eng.register(an)
        got = h.submit(traces).result(timeout=60)
        h.flush()
    assert got.latency_ns == ref.latency_ns
    assert got.congestion_ns == ref.congestion_ns
    assert got.bandwidth_ns == ref.bandwidth_ns
    np.testing.assert_array_equal(got.per_pool_latency_ns, ref.per_pool_latency_ns)


def test_dispatch_key_groups_equal_topologies_only():
    flat = pooled_topology(n_hosts=1).flatten()
    a, b = EpochAnalyzer(flat), EpochAnalyzer(pooled_topology(n_hosts=1).flatten())
    assert dispatch_key(a) == dispatch_key(b)
    c = EpochAnalyzer(pooled_topology(n_hosts=1, cxl_bandwidth_gbps=1.0).flatten())
    assert dispatch_key(a) != dispatch_key(c)
    d = EpochAnalyzer(flat, n_windows=64)
    assert dispatch_key(a) != dispatch_key(d)
    # Pallas impls never coalesce (epoch loop unvalidated under session vmap)
    e = EpochAnalyzer(flat, impl="pallas_interpret")
    assert dispatch_key(e) is None


def test_engine_coalesces_cross_session_not_same_session():
    """While the dispatcher is parked, submissions from K distinct handles
    coalesce into ONE stacked dispatch; two batches of the same handle never
    share a dispatch (bit-stability of the solo path)."""
    flat = pooled_topology(n_hosts=1).flatten()
    analyzers = [EpochAnalyzer(flat) for _ in range(4)]
    traces = [
        [synthetic_trace(300 + 41 * i, flat.n_pools, seed=i, burstiness=0.5)]
        for i in range(4)
    ]
    solo = [a.analyze_batch(tr) for a, tr in zip(analyzers, traces)]
    with AnalysisEngine() as eng:
        park = eng.register(_SlowAnalyzer(flat))
        handles = [eng.register(a) for a in analyzers]
        park.submit([synthetic_trace(8, flat.n_pools)])
        futs = [h.submit(tr) for h, tr in zip(handles, traces)]
        # a second batch on handle 0 must NOT join the same stacked dispatch
        futs.append(handles[0].submit(traces[0]))
        results = [f.result(timeout=60) for f in futs]
        for h in handles:
            h.flush()
        stats = eng.stats()
    assert stats["coalesced_dispatches"] >= 1
    assert stats["max_coalesced_sessions"] == 4
    for ref, got in zip(solo + [solo[0]], results):
        assert got.latency_ns == pytest.approx(ref.latency_ns, rel=1e-6)
        assert got.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-5, abs=1e-3)
        assert got.bandwidth_ns == pytest.approx(ref.bandwidth_ns, rel=1e-5, abs=1e-3)


def test_analyze_batch_multi_matches_solo():
    """The stacked [K, B, N] entry point returns each session's own totals,
    matching per-session analyze_batch, including host decomposition,
    device-cache scales, ragged batch sizes, and empty groups."""
    flat = pooled_topology(n_hosts=2).flatten()
    an = EpochAnalyzer(flat)
    g0 = [
        synthetic_trace(500, flat.n_pools, seed=0, burstiness=0.7).with_host(0),
        synthetic_trace(200, flat.n_pools, seed=1).with_host(1),
    ]
    g1 = [synthetic_trace(333, flat.n_pools, seed=2).with_host(1)]
    scale = np.full((flat.n_hosts * flat.n_pools,), 0.5)
    groups = [g0, [], g1]
    scales = [[None, scale], None, [scale]]
    multi = an.analyze_batch_multi(groups, scales)
    assert len(multi) == 3
    assert multi[1].total_ns == 0.0
    for got, (tr, sc) in zip(
        (multi[0], multi[2]), ((g0, scales[0]), (g1, scales[2]))
    ):
        ref = an.analyze_batch(tr, sc)
        assert got.latency_ns == pytest.approx(ref.latency_ns, rel=1e-6)
        assert got.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-5, abs=1e-3)
        assert got.bandwidth_ns == pytest.approx(ref.bandwidth_ns, rel=1e-5, abs=1e-3)
        np.testing.assert_allclose(
            got.per_host_latency_ns, ref.per_host_latency_ns, rtol=1e-5
        )


def test_analyze_batch_multi_rejects_pallas():
    flat = pooled_topology(n_hosts=1).flatten()
    an = EpochAnalyzer(flat, impl="pallas_interpret")
    with pytest.raises(ValueError, match="inline"):
        an.analyze_batch_multi([[synthetic_trace(16, flat.n_pools)]])


def test_invalid_batch_does_not_poison_coalesced_peers():
    """A session submitting an unreachable-route trace into a coalesced
    group drops ONLY its own batch; peers' results and error state are
    untouched."""
    flat = pooled_topology(n_hosts=1).flatten()
    good_an, bad_an = EpochAnalyzer(flat), EpochAnalyzer(flat)
    good_tr = [synthetic_trace(200, flat.n_pools, seed=0)]
    bad_tr = [synthetic_trace(200, flat.n_pools, seed=1).with_host(3)]  # no such host
    ref = good_an.analyze_batch(good_tr)
    with AnalysisEngine() as eng:
        park = eng.register(_SlowAnalyzer(flat))
        good, bad = eng.register(good_an), eng.register(bad_an)
        park.submit([synthetic_trace(8, flat.n_pools)])
        fut_bad = bad.submit(bad_tr)
        fut_good = good.submit(good_tr)
        got = fut_good.result(timeout=60)
        with pytest.raises(ValueError, match="host id 3"):
            fut_bad.result(timeout=60)
        good.flush()  # innocent peer: no error, nothing dropped
        assert good.dropped_batches == 0
        with pytest.raises(ValueError, match="host id 3"):
            bad.flush()
        assert bad.dropped_batches == 1 and bad.dropped_epochs == 1
    assert got.latency_ns == pytest.approx(ref.latency_ns, rel=1e-6)


def test_cancelled_future_does_not_kill_dispatcher():
    """A caller cancelling a pending submission future must not crash the
    dispatcher or corrupt drop accounting — the future is a notification,
    not the work."""
    flat = pooled_topology(n_hosts=1).flatten()
    with AnalysisEngine() as eng:
        park = eng.register(_SlowAnalyzer(flat, sleep_s=0.2))
        h = eng.register(EpochAnalyzer(flat))
        park.submit([synthetic_trace(8, flat.n_pools)])
        fut = h.submit([synthetic_trace(64, flat.n_pools)])
        assert fut.cancel()  # still queued behind the parked batch
        h.flush()  # batch was analyzed + folded regardless; no error
        assert h.dropped_batches == 0
        # the dispatcher survives: later submissions still complete
        bd = h.submit([synthetic_trace(64, flat.n_pools)]).result(timeout=60)
        assert bd.total_ns >= 0
        assert not eng._broken


def test_default_engine_replaced_after_break():
    eng = AnalysisEngine.default()
    assert AnalysisEngine.default() is eng  # stable while healthy
    try:
        eng._broken = True
        fresh = AnalysisEngine.default()
        assert fresh is not eng
        assert AnalysisEngine.default() is fresh
    finally:
        eng._broken = False  # other tests' handles may still point here


def test_engine_lifecycle_and_backpressure():
    flat = pooled_topology(n_hosts=1).flatten()
    eng = AnalysisEngine()
    h = eng.register(EpochAnalyzer(flat), max_inflight=2)
    for _ in range(5):  # more batches than inflight: submit must backpressure
        h.submit([synthetic_trace(64, flat.n_pools)])
    h.flush()
    h.close()
    with pytest.raises(RuntimeError, match="closed"):
        h.submit([synthetic_trace(8, flat.n_pools)])
    with pytest.raises(ValueError, match="max_inflight"):
        eng.register(EpochAnalyzer(flat), max_inflight=0)
    eng.close()
    eng.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        eng.register(EpochAnalyzer(flat))


# --------------------------------------------------------------------------- #
# satellite: report race (attach) — writes under the report lock
# --------------------------------------------------------------------------- #


def test_report_race_step_vs_report_two_threads():
    """Hammer step() and report reads concurrently with migration + cache
    active: every running-statistic write happens under the report lock,
    so totals stay consistent and nothing raises."""
    regions = RegionMap()
    regions.alloc("w", 1 << 22, "param")
    regions.alloc("kv", 1 << 22, "kvcache")
    phases = [
        Phase(
            "fwd",
            flops=1e8,
            accesses=(Access("w", 1 << 22), Access("kv", 1 << 22, True)),
        )
    ]
    topo = two_tier_topology()
    mig = MigrationSimulator(
        MigrationConfig(mode="software", promote_threshold=1, local_budget_bytes=1 << 30),
        regions,
        topo.flatten(),
    )
    sim = CXLMemSim(
        topo,
        ClassMapPolicy({"kvcache": "cxl_pool"}),
        migration=mig,
        cache=DeviceCacheConfig(capacity_bytes=1 << 26),
        check_capacity=False,
    )
    step = jax.jit(lambda x: (x * x).sum())
    x = jnp.ones((64, 64))
    errors = []
    with sim.attach(step, phases, regions) as prog:

        def reader():
            try:
                for _ in range(40):
                    _ = prog.report.migration_moved_bytes
                    _ = prog._report.cache_hit_fraction
            except BaseException as e:  # pragma: no cover - failure path
                errors.append(e)

        t = threading.Thread(target=reader)
        t.start()
        for _ in range(25):
            prog.step(x)
        t.join()
        rep = prog.report
        assert not errors
        assert rep.steps == 25 and rep.epochs == 25
        assert rep.migration_moved_bytes > 0
        assert np.isfinite(rep.cache_hit_fraction)


# --------------------------------------------------------------------------- #
# satellite: lifecycle — no thread growth across attach/close cycles
# --------------------------------------------------------------------------- #


def test_no_thread_growth_across_attach_close_cycles():
    x = jnp.ones((8, 8))
    # warm-up creates the process-default engine's single dispatcher thread
    with _toy_attach() as prog:
        prog.run(1, x)
    base = threading.active_count()
    for _ in range(50):
        with _toy_attach() as prog:
            prog.run(1, x)
    assert threading.active_count() <= base


def test_no_thread_growth_across_fabric_sessions():
    topo = pooled_topology(n_hosts=2)
    with FabricSession(topo, _tenants(2)) as sess:
        sess.run(1)
    base = threading.active_count()
    for _ in range(10):
        with FabricSession(pooled_topology(n_hosts=2), _tenants(2)) as sess:
            sess.run(1)
    assert threading.active_count() <= base


def test_private_engine_thread_joined_on_close():
    base = threading.active_count()
    with AnalysisEngine() as eng:
        prog = _toy_attach(engine=eng)
        prog.run(2, jnp.ones((8, 8)))
        prog.close()
    assert threading.active_count() <= base


# --------------------------------------------------------------------------- #
# satellite: dropped-batch accounting
# --------------------------------------------------------------------------- #


def test_dropped_batches_recorded_and_error_raised_once():
    """Batch 2 of 5 fails: flush raises once, the report records exactly
    the failed batch's epochs as dropped, and the other 4 batches' totals
    are present."""
    prog = _toy_attach()
    flaky = _FlakyAnalyzer(prog.sim.flat, fail_on=2)
    prog._analyzer = prog._handle.analyzer = flaky
    x = jnp.ones((8, 8))
    for _ in range(5):
        prog.step(x)
    with pytest.raises(RuntimeError, match="injected analyzer failure"):
        prog.flush()
    rep = prog.report  # second flush: error already surfaced, no re-raise
    assert rep.steps == 5
    assert rep.dropped_batches == 1
    assert rep.epochs + rep.dropped_epochs == 5  # one epoch per step here
    assert rep.dropped_epochs == 1
    assert rep.latency_s > 0  # surviving batches were folded
    prog.close()


def test_dropped_batches_sync_path():
    prog = _toy_attach(async_mode=False)
    prog._analyzer = _FlakyAnalyzer(prog.sim.flat, fail_on=1)
    with pytest.raises(RuntimeError, match="injected analyzer failure"):
        prog.step(jnp.ones((8, 8)))
    assert prog._report.dropped_batches == 1
    assert prog._report.dropped_epochs == 1


def test_fabric_dropped_round_recorded():
    sess = FabricSession(pooled_topology(n_hosts=2), _tenants(2))
    flaky = _FlakyAnalyzer(sess.flat, fail_on=2)
    sess._analyzer = sess._handle.analyzer = flaky
    for _ in range(4):
        sess.round()
    with pytest.raises(RuntimeError, match="injected analyzer failure"):
        sess.flush()
    rep = sess.report
    assert rep.rounds == 3 and rep.dropped_batches == 1
    assert rep.dropped_epochs == 1
    sess.close()


# --------------------------------------------------------------------------- #
# satellite: summary key sets locked
# --------------------------------------------------------------------------- #


def test_sim_report_summary_keys_locked():
    assert set(SimReport().summary()) == {
        "steps", "epochs", "native_s", "simulated_s", "slowdown",
        "latency_s", "congestion_s", "bandwidth_s", "coherency_s",
        "injected_sleep_s", "analyzer_s", "overhead",
        "migration_moved_bytes", "cache_hit_fraction",
        "dropped_batches", "dropped_epochs",
        "devices_used", "shard_rows", "padded_waste", "coalesced_group_size",
        "stage_s", "transfer_s", "compile_s", "compute_s",
        "donated_dispatches", "aot_cache_hits",
        "qos_classes", "qos_delay_shares",
    }


def test_fabric_report_summary_keys_locked():
    rep = FabricReport(hosts=[HostClock(0, "a"), HostClock(1, "b")])
    base = {
        "rounds", "epochs", "latency_s", "congestion_s", "bandwidth_s",
        "coherency_s", "bi_messages", "analyzer_s",
        "migration_moved_bytes", "cache_hit_fraction",
        "dropped_batches", "dropped_epochs",
        "devices_used", "shard_rows", "padded_waste", "coalesced_group_size",
        "stage_s", "transfer_s", "compile_s", "compute_s",
        "donated_dispatches", "aot_cache_hits",
        "qos_classes", "qos_delay_shares",
    }
    per_host = {
        f"host{h}_{k}" for h in (0, 1)
        for k in ("native_s", "simulated_s", "slowdown")
    }
    assert set(rep.summary()) == base | per_host


# --------------------------------------------------------------------------- #
# satellite: async-vs-sync FabricSession equivalence (bit-equal)
# --------------------------------------------------------------------------- #


_FABRIC_VARIANTS = {
    "replay": {},  # stateless: round replay cache active
    "migration": dict(
        migration=MigrationConfig(
            mode="software", promote_threshold=1, local_budget_bytes=1 << 30
        )
    ),
    "cache": dict(cache=DeviceCacheConfig(capacity_bytes=1 << 26)),
    "migration+cache": dict(
        migration=MigrationConfig(
            mode="software", promote_threshold=1, local_budget_bytes=1 << 30
        ),
        cache=DeviceCacheConfig(capacity_bytes=1 << 26),
    ),
}


@pytest.mark.parametrize("variant", sorted(_FABRIC_VARIANTS))
def test_fabric_async_matches_sync_bit_equal(variant):
    """Overlapped rounds fold the SAME analyses in the SAME order as forced
    synchronous rounds — per-host clocks and fabric totals are bit-equal
    (trace-only tenants: native clocks are roofline-paced, deterministic).
    Stateful transforms (migration remap, cache tags) run on the submitting
    thread in both modes, so statefulness does not break equivalence."""
    kw = _FABRIC_VARIANTS[variant]
    topo = lambda: pooled_topology(n_hosts=2, cxl_bandwidth_gbps=8.0)
    sync = FabricSession(topo(), _tenants(2, mults=(1, 4)), async_analysis=False, **kw)
    sync.run(3)
    with AnalysisEngine() as eng:  # private engine: no cross-test coalescing
        with FabricSession(topo(), _tenants(2, mults=(1, 4)), engine=eng, **kw) as asy:
            asy.run(3)
    a, b = sync.report, asy.report
    for f in (
        "rounds", "epochs", "latency_s", "congestion_s", "bandwidth_s",
        "coherency_s", "bi_messages", "migration_moved_bytes",
    ):
        assert getattr(a, f) == getattr(b, f), f
    if variant in ("cache", "migration+cache"):
        assert a.cache_hit_fraction == b.cache_hit_fraction
    np.testing.assert_array_equal(a.per_pool_latency_ns, b.per_pool_latency_ns)
    np.testing.assert_array_equal(
        a.per_switch_congestion_ns, b.per_switch_congestion_ns
    )
    np.testing.assert_array_equal(a.per_switch_bandwidth_ns, b.per_switch_bandwidth_ns)
    for ha, hb in zip(a.hosts, b.hosts):
        for f in (
            "steps", "native_s", "simulated_s", "latency_s", "congestion_s",
            "bandwidth_s", "coherency_s", "slowdown",
        ):
            assert getattr(ha, f) == getattr(hb, f), f


# --------------------------------------------------------------------------- #
# tentpole: submission precedes native dispatch (the overlap contract)
# --------------------------------------------------------------------------- #


def test_fabric_round_submits_before_native_steps():
    order = []
    with AnalysisEngine() as eng:
        tenants = _tenants(2, step=True)
        for t in tenants:
            jitted = t.step_fn

            def stepper(x, _jitted=jitted, _name=t.name):
                order.append(f"native:{_name}")
                return _jitted(x)

            t.step_fn = stepper
        sess = FabricSession(pooled_topology(n_hosts=2), tenants, engine=eng)
        orig_submit = sess._handle.submit

        def recording_submit(*args, **kwargs):
            order.append("submit")
            return orig_submit(*args, **kwargs)

        sess._handle.submit = recording_submit
        sess.round()
        sess.close()
    assert order == ["submit", "native:t0", "native:t1"]


def test_fabric_round_returns_breakdown_only_in_sync_mode():
    sync = FabricSession(pooled_topology(n_hosts=2), _tenants(2), async_analysis=False)
    assert sync.round() is not None
    with FabricSession(pooled_topology(n_hosts=2), _tenants(2)) as asy:
        assert asy.round() is None
        # the report property flushes pending folds: never a partial read
        assert asy.report.rounds == 1


def test_attach_async_still_matches_sync():
    """The engine-backed attach path preserves the historical async
    semantics: totals match the synchronous pipeline."""
    x = jnp.ones((32, 32))
    reports = {}
    for mode in (False, True):
        with _toy_attach(async_mode=mode) as prog:
            prog.run(3, x)
            reports[mode] = prog.report
    a, b = reports[False], reports[True]
    assert a.epochs == b.epochs == 3
    assert b.latency_s == pytest.approx(a.latency_s, rel=1e-6)
    assert b.congestion_s == pytest.approx(a.congestion_s, rel=1e-6, abs=1e-12)
    assert b.analyzer_s > 0
