"""HLO collective parsing + roofline-term math."""

import pytest

from repro.core.roofline import (
    DTYPE_BYTES,
    collective_bytes_from_hlo,
    roofline_terms,
)

HLO = """
ENTRY %main {
  %p0 = bf16[1024,512]{1,0} parameter(0)
  %ar = bf16[1024,512]{1,0} all-reduce(bf16[1024,512]{1,0} %p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[2048,128]{1,0} all-gather(f32[512,128]{1,0} %p1), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[256,128]{1,0} reduce-scatter(f32[1024,128]{1,0} %p2), replica_groups=[2,4]<=[8], to_apply=%add
  %a2a = bf16[64,64]{1,0} all-to-all(bf16[64,64]{1,0} %p3), replica_groups={{0,1}}
  %cp = f32[32]{0} collective-permute(f32[32]{0} %p4), source_target_pairs={{0,1},{1,0}}
  %ars = bf16[8,8]{1,0} all-reduce-start(bf16[8,8]{1,0} %p5), replica_groups={{0,1,2,3,4,5,6,7}}
  %solo = f32[999]{0} all-reduce(f32[999]{0} %p6), replica_groups={{0}}
}
"""


def test_collective_parse_factors():
    got = collective_bytes_from_hlo(HLO)
    # all-reduce: 1024·512·2 B × 2·3/4 (+ the -start op: 8·8·2 × 2·7/8)
    assert got["all-reduce"] == pytest.approx(1024 * 512 * 2 * 1.5 + 8 * 8 * 2 * 1.75)
    # all-gather: result 2048·128·4 × 3/4
    assert got["all-gather"] == pytest.approx(2048 * 128 * 4 * 0.75)
    # reduce-scatter: result 256·128·4 × (g−1) = ×3
    assert got["reduce-scatter"] == pytest.approx(256 * 128 * 4 * 3)
    # all-to-all: 64·64·2 × 1/2
    assert got["all-to-all"] == pytest.approx(64 * 64 * 2 * 0.5)
    # collective-permute: result bytes
    assert got["collective-permute"] == pytest.approx(32 * 4)
    # group of size 1 moves nothing
    assert got["n_all-reduce"] == 3
    assert got["total"] == pytest.approx(
        got["all-reduce"] + got["all-gather"] + got["reduce-scatter"]
        + got["all-to-all"] + got["collective-permute"]
    )


def test_roofline_terms_math():
    t = roofline_terms(
        hlo_flops=197e12,  # exactly 1 second of compute
        hlo_bytes=819e9,  # exactly 1 second of HBM
        collective_bytes=25e9,  # 0.5 s of ICI
        model_flops=98.5e12,  # half the HLO flops are "useful"
        n_chips=256,
    )
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(0.5)
    assert t.dominant in ("compute", "memory")
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.bound_s == pytest.approx(1.0)
    # ideal time = model_flops/peak = 0.5 s; bound = 1 s -> fraction 0.5
    assert t.roofline_fraction == pytest.approx(0.5)


def test_dtype_bytes_table():
    assert DTYPE_BYTES["bf16"] == 2 and DTYPE_BYTES["f32"] == 4
    # unknown dtypes are skipped, not crashed
    got = collective_bytes_from_hlo("%x = token[] all-reduce(token[] %y), replica_groups={{0,1}}")
    assert got["total"] == 0.0
