"""Vectorized QoS arbitration cascades: priority / weighted-fair / FIFO
switch queues against the event-by-event DES oracle, dyn-vs-static kernel
parity, ECMP multipath routing, the sweep's ``qos`` axis, and the staging
cap idle-decay.  Exact per-event parity is asserted on tie-free traces
(unique integer timestamps, f32-exact): with tied arrivals the totals are
tie-order-invariant but per-class *attribution* is not, so tied traces are
only checked for conservation (class sums == totals)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QosSpec
from repro.core.analyzer import (
    EpochAnalyzer,
    FineGrainedSimulator,
    analyze_ref,
    plan_cascade,
)
from repro.core.events import EventStager, MemEvents, synthetic_trace
from repro.core.topology import (
    DISCIPLINE_CODES,
    Pool,
    Switch,
    Topology,
    figure1_topology,
    pooled_topology,
)
from repro.core.units import ns_to_s
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.congestion import qos_congestion_cascade as qos_cascade_pallas
from repro.kernels.ref import (
    qos_cascade_dyn,
    qos_serial_queue_cascade,
    serial_queue_cascade,
)

C = 3
WEIGHTS = (4.0, 2.0, 1.0)


def qos_chain(disciplines=("wfq", "priority", "fifo"), weights=WEIGHTS) -> Topology:
    """Depth-3 switch chain with per-switch QoS disciplines."""
    switches = [
        Switch(
            f"sw{d}", 70.0, 64.0 - 8.0 * d, 2.0 + d,
            parent=f"sw{d-1}" if d else None,
            discipline=disc,
            class_weights=weights if disc == "wfq" else None,
        )
        for d, disc in enumerate(disciplines)
    ]
    return Topology(
        pools=[
            Pool("local", 88.9, 76.8, 1 << 36, is_local=True),
            Pool("far1", 180.0, 32.0, 1 << 38, parent=f"sw{len(switches)-1}"),
            Pool("far2", 200.0, 32.0, 1 << 38, parent=f"sw{len(switches)-1}"),
        ],
        switches=switches,
        n_qos_classes=len(weights),
    )


def tie_free_trace(n: int, n_pools: int, seed: int = 0) -> MemEvents:
    """Unique integer timestamps < 2^20: f32-exact and tie-free, so the
    device cascade, the XLA ref, and the DES oracle agree bitwise."""
    rng = np.random.default_rng(seed)
    t = np.sort(rng.choice(np.arange(1, 1 << 20), size=n, replace=False))
    return MemEvents.build(
        t_ns=t.astype(np.float64),
        pool=rng.integers(0, n_pools, n),
        bytes_=np.full(n, 64.0),
        qos=rng.integers(0, C, n),
    )


def _cascade_inputs(flat, ev):
    """(t, bits, stts, qos, disc, weights, names) in the planner's stage
    order — the RC is a stage too, so stages may outnumber the declared
    switches."""
    bits_pool, _, stage_order = plan_cascade(flat)
    order = list(stage_order)
    vpool = ev.host.astype(np.int64) * flat.n_pools + ev.pool.astype(np.int64)
    stage_disc = tuple(flat.switch_discipline[s] for s in order)
    return (
        jnp.asarray(ev.t_ns, jnp.float32),
        jnp.asarray(bits_pool[vpool]),
        jnp.asarray(flat.switch_stt_ns[order], jnp.float32),
        jnp.asarray(ev.qos),
        jnp.asarray(np.asarray(flat.discipline_codes())[order]),
        jnp.asarray(flat.class_weight_table()[order], jnp.float32),
        stage_disc,
    )


# --------------------------------------------------------------------------- #
# kernel-level parity
# --------------------------------------------------------------------------- #


def test_all_fifo_degenerates_bitwise_to_serial_cascade():
    rng = np.random.default_rng(3)
    n, s = 4000, 3
    ts = np.sort(rng.uniform(0, 1e5, n)).astype(np.float32)
    bits = rng.integers(0, 1 << s, n).astype(np.int32)
    stts = jnp.asarray([4.0, 2.0, 0.5], jnp.float32)
    qos = jnp.asarray(rng.integers(0, C, n), jnp.int32)
    w = jnp.ones((s, C), jnp.float32)
    tf_f, idx_f, _ = serial_queue_cascade(jnp.asarray(ts), jnp.asarray(bits), stts)
    tf_q, idx_q, psd = qos_serial_queue_cascade(
        jnp.asarray(ts), jnp.asarray(bits), stts, qos, w, ("fifo",) * s
    )
    np.testing.assert_array_equal(np.asarray(tf_q), np.asarray(tf_f))
    np.testing.assert_array_equal(np.asarray(idx_q), np.asarray(idx_f))
    assert psd.shape == (s, C)  # attribution still per actual class


@pytest.mark.parametrize("disciplines", [
    ("priority", "priority", "priority"),
    ("wfq", "wfq", "wfq"),
    ("wfq", "priority", "fifo"),
])
def test_dyn_matches_static_disciplines(disciplines):
    flat = qos_chain(disciplines).flatten()
    ev = tie_free_trace(3000, flat.n_pools, seed=5)
    t, bits, stts, qos, disc, w, stage_disc = _cascade_inputs(flat, ev)
    tf_s, idx_s, psd_s = qos_serial_queue_cascade(t, bits, stts, qos, w, stage_disc)
    tf_d, idx_d, psd_d = qos_cascade_dyn(t, bits, stts, qos, disc, w)
    np.testing.assert_allclose(np.asarray(tf_d), np.asarray(tf_s), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(psd_d)[:, 0, :], np.asarray(psd_s), rtol=1e-5, atol=1e-3
    )


def test_pallas_interpret_matches_ref():
    flat = qos_chain().flatten()
    ev = tie_free_trace(3000, flat.n_pools, seed=9)
    t, bits, stts, qos, disc, w, _ = _cascade_inputs(flat, ev)
    tf_r, idx_r, psd_r = qos_cascade_dyn(t, bits, stts, qos, disc, w)
    tf_k, idx_k, psd_k = qos_cascade_pallas(
        t, bits, qos, stts, disc, w, block=1024, interpret=True
    )
    np.testing.assert_allclose(np.asarray(tf_k), np.asarray(tf_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))
    np.testing.assert_allclose(
        np.asarray(psd_k), np.asarray(psd_r)[:, 0, :], rtol=1e-5, atol=1e-3
    )


def test_ops_wrapper_routes_and_shapes():
    flat = qos_chain().flatten()
    ev = tie_free_trace(500, flat.n_pools, seed=2)
    t, bits, stts, qos, disc, w, _ = _cascade_inputs(flat, ev)
    S = stts.shape[0]
    tf, idx, psd = kops.qos_congestion_cascade(
        t, bits, stts, qos, disc, w, impl="ref"
    )
    assert psd.shape == (S, 1, C)
    tf_i, _, psd_i = kops.qos_congestion_cascade(
        t, bits, stts, qos, disc, w, impl="pallas_interpret", block=256
    )
    assert psd_i.shape == (S, 1, C)
    np.testing.assert_allclose(np.asarray(tf_i), np.asarray(tf), rtol=1e-6)


def test_priority_class0_sees_no_lower_class_traffic():
    """Strict priority: class 0's per-event times equal a FIFO run over the
    class-0 subsequence alone — lower classes are invisible to it."""
    rng = np.random.default_rng(11)
    n = 2000
    ts = np.sort(rng.choice(np.arange(1, 1 << 20), size=n, replace=False)).astype(np.float32)
    bits = np.ones(n, np.int32)
    qos = rng.integers(0, C, n).astype(np.int32)
    stts = jnp.asarray([5.0], jnp.float32)
    w = jnp.ones((1, C), jnp.float32)
    tf, idx, _ = qos_serial_queue_cascade(
        jnp.asarray(ts), jnp.asarray(bits), stts, jnp.asarray(qos), w, ("priority",)
    )
    out = np.empty(n, np.float64)
    out[np.asarray(idx)] = np.asarray(tf, np.float64)
    sel = qos == 0
    tf0, idx0, _ = serial_queue_cascade(
        jnp.asarray(ts[sel]), jnp.asarray(bits[sel]), stts
    )
    only0 = np.empty(int(sel.sum()), np.float64)
    only0[np.asarray(idx0)] = np.asarray(tf0, np.float64)
    np.testing.assert_allclose(out[sel], only0, rtol=1e-6)


def test_wfq_weight_shifts_delay_between_classes():
    """Heavier weight => smaller inflated service => less queueing charged."""
    rng = np.random.default_rng(4)
    n = 4000
    ts = np.sort(rng.choice(np.arange(1, 1 << 16), size=n, replace=False)).astype(np.float32)
    bits = np.ones(n, np.int32)
    qos = (np.arange(n) % 2).astype(np.int32)
    stts = jnp.asarray([6.0], jnp.float32)

    def cls_delay(w0, w1):
        w = jnp.asarray([[w0, w1]], jnp.float32)
        _, _, psd = qos_serial_queue_cascade(
            jnp.asarray(ts), jnp.asarray(bits), stts, jnp.asarray(qos), w, ("wfq",)
        )
        return np.asarray(psd)[0]

    heavy0 = cls_delay(8.0, 1.0)
    flipped = cls_delay(1.0, 8.0)
    assert heavy0[0] < flipped[0]  # protected class waits less
    assert heavy0[1] > flipped[1]


# --------------------------------------------------------------------------- #
# DES oracle agreement (tie-free => exact)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("disciplines", [
    ("wfq", "priority", "fifo"),
    ("priority", "priority", "priority"),
])
def test_des_per_event_final_time_parity(disciplines):
    flat = qos_chain(disciplines).flatten()
    ev = tie_free_trace(4000, flat.n_pools, seed=7)
    t, bits, stts, qos, disc, w, _ = _cascade_inputs(flat, ev)
    tf, idx, _ = qos_cascade_dyn(t, bits, stts, qos, disc, w)
    out = np.empty(ev.n, np.float64)
    out[np.asarray(idx)] = np.asarray(tf, np.float64)
    des = FineGrainedSimulator(flat, bandwidth_mode="stt")
    np.testing.assert_allclose(
        out, des.final_times(ev, presorted=True), rtol=1e-5
    )


def test_analyzer_matches_ref_and_des_per_class():
    flat = qos_chain().flatten()
    ev = tie_free_trace(3000, flat.n_pools, seed=13)
    ref = analyze_ref(flat, ev)
    got = EpochAnalyzer(flat).analyze(ev)
    des = FineGrainedSimulator(flat, bandwidth_mode="stt").simulate(ev)
    assert got.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-6)
    assert des.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-6)
    np.testing.assert_allclose(
        got.per_class_congestion_ns, ref.per_class_congestion_ns, rtol=1e-6
    )
    np.testing.assert_allclose(
        des.per_class_congestion_ns, ref.per_class_congestion_ns, rtol=1e-6
    )
    assert float(np.sum(got.per_class_congestion_ns)) == pytest.approx(
        got.congestion_ns, rel=1e-6
    )


def test_qos_off_breakdown_keeps_degenerate_class_axis():
    flat = figure1_topology().flatten()
    ev = synthetic_trace(1500, flat.n_pools, epoch_ns=1e5, seed=1, burstiness=0.6)
    bd = EpochAnalyzer(flat).analyze(ev)
    assert bd.per_class_congestion_ns.shape == (1,)
    assert float(bd.per_class_congestion_ns[0]) == pytest.approx(
        bd.congestion_ns, rel=1e-6
    )


# --------------------------------------------------------------------------- #
# host-segmented attribution (satellite: property test + plain fallback)
# --------------------------------------------------------------------------- #


def _check_host_split(seed: int, n: int, tie_span: int) -> None:
    """Host-segmented per-stage delays must sum (<=1e-5) to the unsegmented
    totals — under tie-HEAVY traces (times drawn with replacement from a
    small span), where per-class order sensitivity is maximal."""
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, tie_span, n)).astype(np.float32)
    bits = rng.integers(0, 1 << 3, n).astype(np.int32)
    qos = jnp.asarray(rng.integers(0, C, n), jnp.int32)
    hosts = jnp.asarray(rng.integers(0, 4, n), jnp.int32)
    stts = jnp.asarray([4.0, 2.0, 1.0], jnp.float32)
    disc = jnp.asarray([DISCIPLINE_CODES["wfq"], DISCIPLINE_CODES["priority"],
                        DISCIPLINE_CODES["fifo"]], jnp.int32)
    w = jnp.asarray(np.tile(np.asarray(WEIGHTS), (3, 1)), jnp.float32)
    tf_u, _, psd_u = qos_cascade_dyn(
        jnp.asarray(ts), jnp.asarray(bits), stts, qos, disc, w
    )
    tf_h, _, psd_h = qos_cascade_dyn(
        jnp.asarray(ts), jnp.asarray(bits), stts, qos, disc, w,
        hosts=hosts, n_hosts=4,
    )
    np.testing.assert_allclose(np.asarray(tf_h), np.asarray(tf_u), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(psd_h).sum(axis=1), np.asarray(psd_u).sum(axis=1),
        rtol=1e-5, atol=1e-2,
    )


def test_host_segmented_sums_randomized():
    for seed in range(8):
        _check_host_split(seed, n=500 + 300 * seed, tie_span=64 + 16 * seed)


def test_host_segmented_sums_property():
    pytest.importorskip("hypothesis", reason="optional dev dependency")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        n=st.integers(2, 1500),
        tie_span=st.integers(2, 200),
    )
    def prop(seed, n, tie_span):
        _check_host_split(seed, n, tie_span)

    prop()


# --------------------------------------------------------------------------- #
# QosSpec + topology threading
# --------------------------------------------------------------------------- #


def test_qos_spec_validation():
    with pytest.raises(ValueError, match="unknown discipline"):
        QosSpec(discipline="strict")
    with pytest.raises(ValueError, match="positive"):
        QosSpec(discipline="wfq", class_weights=(1.0, -2.0))
    with pytest.raises(ValueError, match="unknown switch"):
        QosSpec(switch_disciplines=(("nope", "wfq"),)).apply(
            np.zeros(2, np.int32), np.ones((2, 2)), ["a", "b"]
        )
    assert QosSpec(discipline="wfq", class_weights=(2.0, 1.0)).n_classes() == 2
    assert "wfq" in QosSpec(discipline="wfq").describe()


def test_qos_spec_apply_matches_ecmp_replicas():
    disc = np.zeros(3, np.int32)
    w = np.ones((3, 2))
    QosSpec(
        switch_disciplines=(("sw", "priority"),),
        switch_weights=(("sw", (3.0, 1.0)),),
    ).apply(disc, w, ["sw", "sw@1", "other"])
    assert list(disc) == [DISCIPLINE_CODES["priority"]] * 2 + [0]
    np.testing.assert_allclose(w[:2], [[3.0, 1.0]] * 2)
    np.testing.assert_allclose(w[2], [1.0, 1.0])


def test_topology_derives_qos_classes_and_flags():
    topo = qos_chain()
    flat = topo.flatten()
    assert flat.n_qos_classes == C and flat.has_qos
    codes = np.asarray(flat.discipline_codes())
    assert codes.shape == (flat.n_switches,)  # the RC is a stage too
    assert flat.class_weight_table().shape == (flat.n_switches, C)
    # all-fifo, single-class: qos machinery stays off
    assert not figure1_topology().flatten().has_qos


def test_wfq_weight_length_must_match_classes():
    with pytest.raises(ValueError):
        Topology(
            pools=[Pool("l", 88.9, 76.8, 1 << 30, is_local=True),
                   Pool("p", 180.0, 32.0, 1 << 30, parent="sw")],
            switches=[Switch("sw", 70.0, 64.0, 2.0, discipline="wfq",
                             class_weights=(1.0, 2.0))],
            n_qos_classes=3,
        )


# --------------------------------------------------------------------------- #
# ECMP multipath routing
# --------------------------------------------------------------------------- #


def _multipath_topology(multipath):
    # two remote pools behind one switch: flows vp=1 and vp=2 hash onto
    # different replicas, so multipath=2 genuinely splits the traffic
    return Topology(
        pools=[Pool("l", 88.9, 76.8, 1 << 30, is_local=True),
               Pool("p1", 180.0, 32.0, 1 << 30, parent="sw"),
               Pool("p2", 180.0, 32.0, 1 << 30, parent="sw")],
        switches=[Switch("sw", 70.0, 64.0, 4.0, multipath=multipath)],
    )


def test_multipath_lowers_to_replica_columns():
    flat = _multipath_topology(2).flatten()
    # replica columns first, then the per-host RC pseudo-switch stages
    assert list(flat.switch_names)[:2] == ["sw", "sw@1"]
    # every (host, pool) flow hashes onto exactly one replica
    routed = flat.route[:, :2]
    assert np.all(routed.sum(axis=1) <= 1.0)
    assert routed[:, 0].sum() > 0 and routed[:, 1].sum() > 0


def test_multipath_halves_shared_switch_queueing():
    n = 4000
    t = np.arange(n) * 0.5  # far denser than stt=4.0: heavy queueing
    pool = np.where(np.arange(n) % 2 == 0, 1, 2)
    ev = MemEvents.build(t, pool, np.full(n, 64.0)).with_qos(0)
    c1 = analyze_ref(_multipath_topology(1).flatten(), ev).congestion_ns
    double = _multipath_topology(2).flatten()
    c2 = analyze_ref(double, ev).congestion_ns
    assert c2 < c1  # splitting flows across replicas relieves the queue
    got = EpochAnalyzer(double).analyze(ev)
    assert got.congestion_ns == pytest.approx(c2, rel=1e-5, abs=1e-3)


# --------------------------------------------------------------------------- #
# sweep qos axis
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def qos_suite():
    from repro.core import RegionMap, ScenarioSuite
    from repro.core.tracer import Access, Phase

    rng = np.random.default_rng(0)
    rm = RegionMap()
    for i in range(6):
        r = rm.alloc(f"r{i}", 1 << 20, ("param", "opt_state", "kvcache")[i % 3])
        r.access_count = 10.0
    phases = [
        Phase(f"ph{p}", 1e12, tuple(
            Access(f"r{j}", float(rng.integers(1e5, 6e5)), False)
            for j in rng.choice(6, size=3, replace=False)
        ))
        for p in range(3)
    ]
    suite = ScenarioSuite(
        figure1_topology(), rm, phases,
        region_qos={f"r{i}": i % C for i in range(6)},
    )
    return suite


def test_sweep_qos_axis_one_dispatch_with_dedup(qos_suite):
    from repro.core import ClassMapPolicy, Scenario

    pol = ClassMapPolicy({"opt_state": "cxl_pool2", "kvcache": "cxl_pool1"})
    specs = [
        None,
        QosSpec(discipline="priority"),
        QosSpec(discipline="wfq", class_weights=(8.0, 2.0, 1.0)),
        QosSpec(discipline="wfq", class_weights=(8.0, 2.0, 1.0)),  # duplicate
    ]
    scens = [
        Scenario(policy=pol, name=f"s{i}", qos=q) for i, q in enumerate(specs)
    ]
    d0 = qos_suite.dispatch_count
    res = qos_suite.run(scens)
    assert qos_suite.dispatch_count == d0 + 1  # K scenarios, ONE dispatch
    # duplicated (policy, qos) rows share one cascade plane
    assert qos_suite.last_unique_cascades == 3
    assert res.qos_classes == C
    for row, bd in zip(res.table(), res.breakdowns):
        assert row["qos_classes"] == C
        assert len(row["qos_delay_shares"]) == C
        # attribution conserves the total (tie-invariant even when the
        # synthesized workload has tied timestamps)
        assert float(np.sum(bd.per_class_congestion_ns)) == pytest.approx(
            bd.congestion_ns, rel=1e-5, abs=1e-3
        )
    # the duplicate scenarios are numerically identical
    assert res.breakdowns[2].congestion_ns == res.breakdowns[3].congestion_ns


def test_sweep_qos_fifo_matches_qos_off_totals(qos_suite):
    """A no-op QosSpec under region_qos must reproduce the qos-off totals:
    disciplines/weights are data, FIFO semantics are unchanged."""
    from repro.core import ClassMapPolicy, RegionMap, Scenario, ScenarioSuite

    pol = ClassMapPolicy({"opt_state": "cxl_pool2"})
    on = qos_suite.run([Scenario(policy=pol, name="fifo")]).breakdowns[0]
    off_suite = ScenarioSuite(
        figure1_topology(), qos_suite.regions, qos_suite.phases
    )
    off = off_suite.run([Scenario(policy=pol, name="fifo")]).breakdowns[0]
    # abs covers f32 ulp noise at this trace's time magnitude (~1.5e7 ns):
    # the FIFO path's cummax(t - stt*rank) form can round a start ~1 ulp
    # below its arrival (true congestion here is exactly 0); the QoS path's
    # max(t, horizon) form cannot go negative
    assert on.congestion_ns == pytest.approx(off.congestion_ns, rel=1e-5, abs=4.0)
    assert on.latency_ns == pytest.approx(off.latency_ns, rel=1e-5)
    assert on.bandwidth_ns == pytest.approx(off.bandwidth_ns, rel=1e-4, abs=1.0)


# --------------------------------------------------------------------------- #
# fabric + fleet threading
# --------------------------------------------------------------------------- #


def test_fabric_wfq_weights_shift_tenant_shares():
    from repro.core import FabricSession, InterleavePolicy, RegionMap, Tenant
    from repro.core.tracer import Access, Phase

    def mk_topo(w):
        return Topology(
            pools=[Pool("dram", 100.0, 100.0, 1 << 38, is_local=True),
                   Pool("cxl1", 250.0, 64.0, 1 << 38, parent="sw0"),
                   Pool("cxl2", 300.0, 48.0, 1 << 38, parent="sw0")],
            switches=[Switch("sw0", 70.0, 64.0, 2.0, discipline="wfq",
                             class_weights=w)],
        )

    def mk_tenant(name, seed, qos):
        rng = np.random.default_rng(seed)
        rm = RegionMap()
        for i in range(3):
            rm.alloc(f"{name}/r{i}", 1 << 20, "param")
        phases = [Phase(f"{name}/p{p}", 1e12, tuple(
            Access(f"{name}/r{j}", float(rng.integers(1e5, 8e5)), False)
            for j in range(3)))
            for p in range(2)]
        return Tenant(name=name, phases=phases, regions=rm,
                      policy=InterleavePolicy(["cxl1", "cxl2"]), qos_class=qos)

    reports = {}
    for tag, w in (("protect0", (4.0, 1.0)), ("protect1", (1.0, 8.0))):
        sess = FabricSession(
            mk_topo(w),
            [mk_tenant("lat_crit", 0, 0), mk_tenant("batch", 1, 1)],
            async_analysis=False,
        )
        reports[tag] = sess.run(1)
        sess.close()
    a, b = reports["protect0"], reports["protect1"]
    assert a.summary()["qos_classes"] == 2
    for rep in (a, b):
        assert ns_to_s(float(np.sum(rep.per_class_congestion_ns))) == pytest.approx(
            rep.congestion_s, rel=1e-9, abs=1e-15
        )
    # deprioritizing class 0 raises its share of the queueing delay
    assert b.qos_delay_shares()[0] > a.qos_delay_shares()[0]


def test_fabric_rejects_out_of_range_tenant_class():
    from repro.core import FabricSession, LocalOnlyPolicy, RegionMap, Tenant
    from repro.core.tracer import Phase

    rm = RegionMap()
    rm.alloc("r0", 1 << 20, "param")
    t = Tenant(name="t", phases=[Phase("p", 1e12, ())], regions=rm,
               policy=LocalOnlyPolicy(), qos_class=5)
    with pytest.raises(ValueError, match="qos_class=5"):
        FabricSession(pooled_topology(n_hosts=1), [t], async_analysis=False)


def test_fleet_rack_qos_builds_per_rack_policy_leaves():
    from repro.core.fleet import FleetSim, synthetic_tenant

    rq = [QosSpec(discipline="wfq", class_weights=(8.0, 1.0)),
          QosSpec(discipline="priority", class_weights=(1.0, 1.0))]
    fleet = FleetSim(n_racks=2, hosts_per_rack=2, rack_qos=rq)
    assert fleet.qos_on and fleet.n_qos_classes == 2
    n_stages = fleet._disc_stack.shape[1]  # shared switch + per-host RCs
    assert fleet._disc_stack.shape == (2, n_stages) and n_stages >= 1
    # a blanket QosSpec re-disciplines every stage of its rack
    assert (fleet._disc_stack[0] == DISCIPLINE_CODES["wfq"]).all()
    assert (fleet._disc_stack[1] == DISCIPLINE_CODES["priority"]).all()
    np.testing.assert_allclose(fleet._weights_stack[0, 0], [8.0, 1.0])
    with pytest.raises(ValueError, match="rack_qos"):
        FleetSim(n_racks=3, rack_qos=rq)
    t = dataclasses.replace(synthetic_tenant("t0", seed=0, gib=1.0), qos_class=7)
    with pytest.raises(ValueError, match="qos_class=7"):
        fleet.place([t])


# --------------------------------------------------------------------------- #
# staging cap idle-decay (satellite)
# --------------------------------------------------------------------------- #


def test_stage_packed_caps_decay_after_idle_streak():
    stager = EventStager()
    enter = np.asarray([-1, 0], np.int32)  # pool 0 local, pool 1 -> stage 0

    def stage(n):
        ev = MemEvents.build(
            t_ns=np.arange(1, n + 1, dtype=np.float64),
            pool=np.ones(n, np.int64),
            bytes_=np.full(n, 64.0),
        )
        _, _, caps = stager.stage_packed([ev], 1, 4096, enter, 1)
        return caps

    burst_caps = stage(2000)
    assert burst_caps[0] >= 2048
    # small steady state: caps stay sticky for CAP_DECAY_CALLS-1 calls...
    for _ in range(EventStager.CAP_DECAY_CALLS - 1):
        assert stage(20) == burst_caps
    # ...then shrink to the streak's peak demand (bucketed), not to zero
    decayed = stage(20)
    assert decayed[0] < burst_caps[0]
    assert decayed[0] >= 32  # still holds the streak's own peak bucket
    # a fresh burst grows the caps right back (hwm semantics keep correctness)
    assert stage(3000)[0] >= 4096


def test_stage_packed_oscillating_workload_never_decays():
    stager = EventStager()
    enter = np.asarray([-1, 0], np.int32)

    def stage(n):
        ev = MemEvents.build(
            t_ns=np.arange(1, n + 1, dtype=np.float64),
            pool=np.ones(n, np.int64),
            bytes_=np.full(n, 64.0),
        )
        _, _, caps = stager.stage_packed([ev], 1, 4096, enter, 1)
        return caps

    big = stage(2000)
    for i in range(3 * EventStager.CAP_DECAY_CALLS):
        # every few calls the workload touches the high caps again: the
        # decay streak resets and the packed width never flaps
        n = 1900 if i % (EventStager.CAP_DECAY_CALLS - 2) == 0 else 30
        assert stage(n) == big
