"""Placement policies, migration, coherency — the paper's research surfaces."""

import numpy as np
import pytest

from repro.core import (
    CACHELINE_BYTES,
    PAGE_BYTES,
    ClassMapPolicy,
    CoherencyConfig,
    CoherencyModel,
    HotnessTieredPolicy,
    InterleavePolicy,
    LocalOnlyPolicy,
    MemEvents,
    MigrationConfig,
    MigrationSimulator,
    RegionMap,
    capacity_check,
    figure1_topology,
)

FLAT = figure1_topology().flatten()


def _regions():
    r = RegionMap()
    r.alloc("w", 1 << 20, "param")
    r.alloc("opt", 1 << 22, "opt_state")
    r.alloc("kv", 1 << 21, "kvcache")
    r.alloc("act", 1 << 18, "activation")
    return r


def test_local_only():
    r = _regions()
    LocalOnlyPolicy().place(r, FLAT)
    assert all(reg.pool == 0 for reg in r)


def test_class_map_routes_classes():
    r = _regions()
    ClassMapPolicy({"opt_state": "cxl_pool2", "kvcache": "cxl_pool1"}).place(r, FLAT)
    assert r["opt"].pool == FLAT.pool_names.index("cxl_pool2")
    assert r["kv"].pool == FLAT.pool_names.index("cxl_pool1")
    assert r["w"].pool == 0 and r["act"].pool == 0


def test_interleave_spreads_bytes():
    r = RegionMap()
    for i in range(16):
        r.alloc(f"r{i}", 1 << 20, "param")
    InterleavePolicy(["cxl_pool2", "cxl_pool3"], classes=["param"]).place(r, FLAT)
    per_pool = r.bytes_per_pool(FLAT.n_pools)
    assert per_pool[2] > 0 and per_pool[3] > 0
    assert abs(per_pool[2] - per_pool[3]) <= (1 << 20)


def test_hotness_tiered_respects_budget():
    r = _regions()
    hot = {"w": 1000.0, "kv": 500.0, "opt": 1.0, "act": 2000.0}
    HotnessTieredPolicy(
        "cxl_pool1", hotness=hot, local_budget_bytes=(1 << 20) + (1 << 18) + 100
    ).place(r, FLAT)
    # hottest-per-byte fit local: act then w; opt/kv spill to cxl
    assert r["act"].pool == 0 and r["w"].pool == 0
    assert r["opt"].pool != 0 and r["kv"].pool != 0


def test_capacity_check_raises_on_overflow():
    r = RegionMap()
    r.alloc("huge", int(FLAT.pool_capacity[1]) + 1, "param", pool=1)
    r.alloc("local", 1, "param", pool=0)
    with pytest.raises(ValueError):
        capacity_check(r, FLAT)


def test_granularity_names():
    assert "cacheline" in ClassMapPolicy({}, CACHELINE_BYTES).describe()
    assert "page" in ClassMapPolicy({}, PAGE_BYTES).describe()


# --------------------------------------------------------------------------- #
# migration
# --------------------------------------------------------------------------- #


def _trace_for(region_id: int, n: int, pool: int) -> MemEvents:
    return MemEvents.build(
        np.linspace(0, 1e5, n), [pool] * n, [64.0] * n, region=[region_id] * n
    )


def test_migration_promotes_hot_region():
    r = RegionMap()
    reg = r.alloc("hot", 1 << 20, "kvcache", pool=1)
    sim = MigrationSimulator(
        MigrationConfig(mode="software", promote_threshold=10, local_budget_bytes=1 << 30),
        r,
        FLAT,
    )
    tr = _trace_for(reg.rid, 200, pool=1)
    # epoch 1: hotness builds; promotion happens at boundary
    sim.observe_and_migrate(tr)
    assert r["hot"].pool == 0
    assert sim.promotions == 1
    assert sim.moved_bytes_total == reg.nbytes


def test_migration_demotes_cold_region():
    r = RegionMap()
    reg = r.alloc("cold", 1 << 20, "kvcache", pool=1)
    reg.pool = 0  # currently resident local, home pool 1
    sim = MigrationSimulator(
        MigrationConfig(mode="software", demote_threshold=5.0), r, FLAT
    )
    sim._home_pool[reg.rid] = 1
    tr = _trace_for(reg.rid, 1, pool=0)  # nearly no accesses
    sim.observe_and_migrate(tr)
    assert r["cold"].pool == 1
    assert sim.demotions == 1


def test_hardware_migration_remaps_within_epoch():
    r = RegionMap()
    reg = r.alloc("hot", 1 << 12, "kvcache", pool=1)
    sim = MigrationSimulator(
        MigrationConfig(
            mode="hardware", promote_threshold=1, reaction_ns=5e4,
            local_budget_bytes=1 << 30, granularity_bytes=CACHELINE_BYTES,
        ),
        r,
        FLAT,
    )
    tr = _trace_for(reg.rid, 100, pool=1)
    remapped, mig = sim.observe_and_migrate(tr)
    # events after reaction point moved to local pool 0
    after = remapped.t_ns >= 5e4
    assert (remapped.pool[after] == 0).all()
    assert (remapped.pool[~after] == 1).all()
    assert mig.n > 0


def test_migration_off_is_identity():
    r = RegionMap()
    reg = r.alloc("x", 1 << 12, "kvcache", pool=1)
    sim = MigrationSimulator(MigrationConfig(mode="off"), r, FLAT)
    tr = _trace_for(reg.rid, 10, pool=1)
    remapped, mig = sim.observe_and_migrate(tr)
    assert mig.n == 0
    np.testing.assert_array_equal(remapped.pool, tr.pool)


# --------------------------------------------------------------------------- #
# coherency
# --------------------------------------------------------------------------- #


def test_coherency_charges_writes_to_shared_pools():
    r = RegionMap()
    reg = r.alloc("shared_kv", 1 << 20, "kvcache", pool=1)
    model = CoherencyModel(CoherencyConfig(n_hosts=4), r)
    n = 100
    tr = MemEvents.build(
        np.linspace(0, 1e5, n), [1] * n, [64.0] * n,
        is_write=[True] * (n // 2) + [False] * (n // 2),
        region=[reg.rid] * n,
    )
    bi, extra = model.epoch_traffic(tr)
    assert bi.n > 0
    # 50 writes × 3 sharers × 64B of BI traffic
    assert bi.bytes_.sum() == pytest.approx(50 * 3 * 64.0)
    assert extra > 0


def test_coherency_single_host_silent():
    r = RegionMap()
    reg = r.alloc("kv", 1 << 20, "kvcache", pool=1)
    model = CoherencyModel(CoherencyConfig(n_hosts=1), r)
    tr = _trace_for(reg.rid, 10, pool=1)
    bi, extra = model.epoch_traffic(tr)
    assert bi.n == 0 and extra == 0.0
