"""Epoch segmentation: quantum slicing must preserve every event field."""

import numpy as np
import pytest

from repro.core import EpochSchedule, MemEvents, slice_by_quantum


def _weighted_trace():
    n = 40
    return MemEvents(
        t_ns=np.linspace(0.0, 4e6, n, endpoint=False),
        pool=np.arange(n, dtype=np.int32) % 3,
        bytes_=np.full((n,), 128.0),
        is_write=np.arange(n) % 2 == 0,
        region=np.arange(n, dtype=np.int32) % 5,
        weight=np.linspace(1.0, 4.0, n),  # PEBS-style 1/rate multiplicities
        host=np.arange(n, dtype=np.int32) % 2,
    )


def test_slice_by_quantum_preserves_weights():
    """Regression: 'quantum' mode used to rebuild MemEvents without weight,
    silently resetting sampling weights to 1."""
    ev = _weighted_trace()
    slices = slice_by_quantum(ev, 1e6)
    assert len(slices) == 4
    got = np.concatenate([s.weight for s in slices])
    np.testing.assert_allclose(np.sort(got), np.sort(ev.weight))
    assert not np.allclose(got, 1.0)  # the old bug flattened these to 1


def test_slice_by_quantum_preserves_all_fields_and_rebases_times():
    ev = _weighted_trace()
    slices = slice_by_quantum(ev, 1e6)
    n_total = 0
    for q, s in enumerate(slices):
        assert (s.t_ns >= 0).all() and (s.t_ns < 1e6).all()
        # recover original indices by absolute time and compare every field
        t_abs = s.t_ns + q * 1e6
        orig = np.searchsorted(ev.t_ns, t_abs)
        np.testing.assert_array_equal(s.pool, ev.pool[orig])
        np.testing.assert_array_equal(s.region, ev.region[orig])
        np.testing.assert_array_equal(s.is_write, ev.is_write[orig])
        np.testing.assert_array_equal(s.host, ev.host[orig])
        np.testing.assert_allclose(s.weight, ev.weight[orig])
        np.testing.assert_allclose(s.bytes_, ev.bytes_[orig])
        n_total += s.n
    assert n_total == ev.n


def test_quantum_weighted_totals_match_unsliced():
    """Weighted byte/latency accounting must be invariant under slicing."""
    ev = _weighted_trace()
    slices = EpochSchedule("quantum", quantum_ns=7.7e5).slices(ev)
    assert sum(s.n for s in slices) == ev.n
    assert sum(float((s.bytes_ * s.weight).sum()) for s in slices) == pytest.approx(
        float((ev.bytes_ * ev.weight).sum())
    )


def test_dense_slicing_keeps_absolute_quantum_alignment():
    """dense=True must emit empty slices for idle quanta so slice index k
    always means absolute quantum k (the fabric session's alignment
    contract); the default keeps the historical compacted behavior."""
    ev = MemEvents.build([0.5e6, 2.5e6], [0, 0], [64, 64])  # idle quantum 1
    compact = slice_by_quantum(ev, 1e6)
    dense = slice_by_quantum(ev, 1e6, dense=True)
    assert [s.n for s in compact] == [1, 1]
    assert [s.n for s in dense] == [1, 0, 1]
    assert dense[2].t_ns[0] == pytest.approx(0.5e6)


def test_empty_trace():
    assert slice_by_quantum(MemEvents.empty(), 1e6) == []
