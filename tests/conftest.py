import os

# Tests must see the single real CPU device — never the 512-device dry-run
# configuration (the brief forbids setting that flag globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
