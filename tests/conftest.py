import os

# Tests run on CPU with 8 *virtual* devices: the sharded-dispatch tests
# (test_fleet_sharding.py) need a multi-device ('data',) mesh, and the full
# suite is verified to pass unchanged under this flag.  It must be set
# before jax initializes its backends — hence here, at conftest import time
# — and is appended so an externally supplied XLA_FLAGS still applies.
# (The 512-device dry-run configuration stays subprocess-only; see
# test_system.py.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _simlint_sanitizers(request):
    """Opt-in sanitizer harness: ``SIMLINT_SANITIZE=1 pytest ...`` runs
    every test under the lock-order sanitizer (raising on cycles), the
    axis sanitizer (raising on @axes contract violations), and the
    recompile sanitizer in record-only mode (first-compile-per-shape is
    legitimate inside a test; the steady-state assertions live in
    tests/test_simlint.py).  Off by default: wrapping lock creation has
    measurable overhead and the CI lint job runs the sanitized smoke on
    tests/test_engine.py explicitly."""
    if os.environ.get("SIMLINT_SANITIZE") != "1":
        yield
        return
    if request.node.get_closest_marker("no_sanitize") is not None:
        # tests that patch threading or assert sanitizer behavior manage
        # their own scopes
        yield
        return
    from repro.analysis.sanitize import (
        AxisSanitizer,
        LockOrderSanitizer,
        RecompileSanitizer,
    )

    with LockOrderSanitizer():
        with RecompileSanitizer(record_only=True):
            with AxisSanitizer():
                yield


@pytest.fixture(scope="session")
def data_mesh():
    """A ('data',) mesh over every (virtual) device — the sharded-dispatch
    mesh the analyzer/suite/fleet `mesh=` options expect."""
    from repro.launch.mesh import make_data_mesh

    return make_data_mesh()
