"""Clean counterpart of bad_weight_drop.py: the rebuild threads weight/host
through, and fresh synthesis (no derived columns) keeps its exact-weight
defaults.  The event-columns checker must stay silent on both.
"""
import numpy as np

from repro.core.events import MemEvents


def slice_by_quantum(ev, lo, hi):
    pick = (ev.t_ns >= lo) & (ev.t_ns < hi)
    return MemEvents(
        ev.t_ns[pick], ev.pool[pick], ev.bytes_[pick], ev.is_write[pick],
        ev.region[pick], weight=ev.weight[pick], host=ev.host[pick],
        qos=ev.qos[pick],
    )


def synthesize(n):
    # fresh synthesis: defaults (weight 1, host 0) are the correct semantics
    return MemEvents(
        np.zeros(n), np.zeros(n, np.int32), np.full(n, 64.0),
        np.zeros(n, bool), np.zeros(n, np.int32),
    )
