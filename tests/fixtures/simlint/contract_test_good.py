"""Key-lock test side of the matching contract pair (contract_impl_good)."""
from contract_impl_good import SimReport


def test_sim_report_summary_keys_locked():
    base = {"epochs", "latency_ns"}
    assert set(SimReport().summary()) == base
