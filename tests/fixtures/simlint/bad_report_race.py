"""Seeded violation: the PR-5 report race, reconstructed.

An async dispatcher folds per-epoch stats into a shared report while the
stepping thread reads running-statistic fields without the lock.  The
lock-discipline checker must flag every unlocked access — including the
closure built under the lock that escapes to run on another thread.
"""
import threading

from repro.analysis.annotations import guarded_by


class RacyClient:
    _simlint_guards = guarded_by("_report_lock", "_report", "_folds")

    def __init__(self):
        self._report_lock = threading.Lock()
        self._report = {"epochs": 0}
        self._folds = 0

    def fold(self, epochs):
        # BUG: dispatcher-thread write without the report lock
        self._report["epochs"] += epochs
        self._folds += 1

    def snapshot(self):
        # BUG: stepping-thread read while folds are in flight
        return dict(self._report)

    def escape(self):
        with self._report_lock:
            # BUG: the callback is built under the lock but runs later,
            # on the dispatcher thread, without it
            return lambda: self._report["epochs"]
