"""Seeded violation: summary()/key-lock-test drift, both directions.

``summary`` emits ``p99_ns`` (never locked by the test) and the test locks
``dropped_epochs`` (never emitted) — the summary-contract checker must
report both sides of the mismatch.
"""


class SimReport:
    def __init__(self):
        self.epochs = 0
        self.latency_ns = 0.0

    def summary(self):
        return {
            "epochs": self.epochs,
            "latency_ns": self.latency_ns,
            "p99_ns": 0.0,
        }
