"""Clean counterpart of bad_units.py: 0 findings.

Same shapes, with every conversion routed through repro.core.units, the
bandwidth identity exercised (bytes / gbps is already ns — GB/s ==
bytes/ns), and an explicit annotations.unit(...) marker.
"""
from repro.analysis.annotations import unit
from repro.core.units import NS_PER_S, ns_to_s, s_to_ns


def total_latency_ns(native_ns, coherency_s):
    return native_ns + s_to_ns(coherency_s)


def report_seconds(latency_ns):
    return ns_to_s(latency_ns)


def window_ns(span_s):
    return span_s * NS_PER_S


def queue_delay_ns(wbytes, bw_gbps):
    # GB/s == bytes/ns: byte / (byte/ns) = ns, no conversion needed
    return wbytes / bw_gbps


def fold(delay_ns, budget_s):
    if delay_ns > s_to_ns(budget_s):
        return ns_to_s(delay_ns)
    return budget_s


def stamp(total_ns, wall_s):
    elapsed_s = ns_to_s(total_ns)
    drift = unit("s", wall_s - elapsed_s)
    return drift
