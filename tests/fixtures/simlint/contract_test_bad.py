"""Key-lock test side of the drifted contract pair (see contract_impl_bad)."""
from contract_impl_bad import SimReport


def test_sim_report_summary_keys_locked():
    base = {"epochs", "latency_ns", "dropped_epochs"}
    assert set(SimReport().summary()) == base
