"""Clean counterpart of bad_report_race.py: every guarded access is locked,
plus one use of each sanctioned exemption (``*_locked`` convention and
``@single_threaded``).  The lock-discipline checker must stay silent.
"""
import threading

from repro.analysis.annotations import guarded_by, single_threaded


class LockedClient:
    _simlint_guards = guarded_by("_report_lock", "_report", "_folds")

    def __init__(self):
        self._report_lock = threading.Lock()
        self._report = {"epochs": 0}
        self._folds = 0

    def fold(self, epochs):
        with self._report_lock:
            self._report["epochs"] += epochs
            self._folds += 1

    def snapshot(self):
        with self._report_lock:
            return dict(self._report)

    def _fold_into_locked(self, epochs):
        # caller-holds-the-lock convention
        self._report["epochs"] += epochs

    @single_threaded("called only from the single dispatcher thread")
    def drain(self):
        return self._folds
