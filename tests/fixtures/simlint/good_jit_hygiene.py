"""Clean counterpart of bad_jit_hygiene.py.

Static arguments may branch; noneness tests on traced optionals are fine;
``.shape`` reads are static metadata; AOT compiles live inside a 'build'
thunk routed through AotDispatchCache; pipeline entry points donate.
"""
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.aot import AotDispatchCache


def _analyze_pipeline_jax(planes, weights):
    return jnp.sum(planes * weights)


analyze = jax.jit(_analyze_pipeline_jax, donate_argnums=(0,))


@partial(jax.jit, static_argnames=("mode",))
def kernel(x, mode, bias=None):
    if mode == "centered":  # static parameter: python branching is fine
        x = x - jnp.mean(x)
    if bias is not None:  # noneness test on a traced optional is not a sync
        x = x + bias
    n = float(x.shape[0])  # shape reads are static metadata
    return x / n


_cache = AotDispatchCache()


def warm(fn, x):
    def build():
        # the sanctioned convention: AOT compile inside a 'build' thunk
        return jax.jit(fn).lower(x).compile()

    exe, _ = _cache.get(("k", x.shape), build)
    return exe
