"""Seeded violations: all three axes rules in one file.

axes-missing (a required dispatch surface with no contract), axes-mismatch
(transposed dispatch — caller axes are the contract's own vocabulary at
the wrong positions; inconsistent axis binding across one call's
arguments), axes-rank (rank contradiction at a call site; reduction axis
outside the tracked rank).
"""
import jax
import jax.numpy as jnp

from repro.analysis.annotations import axes


# BUG axes-missing: _analyze_multi_jax is a dispatch surface and must
# declare its contract
def _analyze_multi_jax(xs, stts):
    return xs.sum() + stts.sum()


@axes("K,B,N", stts="K,S")
def cascade(xs, stts):
    return xs.sum(axis=-1) + stts.sum(axis=-1)[:, None]


@axes("K,B,N", stts="K,S")
def dispatch_transposed(t, stts):
    # BUG axes-mismatch: the [K,B,N] plane is fed transposed as [B,K,N]
    tt = jnp.transpose(t, (1, 0, 2))
    return cascade(tt, stts)


@axes("G,E,N", stts="E,S")
def dispatch_inconsistent(t, stts):
    # BUG axes-mismatch: renaming is legal, but one call may not bind the
    # contract's K to both G (via t) and E (via stts)
    return cascade(t, stts)


@axes("K,B,N")
def dispatch_wrong_rank(t):
    # BUG axes-rank: contract wants [K,B,N] (rank 3), flattened is rank 1
    flat = t.sum(axis=0)
    return cascade(flat, flat)


@axes("B,N")
def reduce_out_of_range(x):
    # BUG axes-rank: axis=2 does not exist on a [B,N] operand
    return x.sum(axis=2)
