"""Clean counterpart of bad_axes.py: 0 findings.

Contracts declared on every required surface; consistent renaming at call
sites (a sweep's G binds the callee's K everywhere); propagation through
transpose-and-back, reductions, indexing and a vmap closure all check out.
"""
import jax
import jax.numpy as jnp

from repro.analysis.annotations import axes


@axes("K,B,N", stts="K,S")
def _analyze_multi_jax(xs, stts):
    return xs.sum(axis=-1) + stts.sum(axis=-1)[:, None]


@axes("K,B,N", stts="K,S")
def cascade(xs, stts):
    return xs.sum(axis=-1) + stts.sum(axis=-1)[:, None]


@axes("G,B,N", stts="G,S")
def dispatch_renamed(t, stts):
    # G consistently binds the callee's K: legal renaming
    return cascade(t, stts)


@axes("K,B,N", stts="K,S")
def dispatch_roundtrip(t, stts):
    # transpose there and back: the tracked spec returns to [K,B,N]
    tt = jnp.transpose(t, (1, 0, 2))
    back = jnp.transpose(tt, (1, 0, 2))
    return cascade(back, stts)


@axes("K,B,N", stts="K,S")
def dispatch_vmapped(t, stts):
    # the closure sees [B,N] rows; its reductions stay in range
    def one(row):
        return row.sum(axis=1)

    per_session = jax.vmap(one)(t)
    return per_session + stts.sum(axis=-1)[:, None]


@axes("B,N")
def reduce_in_range(x):
    total = x.sum(axis=1)
    kept = x.max(axis=0, keepdims=True)
    return total, kept[0]
