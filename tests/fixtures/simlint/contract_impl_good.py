"""Clean contract pair: summary keys exactly match the key-lock test."""


class SimReport:
    def __init__(self):
        self.epochs = 0
        self.latency_ns = 0.0

    def summary(self):
        return {
            "epochs": self.epochs,
            "latency_ns": self.latency_ns,
        }
