"""Seeded violations: all three units rules in one file.

unit-mismatch (cross-unit add/compare, wrong helper input, contradicting
suffix assignment), unit-return (function suffix vs returned unit),
unit-raw-conversion (bare 1e9-family literal against a united value).
"""
from repro.core.units import ns_to_s


def total_latency_ns(native_ns, coherency_s):
    # BUG unit-mismatch: adding seconds to nanoseconds
    combined = native_ns + coherency_s
    return combined


def report_seconds(latency_ns):
    # BUG unit-raw-conversion: the ns->s scale change bypasses core.units
    return latency_ns * 1e-9


def window_ns(span_s):
    # BUG unit-return: function is *_ns by suffix but returns seconds
    return span_s


def fold(delay_ns, budget_s):
    # BUG unit-mismatch: comparing nanoseconds against seconds
    if delay_ns > budget_s:
        return ns_to_s(delay_ns)
    # BUG unit-mismatch: ns_to_s expects nanoseconds, got seconds
    return ns_to_s(budget_s)


def stamp(total_ns, wall_s):
    # BUG unit-mismatch: a *_s name assigned a nanosecond value
    elapsed_s = total_ns
    return elapsed_s
