"""Seeded violations: all four jit-hygiene rules in one file.

jit-donate (pipeline entry point jitted without donation), jit-host-sync
(cast / np.* / branch / .item() on traced values), jit-f64 (f64 dtype in
the kernel path), jit-aot-bypass (.lower().compile() outside a 'build'
thunk).
"""
import jax
import jax.numpy as jnp
import numpy as np


def _analyze_pipeline_jax(planes, weights):
    return jnp.sum(planes * weights)


# BUG jit-donate: the staging planes are ring-buffered for donation
analyze = jax.jit(_analyze_pipeline_jax)


def kernel(x, scale):
    # BUG jit-host-sync: float() concretizes the tracer
    s = float(scale)
    # BUG jit-host-sync: np.* materializes the traced array on host
    m = np.mean(x)
    # BUG jit-host-sync: branching on a traced value
    if m > 0:
        x = x - m
    # BUG jit-host-sync: .item() forces a device sync per trace
    peak = x.max().item()
    # BUG jit-f64: f64 leaks into the f32 kernel path
    acc = jnp.zeros((4,), dtype=jnp.float64)
    return x * s + acc.sum() + peak


kernel_jit = jax.jit(kernel)


def compile_now(fn, x):
    # BUG jit-aot-bypass: AOT compile outside AotDispatchCache's build thunk
    return jax.jit(fn).lower(x).compile()
