"""Seeded violation: the PR-2 weight/host drop, reconstructed.

A slice_by_quantum-style rebuild gathers five columns of an existing trace
and lets ``weight``/``host`` silently reset to their defaults (exact-weight
1, host 0) — the event-columns checker must flag both the constructor form
and the ``MemEvents.build`` form.
"""
from repro.core.events import MemEvents


def slice_by_quantum(ev, lo, hi):
    pick = (ev.t_ns >= lo) & (ev.t_ns < hi)
    # BUG: gathers five columns, resets PEBS multiplicity and host tags
    return MemEvents(
        ev.t_ns[pick], ev.pool[pick], ev.bytes_[pick], ev.is_write[pick],
        ev.region[pick],
    )


def halve_bytes(ev):
    # BUG: build() cannot carry weight/host at all
    return MemEvents.build(ev.t_ns, ev.pool, ev.bytes_ * 0.5, ev.is_write)
