"""Checkpointing (atomic save/restore/gc), FT manager, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.checkpoint.manager import CheckpointManager, FaultToleranceConfig
from repro.data.pipeline import SyntheticPipeline
from repro.models import ModelConfig


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,))},
        "opt": {"mu": jnp.ones((8, 8)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    t = _tree()
    ckpt.save_checkpoint(d, 5, t)
    restored, step = ckpt.restore_checkpoint(d, _tree(seed=1))
    assert step == 5
    np.testing.assert_allclose(restored["params"]["w"], t["params"]["w"])
    assert int(restored["opt"]["step"]) == 7


def test_latest_and_gc(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        ckpt.save_checkpoint(d, s, _tree())
    assert ckpt.latest_step(d) == 4
    mgr = CheckpointManager(FaultToleranceConfig(directory=d, interval_steps=1, keep=2))
    mgr.maybe_save(5, _tree())
    assert ckpt.list_steps(d) == [4, 5]


def test_uncommitted_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tree())
    # fake a crashed (uncommitted) step 2
    os.makedirs(os.path.join(d, "step_00000002"))
    assert ckpt.latest_step(d) == 1


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        ckpt.restore_checkpoint(d, {"w": jnp.zeros((5, 5))})


def test_manager_resume_or_init(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(FaultToleranceConfig(directory=d, interval_steps=1))
    state, start = mgr.resume_or_init(_tree)
    assert start == 0
    mgr.maybe_save(3, state)
    state2, start2 = mgr.resume_or_init(_tree)
    assert start2 == 4
    np.testing.assert_allclose(state2["params"]["w"], state["params"]["w"])


def test_straggler_detection():
    mgr = CheckpointManager(FaultToleranceConfig(straggler_factor=2.0))
    for i in range(5):
        assert not mgr.observe_step(i, 1.0)
    assert mgr.observe_step(5, 3.0)  # 3x the EWMA
    assert len(mgr.straggler_events) == 1
    # EWMA not poisoned by the straggler
    assert not mgr.observe_step(6, 1.1)


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #

CFG = ModelConfig(
    name="t", family="dense", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab_size=128,
)


def test_pipeline_deterministic_and_restartable():
    p1 = SyntheticPipeline(CFG, batch=4, seq_len=16, seed=7)
    p2 = SyntheticPipeline(CFG, batch=4, seq_len=16, seed=7)
    b1, b2 = p1.batch_at(42), p2.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # different steps differ
    assert not np.array_equal(b1["tokens"], p1.batch_at(43)["tokens"])


def test_pipeline_labels_are_next_tokens():
    p = SyntheticPipeline(CFG, batch=2, seq_len=8, seed=0)
    b = p.batch_at(0)
    assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)


def test_pipeline_host_sharding():
    ps = [SyntheticPipeline(CFG, batch=8, seq_len=4, seed=1, n_hosts=2, host_id=h) for h in (0, 1)]
    b0, b1 = ps[0].batch_at(0), ps[1].batch_at(0)
    assert b0["tokens"].shape[0] == 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_pipeline_prefetch_thread():
    p = SyntheticPipeline(CFG, batch=2, seq_len=8, seed=0).start()
    it = iter(p)
    a = next(it)
    b = next(it)
    p.stop()
    assert a["tokens"].shape == (2, 8)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
