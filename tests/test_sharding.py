"""Sharding-rule tests on an abstract production-shaped mesh (no devices)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as cfgs
from repro.distributed import sharding as shr
from repro.launch.mesh import make_abstract_mesh
from repro.models import Model


def _mesh(multi=False):
    if multi:
        return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_abstract_mesh((16, 16), ("data", "model"))


def _pshapes(arch):
    cfg = cfgs.get_config(arch)
    return cfg, jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0)))


def _check_divisibility(shapes, specs, mesh):
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for sh, spec in zip(flat_shapes, flat_specs):
        for dim, axis in zip(sh.shape, tuple(spec) + (None,) * 8):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, f"{sh.shape} not divisible by {axis}={size}"


@pytest.mark.parametrize("arch", cfgs.ARCH_IDS)
@pytest.mark.parametrize("strategy", ["dp_tp", "fsdp_tp"])
def test_param_specs_divisible(arch, strategy):
    cfg, shapes = _pshapes(arch)
    mesh = _mesh()
    specs = shr.param_pspecs(shapes, cfg, mesh, strategy)
    _check_divisibility(shapes, specs, mesh)


def test_model_axis_actually_used():
    """TP must shard the big matmuls for every arch (not silently replicate)."""
    for arch in cfgs.ARCH_IDS:
        cfg, shapes = _pshapes(arch)
        mesh = _mesh()
        specs = shr.param_pspecs(shapes, cfg, mesh, "dp_tp")
        flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        used = any("model" in str(s) for s in flat)
        assert used, f"{arch}: no parameter sharded on the model axis"


def test_fsdp_shards_more_than_dp():
    cfg, shapes = _pshapes("mistral-large-123b")
    mesh = _mesh()
    dp = shr.param_pspecs(shapes, cfg, mesh, "dp_tp")
    fs = shr.param_pspecs(shapes, cfg, mesh, "fsdp_tp")

    def sharded_fraction(specs):
        flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        return sum("data" in str(s) for s in flat) / len(flat)

    assert sharded_fraction(fs) > sharded_fraction(dp)


def test_input_specs_batch_sharded():
    cfg = cfgs.get_config("qwen3-0.6b")
    mesh = _mesh(multi=True)
    ins = cfgs.input_specs(cfg, cfgs.SHAPES["train_4k"])
    specs = shr.input_pspecs(ins, mesh)
    tok = specs["tokens"]
    assert tok[0] == ("pod", "data")
    _check_divisibility(ins, specs, mesh)


def test_decode_cache_specs_divisible():
    for arch in ("mistral-large-123b", "jamba-v0.1-52b", "mamba2-2.7b"):
        cfg = cfgs.get_config(arch)
        mesh = _mesh()
        ins = cfgs.input_specs(cfg, cfgs.SHAPES["decode_32k"])
        specs = shr.input_pspecs(ins, mesh)
        _check_divisibility(ins, specs, mesh)


def test_batch_axes():
    assert shr.batch_axes(_mesh()) == ("data",)
    assert shr.batch_axes(_mesh(multi=True)) == ("pod", "data")
