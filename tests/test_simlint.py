"""simlint end-to-end: every checker's true positives on the seeded-violation
corpus (tests/fixtures/simlint), zero false positives on the clean
counterparts, suppression semantics, the repo-wide strict gate, the CLI, and
the runtime sanitizers.

The sanitizer tests are marked ``no_sanitize``: they patch ``threading`` /
toggle ``jax_log_compiles`` themselves and must not run nested inside the
``SIMLINT_SANITIZE=1`` autouse harness.
"""
import json
import os
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis import CheckConfig, run_checks

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "simlint"


def _check(*names, checkers=None, strict=False, config=None):
    return run_checks(
        [FIXTURES / n for n in names],
        root=FIXTURES,
        strict=strict,
        checker_names=checkers,
        config=config,
    )


# --------------------------------------------------------------------------- #
# lock-discipline
# --------------------------------------------------------------------------- #


def test_lock_checker_flags_report_race():
    rep = _check("bad_report_race.py", checkers=["locks"])
    rules = {f.rule for f in rep.findings}
    assert rules == {"lock-discipline"}
    # fold: _report + _folds, snapshot: _report, escape: closure _report
    assert len(rep.findings) == 4
    methods = {f.message.split("'")[5] for f in rep.findings}
    assert methods == {"RacyClient.fold", "RacyClient.snapshot",
                       "RacyClient.escape"}


def test_lock_checker_flags_closure_escaping_the_lock():
    """A callback built under the lock runs later without it — the lexical
    checker must treat nested defs/lambdas as unlocked (the PR-5 shape)."""
    rep = _check("bad_report_race.py", checkers=["locks"])
    assert any("RacyClient.escape" in f.message for f in rep.findings)


def test_lock_checker_clean_on_locked_variant():
    rep = _check("good_report_race.py", checkers=["locks"])
    assert rep.ok, [f.format() for f in rep.findings]


# --------------------------------------------------------------------------- #
# event-columns (the PR-2 weight/host drop)
# --------------------------------------------------------------------------- #


def test_contract_checker_flags_weight_drop():
    rep = _check("bad_weight_drop.py", checkers=["contracts"])
    assert {f.rule for f in rep.findings} == {"event-columns"}
    assert len(rep.findings) == 2
    msgs = sorted(f.message for f in rep.findings)
    assert any("MemEvents.build" in m for m in msgs)
    assert any("weight/host" in m for m in msgs)


def test_contract_checker_clean_on_threaded_columns():
    rep = _check("good_weight_drop.py", checkers=["contracts"])
    assert rep.ok, [f.format() for f in rep.findings]


# --------------------------------------------------------------------------- #
# summary-contract
# --------------------------------------------------------------------------- #


def _contract_config(which):
    return CheckConfig(summary_contracts=(
        (f"contract_impl_{which}.py", "SimReport",
         f"contract_test_{which}.py", "test_sim_report_summary_keys_locked"),
    ))


def test_summary_contract_drift_reported_both_ways():
    rep = _check("contract_impl_bad.py", checkers=["contracts"],
                 config=_contract_config("bad"))
    drift = [f for f in rep.findings if f.rule == "summary-contract"]
    assert len(drift) == 1
    assert "p99_ns" in drift[0].message  # summary emits, test never locks
    assert "dropped_epochs" in drift[0].message  # test locks, never emitted


def test_summary_contract_clean_when_keys_match():
    rep = _check("contract_impl_good.py", checkers=["contracts"],
                 config=_contract_config("good"))
    assert rep.ok, [f.format() for f in rep.findings]


# --------------------------------------------------------------------------- #
# jit-hygiene
# --------------------------------------------------------------------------- #


def test_jit_checker_flags_all_four_rules():
    rep = _check("bad_jit_hygiene.py", checkers=["jit"])
    rules = {f.rule for f in rep.findings}
    assert rules == {"jit-host-sync", "jit-aot-bypass", "jit-donate",
                     "jit-f64"}
    # cast, np.*, branch, .item()
    assert sum(f.rule == "jit-host-sync" for f in rep.findings) == 4


def test_jit_checker_clean_on_hygienic_variant():
    rep = _check("good_jit_hygiene.py", checkers=["jit"])
    assert rep.ok, [f.format() for f in rep.findings]


# --------------------------------------------------------------------------- #
# framework: suppressions, parse errors
# --------------------------------------------------------------------------- #

_REBUILD = (
    "from repro.core.events import MemEvents\n\n\n"
    "def f(ev):\n"
    "    return MemEvents(ev.t_ns, ev.pool, ev.bytes_, ev.is_write,"
    " ev.region){}\n"
)


def test_justified_suppression_silences_and_passes_strict(tmp_path):
    p = tmp_path / "snippet.py"
    # the marker is concatenated so this test file's own source line does
    # not register as a (then-unused) suppression in the repo-wide scan
    p.write_text(_REBUILD.format(
        "  # simlint" ": ignore[event-columns] -- fixture: defaults intended"))
    rep = run_checks([p], root=tmp_path, strict=True)
    assert rep.ok and len(rep.suppressed) == 1


def test_bare_suppression_rejected_in_strict(tmp_path):
    p = tmp_path / "snippet.py"
    p.write_text(_REBUILD.format("  # simlint" ": ignore[event-columns]"))
    rep = run_checks([p], root=tmp_path)
    assert rep.ok  # non-strict: the suppression still silences the finding
    rep = run_checks([p], root=tmp_path, strict=True)
    assert [f.rule for f in rep.findings] == ["bare-suppression"]


def test_unused_suppression_rejected_in_strict(tmp_path):
    p = tmp_path / "snippet.py"
    p.write_text("x = 1  # simlint" ": ignore[event-columns] -- stale\n")
    rep = run_checks([p], root=tmp_path, strict=True)
    assert [f.rule for f in rep.findings] == ["unused-suppression"]


def test_parse_error_is_a_finding_not_a_crash(tmp_path):
    p = tmp_path / "broken.py"
    p.write_text("def f(:\n")
    rep = run_checks([p], root=tmp_path)
    assert [f.rule for f in rep.findings] == ["parse-error"]


# --------------------------------------------------------------------------- #
# the repo itself: strict gate + annotation regression locks
# --------------------------------------------------------------------------- #


def test_repo_wide_strict_gate_is_clean():
    paths = [REPO / d for d in ("src/repro", "tests", "benchmarks", "examples")]
    rep = run_checks([p for p in paths if p.exists()], root=REPO, strict=True)
    assert rep.ok, "\n".join(f.format() for f in rep.findings)
    assert rep.files_checked > 50
    # the strict gate implies: every suppression justified and in use
    assert all(s.justification for _, s in rep.suppressed)


def test_concurrency_core_keeps_its_guard_annotations():
    """Regression lock for the PR-5 fix class: the lock-discipline guards on
    the concurrency core must stay declared (deleting them would silently
    turn the checker off for exactly the files it was built for)."""
    for rel in ("src/repro/core/engine.py", "src/repro/core/attach.py",
                "src/repro/core/fabric.py"):
        assert "_simlint_guards" in (REPO / rel).read_text(), rel


def test_cli_strict_json_clean_on_repo():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict", "--json"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    data = json.loads(out.stdout)
    assert data["findings"] == []
    assert data["files_checked"] > 30
    assert all(s["justification"] for s in data["suppressed"])


def test_cli_exits_nonzero_on_findings(tmp_path):
    p = tmp_path / "snippet.py"
    p.write_text(_REBUILD.format(""))
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root", str(tmp_path),
         str(p)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 1, out.stdout + out.stderr
    assert "event-columns" in out.stdout


def test_cli_rejects_unknown_checker():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--checkers", "nope"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 2
    assert "unknown checkers" in out.stderr


# --------------------------------------------------------------------------- #
# LockOrderSanitizer
# --------------------------------------------------------------------------- #


def _inverted_order_program():
    a = threading.Lock()
    b = threading.Lock()
    with a:
        with b:
            pass
    with b:
        with a:
            pass


@pytest.mark.no_sanitize
def test_lock_order_cycle_detected():
    from repro.analysis.sanitize import LockOrderError, LockOrderSanitizer

    with pytest.raises(LockOrderError, match="lock-order cycle"):
        with LockOrderSanitizer():
            _inverted_order_program()


@pytest.mark.no_sanitize
def test_lock_order_record_only_reports_without_raising():
    from repro.analysis.sanitize import LockOrderSanitizer

    san = LockOrderSanitizer(record_only=True)
    with san:
        _inverted_order_program()
    cycle = san.find_cycle()
    assert cycle is not None
    assert "lock-order cycle" in san.format_cycle(cycle)


@pytest.mark.no_sanitize
def test_lock_order_clean_on_consistent_nesting():
    from repro.analysis.sanitize import LockOrderSanitizer

    san = LockOrderSanitizer()
    with san:  # same nesting twice: one edge, no cycle
        a = threading.Lock()
        b = threading.Lock()
        for _ in range(2):
            with a:
                with b:
                    pass
    assert san.locks_created == 2
    assert len(san.edges) == 1
    assert san.find_cycle() is None


@pytest.mark.no_sanitize
def test_lock_order_sanitizer_restores_factories():
    from repro.analysis.sanitize import LockOrderSanitizer

    orig_lock, orig_rlock = threading.Lock, threading.RLock
    with LockOrderSanitizer():
        assert threading.Lock is not orig_lock
    assert threading.Lock is orig_lock and threading.RLock is orig_rlock


@pytest.mark.no_sanitize
def test_lock_order_wrapped_condition_wait_notify():
    """threading.Condition must keep working over wrapped locks (it relies
    on _is_owned/_release_save/_acquire_restore), across real threads."""
    from repro.analysis.sanitize import LockOrderSanitizer

    with LockOrderSanitizer():
        for lock in (threading.Lock(), threading.RLock(), None):
            cv = threading.Condition(lock)
            done = []

            def worker():
                with cv:
                    done.append(1)
                    cv.notify()

            t = threading.Thread(target=worker)
            with cv:
                t.start()
                assert cv.wait_for(lambda: done, timeout=10)
            t.join()


# --------------------------------------------------------------------------- #
# RecompileSanitizer
# --------------------------------------------------------------------------- #


def _build_exe(shape=(8,)):
    import jax
    import jax.numpy as jnp

    return jax.jit(lambda x: x * 2.0).lower(  # simlint: ignore[jit-aot-bypass] -- this IS the build thunk the tests hand to AotDispatchCache.get
        jnp.ones(shape, jnp.float32)).compile()


@pytest.mark.no_sanitize
def test_recompile_sanitizer_steady_state_passes():
    from repro.analysis.sanitize import RecompileSanitizer
    from repro.core.aot import AotDispatchCache

    cache = AotDispatchCache()
    cache.warm("k", _build_exe)
    with RecompileSanitizer() as san:
        exe, hit = cache.get("k", _build_exe)
        assert hit
    assert san.aot_lowerings == 0


@pytest.mark.no_sanitize
def test_recompile_sanitizer_raises_on_cache_miss():
    from repro.analysis.sanitize import RecompileError, RecompileSanitizer
    from repro.core.aot import AotDispatchCache

    cache = AotDispatchCache()
    with pytest.raises(RecompileError, match="AOT lowering"):
        with RecompileSanitizer():
            cache.get("never-warmed", lambda: _build_exe((16,)))


@pytest.mark.no_sanitize
def test_recompile_sanitizer_budget_and_record_only():
    from repro.analysis.sanitize import RecompileSanitizer
    from repro.core.aot import AotDispatchCache

    # both caches stay referenced: the registry is a WeakSet, so dropping
    # one mid-scope would shrink the baseline under the sanitizer's feet
    cache1 = AotDispatchCache()
    with RecompileSanitizer(allowed_lowerings=1):
        cache1.get("one-build-allowed", lambda: _build_exe((32,)))
    san = RecompileSanitizer(record_only=True)
    with san:
        cache2 = AotDispatchCache()
        cache2.get("recorded-miss", lambda: _build_exe((64,)))
    assert san.aot_lowerings == 1


@pytest.mark.no_sanitize
def test_recompile_sanitizer_sees_jit_compiles():
    import jax.numpy as jnp
    import jax

    from repro.analysis.sanitize import RecompileSanitizer

    san = RecompileSanitizer(record_only=True)
    with san:  # fresh function object + odd shape: a guaranteed real compile
        jax.jit(lambda x: x * 3.0 - 1.0)(jnp.ones((13,), jnp.float32))
    assert san.jit_compiles >= 1
    assert any("Compiling" in e for e in san.compile_events)
