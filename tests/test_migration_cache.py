"""Vectorized migration engine + device-cache model (ISSUE 3 tentpole):
vector-vs-loop decision equivalence, weight/host threading, cache hit-rate
monotonicity, capacity-0 exactness, and migration under a shared fabric."""

import numpy as np
import pytest

from repro.core import (
    CACHELINE_BYTES,
    ClassMapPolicy,
    CXLMemSim,
    DeviceCacheConfig,
    DeviceCacheModel,
    EpochAnalyzer,
    MemEvents,
    MigrationConfig,
    MigrationSimulator,
    Phase,
    Access,
    FabricSession,
    RegionMap,
    Tenant,
    analyze_ref,
    figure1_topology,
    pooled_topology,
    two_tier_topology,
)

FLAT = figure1_topology().flatten()
PAGE = 4096


def _random_regions(rng, n=40):
    """Two identical RegionMaps (decisions mutate Region.pool in place)."""
    sizes = (rng.integers(1, 600, size=n) * PAGE).tolist()
    pools = rng.integers(0, FLAT.n_pools, size=n).tolist()
    maps = []
    for _ in range(2):
        rm = RegionMap()
        for i, (s, p) in enumerate(zip(sizes, pools)):
            rm.alloc(f"r{i}", int(s), "kvcache", pool=int(p))
        maps.append(rm)
    return maps


def _trace(rng, n_regions, n_events, pool_vec, weight=None):
    # skewed: each epoch touches a random half of the regions, so the rest
    # decay cold — exercising demotions as well as budget-truncated promotions
    active = rng.choice(n_regions, size=max(n_regions // 2, 1), replace=False)
    reg = rng.choice(active, size=n_events).astype(np.int32)
    ev = MemEvents(
        t_ns=np.sort(rng.uniform(0, 1e5, size=n_events)),
        pool=pool_vec[reg].astype(np.int32),
        bytes_=np.full((n_events,), 64.0),
        is_write=rng.random(n_events) < 0.3,
        region=reg,
    )
    if weight is not None:
        import dataclasses

        ev = dataclasses.replace(ev, weight=weight)
    return ev


# --------------------------------------------------------------------------- #
# vectorized decisions == loop reference
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_vector_matches_loop_on_random_regions(seed):
    rng = np.random.default_rng(seed)
    rm_v, rm_l = _random_regions(rng)
    cfg = MigrationConfig(
        mode="software",
        promote_threshold=8.0,
        demote_threshold=3.0,
        # tight budget so the promotion prefix actually truncates
        local_budget_bytes=int(sum(r.nbytes for r in rm_v) // 3),
        demote_pool="cxl_pool2",
    )
    sim_v = MigrationSimulator(cfg, rm_v, FLAT)
    sim_l = MigrationSimulator(cfg, rm_l, FLAT, impl="loop")
    for _ in range(4):
        pool_vec = rm_l.pool_vector()
        tr = _trace(rng, len(rm_v), 3000, pool_vec)
        out_v, mig_v = sim_v.observe_and_migrate(tr)
        out_l, mig_l = sim_l.observe_and_migrate(tr)
        np.testing.assert_array_equal(rm_v.pool_vector(), rm_l.pool_vector())
        np.testing.assert_array_equal(sim_v._pool, sim_l._pool)
        assert sim_v.promotions == sim_l.promotions
        assert sim_v.demotions == sim_l.demotions
        assert sim_v.moved_bytes_total == sim_l.moved_bytes_total
        assert mig_v.n == mig_l.n
        # same aggregate copy traffic per pool (event ordering may differ)
        P = FLAT.n_pools
        np.testing.assert_allclose(
            np.bincount(mig_v.pool, weights=mig_v.bytes_, minlength=P),
            np.bincount(mig_l.pool, weights=mig_l.bytes_, minlength=P),
        )
        np.testing.assert_array_equal(out_v.pool, out_l.pool)
    assert sim_v.promotions > 0 and sim_v.demotions > 0  # scenario is non-trivial


def test_hardware_vector_matches_loop_remap():
    rng = np.random.default_rng(7)
    rm_v, rm_l = _random_regions(rng, n=12)
    cfg = MigrationConfig(
        mode="hardware", promote_threshold=4.0, reaction_ns=4e4,
        granularity_bytes=CACHELINE_BYTES, local_budget_bytes=1 << 32,
    )
    sim_v = MigrationSimulator(cfg, rm_v, FLAT)
    sim_l = MigrationSimulator(cfg, rm_l, FLAT, impl="loop")
    tr = _trace(rng, len(rm_v), 500, rm_l.pool_vector())
    out_v, _ = sim_v.observe_and_migrate(tr)
    out_l, _ = sim_l.observe_and_migrate(tr)
    np.testing.assert_array_equal(out_v.pool, out_l.pool)
    # mid-epoch remap actually moved post-reaction events
    assert (out_v.pool != tr.pool).any()


# --------------------------------------------------------------------------- #
# weight / host threading (the PR-2 bug class, fixed here for migration)
# --------------------------------------------------------------------------- #


def test_remap_preserves_weight_and_host():
    rm = RegionMap()
    reg = rm.alloc("hot", 1 << 20, "kvcache", pool=1)
    sim = MigrationSimulator(
        MigrationConfig(mode="hardware", promote_threshold=1, reaction_ns=3e4,
                        local_budget_bytes=1 << 30),
        rm, FLAT, host=2,
    )
    n = 300
    tr = MemEvents(
        t_ns=np.linspace(0, 1e5, n),
        pool=np.full((n,), 1, np.int32),
        bytes_=np.full((n,), 64.0),
        is_write=np.zeros((n,), bool),
        region=np.full((n,), reg.rid, np.int32),
        weight=np.full((n,), 4.0),  # PEBS 1/rate multiplicity
        host=np.full((n,), 2, np.int32),
    )
    remapped, mig = sim.observe_and_migrate(tr)
    np.testing.assert_array_equal(remapped.weight, tr.weight)
    np.testing.assert_array_equal(remapped.host, tr.host)
    np.testing.assert_array_equal(remapped.bytes_, tr.bytes_)
    assert mig.n > 0
    assert (mig.host == 2).all()  # copy traffic rides the simulator's host
    assert (mig.weight == 1.0).all()  # copies are exact traffic, not sampled


def test_access_count_refreshed_for_small_maps():
    """Region.access_count (HotnessTieredPolicy's fallback input) keeps the
    legacy every-epoch refresh for ordinarily-sized region maps."""
    rng = np.random.default_rng(5)
    rm, _ = _random_regions(rng, n=10)
    sim = MigrationSimulator(MigrationConfig(mode="software"), rm, FLAT)
    tr = _trace(rng, len(rm), 500, rm.pool_vector())
    sim.observe_and_migrate(tr)
    got = np.array([r.access_count for r in rm])
    np.testing.assert_array_equal(got, sim._hot_ewma)
    assert got.sum() > 0


def test_freed_region_moves_no_bytes():
    """RegionMap.free() zeroes nbytes in place; the simulator must honor it
    (no phantom copy traffic or budget charge for dead regions)."""
    rm = RegionMap()
    reg = rm.alloc("dead", 8 << 20, "kvcache", pool=1)
    sim = MigrationSimulator(
        MigrationConfig(mode="software", promote_threshold=1,
                        local_budget_bytes=1 << 30),
        rm, FLAT,
    )
    rm.free("dead")
    n = 100
    tr = MemEvents.build(
        np.linspace(0, 1e5, n), [1] * n, [64.0] * n, region=[reg.rid] * n
    )
    _, mig = sim.observe_and_migrate(tr)
    assert sim.moved_bytes_total == 0.0
    assert mig.total_bytes == 0.0
    assert sim._budget.used == 0.0


def test_analyze_batch_rejects_mismatched_scales():
    tr = _reuse_setup()[1]
    with pytest.raises(ValueError, match="lat_scales"):
        EpochAnalyzer(FLAT).analyze_batch([tr, tr], [None])


def test_single_map_cache_on_multi_host_topology():
    """One attached program + cache on a Topology(n_hosts=2) must work."""
    flat2 = pooled_topology(n_hosts=2).flatten()
    rm = RegionMap()
    reg = rm.alloc("kv", 16 * PAGE, "kvcache", pool=1)
    model = DeviceCacheModel(
        DeviceCacheConfig(capacity_bytes=PAGE * 64, line_bytes=PAGE), flat2, [rm]
    )
    n = 200
    tr = MemEvents.build(
        np.linspace(0, 1e5, n), [1] * n, [float(PAGE)] * n, region=[reg.rid] * n
    )
    frac = model.observe(tr)
    assert frac.shape == (2, 2) and frac[0, 1] > 0 and frac[1].sum() == 0


def test_hotness_ewma_is_weight_aware():
    """100 weight-1 events must decide like 50 weight-2 events (PEBS)."""
    outs = []
    for n, w in ((100, 1.0), (50, 2.0)):
        rm = RegionMap()
        reg = rm.alloc("kv", 1 << 20, "kvcache", pool=1)
        sim = MigrationSimulator(
            MigrationConfig(mode="software", promote_threshold=30,
                            local_budget_bytes=1 << 30),
            rm, FLAT,
        )
        tr = MemEvents(
            t_ns=np.linspace(0, 1e5, n),
            pool=np.full((n,), 1, np.int32),
            bytes_=np.full((n,), 64.0),
            is_write=np.zeros((n,), bool),
            region=np.full((n,), reg.rid, np.int32),
            weight=np.full((n,), w),
        )
        sim.observe_and_migrate(tr)
        outs.append((sim.promotions, float(sim._hot_ewma[reg.rid])))
    assert outs[0] == outs[1]
    assert outs[0][0] == 1  # ewma 50 >= threshold 30


# --------------------------------------------------------------------------- #
# the demotion dead-end (local-born regions) and the demote_pool fix
# --------------------------------------------------------------------------- #


def test_local_born_cold_region_pins_budget_without_demote_pool():
    rm = RegionMap()
    rm.alloc("cold_local", 1 << 20, "param", pool=0)
    hot = rm.alloc("hot_remote", 1 << 20, "kvcache", pool=1)
    cfg = MigrationConfig(
        mode="software", promote_threshold=5, demote_threshold=5,
        local_budget_bytes=(1 << 20) + 1,  # room for exactly one region
    )
    sim = MigrationSimulator(cfg, rm, FLAT)
    n = 200
    tr = MemEvents.build(
        np.linspace(0, 1e5, n), [1] * n, [64.0] * n, region=[hot.rid] * n
    )
    sim.observe_and_migrate(tr)
    # dead-end: the cold local-born region can never demote, so the hot
    # remote region never fits
    assert sim.demotions == 0 and sim.promotions == 0
    assert rm["hot_remote"].pool == 1


def test_demote_pool_unpins_local_born_cold_regions():
    rm = RegionMap()
    rm.alloc("cold_local", 1 << 20, "param", pool=0)
    hot = rm.alloc("hot_remote", 1 << 20, "kvcache", pool=1)
    cfg = MigrationConfig(
        mode="software", promote_threshold=5, demote_threshold=5,
        local_budget_bytes=(1 << 20) + 1, demote_pool="cxl_pool3",
    )
    sim = MigrationSimulator(cfg, rm, FLAT)
    n = 200
    tr = MemEvents.build(
        np.linspace(0, 1e5, n), [1] * n, [64.0] * n, region=[hot.rid] * n
    )
    sim.observe_and_migrate(tr)
    assert rm["cold_local"].pool == FLAT.pool_names.index("cxl_pool3")
    assert rm["hot_remote"].pool == 0  # freed budget admits the promotion
    assert sim.demotions == 1 and sim.promotions == 1


# --------------------------------------------------------------------------- #
# device cache: exactness at zero capacity, monotonicity, oracle agreement
# --------------------------------------------------------------------------- #


def _reuse_setup(lines=32, events=600):
    """One hot region in pool 1 whose working set is ``lines`` cache lines."""
    rm = RegionMap()
    reg = rm.alloc("kv", lines * PAGE, "kvcache", pool=1)
    rng = np.random.default_rng(0)
    n = events
    tr = MemEvents(
        t_ns=np.sort(rng.uniform(0, 1e5, n)),
        pool=np.full((n,), 1, np.int32),
        bytes_=np.full((n,), float(PAGE)),
        is_write=np.zeros((n,), bool),
        region=np.full((n,), reg.rid, np.int32),
    )
    return rm, tr


def test_zero_capacity_cache_reproduces_no_cache_exactly():
    rm, tr = _reuse_setup()
    an = EpochAnalyzer(FLAT)
    base = an.analyze(tr)
    model = DeviceCacheModel(DeviceCacheConfig(capacity_bytes=0), FLAT, [rm])
    scale = model.latency_scale(model.observe(tr))
    np.testing.assert_array_equal(scale, np.ones_like(scale))
    cached = an.analyze(tr, lat_scale=scale)
    assert cached.latency_ns == base.latency_ns
    assert cached.congestion_ns == base.congestion_ns
    assert cached.bandwidth_ns == base.bandwidth_ns
    np.testing.assert_array_equal(cached.per_pool_latency_ns, base.per_pool_latency_ns)


def test_cache_hit_rate_monotone_delay_monotone():
    cfgs = [
        DeviceCacheConfig(capacity_bytes=k * PAGE * 64, line_bytes=PAGE, n_sets=64)
        for k in range(4)
    ]
    an = EpochAnalyzer(FLAT)
    fracs, delays = [], []
    for cfg in cfgs:
        rm, tr = _reuse_setup()
        model = DeviceCacheModel(cfg, FLAT, [rm])
        total, frac_sum = 0.0, 0.0
        for _ in range(3):  # warm across epochs: tag state persists
            frac = model.observe(tr)
            frac_sum += frac[0, 1]
            total += an.analyze(tr, lat_scale=model.latency_scale(frac)).total_ns
        fracs.append(frac_sum)
        delays.append(total)
    assert all(b >= a for a, b in zip(fracs, fracs[1:]))  # hit rate up
    assert all(b <= a for a, b in zip(delays, delays[1:]))  # delay down
    assert fracs[1] > 0  # working set fits from one way up
    assert delays[1] < delays[0]  # and that strictly helps


def test_scaled_analysis_matches_numpy_oracle():
    rm, tr = _reuse_setup()
    model = DeviceCacheModel(
        DeviceCacheConfig(capacity_bytes=2 * PAGE * 64, line_bytes=PAGE), FLAT, [rm]
    )
    scale = model.latency_scale(model.observe(tr))
    assert (scale < 1.0).any()  # non-trivial scaling under test
    got = EpochAnalyzer(FLAT).analyze(tr, lat_scale=scale)
    want = analyze_ref(FLAT, tr, lat_scale=scale)
    assert got.latency_ns == pytest.approx(want.latency_ns, rel=1e-4)
    assert got.congestion_ns == pytest.approx(want.congestion_ns, rel=1e-3, abs=1e-6)


def test_attach_with_device_cache_lowers_latency():
    import jax
    import jax.numpy as jnp

    def build():
        rm = RegionMap()
        rm.alloc("w", 1 << 20, "param")
        rm.alloc("kv", 16 * PAGE, "kvcache")
        phases = [Phase("fwd", flops=5e8,
                        accesses=(Access("w", 1 << 20), Access("kv", 1 << 22, True)))]
        return rm, phases

    step = jax.jit(lambda x: (x * 2).sum())
    x = jnp.ones((32,))
    reports = {}
    for cap in (0, 1 << 24):
        rm, phases = build()
        sim = CXLMemSim(
            two_tier_topology(), ClassMapPolicy({"kvcache": "cxl_pool"}),
            cache=DeviceCacheConfig(capacity_bytes=cap, line_bytes=PAGE),
        )
        prog = sim.attach(step, phases, rm)
        reports[cap] = prog.run(2, x)
    assert reports[1 << 24].cache_hit_fraction > 0
    assert reports[1 << 24].latency_s < reports[0].latency_s


# --------------------------------------------------------------------------- #
# migration under the shared fabric
# --------------------------------------------------------------------------- #


def _fabric_tenant(name, kv_pages, hot=False):
    rm = RegionMap()
    rm.alloc("kv_" + name, kv_pages * PAGE, "kvcache")
    rm.alloc("act_" + name, 1 << 18, "activation")
    mult = 64 if hot else 1
    phases = [
        Phase("fwd", flops=5e8,
              accesses=(Access("kv_" + name, mult * kv_pages * PAGE, True),
                        Access("act_" + name, 1 << 18)))
    ]
    return Tenant(name, phases, rm, ClassMapPolicy({"kvcache": "shared_pool"}))


def test_tenant_migration_raises_neighbor_congestion():
    topo = pooled_topology(n_hosts=2, cxl_bandwidth_gbps=8.0)

    def run(migration):
        sess = FabricSession(
            topo,
            [_fabric_tenant("mover", 1024, hot=True), _fabric_tenant("victim", 64)],
            migration=migration,
        )
        sess.run(2)
        return sess

    base = run(None)
    mig = run(
        MigrationConfig(mode="software", promote_threshold=2,
                        local_budget_bytes=1 << 32)
    )
    assert mig.report.migration_moved_bytes > 0
    # the mover's promotion copy traffic queued at the shared switch and
    # showed up in the *victim's* congestion share
    assert mig.report.hosts[1].congestion_s > base.report.hosts[1].congestion_s


def test_fabric_tenants_share_one_local_budget():
    topo = pooled_topology(n_hosts=2)
    sess = FabricSession(
        topo,
        [_fabric_tenant("a", 1024, hot=True), _fabric_tenant("b", 1024, hot=True)],
        migration=MigrationConfig(
            mode="software", promote_threshold=2,
            # room for one tenant's kv region (+ both activations), not two
            local_budget_bytes=1024 * PAGE + (1 << 20),
        ),
    )
    sess.run(2)
    promoted = sum(s.promotions for s in sess._migration)
    assert promoted == 1  # the second promotion lost the shared budget race
