"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.congestion import congestion_scan
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


# --------------------------------------------------------------------------- #
# congestion kernel (the paper's hot loop)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("n", [7, 100, 2048, 5000])
@pytest.mark.parametrize("stt", [0.1, 7.5, 100.0])
@pytest.mark.parametrize("frac", [0.0, 0.5, 1.0])
def test_congestion_kernel_matches_ref(n, stt, frac):
    rng = np.random.default_rng(n)
    t = np.sort(rng.uniform(0, 1e5, n)).astype(np.float32)
    m = rng.random(n) < frac
    start, delay = congestion_scan(jnp.asarray(t), jnp.asarray(m), stt, interpret=True)
    want = ref.serial_queue(jnp.asarray(t), jnp.asarray(m), stt)
    np.testing.assert_allclose(np.asarray(start), np.asarray(want), rtol=1e-6, atol=1e-3)
    assert (np.asarray(delay) >= -1e-3).all()


def test_congestion_kernel_block_boundary_carry():
    """Carry across grid steps: saturated queue spanning many blocks."""
    n, stt = 4096 + 3, 10.0
    t = np.zeros((n,), np.float32)  # all arrive at once -> pure serial queue
    m = np.ones((n,), bool)
    start, _ = congestion_scan(jnp.asarray(t), jnp.asarray(m), stt, block=1024, interpret=True)
    np.testing.assert_allclose(np.asarray(start), np.arange(n) * stt, rtol=1e-5)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #

ATTN_CASES = [
    # B, H, Hk, Sq, Sk, D, causal, qoff
    (1, 4, 2, 256, 256, 64, True, 0),
    (2, 8, 2, 128, 128, 32, False, 0),
    (1, 2, 2, 128, 512, 64, True, 384),  # decode tail with cache
    (1, 16, 8, 512, 512, 128, True, 0),
    (2, 4, 4, 256, 256, 128, True, 0),  # MHA (no GQA)
]


@pytest.mark.parametrize("case", ATTN_CASES, ids=[str(c) for c in ATTN_CASES])
def test_flash_attention_matches_ref(case):
    B, H, Hk, Sq, Sk, D, causal, qoff = case
    ks = jax.random.split(jax.random.PRNGKey(B * Sq + D), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hk, Sk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hk, Sk, D), jnp.float32)
    o = flash_attention(
        q, k, v, q_offset=qoff, causal=causal, block_q=128, block_k=128, interpret=True
    )
    w = ref.mha_attention(q, k, v, causal=causal, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(o), np.asarray(w), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), dtype)
    o = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    w = ref.mha_attention(q, k, v)
    assert o.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(w, np.float32), rtol=tol, atol=tol
    )


def test_chunked_attention_matches_ref_nondivisible():
    from repro.models.attention import chunked_attention

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 200, 32))
    k = jax.random.normal(ks[1], (2, 2, 200, 32))
    v = jax.random.normal(ks[2], (2, 2, 200, 32))
    o = chunked_attention(q, k, v, causal=True, block_q=128, block_k=128)
    w = ref.mha_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(w), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------------- #

SSD_CASES = [
    # B, L, H, P, N, chunk
    (2, 256, 4, 32, 16, 64),
    (1, 128, 2, 64, 128, 128),
    (1, 512, 8, 16, 32, 128),
    (2, 64, 1, 8, 8, 32),
]


@pytest.mark.parametrize("case", SSD_CASES, ids=[str(c) for c in SSD_CASES])
def test_ssd_kernel_matches_naive(case):
    B, L, H, P, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(L + H), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N)) * 0.5
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    w = ref.ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(w), rtol=2e-4, atol=2e-4)


def test_ssd_chunked_ref_matches_naive():
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    B, L, H, P, N = 2, 128, 4, 16, 8
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N)) * 0.5
    y = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    w = ref.ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(w), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# ops dispatch layer
# --------------------------------------------------------------------------- #


def test_ops_dispatch_modes_agree():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    a = ops.attention(q, k, v, impl="ref")
    b = ops.attention(q, k, v, impl="pallas_interpret", block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
    assert ops.get_implementation() in ("ref", "pallas", "pallas_interpret")
    with pytest.raises(ValueError):
        ops.set_implementation("nope")
