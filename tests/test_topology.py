import pytest

from repro.core.topology import (
    Pool,
    Switch,
    Topology,
    figure1_topology,
    local_only_topology,
    two_tier_topology,
)


def test_figure1_structure():
    t = figure1_topology()
    flat = t.flatten()
    assert flat.n_pools == 4
    assert flat.n_switches == 3  # 2 switches + RC
    # local pool traverses nothing
    assert flat.route[0].sum() == 0
    # pool1 -> switch0 + RC
    assert flat.route[1, 0] == 1 and flat.route[1, 2] == 1 and flat.route[1, 1] == 0
    # pool2/3 -> switch1 + switch0 + RC
    for p in (2, 3):
        assert flat.route[p].sum() == 3


def test_total_latency_accumulates_along_path():
    t = figure1_topology()
    p2 = t.pools[2]
    want = 180.0 + 70.0 + 70.0 + 10.0  # media + sw1 + sw0 + RC
    assert t.pool_total_latency_ns(p2) == pytest.approx(want)


def test_bottleneck_bandwidth():
    t = figure1_topology()
    assert t.pool_path_bandwidth_gbps(t.pools[2]) == 32.0
    assert t.pool_path_bandwidth_gbps(t.pools[0]) == 76.8


def test_stage_order_deepest_first():
    flat = figure1_topology().flatten()
    order = list(flat.stage_order())
    # switch1 (depth 2) before switch0 (depth 1) before RC (depth 0)
    assert order.index(1) < order.index(0) < order.index(2)


def test_validation_rejects_bad_topologies():
    with pytest.raises(ValueError):  # no local pool
        Topology(pools=[Pool("a", 100, 10, 1 << 30, parent=None)])
    with pytest.raises(ValueError):  # two local pools
        Topology(
            pools=[
                Pool("a", 100, 10, 1 << 30, is_local=True),
                Pool("b", 100, 10, 1 << 30, is_local=True),
            ]
        )
    with pytest.raises(ValueError):  # unknown parent
        Topology(
            pools=[
                Pool("local", 88, 76, 1 << 30, is_local=True),
                Pool("x", 100, 10, 1 << 30, parent="nope"),
            ]
        )
    with pytest.raises(ValueError):  # cycle
        Topology(
            pools=[Pool("local", 88, 76, 1 << 30, is_local=True)],
            switches=[
                Switch("s1", 10, 10, 1, parent="s2"),
                Switch("s2", 10, 10, 1, parent="s1"),
            ],
        )


def test_local_only_has_zero_route():
    flat = local_only_topology().flatten()
    assert flat.route.sum() == 0


def test_describe_mentions_every_component():
    t = two_tier_topology()
    d = t.describe()
    assert "cxl_pool" in d and "local_dram" in d and "sw" in d
