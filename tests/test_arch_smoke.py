"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as cfgs
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim.adamw import AdamWConfig, adamw_init

B, S = 2, 64


def _inputs(cfg, key):
    if cfg.embed_inputs:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    emb = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    lab = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"embeds": emb, "labels": lab}


@pytest.mark.parametrize("arch", cfgs.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = dataclasses.replace(
        cfgs.get_smoke(arch), dtype=jnp.float32, cache_dtype=jnp.float32
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _inputs(cfg, jax.random.PRNGKey(1))

    # forward
    inp = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
    logits, aux = model.forward(params, inp)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), "NaN in forward logits"

    # one full train step (grad + AdamW)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    opt_state = {"adam": adamw_init(params, opt_cfg), "ef": {}}
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), "non-finite loss"
    assert int(new_opt["adam"]["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum()),
            params, new_params,
        ),
    )
    assert delta > 0, "optimizer made no update"


@pytest.mark.parametrize(
    "arch", [a for a in cfgs.ARCH_IDS if cfgs.get_config(a).causal]
)
def test_smoke_prefill_decode_roundtrip(arch):
    cfg = dataclasses.replace(
        cfgs.get_smoke(arch),
        dtype=jnp.float32,
        cache_dtype=jnp.float32,
        # lossless capacity so MoE decode matches forward exactly
        capacity_factor=float(max(cfgs.get_smoke(arch).n_experts, 1)),
        decode_capacity_factor=float(max(cfgs.get_smoke(arch).n_experts, 1)),
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.embed_inputs:
        inp = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    else:
        inp = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)

    logits, _ = model.forward(params, inp)
    _, caches, clen = model.prefill(params, inp[:, : S - 1], pad_to=S + 4)
    lg_dec, new_caches = model.decode_step(params, caches, inp[:, S - 1 : S], clen)
    ref = logits[:, -1].astype(jnp.float32)
    got = lg_dec.astype(jnp.float32)
    rel = float(jnp.abs(ref - got).max()) / (float(jnp.abs(ref).max()) + 1e-9)
    assert rel < 5e-4, f"decode diverges from forward: rel={rel}"
    # cache structurally unchanged
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


def test_cells_accounting():
    cells = cfgs.cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c["runnable"]]
    assert len(runnable) == 31
    for c in cells:
        if not c["runnable"]:
            assert c["skip"]


@pytest.mark.parametrize("arch", cfgs.ARCH_IDS)
def test_input_specs_abstract(arch):
    """input_specs must be pure ShapeDtypeStructs (no allocation)."""
    cfg = cfgs.get_config(arch)
    for sname, shape in cfgs.SHAPES.items():
        spec = cfgs.input_specs(cfg, shape)
        for leaf in jax.tree.leaves(spec):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_param_counts_hit_targets():
    targets = {
        "mistral-large-123b": (123e9, 0.05),
        "chatglm3-6b": (6e9, 0.10),
        "starcoder2-3b": (3e9, 0.10),
        "qwen3-0.6b": (0.6e9, 0.15),
        "granite-moe-3b-a800m": (3.3e9, 0.10),
        "llama4-maverick-400b-a17b": (400e9, 0.05),
        "jamba-v0.1-52b": (52e9, 0.05),
        "mamba2-2.7b": (2.7e9, 0.05),
        "qwen2-vl-72b": (72e9, 0.05),
        "hubert-xlarge": (1e9, 0.15),
    }
    for arch, (want, tol) in targets.items():
        got = cfgs.get_config(arch).param_counts()["total"]
        assert abs(got - want) / want < tol, f"{arch}: {got/1e9:.2f}B vs {want/1e9}B"
