"""CoherencyModel: BI traffic and miss latency vs hand-computed oracles."""

import numpy as np
import pytest

from repro.core import CoherencyConfig, CoherencyModel, MemEvents, RegionMap


def _regions(pool=1, cls="kvcache"):
    rm = RegionMap()
    rm.alloc("shared", 1 << 20, cls, pool=pool)
    rm.alloc("private", 1 << 20, "activation", pool=pool)
    return rm


def _trace(n_writes, n_reads, rid=0, pool=1):
    n = n_writes + n_reads
    return MemEvents(
        t_ns=np.linspace(0.0, 1000.0, n),
        pool=np.full((n,), pool, np.int32),
        bytes_=np.full((n,), 64.0),
        is_write=np.arange(n) < n_writes,
        region=np.full((n,), rid, np.int32),
    )


# --------------------------------------------------------------------------- #
# single-attach analytic mode
# --------------------------------------------------------------------------- #


def test_epoch_traffic_bi_oracle():
    """n_hosts=3 => 2 sharers: BI count and bytes are exactly writes * 2."""
    rm = _regions()
    cfg = CoherencyConfig(n_hosts=3, shared_classes=("kvcache",))
    model = CoherencyModel(cfg, rm)
    n_writes, n_reads = 40, 60
    bi, extra = model.epoch_traffic(_trace(n_writes, n_reads))
    want_bi = n_writes * 2  # one packet per sharer per write
    assert model.bi_messages_total == want_bi
    assert bi.total_bytes == pytest.approx(want_bi * cfg.bi_message_bytes)
    assert bi.is_write.all()
    # miss latency: reads * writes/(reads+writes) * miss_ns
    want_extra = n_reads * (n_writes / (n_writes + n_reads)) * cfg.coherency_miss_ns
    assert extra == pytest.approx(want_extra)


def test_epoch_traffic_subsampling_preserves_bytes():
    rm = _regions()
    cfg = CoherencyConfig(n_hosts=4, shared_classes=("kvcache",), max_bi_events=16)
    model = CoherencyModel(cfg, rm)
    bi, _ = model.epoch_traffic(_trace(1000, 0))
    assert bi.n == 16  # capped
    assert bi.total_bytes == pytest.approx(1000 * 3 * cfg.bi_message_bytes)


def test_epoch_traffic_subsampling_preserves_weight():
    """Regression (simlint event-columns): the capped BI rebuild must scale
    statistical multiplicity like _bi_for does, not reset it to 1 — else
    weight-proportional (latency-class) charges are biased under the cap."""
    rm = _regions()
    cfg = CoherencyConfig(n_hosts=4, shared_classes=("kvcache",), max_bi_events=16)
    bi, _ = CoherencyModel(cfg, rm).epoch_traffic(_trace(1000, 0))
    assert float(bi.weight.sum()) == pytest.approx(1000 * 3)
    # uncapped path: one packet per sharer per write, exact weight 1 each
    cfg = CoherencyConfig(n_hosts=4, shared_classes=("kvcache",))
    bi, _ = CoherencyModel(cfg, rm).epoch_traffic(_trace(100, 0))
    assert float(bi.weight.sum()) == pytest.approx(100 * 3)


def test_epoch_traffic_single_host_noop():
    for n_hosts in (0, 1):
        model = CoherencyModel(
            CoherencyConfig(n_hosts=n_hosts, shared_classes=("kvcache",)), _regions()
        )
        bi, extra = model.epoch_traffic(_trace(50, 50))
        assert bi.n == 0 and extra == 0.0
        assert model.bi_messages_total == 0.0


def test_epoch_traffic_shared_class_filtering():
    # region class not in shared_classes => no traffic
    model = CoherencyModel(
        CoherencyConfig(n_hosts=2, shared_classes=("param",)), _regions(cls="kvcache")
    )
    bi, extra = model.epoch_traffic(_trace(50, 50))
    assert bi.n == 0 and extra == 0.0
    # shared class but resident in local DRAM (pool 0) => not pooled, no BI
    model = CoherencyModel(
        CoherencyConfig(n_hosts=2, shared_classes=("kvcache",)), _regions(pool=0)
    )
    bi, extra = model.epoch_traffic(_trace(50, 50))
    assert bi.n == 0 and extra == 0.0


# --------------------------------------------------------------------------- #
# fabric mode: sharers derived from the actual per-host traces
# --------------------------------------------------------------------------- #


def _fabric_setup(n_hosts=3):
    maps = []
    for _ in range(n_hosts):
        rm = RegionMap()
        rm.alloc("kv", 1 << 20, "kvcache", pool=1)
        maps.append(rm)
    return maps


def test_fabric_traffic_bi_injected_into_sharers_streams():
    """Writer's writes fan out one BI per observed sharer, landing in the
    sharer's stream (host-tagged, on the sharer's pool mapping)."""
    maps = _fabric_setup(3)
    cfg = CoherencyConfig(shared_classes=("kvcache",))
    model = CoherencyModel(cfg)
    traces = [
        _trace(10, 0),  # host 0 writes 10 times
        _trace(0, 20),  # host 1 only reads
        MemEvents.empty(),  # host 2 never touches the region: NOT a sharer
    ]
    bi, miss = model.fabric_traffic(traces, maps)
    # host 1 (the only other observed sharer) receives host 0's fan-out
    assert bi[1].n == 10
    assert (bi[1].host == 1).all()
    assert (bi[1].pool == 1).all()
    assert bi[1].total_bytes == pytest.approx(10 * cfg.bi_message_bytes)
    # the writer and the absent host receive nothing
    assert bi[0].n == 0 and bi[2].n == 0
    # miss latency only for the reading sharer:
    # reads * remote_writes/total_accesses * miss_ns = 20 * 10/30 * 60
    assert miss[1] == pytest.approx(20 * (10 / 30) * cfg.coherency_miss_ns)
    assert miss[0] == 0.0 and miss[2] == 0.0
    assert model.bi_messages_total == pytest.approx(10.0)


def test_fabric_traffic_sharers_from_traces_not_config():
    """cfg.n_hosts must be irrelevant in fabric mode: with a single observed
    accessor there are no sharers, hence no traffic."""
    maps = _fabric_setup(2)
    model = CoherencyModel(CoherencyConfig(n_hosts=8, shared_classes=("kvcache",)))
    bi, miss = model.fabric_traffic([_trace(50, 50), MemEvents.empty()], maps)
    assert all(b.n == 0 for b in bi)
    assert (miss == 0).all()


def test_fabric_traffic_symmetric_writers():
    """Two writing sharers invalidate each other."""
    maps = _fabric_setup(2)
    cfg = CoherencyConfig(shared_classes=("kvcache",))
    model = CoherencyModel(cfg)
    bi, miss = model.fabric_traffic([_trace(5, 5), _trace(7, 3)], maps)
    assert bi[0].n == 7 and bi[1].n == 5  # each receives the other's writes
    assert bi[0].total_bytes == pytest.approx(7 * cfg.bi_message_bytes)
    # miss: host0 reads=5, remote writes=7, total accesses=20
    assert miss[0] == pytest.approx(5 * (7 / 20) * cfg.coherency_miss_ns)
    assert miss[1] == pytest.approx(3 * (5 / 20) * cfg.coherency_miss_ns)


def test_fabric_traffic_weight_aware_bytes():
    """PEBS-sampled writer traces keep aggregate BI bytes unbiased."""
    maps = _fabric_setup(2)
    cfg = CoherencyConfig(shared_classes=("kvcache",))
    model = CoherencyModel(cfg)
    tr = _trace(10, 0)
    tr = MemEvents(tr.t_ns, tr.pool, tr.bytes_, tr.is_write, tr.region,
                   weight=np.full((tr.n,), 4.0), host=tr.host, qos=tr.qos)
    bi, _ = model.fabric_traffic([tr, _trace(0, 5)], maps)
    assert bi[1].total_bytes == pytest.approx(10 * 4.0 * cfg.bi_message_bytes)
    # statistical multiplicity rides in weight too, so weight-proportional
    # (latency-class) charges for BI messages stay unbiased as well
    assert float(bi[1].weight.sum()) == pytest.approx(10 * 4.0)


def test_fabric_traffic_shared_class_filtering():
    maps = []
    for _ in range(2):
        rm = RegionMap()
        rm.alloc("kv", 1 << 20, "activation", pool=1)  # not a shared class
        maps.append(rm)
    model = CoherencyModel(CoherencyConfig(shared_classes=("kvcache",)))
    bi, miss = model.fabric_traffic([_trace(10, 0), _trace(0, 10)], maps)
    assert all(b.n == 0 for b in bi) and (miss == 0).all()
