"""Fused epoch-analysis pipeline: oracle agreement over randomized
multi-switch topologies and bursty traces, batched-vs-sequential
equivalence, merge planning, staging-buffer reuse, and the async attach
path.  No optional deps — this file keeps the deterministic analyzer
coverage alive when ``hypothesis`` (tests/test_analyzer.py) is absent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analyzer import (
    EpochAnalyzer,
    FineGrainedSimulator,
    analyze_ref,
    plan_cascade,
)
from repro.core.events import EventStager, MemEvents, synthetic_trace
from repro.core.topology import Pool, Switch, Topology, figure1_topology
from repro.kernels.congestion import congestion_cascade
from repro.kernels.ref import merge_sorted_runs, serial_queue_cascade

FLAT = figure1_topology().flatten()


def chain_topology(depth: int = 3) -> Topology:
    """All remote pools behind a ``depth``-switch chain (zero-merge plan)."""
    switches = [
        Switch(f"sw{d}", 70.0, 64.0 - 8.0 * d, 2.0 + d, parent=f"sw{d-1}" if d else None)
        for d in range(depth)
    ]
    return Topology(
        pools=[
            Pool("local", 88.9, 76.8, 1 << 36, is_local=True),
            Pool("far1", 180.0, 32.0, 1 << 38, parent=f"sw{depth-1}"),
            Pool("far2", 200.0, 32.0, 1 << 38, parent=f"sw{depth-1}"),
        ],
        switches=switches,
    )


def random_tree_topology(seed: int) -> Topology:
    """Random switch tree with pools hung at random levels."""
    rng = np.random.default_rng(seed)
    n_sw = int(rng.integers(1, 5))
    switches = []
    for i in range(n_sw):
        parent = None if i == 0 else f"sw{int(rng.integers(0, i))}"
        switches.append(
            Switch(
                f"sw{i}",
                latency_ns=float(rng.uniform(30, 90)),
                bandwidth_gbps=float(rng.uniform(16, 64)),
                stt_ns=float(rng.uniform(0.5, 6.0)),
                parent=parent,
            )
        )
    pools = [Pool("local", 88.9, 76.8, 1 << 36, is_local=True)]
    for p in range(int(rng.integers(1, 4))):
        parent = f"sw{int(rng.integers(0, n_sw))}" if rng.random() < 0.8 else None
        pools.append(
            Pool(
                f"pool{p}",
                latency_ns=float(rng.uniform(120, 260)),
                bandwidth_gbps=float(rng.uniform(16, 48)),
                capacity_bytes=1 << 38,
                parent=parent,
            )
        )
    return Topology(pools=pools, switches=switches)


# --------------------------------------------------------------------------- #
# oracle agreement (randomized topologies x bursty traces x impls)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("impl", ["inline", "pallas_interpret"])
def test_fused_matches_ref_on_random_topologies(seed, impl):
    flat = random_tree_topology(seed).flatten()
    burst = (0.0, 0.5, 0.9)[seed % 3]
    ev = synthetic_trace(1500 + 700 * seed, flat.n_pools, epoch_ns=3e5, seed=seed, burstiness=burst)
    ref = analyze_ref(flat, ev)
    got = EpochAnalyzer(flat, impl=impl).analyze(ev)
    assert got.latency_ns == pytest.approx(ref.latency_ns, rel=1e-4, abs=1e-3)
    assert got.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-3, abs=1e-2)
    assert got.bandwidth_ns == pytest.approx(ref.bandwidth_ns, rel=1e-2, abs=1.0)
    np.testing.assert_allclose(
        got.per_switch_congestion_ns, ref.per_switch_congestion_ns, rtol=2e-3, atol=0.1
    )


@pytest.mark.parametrize("burst", [0.0, 0.7, 0.95])
def test_fused_matches_ref_bursty_chain(burst):
    flat = chain_topology(3).flatten()
    ev = synthetic_trace(8000, flat.n_pools, epoch_ns=5e5, seed=7, burstiness=burst)
    ref = analyze_ref(flat, ev)
    got = EpochAnalyzer(flat).analyze(ev)
    assert got.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-3, abs=1e-2)


def test_fused_matches_legacy_path():
    """fused=True and the seed per-stage loop agree on the same trace."""
    ev = synthetic_trace(4000, FLAT.n_pools, epoch_ns=1e6, seed=11, burstiness=0.8)
    fused = EpochAnalyzer(FLAT).analyze(ev)
    legacy = EpochAnalyzer(FLAT, fused=False).analyze(ev)
    assert fused.congestion_ns == pytest.approx(legacy.congestion_ns, rel=1e-4)
    assert fused.latency_ns == pytest.approx(legacy.latency_ns, rel=1e-5)
    assert fused.bandwidth_ns == pytest.approx(legacy.bandwidth_ns, rel=1e-3, abs=1.0)


def test_ref_matches_fine_grained():
    """Oracle vs event-by-event DES (stt mode) — kept from test_analyzer."""
    ev = synthetic_trace(2000, FLAT.n_pools, epoch_ns=1e6, seed=1, burstiness=0.5)
    ref = analyze_ref(FLAT, ev)
    des = FineGrainedSimulator(FLAT, bandwidth_mode="stt").simulate(ev)
    assert ref.congestion_ns == pytest.approx(des.congestion_ns, rel=1e-6)


def test_unsorted_trace_is_sorted_by_stager():
    ev = synthetic_trace(3000, FLAT.n_pools, epoch_ns=1e6, seed=3, burstiness=0.8)
    perm = np.random.default_rng(0).permutation(ev.n)
    a = EpochAnalyzer(FLAT).analyze(ev)
    b = EpochAnalyzer(FLAT).analyze(ev.take(perm))
    assert b.congestion_ns == pytest.approx(a.congestion_ns, rel=1e-5)
    assert b.latency_ns == pytest.approx(a.latency_ns, rel=1e-6)


def test_empty_trace_and_bucketing():
    an = EpochAnalyzer(FLAT)
    assert an.analyze(MemEvents.empty()).total_ns == 0.0
    ev = synthetic_trace(100, FLAT.n_pools, epoch_ns=1e5, seed=0)
    a, b = an.analyze(ev), an.analyze(ev)  # second call: warm caches + buffers
    assert a.total_ns == pytest.approx(b.total_ns)


# --------------------------------------------------------------------------- #
# batching
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("impl", ["inline", "pallas_interpret"])
def test_analyze_batch_equals_sequential(impl):
    an = EpochAnalyzer(FLAT, impl=impl)
    traces = [
        synthetic_trace(n, FLAT.n_pools, epoch_ns=1e6, seed=i, burstiness=0.6)
        for i, n in enumerate((1200, 3000, 500, 2048, 1))
    ]
    seq = an.analyze(traces[0])
    for tr in traces[1:]:
        seq = seq + an.analyze(tr)
    bat = an.analyze_batch(traces)
    assert bat.latency_ns == pytest.approx(seq.latency_ns, rel=1e-5)
    assert bat.congestion_ns == pytest.approx(seq.congestion_ns, rel=1e-4)
    assert bat.bandwidth_ns == pytest.approx(seq.bandwidth_ns, rel=1e-3, abs=1.0)
    np.testing.assert_allclose(
        bat.per_pool_latency_ns, seq.per_pool_latency_ns, rtol=1e-4
    )


def test_analyze_batch_with_empty_members():
    an = EpochAnalyzer(FLAT)
    ev = synthetic_trace(600, FLAT.n_pools, epoch_ns=1e5, seed=2)
    bat = an.analyze_batch([MemEvents.empty(), ev, MemEvents.empty()])
    assert bat.total_ns == pytest.approx(an.analyze(ev).total_ns, rel=1e-5)
    assert an.analyze_batch([]).total_ns == 0.0


# --------------------------------------------------------------------------- #
# merge planning + kernel internals
# --------------------------------------------------------------------------- #


def test_plan_chain_needs_no_merges():
    _, plan, _ = plan_cascade(chain_topology(3).flatten())
    assert plan is not None and all(len(ops) == 0 for ops in plan)


def test_plan_figure1_needs_one_merge():
    _, plan, _ = plan_cascade(FLAT)
    assert plan is not None and sum(len(ops) for ops in plan) == 1


def test_cascade_kernel_matches_jnp_reference():
    rng = np.random.default_rng(5)
    n, s = 3000, 3
    ts = np.sort(rng.uniform(0, 1e5, n)).astype(np.float32)
    bits = rng.integers(0, 1 << s, n).astype(np.int32)
    stts = jnp.asarray([4.0, 2.0, 0.5], jnp.float32)
    tf_r, idx_r, psd_r = serial_queue_cascade(jnp.asarray(ts), jnp.asarray(bits), stts)
    tf_k, idx_k, psd_k = congestion_cascade(
        jnp.asarray(ts), jnp.asarray(bits), stts, block=1024, interpret=True
    )
    np.testing.assert_allclose(psd_k, psd_r, rtol=1e-5)
    np.testing.assert_allclose(tf_k, tf_r, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))


def test_merge_sorted_runs_within_mask():
    """Piecewise merge: only the `within` subsequence is permuted."""
    # both runs sorted along storage: changed [5, 9], unchanged-within [2, 3]
    x = jnp.asarray([1.0, 5.0, 2.0, 9.0, 3.0, 7.0], jnp.float32)
    changed = jnp.asarray([False, True, False, True, False, False])
    within = jnp.asarray([False, True, True, True, True, False])
    payload = jnp.arange(6, dtype=jnp.int32)
    xm, pm = merge_sorted_runs(x, changed, payload, within=within)
    # within-subsequence values {5,2,9,3} come back sorted over the within
    # positions {1,2,3,4}; positions 0 and 5 untouched
    np.testing.assert_allclose(np.asarray(xm), [1.0, 2.0, 3.0, 5.0, 9.0, 7.0])
    assert list(np.asarray(pm)) == [0, 2, 4, 1, 3, 5]


# --------------------------------------------------------------------------- #
# staging buffers
# --------------------------------------------------------------------------- #


def test_event_stager_reuses_buffers():
    st = EventStager()
    ev1 = synthetic_trace(100, 3, epoch_ns=1e4, seed=0)
    ev2 = synthetic_trace(90, 3, epoch_ns=1e4, seed=1)
    buf1 = st.stage([ev1], 1, 128)
    t1 = buf1["t"]
    buf2 = st.stage([ev2], 1, 128)
    assert buf2["t"] is t1  # same backing array, refilled in place
    assert not buf2["valid"][0, 90:].any()  # previous epoch's tail cleared
    np.testing.assert_allclose(buf2["t"][0, :90], np.sort(ev2.t_ns), rtol=1e-6)


def test_event_stager_sorts_unsorted_rows():
    t = np.array([5.0, 1.0, 3.0])
    ev = MemEvents.build(t, [1, 2, 1], [64, 64, 64])
    buf = EventStager().stage([ev], 1, 16)
    np.testing.assert_allclose(buf["t"][0, :3], [1.0, 3.0, 5.0])
    np.testing.assert_array_equal(buf["pool"][0, :3], [2, 1, 1])


# --------------------------------------------------------------------------- #
# async attach pipeline
# --------------------------------------------------------------------------- #


def _toy_attach(async_mode):
    from repro.core import CXLMemSim, ClassMapPolicy, RegionMap, two_tier_topology
    from repro.core.tracer import Access, Phase

    regions = RegionMap()
    regions.alloc("w", 1 << 22, "param")
    regions.alloc("opt", 1 << 23, "opt_state")
    phases = [
        Phase("fwd", flops=1e8, accesses=(Access("w", 1 << 22),)),
        Phase("opt", flops=1e7, accesses=(Access("opt", 1 << 23, True),)),
    ]
    step = jax.jit(lambda x: (x * x).sum())
    sim = CXLMemSim(
        two_tier_topology(),
        ClassMapPolicy({"opt_state": "cxl_pool"}),
        async_analysis=async_mode,
    )
    return sim.attach(step, phases, regions)


def test_async_attach_matches_sync():
    x = jnp.ones((64, 64))
    reports = {}
    for mode in (False, True):
        prog = _toy_attach(mode)
        prog.run(3, x)
        reports[mode] = prog.report
        prog.close()
    a, b = reports[False], reports[True]
    assert a.epochs == b.epochs == 3
    assert b.latency_s == pytest.approx(a.latency_s, rel=1e-6)
    assert b.congestion_s == pytest.approx(a.congestion_s, rel=1e-6)
    assert b.bandwidth_s == pytest.approx(a.bandwidth_s, rel=1e-5)
    assert b.analyzer_s > 0  # overhead accounting preserved under overlap


def test_report_read_flushes_async_work():
    prog = _toy_attach(True)
    x = jnp.ones((64, 64))
    for _ in range(4):
        prog.step(x)
    r = prog.report  # property flushes the pipeline
    assert r.steps == 4 and r.epochs == 4
    assert r.latency_s > 0
    prog.close()
