"""simdim end-to-end: the units and axes abstract interpreters on the
seeded-violation corpus, the runtime AxisSanitizer against transposed
dispatches (including a real ``[K, B, N]`` analyzer surface), and the
bitwise-neutrality guarantees of the annotation layer and the
``repro.core.units`` helpers.
"""
import inspect
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import registered_checkers, run_checks
from repro.analysis.annotations import (
    AxisContractError,
    axes,
    axes_validation,
    unit,
)
from repro.analysis.sanitize import AxisSanitizer
from repro.core import units as U

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "simlint"


def _check(*names, checkers=None, strict=False):
    return run_checks(
        [FIXTURES / n for n in names],
        root=FIXTURES,
        strict=strict,
        checker_names=checkers,
    )


def _rules(rep):
    out = {}
    for f in rep.findings:
        out[f.rule] = out.get(f.rule, 0) + 1
    return out


# --------------------------------------------------------------------------- #
# units checker: seeded corpus
# --------------------------------------------------------------------------- #


def test_units_checker_is_registered():
    assert "units" in registered_checkers()
    assert "axes" in registered_checkers()


def test_units_corpus_all_rules_fire():
    rep = _check("bad_units.py", checkers=["units"])
    assert _rules(rep) == {
        "unit-mismatch": 4,
        "unit-return": 1,
        "unit-raw-conversion": 1,
    }, [f.format() for f in rep.findings]


def test_units_cross_unit_add_names_both_units():
    rep = _check("bad_units.py", checkers=["units"])
    msgs = [f.message for f in rep.findings]
    assert "mixing ns with s" in msgs
    assert "comparison of ns against s" in msgs
    assert any("expects a ns input, got s" in m for m in msgs)


def test_units_clean_counterpart_has_no_findings():
    rep = _check("good_units.py", checkers=["units"])
    assert rep.ok, [f.format() for f in rep.findings]


def test_units_bandwidth_identity_needs_no_annotation():
    # good_units.py relies on GB/s == bytes/ns: wbytes / bw_gbps is already
    # nanoseconds and must NOT be flagged as a cross-unit operation.
    rep = _check("good_units.py", checkers=["units"])
    assert not any("gbps" in f.message for f in rep.findings)


# --------------------------------------------------------------------------- #
# axes checker: seeded corpus
# --------------------------------------------------------------------------- #


def test_axes_corpus_all_rules_fire():
    rep = _check("bad_axes.py", checkers=["axes"])
    assert _rules(rep) == {
        "axes-missing": 1,
        "axes-mismatch": 3,
        "axes-rank": 2,
    }, [f.format() for f in rep.findings]


def test_axes_missing_names_the_surface():
    rep = _check("bad_axes.py", checkers=["axes"])
    missing = [f for f in rep.findings if f.rule == "axes-missing"]
    assert len(missing) == 1
    assert "_analyze_multi_jax" in missing[0].message


def test_axes_transposed_dispatch_is_flagged():
    rep = _check("bad_axes.py", checkers=["axes"])
    mism = [f.message for f in rep.findings if f.rule == "axes-mismatch"]
    assert any("transposed" in m for m in mism), mism


def test_axes_clean_counterpart_has_no_findings():
    # Consistent renaming (G for K), transpose round-trips, vmap closures
    # and keepdims reductions must all stay quiet.
    rep = _check("good_axes.py", checkers=["axes"])
    assert rep.ok, [f.format() for f in rep.findings]


def test_axes_required_surfaces_all_annotated_in_repo():
    # The acceptance criterion: every listed jitted entry point carries a
    # contract, so the repo-wide axes pass emits no axes-missing.
    rep = run_checks(
        [REPO / "src" / "repro"], root=REPO, checker_names=["axes"],
    )
    assert not [f for f in rep.findings if f.rule == "axes-missing"], [
        f.format() for f in rep.findings
    ]


# --------------------------------------------------------------------------- #
# annotation layer: validation and transparency
# --------------------------------------------------------------------------- #


def test_unit_marker_is_identity():
    x = jnp.arange(4.0)
    assert unit("ns", x) is x
    with pytest.raises(ValueError):
        unit("", x)


def test_axes_decorator_rejects_bad_specs():
    with pytest.raises(ValueError):
        axes("K,B!,N")(lambda t: t)
    with pytest.raises(ValueError):
        axes(nosuch="K,N")(lambda t: t)
    with pytest.raises(ValueError):
        axes("K", "B", "N")(lambda t: t)  # more specs than params


def test_axes_wrapper_is_signature_transparent():
    @axes("K,B,N", stts="S")
    def f(t, stts, n_hosts=1):
        return t.sum() + stts.sum()

    assert f.__wrapped__ is not None
    assert list(inspect.signature(f).parameters) == ["t", "stts", "n_hosts"]
    assert f.__simlint_axes__["t"] == ("K", "B", "N")


def test_axes_wrapper_bitwise_identity():
    @axes("K,B,N", stts="S")
    def f(t, stts):
        return t * stts.sum() + jnp.float32(1.5)

    t = jnp.asarray(np.random.default_rng(0).random((2, 3, 4)), jnp.float32)
    stts = jnp.arange(5, dtype=jnp.float32)
    with AxisSanitizer():
        armed = f(t, stts)
    off = f(t, stts)
    raw = f.__wrapped__(t, stts)
    np.testing.assert_array_equal(np.asarray(armed), np.asarray(raw))
    np.testing.assert_array_equal(np.asarray(off), np.asarray(raw))


# --------------------------------------------------------------------------- #
# runtime AxisSanitizer
# --------------------------------------------------------------------------- #


@axes("K,B,N", bw="K,B", stts="S")
def _toy_dispatch(t, bw, stts, n_hosts=1):
    # rank-agnostic body: runs (wrongly) even on a transposed plane, so the
    # sanitizer is the only thing standing between the bug and a result
    return t.sum(axis=-1) + bw.sum() * 0 + stts.sum() * 0


def _toy_args(transpose_t=False):
    K, B, N, S = 2, 3, 4, 5
    t = jnp.ones((K, B, N), jnp.float32)
    if transpose_t:
        t = jnp.transpose(t, (1, 0, 2))  # [B, K, N]: the seeded violation
    return t, jnp.ones((K, B), jnp.float32), jnp.ones((S,), jnp.float32)


def test_sanitizer_passes_valid_shapes():
    with AxisSanitizer():
        out = _toy_dispatch(*_toy_args())
    assert out.shape == (2, 3)


@pytest.mark.no_sanitize  # asserts the wrapper is inert outside any scope
def test_sanitizer_detects_transposed_dispatch():
    t, bw, stts = _toy_args(transpose_t=True)
    with AxisSanitizer():
        with pytest.raises(AxisContractError, match="axis"):
            _toy_dispatch(t, bw, stts)
    # off-scope: the wrapper is a pure pass-through, no validation
    assert _toy_dispatch(t, bw, stts).shape == (3, 2)


def test_sanitizer_detects_transposition_at_jit_trace_time():
    jf = jax.jit(_toy_dispatch, static_argnames=("n_hosts",))
    t, bw, stts = _toy_args(transpose_t=True)
    with AxisSanitizer():
        with pytest.raises(AxisContractError):
            jf(t, bw, stts, n_hosts=2)


def test_sanitizer_record_only_collects_instead_of_raising():
    t, bw, stts = _toy_args(transpose_t=True)
    with AxisSanitizer(record_only=True) as san:
        out = _toy_dispatch(t, bw, stts)
    assert out.shape == (3, 2)
    # record mode keeps validating past the first failure: both the K and
    # the B binding of the transposed plane are reported
    assert len(san.violations) >= 1
    assert all("_toy_dispatch" in v for v in san.violations)


def test_sanitizer_innermost_scope_wins():
    t, bw, stts = _toy_args(transpose_t=True)
    with axes_validation():  # raising outer scope (the autouse harness)
        with AxisSanitizer(record_only=True) as san:
            _toy_dispatch(t, bw, stts)
        assert san.violations
        with pytest.raises(AxisContractError):
            _toy_dispatch(t, bw, stts)


def test_sanitizer_detects_transposed_real_analyzer_dispatch():
    """The acceptance scenario: a [K, B, N] plane fed as [B, K, N] into the
    real multi-session surface trips the contract before any compute."""
    from repro.core.analyzer import _analyze_multi_jax

    K, B, N, V, S, C = 2, 3, 4, 2, 2, 1
    f32 = jnp.float32
    i32 = jnp.int32
    plane = lambda dt: jnp.zeros((K, B, N), dt)  # noqa: E731
    kwargs = dict(
        t=jnp.transpose(jnp.ones((K, B, N), f32), (1, 0, 2)),  # [B, K, N]
        pool=plane(i32),
        nbytes=jnp.ones((K, B, N), f32),
        weight=jnp.ones((K, B, N), f32),
        host=plane(i32),
        qos=plane(i32),
        valid=jnp.ones((K, B, N), bool),
        bw_window_ns=jnp.full((K, B), 1e6, f32),
        lat_scale=jnp.ones((K, B, V), f32),
        bits_table=jnp.zeros((V,), i32),
        pool_latency_ns=jnp.ones((V,), f32),
        local_latency_ns=jnp.float32(100.0),
        route=jnp.zeros((V, S), f32),
        switch_stt_ns=jnp.ones((S,), f32),
        switch_bw=jnp.ones((S,), f32),
        disc_code=jnp.zeros((S,), i32),
        class_weights=jnp.ones((S, C), f32),
        stage_order=(0, 1),
        n_windows=1,
        n_hosts=1,
    )
    with AxisSanitizer(record_only=True) as san:
        try:
            _analyze_multi_jax(**kwargs)
        except Exception:
            pass  # downstream shape errors are expected; the record matters
    assert san.violations, "transposed [B,K,N] dispatch went undetected"
    assert "_analyze_multi_jax" in san.violations[0]


# --------------------------------------------------------------------------- #
# units helpers: bitwise neutrality of the centralization satellite
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("x", [0.0, 1.0, 137.25, 3.333e7, 1e-3])
def test_units_helpers_match_raw_literal_arithmetic(x):
    # Each helper keeps the exact arithmetic form of the literal it replaced,
    # so every converted call site is bitwise-identical to the seed.
    assert U.ns_to_s(x) == x * 1e-9
    assert U.s_to_ns(x) == x * 1e9
    assert U.s_to_ms(x) == x * 1e3
    assert U.ns_to_ms(x) == x / 1e6
    assert U.ms_to_ns(x) == x * 1e6
    assert U.ns_to_us(x) == x / 1e3
    assert U.us_to_ns(x) == x * 1e3
    assert U.bytes_to_mib(x) == x / 2**20
    assert U.mib_to_bytes(x) == x * 2**20
    assert U.bytes_to_gib(x) == x / 2**30
    assert U.gib_to_bytes(x) == x * 2**30


def test_units_constants_values():
    assert U.NS_PER_S == 1e9 and U.S_PER_NS == 1e-9
    assert U.NS_PER_MS == 1e6 and U.NS_PER_US == 1e3
    assert U.BYTES_PER_GIB == 2**30 and U.BYTES_PER_MIB == 2**20
    assert U.BYTES_PER_GB == 1e9 and U.MS_PER_S == 1e3
