"""End-to-end attach tests: the full CXLMemSim pipeline on a real jitted step."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    Access,
    CXLMemSim,
    ClassMapPolicy,
    CoherencyConfig,
    CoherencyModel,
    EpochSchedule,
    LocalOnlyPolicy,
    MigrationConfig,
    MigrationSimulator,
    Phase,
    RegionMap,
    local_only_topology,
    two_tier_topology,
)


def _toy():
    regions = RegionMap()
    regions.alloc("w", 1 << 24, "param")
    regions.alloc("opt", 1 << 25, "opt_state")
    regions.alloc("act", 1 << 20, "activation")
    phases = [
        Phase("fwd", flops=5e8, accesses=(Access("w", 1 << 24), Access("act", 1 << 20, True))),
        Phase("opt", flops=1e7, accesses=(Access("opt", 1 << 25), Access("opt", 1 << 25, True))),
    ]
    step = jax.jit(lambda x: (x @ x.T).sum())
    x = jnp.ones((128, 128))
    return regions, phases, step, x


def test_local_only_topology_zero_delay():
    regions, phases, step, x = _toy()
    sim = CXLMemSim(local_only_topology(), LocalOnlyPolicy())
    prog = sim.attach(step, phases, regions)
    rep = prog.run(3, x)
    assert rep.latency_s == 0 and rep.congestion_s == 0 and rep.bandwidth_s == 0
    assert rep.slowdown == pytest.approx(1.0)


def test_offload_policy_creates_delay_and_slowdown():
    regions, phases, step, x = _toy()
    sim = CXLMemSim(two_tier_topology(), ClassMapPolicy({"opt_state": "cxl_pool"}))
    prog = sim.attach(step, phases, regions)
    rep = prog.run(3, x)
    assert rep.simulated_s > rep.native_s
    assert rep.slowdown > 1.0
    assert rep.latency_s > 0 or rep.bandwidth_s > 0


def test_delay_injection_slows_host():
    regions, phases, step, x = _toy()
    sim = CXLMemSim(
        two_tier_topology(), ClassMapPolicy({"opt_state": "cxl_pool", "param": "cxl_pool"}),
        inject_delays=True,
    )
    prog = sim.attach(step, phases, regions)
    rep = prog.run(2, x)
    assert rep.injected_sleep_s > 0


def test_epoch_modes_agree_on_totals():
    """'step' vs 'layer' epochs: latency totals identical (same events)."""
    regions, phases, step, x = _toy()
    reps = {}
    for mode in ("step", "layer"):
        sim = CXLMemSim(
            two_tier_topology(), ClassMapPolicy({"opt_state": "cxl_pool"}),
            epoch=EpochSchedule(mode),
        )
        prog = sim.attach(step, phases, regions)
        reps[mode] = prog.run(1, x)
    assert reps["step"].latency_s == pytest.approx(reps["layer"].latency_s, rel=1e-6)


def test_fine_grained_analyzer_mode():
    regions, phases, step, x = _toy()
    sim = CXLMemSim(
        two_tier_topology(), ClassMapPolicy({"opt_state": "cxl_pool"}), analyzer="fine"
    )
    prog = sim.attach(step, phases, regions)
    rep = prog.run(1, x)
    assert rep.simulated_s > rep.native_s


def test_epoch_vs_fine_agreement():
    """Epoch batching vs event-by-event DES: identical latency accounting;
    both charge the saturated link.  (Bandwidth models differ by design —
    windowed stretch vs per-transaction serialization — the accuracy
    benchmark quantifies that gap on fine-granularity traces.)"""
    regions, phases, step, x = _toy()
    reps = {}
    for analyzer in ("epoch", "fine"):
        sim = CXLMemSim(
            two_tier_topology(), ClassMapPolicy({"opt_state": "cxl_pool"}),
            analyzer=analyzer,
        )
        prog = sim.attach(step, phases, regions)
        reps[analyzer] = prog.run(1, x)
    assert reps["epoch"].latency_s == pytest.approx(reps["fine"].latency_s, rel=1e-6)
    for r in reps.values():
        assert r.simulated_s > r.native_s


def test_sampling_mode_close_to_exact():
    regions, phases, step, x = _toy()
    rep = {}
    for rate in (1.0, 0.25):
        sim = CXLMemSim(
            two_tier_topology(), ClassMapPolicy({"opt_state": "cxl_pool"}),
            sample_rate=rate,
        )
        prog = sim.attach(step, phases, regions)
        rep[rate] = prog.run(1, x).latency_s
    assert rep[0.25] == pytest.approx(rep[1.0], rel=0.3)


def test_attach_with_migration_and_coherency():
    regions, phases, step, x = _toy()
    topo = two_tier_topology()
    mig = MigrationSimulator(
        MigrationConfig(mode="software", promote_threshold=1, local_budget_bytes=1 << 30),
        regions,
        topo.flatten(),
    )
    coh = CoherencyModel(CoherencyConfig(n_hosts=2, shared_classes=("param",)), regions)
    sim = CXLMemSim(
        topo, ClassMapPolicy({"param": "cxl_pool"}), migration=mig, coherency=coh,
        check_capacity=False,
    )
    prog = sim.attach(step, phases, regions)
    rep = prog.run(2, x)
    assert rep.steps == 2
    # hot param region should have been promoted by the migration daemon
    assert mig.promotions >= 1
