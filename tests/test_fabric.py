"""Shared-fabric multi-host simulation: event merging, per-(host, pool)
routing, host-segmented analysis, and the FabricSession end-to-end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Access,
    ClassMapPolicy,
    CoherencyConfig,
    CXLMemSim,
    EpochAnalyzer,
    FabricSession,
    FineGrainedSimulator,
    MemEvents,
    Phase,
    RegionMap,
    Tenant,
    analyze_ref,
    figure1_topology,
    merge_host_traces,
    pooled_topology,
    split_by_host,
    synthetic_trace,
    two_tier_topology,
)
from repro.core.events import EventStager
from repro.core.topology import Topology


# --------------------------------------------------------------------------- #
# events: host tagging, merge/split
# --------------------------------------------------------------------------- #


def test_events_default_host_zero():
    ev = synthetic_trace(100, 2, seed=0)
    assert (ev.host == 0).all()
    assert ev.take(np.arange(10)).host.shape == (10,)


def test_merge_split_round_trip():
    a = synthetic_trace(200, 2, epoch_ns=1e5, seed=0)
    b = synthetic_trace(150, 2, epoch_ns=1e5, seed=1)
    merged = merge_host_traces([a, b])
    assert merged.n == 350
    assert (np.diff(merged.t_ns) >= 0).all()  # time-sorted
    pa, pb = split_by_host(merged, 2)
    assert pa.n == 200 and pb.n == 150
    np.testing.assert_allclose(np.sort(pa.t_ns), np.sort(a.t_ns))
    assert pa.total_bytes == pytest.approx(a.total_bytes)
    assert (pa.host == 0).all() and (pb.host == 1).all()


def test_stager_stages_host_column():
    a = synthetic_trace(20, 2, seed=0).with_host(1)
    buf = EventStager().stage([a], 1, 32)
    assert (buf["host"][0, :20] == 1).all()
    assert (buf["host"][0, 20:] == 0).all()


# --------------------------------------------------------------------------- #
# topology: multi-host lowering
# --------------------------------------------------------------------------- #


def test_single_host_lowering_unchanged():
    """n_hosts=1 keeps the historical shapes and names exactly."""
    flat = figure1_topology().flatten()
    assert flat.n_hosts == 1
    assert flat.route.shape == (4, 3)
    assert flat.switch_names[-1] == "RC"
    assert flat.n_vpools == flat.n_pools


def test_multi_host_lowering_shares_switches_privately_rcs():
    flat = pooled_topology(n_hosts=2).flatten()
    P = flat.n_pools
    assert flat.n_hosts == 2
    assert flat.switch_names == ("fabric_sw", "RC0", "RC1")
    assert flat.route.shape == (2 * P, 3)
    # both hosts' expander rows traverse the shared switch...
    assert flat.route[flat.vp_index(0, 1), 0] == 1
    assert flat.route[flat.vp_index(1, 1), 0] == 1
    # ...but only their own RC
    assert flat.route[flat.vp_index(0, 1), 1] == 1
    assert flat.route[flat.vp_index(0, 1), 2] == 0
    assert flat.route[flat.vp_index(1, 1), 2] == 1
    assert flat.route[flat.vp_index(1, 1), 1] == 0
    # local DRAM rows route nowhere for every host
    assert flat.route[flat.vp_index(0, 0)].sum() == 0
    assert flat.route[flat.vp_index(1, 0)].sum() == 0


def test_analyzers_reject_unreachable_traffic():
    """Events targeting a pool the host's ports exclude have no fabric
    route; analyzing them silently would charge latency with zero switch
    traversal, so every analyzer path refuses."""
    flat = pooled_topology(n_hosts=2, host_ports={1: ()}).flatten()
    bad = merge_host_traces(
        [synthetic_trace(50, 2, seed=0), synthetic_trace(50, 2, seed=1)]
    )
    with pytest.raises(ValueError, match="cannot reach"):
        analyze_ref(flat, bad)
    with pytest.raises(ValueError, match="cannot reach"):
        EpochAnalyzer(flat).analyze(bad)
    with pytest.raises(ValueError, match="cannot reach"):
        FineGrainedSimulator(flat).simulate(bad)
    # host 0's traffic alone (and host 1's local-only traffic) is fine
    ok = merge_host_traces([synthetic_trace(50, 2, seed=0), synthetic_trace(50, 1, seed=1)])
    analyze_ref(flat, ok)


def test_host_ports_restrict_reachability():
    topo = pooled_topology(n_hosts=2, host_ports={1: ()})
    flat = topo.flatten()
    assert flat.host_reachable[0, 1] and not flat.host_reachable[1, 1]
    assert flat.route[flat.vp_index(1, 1)].sum() == 0
    with pytest.raises(ValueError):
        pooled_topology(n_hosts=2, host_ports={1: ("no_such_port",)})
    with pytest.raises(ValueError):
        pooled_topology(n_hosts=2, host_ports={5: ("fabric_sw",)})


# --------------------------------------------------------------------------- #
# analyzer: single-host path unchanged (acceptance)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("topo_fn", [figure1_topology, two_tier_topology])
def test_fused_matches_oracle_single_host(topo_fn):
    """n_hosts=1 fused output must match analyze_ref to existing tolerances."""
    flat = topo_fn().flatten()
    ev = synthetic_trace(2000, flat.n_pools, epoch_ns=1e6, seed=3, burstiness=0.7)
    ref = analyze_ref(flat, ev)
    got = EpochAnalyzer(flat).analyze(ev)
    assert got.latency_ns == pytest.approx(ref.latency_ns, rel=1e-4)
    assert got.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-3)
    # the single-element host decomposition is the total
    assert got.per_host_latency_ns.shape == (1,)
    assert got.per_host_latency_ns[0] == pytest.approx(got.latency_ns, rel=1e-6)
    assert got.per_host_congestion_ns[0] == pytest.approx(got.congestion_ns, rel=1e-6)


# --------------------------------------------------------------------------- #
# analyzer: shared fabric semantics (acceptance)
# --------------------------------------------------------------------------- #


def _saturating_traces(n=400, epoch_ns=1e5, nbytes=2.5e4):
    """Two co-scheduled bursty tenants hammering pool 1 hard enough to
    saturate a 1 GB/s link even privately."""
    out = []
    for seed in (0, 1):
        rng = np.random.default_rng(seed)
        t = np.sort(rng.uniform(0, epoch_ns, n))
        out.append(
            MemEvents.build(t, [1] * n, [nbytes] * n)
        )
    return out


def test_shared_fabric_strictly_more_contended_than_private():
    """Two hosts on one shared expander: strictly more congestion+bandwidth
    than the same two traces on private copies of the topology; per-host
    breakdowns sum to the fabric totals."""
    tr0, tr1 = _saturating_traces()
    shared_flat = pooled_topology(n_hosts=2, cxl_bandwidth_gbps=1.0).flatten()
    priv_flat = pooled_topology(n_hosts=1, cxl_bandwidth_gbps=1.0).flatten()

    fabric = analyze_ref(shared_flat, merge_host_traces([tr0, tr1]))
    priv = analyze_ref(priv_flat, tr0) + analyze_ref(priv_flat, tr1)

    assert fabric.congestion_ns > priv.congestion_ns
    assert fabric.bandwidth_ns > priv.bandwidth_ns
    # latency delay is contention-free: identical on shared and private
    assert fabric.latency_ns == pytest.approx(priv.latency_ns, rel=1e-9)
    # decomposition closes
    assert fabric.per_host_congestion_ns.sum() == pytest.approx(
        fabric.congestion_ns, rel=1e-9
    )
    assert fabric.per_host_bandwidth_ns.sum() == pytest.approx(
        fabric.bandwidth_ns, rel=1e-9
    )
    assert fabric.per_host_latency_ns.sum() == pytest.approx(
        fabric.latency_ns, rel=1e-9
    )


@pytest.mark.parametrize("impl", ["inline", "pallas_interpret"])
def test_fused_fabric_matches_oracle(impl):
    """Fused device paths reproduce the multi-host oracle, host segments
    included."""
    topo = figure1_topology()
    topo3 = Topology(
        topo.pools, topo.switches, topo.rc_latency_ns, topo.rc_bandwidth_gbps,
        topo.rc_stt_ns, topo.local_dram_latency_ns, n_hosts=3,
    )
    flat = topo3.flatten()
    merged = merge_host_traces(
        [
            synthetic_trace(1200, flat.n_pools, epoch_ns=2e5, seed=i, burstiness=0.8)
            for i in range(3)
        ]
    )
    ref = analyze_ref(flat, merged)
    got = EpochAnalyzer(flat, impl=impl).analyze(merged)
    assert got.latency_ns == pytest.approx(ref.latency_ns, rel=1e-4)
    assert got.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-3)
    np.testing.assert_allclose(
        got.per_host_congestion_ns, ref.per_host_congestion_ns, rtol=5e-3
    )
    np.testing.assert_allclose(
        got.per_host_latency_ns, ref.per_host_latency_ns, rtol=1e-4
    )


def test_fine_grained_matches_oracle_on_fabric():
    """Event-by-event DES agrees with the epoch oracle on a shared fabric
    (stt service mode), per-host segments included."""
    flat = pooled_topology(n_hosts=2).flatten()
    merged = merge_host_traces(
        [
            synthetic_trace(1500, flat.n_pools, epoch_ns=2e5, seed=i, burstiness=0.8)
            for i in range(2)
        ]
    )
    ref = analyze_ref(flat, merged)
    des = FineGrainedSimulator(flat, bandwidth_mode="stt").simulate(merged)
    assert des.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-6)
    np.testing.assert_allclose(
        des.per_host_congestion_ns, ref.per_host_congestion_ns, rtol=1e-6
    )


def test_analyzers_reject_out_of_range_hosts():
    """A merged trace with more hosts than the topology declares must fail
    loudly — the jitted gather would otherwise clamp the host id and route
    the traffic through the wrong (host, pool) row."""
    flat = pooled_topology(n_hosts=2).flatten()
    bad = merge_host_traces(
        [synthetic_trace(30, 2, seed=i) for i in range(3)]  # hosts 0..2
    )
    with pytest.raises(ValueError, match="host id 2"):
        analyze_ref(flat, bad)
    with pytest.raises(ValueError, match="host id 2"):
        EpochAnalyzer(flat).analyze(bad)
    with pytest.raises(ValueError, match="host id 2"):
        FineGrainedSimulator(flat).simulate(bad)


def test_fabric_session_rejects_single_tenant_coherency():
    """One tenant has no sharers to derive coherency from; a silently-zero
    BI report would masquerade as a coherency-free result."""
    with pytest.raises(ValueError, match="single-tenant"):
        FabricSession(
            pooled_topology(n_hosts=1),
            [_tenant("solo", step=False)],
            coherency=CoherencyConfig(shared_classes=("kvcache",)),
        )


def test_fabric_session_rejects_host_count_mismatch():
    """Only single-host topologies are auto-lifted to the tenant count; an
    explicit multi-host declaration that disagrees is a config error."""
    with pytest.raises(ValueError, match="4 hosts but 2 tenants"):
        FabricSession(
            pooled_topology(n_hosts=4),
            [_tenant("a", step=False), _tenant("b", step=False)],
        )


def test_wide_fabric_falls_back_to_unfused():
    """>31 cascade stages (switches + per-host RCs) exceed the 31-bit route
    word; EpochAnalyzer must degrade to the unfused path, not crash — the
    rack-scale pooling scenario stays simulable."""
    H = 31  # 1 shared switch + 31 RCs = 32 stages
    flat = pooled_topology(n_hosts=H).flatten()
    an = EpochAnalyzer(flat)
    assert not an.fused
    merged = merge_host_traces(
        [synthetic_trace(40, flat.n_pools, epoch_ns=1e5, seed=i) for i in range(H)]
    )
    ref = analyze_ref(flat, merged)
    got = an.analyze(merged)
    assert got.latency_ns == pytest.approx(ref.latency_ns, rel=1e-4)
    assert got.congestion_ns == pytest.approx(ref.congestion_ns, rel=1e-3, abs=1e-3)
    assert got.per_host_latency_ns.shape == (H,)


def test_rc_contention_stays_private():
    """Traffic from host 0 must not queue behind host 1 at the RC: two
    hosts' identical streams see exactly the per-host RC delay, not a
    merged queue."""
    t = np.zeros((8,))  # 8 simultaneous events, all to pool 1
    one = MemEvents.build(t, [1] * 8, [64] * 8)
    flat2 = pooled_topology(n_hosts=2, switch_stt_ns=0.0).flatten()
    flat1 = pooled_topology(n_hosts=1, switch_stt_ns=0.0).flatten()
    fabric = analyze_ref(flat2, merge_host_traces([one, one]))
    priv = analyze_ref(flat1, one)
    # with the shared switch's stt silenced, only the RC queues remain —
    # and they are private, so fabric == 2x private exactly
    assert fabric.congestion_ns == pytest.approx(2 * priv.congestion_ns, rel=1e-9)


# --------------------------------------------------------------------------- #
# FabricSession end-to-end
# --------------------------------------------------------------------------- #


def _tenant(name, traffic_mult=1, step=True):
    rm = RegionMap()
    rm.alloc("w", 1 << 22, "param")
    rm.alloc("kv", 1 << 22, "kvcache")
    rm.alloc("act", 1 << 20, "activation")
    phases = [
        Phase(
            "fwd",
            flops=5e8,
            accesses=(
                Access("w", traffic_mult * (1 << 22)),
                Access("kv", traffic_mult * (1 << 22), True),
                Access("act", 1 << 20, True),
            ),
        ),
    ]
    step_fn = jax.jit(lambda x: (x @ x.T).sum()) if step else None
    args = (jnp.ones((64, 64)),) if step else ()
    return Tenant(
        name, phases, rm, ClassMapPolicy({"kvcache": "shared_pool"}),
        step_fn=step_fn, step_args=args,
    )


def test_fabric_session_two_tenants():
    sess = FabricSession(
        pooled_topology(n_hosts=2, cxl_bandwidth_gbps=8.0),
        [_tenant("a"), _tenant("b", traffic_mult=4)],
        coherency=CoherencyConfig(shared_classes=("kvcache",)),
    )
    rep = sess.run(2)
    assert rep.rounds == 2 and rep.epochs == 2
    assert all(hc.steps == 2 for hc in rep.hosts)
    assert all(hc.simulated_s >= hc.native_s for hc in rep.hosts)
    # per-host decomposition closes against the fabric totals
    assert sum(hc.latency_s for hc in rep.hosts) == pytest.approx(
        rep.latency_s, rel=1e-5
    )
    assert sum(hc.congestion_s for hc in rep.hosts) == pytest.approx(
        rep.congestion_s, rel=1e-4, abs=1e-12
    )
    assert sum(hc.bandwidth_s for hc in rep.hosts) == pytest.approx(
        rep.bandwidth_s, rel=1e-4, abs=1e-12
    )
    # writes to the shared kv region produced BI fan-out
    assert rep.bi_messages > 0


def test_fabric_session_single_tenant_matches_attach():
    """One tenant on the fabric == the plain CXLMemSim attach pipeline."""
    topo = two_tier_topology()
    rm1 = RegionMap()
    rm1.alloc("w", 1 << 22, "param")
    rm1.alloc("opt", 1 << 23, "opt_state")
    phases = [
        Phase("fwd", flops=5e8, accesses=(Access("w", 1 << 22), Access("opt", 1 << 23, True))),
    ]
    step = jax.jit(lambda x: (x * 2).sum())
    x = jnp.ones((32,))

    sess = FabricSession(
        topo,
        [Tenant("solo", phases, rm1, ClassMapPolicy({"opt_state": "cxl_pool"}),
                step_fn=step, step_args=(x,))],
    )
    sess.run(1)

    rm2 = RegionMap()
    rm2.alloc("w", 1 << 22, "param")
    rm2.alloc("opt", 1 << 23, "opt_state")
    sim = CXLMemSim(two_tier_topology(), ClassMapPolicy({"opt_state": "cxl_pool"}))
    prog = sim.attach(step, phases, rm2)
    rep = prog.run(1, x)

    assert sess.report.latency_s == pytest.approx(rep.latency_s, rel=1e-6)
    assert sess.report.congestion_s == pytest.approx(rep.congestion_s, rel=1e-5, abs=1e-12)
    assert sess.report.bandwidth_s == pytest.approx(rep.bandwidth_s, rel=1e-5, abs=1e-12)


def test_fabric_session_rejects_unreachable_placement():
    topo = pooled_topology(n_hosts=2, host_ports={1: ()})  # host 1 sees nothing
    with pytest.raises(ValueError, match="cannot reach"):
        FabricSession(topo, [_tenant("a", step=False), _tenant("b", step=False)])


def test_fabric_session_oversubscription_check():
    topo = pooled_topology(n_hosts=2, cxl_capacity_gib=0.005)  # ~5 MiB shared
    with pytest.raises(ValueError, match="oversubscribed"):
        FabricSession(topo, [_tenant("a", step=False), _tenant("b", step=False)])


def test_fabric_capacity_counts_coherent_shared_object_once():
    """With coherency declared, name-matched shared-class regions are ONE
    pooled object (the shared-kv-cache scenario): two 4 MiB 'kv' copies on
    a ~5 MiB pool must fit — the same name-matching rule the coherency
    model uses to derive sharers."""
    topo = pooled_topology(n_hosts=2, cxl_capacity_gib=0.005)
    FabricSession(
        topo,
        [_tenant("a", step=False), _tenant("b", step=False)],
        coherency=CoherencyConfig(shared_classes=("kvcache",)),
    )  # must not raise: both tenants' 'kv' is one shared object


def test_fabric_session_noisy_neighbor_hurts_victim():
    """Co-attaching a noisy neighbor must inflict contention delay on a
    victim that runs clean alone — the pooling scenario the refactor
    exists for.  Both tenants are compute-paced to the same epoch span, so
    their event streams genuinely overlap on the shared link."""

    def tenants(with_noisy):
        out = []
        for name, kv_bytes in [("victim", 1 << 18)] + (
            [("noisy", 1 << 25)] if with_noisy else []
        ):
            rm = RegionMap()
            rm.alloc("kv", max(kv_bytes, 1 << 22), "kvcache")
            phases = [
                Phase("fwd", flops=5e10, accesses=(Access("kv", kv_bytes, True),))
            ]
            out.append(
                Tenant(name, phases, rm, ClassMapPolicy({"kvcache": "shared_pool"}))
            )
        return out

    def victim_contention(with_noisy):
        sess = FabricSession(
            pooled_topology(n_hosts=2 if with_noisy else 1, cxl_bandwidth_gbps=4.0),
            tenants(with_noisy),
        )
        sess.run(1)
        hc = sess.report.hosts[0]
        return hc.congestion_s + hc.bandwidth_s

    alone = victim_contention(False)
    contended = victim_contention(True)
    assert contended > alone
    assert alone == pytest.approx(0.0, abs=1e-12)  # victim is clean by itself
