"""Vectorized QoS arbitration cascades vs the DES oracle -> BENCH_qos.json.

Two headline measurements (ISSUE 9):

  * **kernel vs oracle** — the data-driven QoS cascade
    (:func:`repro.kernels.ref.qos_cascade_dyn`, one lowering for every
    discipline/weight mix) against the event-by-event
    :class:`repro.core.FineGrainedSimulator` decision oracle on an N=64k
    depth-3 switch chain, for both strict-priority and weighted-fair
    arbitration.  Per-event final times must agree to <=1e-5 relative on a
    tie-free trace (unique integer timestamps: f32-exact, so the closed-form
    scans and the DES walk the same schedule), and the vectorized cascade
    must be >=20x faster steady-state.  All-FIFO weights must degenerate
    *bitwise* to the plain ``serial_queue_cascade``.
  * **K=256 QoS sweep** — discipline x weight :class:`QosSpec` grid riding
    :meth:`repro.core.ScenarioSuite.run`'s stacked ``[K, B, N]`` dispatch.
    Disciplines and weights are runtime data, so the whole grid must run as
    ONE counted dispatch with ZERO steady-state recompiles.

``--quick`` (CI smoke) shrinks N and K; the 20x speedup gate only applies
to the full run (the parity / bitwise / one-dispatch gates always hold).
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ClassMapPolicy,
    FineGrainedSimulator,
    MemEvents,
    QosSpec,
    RegionMap,
    Scenario,
    ScenarioSuite,
    figure1_topology,
)
from repro.core.analyzer import plan_cascade
from repro.core.topology import Pool, Switch, Topology
from repro.core.tracer import Access, Phase
from repro.kernels.ref import (
    qos_cascade_dyn,
    qos_serial_queue_cascade,
    serial_queue_cascade,
)

SPEEDUP_GATE = 20.0
PARITY_GATE = 1e-5
FULL_N = 1 << 16
FULL_K = 256
N_CLASSES = 3
WFQ_WEIGHTS = (4.0, 2.0, 1.0)


def qos_chain(disciplines: Tuple[str, ...]) -> Topology:
    """Depth-3 switch chain with per-switch QoS disciplines (the benchmark
    topology from the QoS cascade tests)."""
    switches = [
        Switch(
            f"sw{d}", 70.0, 64.0 - 8.0 * d, 2.0 + d,
            parent=f"sw{d-1}" if d else None,
            discipline=disc,
            class_weights=WFQ_WEIGHTS if disc == "wfq" else None,
        )
        for d, disc in enumerate(disciplines)
    ]
    return Topology(
        pools=[
            Pool("local", 88.9, 76.8, 1 << 36, is_local=True),
            Pool("far1", 180.0, 32.0, 1 << 38, parent=f"sw{len(switches)-1}"),
            Pool("far2", 200.0, 32.0, 1 << 38, parent=f"sw{len(switches)-1}"),
        ],
        switches=switches,
        n_qos_classes=N_CLASSES,
        # the paper's depth-3 measurement counts the three switch hops; a
        # zero-service root-complex stage keeps the DES and the kernel on
        # the same schedule without adding a fourth arbitration point
        rc_stt_ns=0.0,
    )


def tie_free_trace(n: int, n_pools: int, seed: int = 0) -> MemEvents:
    """Unique integer timestamps: f32-exact and tie-free, so the device
    cascade and the DES oracle agree to float tolerance per event."""
    rng = np.random.default_rng(seed)
    t = np.sort(rng.choice(np.arange(1, 1 << 20), size=n, replace=False))
    return MemEvents.build(
        t_ns=t.astype(np.float64),
        # all-routed: every event targets a far pool and traverses the full
        # depth-3 chain — the arbitration-bound regime the gate measures
        pool=rng.integers(1, n_pools, n),
        bytes_=np.full(n, 64.0),
        qos=rng.integers(0, N_CLASSES, n),
    )


def cascade_inputs(flat, ev: MemEvents):
    """Kernel inputs in the planner's stage order (the RC is a stage too)."""
    bits_pool, _merge_plan, stage_order = plan_cascade(flat)
    order = list(stage_order)
    vpool = ev.host.astype(np.int64) * flat.n_pools + ev.pool.astype(np.int64)
    return (
        jnp.asarray(ev.t_ns, jnp.float32),
        jnp.asarray(bits_pool[vpool]),
        jnp.asarray(flat.switch_stt_ns[order], jnp.float32),
        jnp.asarray(ev.qos),
        jnp.asarray(np.asarray(flat.discipline_codes())[order]),
        jnp.asarray(flat.class_weight_table()[order], jnp.float32),
    )


def bench_kernel_vs_des(disciplines: Tuple[str, ...], n: int, repeats: int):
    """Steady-state vectorized cascade time, DES oracle time, parity."""
    flat = qos_chain(disciplines).flatten()
    ev = tie_free_trace(n, flat.n_pools, seed=7)
    t, bits, stts, qos, disc, w = cascade_inputs(flat, ev)
    fn = jax.jit(qos_cascade_dyn)
    tf, idx, psd = fn(t, bits, stts, qos, disc, w)  # warm (compile)
    jax.block_until_ready((tf, idx, psd))

    t_vec = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        tf, idx, psd = fn(t, bits, stts, qos, disc, w)
        jax.block_until_ready((tf, idx, psd))
        t_vec.append(time.perf_counter() - t0)

    des = FineGrainedSimulator(flat, bandwidth_mode="stt")
    t0 = time.perf_counter()
    oracle = des.final_times(ev, presorted=True)
    t_des = time.perf_counter() - t0

    out = np.empty(ev.n, np.float64)
    out[np.asarray(idx)] = np.asarray(tf, np.float64)
    rel = np.abs(out - oracle) / np.maximum(np.abs(oracle), 1.0)
    return {
        "disciplines": list(disciplines),
        "vectorized_s": min(t_vec),
        "des_s": t_des,
        "speedup": t_des / min(t_vec),
        "max_rel_err_vs_des": float(rel.max()),
    }


def fifo_bitwise_degeneracy(n: int = 8192) -> bool:
    """All-FIFO weights must reproduce serial_queue_cascade bit-for-bit."""
    rng = np.random.default_rng(3)
    s = 3
    ts = jnp.asarray(np.sort(rng.uniform(0, 1e5, n)).astype(np.float32))
    bits = jnp.asarray(rng.integers(0, 1 << s, n).astype(np.int32))
    stts = jnp.asarray([4.0, 2.0, 0.5], jnp.float32)
    qos = jnp.asarray(rng.integers(0, N_CLASSES, n), jnp.int32)
    w = jnp.ones((s, N_CLASSES), jnp.float32)
    tf_f, idx_f, _ = serial_queue_cascade(ts, bits, stts)
    tf_q, idx_q, _ = qos_serial_queue_cascade(
        ts, bits, stts, qos, w, ("fifo",) * s
    )
    return bool(
        np.array_equal(np.asarray(tf_q), np.asarray(tf_f))
        and np.array_equal(np.asarray(idx_q), np.asarray(idx_f))
    )


def qos_spec_grid(k: int) -> List[Optional[QosSpec]]:
    """K distinct discipline x weight points (plus a FIFO baseline)."""
    specs: List[Optional[QosSpec]] = [None]
    i = 0
    while len(specs) < k:
        d = ("priority", "wfq")[i % 2]
        w = (float(1 + (i % 8)), float(1 + ((i // 8) % 8)), 1.0)
        specs.append(QosSpec(discipline=d, class_weights=w))
        i += 1
    return specs[:k]


def sweep_workload():
    rng = np.random.default_rng(0)
    rm = RegionMap()
    for i in range(12):
        r = rm.alloc(f"r{i}", 1 << 20, ("param", "opt_state", "kvcache")[i % 3])
        r.access_count = 10.0
    phases = [
        Phase(f"ph{p}", 1e12, tuple(
            Access(f"r{int(j)}", float(rng.integers(1e5, 6e5)), False)
            for j in rng.choice(12, size=4, replace=False)
        ))
        for p in range(4)
    ]
    return rm, phases


def bench_qos_sweep(k: int, repeats: int):
    """K QoS scenarios through the stacked sweep: one dispatch, no recompiles."""
    rm, phases = sweep_workload()
    suite = ScenarioSuite(
        figure1_topology(), rm, phases,
        region_qos={f"r{i}": i % N_CLASSES for i in range(12)},
    )
    pol = ClassMapPolicy({"opt_state": "cxl_pool2", "kvcache": "cxl_pool1"})
    scens = [
        Scenario(policy=pol, name=f"q{i}", qos=sp)
        for i, sp in enumerate(qos_spec_grid(k))
    ]
    suite.run(scens)  # warm: compile the (single) stacked graph
    d0, c0 = suite.dispatch_count, suite.compile_cache_size()
    t_run = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = suite.run(scens)
        t_run.append(time.perf_counter() - t0)
    dispatches = suite.dispatch_count - d0
    compiles = suite.compile_cache_size() - c0
    conserved = max(
        abs(float(np.sum(b.per_class_congestion_ns)) - b.congestion_ns)
        / max(abs(b.congestion_ns), 1.0)
        for b in res.breakdowns
    )
    return {
        "k": len(scens),
        "unique_cascades": suite.last_unique_cascades,
        "qos_classes": res.qos_classes,
        "run_s": min(t_run),
        "dispatches_during_timed_runs": dispatches,
        "compiles_during_timed_runs": compiles,
        "one_dispatch_per_run": bool(dispatches == repeats and compiles == 0),
        "max_class_conservation_err": conserved,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=FULL_N)
    ap.add_argument("--k", type=int, default=FULL_K)
    ap.add_argument("--quick", action="store_true", help="CI smoke: N=4096, K=32")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--out", default="BENCH_qos.json")
    args = ap.parse_args(argv)
    n = 4096 if args.quick else args.n
    k = 32 if args.quick else args.k
    full = n >= FULL_N

    prio = bench_kernel_vs_des(("priority",) * 3, n, args.repeats)
    wfq = bench_kernel_vs_des(("wfq",) * 3, n, args.repeats)
    bitwise = fifo_bitwise_degeneracy()
    sweep = bench_qos_sweep(k, args.repeats)

    gates = {
        "fifo_degenerates_bitwise": bitwise,
        "per_event_parity_le_1e-5": bool(
            max(prio["max_rel_err_vs_des"], wfq["max_rel_err_vs_des"])
            <= PARITY_GATE
        ),
        "priority_speedup_ge_20x_at_n64k": (
            bool(prio["speedup"] >= SPEEDUP_GATE) if full else None
        ),
        "wfq_speedup_ge_20x_at_n64k": (
            bool(wfq["speedup"] >= SPEEDUP_GATE) if full else None
        ),
        "one_dispatch_zero_recompiles": sweep["one_dispatch_per_run"],
        "per_class_attribution_conserves_total": bool(
            sweep["max_class_conservation_err"] <= 1e-5
        ),
    }
    ok = all(v for v in gates.values() if v is not None)

    record = {
        "bench": "qos_arbitration",
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "n_events": n,
        "cascade_depth": 3,
        "priority": prio,
        "wfq": wfq,
        "sweep": sweep,
        "gates": gates,
        "pass": bool(ok),
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(json.dumps(record, indent=1))
    if not ok:
        print("ACCEPTANCE GATE FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
