"""Shared-engine attach throughput -> BENCH_attach.json perf/fidelity record.

Two measurements of the ISSUE-5 engine:

  1. **cross-session batching** (gated): N trace-only ``FabricSession``s on
     equal topologies, driven round-robin.  Baseline analyzes each round
     synchronously on the critical path (one private dispatch per session
     per round — the pre-engine behavior); the shared path submits every
     round to ONE :class:`~repro.core.engine.AnalysisEngine`, whose
     dispatcher coalesces concurrently-pending sessions into stacked
     ``[K, B, N]`` dispatches.  Gate (full mode): >= 1.5x aggregate
     round throughput at N=4, with every session's fabric totals matching
     its synchronous twin within float32 tolerance.

  2. **native overlap** (recorded): one real jitted step attached via
     ``CXLMemSim`` async vs sync — the analyzer hides behind the step's
     own execution, so async wall time approaches max(native, analyzer)
     instead of their sum.

Run: ``PYTHONPATH=src python -m benchmarks.attach_overlap [--quick]``.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import (
    Access,
    AnalysisEngine,
    CXLMemSim,
    ClassMapPolicy,
    FabricSession,
    Phase,
    RegionMap,
    Tenant,
    pooled_topology,
    two_tier_topology,
)

SPEEDUP_GATE = 1.5
TOTALS_RTOL = 1e-5  # float32 accumulation tolerance vs the sync path


def _tenant(i: int) -> Tenant:
    rm = RegionMap()
    rm.alloc("w", 1 << 22, "param")
    rm.alloc("kv", 1 << 22, "kvcache")
    rm.alloc("act", 1 << 20, "activation")
    phases = [
        Phase(
            "fwd",
            flops=5e8,
            accesses=(
                Access("w", 1 << 22),
                Access("kv", 1 << 22, True),
                Access("act", 1 << 20, True),
            ),
        )
    ]
    return Tenant(f"s{i}", phases, rm, ClassMapPolicy({"kvcache": "shared_pool"}))


def _sessions(n: int, engine=None, async_analysis=True) -> List[FabricSession]:
    return [
        FabricSession(
            pooled_topology(n_hosts=1, cxl_bandwidth_gbps=8.0),
            [_tenant(i)],
            async_analysis=async_analysis,
            engine=engine,
        )
        for i in range(n)
    ]


def _drive(sessions: List[FabricSession], rounds: int) -> float:
    t0 = time.perf_counter()
    for _ in range(rounds):
        for s in sessions:
            s.round()
    for s in sessions:
        s.flush()
    return time.perf_counter() - t0


def bench_cross_session(n_sessions: int, rounds: int, warmup: int) -> Dict:
    # throwaway warm-up sessions compile the solo [B, N] and stacked
    # [K, B, N] shapes (jit compile caches are process-global, so the
    # fresh timed sessions below stay warm) — the timed sessions and the
    # timed engine stats then cover exactly the measured window
    _drive(_sessions(n_sessions, async_analysis=False), warmup)
    with AnalysisEngine() as weng:
        warm = _sessions(n_sessions, engine=weng)
        _drive(warm, warmup)
        for s in warm:
            s.close()

    # -- private synchronous pipelines (the pre-engine critical path) ------- #
    sync = _sessions(n_sessions, async_analysis=False)
    sync_s = _drive(sync, rounds)

    # -- one shared engine, overlapped + coalesced -------------------------- #
    eng = AnalysisEngine()
    shared = _sessions(n_sessions, engine=eng)
    shared_s = _drive(shared, rounds)
    stats = eng.stats()

    # -- fidelity: each shared session's totals vs its synchronous twin ----- #
    max_rel = 0.0
    for s_sync, s_shared in zip(sync, shared):
        a, b = s_sync.report, s_shared.report
        for f in ("latency_s", "congestion_s", "bandwidth_s"):
            va, vb = getattr(a, f), getattr(b, f)
            denom = max(abs(va), 1e-12)
            max_rel = max(max_rel, abs(va - vb) / denom)
    for s in shared:
        s.close()
    eng.close()

    speedup = sync_s / shared_s if shared_s > 0 else float("nan")
    return {
        "sweep": "cross_session_batching",
        "sessions": n_sessions,
        "rounds": rounds,
        "sync_s": sync_s,
        "shared_s": shared_s,
        "speedup": speedup,
        "rounds_per_s_sync": n_sessions * rounds / sync_s,
        "rounds_per_s_shared": n_sessions * rounds / shared_s,
        "coalesced_dispatches": stats["coalesced_dispatches"],
        "max_coalesced_sessions": stats["max_coalesced_sessions"],
        "max_rel_err_vs_sync": max_rel,
    }


def bench_native_overlap(steps: int) -> Dict:
    """One real jitted step: async attach hides analyzer work behind it."""
    regions = RegionMap()
    regions.alloc("w", 1 << 24, "param")
    regions.alloc("opt", 1 << 25, "opt_state")
    phases = [
        Phase("fwd", flops=5e9, accesses=(Access("w", 1 << 24),)),
        Phase("opt", flops=1e8, accesses=(Access("opt", 1 << 25, True),)),
    ]
    step = jax.jit(lambda x: (x @ x.T).sum())
    x = jnp.ones((1024, 1024))

    walls = {}
    reports = {}
    for mode in (False, True):
        sim = CXLMemSim(
            two_tier_topology(),
            ClassMapPolicy({"opt_state": "cxl_pool"}),
            async_analysis=mode,
        )
        with sim.attach(step, phases, regions) as prog:
            prog.run(3, x)  # warm both the step and the analyzer shapes
            t0 = time.perf_counter()
            prog.run(steps, x)
            walls[mode] = time.perf_counter() - t0
            reports[mode] = prog.report
    return {
        "sweep": "native_overlap",
        "steps": steps,
        "sync_wall_s": walls[False],
        "async_wall_s": walls[True],
        "overlap_gain": walls[False] / walls[True] if walls[True] > 0 else float("nan"),
        "analyzer_s_async": reports[True].analyzer_s,
        "native_s_async": reports[True].native_s,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_attach.json")
    ap.add_argument("--quick", action="store_true", help="small run (CI smoke)")
    ap.add_argument("--sessions", type=int, default=4)
    args = ap.parse_args(argv)
    with open(args.out, "a"):  # fail on an unwritable path up front
        pass

    if args.quick:
        rows = [bench_cross_session(args.sessions, rounds=60, warmup=10)]
        rows.append(bench_native_overlap(steps=5))
    else:
        rows = [bench_cross_session(args.sessions, rounds=400, warmup=40)]
        rows.append(bench_native_overlap(steps=20))

    xs = rows[0]
    print(
        f"# cross-session: {xs['sessions']} sessions x {xs['rounds']} rounds — "
        f"sync {xs['sync_s']:.3f}s, shared {xs['shared_s']:.3f}s, "
        f"speedup {xs['speedup']:.2f}x "
        f"(coalesced dispatches {xs['coalesced_dispatches']}, "
        f"max group {xs['max_coalesced_sessions']}, "
        f"rel err {xs['max_rel_err_vs_sync']:.2e})"
    )
    ov = rows[1]
    print(
        f"# native overlap: sync {ov['sync_wall_s']:.3f}s vs async "
        f"{ov['async_wall_s']:.3f}s ({ov['overlap_gain']:.2f}x; analyzer "
        f"{ov['analyzer_s_async']:.3f}s off the critical path, native "
        f"{ov['native_s_async']:.3f}s; recorded, not gated — on a "
        f"CPU-only host both halves compete for the same cores)"
    )

    totals_ok = xs["max_rel_err_vs_sync"] <= TOTALS_RTOL
    coalesced_ok = xs["coalesced_dispatches"] > 0
    gates = {
        "totals_match_sync_fp32": bool(totals_ok),
        "cross_session_coalescing_observed": bool(coalesced_ok),
        # the 1.5x wall-clock gate applies to the full run only: the quick
        # (CI smoke) round counts are too short for stable timing
        "speedup_ge_1p5x_at_n4": (
            bool(xs["speedup"] >= SPEEDUP_GATE)
            if not args.quick and xs["sessions"] >= 4
            else None
        ),
    }
    ok = all(v for v in gates.values() if v is not None)
    record = {
        "bench": "attach_overlap",
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "rows": rows,
        "gates": gates,
        "pass": bool(ok),
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# acceptance: {gates} -> {'PASS' if ok else 'FAIL'}")
    print(f"# wrote {args.out}")
    if not ok:
        print("ACCEPTANCE GATE FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
