"""Analyzer throughput: events/second for each implementation.

The paper's speed claim (73× faster than Gem5) comes from replacing
event-by-event simulation with epoch batching.  This benchmark measures
simulation throughput (trace events per second of simulator time) for:

  * fine-grained DES (the Gem5 stand-in),
  * numpy epoch analyzer (ref),
  * JAX epoch analyzer (jitted, inline congestion math),
  * JAX epoch analyzer + Pallas congestion kernel (interpret mode on CPU).
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core.analyzer import EpochAnalyzer, FineGrainedSimulator, analyze_ref
from repro.core.events import synthetic_trace
from repro.core.topology import figure1_topology

FLAT = figure1_topology().flatten()


def _time(fn, ev, reps=3) -> float:
    fn(ev)  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(ev)
    return (time.perf_counter() - t0) / reps


def run(sizes=(1_000, 10_000, 100_000)) -> List[Dict]:
    rows = []
    jax_an = EpochAnalyzer(FLAT)
    pallas_an = EpochAnalyzer(FLAT, impl="pallas_interpret")
    des = FineGrainedSimulator(FLAT, bandwidth_mode="per_txn")
    for n in sizes:
        ev = synthetic_trace(n, FLAT.n_pools, epoch_ns=1e6, seed=n, burstiness=0.5)
        impls = {
            "fine_grained_des": lambda e: des.simulate(e),
            "epoch_numpy": lambda e: analyze_ref(FLAT, e),
            "epoch_jax": lambda e: jax_an.analyze(e),
        }
        if n <= 10_000:  # interpret-mode kernel is slow on CPU; keep it bounded
            impls["epoch_jax_pallas"] = lambda e: pallas_an.analyze(e)
        for name, fn in impls.items():
            dt = _time(fn, ev, reps=2 if n >= 100_000 else 3)
            rows.append(
                {"impl": name, "events": n, "s_per_epoch": dt, "events_per_s": n / dt}
            )
    return rows


def main():
    rows = run()
    print("impl,events,s_per_epoch,events_per_s")
    for r in rows:
        print(f"{r['impl']},{r['events']},{r['s_per_epoch']:.5f},{r['events_per_s']:.0f}")
    # headline: epoch vs DES at largest common size
    des = {r["events"]: r for r in rows if r["impl"] == "fine_grained_des"}
    jaxr = {r["events"]: r for r in rows if r["impl"] == "epoch_jax"}
    common = max(set(des) & set(jaxr))
    print(
        f"# epoch_jax vs fine-grained speedup at {common} events: "
        f"{des[common]['s_per_epoch'] / jaxr[common]['s_per_epoch']:.1f}x "
        "(paper: 73x vs Gem5)"
    )
    return rows


if __name__ == "__main__":
    main()
