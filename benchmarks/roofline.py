"""Roofline table from the dry-run JSON (§Roofline deliverable).

Prints the full per-cell table (three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs useful ratio, roofline fraction) and emits the
markdown table EXPERIMENTS.md embeds.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

DEFAULT = os.path.join(os.path.dirname(__file__), "dryrun_results.json")


def load(path: str = DEFAULT) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def rows(path: str = DEFAULT, mesh: Optional[str] = "1pod_16x16") -> List[Dict]:
    out = []
    for r in load(path):
        if "error" in r or (mesh and r.get("mesh") != mesh):
            continue
        rl = r["roofline"]
        out.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "mesh": r["mesh"],
                "strategy": r.get("strategy", "?"),
                "compute_s": rl["compute_s"],
                "memory_s": rl["memory_s"],
                "collective_s": rl["collective_s"],
                "dominant": rl["dominant"],
                "useful_ratio": rl["useful_flops_ratio"],
                "roofline_fraction": rl["roofline_fraction"],
            }
        )
    return out


def markdown(path: str = DEFAULT, mesh: str = "1pod_16x16") -> str:
    rs = rows(path, mesh)
    lines = [
        f"| arch | shape | strategy | compute (s) | memory (s) | collective (s) "
        f"| dominant | useful FLOPs | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rs:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy']} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def main():
    if not os.path.exists(DEFAULT):
        print("# no dryrun_results.json yet — run repro.launch.dryrun first")
        return []
    rs = rows()
    print("arch,shape,strategy,compute_s,memory_s,collective_s,dominant,useful_ratio,roofline_fraction")
    for r in rs:
        print(
            f"{r['arch']},{r['shape']},{r['strategy']},{r['compute_s']:.5f},"
            f"{r['memory_s']:.5f},{r['collective_s']:.5f},{r['dominant']},"
            f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f}"
        )
    return rs


if __name__ == "__main__":
    main()
