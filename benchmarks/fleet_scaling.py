"""Device-sharded fleet dispatch vs single-device -> BENCH_fleet.json.

A 100+-host fleet frontier sweep (F offload fractions x R racks = K >= 256
stacked rack planes) evaluated three ways:

  * **sharded** — :meth:`repro.core.FleetSim.frontier` with an 8-virtual-
    device ``('data',)`` mesh: ONE ``[K, B, N]`` dispatch whose rack axis
    is sharded across devices, per-shard on-device reduction, one ``[K]``
    host transfer.
  * **stacked (1 device)** — the same single stacked dispatch, unsharded:
    isolates what sharding adds over stacking.
  * **sequential per-rack** — the pre-fleet pattern: one
    ``EpochAnalyzer.analyze_batch`` dispatch per rack per fraction (K host
    round-trips), the way K independent sessions would price their racks.

All paths are warmed before timing (compile excluded).  Virtual devices
share this machine's physical cores, so the sharded win is real scheduling
and cache-locality headroom, not extra silicon; the record includes the
physical core count so readers can calibrate.

The capacity-planning output — the paper's stranding question at rack
scale — is the frontier curve: stranded GB recovered (bytes the hosts no
longer provision because they moved to the racks' shared expanders) vs
p99 tenant slowdown, at each offload fraction.

Acceptance gate (ISSUE 6):
  * sharded >= 3x sequential per-rack wall-clock at K >= 256 on a
    100+-host fleet,
  * sharded totals within 1e-6 relative of the single-device stacked
    dispatch on every plane,
  * the frontier curve is reported at >= 100 hosts.

``--quick`` (CI smoke) shrinks the fleet; the throughput gate applies only
at full scale (parity and curve gates always hold).
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")
_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import numpy as np

SPEEDUP_GATE = 3.0
PARITY_GATE = 1e-6
FULL_RACKS = 32
HOSTS_PER_RACK = 4
FULL_FRACTIONS = 8
MIN_HOSTS = 100
MIN_K = 256


def build_fleet(n_racks: int, mesh=None):
    from repro.core.fleet import FleetSim

    # 64 KiB granules with 8-event statistical trains per access: the
    # weight field preserves total bytes, so stranding/slowdown totals
    # match finer trains while each rack plane stays dispatch-bound —
    # the regime a fleet sweep actually runs in
    return FleetSim(
        n_racks=n_racks,
        hosts_per_rack=HOSTS_PER_RACK,
        granularity_bytes=65536.0,
        max_events_per_access=8,
        mesh=mesh,
    )


def build_tenants(n_hosts: int):
    from repro.core.fleet import synthetic_tenant

    # ~1.5 tenants per host keeps every host busy without overflowing DRAM
    return [
        synthetic_tenant(f"t{i}", seed=i, gib=10.0)
        for i in range(int(n_hosts * 1.5))
    ]


def sequential_eval(fleet, per_frac):
    """One per-rack dispatch at a time: K host round-trips."""
    from repro.core.analyzer import EpochAnalyzer

    an = EpochAnalyzer(
        fleet.flat,
        bw_window_ns=fleet.bw_window_ns,
        n_windows=fleet.n_windows,
        dtype=fleet.dtype,
    )
    out = []
    for traces, _ in per_frac:
        for rack_rows in traces:
            out.append(an.analyze_batch(rack_rows))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--racks", type=int, default=FULL_RACKS)
    ap.add_argument("--fractions", type=int, default=FULL_FRACTIONS)
    ap.add_argument("--quick", action="store_true", help="CI smoke: 4 racks x 2 fractions")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)
    R = 4 if args.quick else args.racks
    F = 2 if args.quick else args.fractions

    import jax

    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh()
    n_dev = jax.device_count()
    fracs = tuple(np.linspace(0.0, 1.0, F))
    n_hosts = R * HOSTS_PER_RACK
    tenants = build_tenants(n_hosts)
    K = F * R

    fleet_1dev = build_fleet(R)
    fleet_mesh = build_fleet(R, mesh=mesh)

    # the placement/synthesis half is shared by every path; stage it once so
    # the timed region measures dispatch, as the frontier itself does
    per_frac = []
    for f in fracs:
        placements = fleet_1dev.place(tenants, "least_loaded", float(f))
        per_frac.append(fleet_1dev._rack_timelines(placements))
    all_traces = [rows for traces, _ in per_frac for rows in traces]

    # warm every path (compile out of the timed region)
    fleet_1dev._dispatch(all_traces, tiles=F, mesh=None)
    fleet_mesh._dispatch(all_traces, tiles=F, mesh=mesh)
    sequential_eval(fleet_1dev, per_frac[:1])

    def timed(fn, repeats):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    t_shard, bd_shard = timed(
        lambda: fleet_mesh._dispatch(all_traces, tiles=F, mesh=mesh), args.repeats
    )
    t_stack, bd_stack = timed(
        lambda: fleet_1dev._dispatch(all_traces, tiles=F, mesh=None), args.repeats
    )
    t_seq, bd_seq = timed(
        lambda: sequential_eval(fleet_1dev, per_frac), max(args.repeats // 2, 1)
    )

    # plane-for-plane parity: sharded vs 1-device stacked, and vs sequential
    def worst_rel(a_list, b_list):
        worst = 0.0
        for a, b in zip(a_list, b_list):
            for f in ("latency_ns", "congestion_ns", "bandwidth_ns"):
                x, y = getattr(a, f), getattr(b, f)
                worst = max(worst, abs(x - y) / max(abs(y), 1.0))
        return worst

    parity_shard = worst_rel(bd_shard, bd_stack)
    parity_seq = worst_rel(bd_shard, bd_seq)

    # the capacity-planning curve itself (full frontier path, end to end)
    points = fleet_mesh.frontier(tenants, offload_fractions=fracs)
    stats = fleet_mesh.last_dispatch
    curve = [
        {
            "offload_fraction": p.offload_fraction,
            "stranded_recovered_gb": p.stranded_recovered_gb,
            "p99_slowdown": p.p99_slowdown,
            "mean_slowdown": p.mean_slowdown,
        }
        for p in points
    ]

    speedup_vs_seq = t_seq / t_shard
    speedup_vs_stack = t_stack / t_shard
    full_scale = K >= MIN_K and n_hosts >= MIN_HOSTS
    gates = {
        "sharded_parity_le_1e-6": bool(parity_shard <= PARITY_GATE),
        "curve_at_100plus_hosts": bool(n_hosts >= MIN_HOSTS) if not args.quick else None,
        "throughput_ge_3x_at_8dev": (
            bool(speedup_vs_seq >= SPEEDUP_GATE) if full_scale else None
        ),
    }
    ok = all(v for v in gates.values() if v is not None)

    record = {
        "bench": "fleet_scaling",
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "physical_cores": os.cpu_count(),
        "jax_devices": n_dev,
        "racks": R,
        "hosts_per_rack": HOSTS_PER_RACK,
        "n_hosts": n_hosts,
        "n_tenants": len(tenants),
        "offload_fractions": F,
        "k_planes": K,
        "dispatch_stats": {
            "devices_used": stats.devices_used,
            "shard_rows": stats.shard_rows,
            "rows": stats.rows,
            "padded_fraction": stats.padded_fraction,
        },
        "sharded_s": t_shard,
        "stacked_1dev_s": t_stack,
        "sequential_per_rack_s": t_seq,
        "speedup_sharded_vs_sequential": speedup_vs_seq,
        "speedup_sharded_vs_stacked_1dev": speedup_vs_stack,
        "max_rel_err_sharded_vs_stacked": parity_shard,
        "max_rel_err_sharded_vs_sequential": parity_seq,
        "frontier": curve,
        "gates": gates,
        "pass": bool(ok),
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(json.dumps(record, indent=1))
    if not ok:
        print("ACCEPTANCE GATE FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
