# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  table1          paper Table 1: native vs CXLMemSim vs fine-grained baseline
  accuracy        epoch analyzer vs event-by-event DES agreement
  throughput      analyzer implementations: events/second (speed claim)
  topology_sweep  Figure-1 topology × placement-policy delay decomposition
  roofline        §Roofline table from the multi-pod dry-run JSON
  fabric          shared-fabric contention: hosts × bandwidth + noisy neighbor
  migration       vectorized migration scaling + device-cache capacity sweep

Run everything:      PYTHONPATH=src python -m benchmarks.run
Run one:             PYTHONPATH=src python -m benchmarks.run table1
"""

import sys
import time


def main() -> None:
    from benchmarks import (
        accuracy, fabric_contention, migration_scaling, roofline, table1,
        throughput, topology_sweep,
    )

    suites = {
        "table1": table1.main,
        "accuracy": accuracy.main,
        "throughput": throughput.main,
        "topology_sweep": topology_sweep.main,
        "roofline": roofline.main,
        "fabric": lambda: fabric_contention.main(["--quick"]),
        "migration": lambda: migration_scaling.main(["--quick"]),
    }
    wanted = sys.argv[1:] or list(suites)
    for name in wanted:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        suites[name]()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
