"""Accuracy benchmark: epoch analyzer vs fine-grained DES.

The paper's design bet is that epoch-batched analysis matches event-by-event
simulation closely enough at a fraction of the cost.  We quantify it: for
cacheline-granularity traces across burstiness levels and topologies, compare
total simulated delay (latency + congestion + bandwidth) between the epoch
analyzer and the per-transaction DES oracle.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.analyzer import FineGrainedSimulator, analyze_ref
from repro.core.events import synthetic_trace
from repro.core.topology import figure1_topology, two_tier_topology


def run() -> List[Dict]:
    rows = []
    for topo_name, topo in (("figure1", figure1_topology()), ("two_tier", two_tier_topology())):
        flat = topo.flatten()
        for burst in (0.0, 0.5, 0.9):
            for n in (2000, 20000):
                ev = synthetic_trace(
                    n, flat.n_pools, epoch_ns=2e5, seed=n + int(burst * 10),
                    burstiness=burst,
                )
                epoch = analyze_ref(flat, ev)
                des = FineGrainedSimulator(flat, bandwidth_mode="per_txn").simulate(ev)
                e_tot, d_tot = epoch.total_ns, des.total_ns
                rows.append(
                    {
                        "topology": topo_name,
                        "burstiness": burst,
                        "events": n,
                        "epoch_total_ns": e_tot,
                        "des_total_ns": d_tot,
                        "rel_err": abs(e_tot - d_tot) / max(d_tot, 1e-9),
                        "latency_exact": abs(epoch.latency_ns - des.latency_ns) < 1e-6 * max(des.latency_ns, 1),
                    }
                )
    return rows


def main():
    rows = run()
    print("topology,burstiness,events,epoch_total_ns,des_total_ns,rel_err,latency_exact")
    for r in rows:
        print(
            f"{r['topology']},{r['burstiness']},{r['events']},"
            f"{r['epoch_total_ns']:.0f},{r['des_total_ns']:.0f},"
            f"{r['rel_err']:.4f},{r['latency_exact']}"
        )
    errs = [r["rel_err"] for r in rows]
    print(f"# median rel err {np.median(errs):.4f}, max {max(errs):.4f}")
    return rows


if __name__ == "__main__":
    main()
