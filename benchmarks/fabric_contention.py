"""Shared-fabric contention sweep -> BENCH_fabric.json perf/fidelity record.

Sweeps co-attached host count x shared-pool bandwidth on the pooling
topology and records, per cell:

  * ``fabric_*``        — congestion/bandwidth/latency of the merged
                          shared-timeline analysis (the new multi-host mode),
  * ``private_*``       — the same per-host traces analyzed on private
                          copies of the topology and summed (what the seed
                          repo could express: no cross-host contention),
  * ``amplification``   — fabric / private contention (> 1 iff sharing the
                          fabric actually costs something; the whole point
                          of the pooling scenario),
  * ``host_sum_err``    — closure error of the per-host decomposition
                          against the fabric totals (must be ~0),
  * ``s_per_epoch``     — wall time of the fused analyzer on the merged
                          timeline.

A second sweep holds the fabric fixed (2 hosts) and skews the tenants
(noisy-neighbor): the victim's contention delay is recorded as a function
of the neighbor's traffic multiplier.

Acceptance gate (ISSUE 2): at every >= 2-host cell, fabric congestion +
bandwidth strictly exceeds the private baseline, and per-host breakdowns
sum to the fabric totals within 1e-4 relative (the fused analyzer
accumulates in float32, so totals and host segments differ by reduction
order; the float64 oracle closes to ~1e-9).
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List

import numpy as np

from repro.core.analyzer import EpochAnalyzer
from repro.core.events import MemEvents, merge_host_traces, synthetic_trace
from repro.core.topology import pooled_topology

EPOCH_NS = 2e5


def _host_trace(n: int, seed: int, nbytes: float = 4096.0) -> MemEvents:
    """Bursty traffic split between local DRAM and the shared pool."""
    return synthetic_trace(
        n, 2, epoch_ns=EPOCH_NS, granule_bytes=nbytes,
        pool_probs=(0.3, 0.7), seed=seed, burstiness=0.7,
    )


def _time(fn, reps: int) -> float:
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def sweep_hosts_bandwidth(
    hosts=(1, 2, 4, 8), bandwidths=(4.0, 8.0, 16.0, 32.0), n_events=8192, reps=3
) -> List[Dict]:
    rows: List[Dict] = []
    for bw in bandwidths:
        priv_flat = pooled_topology(n_hosts=1, cxl_bandwidth_gbps=bw).flatten()
        priv_an = EpochAnalyzer(priv_flat)
        for H in hosts:
            flat = pooled_topology(n_hosts=H, cxl_bandwidth_gbps=bw).flatten()
            an = EpochAnalyzer(flat)
            traces = [_host_trace(n_events, seed=i) for i in range(H)]
            merged = merge_host_traces(traces)
            fabric = an.analyze(merged)
            private = [priv_an.analyze(tr) for tr in traces]
            priv_cong = sum(p.congestion_ns for p in private)
            priv_bw = sum(p.bandwidth_ns for p in private)
            fab_cont = fabric.congestion_ns + fabric.bandwidth_ns
            priv_cont = priv_cong + priv_bw
            host_sums = np.array(
                [
                    abs(fabric.per_host_congestion_ns.sum() - fabric.congestion_ns),
                    abs(fabric.per_host_bandwidth_ns.sum() - fabric.bandwidth_ns),
                    abs(fabric.per_host_latency_ns.sum() - fabric.latency_ns),
                ]
            )
            denom = max(fabric.total_ns, 1.0)
            s_per_epoch = _time(lambda: an.analyze(merged), reps)
            rows.append(
                {
                    "sweep": "hosts_x_bandwidth",
                    "hosts": H,
                    "shared_bw_gbps": bw,
                    "events_per_host": n_events,
                    "fabric_congestion_ns": fabric.congestion_ns,
                    "fabric_bandwidth_ns": fabric.bandwidth_ns,
                    "fabric_latency_ns": fabric.latency_ns,
                    "private_congestion_ns": priv_cong,
                    "private_bandwidth_ns": priv_bw,
                    "amplification": fab_cont / priv_cont if priv_cont > 0 else float("nan"),
                    "per_host_congestion_ns": fabric.per_host_congestion_ns.tolist(),
                    "per_host_bandwidth_ns": fabric.per_host_bandwidth_ns.tolist(),
                    "host_sum_err": float(host_sums.max() / denom),
                    "s_per_epoch": s_per_epoch,
                    "events_per_s": H * n_events / s_per_epoch,
                }
            )
    return rows


def sweep_noisy_neighbor(mults=(1, 2, 4, 8, 16), n_events=8192, bw=8.0) -> List[Dict]:
    """Two hosts; host 1's byte volume scales, host 0 (victim) is fixed."""
    rows: List[Dict] = []
    flat = pooled_topology(n_hosts=2, cxl_bandwidth_gbps=bw).flatten()
    an = EpochAnalyzer(flat)
    victim = _host_trace(n_events, seed=0)
    alone = an.analyze(merge_host_traces([victim]))
    for m in mults:
        noisy = _host_trace(n_events, seed=1, nbytes=4096.0 * m)
        bd = an.analyze(merge_host_traces([victim, noisy]))
        victim_cont = float(bd.per_host_congestion_ns[0] + bd.per_host_bandwidth_ns[0])
        rows.append(
            {
                "sweep": "noisy_neighbor",
                "neighbor_mult": m,
                "shared_bw_gbps": bw,
                "victim_contention_ns": victim_cont,
                "victim_alone_contention_ns": float(
                    alone.congestion_ns + alone.bandwidth_ns
                ),
                "victim_inflicted_ns": victim_cont
                - float(alone.congestion_ns + alone.bandwidth_ns),
                "noisy_contention_ns": float(
                    bd.per_host_congestion_ns[1] + bd.per_host_bandwidth_ns[1]
                ),
            }
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_fabric.json")
    ap.add_argument("--quick", action="store_true", help="small sweep (CI smoke)")
    args = ap.parse_args(argv)
    # fail on an unwritable record path before the sweep, not after
    with open(args.out, "a"):
        pass
    if args.quick:
        rows = sweep_hosts_bandwidth(hosts=(1, 2), bandwidths=(8.0,), n_events=2048, reps=1)
        rows += sweep_noisy_neighbor(mults=(1, 8), n_events=2048)
    else:
        rows = sweep_hosts_bandwidth()
        rows += sweep_noisy_neighbor()

    print(f"{'hosts':>5} {'bw':>5} {'fab cong+bw (ns)':>17} {'priv (ns)':>12} "
          f"{'amp':>6} {'sum_err':>9} {'ms/epoch':>9}")
    for r in rows:
        if r["sweep"] != "hosts_x_bandwidth":
            continue
        fab = r["fabric_congestion_ns"] + r["fabric_bandwidth_ns"]
        priv = r["private_congestion_ns"] + r["private_bandwidth_ns"]
        print(
            f"{r['hosts']:>5} {r['shared_bw_gbps']:>5.0f} {fab:>17.3e} {priv:>12.3e} "
            f"{r['amplification']:>6.2f} {r['host_sum_err']:>9.1e} "
            f"{r['s_per_epoch'] * 1e3:>9.2f}"
        )
    for r in rows:
        if r["sweep"] != "noisy_neighbor":
            continue
        print(
            f"# noisy x{r['neighbor_mult']:<3}: victim contention "
            f"{r['victim_contention_ns']:.3e} ns "
            f"(alone {r['victim_alone_contention_ns']:.3e}, inflicted "
            f"{r['victim_inflicted_ns']:.3e})"
        )

    multi = [r for r in rows if r["sweep"] == "hosts_x_bandwidth" and r["hosts"] >= 2]
    ok_contention = all(
        r["fabric_congestion_ns"] + r["fabric_bandwidth_ns"]
        > r["private_congestion_ns"] + r["private_bandwidth_ns"]
        for r in multi
    )
    ok_closure = all(r["host_sum_err"] <= 1e-4 for r in multi)
    record = {
        "bench": "fabric_contention",
        "platform": platform.platform(),
        "rows": rows,
        "acceptance": {
            "shared_exceeds_private_everywhere": bool(ok_contention),
            "per_host_sums_close": bool(ok_closure),
            "pass": bool(ok_contention and ok_closure),
        },
    }
    print(
        f"# acceptance: shared>private {ok_contention}, closure {ok_closure} -> "
        f"{'PASS' if record['acceptance']['pass'] else 'FAIL'}"
    )
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
