"""Vectorized-migration scaling + device-cache sweep -> BENCH_migration.json.

Two sweeps:

  1. **Migration scaling** — one epoch of ``observe_and_migrate`` over R
     regions, vectorized engine vs the per-region Python loop baseline
     (``impl='loop'``), R up to 1e5.  Decisions are asserted identical per
     epoch (the loop is the oracle), and the recorded ``parity`` block
     re-runs the ``tests/test_policy_migration.py`` scenarios under both
     engines.
  2. **Device cache** — hit fraction and simulated delay across a capacity
     sweep on a reuse-heavy trace; capacity 0 must reproduce the no-cache
     analysis exactly and every nonzero capacity must land strictly below
     the no-cache latency.

Acceptance gate (ISSUE 3): vectorized >= 10x at R = 1e5 with decision
parity, cache capacity-0 exactness, and strictly-lower latency at every
nonzero capacity cell.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import Dict, List

import numpy as np

from repro.core import (
    CACHELINE_BYTES,
    DeviceCacheConfig,
    DeviceCacheModel,
    EpochAnalyzer,
    MemEvents,
    MigrationConfig,
    MigrationSimulator,
    RegionMap,
    figure1_topology,
)
from repro.core.units import bytes_to_mib

FLAT = figure1_topology().flatten()
PAGE = 4096


def _regions(rng, n: int) -> RegionMap:
    rm = RegionMap()
    sizes = rng.integers(1, 64, size=n) * PAGE
    pools = rng.integers(0, FLAT.n_pools, size=n)
    for i in range(n):
        rm.alloc(f"r{i}", int(sizes[i]), "kvcache", pool=int(pools[i]))
    return rm


def _epoch_trace(rng, rm: RegionMap, events_per_region: int = 2) -> MemEvents:
    n_regions = len(rm)
    n = n_regions * events_per_region
    active = rng.choice(n_regions, size=max(n_regions // 2, 1), replace=False)
    reg = rng.choice(active, size=n).astype(np.int32)
    pool_vec = rm.pool_vector()
    return MemEvents(
        t_ns=np.sort(rng.uniform(0, 1e6, size=n)),
        pool=pool_vec[reg].astype(np.int32),
        bytes_=np.full((n,), 64.0),
        is_write=np.zeros((n,), bool),
        region=reg,
    )


def _cfg(rm: RegionMap) -> MigrationConfig:
    return MigrationConfig(
        mode="software",
        promote_threshold=1.0,
        demote_threshold=0.5,
        local_budget_bytes=int(sum(r.nbytes for r in rm) // 3),
        demote_pool="cxl_pool2",
    )


def sweep_scaling(sizes=(1_000, 10_000, 100_000), epochs=3) -> List[Dict]:
    rows: List[Dict] = []
    for R in sizes:
        rng = np.random.default_rng(0)
        rm_v = _regions(rng, R)
        rng = np.random.default_rng(0)
        rm_l = _regions(rng, R)
        sim_v = MigrationSimulator(_cfg(rm_v), rm_v, FLAT)
        sim_l = MigrationSimulator(_cfg(rm_l), rm_l, FLAT, impl="loop")
        t_v = t_l = 0.0
        parity = True
        rng = np.random.default_rng(1)
        for _ in range(epochs):
            tr = _epoch_trace(rng, rm_l)
            t0 = time.perf_counter()
            sim_v.observe_and_migrate(tr)
            t_v += time.perf_counter() - t0
            t0 = time.perf_counter()
            sim_l.observe_and_migrate(tr)
            t_l += time.perf_counter() - t0
            parity &= bool(
                np.array_equal(sim_v._pool, sim_l._pool)
                and sim_v.promotions == sim_l.promotions
                and sim_v.demotions == sim_l.demotions
            )
        rows.append(
            {
                "sweep": "migration_scaling",
                "regions": R,
                "events_per_epoch": len(rm_v) * 2,
                "vector_s_per_epoch": t_v / epochs,
                "loop_s_per_epoch": t_l / epochs,
                "speedup": t_l / t_v if t_v > 0 else float("inf"),
                "decisions_equal": parity,
                "promotions": sim_v.promotions,
                "demotions": sim_v.demotions,
            }
        )
    return rows


def _policy_migration_scenarios(impl: str):
    """The tests/test_policy_migration.py scenarios, under either engine."""
    out = []

    def run(cfg, setup, trace_fn):
        rm = RegionMap()
        reg = setup(rm)
        sim = MigrationSimulator(cfg, rm, FLAT, impl=impl)
        tr = trace_fn(reg, rm)
        sim.observe_and_migrate(tr)
        out.append((sim.promotions, sim.demotions, rm.pool_vector().tolist()))

    def line(reg, n, pool):
        return MemEvents.build(
            np.linspace(0, 1e5, n), [pool] * n, [64.0] * n, region=[reg.rid] * n
        )

    # promote-hot
    run(
        MigrationConfig(mode="software", promote_threshold=10, local_budget_bytes=1 << 30),
        lambda rm: rm.alloc("hot", 1 << 20, "kvcache", pool=1),
        lambda reg, rm: line(reg, 200, 1),
    )
    # demote-cold (home overridden to pool 1)
    def setup_cold(rm):
        reg = rm.alloc("cold", 1 << 20, "kvcache", pool=1)
        reg.pool = 0
        return reg

    def cold_trace(reg, rm):
        return line(reg, 1, 0)

    rm = RegionMap()
    reg = setup_cold(rm)
    sim = MigrationSimulator(
        MigrationConfig(mode="software", demote_threshold=5.0), rm, FLAT, impl=impl
    )
    sim._home_pool[reg.rid] = 1
    sim.observe_and_migrate(cold_trace(reg, rm))
    out.append((sim.promotions, sim.demotions, rm.pool_vector().tolist()))
    # hardware mid-epoch remap
    run(
        MigrationConfig(mode="hardware", promote_threshold=1, reaction_ns=5e4,
                        local_budget_bytes=1 << 30,
                        granularity_bytes=CACHELINE_BYTES),
        lambda rm: rm.alloc("hot", 1 << 12, "kvcache", pool=1),
        lambda reg, rm: line(reg, 100, 1),
    )
    return out


def sweep_cache(ks=(0, 1, 2, 4, 8), lines=160, events=1600, epochs=3) -> List[Dict]:
    an = EpochAnalyzer(FLAT)

    def reuse_trace():
        rm = RegionMap()
        reg = rm.alloc("kv", lines * PAGE, "kvcache", pool=1)
        rng = np.random.default_rng(0)
        tr = MemEvents(
            t_ns=np.sort(rng.uniform(0, 1e5, events)),
            pool=np.full((events,), 1, np.int32),
            bytes_=np.full((events,), float(PAGE)),
            is_write=np.zeros((events,), bool),
            region=np.full((events,), reg.rid, np.int32),
        )
        return rm, tr

    rm, tr = reuse_trace()
    base = an.analyze(tr)
    rows: List[Dict] = []
    for k in ks:
        rm, tr = reuse_trace()
        cfg = DeviceCacheConfig(
            capacity_bytes=k * PAGE * 64, line_bytes=PAGE, n_sets=64
        )
        model = DeviceCacheModel(cfg, FLAT, [rm])
        lat = frac_sum = 0.0
        exact = True
        for _ in range(epochs):
            frac = model.observe(tr)
            bd = an.analyze(tr, lat_scale=model.latency_scale(frac))
            frac_sum += float(frac[0, 1])
            lat += bd.latency_ns
            exact &= bd.latency_ns == base.latency_ns
        rows.append(
            {
                "sweep": "cache_capacity",
                "capacity_bytes": cfg.capacity_bytes,
                "ways": cfg.ways,
                "hit_fraction": frac_sum / epochs,
                "latency_ns": lat / epochs,
                "no_cache_latency_ns": base.latency_ns,
                "exact_no_cache_match": bool(exact),
            }
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_migration.json")
    ap.add_argument("--quick", action="store_true", help="small sweep (CI smoke)")
    args = ap.parse_args(argv)
    with open(args.out, "a"):
        pass  # fail on an unwritable record path before the sweep
    if args.quick:
        rows = sweep_scaling(sizes=(1_000, 10_000), epochs=2)
        rows += sweep_cache(ks=(0, 2))
    else:
        rows = sweep_scaling()
        rows += sweep_cache()
    parity_scenarios = (
        _policy_migration_scenarios("vector") == _policy_migration_scenarios("loop")
    )

    print(f"{'regions':>8} {'vector ms':>10} {'loop ms':>10} {'speedup':>8} {'parity':>7}")
    scaling = [r for r in rows if r["sweep"] == "migration_scaling"]
    for r in scaling:
        print(
            f"{r['regions']:>8} {r['vector_s_per_epoch'] * 1e3:>10.2f} "
            f"{r['loop_s_per_epoch'] * 1e3:>10.2f} {r['speedup']:>8.1f} "
            f"{str(r['decisions_equal']):>7}"
        )
    cache = [r for r in rows if r["sweep"] == "cache_capacity"]
    for r in cache:
        print(
            f"# cache {bytes_to_mib(r['capacity_bytes']):6.1f} MiB ({r['ways']} ways): "
            f"hit {r['hit_fraction']:.3f}, latency {r['latency_ns']:.3e} ns "
            f"(no-cache {r['no_cache_latency_ns']:.3e})"
        )

    big = max(scaling, key=lambda r: r["regions"])
    # the 10x wall-clock criterion is evaluated only by the full sweep
    # (quick mode runs small region counts on shared CI hardware)
    ok_speed = big["speedup"] >= 10.0 or args.quick
    ok_parity = all(r["decisions_equal"] for r in scaling) and parity_scenarios
    ok_zero = all(
        r["exact_no_cache_match"] for r in cache if r["capacity_bytes"] == 0
    )
    ok_lower = all(
        r["latency_ns"] < r["no_cache_latency_ns"]
        for r in cache
        if r["capacity_bytes"] > 0
    )
    record = {
        "bench": "migration_scaling",
        "platform": platform.platform(),
        "rows": rows,
        "acceptance": {
            "speedup_at_max_regions": big["speedup"],
            "timing_criterion_evaluated": not args.quick,
            "vector_ge_10x": bool(ok_speed),
            "decision_parity": bool(ok_parity),
            "cache_zero_capacity_exact": bool(ok_zero),
            "cache_strictly_lower_everywhere": bool(ok_lower),
            "pass": bool(ok_speed and ok_parity and ok_zero and ok_lower),
        },
    }
    speed_txt = (
        f">=10x {big['speedup'] >= 10.0} ({big['speedup']:.1f}x at {big['regions']})"
        if not args.quick
        else f">=10x skipped in --quick ({big['speedup']:.1f}x at {big['regions']})"
    )
    print(
        f"# acceptance: {speed_txt}, parity {ok_parity}, "
        f"cache exact@0 {ok_zero}, strictly lower {ok_lower} -> "
        f"{'PASS' if record['acceptance']['pass'] else 'FAIL'}"
    )
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {args.out}")
    # the gate is a gate: a failing acceptance block fails the process, so
    # the CI smoke step and the verify recipe actually catch regressions
    if not record["acceptance"]["pass"]:
        sys.exit(1)
    return rows


if __name__ == "__main__":
    main()
