"""Device-resident epoch pipeline benchmark -> BENCH_pipeline.json.

End-to-end epochs/s of a steady-state serving loop on a deep chained
topology, old path vs new:

  * ``old``  — the pre-pipeline dispatch (``pipeline=False``: full-plane
    fused cascade) fed through the PR6 ingest shim (``np.asarray(list(x))``
    per event column, the slow path :meth:`MemEvents.build` used to take on
    every input);
  * ``new``  — the device-resident pipeline: packed zero-argsort staging,
    on-device staging sort + compact suffix cascade, donated ring-buffer
    planes, AOT executable cache, and depth-1 launch/finish overlap so
    round k+1's staging+H2D overlaps round k's compute.

Both paths rebuild their traces every round (a serving loop ingests per
step) and analyze the identical epoch batch, checked against each other at
the end (rtol 1e-3: f32 device accumulation vs two different reduction
orders).

Hard asserts (both modes):

  * the on-device staging sort is **bitwise** equal to the host stable
    argsort it replaced;
  * staging ingest is O(copy) — `MemEvents.build` on ndarray input must
    not detour through ``list()``;
  * every pipeline dispatch actually donated its staging planes (a silent
    fallback to copies is a hard failure, not a slow success);
  * zero AOT recompiles across the steady-state loop.

Acceptance gate (full mode): ``new`` >= 2x ``old`` end-to-end epochs/s at
N=64k events x B=8 epochs on the depth-8 chain.

``--quick`` (CI smoke): N=4096, B=2, correctness asserts only.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.analyzer import EpochAnalyzer, plan_chain
from repro.core.events import EventStager, MemEvents, concat_events
from repro.core.topology import chained_topology
from repro.core.units import s_to_ms
from repro.kernels import ref


# --------------------------------------------------------------------------- #
# workload
# --------------------------------------------------------------------------- #


def _columns(n_pools: int, n: int, seed: int):
    rng = np.random.default_rng(seed)
    t = np.sort(rng.uniform(0, 1e6, n))
    pool = rng.integers(0, n_pools, n)
    nbytes = rng.integers(64, 4097, n).astype(np.float64)
    return t, pool, nbytes


def build_batch(
    n_pools: int, B: int, N: int, seed: int, tenants: int, shim: bool
) -> List[MemEvents]:
    """One round's epoch batch; ``shim`` routes every column through the
    PR6 ``np.asarray(list(x))`` ingest path."""
    out = []
    for b in range(B):
        parts = []
        per = N // tenants
        for tn in range(tenants):
            t, pool, nbytes = _columns(n_pools, per, seed + 1000 * b + tn)
            if shim:
                t, pool, nbytes = list(t), list(pool), list(nbytes)
            parts.append(MemEvents.build(t_ns=t, pool=pool, bytes_=nbytes))
        ev = parts[0] if tenants == 1 else concat_events(parts).sorted_by_time()
        out.append(ev)
    return out


# --------------------------------------------------------------------------- #
# correctness asserts
# --------------------------------------------------------------------------- #


def assert_staging_sort_bitwise(flat, quick: bool) -> None:
    rng = np.random.default_rng(7)
    caps = (64, 128, 32, 64)
    x = np.full((sum(caps),), np.inf, np.float32)
    idx = np.full((sum(caps),), -1, np.int32)
    off = 0
    for c in caps:
        fill = int(rng.integers(1, c + 1))
        x[off : off + fill] = np.sort(rng.uniform(0, 1e5, fill)).astype(np.float32)
        idx[off : off + fill] = off + np.arange(fill, dtype=np.int32)
        off += c
    gx, gi = ref.staging_sort(x, caps, idx)
    order = np.argsort(x, kind="stable")
    if not (
        np.array_equal(np.asarray(gx), x[order])
        and np.array_equal(np.asarray(gi), idx[order])
    ):
        raise SystemExit("FATAL: on-device staging sort != host stable argsort")


def assert_ingest_o_copy() -> None:
    n = 1 << 20
    t = np.sort(np.random.default_rng(0).uniform(0, 1e6, n))
    pool = np.zeros((n,), np.int64)
    nbytes = np.full((n,), 64.0)
    t0 = time.perf_counter()
    MemEvents.build(t_ns=t, pool=pool, bytes_=nbytes)
    build_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for a in (t, pool, nbytes):
        a.astype(a.dtype, copy=True)
    copy_s = time.perf_counter() - t0
    if build_s > max(30 * copy_s, 0.1):
        raise SystemExit(
            f"FATAL: MemEvents.build is not O(copy): {s_to_ms(build_s):.1f} ms "
            f"vs {s_to_ms(copy_s):.1f} ms raw copy — the list() ingest shim is back"
        )


# --------------------------------------------------------------------------- #
# timed loops
# --------------------------------------------------------------------------- #


def run_old(flat, B, N, tenants, rounds, seed=0):
    an = EpochAnalyzer(flat, n_windows=128)
    bd = an.analyze_batch(build_batch(flat.n_pools, B, N, seed, tenants, shim=False))
    t0 = time.perf_counter()
    for _ in range(rounds):
        batch = build_batch(flat.n_pools, B, N, seed, tenants, shim=True)
        bd = an.analyze_batch(batch)
    elapsed = time.perf_counter() - t0
    return bd, elapsed


def run_new(flat, B, N, tenants, rounds, seed=0):
    an = EpochAnalyzer(flat, n_windows=128, pipeline=True)
    stager = EventStager(slots=2)
    an.warmup(build_batch(flat.n_pools, B, N, seed, tenants, shim=False))
    base_lowerings = an._aot.lowerings
    pend = None
    bd = None
    t0 = time.perf_counter()
    for _ in range(rounds):
        batch = build_batch(flat.n_pools, B, N, seed, tenants, shim=False)
        nxt = an.launch_batch(batch, stager=stager)
        if not nxt.stats.donated and plan_chain(flat) is not None:
            raise SystemExit(
                "FATAL: chain dispatch fell back to copying its staging "
                "planes — donation is part of the perf contract"
            )
        if pend is not None:
            bd = pend.finish()
        pend = nxt
    bd = pend.finish()
    elapsed = time.perf_counter() - t0
    if an._aot.lowerings != base_lowerings:
        raise SystemExit(
            f"FATAL: {an._aot.lowerings - base_lowerings} AOT recompiles in "
            "the steady-state loop (expected zero)"
        )
    return bd, elapsed, an.last_dispatch


# --------------------------------------------------------------------------- #


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke: small sizes, no perf gate")
    ap.add_argument("--depth", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--events", type=int, default=65536)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args()

    if args.quick:
        args.events, args.batch, args.rounds = 4096, 2, 6

    topo = chained_topology(args.depth)
    flat = topo.flatten()
    if plan_chain(flat) is None:
        raise SystemExit("FATAL: chained topology must be chain-eligible")

    assert_staging_sort_bitwise(flat, args.quick)
    assert_ingest_o_copy()
    print("# correctness: staging sort bitwise OK, ingest O(copy) OK")

    record: Dict = {
        "bench": "epoch_pipeline",
        "platform": platform.platform(),
        "device": jax.devices()[0].device_kind,
        "config": {
            "depth": args.depth,
            "batch": args.batch,
            "events": args.events,
            "rounds": args.rounds,
            "quick": args.quick,
        },
        "runs": [],
    }

    for label, tenants in (("single", 1), ("two_tenant", 2)):
        old_bd, old_s = run_old(
            flat, args.batch, args.events, tenants, args.rounds
        )
        new_bd, new_s, st = run_new(
            flat, args.batch, args.events, tenants, args.rounds
        )
        rel = abs(new_bd.total_ns - old_bd.total_ns) / max(old_bd.total_ns, 1e-9)
        if rel > 1e-3:
            raise SystemExit(
                f"FATAL: old/new disagree on {label}: rel err {rel:.2e}"
            )
        epochs = args.batch * args.rounds
        row = {
            "workload": label,
            "old_epochs_per_s": epochs / old_s,
            "new_epochs_per_s": epochs / new_s,
            "speedup": old_s / new_s,
            "rel_err": rel,
            "donated": bool(st.donated),
            "aot_cache_hit": bool(st.aot_cache_hit),
            "last_stage_s": st.stage_s,
            "last_transfer_s": st.transfer_s,
            "last_compute_s": st.compute_s,
        }
        record["runs"].append(row)
        print(
            f"# {label}: old {row['old_epochs_per_s']:.2f} ep/s, "
            f"new {row['new_epochs_per_s']:.2f} ep/s, "
            f"speedup {row['speedup']:.2f}x, rel_err {rel:.1e}"
        )

    best = max(r["speedup"] for r in record["runs"])
    record["best_speedup"] = best
    record["gate"] = {"required_speedup": 2.0, "passed": bool(best >= 2.0)}
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {args.out}")
    if not args.quick and best < 2.0:
        raise SystemExit(f"FATAL: best speedup {best:.2f}x < required 2.0x")
    print(f"# gate {'PASS' if args.quick or best >= 2.0 else 'FAIL'} (best {best:.2f}x)")


if __name__ == "__main__":
    main()
