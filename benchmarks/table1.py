"""Paper Table 1 analog: native vs CXLMemSim vs fine-grained baseline.

The paper runs five allocation-pattern microbenchmarks (mmap_read,
mmap_write, sbrk, malloc, calloc) plus two SPEC2017 applications under
{native, Gem5, CXLMemSim} and reports wall-clock.  Our analog:

  * five microbenchmarks with the same allocation *shapes* (sequential
    read, sequential write, growing region, many small regions, one huge
    zeroed region) expressed as region maps + access phases over a jitted
    compute kernel;
  * two "real applications": training steps of two reduced-config archs
    from the zoo (the SPEC stand-ins);
  * three execution modes: native (no simulator), CXLMemSim attach
    (epoch analyzer — the paper's tool), and the fine-grained event-by-event
    DES (our Gem5 stand-in).

Reported per row: native wall, CXLMemSim wall (native + analyzer overhead),
fine-grained wall, CXLMemSim slowdown over native, and speedup vs the
fine-grained baseline — the two headline ratios of the paper (4.41×
slowdown on real apps; ~73× faster than Gem5).
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Access,
    CXLMemSim,
    ClassMapPolicy,
    Phase,
    RegionMap,
    figure1_topology,
)

STEPS = 4


def _micro(name: str) -> Tuple[RegionMap, List[Phase]]:
    """Allocation-pattern microbenchmarks (paper's five syscalls)."""
    r = RegionMap()
    MB = 1 << 20
    if name == "mmap_read":
        r.alloc("buf", 100 * MB, "other")
        phases = [Phase("read", 1e7, (Access("buf", 100 * MB),))]
    elif name == "mmap_write":
        r.alloc("buf", 100 * MB, "other")
        phases = [Phase("write", 1e7, (Access("buf", 100 * MB, True),))]
    elif name == "sbrk":
        # growing heap: phases over an expanding region
        r.alloc("heap", 100 * MB, "other")
        phases = [
            Phase(f"grow{i}", 1e6, (Access("heap", 10 * MB * (i + 1), True),))
            for i in range(10)
        ]
    elif name == "malloc":
        # many small allocations touched once
        for i in range(64):
            r.alloc(f"m{i}", int(1.5 * MB), "other")
        phases = [
            Phase(f"touch{i}", 2e5, (Access(f"m{i}", int(1.5 * MB), True),))
            for i in range(64)
        ]
    elif name == "calloc":
        # one huge zeroed region (paper: 10 GB working set)
        r.alloc("big", 1 << 30, "other")
        phases = [
            Phase("zero", 1e7, (Access("big", 1 << 30, True),)),
            Phase("touch", 1e7, (Access("big", 1 << 30),)),
        ]
    else:
        raise ValueError(name)
    return r, phases


def _real_app(arch: str) -> Tuple[RegionMap, List[Phase]]:
    import repro.configs as cfgs
    from repro.models.phases import build_regions_and_phases

    cfg = cfgs.get_smoke(arch)
    return build_regions_and_phases(cfg, "train", batch=8, seq=256)


def _wall(fn, *args, n=STEPS) -> float:
    fn(*args)  # warm up (compile)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run() -> List[Dict]:
    topo = figure1_topology()
    policy = ClassMapPolicy({"other": "cxl_pool2", "opt_state": "cxl_pool2"})
    step = jax.jit(lambda x: (x @ x.T).sum())
    x = jnp.ones((256, 256))

    rows = []
    benches = [(n, *_micro(n)) for n in ("mmap_read", "mmap_write", "sbrk", "malloc", "calloc")]
    benches += [(f"train_{a}", *_real_app(a)) for a in ("qwen3-0.6b", "mamba2-2.7b")]

    for name, regions, phases in benches:
        native_s = _wall(step, x)

        def run_mode(analyzer: str) -> Tuple[float, float]:
            sim = CXLMemSim(
                topo, policy, analyzer=analyzer, check_capacity=False,
                max_events_per_access=512,  # fine-granularity traces
            )
            prog = sim.attach(step, phases, regions)
            prog.step(x)  # warm-up epoch (compiles analyzer)
            t0 = time.perf_counter()
            for _ in range(STEPS):
                prog.step(x)
            wall = (time.perf_counter() - t0) / STEPS
            sim_s = prog.report.simulated_s / prog.report.steps
            return wall, sim_s

        cxl_wall, cxl_sim = run_mode("epoch")
        des_wall, des_sim = run_mode("fine")
        rows.append(
            {
                "benchmark": name,
                "native_s": native_s,
                "cxlmemsim_s": cxl_wall,
                "fine_grained_s": des_wall,
                "simulated_s": cxl_sim,
                "overhead_x": cxl_wall / native_s,
                "speedup_vs_fine": des_wall / cxl_wall,
            }
        )
    return rows


def main():
    rows = run()
    print("benchmark,native_s,cxlmemsim_s,fine_grained_s,overhead_x,speedup_vs_fine")
    for r in rows:
        print(
            f"{r['benchmark']},{r['native_s']:.4f},{r['cxlmemsim_s']:.4f},"
            f"{r['fine_grained_s']:.4f},{r['overhead_x']:.2f},{r['speedup_vs_fine']:.1f}"
        )
    ovh = np.mean([r["overhead_x"] for r in rows])
    spd = np.mean([r["speedup_vs_fine"] for r in rows])
    print(f"# avg overhead {ovh:.2f}x (paper: 4.41x on real apps, 41x overall)")
    print(f"# avg speedup vs fine-grained {spd:.1f}x (paper: 73x vs Gem5)")
    return rows


if __name__ == "__main__":
    main()
