"""Batched scenario sweep vs the sequential loop -> BENCH_sweep.json.

One fixed workload; K = policies × topology overrides × granularities ×
device-cache configs scenarios, evaluated two ways:

  * **batched** — :meth:`repro.core.ScenarioSuite.run`: one ``[K, B, N]``
    stacked jitted dispatch (placement matrix + shared trace skeletons +
    stacked topology leaves + deduplicated congestion cascades).
  * **sequential** — the pre-port sweep-surface pattern, one scenario per
    Python iteration: ``policy.place`` loop, ``synthesize_step_trace``,
    per-scenario ``EpochAnalyzer.analyze`` dispatch (+ per-scenario cache
    model), K host round-trips.

Both are warmed before timing, so compile time is excluded from both
sides.  Accuracy is checked for EVERY scenario against the float64 numpy
oracle ``analyze_ref`` (windows pinned to the analyzer's static count so
the comparison measures the sweep stacking, not window discretization).

Acceptance gate (ISSUE 4):
  * batched >= 5x sequential wall-clock at K = 256,
  * max relative error vs sequential ``analyze_ref`` <= 1e-4 on every
    scenario's latency/congestion/bandwidth totals,
  * exactly one stacked dispatch per ``run``.

``--quick`` (CI smoke) shrinks K; the speedup gate only applies at full
K = 256 (small K can't amortize, the accuracy/dispatch gates always hold).
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import List

import numpy as np

from repro.core import (
    CACHELINE_BYTES,
    ClassMapPolicy,
    DeviceCacheConfig,
    DeviceCacheModel,
    EpochAnalyzer,
    HotnessTieredPolicy,
    InterleavePolicy,
    LocalOnlyPolicy,
    PAGE_BYTES,
    RegionMap,
    ScenarioSuite,
    TopologyOverride,
    analyze_ref,
    figure1_topology,
)
from repro.core.scenario import Scenario
from repro.core.topology import flatten_stack
from repro.core.tracer import Access, Phase, synthesize_step_trace

SPEEDUP_GATE = 5.0
REL_ERR_GATE = 1e-4
FULL_K = 256


def workload(n_regions: int = 24, n_phases: int = 8, seed: int = 0):
    """Deterministic synthetic training-step workload (~4k events/epoch)."""
    rng = np.random.default_rng(seed)
    rm = RegionMap()
    classes = ["param", "grad", "opt_state", "kvcache", "activation"]
    for i in range(n_regions):
        r = rm.alloc(f"r{i}", int(rng.integers(1 << 16, 1 << 22)), classes[i % 5])
        r.access_count = float(rng.integers(0, 50))
    phases = []
    for pi in range(n_phases):
        accs = tuple(
            Access(
                f"r{int(j)}",
                float(rng.integers(100_000, 3_000_000)),
                bool(rng.random() < 0.3),
            )
            for j in rng.choice(n_regions, size=8, replace=False)
        )
        phases.append(Phase(f"ph{pi}", 5e10, accs))
    return rm, phases


def scenario_grid(rm: RegionMap, k: int) -> List[Scenario]:
    """policies(4) × overrides(k/32) × granularity(2) × cache(4 of 16)."""
    total = int(sum(r.nbytes for r in rm))
    policies = {
        "local": LocalOnlyPolicy(),
        "opt_off": ClassMapPolicy({"opt_state": "cxl_pool2", "kvcache": "cxl_pool1"}),
        "il": InterleavePolicy(["cxl_pool2", "cxl_pool3"], weights=[1, 2]),
        "hot": HotnessTieredPolicy("cxl_pool1", local_budget_bytes=total // 2),
    }
    n_ov = max(k // 16, 1)  # 16 scenarios per override (4 pol x 2 gran x 2 cache)
    lats = np.linspace(120.0, 400.0, max(n_ov // 4, 1))
    bws = (8.0, 16.0, 32.0, 64.0)[: max(min(4, n_ov), 1)]
    overrides = {
        f"lat{int(l)}_bw{bw:g}": TopologyOverride(
            pools={
                "cxl_pool2": {"latency_ns": float(l)},
                "cxl_pool3": {"latency_ns": float(l)},
            },
            switches={"switch1": {"bandwidth_gbps": float(bw)}},
        )
        for l in lats
        for bw in bws
    }
    caches = {
        "nc": None,
        "c16m": DeviceCacheConfig(capacity_bytes=16 << 20, line_bytes=4096, n_sets=64),
    }
    scens = ScenarioSuite.cartesian(
        policies, overrides, caches, granularities=[CACHELINE_BYTES, PAGE_BYTES]
    )
    return scens[:k]


def sequential_eval(suite: ScenarioSuite, scens: List[Scenario], rm, phases):
    """The pre-port loop: K placements, syntheses, dispatches, transfers."""
    stack = flatten_stack(suite.topology, [s.topology for s in scens])
    out = []
    for k, s in enumerate(scens):
        flat_k = stack.member(k)
        s.policy.place(rm, suite.base_flat)
        traces, native, _ = synthesize_step_trace(
            phases, rm, granularity_bytes=s.policy.granularity_bytes
        )
        scale = None
        if s.cache is not None:
            model = DeviceCacheModel(s.cache, flat_k, [rm])
            scale = model.observe_scale(traces[0])
        an = EpochAnalyzer(flat_k)
        out.append(an.analyze(traces[0], lat_scale=scale))
    return out


def oracle_errors(suite: ScenarioSuite, scens, rm, phases, res) -> float:
    """Max relative error of every scenario total vs sequential analyze_ref."""
    stack = flatten_stack(suite.topology, [s.topology for s in scens])
    worst = 0.0
    for k, s in enumerate(scens):
        flat_k = stack.member(k)
        s.policy.place(rm, suite.base_flat)
        traces, _, _ = synthesize_step_trace(
            phases, rm, granularity_bytes=s.policy.granularity_bytes
        )
        tr = traces[0]
        span = max(float(tr.t_ns.max()) + 1.0, suite.bw_window_ns)
        bww = max(span / suite.n_windows, 1.0)
        scale = None
        if s.cache is not None:
            scale = DeviceCacheModel(s.cache, flat_k, [rm]).observe_scale(tr)
        ref = analyze_ref(
            flat_k, tr, bw_window_ns=bww, lat_scale=scale, n_windows=suite.n_windows
        )
        got = res.breakdowns[k]
        for f in ("latency_ns", "congestion_ns", "bandwidth_ns"):
            a, b = getattr(got, f), getattr(ref, f)
            worst = max(worst, abs(a - b) / max(abs(b), 1.0))
    return worst


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=FULL_K)
    ap.add_argument("--quick", action="store_true", help="CI smoke: K=32")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_sweep.json")
    args = ap.parse_args(argv)
    K = 32 if args.quick else args.k

    rm, phases = workload()
    topo = figure1_topology()
    suite = ScenarioSuite(topo, rm, phases)
    scens = scenario_grid(rm, K)
    K = len(scens)

    # warm both paths (compile/caches out of the timed region)
    res = suite.run(scens)
    sequential_eval(suite, scens, rm, phases)

    dispatches_before = suite.dispatch_count
    compiles_before = suite.compile_cache_size()
    t_batch = []
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        res = suite.run(scens)
        t_batch.append(time.perf_counter() - t0)
    dispatches_timed = suite.dispatch_count - dispatches_before
    compiles_timed = suite.compile_cache_size() - compiles_before
    t_seq = []
    for _ in range(max(args.repeats // 2, 1)):
        t0 = time.perf_counter()
        seq = sequential_eval(suite, scens, rm, phases)
        t_seq.append(time.perf_counter() - t0)

    batch_s, seq_s = min(t_batch), min(t_seq)
    speedup = seq_s / batch_s
    max_rel = oracle_errors(suite, scens, rm, phases, res)
    # sweep-kernel dispatches are counted at the jitted callable, so any
    # extra dispatch path inside run() trips this; zero compile-cache
    # growth across timed runs means no per-scenario jit/compile either
    one_dispatch = dispatches_timed == args.repeats and compiles_timed == 0

    gates = {
        "one_stacked_dispatch_per_run": bool(one_dispatch),
        "max_rel_err_le_1e-4": bool(max_rel <= REL_ERR_GATE),
        "speedup_ge_5x_at_k256": bool(speedup >= SPEEDUP_GATE) if K >= FULL_K else None,
    }
    ok = all(v for v in gates.values() if v is not None)

    record = {
        "bench": "scenario_sweep",
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "k": K,
        "epochs": suite.skeleton_for(CACHELINE_BYTES).n_epochs,
        "events_per_epoch_bucket": int(
            suite._staged[next(iter(suite._staged))]["t"].shape[1]
        ),
        "unique_cascades": suite.last_unique_cascades,
        "dispatches_during_timed_runs": dispatches_timed,
        "compiles_during_timed_runs": compiles_timed,
        "batched_s": batch_s,
        "sequential_s": seq_s,
        "speedup": speedup,
        "max_rel_err_vs_analyze_ref": max_rel,
        "gates": gates,
        "pass": bool(ok),
        "best_scenario": res.scenarios[res.best()].label() if res.best() is not None else None,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(json.dumps(record, indent=1))
    if not ok:
        print("ACCEPTANCE GATE FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
