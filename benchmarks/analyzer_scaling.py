"""Fused/batched analyzer scaling sweep -> BENCH_analyzer.json perf record.

Sweeps event count x switch depth x batch size across analyzer
implementations and writes a machine-readable record so future PRs can
track the trajectory of the hot path:

  * ``seed``           — the pre-fusion per-epoch path (``fused=False``:
                         one argsort + scatter per switch stage per epoch,
                         one dispatch + host sync per epoch),
  * ``fused``          — fused single-sort cascade, still one epoch per
                         dispatch,
  * ``fused_batched``  — fused cascade + ``analyze_batch`` ([B, N] stacked
                         epochs, one dispatch, on-device accumulation),
  * ``fused_pallas``   — the multi-stage Pallas kernel via the interpreter
                         (CPU correctness path; compiled speed needs a TPU),
                         small sizes only.

Topologies: a ``depth``-switch chain with the remote pools behind the
deepest switch (the analyzer's static merge plan needs zero inter-stage
merges) and, at the acceptance point, the branching Figure-1 topology
(one merge per epoch) for an honest worst-ish case.

Every timed config is also checked against ``analyze_ref`` run with the
same effective window length, recording the relative error.

Acceptance gate (ISSUE 1): fused_batched >= 5x seed at N=65536, depth 3,
with <= 1e-3 relative error vs the oracle.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List


from repro.core.analyzer import EpochAnalyzer, analyze_ref
from repro.core.events import synthetic_trace
from repro.core.topology import FlatTopology, Pool, Switch, Topology, figure1_topology

BURSTINESS = 0.5


def chain_topology(depth: int) -> Topology:
    switches = [
        Switch(f"sw{d}", 70.0, 64.0 - 8.0 * d, 2.0 + d, parent=f"sw{d-1}" if d else None)
        for d in range(depth)
    ]
    return Topology(
        pools=[
            Pool("local", 88.9, 76.8, 1 << 36, is_local=True),
            Pool("far1", 180.0, 32.0, 1 << 38, parent=f"sw{depth-1}"),
            Pool("far2", 200.0, 32.0, 1 << 38, parent=f"sw{depth-1}"),
        ],
        switches=switches,
    )


def _oracle_rel_err(an: EpochAnalyzer, flat: FlatTopology, ev) -> float:
    """Max relative error of the three delay totals vs analyze_ref, with the
    oracle run at the analyzer's effective window length."""
    got = an.analyze(ev)
    span = max(float(ev.t_ns.max()) + 1.0, an.bw_window_ns)
    ref = analyze_ref(flat, ev, bw_window_ns=max(span / an.n_windows, 1.0))
    errs = []
    for g, r in (
        (got.latency_ns, ref.latency_ns),
        (got.congestion_ns, ref.congestion_ns),
        (got.bandwidth_ns, ref.bandwidth_ns),
    ):
        if abs(r) > 1e-6:
            errs.append(abs(g - r) / abs(r))
    return max(errs) if errs else 0.0


def _time_per_epoch(fn, reps: int) -> float:
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(
    sizes=(4096, 16384, 65536),
    depths=(1, 2, 3),
    batches=(1, 8, 32),
    pallas_max_events: int = 4096,
) -> List[Dict]:
    rows: List[Dict] = []
    for depth in depths:
        topo = chain_topology(depth)
        flat = topo.flatten()
        for n in sizes:
            traces = [
                synthetic_trace(n, flat.n_pools, epoch_ns=1e6, seed=i, burstiness=BURSTINESS)
                for i in range(max(batches))
            ]
            reps = 2 if n >= 65536 else 3
            seed_an = EpochAnalyzer(flat, fused=False)
            fused_an = EpochAnalyzer(flat)
            seed_s = _time_per_epoch(lambda: seed_an.analyze(traces[0]), reps)
            configs = [("seed", seed_an, 1), ("fused", fused_an, 1)]
            configs += [("fused_batched", fused_an, b) for b in batches if b > 1]
            if n <= pallas_max_events:
                configs.append(
                    ("fused_pallas", EpochAnalyzer(flat, impl="pallas_interpret"), 1)
                )
            for name, an, b in configs:
                if name == "seed":
                    per_epoch = seed_s
                elif b == 1:
                    per_epoch = _time_per_epoch(lambda: an.analyze(traces[0]), reps)
                else:
                    per_epoch = (
                        _time_per_epoch(lambda: an.analyze_batch(traces[:b]), reps) / b
                    )
                rows.append(
                    {
                        "impl": name,
                        "topology": f"chain{depth}",
                        "events": n,
                        "switch_depth": depth,
                        "batch": b,
                        "s_per_epoch": per_epoch,
                        "events_per_s": n / per_epoch,
                        "speedup_vs_seed": seed_s / per_epoch,
                        "oracle_rel_err": _oracle_rel_err(an, flat, traces[0]),
                    }
                )
    # honest non-chain data point: Figure-1 (branching => one merge/epoch)
    flat = figure1_topology().flatten()
    n, b = 65536, 8
    traces = [
        synthetic_trace(n, flat.n_pools, epoch_ns=1e6, seed=i, burstiness=BURSTINESS)
        for i in range(b)
    ]
    seed_an = EpochAnalyzer(flat, fused=False)
    fused_an = EpochAnalyzer(flat)
    seed_s = _time_per_epoch(lambda: seed_an.analyze(traces[0]), 2)
    fused_s = _time_per_epoch(lambda: fused_an.analyze_batch(traces), 2) / b
    rows.append(
        {
            "impl": "fused_batched",
            "topology": "figure1",
            "events": n,
            "switch_depth": 2,
            "batch": b,
            "s_per_epoch": fused_s,
            "events_per_s": n / fused_s,
            "speedup_vs_seed": seed_s / fused_s,
            "oracle_rel_err": _oracle_rel_err(fused_an, flat, traces[0]),
        }
    )
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_analyzer.json")
    ap.add_argument("--quick", action="store_true", help="small sweep (CI smoke)")
    args = ap.parse_args()
    # fail on an unwritable record path before the sweep, not after
    with open(args.out, "a"):
        pass
    if args.quick:
        rows = run(sizes=(4096,), depths=(2,), batches=(1, 4), pallas_max_events=4096)
    else:
        rows = run()

    print(f"{'impl':<14} {'topo':<8} {'events':>7} {'batch':>5} "
          f"{'ms/epoch':>9} {'vs seed':>8} {'rel_err':>9}")
    for r in rows:
        print(
            f"{r['impl']:<14} {r['topology']:<8} {r['events']:>7} {r['batch']:>5} "
            f"{r['s_per_epoch'] * 1e3:>9.2f} {r['speedup_vs_seed']:>7.1f}x "
            f"{r['oracle_rel_err']:>9.1e}"
        )

    gate = [
        r
        for r in rows
        if r["impl"] == "fused_batched"
        and r["events"] == 65536
        and r["switch_depth"] == 3
    ]
    record = {
        "bench": "analyzer_scaling",
        "burstiness": BURSTINESS,
        "platform": platform.platform(),
        "rows": rows,
    }
    if gate:
        best = max(gate, key=lambda r: r["speedup_vs_seed"])
        record["acceptance"] = {
            "config": "N=65536 depth=3 (chain)",
            "speedup_vs_seed": best["speedup_vs_seed"],
            "oracle_rel_err": best["oracle_rel_err"],
            "pass": bool(
                best["speedup_vs_seed"] >= 5.0 and best["oracle_rel_err"] <= 1e-3
            ),
        }
        print(
            f"# acceptance: fused+batched {best['speedup_vs_seed']:.1f}x vs seed, "
            f"rel_err {best['oracle_rel_err']:.1e} -> "
            f"{'PASS' if record['acceptance']['pass'] else 'FAIL'}"
        )
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
