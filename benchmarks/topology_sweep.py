"""Topology / policy sweep: the tool's deployment use-case (paper §1,
"allows data-center operators to evaluate potential topologies before
procurement").

One fixed workload (a training step of a zoo config), priced against:
  topologies × placement policies × management granularities
with the full three-delay decomposition per cell.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import (
    CACHELINE_BYTES,
    PAGE_BYTES,
    CXLMemSim,
    ClassMapPolicy,
    InterleavePolicy,
    LocalOnlyPolicy,
    figure1_topology,
    local_only_topology,
    two_tier_topology,
)
from repro.core.analyzer import EpochAnalyzer
from repro.core.tracer import synthesize_step_trace
from repro.models.phases import build_regions_and_phases

import repro.configs as cfgs


def run(arch: str = "qwen3-0.6b") -> List[Dict]:
    cfg = cfgs.get_smoke(arch)
    rows = []
    topos = {
        "local_only": local_only_topology(),
        "two_tier": two_tier_topology(),
        "figure1": figure1_topology(),
    }
    for topo_name, topo in topos.items():
        flat = topo.flatten()
        remote = [n for n in flat.pool_names if n != "local_dram"]
        policies = {"all_local": LocalOnlyPolicy()}
        if remote:
            policies["opt_offload"] = ClassMapPolicy({"opt_state": remote[0]})
            policies["opt_offload_page"] = ClassMapPolicy(
                {"opt_state": remote[0]}, granularity_bytes=PAGE_BYTES
            )
            if len(remote) >= 2:
                policies["interleave"] = InterleavePolicy(
                    remote, classes=["opt_state", "grad"]
                )
        for pol_name, pol in policies.items():
            regions, phases = build_regions_and_phases(cfg, "train", batch=8, seq=256)
            pol.place(regions, flat)
            traces, native_ns, _ = synthesize_step_trace(
                phases, regions, granularity_bytes=pol.granularity_bytes
            )
            an = EpochAnalyzer(flat)
            bd = an.analyze(traces[0])
            rows.append(
                {
                    "topology": topo_name,
                    "policy": pol_name,
                    "native_ms": native_ns[0] / 1e6,
                    "latency_ms": bd.latency_ns / 1e6,
                    "congestion_ms": bd.congestion_ns / 1e6,
                    "bandwidth_ms": bd.bandwidth_ns / 1e6,
                    "slowdown": (native_ns[0] + bd.total_ns) / native_ns[0],
                }
            )
    return rows


def main():
    rows = run()
    print("topology,policy,native_ms,latency_ms,congestion_ms,bandwidth_ms,slowdown")
    for r in rows:
        print(
            f"{r['topology']},{r['policy']},{r['native_ms']:.3f},{r['latency_ms']:.3f},"
            f"{r['congestion_ms']:.3f},{r['bandwidth_ms']:.3f},{r['slowdown']:.3f}"
        )
    return rows


if __name__ == "__main__":
    main()
