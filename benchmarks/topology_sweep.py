"""Topology / policy sweep: the tool's deployment use-case (paper §1,
"allows data-center operators to evaluate potential topologies before
procurement").

One fixed workload (a training step of a zoo config), priced against:
  topologies × placement policies × management granularities
with the full three-delay decomposition per cell.

Ported to :class:`~repro.core.ScenarioSuite`: each base topology structure
evaluates its whole policy × granularity grid in ONE stacked device
dispatch (3 dispatches total here, vs one per cell before).
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import (
    PAGE_BYTES,
    ClassMapPolicy,
    InterleavePolicy,
    LocalOnlyPolicy,
    ScenarioSuite,
    figure1_topology,
    local_only_topology,
    two_tier_topology,
)
from repro.core.units import ns_to_ms
from repro.models.phases import build_regions_and_phases

import repro.configs as cfgs


def run(arch: str = "qwen3-0.6b") -> List[Dict]:
    cfg = cfgs.get_smoke(arch)
    regions, phases = build_regions_and_phases(cfg, "train", batch=8, seq=256)
    rows = []
    topos = {
        "local_only": local_only_topology(),
        "two_tier": two_tier_topology(),
        "figure1": figure1_topology(),
    }
    for topo_name, topo in topos.items():
        flat = topo.flatten()
        remote = [n for n in flat.pool_names if n != "local_dram"]
        policies = {"all_local": LocalOnlyPolicy()}
        if remote:
            policies["opt_offload"] = ClassMapPolicy({"opt_state": remote[0]})
            policies["opt_offload_page"] = ClassMapPolicy(
                {"opt_state": remote[0]}, granularity_bytes=PAGE_BYTES
            )
            if len(remote) >= 2:
                policies["interleave"] = InterleavePolicy(
                    remote, classes=["opt_state", "grad"]
                )
        suite = ScenarioSuite(topo, regions, phases)
        scens = ScenarioSuite.cartesian(policies)
        res = suite.run(scens)  # the whole policy grid: one dispatch
        for s, bd, slow in zip(res.scenarios, res.breakdowns, res.slowdowns()):
            rows.append(
                {
                    "topology": topo_name,
                    "policy": s.name.split("/")[1],
                    "native_ms": ns_to_ms(res.native_ns),
                    "latency_ms": ns_to_ms(bd.latency_ns),
                    "congestion_ms": ns_to_ms(bd.congestion_ns),
                    "bandwidth_ms": ns_to_ms(bd.bandwidth_ns),
                    "slowdown": float(slow),
                }
            )
    return rows


def main():
    rows = run()
    print("topology,policy,native_ms,latency_ms,congestion_ms,bandwidth_ms,slowdown")
    for r in rows:
        print(
            f"{r['topology']},{r['policy']},{r['native_ms']:.3f},{r['latency_ms']:.3f},"
            f"{r['congestion_ms']:.3f},{r['bandwidth_ms']:.3f},{r['slowdown']:.3f}"
        )
    return rows


if __name__ == "__main__":
    main()
