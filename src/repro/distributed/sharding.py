"""Sharding rules: logical tensor classes -> mesh PartitionSpecs.

Mesh axes: ``('data', 'model')`` single-pod, ``('pod', 'data', 'model')``
multi-pod.  Strategies:

  * ``'dp_tp'``   (baseline)  — batch on (pod, data); TP on model: attention
    heads / FFN hidden / vocab sharded; params otherwise replicated across
    data.  This is the classic Megatron layout.
  * ``'fsdp_tp'`` (ZeRO-3-style) — additionally shards every weight's
    *input* dim across 'data'; XLA inserts all-gathers at use and
    reduce-scatters of grads.  Required for ≥50B archs to fit HBM.

Every rule checks divisibility: a dim that does not divide its mesh axis is
left unsharded (e.g. granite's vocab 49155, hubert's vocab 504) — recorded
in EXPERIMENTS.md §Dry-run notes.  Expert dims: E on 'model' (EP) when
divisible, else the expert hidden dim.
"""

from __future__ import annotations

import time
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "batch_axes",
    "param_pspecs",
    "opt_pspecs",
    "input_pspecs",
    "named",
    "tree_named",
    "resolve_data_mesh",
    "pad_to_multiple",
    "shard_rows",
    "replicated",
    "timed_device_put",
]


# --------------------------------------------------------------------------- #
# Leading-axis ("data") dispatch sharding — used by the analyzer's stacked
# [K, B, N] dispatches (ScenarioSuite sweeps, AnalysisEngine coalescing,
# FleetSim racks).  The contract: the K leading axis shards over the mesh's
# 'data' axis; everything else (topology structure, skeleton stacks, unique
# cascades) replicates.
# --------------------------------------------------------------------------- #


def resolve_data_mesh(mesh: Optional[Mesh], rows: int, *, what: str = "dispatch"):
    """Validate ``mesh`` for sharding ``rows`` leading-axis rows.

    Returns ``(mesh, n_shards)``.  ``(None, 1)`` means sharding does not
    engage (no mesh, a single device, or nothing to shard).  When the mesh
    holds more devices along 'data' than there are rows, we fall back to a
    submesh over the first ``rows`` devices with a warning instead of letting
    XLA die on a shape-divisibility error — the work still runs, just on
    fewer shards.
    """
    if mesh is None or rows <= 0:
        return None, 1
    if "data" not in mesh.axis_names:
        raise ValueError(
            f"sharded {what} needs a mesh with a 'data' axis; got axes "
            f"{tuple(mesh.axis_names)} — build one with "
            "repro.launch.mesh.make_data_mesh()"
        )
    n = int(mesh.shape["data"])
    for ax in mesh.axis_names:
        if ax != "data" and int(mesh.shape[ax]) != 1:
            raise ValueError(
                f"sharded {what} shards only the 'data' axis; mesh axis "
                f"{ax!r} has size {mesh.shape[ax]} > 1 (leading-axis rows "
                "cannot also shard over it)"
            )
    if n <= 1:
        return None, 1
    if n > rows:
        warnings.warn(
            f"mesh has {n} devices on 'data' but the {what} has only "
            f"{rows} rows; falling back to {rows} shard(s)",
            stacklevel=3,
        )
        devs = np.asarray(mesh.devices).reshape(-1)[:rows]
        sub = Mesh(devs, ("data",))
        return (None, 1) if rows == 1 else (sub, rows)
    return mesh, n


def pad_to_multiple(n: int, k: int) -> int:
    """Smallest multiple of ``k`` that is >= ``n`` (k <= 1 => n)."""
    if k <= 1:
        return n
    return ((n + k - 1) // k) * k


def shard_rows(mesh: Optional[Mesh], x):
    """Device_put ``x`` with its leading axis sharded over 'data'.

    No-op passthrough when ``mesh`` is None so callers can write one code
    path; the leading dim must be a multiple of the data-axis size (callers
    pad with :func:`pad_to_multiple` first).
    """
    if mesh is None:
        return x
    spec = P(*(("data",) + (None,) * (np.ndim(x) - 1)))
    return jax.device_put(x, NamedSharding(mesh, spec))


def replicated(mesh: Optional[Mesh], x):
    """Device_put ``x`` fully replicated over ``mesh`` (passthrough if None)."""
    if mesh is None:
        return x
    return jax.device_put(x, NamedSharding(mesh, P()))


def timed_device_put(tree, mesh: Optional[Mesh] = None, spec: Optional[P] = None):
    """H2D placement with the transfer wall clock measured at the source.

    Returns ``(device_tree, seconds)``.  The pipeline dispatcher uses this
    to report ``transfer_s`` per dispatch and, because the placement is an
    explicit ``device_put`` (not an implicit transfer inside the jitted
    call), the resulting device buffers are what ``donate_argnums``
    consumes — donation engages on the copies, never on the caller's host
    staging planes.  With ``mesh`` (and optionally ``spec``) the placement
    is sharded; default is the single default device.
    """
    t0 = time.perf_counter()
    if mesh is None:
        out = jax.device_put(tree)
    else:
        out = jax.device_put(tree, NamedSharding(mesh, spec if spec is not None else P()))
    t1 = time.perf_counter()
    return out, t1 - t0


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _ok(mesh: Mesh, dim: int, axis) -> Optional[Any]:
    """Return axis if dim divides its mesh extent, else None."""
    return axis if axis is not None and dim % _axis_size(mesh, axis) == 0 else None


def _path_names(path) -> Tuple[str, ...]:
    return tuple(
        str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path
    )


def param_pspecs(param_shapes, cfg, mesh: Mesh, strategy: str = "dp_tp"):
    """PartitionSpec pytree matching ``param_shapes`` (shapes or arrays).

    Strategies: 'dp_tp', 'fsdp_tp', plus '+moe_dp' suffix (e.g.
    'fsdp_tp+moe_dp') to replicate expert weights over the model axis —
    trades redundant expert compute for the elimination of the per-layer
    partial-sum all-reduce when E doesn't divide the model axis.
    """
    moe_dp = "+moe_dp" in strategy
    gqa_fix = "+gqa_fix" in strategy
    ep_data = "+ep_data" in strategy
    strategy = (
        strategy.replace("+moe_dp", "").replace("+gqa_fix", "").replace("+ep_data", "")
    )
    fsdp = "data" if strategy == "fsdp_tp" else None
    model = "model"
    msize = _axis_size(mesh, model)

    def rule(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        last = names[-1]
        in_blocks = "blocks" in names
        # scan-stacked params carry a leading n_groups dim; unrolled stacks
        # (scan_layers=False) are lists of groups — SequenceKey in the path
        unrolled = any(
            isinstance(k, jax.tree_util.SequenceKey) for k in path
        )
        lead = (None,) if (in_blocks and not unrolled) else ()

        def spec(*axes):
            axes = lead + axes
            # pad with None to rank
            axes = axes + (None,) * (len(shape) - len(axes))
            checked = tuple(
                _ok(mesh, shape[i], a) for i, a in enumerate(axes)
            )
            return P(*checked)

        if last == "embed":
            v, d = shape
            if v % _axis_size(mesh, model) == 0:
                return P(model, _ok(mesh, d, fsdp))
            return P(None, _ok(mesh, d, model))  # fallback: shard d_model
        if last == "lm_head":
            return spec(fsdp, model)
        if "attn" in names:
            # +gqa_fix: GSPMD cannot propagate a model-axis sharding through
            # the [.., Hk·Dh] -> [.., Hk, Dh] head split unless the HEAD count
            # divides the axis.  Sharding the flat projection anyway forces a
            # per-layer activation re-shard (measured: TB-scale all-reduce).
            # Fix: only shard projections whose head count divides the axis;
            # small KV projections are replicated instead.
            if gqa_fix:
                q_ok = cfg.n_heads % msize == 0
                kv_ok = cfg.n_kv_heads % msize == 0
                if last == "wq":
                    return spec(fsdp, model) if q_ok else spec(fsdp, None)
                if last in ("wk", "wv"):
                    return spec(fsdp, model) if kv_ok else spec(fsdp, None)
                if last == "wo":
                    return spec(model, fsdp) if q_ok else spec(None, fsdp)
                return spec()
            if last in ("wq", "wk", "wv"):
                return spec(fsdp, model)
            if last == "wo":
                return spec(model, fsdp)
            return spec()  # q_norm / k_norm
        if "moe" in names:
            E = cfg.n_experts
            ep_ok = E % _axis_size(mesh, model) == 0 and not moe_dp
            if last == "router":
                return spec(fsdp, None)
            if last in ("wi", "wu"):
                if ep_data:
                    return spec("data", None, model)  # EP on data, TP on hidden
                if moe_dp:
                    return spec(None, fsdp, None)  # experts replicated on model
                return spec(model, fsdp, None) if ep_ok else spec(None, fsdp, model)
            if last == "wo":
                if ep_data:
                    return spec("data", model, None)
                if moe_dp:
                    return spec(None, None, fsdp)
                return spec(model, None, fsdp) if ep_ok else spec(None, model, fsdp)
            if last in ("shared_wi", "shared_wu"):
                return spec(fsdp, model)
            if last == "shared_wo":
                return spec(model, fsdp)
            return spec()
        if "mlp" in names:
            if last in ("wi", "wu"):
                return spec(fsdp, model)
            if last == "wo":
                return spec(model, fsdp)
            return spec()
        if "mamba" in names:
            if last == "in_proj":
                return spec(fsdp, model)
            if last == "out_proj":
                return spec(model, fsdp)
            if last == "conv_w":
                return spec(None, model)
            if last == "norm":
                return spec(model)  # inner-width gain, sharded with di
            return spec()  # A_log, dt_bias, D
        return spec()  # norms etc.

    flat = jax.tree_util.tree_flatten_with_path(param_shapes)
    specs = [rule(p, l) for p, l in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], specs)


def opt_pspecs(param_specs, strategy: str = "dp_tp"):
    """Optimizer state specs: moments mirror the params; step replicated.

    Under plain dp_tp the moments additionally get ZeRO-1 treatment only if
    strategy requests it upstream — here they simply mirror the param spec
    (correct in both modes; fsdp_tp already shards the underlying params).
    """
    return {
        "mu": param_specs,
        "nu": param_specs,
        "step": P(),
    }


def input_pspecs(specs: Dict[str, Any], mesh: Mesh):
    """Sharding for step inputs (train batch or serve state)."""
    b_axes = batch_axes(mesh)
    baxis = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)

    def rule(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        first = names[0] if names else ""
        if first in ("tokens", "labels", "embeds", "token", "embed"):
            b = _ok(mesh, shape[0], baxis)
            return P(b, *([None] * (len(shape) - 1)))
        if first == "caches":
            last = names[-1]
            if last in ("k", "v"):
                # [G, na, B, Hk, Smax, Dh]: batch + sequence sharding
                g_, na_, B, Hk, S, Dh = shape
                b = _ok(mesh, B, baxis)
                s = _ok(mesh, S, "model")
                return P(None, None, b, None, s, None)
            if last == "ssm_conv":
                g_, nm_, B, k_, di = shape
                return P(None, None, _ok(mesh, B, baxis), None, _ok(mesh, di, "model"))
            if last == "ssm_state":
                g_, nm_, B, H, N, Pd = shape
                return P(
                    None, None, _ok(mesh, B, baxis), _ok(mesh, H, "model"), None, None
                )
        if first == "cache_len":
            return P()
        return P(*([None] * len(shape)))

    flat = jax.tree_util.tree_flatten_with_path(specs)
    out = [rule(p, l) for p, l in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], out)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
