"""Collective-overlap helpers.

Under GSPMD the collectives are compiler-inserted, so "overlap" is expressed
structurally: bucketing gradients so reduce-scatter can start before the full
backward finishes, and sharding constraints that keep partial results resident
where the next op wants them.  These helpers are used by the trainer and by
the §Perf hillclimb.
"""

from __future__ import annotations

from typing import Any, List

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["constrain", "bucketed", "psum_scatter_tree"]


def constrain(x, spec: P):
    """with_sharding_constraint that tolerates running outside a mesh."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def bucketed(tree, bucket_bytes: int = 64 << 20) -> List[List[Any]]:
    """Group leaves into ~bucket_bytes buckets (gradient-bucketing order)."""
    leaves = jax.tree.leaves(tree)
    buckets: List[List[Any]] = [[]]
    size = 0
    for l in leaves:
        b = l.size * l.dtype.itemsize
        if size + b > bucket_bytes and buckets[-1]:
            buckets.append([])
            size = 0
        buckets[-1].append(l)
        size += b
    return buckets


def psum_scatter_tree(tree, axis_name: str):
    """shard_map-side helper: reduce-scatter every leaf over ``axis_name``."""
    return jax.tree.map(
        lambda g: jax.lax.psum_scatter(g, axis_name, tiled=True), tree
    )
