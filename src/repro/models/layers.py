"""Shared building blocks: norms, initializers, MLPs.

Pure functions over parameter pytrees (dicts of jnp arrays) — no framework.
Initializers take an explicit PRNG key and return f32 params; the training
dtype (bf16 compute) is handled by callers casting activations.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "dense_mlp",
    "gated_mlp",
    "init_dense_mlp",
    "init_gated_mlp",
    "init_linear",
    "rms_norm",
    "layer_norm",
]

Params = Dict[str, jnp.ndarray]


def init_linear(key, d_in: int, d_out: int, scale: Optional[float] = None) -> jnp.ndarray:
    """Truncated-normal fan-in init (the LLaMA/PaLM convention)."""
    if scale is None:
        scale = d_in ** -0.5
    return jax.random.truncated_normal(key, -3, 3, (d_in, d_out), jnp.float32) * scale


def rms_norm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * gain).astype(dt)


def layer_norm(
    x: jnp.ndarray, gain: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * gain + bias).astype(dt)


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #


def init_gated_mlp(key, d_model: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": init_linear(k1, d_model, d_ff),  # gate proj
        "wu": init_linear(k2, d_model, d_ff),  # up proj
        "wo": init_linear(k3, d_ff, d_model, scale=d_ff ** -0.5),
    }


def gated_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU: (silu(x·wi) ⊙ x·wu)·wo — the LLaMA-family MLP."""
    h = jax.nn.silu(x @ p["wi"].astype(x.dtype)) * (x @ p["wu"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


def init_dense_mlp(key, d_model: int, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key, 2)
    return {
        "wi": init_linear(k1, d_model, d_ff),
        "wo": init_linear(k2, d_ff, d_model, scale=d_ff ** -0.5),
    }


def dense_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """GELU MLP — used by the encoder-only (HuBERT) family."""
    return jax.nn.gelu(x @ p["wi"].astype(x.dtype)) @ p["wo"].astype(x.dtype)
