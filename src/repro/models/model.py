"""Unified model: config, init, train forward/loss, prefill, decode.

One :class:`ModelConfig` describes every assigned architecture; the family
field selects the group structure (see :mod:`repro.models.transformer`).
All step functions are pure (params explicit) and jit/pjit-able; the
trainer and launcher compose them with sharding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import transformer as tf
from .layers import rms_norm, layer_norm

__all__ = ["ModelConfig", "Model"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'hybrid' | 'ssm' | 'vlm' | 'audio'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 => d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # expert hidden (granite: 512); 0 => d_ff
    moe_interleave: int = 1  # MoE every k-th layer
    shared_expert: bool = False
    capacity_factor: float = 1.25
    decode_capacity_factor: float = 2.0
    moe_dispatch: str = "einsum"  # 'einsum' | 'dense'
    moe_group_tokens: int = 4096  # GShard dispatch group size
    # --- attention ---
    rope_variant: str = "rope"  # 'rope' | 'rope2d' | 'mrope' | 'none'
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    causal: bool = True
    window: Optional[int] = None  # sliding-window span (attn layers)
    attn_block_q: int = 1024
    attn_block_k: int = 1024
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_d_head: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0  # hybrid: 1 attn sublayer per group of this size
    # --- embeddings / misc ---
    tie_embeddings: bool = True
    embed_inputs: bool = True  # False: step takes precomputed embeddings
    norm: str = "rms"  # 'rms' | 'ln'
    mlp_gated: bool = True  # False: plain 2-matrix GELU MLP (StarCoder2, encoders)
    # Cast every weight matrix to cfg.dtype ONCE at step entry (instead of at
    # each use).  Under FSDP this moves the cast BEFORE the parameter
    # all-gather, so collectives move bf16 instead of f32 — a §Perf lever.
    cast_params_at_step: bool = False
    # Pad the embedding/lm_head vocab dim to a multiple of this so the vocab
    # axis shards on the model mesh axis (odd vocabs like 49155 otherwise
    # fall back to d_model sharding, whose contraction partial-sums the FULL
    # f32 logits across the model axis).  Padded columns are masked to -inf
    # before log_softmax, so the loss is bit-identical to the unpadded model.
    pad_vocab_to_multiple: int = 0
    # ZeRO-3 "gather at use": inside each scan-body group, cast the group's
    # weights to cfg.dtype and constrain them to a TP-only sharding, forcing
    # GSPMD to all-gather bf16 weights per layer instead of partial-summing
    # f32 activations against the data-sharded weight dim (§Perf cell 2/3).
    fsdp_gather_at_layer: bool = False
    dtype: Any = jnp.bfloat16
    cache_dtype: Any = jnp.bfloat16
    remat: bool = True
    remat_policy_name: str = "nothing"  # 'nothing' | 'dots'
    scan_layers: bool = True

    # ------------------------------------------------------------------ #

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))
        if self.family in ("moe",) and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to_multiple
        if m and self.vocab_size % m:
            return self.vocab_size + (m - self.vocab_size % m)
        return self.vocab_size

    @property
    def remat_policy(self):
        if self.remat_policy_name == "dots":
            return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return None  # save nothing

    def group_spec(self) -> Tuple[Tuple[str, Optional[str]], ...]:
        """((mixer, ffn), ...) for one group."""
        fam = self.family
        if fam in ("dense", "vlm", "audio"):
            return (("attn", "mlp"),)
        if fam == "moe":
            k = max(self.moe_interleave, 1)
            return tuple(
                ("attn", "moe" if i == k - 1 else "mlp") for i in range(k)
            )
        if fam == "ssm":
            return (("mamba", None if self.d_ff == 0 else "mlp"),)
        if fam == "hybrid":
            k = self.attn_every
            attn_pos = k // 2  # attention mid-group (Jamba places it interior)
            spec = []
            for i in range(k):
                mixer = "attn" if i == attn_pos else "mamba"
                ffn = "moe" if (self.n_experts and i % 2 == 1) else "mlp"
                spec.append((mixer, ffn))
            return tuple(spec)
        raise ValueError(f"unknown family {fam}")

    @property
    def group_size(self) -> int:
        return len(self.group_spec())

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by group "
            f"size {self.group_size}"
        )
        return self.n_layers // self.group_size

    @property
    def attn_layers_per_group(self) -> int:
        return sum(1 for m, _ in self.group_spec() if m == "attn")

    @property
    def mamba_layers_per_group(self) -> int:
        return sum(1 for m, _ in self.group_spec() if m == "mamba")

    # ------------------------------------------------------------------ #
    # parameter accounting (via eval_shape: no allocation)
    # ------------------------------------------------------------------ #

    def param_shapes(self):
        return jax.eval_shape(
            lambda: Model(self).init(jax.random.PRNGKey(0), abstract=True)
        )

    def param_counts(self) -> Dict[str, float]:
        shapes = self.param_shapes()
        total = 0
        expert = 0

        def visit(path, leaf):
            nonlocal total, expert
            n = 1
            for d in leaf.shape:
                n *= d
            total += n
            keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            if any("moe" == k for k in keys) and keys[-1] in ("wi", "wu", "wo"):
                expert += n

        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for path, leaf in flat:
            visit(path, leaf)
        active = total
        if self.n_experts and self.top_k:
            active = total - expert * (1.0 - self.top_k / self.n_experts)
        return {"total": float(total), "active": float(active), "expert": float(expert)}

    def model_flops(self, kind: str, batch: int, seq: int) -> float:
        """MODEL_FLOPS per the brief: 6·N_active·D (train), 2·N_active·D
        (prefill), 2·N_active·B (decode; D = one token per sequence)."""
        n = self.param_counts()["active"]
        if kind == "train":
            return 6.0 * n * batch * seq
        if kind == "prefill":
            return 2.0 * n * batch * seq
        if kind == "decode":
            return 2.0 * n * batch
        raise ValueError(kind)


# --------------------------------------------------------------------------- #


class Model:
    """Functional wrapper: init + step functions for one config."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- init ---------------------------------------------------------- #

    def init(self, key, abstract: bool = False):
        cfg = self.cfg
        k_embed, k_stack, k_head, k_norm = jax.random.split(key, 4)
        params: Dict[str, Any] = {}
        if cfg.embed_inputs:
            params["embed"] = (
                jax.random.normal(k_embed, (cfg.padded_vocab, cfg.d_model), jnp.float32)
                * 0.02
            )
        params["blocks"] = tf.init_stack(k_stack, cfg)
        if cfg.norm == "ln":
            params["final_norm"] = {
                "g": jnp.ones((cfg.d_model,), jnp.float32),
                "b": jnp.zeros((cfg.d_model,), jnp.float32),
            }
        else:
            params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        if not cfg.tie_embeddings or not cfg.embed_inputs:
            params["lm_head"] = (
                jax.random.normal(k_head, (cfg.d_model, cfg.padded_vocab), jnp.float32)
                * 0.02
            )
        return params

    # ---- shared forward ------------------------------------------------- #

    def _positions(self, batch: int, seq: int, offset=0):
        cfg = self.cfg
        pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset  # [1, S]
        pos = jnp.broadcast_to(pos, (batch, seq))
        if cfg.rope_variant == "rope2d":
            return jnp.stack([pos, jnp.zeros_like(pos)], axis=1)  # [B, 2, S]
        if cfg.rope_variant == "mrope":
            return jnp.stack([pos, pos, pos], axis=1)  # [B, 3, S] (text stub)
        return pos

    def _embed(self, params, tokens_or_embeds):
        cfg = self.cfg
        if cfg.embed_inputs:
            return params["embed"].astype(cfg.dtype)[tokens_or_embeds]
        return tokens_or_embeds.astype(cfg.dtype)

    def _head(self, params, x):
        cfg = self.cfg
        xn = (
            layer_norm(x, params["final_norm"]["g"], params["final_norm"]["b"])
            if cfg.norm == "ln"
            else rms_norm(x, params["final_norm"])
        )
        if "lm_head" in params:
            w = params["lm_head"].astype(cfg.dtype)
        else:
            w = params["embed"].T.astype(cfg.dtype)
        logits = xn @ w  # [B, S, V_padded]
        if cfg.padded_vocab != cfg.vocab_size:
            # mask pad columns to -inf: loss/argmax identical to unpadded
            col = jnp.arange(cfg.padded_vocab)
            logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
        return logits

    def forward(self, params, tokens_or_embeds, positions=None, block_specs=None):
        cfg = self.cfg
        x = self._embed(params, tokens_or_embeds)
        B, S = x.shape[:2]
        if positions is None:
            positions = self._positions(B, S)
        x, aux, _ = tf.apply_stack(
            params["blocks"], x, positions, cfg, block_specs=block_specs
        )
        return self._head(params, x), aux

    # ---- training loss --------------------------------------------------- #

    def loss(self, params, batch, aux_weight: float = 0.01, block_specs=None):
        """batch: {'tokens' | 'embeds', 'labels' [B,S] (-1 = masked)}."""
        inp = batch["tokens"] if self.cfg.embed_inputs else batch["embeds"]
        logits, aux = self.forward(
            params, inp, batch.get("positions"), block_specs=block_specs
        )
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        valid = labels >= 0
        safe = jnp.where(valid, labels, 0)
        ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        n = jnp.maximum(valid.sum(), 1)
        ce = -(ll * valid).sum() / n
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}

    # ---- serving --------------------------------------------------------- #

    def prefill(self, params, tokens_or_embeds, pad_to: Optional[int] = None):
        """Returns (last_logits [B,V], caches, cache_len)."""
        cfg = self.cfg
        x = self._embed(params, tokens_or_embeds)
        B, S = x.shape[:2]
        positions = self._positions(B, S)
        x, _, caches = tf.apply_stack(
            params["blocks"], x, positions, cfg,
            collect_cache=True, cache_pad_to=pad_to or S,
        )
        logits = self._head(params, x[:, -1:, :])[:, 0]
        return logits, caches, jnp.asarray(S, jnp.int32)

    def init_caches(self, batch: int, s_max: int):
        """Zero caches for decode-from-scratch (dry-run decode shapes)."""
        cfg = self.cfg
        cache: Dict[str, Any] = {}
        na, nm = cfg.attn_layers_per_group, cfg.mamba_layers_per_group
        G = cfg.n_groups
        if na:
            shape = (G, na, batch, cfg.n_kv_heads, s_max, cfg.d_head)
            cache["kv"] = {
                "k": jnp.zeros(shape, cfg.cache_dtype),
                "v": jnp.zeros(shape, cfg.cache_dtype),
            }
        if nm:
            di = cfg.ssm_heads * cfg.ssm_d_head
            cache["ssm_conv"] = jnp.zeros((G, nm, batch, 3, di), jnp.float32)
            cache["ssm_state"] = jnp.zeros(
                (G, nm, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_d_head),
                jnp.float32,
            )
        return cache

    def decode_step(self, params, caches, token_or_embed, cache_len):
        """One token for every sequence; returns (logits [B,V], new_caches)."""
        cfg = self.cfg
        x = self._embed(params, token_or_embed)  # [B, 1, D]
        B = x.shape[0]
        positions = self._positions(B, 1, offset=cache_len)
        x, new_caches = tf.decode_stack(
            params["blocks"], x, positions, caches, cache_len, cfg
        )
        logits = self._head(params, x)[:, 0]
        return logits, new_caches
