"""GQA attention with RoPE variants, qk-norm, KV cache, and a
memory-efficient pure-jnp flash path.

Why a jnp flash path exists alongside the Pallas kernel: the multi-pod
dry-run lowers for the CPU host platform where Pallas TPU kernels cannot
lower, and GSPMD partitions plain-jnp code best.  ``chunked_attention`` is
an online-softmax double loop (lax.scan over q-blocks and kv-blocks) whose
peak live buffer is [B, H, bq, bk] — the jnp twin of the Pallas kernel's
VMEM tiling, and the only way a 32k-token prefill fits at all.

RoPE variants (per assigned architectures):
  * 'rope'    — standard 1d rotary (Mistral/StarCoder2/Qwen3/Jamba/Granite)
  * 'rope2d'  — ChatGLM-style: rotary over the first half of head dim on
                stream-0 positions, second half on stream-1 positions
  * 'mrope'   — Qwen2-VL M-RoPE: head dim split into 3 sections
                (temporal/height/width), one position stream each
  * 'none'    — HuBERT (encoder uses learned/conv positions upstream)
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, rms_norm

__all__ = [
    "init_attention",
    "attention_block",
    "decode_attention_block",
    "chunked_attention",
    "rope_frequencies",
    "apply_rope",
]

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #


def rope_frequencies(d: int, theta: float = 10_000.0) -> jnp.ndarray:
    """Inverse frequencies for a rotary span of ``d`` dims (d even)."""
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def _rotate(x: jnp.ndarray, pos: jnp.ndarray, inv_freq: jnp.ndarray) -> jnp.ndarray:
    """x [..., S, d_span] rotated by pos [..., S] (broadcastable)."""
    ang = pos[..., None].astype(jnp.float32) * inv_freq  # [..., S, d/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(
    x: jnp.ndarray,  # [B, H, S, D]
    positions: jnp.ndarray,  # [B, S] ('rope') or [B, n_streams, S]
    variant: str = "rope",
    theta: float = 10_000.0,
) -> jnp.ndarray:
    B, H, S, D = x.shape
    if variant == "none":
        return x
    if variant == "rope":
        pos = positions if positions.ndim == 2 else positions[:, 0]
        inv = rope_frequencies(D, theta)
        return _rotate(x, pos[:, None, :], inv)
    if variant == "rope2d":
        # ChatGLM: two independent rotary halves on two position streams
        assert positions.ndim == 3 and positions.shape[1] >= 2
        half = D // 2
        inv = rope_frequencies(half, theta)
        a = _rotate(x[..., :half], positions[:, 0][:, None, :], inv)
        b = _rotate(x[..., half:], positions[:, 1][:, None, :], inv)
        return jnp.concatenate([a, b], axis=-1)
    if variant == "mrope":
        # Qwen2-VL: 3 sections (t, h, w); section sizes 2:1:1 of the head dim
        assert positions.ndim == 3 and positions.shape[1] >= 3
        s_t = D // 2
        s_h = D // 4
        s_w = D - s_t - s_h
        parts = []
        off = 0
        for span, stream in ((s_t, 0), (s_h, 1), (s_w, 2)):
            inv = rope_frequencies(span, theta)
            parts.append(
                _rotate(x[..., off : off + span], positions[:, stream][:, None, :], inv)
            )
            off += span
        return jnp.concatenate(parts, axis=-1)
    raise ValueError(f"unknown rope variant {variant!r}")


# --------------------------------------------------------------------------- #
# Memory-efficient attention (pure jnp, GSPMD-friendly)
# --------------------------------------------------------------------------- #


def chunked_attention(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,  # [B, Hk, Sk, D]
    v: jnp.ndarray,  # [B, Hk, Sk, D]
    causal: bool = True,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 1024,
    block_k: int = 1024,
    window: Optional[int] = None,  # sliding-window attention span
) -> jnp.ndarray:
    """Online-softmax attention, peak live buffer [B, H, bq, bk]."""
    B, H, Sq, D = q.shape
    _, Hk, Sk, _ = k.shape
    g = H // Hk
    if scale is None:
        scale = D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad S to block multiples (masked out below)
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    nq, nk = qp.shape[2] // block_q, kp.shape[2] // block_k

    # fold GQA: [B, Hk, g, S, D]
    qg = qp.reshape(B, Hk, g, qp.shape[2], D)
    kb = kp.reshape(B, Hk, nk, block_k, D)
    vb = vp.reshape(B, Hk, nk, block_k, D)

    def q_block(qi, qtile):  # qtile [B, Hk, g, bq, D]
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, ktile, vtile = inp  # [B, Hk, bk, D]
            k_pos = ki * block_k + jnp.arange(block_k)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk",
                qtile.astype(jnp.float32),
                ktile.astype(jnp.float32),
            ) * scale
            mask = k_pos[None, :] < Sk  # padded keys
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])
            if window is not None:
                mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vtile.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hk, g, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hk, g, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hk, g, block_q, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0)),
        )
        denom = jnp.where(l > 0, l, 1.0)
        return (acc / denom[..., None]).astype(q.dtype)

    if nq == 1:
        out = q_block(0, qg)
    else:
        qtiles = jnp.moveaxis(
            qg.reshape(B, Hk, g, nq, block_q, D), 3, 0
        )  # [nq, B, Hk, g, bq, D]
        out = jax.lax.map(lambda i_t: q_block(i_t[0], i_t[1]), (jnp.arange(nq), qtiles))
        out = jnp.moveaxis(out, 0, 3).reshape(B, Hk, g, nq * block_q, D)
    out = out.reshape(B, H, -1, D)
    return out[:, :, :Sq]


# --------------------------------------------------------------------------- #
# Attention block (projections + rope + cache)
# --------------------------------------------------------------------------- #


def init_attention(
    key, d_model: int, n_heads: int, n_kv_heads: int, d_head: int, qk_norm: bool = False
) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": init_linear(k1, d_model, n_heads * d_head),
        "wk": init_linear(k2, d_model, n_kv_heads * d_head),
        "wv": init_linear(k3, d_model, n_kv_heads * d_head),
        "wo": init_linear(k4, n_heads * d_head, d_model, scale=(n_heads * d_head) ** -0.5),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((d_head,), jnp.float32)
        p["k_norm"] = jnp.ones((d_head,), jnp.float32)
    return p


def _project_qkv(p: Params, x: jnp.ndarray, n_heads, n_kv_heads, d_head, positions, rope_variant, qk_norm, theta, q_offset_positions=None):
    B, S, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, n_heads, d_head).transpose(0, 2, 1, 3)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, n_kv_heads, d_head).transpose(0, 2, 1, 3)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, n_kv_heads, d_head).transpose(0, 2, 1, 3)
    if qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, rope_variant, theta)
    k = apply_rope(k, positions, rope_variant, theta)
    return q, k, v


def attention_block(
    p: Params,
    x: jnp.ndarray,  # [B, S, d_model]
    positions: jnp.ndarray,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    causal: bool = True,
    rope_variant: str = "rope",
    qk_norm: bool = False,
    theta: float = 10_000.0,
    window: Optional[int] = None,
    block_q: int = 1024,
    block_k: int = 1024,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, d_head, positions, rope_variant, qk_norm, theta)
    o = chunked_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k, window=window
    )
    o = o.transpose(0, 2, 1, 3).reshape(B, S, n_heads * d_head)
    return o @ p["wo"].astype(x.dtype)


def decode_attention_block(
    p: Params,
    x: jnp.ndarray,  # [B, 1, d_model]
    positions: jnp.ndarray,  # [B, 1] (or [B, streams, 1])
    kv_cache: Tuple[jnp.ndarray, jnp.ndarray],  # ([B, Hk, Smax, D], ...)
    cache_len,  # scalar int32: current cache fill
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    rope_variant: str = "rope",
    qk_norm: bool = False,
    theta: float = 10_000.0,
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Single-token decode with KV-cache update; returns (out, new_cache)."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, d_head, positions, rope_variant, qk_norm, theta)
    ck, cv = kv_cache
    Smax = ck.shape[2]
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, cache_len, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, cache_len, 0))
    g = n_heads // n_kv_heads
    qg = q.reshape(B, n_kv_heads, g, 1, d_head).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, ck.astype(jnp.float32)) * (d_head ** -0.5)
    kpos = jnp.arange(Smax)
    mask = kpos[None, :] <= cache_len
    if window is not None:
        mask = mask & (kpos[None, :] > cache_len - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", w, cv.astype(jnp.float32))
    o = o.reshape(B, n_heads, 1, d_head).transpose(0, 2, 1, 3).reshape(B, 1, n_heads * d_head)
    return (o.astype(x.dtype) @ p["wo"].astype(x.dtype)), (ck, cv)
