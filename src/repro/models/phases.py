"""Memory-program synthesis: ModelConfig -> (RegionMap, [Phase]).

This is the allocation half of the Tracer: every logical tensor class of a
step is registered as a region (the eBPF range-map analogue), and each layer
group becomes a Phase with its byte-accurate access list.  The CXLMemSim
attach path then prices any placement policy / topology against the step.

Accounting (per group, per step):
  train:   fwd reads W, writes A; bwd reads W + A, writes G(=W bytes);
           optimizer reads G + M (2 moments) + P, writes M + P.
  prefill: reads W, writes A + KV.
  decode:  reads W + KV(cache_len·kv_bytes_per_tok) + states, writes 1 token KV.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core.events import RegionMap
from repro.core.tracer import Access, Phase

__all__ = ["build_regions_and_phases", "group_param_bytes"]


def _bytes_of(n_params: float, dtype_bytes: int = 4) -> float:
    return n_params * dtype_bytes


def group_param_bytes(cfg) -> float:
    """Parameters of one group (from the analytic counts)."""
    counts = cfg.param_counts()
    # embed (+head) params
    embed = cfg.vocab_size * cfg.d_model * (1 if cfg.embed_inputs else 0)
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        embed += cfg.d_model * cfg.vocab_size
    per_group = (counts["total"] - embed - cfg.d_model) / max(cfg.n_groups, 1)
    return max(per_group, 0.0)


def build_regions_and_phases(
    cfg,
    kind: str,  # 'train' | 'prefill' | 'decode'
    batch: int,
    seq: int,
    param_dtype_bytes: int = 4,
    act_dtype_bytes: int = 4,
    cache_len: int = 0,
) -> Tuple[RegionMap, List[Phase]]:
    regions = RegionMap()
    G = cfg.n_groups
    D = cfg.d_model
    tokens = batch * (seq if kind != "decode" else 1)

    pg = group_param_bytes(cfg) * param_dtype_bytes
    embed_bytes = cfg.vocab_size * D * param_dtype_bytes
    act_bytes = tokens * D * act_dtype_bytes  # residual stream per group
    kv_per_tok = (
        2 * cfg.n_kv_heads * cfg.d_head * cfg.attn_layers_per_group * act_dtype_bytes
    )

    if cfg.embed_inputs:
        regions.alloc("embed", int(embed_bytes), "param")
    for g in range(G):
        regions.alloc(f"block{g}.w", int(pg), "param")
        regions.alloc(f"block{g}.act", int(act_bytes), "activation")
        if kind == "train":
            regions.alloc(f"block{g}.grad", int(pg), "grad")
            regions.alloc(f"block{g}.opt", int(2 * pg), "opt_state")
        if kind in ("prefill", "decode") and kv_per_tok:
            cache_tokens = batch * max(seq, cache_len)
            regions.alloc(
                f"block{g}.kv", int(cache_tokens * kv_per_tok), "kvcache"
            )
    if kind == "train":
        regions.alloc("logits", int(tokens * cfg.vocab_size * act_dtype_bytes), "activation")

    # per-group model FLOPs (6·n·tokens train, 2·n·tokens inference)
    n_active_group = cfg.param_counts()["active"] / max(G, 1)
    mult = 6.0 if kind == "train" else 2.0
    flops_g = mult * n_active_group * tokens

    phases: List[Phase] = []
    if cfg.embed_inputs:
        phases.append(
            Phase(
                "embed",
                flops=2.0 * tokens * D,
                accesses=(
                    Access("embed", embed_bytes),
                    *(() if kind == "decode" else ()),
                ),
            )
        )
    for g in range(G):
        acc = [Access(f"block{g}.w", pg)]
        if kind == "train":
            acc += [
                Access(f"block{g}.act", act_bytes, is_write=True),
                Access(f"block{g}.act", act_bytes),  # bwd re-read
                Access(f"block{g}.grad", pg, is_write=True),
            ]
        elif kind == "prefill":
            acc += [
                Access(f"block{g}.act", act_bytes, is_write=True),
                Access(f"block{g}.kv", tokens * kv_per_tok, is_write=True),
            ]
        else:  # decode
            acc += [
                Access(f"block{g}.act", act_bytes, is_write=True),
                Access(f"block{g}.kv", batch * max(cache_len, seq) * kv_per_tok),
                Access(f"block{g}.kv", batch * kv_per_tok, is_write=True),
            ]
        phases.append(Phase(f"block{g}", flops=flops_g, accesses=tuple(acc)))

    if kind == "train":
        lb = tokens * cfg.vocab_size * act_dtype_bytes
        phases.append(
            Phase(
                "loss",
                flops=2.0 * tokens * D * cfg.vocab_size,
                accesses=(Access("logits", lb, is_write=True), Access("logits", lb)),
            )
        )
        opt_acc = []
        for g in range(G):
            opt_acc += [
                Access(f"block{g}.grad", pg),
                Access(f"block{g}.opt", 2 * pg),
                Access(f"block{g}.opt", 2 * pg, is_write=True),
                Access(f"block{g}.w", pg, is_write=True),
            ]
        phases.append(Phase("optimizer", flops=0.0, accesses=tuple(opt_acc)))
    return regions, phases
