"""Block assembly: per-family layer groups + scan-over-layers.

Every architecture is expressed as a stack of identical **groups** so the
whole depth is a single ``lax.scan`` over stacked parameters (one group
compiles once — essential for 88-layer models on a single-core build host,
and the idiomatic JAX structure for remat + pipeline-friendly HLO).

Group composition per family (cfg.group_spec()):

  dense   1 group  = [attn + mlp]                        × n_layers
  moe     1 group  = [attn+mlp] × (interleave−1) + [attn+moe]
  hybrid  1 group  = attn_every sublayers, one of them attention, the rest
          Mamba2; FFNs alternate dense/MoE (Jamba's 1:7 + MoE-every-2)
  ssm     1 group  = [mamba2]                             × n_layers
  vlm     = dense (M-RoPE positions)
  audio   = dense non-causal encoder (LN + GELU MLP)
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba2 as m2
from . import moe as moe_mod
from .layers import (
    dense_mlp,
    gated_mlp,
    init_dense_mlp,
    init_gated_mlp,
    layer_norm,
    rms_norm,
)

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# group init
# --------------------------------------------------------------------------- #


def init_group(key, cfg) -> Params:
    """Parameters for ONE group (to be stacked over cfg.n_groups)."""
    p: Params = {}
    spec = cfg.group_spec()
    keys = jax.random.split(key, len(spec))
    for i, (mixer, ffn) in enumerate(spec):
        sk = jax.random.split(keys[i], 4)
        sub: Params = {}
        if cfg.norm == "ln":
            sub["norm1"] = {"g": jnp.ones((cfg.d_model,), jnp.float32), "b": jnp.zeros((cfg.d_model,), jnp.float32)}
        else:
            sub["norm1"] = jnp.ones((cfg.d_model,), jnp.float32)
        if mixer == "attn":
            sub["attn"] = attn_mod.init_attention(
                sk[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.qk_norm
            )
        elif mixer == "mamba":
            sub["mamba"] = m2.init_mamba2(
                sk[0], cfg.d_model, cfg.ssm_heads, cfg.ssm_d_head, cfg.ssm_state
            )
        else:
            raise ValueError(mixer)
        if ffn is not None:
            if cfg.norm == "ln":
                sub["norm2"] = {"g": jnp.ones((cfg.d_model,), jnp.float32), "b": jnp.zeros((cfg.d_model,), jnp.float32)}
            else:
                sub["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
            if ffn == "mlp":
                if cfg.norm == "ln" or not cfg.mlp_gated:  # plain GELU MLP
                    sub["mlp"] = init_dense_mlp(sk[1], cfg.d_model, cfg.d_ff)
                else:
                    sub["mlp"] = init_gated_mlp(sk[1], cfg.d_model, cfg.d_ff)
            elif ffn == "moe":
                sub["moe"] = moe_mod.init_moe(
                    sk[1], cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts,
                    shared_expert=cfg.shared_expert,
                )
            else:
                raise ValueError(ffn)
        p[f"sub{i}"] = sub
    return p


def _norm(cfg, x, np_):
    if cfg.norm == "ln":
        return layer_norm(x, np_["g"], np_["b"])
    return rms_norm(x, np_)


# --------------------------------------------------------------------------- #
# group forward (train / prefill)
# --------------------------------------------------------------------------- #


def apply_group(
    p: Params,
    x: jnp.ndarray,  # [B, S, D]
    positions: jnp.ndarray,
    cfg,
    collect_cache: bool = False,
    cache_pad_to: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict[str, Any]]]:
    """Returns (x, aux_loss, group_cache) for one group.

    ``group_cache`` (prefill only) is already in decode format:
      {'kv': {'k': [n_attn, B, Hk, Smax, D], 'v': ...},
       'ssm_conv': [n_mamba, B, K-1, di], 'ssm_state': [n_mamba, B, H, N, P]}
    K/V are padded on the sequence axis to ``cache_pad_to`` (decode budget).
    """
    aux = jnp.zeros((), jnp.float32)
    kv_k: List = []
    kv_v: List = []
    ssm_conv: List = []
    ssm_state: List = []
    for i, (mixer, ffn) in enumerate(cfg.group_spec()):
        sub = p[f"sub{i}"]
        h = _norm(cfg, x, sub["norm1"])
        if mixer == "attn":
            if collect_cache:
                # prefill: also materialize this sublayer's K/V for the cache
                B, S, _ = h.shape
                q, k, v = attn_mod._project_qkv(
                    sub["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                    positions, cfg.rope_variant, cfg.qk_norm, cfg.rope_theta,
                )
                o = attn_mod.chunked_attention(
                    q, k, v, causal=cfg.causal,
                    block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
                    window=cfg.window,
                )
                o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
                mix = o @ sub["attn"]["wo"].astype(h.dtype)
                pad = (cache_pad_to or S) - S
                if pad > 0:
                    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
                kv_k.append(k.astype(cfg.cache_dtype))
                kv_v.append(v.astype(cfg.cache_dtype))
            else:
                mix = attn_mod.attention_block(
                    sub["attn"], h, positions,
                    cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                    causal=cfg.causal, rope_variant=cfg.rope_variant,
                    qk_norm=cfg.qk_norm, theta=cfg.rope_theta, window=cfg.window,
                    block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
                )
        else:  # mamba
            if collect_cache:
                mix, mcache = m2.mamba2_prefill(
                    sub["mamba"], h, cfg.ssm_heads, cfg.ssm_d_head, cfg.ssm_state,
                    chunk=cfg.ssm_chunk,
                )
                ssm_conv.append(mcache["conv"])
                ssm_state.append(mcache["ssm"])
            else:
                mix = m2.mamba2_block(
                    sub["mamba"], h, cfg.ssm_heads, cfg.ssm_d_head, cfg.ssm_state,
                    chunk=cfg.ssm_chunk,
                )
        x = x + mix
        if ffn is not None:
            h = _norm(cfg, x, sub["norm2"])
            if ffn == "mlp":
                out = (
                    dense_mlp(sub["mlp"], h) if (cfg.norm == "ln" or not cfg.mlp_gated) else gated_mlp(sub["mlp"], h)
                )
            else:
                out, a = moe_mod.moe_block(
                    sub["moe"], h, cfg.top_k,
                    capacity_factor=cfg.capacity_factor, dispatch=cfg.moe_dispatch,
                    group_tokens=cfg.moe_group_tokens,
                )
                aux = aux + a
            x = x + out
    cache = None
    if collect_cache:
        cache = {}
        if kv_k:
            cache["kv"] = {"k": jnp.stack(kv_k), "v": jnp.stack(kv_v)}
        if ssm_conv:
            cache["ssm_conv"] = jnp.stack(ssm_conv)
            cache["ssm_state"] = jnp.stack(ssm_state)
    return x, aux, cache


# --------------------------------------------------------------------------- #
# group decode (single token, cache update)
# --------------------------------------------------------------------------- #


def decode_group(
    p: Params,
    x: jnp.ndarray,  # [B, 1, D]
    positions: jnp.ndarray,
    cache: Dict[str, Any],  # this group's cache slice
    cache_len,
    cfg,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    new_cache: Dict[str, Any] = {}
    ai = 0
    mi = 0
    for i, (mixer, ffn) in enumerate(cfg.group_spec()):
        sub = p[f"sub{i}"]
        h = _norm(cfg, x, sub["norm1"])
        if mixer == "attn":
            kv = (cache["kv"]["k"][ai], cache["kv"]["v"][ai])
            mix, kv_new = attn_mod.decode_attention_block(
                sub["attn"], h, positions, kv, cache_len,
                cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                rope_variant=cfg.rope_variant, qk_norm=cfg.qk_norm,
                theta=cfg.rope_theta, window=cfg.window,
            )
            new_cache.setdefault("kv", {"k": [], "v": []})
            new_cache["kv"]["k"].append(kv_new[0])
            new_cache["kv"]["v"].append(kv_new[1])
            ai += 1
        else:
            mc = {"conv": cache["ssm_conv"][mi], "ssm": cache["ssm_state"][mi]}
            mix, mc_new = m2.mamba2_decode(
                sub["mamba"], h, mc, cfg.ssm_heads, cfg.ssm_d_head, cfg.ssm_state
            )
            new_cache.setdefault("ssm_conv", []).append(mc_new["conv"])
            new_cache.setdefault("ssm_state", []).append(mc_new["ssm"])
            mi += 1
        x = x + mix
        if ffn is not None:
            h = _norm(cfg, x, sub["norm2"])
            if ffn == "mlp":
                out = (
                    dense_mlp(sub["mlp"], h) if (cfg.norm == "ln" or not cfg.mlp_gated) else gated_mlp(sub["mlp"], h)
                )
            else:
                out, _ = moe_mod.moe_block(
                    sub["moe"], h, cfg.top_k,
                    capacity_factor=cfg.decode_capacity_factor, dispatch=cfg.moe_dispatch,
                    group_tokens=cfg.moe_group_tokens,
                )
            x = x + out
    # restack lists into arrays
    if "kv" in new_cache:
        new_cache["kv"] = {
            "k": jnp.stack(new_cache["kv"]["k"]),
            "v": jnp.stack(new_cache["kv"]["v"]),
        }
    if "ssm_conv" in new_cache:
        new_cache["ssm_conv"] = jnp.stack(new_cache["ssm_conv"])
        new_cache["ssm_state"] = jnp.stack(new_cache["ssm_state"])
    return x, new_cache


# --------------------------------------------------------------------------- #
# full stacks
# --------------------------------------------------------------------------- #


def init_stack(key, cfg) -> Params:
    """Stacked group params: every leaf gains a leading n_groups dim."""
    keys = jax.random.split(key, cfg.n_groups)
    if cfg.scan_layers:
        return jax.vmap(lambda k: init_group(k, cfg))(keys)
    return [init_group(k, cfg) for k in keys]


def apply_stack(
    stack: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg,
    collect_cache: bool = False,
    cache_pad_to: Optional[int] = None,
    block_specs=None,
):
    """Scan over groups. Returns (x, aux, stacked_caches).

    ``block_specs``: optional PartitionSpec pytree for ONE group (TP-only,
    no data axis).  With cfg.fsdp_gather_at_layer the scan body casts the
    group's weights to cfg.dtype and constrains them to these specs — the
    explicit ZeRO-3 gather-at-use.
    """

    def maybe_gather(gp):
        if not (cfg.fsdp_gather_at_layer and block_specs is not None):
            return gp
        from repro.distributed.collectives import constrain

        def one(w, spec):
            w = w.astype(cfg.dtype) if w.ndim >= 2 else w
            return constrain(w, spec)

        return jax.tree.map(
            one, gp, block_specs,
            is_leaf=lambda v: not isinstance(v, dict),
        )

    def body(carry, gp):
        h, aux = carry
        h, a, cache = apply_group(
            maybe_gather(gp), h, positions, cfg,
            collect_cache=collect_cache, cache_pad_to=cache_pad_to,
        )
        return (h, aux + a), cache

    if cfg.remat:
        body = jax.checkpoint(body, policy=cfg.remat_policy)

    if cfg.scan_layers:
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack)
    else:
        aux = jnp.zeros((), jnp.float32)
        outs = []
        for gp in stack:
            (x, aux), c = body((x, aux), gp)
            outs.append(c)
        caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            if outs and outs[0] is not None
            else None
        )
    return x, aux, caches


def decode_stack(stack: Params, x, positions, caches, cache_len, cfg):
    """Scan decode over groups with per-group cache slices."""

    def body(h, inp):
        gp, cache = inp
        h, new_cache = decode_group(gp, h, positions, cache, cache_len, cfg)
        return h, new_cache

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (stack, caches))
    else:
        new_list = []
        for i, gp in enumerate(stack):
            c = jax.tree.map(lambda a: a[i], caches)
            x, nc = body(x, (gp, c))
            new_list.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    return x, new_caches
