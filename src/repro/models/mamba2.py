"""Mamba2 block (SSD mixer) — attention-free sequence mixing.

Structure (Dao & Gu 2024, simplified to 1 B/C group):

  in_proj -> [x (H·P), z (H·P), B (N), C (N), dt (H)]
  depthwise causal conv1d (kernel 4) on x
  SSD scan (kernels/ssd_scan.py, or the chunked jnp ref under GSPMD)
  gate: y ⊙ silu(z); RMSNorm; out_proj

Decode keeps two caches per layer: the conv tail [B, K-1, H·P] and the SSM
state [B, H, N, P]; a decode step is O(1) in sequence length, which is why
the ``long_500k`` shape runs on this family.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .layers import init_linear, rms_norm

__all__ = [
    "init_mamba2",
    "mamba2_block",
    "mamba2_decode",
    "mamba2_prefill",
    "init_mamba2_cache",
]

Params = Dict[str, jnp.ndarray]

CONV_K = 4


def init_mamba2(key, d_model: int, n_heads: int, d_head: int, d_state: int) -> Params:
    di = n_heads * d_head  # inner width
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_linear(ks[0], d_model, 2 * di + 2 * d_state + n_heads),
        "conv_w": jax.random.truncated_normal(ks[1], -3, 3, (CONV_K, di), jnp.float32) * 0.3,
        "A_log": jnp.log(jnp.linspace(1.0, 8.0, n_heads).astype(jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),  # skip connection
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(ks[2], di, d_model, scale=di ** -0.5),
    }


def _pad_seq(chunk: int, *arrays):
    """Pad the seq axis (axis 1) to a chunk multiple.  Zero-padding is exact
    for the SSD recurrence: padded steps have dt=0 (decay 1, zero input), so
    the state is unchanged and padded outputs are sliced away."""
    S = arrays[0].shape[1]
    pad = (-S) % chunk
    if pad == 0:
        return S, arrays
    out = tuple(
        jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2)) for a in arrays
    )
    return S, out


def _split_proj(p, u, n_heads, d_head, d_state):
    di = n_heads * d_head
    z = u[..., :di]
    x = u[..., di : 2 * di]
    Bm = u[..., 2 * di : 2 * di + d_state]
    Cm = u[..., 2 * di + d_state : 2 * di + 2 * d_state]
    dt = jax.nn.softplus(
        u[..., 2 * di + 2 * d_state :].astype(jnp.float32) + p["dt_bias"]
    )
    return z, x, Bm, Cm, dt


def mamba2_block(
    p: Params,
    h: jnp.ndarray,  # [B, S, d_model]
    n_heads: int,
    d_head: int,
    d_state: int,
    chunk: int = 128,
) -> jnp.ndarray:
    B, S, _ = h.shape
    di = n_heads * d_head
    u = h @ p["in_proj"].astype(h.dtype)
    z, x, Bm, Cm, dt = _split_proj(p, u, n_heads, d_head, d_state)

    # depthwise causal conv (kernel CONV_K) over sequence
    xp = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    conv = sum(
        xp[:, i : i + S, :] * p["conv_w"][i].astype(h.dtype) for i in range(CONV_K)
    )
    x = jax.nn.silu(conv)

    A = -jnp.exp(p["A_log"])  # [H] negative decay rates
    xh = x.reshape(B, S, n_heads, d_head)
    _, (xh_p, dt_p, B_p, C_p) = _pad_seq(
        chunk, xh, dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    )
    y = ops.ssd(xh_p, dt_p, A, B_p, C_p, chunk=chunk)[:, :S]
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)  # skip
    y = y.reshape(B, S, di)

    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"])
    return y @ p["out_proj"].astype(h.dtype)


def _final_state(xh, dt, A, Bm, chunk: int = 128):
    """SSM state after the full sequence (for prefill -> decode handoff).

    h_final = Σ_s dt_s·exp(Σ_{u>s} a_u)·B_s ⊗ x_s, computed chunk-blocked:
    per-chunk partial states folded left-to-right with chunk decays.
    """
    B, S0, H, P = xh.shape
    chunk = min(chunk, S0)
    _, (xh, dt, Bm) = _pad_seq(chunk, xh, dt, Bm)
    S = xh.shape[1]
    N = Bm.shape[-1]
    C = S // chunk
    f32 = jnp.float32
    x_ = xh.astype(f32).reshape(B, C, chunk, H, P)
    dt_ = dt.astype(f32).reshape(B, C, chunk, H)
    B_ = Bm.astype(f32).reshape(B, C, chunk, N)
    a = A.astype(f32)[None, None, None, :] * dt_
    acum = jnp.cumsum(a, axis=2)
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)
    S_c = jnp.einsum("bcsn,bcsh,bcshp->bchnp", B_, dt_ * decay_to_end, x_)
    chunk_decay = jnp.exp(acum[:, :, -1, :])  # [B, C, H]

    def fold(h, inp):
        s_c, dec = inp
        return dec[..., None, None] * h + s_c, None

    h0 = jnp.zeros((B, H, N, P), f32)
    h, _ = jax.lax.scan(
        fold, h0, (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    return h  # [B, H, N, P]


def mamba2_prefill(
    p: Params,
    h: jnp.ndarray,  # [B, S, d_model]
    n_heads: int,
    d_head: int,
    d_state: int,
    chunk: int = 128,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward that also returns the decode cache."""
    B, S, _ = h.shape
    di = n_heads * d_head
    u = h @ p["in_proj"].astype(h.dtype)
    z, x, Bm, Cm, dt = _split_proj(p, u, n_heads, d_head, d_state)

    conv_tail = x[:, S - (CONV_K - 1) :, :]  # pre-conv stream tail
    xp = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    conv = sum(
        xp[:, i : i + S, :] * p["conv_w"][i].astype(h.dtype) for i in range(CONV_K)
    )
    x = jax.nn.silu(conv)

    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B, S, n_heads, d_head)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    _, (xh_p, dt_p, B_p, C_p) = _pad_seq(chunk, xh, dt, Bf, Cf)
    y = ops.ssd(xh_p, dt_p, A, B_p, C_p, chunk=chunk)[:, :S]
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"])
    out = y @ p["out_proj"].astype(h.dtype)
    cache = {
        "conv": conv_tail.astype(jnp.float32),
        "ssm": _final_state(xh, dt, A, Bf, chunk=chunk),
    }
    return out, cache


def init_mamba2_cache(batch: int, n_heads: int, d_head: int, d_state: int, dtype=jnp.float32):
    di = n_heads * d_head
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, di), dtype),
        "ssm": jnp.zeros((batch, n_heads, d_state, d_head), jnp.float32),
    }


def mamba2_decode(
    p: Params,
    h: jnp.ndarray,  # [B, 1, d_model]
    cache: Dict[str, jnp.ndarray],
    n_heads: int,
    d_head: int,
    d_state: int,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B = h.shape[0]
    di = n_heads * d_head
    u = h @ p["in_proj"].astype(h.dtype)
    z, x, Bm, Cm, dt = _split_proj(p, u, n_heads, d_head, d_state)
    x = x[:, 0]  # [B, di]
    z = z[:, 0]
    Bm = Bm[:, 0].astype(jnp.float32)  # [B, N]
    Cm = Cm[:, 0].astype(jnp.float32)
    dt = dt[:, 0]  # [B, H]

    # conv cache: window = [tail, x]
    win = jnp.concatenate([cache["conv"], x[:, None, :].astype(cache["conv"].dtype)], axis=1)
    conv = sum(win[:, i, :] * p["conv_w"][i].astype(h.dtype) for i in range(CONV_K))
    xc = jax.nn.silu(conv)  # [B, di]
    new_conv = win[:, 1:, :]

    A = -jnp.exp(p["A_log"])  # [H]
    xh = xc.reshape(B, n_heads, d_head).astype(jnp.float32)
    dec = jnp.exp(A[None, :] * dt)  # [B, H]
    s = cache["ssm"]  # [B, H, N, P]
    s = dec[..., None, None] * s + dt[..., None, None] * (
        Bm[:, None, :, None] * xh[:, :, None, :]
    )
    y = jnp.einsum("bn,bhnp->bhp", Cm, s)  # [B, H, P]
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, di).astype(h.dtype)

    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"])
    out = (y @ p["out_proj"].astype(h.dtype)).reshape(B, 1, -1)
    return out, {"conv": new_conv, "ssm": s}
