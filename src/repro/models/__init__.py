"""Model zoo: every assigned architecture as a ModelConfig + pure step fns."""

from .model import Model, ModelConfig

__all__ = ["Model", "ModelConfig"]
