"""Mixture-of-Experts layer: top-k router + capacity-based einsum dispatch.

Dispatch is GShard-style one-hot einsum with a capacity factor — fully
GSPMD-partitionable: experts shard on the 'model' axis (expert parallelism),
tokens on ('pod','data').  The dispatch einsum's FLOPs are real overhead and
show up in the roofline's useful-FLOPs ratio; replacing it with sort-based
dispatch is one of the §Perf hillclimb levers.

Router jitter/aux-loss: load-balance auxiliary loss (Switch §2.2) is
returned so the trainer can add ``aux_weight * aux``.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear

__all__ = ["init_moe", "moe_block"]

Params = Dict[str, jnp.ndarray]


def init_moe(
    key,
    d_model: int,
    d_ff: int,
    n_experts: int,
    shared_expert: bool = False,
) -> Params:
    ks = jax.random.split(key, 5)
    p = {
        "router": init_linear(ks[0], d_model, n_experts),
        # stacked expert weights: [E, d_model, d_ff] / [E, d_ff, d_model]
        "wi": jax.random.truncated_normal(ks[1], -3, 3, (n_experts, d_model, d_ff), jnp.float32) * d_model ** -0.5,
        "wu": jax.random.truncated_normal(ks[2], -3, 3, (n_experts, d_model, d_ff), jnp.float32) * d_model ** -0.5,
        "wo": jax.random.truncated_normal(ks[3], -3, 3, (n_experts, d_ff, d_model), jnp.float32) * d_ff ** -0.5,
    }
    if shared_expert:
        kk = jax.random.split(ks[4], 3)
        p["shared_wi"] = init_linear(kk[0], d_model, d_ff)
        p["shared_wu"] = init_linear(kk[1], d_model, d_ff)
        p["shared_wo"] = init_linear(kk[2], d_ff, d_model, scale=d_ff ** -0.5)
    return p


def moe_block(
    p: Params,
    x: jnp.ndarray,  # [B, S, d_model]
    top_k: int,
    capacity_factor: float = 1.25,
    dispatch: str = "einsum",  # 'einsum' (GShard) | 'dense' (compute-all)
    group_tokens: int = 4096,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [B,S,D], aux_loss scalar).

    GShard-style grouped dispatch: tokens are cut into groups of
    ``group_tokens`` and capacity is **per group** (C = gs·k·cf/E), so the
    dispatch tensor is [g, gs, E, C] — linear in total tokens.  The group
    axis inherits the batch sharding, so groups are device-local and the
    expert einsums become the EP all-to-all under GSPMD.
    """
    B, S, D = x.shape
    E = p["router"].shape[1]
    T = B * S
    xt = x.reshape(T, D)

    # ---- grouping ------------------------------------------------------- #
    gs = min(group_tokens, T)
    Gm = T // gs
    pad = Gm * gs < T
    if pad:
        Gm += 1
        xt = jnp.pad(xt, ((0, Gm * gs - T), (0, 0)))
    xg = xt.reshape(Gm, gs, D)

    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)  # [g, gs, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)  # [g, gs, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss: E · Σ_e f_e · P_e   (over real tokens only)
    me = probs.reshape(-1, E)[:T].mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32)
    ce = ce.at[idx.reshape(-1)[: T * top_k]].add(1.0) / (T * top_k)
    aux = E * jnp.sum(me * ce)

    if dispatch == "scatter":
        # Scatter/gather dispatch (beyond-paper §Perf lever): instead of the
        # GShard one-hot einsums — whose [gs, E, C] dispatch products dominate
        # HLO bytes — scatter token vectors straight into the expert buffers
        # and gather them back for the combine.  O(T·k·D) data movement.
        C = max(int(gs * top_k * capacity_factor / E), 1)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)
        flat = onehot.reshape(Gm, gs * top_k, E)
        pos = jnp.cumsum(flat, axis=1) - flat
        pos = (pos * flat).sum(-1).reshape(Gm, gs, top_k)
        keep = pos < C
        cidx = jnp.where(keep, pos, C)  # C = overflow slot (dropped)
        gi = jnp.arange(Gm)[:, None, None]
        xe = jnp.zeros((Gm, E, C + 1, D), x.dtype)
        xe = xe.at[gi, idx, cidx].add(x.dtype.type(1) * xg[:, :, None, :])
        xe = xe[:, :, :C]
        h = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(x.dtype))
        u = jnp.einsum("gecd,edf->gecf", xe, p["wu"].astype(x.dtype))
        eo = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, p["wo"].astype(x.dtype))
        eo = jnp.pad(eo, ((0, 0), (0, 0), (0, 1), (0, 0)))  # overflow row = 0
        gathered = eo[gi, idx, cidx]  # [g, gs, k, D]
        gates = jnp.where(keep, gate_vals, 0.0).astype(x.dtype)
        out = (gathered * gates[..., None]).sum(axis=2)
    elif dispatch == "dense":
        # compute every expert for every token (upper-bound baseline)
        h = jnp.einsum("gsd,edf->gsef", xg, p["wi"].astype(x.dtype))
        u = jnp.einsum("gsd,edf->gsef", xg, p["wu"].astype(x.dtype))
        eo = jnp.einsum("gsef,efd->gsed", jax.nn.silu(h) * u, p["wo"].astype(x.dtype))
        comb = (
            jax.nn.one_hot(idx, E, dtype=x.dtype)
            * gate_vals.astype(x.dtype)[..., None]
        ).sum(2)  # [g, gs, E]
        out = jnp.einsum("gsed,gse->gsd", eo, comb)
    else:
        # GShard capacity dispatch, per group
        C = max(int(gs * top_k * capacity_factor / E), 1)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # [g, gs, k, E]
        flat = onehot.reshape(Gm, gs * top_k, E)
        pos = jnp.cumsum(flat, axis=1) - flat  # entries before me (per group)
        pos = (pos * flat).sum(-1).reshape(Gm, gs, top_k)
        keep = pos < C
        slot = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=x.dtype)[..., :C]
        ek = jax.nn.one_hot(idx, E, dtype=x.dtype)  # [g, gs, k, E]
        disp = jnp.einsum("gske,gskc->gsec", ek, slot)  # [g, gs, E, C]
        xe = jnp.einsum("gsec,gsd->gecd", disp, xg)  # [g, E, C, D]
        h = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(x.dtype))
        u = jnp.einsum("gecd,edf->gecf", xe, p["wu"].astype(x.dtype))
        eo = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, p["wo"].astype(x.dtype))
        cw = jnp.einsum(
            "gske,gskc->gsec",
            ek * jnp.where(keep, gate_vals, 0.0).astype(x.dtype)[..., None],
            slot,
        )
        out = jnp.einsum("gsec,gecd->gsd", cw, eo)

    if "shared_wi" in p:
        h = jax.nn.silu(xg @ p["shared_wi"].astype(x.dtype)) * (
            xg @ p["shared_wu"].astype(x.dtype)
        )
        out = out + h @ p["shared_wo"].astype(x.dtype)

    out = out.reshape(Gm * gs, D)[:T]
    return out.reshape(B, S, D), aux
