"""Gradient compression for the data-parallel all-reduce.

int8 symmetric quantization with **error feedback** (residual carried to the
next step), the standard trick to cut DP collective bytes 4× with negligible
quality loss at LLM scale.  Compression happens *before* the pmean so the
all-reduce moves int8; decompression after.

Under GSPMD we express this as quantize -> psum-of-int32 -> dequantize inside
the step; the compiled HLO's all-reduce operand is then 8/32-bit instead of
f32, which shows up directly in the §Roofline collective term.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_tree", "decompress_tree", "init_error_state", "ef_compress"]


def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(tree) -> Dict[str, Any]:
    qs = jax.tree.map(lambda g: _quant(g.astype(jnp.float32)), tree, is_leaf=None)
    return {
        "q": jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple)),
        "scale": jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple)),
    }


def decompress_tree(packed: Dict[str, Any]):
    return jax.tree.map(_dequant, packed["q"], packed["scale"])


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(grads, err_state):
    """Error-feedback compression: returns (dequantized grads, new residual).

    g' = Q(g + e);  e' = (g + e) - g'
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = _quant(x)
        deq = _dequant(q, s)
        return deq.astype(g.dtype), x - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])
