"""AdamW in pure JAX (no optax): init/update over arbitrary pytrees.

Supports the memory knobs the CXL experiments sweep:
  * ``moment_dtype``  — fp32 (default) or bf16 moments (halves optimizer
    state, the classic trade when state is offloaded to pooled memory);
  * ``master_dtype``  — fp32 master copy of params when training in bf16
    (or None to update params in their own dtype).

The returned state is a flat dict pytree so the checkpointer and the
sharding rules treat it like any other tree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), n


def adamw_init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    zeros_like = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "mu": jax.tree.map(zeros_like, params),
        "nu": jax.tree.map(zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params,
    grads,
    state: Dict[str, Any],
    cfg: AdamWConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mu32.astype(cfg.moment_dtype), nu32.astype(cfg.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return (
        new_params,
        {"mu": new_mu, "nu": new_nu, "step": step},
        {"lr": lr, "grad_norm": gnorm},
    )
