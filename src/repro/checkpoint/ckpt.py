"""Sharded, atomic pytree checkpointing (no orbax offline — built on npz).

Layout of a checkpoint directory::

    <dir>/step_000123/
        manifest.msgpack   # treedef + per-leaf {shape, dtype, file}
        shard_<host>.npz   # this host's leaf data (全 leaves on 1-host runs)
        _COMMITTED         # written last: crash-consistent marker

Atomicity: write into ``step_XXXX.tmp`` then rename + marker.  Restore picks
the newest committed step.  Elastic re-shard: leaves are saved as *global*
arrays (single-host build) or per-shard slices keyed by shard index; at load
the caller passes target shardings and each leaf is device_put to the live
mesh — the checkpoint stores logical shapes, so mesh shape may change
between save and load.
"""

from __future__ import annotations

import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_names(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path
        )
        out[name] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree, host_id: int = 0) -> str:
    """Atomic save; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    named = _flatten_with_names(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}}
    for name, leaf in named.items():
        arr = np.asarray(leaf)
        arrays[name] = arr
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "file": f"shard_{host_id}.npz",
        }
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(directory, d, "_COMMITTED")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str,
    target_tree,
    step: Optional[int] = None,
    shardings=None,
) -> Tuple[Any, int]:
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional pytree of Sharding matching target_tree — leaves
    are device_put with them (elastic re-shard onto the live mesh).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())

    files: Dict[str, Any] = {}
    named_target = _flatten_with_names(target_tree)
    named_shard = _flatten_with_names(shardings) if shardings is not None else {}
    restored = {}
    for name, meta in manifest["leaves"].items():
        fname = meta["file"]
        if fname not in files:
            files[fname] = np.load(os.path.join(path, fname))
        arr = files[fname][name]
        if name in named_target:
            want = named_target[name]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"leaf {name}: checkpoint shape {arr.shape} != target {want.shape}"
                )
            arr = arr.astype(want.dtype)
        if name in named_shard:
            arr = jax.device_put(arr, named_shard[name])
        restored[name] = arr

    # rebuild the target structure
    flat = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for pathk, leaf in flat[0]:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in pathk
        )
        if name not in restored:
            raise KeyError(f"checkpoint missing leaf {name}")
        leaves.append(restored[name])
    return jax.tree_util.tree_unflatten(flat[1], leaves), step
