"""Fault-tolerance manager: periodic checkpoints, restart, straggler watch.

Designed for the 1000+-node posture even though this build runs 1 host:

  * **periodic atomic checkpoints** with retention (keep last N) — a pod
    failure loses at most ``interval`` steps;
  * **restart**: ``resume_or_init`` restores the newest committed step (with
    elastic re-shard onto whatever mesh is live) or initializes fresh;
  * **straggler mitigation hook**: per-step durations feed an EWMA; steps
    slower than ``straggler_factor``× the EWMA are flagged, and the
    CXLMemSim per-epoch timing decomposition says *which* component (pool
    latency / switch congestion / bandwidth) is responsible — the simulator
    doubles as the production telemetry model;
  * **preemption-signal checkpoint**: ``request_checkpoint()`` forces a save
    at the next step boundary (what a SIGTERM handler calls on real pods).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from . import ckpt

__all__ = ["FaultToleranceConfig", "CheckpointManager"]


@dataclasses.dataclass
class FaultToleranceConfig:
    directory: str = "/tmp/repro_ckpt"
    interval_steps: int = 100
    keep: int = 3
    straggler_factor: float = 2.0
    ewma_alpha: float = 0.1


class CheckpointManager:
    def __init__(self, cfg: FaultToleranceConfig):
        self.cfg = cfg
        self._ewma: Optional[float] = None
        self._forced = False
        self.straggler_events: List[Dict[str, Any]] = []

    # ---- restart ------------------------------------------------------- #

    def resume_or_init(self, init_fn: Callable[[], Any], shardings=None):
        """Returns (state, start_step). state = whatever pytree init_fn makes."""
        template = None
        step = ckpt.latest_step(self.cfg.directory)
        if step is None:
            return init_fn(), 0
        template = init_fn()
        state, step = ckpt.restore_checkpoint(
            self.cfg.directory, template, step=step, shardings=shardings
        )
        return state, step + 1

    # ---- periodic save --------------------------------------------------- #

    def request_checkpoint(self):
        self._forced = True

    def maybe_save(self, step: int, state) -> Optional[str]:
        due = step > 0 and step % self.cfg.interval_steps == 0
        if not (due or self._forced):
            return None
        self._forced = False
        path = ckpt.save_checkpoint(self.cfg.directory, step, state)
        self._gc()
        return path

    def _gc(self):
        steps = ckpt.list_steps(self.cfg.directory)
        import os, shutil

        for s in steps[: -self.cfg.keep]:
            shutil.rmtree(
                os.path.join(self.cfg.directory, f"step_{s:08d}"), ignore_errors=True
            )

    # ---- straggler watch --------------------------------------------------- #

    def observe_step(self, step: int, duration_s: float, detail: Optional[Dict] = None) -> bool:
        """Feed a step duration; returns True if flagged as straggler."""
        if self._ewma is None:
            self._ewma = duration_s
            return False
        flagged = duration_s > self.cfg.straggler_factor * self._ewma
        if flagged:
            self.straggler_events.append(
                {"step": step, "duration_s": duration_s, "ewma_s": self._ewma, **(detail or {})}
            )
        # EWMA excludes flagged steps so one straggler doesn't poison the baseline
        if not flagged:
            a = self.cfg.ewma_alpha
            self._ewma = (1 - a) * self._ewma + a * duration_s
        return flagged
