"""Finding records — simlint's machine-readable output unit."""

from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["Finding"]


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source location.

    Sort order (path, line, col) gives deterministic reports; ``rule`` is
    the suppression key (``# simlint: ignore[<rule>] -- why``).
    """

    path: str
    line: int
    col: int
    rule: str
    message: str
    checker: str = ""

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def to_dict(self) -> Dict[str, object]:
        return {
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "rule": self.rule,
            "message": self.message,
            "checker": self.checker,
        }
