"""simlint — repo-aware static analysis + runtime sanitizers.

This package encodes the invariants this codebase has historically shipped
bugs against, as machine-checkable rules:

  * **lock-discipline** (:mod:`repro.analysis.locks`): classes declare which
    attributes a lock guards (:func:`repro.analysis.annotations.guarded_by`);
    every lexical read/write of a guarded attribute must sit inside a
    ``with <...>.<lock>:`` block.  The PR-5 report race (async dispatcher
    folding into ``SimReport`` while the stepping thread wrote
    running-statistic snapshots unlocked) becomes un-reintroducible.
  * **jit-hygiene** (:mod:`repro.analysis.jit`): no host-side ``np.`` /
    ``.item()`` / ``float()`` / ``bool()`` on traced values inside jitted or
    AOT-dispatched functions; no ``.lower().compile()`` outside the
    :class:`~repro.core.aot.AotDispatchCache` build convention; pipeline
    entry points must donate their staging planes; no f64 dtypes inside f32
    kernel paths.
  * **contracts** (:mod:`repro.analysis.contracts`): ``summary()`` key-set
    literals must match their key-lock tests, and event-trace rebuilds must
    thread the ``weight``/``host`` columns (the twice-shipped PR-2 drop).

Run it::

    PYTHONPATH=src python -m repro.analysis --strict

Suppress a finding with an inline ``simlint: ignore[rule] -- justification``
comment on the finding's line (``--strict`` rejects bare suppressions and
suppressions that no longer match anything).

The runtime half lives in :mod:`repro.analysis.sanitize`:
:class:`~repro.analysis.sanitize.RecompileSanitizer` (fails a scope that
triggers steady-state jit/AOT lowerings) and
:class:`~repro.analysis.sanitize.LockOrderSanitizer` (builds a lock-order
graph from instrumented acquisitions; cycles -> potential-deadlock report).
"""

from .findings import Finding
from .framework import CheckConfig, Checker, SourceFile, registered_checkers, run_checks

__all__ = [
    "CheckConfig",
    "Checker",
    "Finding",
    "SourceFile",
    "registered_checkers",
    "run_checks",
]

# importing the checker modules registers them
from . import axes, contracts, jit, locks, units  # noqa: E402,F401  (registration imports)
