"""Source-level annotations the simlint checkers understand.

These are ordinary runtime objects (introspectable, importable with zero
dependencies on the analysis framework) whose *syntactic* form is what the
AST checkers read — annotating a class never changes its behavior.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple, TypeVar

__all__ = ["guarded_by", "single_threaded"]

F = TypeVar("F", bound=Callable)


def guarded_by(lock: str, *fields: str) -> Dict[str, Tuple[str, ...]]:
    """Declare that ``fields`` may only be accessed while ``<lock>`` is held.

    Used as a class-body declaration::

        class Session:
            _simlint_guards = guarded_by("_report_lock", "_report")

    Each field is an attribute name (``"_report"`` matches any
    ``<expr>._report``) or a dotted pair (``"_handle.dropped_batches"``
    matches only ``<expr>._handle.dropped_batches``), so fields of owned
    sub-objects can be guarded without claiming every same-named attribute.
    ``lock`` is matched by the *final* attribute name of a with-item's
    context expression: ``with self._cv:``, ``with eng._cv:`` and
    ``with self.engine._cv:`` all hold ``"_cv"`` — the convention is that a
    lock attribute name identifies one lock protocol wherever it appears.

    The lock-discipline checker exempts ``__init__``/``__post_init__``
    (single-threaded by construction), methods whose name ends in
    ``_locked`` (the caller-holds-the-lock convention this repo already
    uses), and methods decorated with :func:`single_threaded`.

    Declarations merge with ``|``::

        _simlint_guards = guarded_by("_cv", "_pending") | guarded_by(...)
    """
    return {lock: tuple(fields)}


def single_threaded(reason: str) -> Callable[[F], F]:
    """Mark a method as running on one thread only (checker-exempt).

    The reason is mandatory — an unexplained exemption is how the next
    reader reintroduces the race::

        @single_threaded("dispatcher-thread only: stagers never escape it")
        def _stager_for(self, analyzer): ...
    """
    if not isinstance(reason, str) or not reason.strip():
        raise ValueError("single_threaded requires a non-empty reason string")

    def mark(fn: F) -> F:
        fn.__simlint_single_threaded__ = reason  # type: ignore[attr-defined]
        return fn

    return mark
