"""Source-level annotations the simlint checkers understand.

These are ordinary runtime objects (introspectable, importable with zero
dependencies on the analysis framework) whose *syntactic* form is what the
AST checkers read — annotating a class never changes its behavior.
"""

from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, Dict, Tuple, TypeVar

__all__ = [
    "AxisContractError",
    "axes",
    "axes_validation",
    "guarded_by",
    "single_threaded",
    "unit",
]

F = TypeVar("F", bound=Callable)


def guarded_by(lock: str, *fields: str) -> Dict[str, Tuple[str, ...]]:
    """Declare that ``fields`` may only be accessed while ``<lock>`` is held.

    Used as a class-body declaration::

        class Session:
            _simlint_guards = guarded_by("_report_lock", "_report")

    Each field is an attribute name (``"_report"`` matches any
    ``<expr>._report``) or a dotted pair (``"_handle.dropped_batches"``
    matches only ``<expr>._handle.dropped_batches``), so fields of owned
    sub-objects can be guarded without claiming every same-named attribute.
    ``lock`` is matched by the *final* attribute name of a with-item's
    context expression: ``with self._cv:``, ``with eng._cv:`` and
    ``with self.engine._cv:`` all hold ``"_cv"`` — the convention is that a
    lock attribute name identifies one lock protocol wherever it appears.

    The lock-discipline checker exempts ``__init__``/``__post_init__``
    (single-threaded by construction), methods whose name ends in
    ``_locked`` (the caller-holds-the-lock convention this repo already
    uses), and methods decorated with :func:`single_threaded`.

    Declarations merge with ``|``::

        _simlint_guards = guarded_by("_cv", "_pending") | guarded_by(...)
    """
    return {lock: tuple(fields)}


def single_threaded(reason: str) -> Callable[[F], F]:
    """Mark a method as running on one thread only (checker-exempt).

    The reason is mandatory — an unexplained exemption is how the next
    reader reintroduces the race::

        @single_threaded("dispatcher-thread only: stagers never escape it")
        def _stager_for(self, analyzer): ...
    """
    if not isinstance(reason, str) or not reason.strip():
        raise ValueError("single_threaded requires a non-empty reason string")

    def mark(fn: F) -> F:
        fn.__simlint_single_threaded__ = reason  # type: ignore[attr-defined]
        return fn

    return mark


# --------------------------------------------------------------------------- #
# units


def unit(u: str, x: Any) -> Any:
    """Assert the physical unit of ``x`` for the units checker; returns ``x``.

    An identity at runtime — the *string literal* is what the abstract
    interpreter reads, so it must be a literal at the call site::

        budget = unit("ns", window_end - window_start)

    Unit vocabulary matches the name-suffix seeds: ``"ns"``, ``"s"``,
    ``"ms"``, ``"us"``, ``"bytes"``, ``"gbps"`` (GB/s == bytes/ns),
    ``"gib"``, ``"mib"``, ``"1"`` (dimensionless).  Compound units use
    ``/``: ``"bytes/s"``.
    """
    if not isinstance(u, str) or not u.strip():
        raise ValueError("unit() requires a non-empty unit string literal")
    return x


# --------------------------------------------------------------------------- #
# named-axis shape contracts


class AxisContractError(TypeError):
    """An array reached an ``@axes``-annotated function with the wrong shape."""


_AXES_ACTIVE = 0  # nesting depth of active axes_validation() scopes
_AXES_SINK: Any = None  # innermost scope's record-only list, or None to raise


class axes_validation:
    """Context manager that arms runtime checking of ``@axes`` contracts.

    Zero-cost when not entered: decorated functions check one module-global
    integer and call straight through.  Used by
    :class:`repro.analysis.sanitize.AxisSanitizer`; nests correctly.

    With ``sink`` (a list), violation messages are appended to it instead
    of raising — the innermost scope's mode wins while it is active.
    """

    def __init__(self, sink: Any = None) -> None:
        self._sink = sink
        self._prev_sink: Any = None

    def __enter__(self) -> "axes_validation":
        global _AXES_ACTIVE, _AXES_SINK
        _AXES_ACTIVE += 1
        self._prev_sink = _AXES_SINK
        _AXES_SINK = self._sink
        return self

    def __exit__(self, *exc: Any) -> None:
        global _AXES_ACTIVE, _AXES_SINK
        _AXES_ACTIVE -= 1
        _AXES_SINK = self._prev_sink


def _parse_spec(spec: str) -> Tuple[str, ...]:
    toks = tuple(t.strip() for t in spec.split(",")) if spec.strip() else ()
    for t in toks:
        if not (t == "_" or t.isdigit() or t.isidentifier()):
            raise ValueError(f"bad axis token {t!r} in spec {spec!r}")
    return toks


def axes(*pos_specs: str, **kw_specs: str) -> Callable[[F], F]:
    """Declare named-axis shape contracts on a function's array parameters.

    Positional specs bind to the function's leading parameters in order;
    keyword specs bind by parameter name::

        @axes("K,B,N", stts="K,S", class_weights="S,C")
        def _analyze_multi_jax(xs, stts, route, ...): ...

    A spec is a comma-separated axis list.  Tokens are axis *names*
    (``K``, ``B``, ``N`` — unified across all parameters of one call, so a
    transposed ``[B,K,N]`` dispatch fails the moment ``K`` binds two
    different sizes), integer literals (exact size), or ``_`` (wildcard).
    The empty spec ``""`` means scalar (rank 0).

    The static axes checker (:mod:`repro.analysis.axes`) reads the
    decorator syntactically and propagates the contracts through
    ``vmap``/``transpose``/reductions; at runtime the wrapper is an
    identity unless an :class:`axes_validation` scope (armed by the
    ``SIMLINT_SANITIZE=1`` :class:`~repro.analysis.sanitize.AxisSanitizer`)
    is active — then every call validates declared axes against actual
    ``.shape`` tuples, **including at jit trace time**, since traced
    arguments carry concrete shapes.  Parameters bound to ``None`` or to
    shapeless values are skipped.  ``functools.wraps`` publishes
    ``__wrapped__``, so ``jax.jit(fn, static_argnames=...)`` and
    ``donate_argnums`` keep resolving signatures through the wrapper.
    """
    parsed_kw = {name: _parse_spec(s) for name, s in kw_specs.items()}
    parsed_pos = tuple(_parse_spec(s) for s in pos_specs)

    def deco(fn: F) -> F:
        sig = inspect.signature(fn)
        params = [
            p.name
            for p in sig.parameters.values()
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
        ]
        if len(parsed_pos) > len(params):
            raise ValueError(
                f"axes(): {len(parsed_pos)} positional specs but "
                f"{fn.__name__} has only {len(params)} positional parameters"
            )
        specs: Dict[str, Tuple[str, ...]] = dict(zip(params, parsed_pos))
        for name, toks in parsed_kw.items():
            if name not in sig.parameters:
                raise ValueError(f"axes(): {fn.__name__} has no parameter {name!r}")
            specs[name] = toks

        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any) -> Any:
            if _AXES_ACTIVE:
                _validate(fn.__qualname__, sig, specs, args, kwargs)
            return fn(*args, **kwargs)

        wrapper.__simlint_axes__ = specs  # type: ignore[attr-defined]
        return wrapper  # type: ignore[return-value]

    return deco


def _fail(msg: str) -> None:
    if _AXES_SINK is not None:
        _AXES_SINK.append(msg)
        return
    raise AxisContractError(msg)


def _validate(
    qualname: str,
    sig: inspect.Signature,
    specs: Dict[str, Tuple[str, ...]],
    args: Tuple[Any, ...],
    kwargs: Dict[str, Any],
) -> None:
    try:
        bound = sig.bind(*args, **kwargs)
    except TypeError:
        return  # let the call itself raise the real signature error
    env: Dict[str, int] = {}
    for name, toks in specs.items():
        if name not in bound.arguments:
            continue
        val = bound.arguments[name]
        if val is None:
            continue
        shape = getattr(val, "shape", None)
        if shape is None:
            continue
        shape = tuple(shape)
        if len(shape) != len(toks):
            _fail(
                f"{qualname}: {name} declared axes [{','.join(toks)}] "
                f"(rank {len(toks)}) but got shape {shape} (rank {len(shape)})"
            )
            continue
        for i, (tok, dim) in enumerate(zip(toks, shape)):
            if tok == "_":
                continue
            if tok.isdigit():
                if int(tok) != dim:
                    _fail(
                        f"{qualname}: {name} axis {i} declared {tok} "
                        f"but got {dim} (shape {shape})"
                    )
                continue
            if tok in env and env[tok] != dim:
                _fail(
                    f"{qualname}: axis {tok!r} bound to {env[tok]} earlier in "
                    f"this call but {name} has {tok}={dim} at position {i} "
                    f"(shape {shape}) — transposed or mismatched dispatch"
                )
            env[tok] = dim
