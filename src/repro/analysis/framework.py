"""Checker framework: registry, per-file visitor walk, suppressions, driver.

A checker subclasses :class:`Checker` and registers itself with
:func:`register`.  The driver (:func:`run_checks`) parses every target file
once, hands each :class:`SourceFile` to every checker, filters findings
through inline ``simlint: ignore[rule]`` comment suppressions, and
(``strict``) flags suppressions that carry no justification or suppress
nothing.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

from .findings import Finding

__all__ = [
    "CheckConfig",
    "Checker",
    "SourceFile",
    "register",
    "registered_checkers",
    "run_checks",
]

# matches inline ``simlint: ignore[rule-a,rule-b] -- justification`` comments
_SUPPRESS_RE = re.compile(
    r"#\s*simlint:\s*ignore\[(?P<rules>[A-Za-z0-9_*,\- ]+)\]"
    r"(?:\s*--\s*(?P<why>\S.*))?"
)


@dataclasses.dataclass
class Suppression:
    line: int
    rules: Tuple[str, ...]
    justification: Optional[str]
    used: bool = False

    def covers(self, rule: str) -> bool:
        return "*" in self.rules or rule in self.rules


class SourceFile:
    """One parsed target file: source text, AST, and its suppressions."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel  # repo-relative, used in findings
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=str(path))
        self.suppressions: Dict[int, Suppression] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                rules = tuple(
                    r.strip() for r in m.group("rules").split(",") if r.strip()
                )
                self.suppressions[i] = Suppression(i, rules, m.group("why"))

    def finding(
        self, node: ast.AST, rule: str, message: str, checker: str = ""
    ) -> Finding:
        return Finding(
            path=self.rel,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0) + 1,
            rule=rule,
            message=message,
            checker=checker,
        )


@dataclasses.dataclass
class CheckConfig:
    """Tunable knobs; defaults encode this repo's conventions."""

    # directories (relative, prefix match) never scanned — the seeded
    # violation corpus must not fail the repo run
    exclude: Tuple[str, ...] = ("tests/fixtures",)
    # jit-hygiene: jitted entry points whose array arguments are staging
    # planes and must be donated (the device-resident pipeline contract)
    donate_required: Tuple[str, ...] = ("_analyze_pipeline_jax",)
    # simdim: dispatch-surface functions that must declare named-axis
    # contracts with @annotations.axes(...) (checked under src/repro only)
    axes_required: Tuple[str, ...] = (
        "_analyze_jax",
        "_analyze_batch_jax",
        "_analyze_multi_jax",
        "_analyze_fleet_jax",
        "_analyze_sweep_jax",
        "_analyze_pipeline_jax",
        "qos_cascade_dyn",
        "attention",
        "ssd",
        "congestion_queue",
        "congestion_cascade",
        "qos_congestion_cascade",
        "two_run_merge",
        "staging_sort",
        "chain_cascade",
    )
    # contracts: (impl file, summary-owning class, test file, test function)
    summary_contracts: Tuple[Tuple[str, str, str, str], ...] = (
        (
            "src/repro/core/attach.py",
            "SimReport",
            "tests/test_engine.py",
            "test_sim_report_summary_keys_locked",
        ),
        (
            "src/repro/core/fabric.py",
            "FabricReport",
            "tests/test_engine.py",
            "test_fabric_report_summary_keys_locked",
        ),
    )


class Checker:
    """Base class.  Subclasses set ``name`` + ``rules`` and implement
    :meth:`check_file`; repo-level (cross-file) checks go in
    :meth:`check_repo`, called once after every file was visited."""

    name: str = ""
    rules: Tuple[str, ...] = ()

    def check_file(
        self, sf: SourceFile, config: CheckConfig
    ) -> Iterable[Finding]:
        return ()

    def check_repo(
        self, files: Sequence[SourceFile], root: Path, config: CheckConfig
    ) -> Iterable[Finding]:
        return ()


_REGISTRY: Dict[str, Type[Checker]] = {}


def register(cls: Type[Checker]) -> Type[Checker]:
    if not cls.name:
        raise ValueError(f"checker {cls.__name__} has no name")
    if cls.name in _REGISTRY and _REGISTRY[cls.name] is not cls:
        raise ValueError(f"checker name {cls.name!r} already registered")
    _REGISTRY[cls.name] = cls
    return cls


def registered_checkers() -> Dict[str, Type[Checker]]:
    return dict(_REGISTRY)


def _iter_files(paths: Sequence[Path], root: Path, config: CheckConfig):
    seen = set()
    for p in paths:
        candidates = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in candidates:
            try:
                rel = str(f.resolve().relative_to(root.resolve()))
            except ValueError:
                rel = str(f)
            if rel in seen:
                continue
            if any(
                rel == ex or rel.startswith(ex.rstrip("/") + "/")
                for ex in config.exclude
            ):
                continue
            seen.add(rel)
            yield f, rel


@dataclasses.dataclass
class CheckReport:
    findings: List[Finding]
    suppressed: List[Tuple[Finding, Suppression]]
    files_checked: int

    @property
    def ok(self) -> bool:
        return not self.findings


def run_checks(
    paths: Sequence[Path],
    root: Path,
    strict: bool = False,
    checker_names: Optional[Sequence[str]] = None,
    config: Optional[CheckConfig] = None,
) -> CheckReport:
    """Run the registered checkers over ``paths``; see the CLI in
    ``repro.analysis.__main__``.

    ``strict`` additionally reports suppressions without a ``--``
    justification (``bare-suppression``) and suppressions that matched no
    finding (``unused-suppression``) — the policy the acceptance gate
    enforces: nothing is silenced without a recorded reason.
    """
    config = config or CheckConfig()
    names = list(checker_names) if checker_names else sorted(_REGISTRY)
    checkers = [_REGISTRY[n]() for n in names]

    files: List[SourceFile] = []
    findings: List[Finding] = []
    for f, rel in _iter_files(paths, root, config):
        try:
            sf = SourceFile(f, rel, f.read_text())
        except SyntaxError as e:
            findings.append(
                Finding(rel, e.lineno or 1, 1, "parse-error", str(e), "framework")
            )
            continue
        files.append(sf)

    for checker in checkers:
        for sf in files:
            findings.extend(checker.check_file(sf, config))
        findings.extend(checker.check_repo(files, root, config))

    by_rel = {sf.rel: sf for sf in files}
    kept: List[Finding] = []
    suppressed: List[Tuple[Finding, Suppression]] = []
    for fi in findings:
        sf = by_rel.get(fi.path)
        sup = sf.suppressions.get(fi.line) if sf is not None else None
        if sup is not None and sup.covers(fi.rule):
            sup.used = True
            suppressed.append((fi, sup))
        else:
            kept.append(fi)

    if strict:
        for sf in files:
            for sup in sf.suppressions.values():
                if not sup.justification:
                    kept.append(
                        Finding(
                            sf.rel,
                            sup.line,
                            1,
                            "bare-suppression",
                            "suppression without a '-- justification'; "
                            "explain why the finding is safe to ignore",
                            "framework",
                        )
                    )
                if not sup.used:
                    kept.append(
                        Finding(
                            sf.rel,
                            sup.line,
                            1,
                            "unused-suppression",
                            f"suppression for {','.join(sup.rules)} matched "
                            "no finding; remove it",
                            "framework",
                        )
                    )
    return CheckReport(sorted(kept), suppressed, len(files))
