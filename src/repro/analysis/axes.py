"""simdim axes checker — named-axis shape contracts over the dispatch surfaces.

The ``[K,B,N]`` / ``[S,H,C]`` axis conventions of the analyzer entry points
used to live only in comments.  :func:`repro.analysis.annotations.axes`
makes them declarations; this checker makes them *checked*:

* ``axes-missing`` — a dispatch-surface function named in
  ``CheckConfig.axes_required`` carries no ``@axes(...)`` decorator.
* ``axes-mismatch`` — a call site passes an argument whose tracked axis
  spec is a *permutation* of the contract's (``[B,K,N]`` into a ``[K,B,N]``
  parameter — the transposed-dispatch bug), or binds one contract axis to
  two different caller axes across the call's arguments.
* ``axes-rank`` — a call site passes an argument whose tracked rank
  contradicts the contract, or a reduction names a constant axis outside
  the operand's tracked rank.

Axis specs are tracked flow-sensitively inside each function: parameters
of ``@axes``-decorated functions seed the environment, and specs propagate
through assignment, ``transpose`` (permutation applied), reductions with a
constant ``axis=`` (dimension dropped, or kept as ``_`` under
``keepdims``), elementwise arithmetic, indexing, and ``jax.vmap`` — a
``vmap(one)(*xs)`` call peels the leading axis off every argument spec and
analyzes the *closure* ``one`` under the peeled bindings, so a contract
violation buried two vmap levels down in the batched analyzer still
surfaces at the innermost call site.  Renaming is legal (a sweep may pass
``G`` where a callee says ``K``); only bindings *inconsistent within one
call* or using the callee's own vocabulary at the wrong position are
errors — that is exactly the transposition class, and it keeps the checker
quiet on legitimately generic callers.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .findings import Finding
from .framework import CheckConfig, Checker, SourceFile, register

__all__ = ["AxesChecker"]

Spec = Tuple[str, ...]  # axis tokens, e.g. ("K", "B", "N"); "_" = wildcard

_REDUCERS = {
    "sum", "max", "min", "mean", "prod", "argmax", "argmin", "any", "all",
    "median", "std", "var", "cummax", "cumsum",
}
_CUMULATIVE = {"cummax", "cumsum"}  # reduce nothing: shape-preserving
_SEGMENT_OPS = {"segment_sum", "segment_max", "segment_min", "segment_prod"}
_ELEMENTWISE = {
    "where", "maximum", "minimum", "abs", "exp", "log", "sqrt", "clip",
    "astype", "asarray", "array", "copy", "nan_to_num",
}


def _parse_decorator(dec: ast.expr) -> Optional[Tuple[List[Spec], Dict[str, Spec]]]:
    """``@axes("K,B,N", stts="K,S")`` -> positional + keyword token specs."""
    if not isinstance(dec, ast.Call):
        return None
    name = dec.func.attr if isinstance(dec.func, ast.Attribute) else (
        dec.func.id if isinstance(dec.func, ast.Name) else None
    )
    if name != "axes":
        return None
    pos: List[Spec] = []
    kw: Dict[str, Spec] = {}
    for a in dec.args:
        if not (isinstance(a, ast.Constant) and isinstance(a.value, str)):
            return None
        pos.append(_parse_spec(a.value))
    for k in dec.keywords:
        if k.arg is None or not (
            isinstance(k.value, ast.Constant) and isinstance(k.value.value, str)
        ):
            return None
        kw[k.arg] = _parse_spec(k.value.value)
    return pos, kw


def _parse_spec(s: str) -> Spec:
    return tuple(t.strip() for t in s.split(",")) if s.strip() else ()


def _positional_params(fn: ast.FunctionDef) -> List[str]:
    return [a.arg for a in list(fn.args.posonlyargs) + list(fn.args.args)]


class Contract:
    """One function's declared axis contract, keyed by parameter name."""

    def __init__(self, fn: ast.FunctionDef, pos: List[Spec], kw: Dict[str, Spec]):
        self.params = _positional_params(fn)
        self.specs: Dict[str, Spec] = dict(zip(self.params, pos))
        self.specs.update(kw)
        self.vocab = {t for spec in self.specs.values() for t in spec}

    def spec_for_arg(self, i: int) -> Optional[Spec]:
        if i < len(self.params):
            return self.specs.get(self.params[i])
        return None


def _collect_contracts(files: Sequence[SourceFile]) -> Dict[str, Contract]:
    out: Dict[str, Optional[Contract]] = {}
    for sf in files:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            for dec in node.decorator_list:
                parsed = _parse_decorator(dec)
                if parsed is None:
                    continue
                c = Contract(node, *parsed)
                # same name declared twice with different specs: ambiguous
                if node.name in out and (
                    out[node.name] is None or out[node.name].specs != c.specs
                ):
                    out[node.name] = None
                else:
                    out[node.name] = c
    return {k: v for k, v in out.items() if v is not None}


# --------------------------------------------------------------------------- #
# per-function spec tracking


class _FuncWalk:
    def __init__(
        self,
        sf: SourceFile,
        fn: ast.FunctionDef,
        contracts: Dict[str, Contract],
        findings: List[Finding],
        checker: str,
        seed: Optional[Dict[str, Spec]] = None,
        depth: int = 0,
    ):
        self.sf = sf
        self.fn = fn
        self.contracts = contracts
        self.findings = findings
        self.checker = checker
        self.depth = depth
        self._checked: set = set()
        self.env: Dict[str, Optional[Spec]] = {}
        self.tuples: Dict[str, List[ast.expr]] = {}  # name -> tuple literal elts
        self.local_fns: Dict[str, ast.FunctionDef] = {}
        own = _own_contract(fn)
        for p in _positional_params(fn):
            self.env[p] = None
        if own is not None:
            for p, spec in own.specs.items():
                self.env[p] = spec
        if seed:
            self.env.update(seed)

    def _find(self, node: ast.AST, rule: str, msg: str) -> None:
        self.findings.append(self.sf.finding(node, rule, msg, self.checker))

    # -- spec inference --------------------------------------------------- #

    def spec_of(self, node: ast.AST) -> Optional[Spec]:  # noqa: C901
        if isinstance(node, ast.Name):
            return self.env.get(node.id)
        if isinstance(node, ast.Starred):
            return self.spec_of(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.spec_of(node.operand)
        if isinstance(node, ast.BinOp):
            if isinstance(node.op, (ast.MatMult, ast.Pow)):
                return None
            a, b = self.spec_of(node.left), self.spec_of(node.right)
            if a is None or b is None:
                return None  # unknown side may broadcast to any rank
            if len(a) == len(b):
                return a  # elementwise; renamings are legal, keep left
            return a if len(a) > len(b) else b  # numpy right-aligned broadcast
        if isinstance(node, ast.IfExp):
            a, b = self.spec_of(node.body), self.spec_of(node.orelse)
            if a is not None and b is not None and len(a) == len(b):
                return a
            return None
        if isinstance(node, (ast.Tuple, ast.List)):
            for e in node.elts:
                self.spec_of(e)
            return None
        if isinstance(node, ast.Subscript):
            return self._subscript(node)
        if isinstance(node, ast.Call):
            return self._call_spec(node)
        if isinstance(node, ast.Attribute):
            if node.attr == "T":
                base = self.spec_of(node.value)
                return tuple(reversed(base)) if base is not None else None
            if node.attr in ("shape", "dtype", "size", "ndim"):
                return None
            return None
        return None

    def _subscript(self, node: ast.Subscript) -> Optional[Spec]:
        base = self.spec_of(node.value)
        if base is None:
            return None
        idx = node.slice
        items = list(idx.elts) if isinstance(idx, ast.Tuple) else [idx]
        out: List[str] = []
        pos = 0
        for it in items:
            if isinstance(it, ast.Slice):
                if pos < len(base):
                    out.append(base[pos])
                pos += 1
            elif isinstance(it, ast.Constant) and it.value is None:
                out.append("_")  # newaxis
            elif isinstance(it, ast.Constant) and isinstance(it.value, int):
                pos += 1  # static integer index: drops the dim
            else:
                # array/variable index is a *gather* (rank-preserving), an
                # ellipsis is ambiguous — tracking ends either way
                return None
        out.extend(base[pos:])
        return tuple(out)

    def _const_axis(self, call: ast.Call) -> Optional[int]:
        for kw in call.keywords:
            if kw.arg == "axis" and isinstance(kw.value, ast.Constant):
                v = kw.value.value
                return v if isinstance(v, int) else None
        return None

    def _keepdims(self, call: ast.Call) -> bool:
        return any(
            kw.arg == "keepdims"
            and isinstance(kw.value, ast.Constant)
            and kw.value.value is True
            for kw in call.keywords
        )

    def _call_spec(self, node: ast.Call) -> Optional[Spec]:  # noqa: C901
        self.check_call(node)
        func = node.func
        fname = func.attr if isinstance(func, ast.Attribute) else (
            func.id if isinstance(func, ast.Name) else None
        )
        recv = func.value if isinstance(func, ast.Attribute) else None

        recv_spec = self.spec_of(recv) if recv is not None else None

        if fname == "transpose":
            if recv_spec is not None:  # x.transpose(...) method form
                base, perm = recv_spec, self._perm(node, True)
            elif node.args:  # jnp.transpose(x, ...) module-function form
                base, perm = self.spec_of(node.args[0]), self._perm(node, False)
            else:
                return None
            if base is None:
                return None
            if perm is None:
                return tuple(reversed(base))
            if len(perm) != len(base) or sorted(perm) != list(range(len(base))):
                self._find(
                    node, "axes-rank",
                    f"transpose permutation {perm} does not fit tracked "
                    f"axes [{','.join(base)}]",
                )
                return None
            return tuple(base[i] for i in perm)

        if fname in _REDUCERS:
            base = recv_spec if recv_spec is not None else (
                self.spec_of(node.args[0]) if node.args else None
            )
            ax = self._const_axis(node)
            if base is None:
                return None
            if fname in _CUMULATIVE:
                return base
            if ax is None:
                # full reduction only when no axis kwarg at all
                if any(kw.arg == "axis" for kw in node.keywords):
                    return None
                return ()
            if not -len(base) <= ax < len(base):
                self._find(
                    node, "axes-rank",
                    f"{fname}(axis={ax}) out of range for tracked axes "
                    f"[{','.join(base)}] (rank {len(base)})",
                )
                return None
            ax %= len(base)
            if self._keepdims(node):
                return base[:ax] + ("_",) + base[ax + 1:]
            return base[:ax] + base[ax + 1:]

        if fname in _SEGMENT_OPS and node.args:
            base = self.spec_of(node.args[0])
            return ("_",) + base[1:] if base else None

        if fname in _ELEMENTWISE:
            if fname == "where" and len(node.args) == 3:
                a = self.spec_of(node.args[1])
                b = self.spec_of(node.args[2])
                if a is None or b is None:
                    return None
                return a if len(a) >= len(b) else b
            if recv_spec is not None and not node.args:
                return recv_spec
            if node.args:
                return self.spec_of(node.args[0])
            return None

        if fname == "reshape":
            return None  # arbitrary re-layout: tracking ends here

        return None

    def _perm(self, node: ast.Call, method_form: bool) -> Optional[Tuple[int, ...]]:
        args = node.args
        if not args:
            return None
        cand = args if method_form else args[1:]
        if len(cand) == 1 and isinstance(cand[0], (ast.Tuple, ast.List)):
            elts = cand[0].elts
        else:
            elts = list(cand)
        perm = []
        for e in elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            perm.append(e.value)
        return tuple(perm) if perm else None

    # -- contract checking at call sites ----------------------------------- #

    def check_call(self, node: ast.Call) -> None:
        if id(node) in self._checked:
            return
        self._checked.add(id(node))
        func = node.func
        fname = func.attr if isinstance(func, ast.Attribute) else (
            func.id if isinstance(func, ast.Name) else None
        )
        if fname in ("vmap",):
            return  # handled by the caller of vmap's result
        contract = self.contracts.get(fname or "")
        if contract is not None:
            self._check_against(node, fname, contract)

    def _check_against(self, node: ast.Call, fname: str, c: Contract) -> None:
        binding: Dict[str, str] = {}
        reverse: Dict[str, str] = {}
        args: List[Tuple[Optional[Spec], Optional[Spec], str]] = []
        flat: List[ast.expr] = []
        for a in node.args:
            if isinstance(a, ast.Starred):
                inner = self._tuple_elts(a.value)
                if inner is None:
                    return  # unknown expansion: cannot line up positions
                flat.extend(inner)
            else:
                flat.append(a)
        for i, a in enumerate(flat):
            args.append((c.spec_for_arg(i), self.spec_of(a), f"arg {i}"))
        for kw in node.keywords:
            if kw.arg is not None and kw.arg in c.specs:
                args.append((c.specs[kw.arg], self.spec_of(kw.value), kw.arg))

        for want, got, label in args:
            if want is None or got is None:
                continue
            if len(want) != len(got):
                self._find(
                    node, "axes-rank",
                    f"{fname}() {label}: contract [{','.join(want)}] is rank "
                    f"{len(want)} but tracked value is [{','.join(got)}] "
                    f"(rank {len(got)})",
                )
                continue
            for pos, (w, g) in enumerate(zip(want, got)):
                if w == "_" or g == "_" or w.isdigit() or g.isdigit():
                    continue
                if w == g:
                    binding.setdefault(w, g)
                    reverse.setdefault(g, w)
                    continue
                # caller speaks the contract's own vocabulary but at the
                # wrong position: the transposition class
                if g in c.vocab:
                    self._find(
                        node, "axes-mismatch",
                        f"{fname}() {label}: axis {pos} is {g!r} but the "
                        f"contract wants {w!r} ([{','.join(want)}]) — "
                        "transposed dispatch?",
                    )
                    break
                if binding.get(w, g) != g or reverse.get(g, w) != w:
                    self._find(
                        node, "axes-mismatch",
                        f"{fname}() {label}: contract axis {w!r} binds both "
                        f"{binding.get(w, reverse.get(g))!r} and {g!r} in one "
                        "call — inconsistent dispatch",
                    )
                    break
                binding[w] = g
                reverse[g] = w

    def _tuple_elts(self, node: ast.expr) -> Optional[List[ast.expr]]:
        if isinstance(node, (ast.Tuple, ast.List)):
            return list(node.elts)
        if isinstance(node, ast.Name) and node.id in self.tuples:
            return self.tuples[node.id]
        return None

    # -- vmap closures ------------------------------------------------------ #

    def _maybe_vmap_call(self, node: ast.Call) -> bool:
        """``vmap(one, ...)(args)``: peel axis 0, analyze the closure."""
        inner = node.func
        if not isinstance(inner, ast.Call):
            return False
        iname = inner.func.attr if isinstance(inner.func, ast.Attribute) else (
            inner.func.id if isinstance(inner.func, ast.Name) else None
        )
        if iname != "vmap" or not inner.args:
            return False
        target = inner.args[0]
        if not isinstance(target, ast.Name):
            return False
        fn = self.local_fns.get(target.id)
        if fn is None or self.depth >= 4:
            return True  # it *was* a vmap call, just not analyzable
        flat: List[ast.expr] = []
        for a in node.args:
            if isinstance(a, ast.Starred):
                elts = self._tuple_elts(a.value)
                if elts is None:
                    return True
                flat.extend(elts)
            else:
                flat.append(a)
        params = _positional_params(fn)
        seed: Dict[str, Spec] = {}
        for p, a in zip(params, flat):
            spec = self.spec_of(a)
            if spec:
                seed[p] = spec[1:]
        sub = _FuncWalk(
            self.sf, fn, self.contracts, self.findings, self.checker,
            seed=seed, depth=self.depth + 1,
        )
        sub.local_fns.update(self.local_fns)
        sub.run()
        return True

    # -- statement walk ----------------------------------------------------- #

    def run(self) -> None:
        self._block(self.fn.body)

    def _block(self, stmts: Sequence[ast.stmt]) -> None:  # noqa: C901
        for st in stmts:
            if isinstance(st, ast.FunctionDef):
                self.local_fns[st.name] = st
                continue  # analyzed when vmapped/called, with real seeds
            if isinstance(st, ast.Assign):
                self._visit_value(st.value)
                spec = self.spec_of(st.value)
                for tgt in st.targets:
                    if isinstance(tgt, ast.Name):
                        self.env[tgt.id] = spec
                        if isinstance(st.value, (ast.Tuple, ast.List)):
                            self.tuples[tgt.id] = list(st.value.elts)
                    elif isinstance(tgt, (ast.Tuple, ast.List)):
                        for e in tgt.elts:
                            if isinstance(e, ast.Name):
                                self.env[e.id] = None
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                self._visit_value(st.value)
                if isinstance(st.target, ast.Name):
                    self.env[st.target.id] = self.spec_of(st.value)
            elif isinstance(st, ast.AugAssign):
                self._visit_value(st.value)
            elif isinstance(st, (ast.Return, ast.Expr)):
                if getattr(st, "value", None) is not None:
                    self._visit_value(st.value)
                    self.spec_of(st.value)  # reduction-rank checks fire here
            elif isinstance(st, (ast.If, ast.While)):
                self._visit_value(st.test)
                self._block(st.body)
                self._block(st.orelse)
            elif isinstance(st, ast.For):
                self._visit_value(st.iter)
                if isinstance(st.target, ast.Name):
                    self.env[st.target.id] = None
                self._block(st.body)
                self._block(st.orelse)
            elif isinstance(st, ast.With):
                for item in st.items:
                    self._visit_value(item.context_expr)
                self._block(st.body)
            elif isinstance(st, ast.Try):
                self._block(st.body)
                for h in st.handlers:
                    self._block(h.body)
                self._block(st.orelse)
                self._block(st.finalbody)

    def _visit_value(self, node: ast.AST) -> None:
        """Check every call in the expression (vmap closures included)."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                if self._maybe_vmap_call(sub):
                    continue
                self.check_call(sub)


def _own_contract(fn: ast.FunctionDef) -> Optional[Contract]:
    for dec in fn.decorator_list:
        parsed = _parse_decorator(dec)
        if parsed is not None:
            return Contract(fn, *parsed)
    return None


# --------------------------------------------------------------------------- #


@register
class AxesChecker(Checker):
    """Named-axis contract checking (see module docstring)."""

    name = "axes"
    rules = ("axes-missing", "axes-mismatch", "axes-rank")

    def check_repo(
        self, files: Sequence[SourceFile], root: Path, config: CheckConfig
    ) -> Iterable[Finding]:
        contracts = _collect_contracts(files)
        findings: List[Finding] = []

        for sf in files:
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.FunctionDef):
                    continue
                if (
                    node.name in config.axes_required
                    and _own_contract(node) is None
                ):
                    findings.append(
                        sf.finding(
                            node,
                            "axes-missing",
                            f"dispatch surface {node.name}() must declare "
                            "its axis contract with @annotations.axes(...)",
                            self.name,
                        )
                    )

        # flow-sensitive walk of every module-level function and method
        for sf in files:
            for node in sf.tree.body:
                fns: List[ast.FunctionDef] = []
                if isinstance(node, ast.FunctionDef):
                    fns.append(node)
                elif isinstance(node, ast.ClassDef):
                    fns.extend(
                        n for n in node.body if isinstance(n, ast.FunctionDef)
                    )
                for fn in fns:
                    walk = _FuncWalk(sf, fn, contracts, findings, self.name)
                    walk.run()
        return findings
