"""``python -m repro.analysis`` — the simlint CLI.

Examples::

    PYTHONPATH=src python -m repro.analysis --strict       # the CI gate
    PYTHONPATH=src python -m repro.analysis --json src/repro/core
    PYTHONPATH=src python -m repro.analysis --checkers locks,contracts

Exit status: 0 clean, 1 findings, 2 usage error.  ``--strict`` additionally
fails on suppressions without a ``-- justification`` and on suppressions
that no longer suppress anything.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .framework import registered_checkers, run_checks


def _find_root(start: Path) -> Path:
    """The repo root: nearest ancestor holding pyproject.toml or .git."""
    for p in [start] + list(start.parents):
        if (p / "pyproject.toml").exists() or (p / ".git").exists():
            return p
    return start


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__
    )
    parser.add_argument(
        "paths", nargs="*",
        help="files/directories to check (default: src/repro under the repo root)",
    )
    parser.add_argument(
        "--strict", action="store_true",
        help="also fail on bare or unused suppressions",
    )
    parser.add_argument(
        "--json", action="store_true", dest="as_json",
        help="machine-readable findings on stdout",
    )
    parser.add_argument(
        "--checkers", default=None,
        help="comma-separated subset (default: all registered)",
    )
    parser.add_argument(
        "--root", default=None,
        help="repo root override (default: auto-detected)",
    )
    args = parser.parse_args(argv)

    root = Path(args.root).resolve() if args.root else _find_root(Path.cwd())
    paths = (
        [Path(p) for p in args.paths]
        if args.paths
        else [root / "src" / "repro"]
    )
    for p in paths:
        if not p.exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2
    checker_names = None
    if args.checkers:
        checker_names = [c.strip() for c in args.checkers.split(",") if c.strip()]
        unknown = set(checker_names) - set(registered_checkers())
        if unknown:
            print(
                f"error: unknown checkers {sorted(unknown)}; "
                f"registered: {sorted(registered_checkers())}",
                file=sys.stderr,
            )
            return 2

    report = run_checks(
        paths, root, strict=args.strict, checker_names=checker_names
    )
    if args.as_json:
        print(json.dumps(
            {
                "findings": [f.to_dict() for f in report.findings],
                "suppressed": [
                    {**f.to_dict(), "justification": s.justification}
                    for f, s in report.suppressed
                ],
                "files_checked": report.files_checked,
            },
            indent=2,
        ))
    else:
        for f in report.findings:
            print(f.format())
        print(
            f"simlint: {len(report.findings)} finding(s), "
            f"{len(report.suppressed)} suppressed, "
            f"{report.files_checked} file(s) checked"
            + (" [strict]" if args.strict else "")
        )
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
