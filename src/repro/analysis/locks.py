"""Lock-discipline checker — the PR-5 report-race class, made un-shippable.

Classes declare guarded attributes with
:func:`repro.analysis.annotations.guarded_by`::

    class AttachedProgram(EngineClient):
        _simlint_guards = guarded_by("_report_lock", "_report")

The checker then verifies every lexical read/write of a guarded attribute
inside the class's methods happens under a ``with <...>.<lock>:`` block
whose context expression ends in the declared lock name.  Exempt:
``__init__``/``__post_init__``, methods named ``*_locked`` (the
caller-holds-it convention), and methods decorated
``@single_threaded("why")``.

This is *lexical* checking: a closure defined inside a ``with`` block runs
later, without the lock, so nested functions are checked against an empty
held-lock set — which is exactly the bug class where a fold callback built
under the lock escapes to the dispatcher thread.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Tuple

from .findings import Finding
from .framework import CheckConfig, Checker, SourceFile, register

__all__ = ["LockDisciplineChecker"]

RULE = "lock-discipline"
GUARDS_ATTR = "_simlint_guards"
EXEMPT_NAMES = ("__init__", "__post_init__")


def _call_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name):
            return f.id
        if isinstance(f, ast.Attribute):
            return f.attr
    return None


def _parse_guards(value: ast.AST) -> Optional[Dict[str, Tuple[str, ...]]]:
    """Parse ``guarded_by(...)`` / ``guarded_by(...) | guarded_by(...)``."""
    if isinstance(value, ast.BinOp) and isinstance(value.op, ast.BitOr):
        left = _parse_guards(value.left)
        right = _parse_guards(value.right)
        if left is None or right is None:
            return None
        for lock, fields in right.items():
            left[lock] = tuple(dict.fromkeys(left.get(lock, ()) + fields))
        return left
    if _call_name(value) == "guarded_by":
        args = value.args  # type: ignore[union-attr]
        if args and all(
            isinstance(a, ast.Constant) and isinstance(a.value, str) for a in args
        ):
            return {args[0].value: tuple(a.value for a in args[1:])}
    return None


def _class_guards(cls: ast.ClassDef) -> Optional[Dict[str, Tuple[str, ...]]]:
    for stmt in cls.body:
        if (
            isinstance(stmt, ast.Assign)
            and any(
                isinstance(t, ast.Name) and t.id == GUARDS_ATTR for t in stmt.targets
            )
        ):
            return _parse_guards(stmt.value)
    return None


def _is_exempt(fn: ast.AST) -> bool:
    if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return True
    if fn.name in EXEMPT_NAMES or fn.name.endswith("_locked"):
        return True
    for dec in fn.decorator_list:
        if _call_name(dec) == "single_threaded":
            return True
    return False


def _with_lock_names(node: ast.With) -> List[str]:
    names = []
    for item in node.items:
        expr = item.context_expr
        if isinstance(expr, ast.Attribute):
            names.append(expr.attr)
        elif isinstance(expr, ast.Name):
            names.append(expr.id)
    return names


class _MethodVisitor(ast.NodeVisitor):
    """Walk one method body tracking the lexically-held lock set."""

    def __init__(
        self,
        sf: SourceFile,
        guards: Dict[str, Tuple[str, ...]],
        method: str,
    ):
        self.sf = sf
        self.method = method
        self.held: List[str] = []
        self.findings: List[Finding] = []
        # field spec -> lock, split into plain and dotted ("owner.field")
        self.plain: Dict[str, str] = {}
        self.dotted: Dict[Tuple[str, str], str] = {}
        for lock, fields in guards.items():
            for f in fields:
                if "." in f:
                    owner, attr = f.rsplit(".", 1)
                    self.dotted[(owner, attr)] = lock
                else:
                    self.plain[f] = lock

    def visit_With(self, node: ast.With) -> None:
        for item in node.items:
            self.visit(item.context_expr)
        locks = _with_lock_names(node)
        self.held.extend(locks)
        for stmt in node.body:
            self.visit(stmt)
        del self.held[len(self.held) - len(locks):]

    visit_AsyncWith = visit_With  # type: ignore[assignment]

    def _enter_scope(self, node: ast.AST) -> None:
        # nested defs/lambdas run later, when the enclosing with-block's
        # lock is no longer held
        held, self.held = self.held, []
        self.generic_visit(node)
        self.held = held

    visit_FunctionDef = _enter_scope  # type: ignore[assignment]
    visit_AsyncFunctionDef = _enter_scope  # type: ignore[assignment]
    visit_Lambda = _enter_scope  # type: ignore[assignment]

    def visit_Attribute(self, node: ast.Attribute) -> None:
        lock = self.plain.get(node.attr)
        if lock is None and isinstance(node.value, ast.Attribute):
            lock = self.dotted.get((node.value.attr, node.attr))
        if lock is not None and lock not in self.held:
            self.findings.append(
                self.sf.finding(
                    node,
                    RULE,
                    f"'{node.attr}' is guarded by '{lock}' but accessed in "
                    f"'{self.method}' outside 'with ...{lock}:'",
                    checker="locks",
                )
            )
        self.generic_visit(node)


@register
class LockDisciplineChecker(Checker):
    name = "locks"
    rules = (RULE,)

    def check_file(
        self, sf: SourceFile, config: CheckConfig
    ) -> Iterable[Finding]:
        findings: List[Finding] = []
        for cls in ast.walk(sf.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            guards = _class_guards(cls)
            if not guards:
                continue
            for fn in cls.body:
                if _is_exempt(fn):
                    continue
                visitor = _MethodVisitor(sf, guards, f"{cls.name}.{fn.name}")
                for stmt in fn.body:  # type: ignore[union-attr]
                    visitor.visit(stmt)
                findings.extend(visitor.findings)
        return findings
