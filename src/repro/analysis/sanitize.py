"""Runtime sanitizers — the dynamic half of simlint.

Three context managers, all usable standalone or as test fixtures (see
``tests/conftest.py``, gated by ``SIMLINT_SANITIZE=1``):

* :class:`RecompileSanitizer` — fails a scope that triggers steady-state
  compilation.  It watches two independent signals: the
  :class:`~repro.core.aot.AotDispatchCache` ``lowerings`` counters (every
  live cache, via the class registry) and JAX's own compile log
  (``jax_log_compiles``), so it catches both AOT rebuilds that should have
  been cache hits and ``jax.jit`` retraces from unstable static arguments
  or weak-type flapping — the documented footgun class of ``core/aot.py``.
* :class:`LockOrderSanitizer` — wraps ``threading.Lock``/``threading.RLock``
  creation for the scope's duration, records every *blocking* acquisition
  against the acquiring thread's currently-held set, aggregates edges by
  lock **creation site**, and reports any cycle in the resulting lock-order
  graph as a potential deadlock.  Non-blocking probe acquires (e.g.
  ``Condition._is_owned``) are tracked for held-set bookkeeping but add no
  edges — a ``try``-acquire cannot deadlock.
* :class:`AxisSanitizer` — arms runtime validation of the
  :func:`repro.analysis.annotations.axes` shape contracts.  While the
  scope is active, every call to an ``@axes``-annotated function (eager
  *and* at jit trace time, where traced arguments carry concrete shapes)
  unifies the declared named axes against the actual ``.shape`` tuples and
  raises :class:`~repro.analysis.annotations.AxisContractError` on a
  transposed or mismatched dispatch.  Outside the scope the wrappers check
  one module-global integer and call straight through.

The lock/recompile sanitizers only observe objects *created inside* their
scope: an engine constructed before ``__enter__`` keeps its raw locks.
That is the intended test shape — construct the system under test inside
the scope.  The axis sanitizer has no such restriction (contracts live on
the functions, not on instances), but jitted callables *traced before* the
scope replay their cached executables without re-entering the Python
wrapper — validate with fresh shapes or eager calls.
"""

from __future__ import annotations

import logging
import sys
import threading
from typing import Dict, List, Optional, Tuple

__all__ = [
    "AxisSanitizer",
    "LockOrderError",
    "LockOrderSanitizer",
    "RecompileError",
    "RecompileSanitizer",
]


class RecompileError(AssertionError):
    """A scope compiled more than its budget allows."""


class LockOrderError(AssertionError):
    """The scope's lock-order graph contains a cycle (potential deadlock)."""


# --------------------------------------------------------------------------- #
# RecompileSanitizer
# --------------------------------------------------------------------------- #


class _CompileLogHandler(logging.Handler):
    """Collects JAX's ``Compiling <name> ...`` records (one per real XLA
    compile when ``jax_log_compiles`` is on; cache hits emit nothing)."""

    def __init__(self) -> None:
        super().__init__(level=logging.DEBUG)
        self.events: List[str] = []

    def emit(self, record: logging.LogRecord) -> None:
        try:
            msg = record.getMessage()
        except Exception:  # a malformed record must not kill the test body
            return
        if msg.startswith("Compiling "):
            self.events.append(msg.split(".")[0][:200])


class RecompileSanitizer:
    """Fail (or record) compilation happening inside the scope.

    Args:
      allowed_lowerings: AOT-cache builds the scope may perform (0 for a
        steady-state scope that was warmed beforehand).
      allowed_jit_compiles: budget for ``jax.jit``-level XLA compiles seen
        in the compile log; ``None`` disables that check (the log is a
        process-global signal, so concurrent compilation elsewhere would
        count too — keep it ``None`` unless the scope owns the process).
      record_only: never raise; just expose the counters.

    After exit: ``aot_lowerings``, ``jit_compiles`` and ``compile_events``
    describe what happened.
    """

    def __init__(
        self,
        allowed_lowerings: int = 0,
        allowed_jit_compiles: Optional[int] = None,
        record_only: bool = False,
    ):
        self.allowed_lowerings = int(allowed_lowerings)
        self.allowed_jit_compiles = allowed_jit_compiles
        self.record_only = bool(record_only)
        self.aot_lowerings = 0
        self.jit_compiles = 0
        self.compile_events: List[str] = []
        self._aot0 = 0
        self._handler: Optional[_CompileLogHandler] = None
        self._log_compiles_was: Optional[bool] = None

    def __enter__(self) -> "RecompileSanitizer":
        from ..core.aot import AotDispatchCache

        self._aot0 = AotDispatchCache.total_lowerings()
        self._handler = _CompileLogHandler()
        logging.getLogger("jax").addHandler(self._handler)
        try:
            import jax

            self._log_compiles_was = bool(jax.config.jax_log_compiles)
            jax.config.update("jax_log_compiles", True)
        except Exception:  # no usable jax config: AOT counters still work
            self._log_compiles_was = None
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        from ..core.aot import AotDispatchCache

        if self._log_compiles_was is not None:
            import jax

            jax.config.update("jax_log_compiles", self._log_compiles_was)
        if self._handler is not None:
            logging.getLogger("jax").removeHandler(self._handler)
            self.compile_events = self._handler.events
            self.jit_compiles = len(self.compile_events)
        self.aot_lowerings = AotDispatchCache.total_lowerings() - self._aot0
        if self.record_only or exc_type is not None:
            return  # never mask the body's own failure
        problems = []
        if self.aot_lowerings > self.allowed_lowerings:
            problems.append(
                f"{self.aot_lowerings} AOT lowering(s) (allowed "
                f"{self.allowed_lowerings}) — a steady-state scope should be "
                "served from AotDispatchCache"
            )
        if (
            self.allowed_jit_compiles is not None
            and self.jit_compiles > self.allowed_jit_compiles
        ):
            shown = "; ".join(self.compile_events[:5])
            problems.append(
                f"{self.jit_compiles} XLA compile(s) (allowed "
                f"{self.allowed_jit_compiles}): {shown}"
            )
        if problems:
            raise RecompileError("recompile sanitizer: " + "; ".join(problems))


# --------------------------------------------------------------------------- #
# LockOrderSanitizer
# --------------------------------------------------------------------------- #


def _creation_site() -> str:
    """``file:line`` of the frame that called the patched lock factory."""
    f = sys._getframe(2)
    # skip interpreter-internal threading frames (Condition() building its
    # own lock, etc.) so the site names user code when possible
    while f is not None and f.f_globals.get("__name__", "").startswith(
        "threading"
    ):
        f = f.f_back
    if f is None:
        return "<unknown>"
    return f"{f.f_code.co_filename}:{f.f_lineno}"


class _TrackedLock:
    """Wrapper around a raw lock that reports acquisitions to the sanitizer.

    Keeps working (as a plain pass-through) after the sanitizer scope ends,
    since threads started inside the scope may outlive it.
    """

    __slots__ = ("_raw", "site", "_san", "_reentrant", "_owner", "_count")

    def __init__(self, raw, site: str, san: "LockOrderSanitizer", reentrant: bool):
        self._raw = raw
        self.site = site
        self._san = san
        self._reentrant = reentrant
        self._owner: Optional[int] = None  # reentrant bookkeeping only
        self._count = 0

    def acquire(self, blocking: bool = True, timeout: float = -1):
        me = threading.get_ident()
        if self._reentrant and self._owner == me:
            self._count += 1
            return self._raw.acquire(blocking, timeout)
        if blocking and self._san._active:
            # record the *intent* before blocking: a deadlocked acquire
            # never returns, but the edge that caused it must still exist
            self._san._note_edges(self, me)
        ok = self._raw.acquire(blocking, timeout)
        if ok:
            if self._reentrant:
                self._owner, self._count = me, 1
            self._san._push(self, me)
        return ok

    def release(self) -> None:
        if self._reentrant and self._owner == threading.get_ident():
            self._count -= 1
            if self._count > 0:
                self._raw.release()
                return
            self._owner = None
        self._san._pop(self, threading.get_ident())
        self._raw.release()

    def locked(self) -> bool:
        return self._raw.locked()

    # Condition() integration: threading.Condition looks these up on its
    # lock (real RLocks provide them; its probe-based fallbacks misread a
    # reentrant wrapper as un-owned).  They must also keep the sanitizer's
    # held-set bookkeeping consistent across a wait()'s release/reacquire.

    def _is_owned(self) -> bool:
        if self._reentrant:
            return self._raw._is_owned()
        if self._raw.acquire(False):  # plain-lock probe, bookkeeping-free
            self._raw.release()
            return False
        return True

    def _release_save(self):
        me = threading.get_ident()
        if self._reentrant:
            count, owner = self._count, self._owner
            self._count, self._owner = 0, None
            self._san._pop(self, me)
            return (count, owner, self._raw._release_save())
        self._san._pop(self, me)
        self._raw.release()
        return None

    def _acquire_restore(self, state) -> None:
        me = threading.get_ident()
        if self._san._active:
            # waking from wait() reacquires while possibly holding other
            # locks — a real ordering edge, recorded like any acquire
            self._san._note_edges(self, me)
        if self._reentrant:
            count, owner, raw_state = state
            self._raw._acquire_restore(raw_state)
            self._count, self._owner = count, owner
        else:
            self._raw.acquire()
        self._san._push(self, me)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # shows up in deadlock reports
        return f"<TrackedLock {self.site}>"


class LockOrderSanitizer:
    """Build a creation-site lock-order graph for the scope; cycles raise.

    The classic report: thread A acquires lock₁ then lock₂ while thread B
    acquires lock₂ then lock₁ — each order is an edge, the pair is a cycle,
    and the scope ends with a :class:`LockOrderError` naming both sites and
    the witnessing threads, whether or not the timing actually deadlocked
    on this run.
    """

    def __init__(self, record_only: bool = False):
        self.record_only = bool(record_only)
        self._active = False
        self._orig_lock = None
        self._orig_rlock = None
        # raw (never wrapped) lock protecting the sanitizer's own state
        self._struct = threading.Lock()
        self._held: Dict[int, List[_TrackedLock]] = {}
        # (site_from, site_to) -> first witness description
        self.edges: Dict[Tuple[str, str], str] = {}
        self.locks_created = 0

    # -- tracking hooks (called from _TrackedLock) ---------------------- #

    def _note_edges(self, lock: _TrackedLock, thread_id: int) -> None:
        # NOT threading.current_thread(): from a not-yet-registered thread
        # it constructs a _DummyThread whose Event acquires a wrapped lock,
        # recursing straight back here.  The registry read has no side
        # effects; unregistered threads report their ident.
        t = getattr(threading, "_active", {}).get(thread_id)
        tname = t.name if t is not None else f"tid={thread_id}"
        with self._struct:
            for held in self._held.get(thread_id, ()):
                # same-site edges are skipped: sites aggregate every lock a
                # line creates (lock striping, per-session locks), and the
                # graph cannot see an ordering *within* one site — flagging
                # them would make ordered same-site acquisition cry wolf
                if held is lock or held.site == lock.site:
                    continue
                edge = (held.site, lock.site)
                if edge not in self.edges:
                    self.edges[edge] = (
                        f"thread {tname!r} acquired {lock.site} while "
                        f"holding {held.site}"
                    )

    def _push(self, lock: _TrackedLock, thread_id: int) -> None:
        with self._struct:
            self._held.setdefault(thread_id, []).append(lock)

    def _pop(self, lock: _TrackedLock, thread_id: int) -> None:
        with self._struct:
            stack = self._held.get(thread_id)
            if stack and lock in stack:
                stack.reverse()
                stack.remove(lock)
                stack.reverse()
                return
            # released from a different thread than the acquirer (legal for
            # plain Locks): find and drop it wherever it is held
            for other in self._held.values():
                if lock in other:
                    other.remove(lock)
                    return

    # -- lifecycle ------------------------------------------------------ #

    def __enter__(self) -> "LockOrderSanitizer":
        self._orig_lock = threading.Lock
        self._orig_rlock = threading.RLock
        san = self

        def make_lock():  # noqa: ANN202 - threading factory signature
            san.locks_created += 1
            return _TrackedLock(san._orig_lock(), _creation_site(), san, False)

        def make_rlock():
            san.locks_created += 1
            return _TrackedLock(san._orig_rlock(), _creation_site(), san, True)

        threading.Lock = make_lock  # type: ignore[misc]
        threading.RLock = make_rlock  # type: ignore[misc]
        self._active = True
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._active = False
        threading.Lock = self._orig_lock  # type: ignore[misc]
        threading.RLock = self._orig_rlock  # type: ignore[misc]
        if exc_type is not None:  # never mask the body's own failure
            return
        cycle = self.find_cycle()
        if cycle and not self.record_only:
            raise LockOrderError(self.format_cycle(cycle))

    # -- reporting ------------------------------------------------------ #

    def find_cycle(self) -> Optional[List[str]]:
        """A list of sites forming a cycle in the order graph, or None."""
        with self._struct:
            adj: Dict[str, List[str]] = {}
            for a, b in self.edges:
                adj.setdefault(a, []).append(b)
        WHITE, GREY, BLACK = 0, 1, 2
        color: Dict[str, int] = {}
        parent: Dict[str, str] = {}

        def dfs(start: str) -> Optional[List[str]]:
            stack = [(start, iter(adj.get(start, ())))]
            color[start] = GREY
            while stack:
                node, it = stack[-1]
                advanced = False
                for nxt in it:
                    c = color.get(nxt, WHITE)
                    if c == GREY:  # back edge: unwind the cycle
                        cyc = [nxt, node]
                        cur = node
                        while cur != nxt:
                            cur = parent[cur]
                            cyc.append(cur)
                        cyc.reverse()
                        return cyc
                    if c == WHITE:
                        parent[nxt] = node
                        color[nxt] = GREY
                        stack.append((nxt, iter(adj.get(nxt, ()))))
                        advanced = True
                        break
                if not advanced:
                    color[node] = BLACK
                    stack.pop()
            return None

        for site in list(adj):
            if color.get(site, WHITE) == WHITE:
                cyc = dfs(site)
                if cyc:
                    return cyc
        return None

    def format_cycle(self, cycle: List[str]) -> str:
        lines = ["lock-order cycle (potential deadlock):"]
        with self._struct:
            for a, b in zip(cycle, cycle[1:]):
                witness = self.edges.get((a, b), "")
                lines.append(f"  {a} -> {b}    [{witness}]")
        return "\n".join(lines)


# --------------------------------------------------------------------------- #
# AxisSanitizer
# --------------------------------------------------------------------------- #


class AxisSanitizer:
    """Arm runtime checking of ``@axes`` named-axis contracts for a scope.

    A lifecycle wrapper around
    :class:`repro.analysis.annotations.axes_validation` that matches the
    other sanitizers' shape.  Default mode raises
    :class:`~repro.analysis.annotations.AxisContractError` at the violating
    call; ``record_only=True`` collects violation messages into
    ``self.violations`` and, on a clean body exit, raises nothing — the
    caller inspects the list (the conftest fixture uses the raising mode).
    """

    def __init__(self, record_only: bool = False):
        self.record_only = bool(record_only)
        self.violations: List[str] = []
        self._scope = None

    def __enter__(self) -> "AxisSanitizer":
        from .annotations import axes_validation

        sink = self.violations if self.record_only else None
        self._scope = axes_validation(sink=sink).__enter__()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._scope is not None:
            self._scope.__exit__(exc_type, exc, tb)
            self._scope = None
