"""simdim units checker — flow-sensitive physical-unit abstract interpretation.

The repo's unit discipline is conventional: ``_ns`` names hold nanoseconds,
``_s`` seconds, ``_bytes`` bytes, ``_gbps`` GB/s (== bytes/ns — the 1e9
cancels, see ``core/topology.py``), and every scale change routes through
:mod:`repro.core.units`.  This checker turns the convention into rules:

* ``unit-mismatch`` — an add/sub/compare/assign whose two sides carry
  *different known* units (``lat_ns + win_s``, ``if t_ns < budget_s:``),
  or a conversion helper applied to the wrong input unit
  (``ns_to_s(latency_s)``).
* ``unit-return`` — a ``return`` whose expression's inferred unit
  contradicts the function's own name suffix (``def window_ns(...):
  return span_s``).
* ``unit-raw-conversion`` — a bare ``* 1e9``-family literal multiplied or
  divided against a value with a known unit anywhere outside
  ``repro/core/units.py``.  Scattered conversion literals are exactly how
  the shipped ns↔s accounting slips happened; the named helpers are the
  only legal conversion points.

The abstract domain is a symbol fraction (``byte/ns`` for link rates,
``ns`` for clocks, ``1`` for dimensionless) so ordinary bandwidth math
checks out with **no annotations at all**: ``wbytes / bw_gbps`` is
``byte / (byte/ns) = ns``.  Units seed from name suffixes, from
:func:`repro.analysis.annotations.unit` markers, and from the
:mod:`repro.core.units` constants (``NS_PER_S`` is ``ns/s``); they flow
through assignments, arithmetic, known pass-through calls (``jnp.sum``,
``.cumsum()``, ``jnp.where``), and user calls via interprocedural
summaries (a fixpoint over every function's inferred return unit, merged
with its name suffix).  Unknown values stay unknown — the checker only
speaks when *both* sides of an operation are known, which is what keeps
it quiet on untyped code.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .findings import Finding
from .framework import CheckConfig, Checker, SourceFile, register

__all__ = ["UnitsChecker"]

# --------------------------------------------------------------------------- #
# the unit algebra: a reduced fraction over base symbols

Unit = Tuple[Tuple[str, ...], Tuple[str, ...]]  # (numerator, denominator)

ONE: Unit = ((), ())


def _mk(num: Sequence[str] = (), den: Sequence[str] = ()) -> Unit:
    n, d = list(num), list(den)
    for sym in list(n):
        if sym in d:
            n.remove(sym)
            d.remove(sym)
    return (tuple(sorted(n)), tuple(sorted(d)))


def _mul(a: Unit, b: Unit) -> Unit:
    return _mk(a[0] + b[0], a[1] + b[1])


def _div(a: Unit, b: Unit) -> Unit:
    return _mk(a[0] + b[1], a[1] + b[0])


def _fmt(u: Unit) -> str:
    if u == ONE:
        return "1"
    num = "*".join(u[0]) or "1"
    return f"{num}/{'*'.join(u[1])}" if u[1] else num


NS = _mk(["ns"])
S = _mk(["s"])
MS = _mk(["ms"])
US = _mk(["us"])
BYTE = _mk(["byte"])
GIB = _mk(["gib"])
MIB = _mk(["mib"])
GBPS = _mk(["byte"], ["ns"])  # GB/s == bytes/ns, the repo link-rate unit

# name-suffix seeds (the declaration is the name)
_SUFFIX_UNITS: Dict[str, Unit] = {
    "_ns": NS,
    "_s": S,
    "_ms": MS,
    "_us": US,
    "_bytes": BYTE,
    "_gib": GIB,
    "_mib": MIB,
    "_gbps": GBPS,
    "_frac": ONE,
}
_EXACT_NAMES: Dict[str, Unit] = {"nbytes": BYTE, "wbytes": BYTE}

# repro.core.units constants carry conversion-factor units, so plain
# fraction algebra makes `x_s * NS_PER_S` come out as ns
_CONSTANT_UNITS: Dict[str, Unit] = {
    "NS_PER_S": _mk(["ns"], ["s"]),
    "S_PER_NS": _mk(["s"], ["ns"]),
    "NS_PER_MS": _mk(["ns"], ["ms"]),
    "NS_PER_US": _mk(["ns"], ["us"]),
    "MS_PER_S": _mk(["ms"], ["s"]),
    "BYTES_PER_GB": _mk(["byte"], ["gb"]),
    "BYTES_PER_GIB": _mk(["byte"], ["gib"]),
    "BYTES_PER_MIB": _mk(["byte"], ["mib"]),
}

# helper name -> (expected input unit or None, output unit)
_HELPERS: Dict[str, Tuple[Optional[Unit], Unit]] = {
    "ns_to_s": (NS, S),
    "s_to_ns": (S, NS),
    "s_to_ms": (S, MS),
    "ns_to_ms": (NS, MS),
    "ms_to_ns": (MS, NS),
    "ns_to_us": (NS, US),
    "us_to_ns": (US, NS),
    "gib_to_bytes": (GIB, BYTE),
    "bytes_to_gib": (BYTE, GIB),
    "mib_to_bytes": (MIB, BYTE),
    "bytes_to_mib": (BYTE, MIB),
    "gbps_to_bytes_per_s": (GBPS, _mk(["byte"], ["s"])),
}

# unit-string vocabulary for annotations.unit("...") markers
_UNIT_TOKENS: Dict[str, Unit] = {
    "ns": NS,
    "s": S,
    "ms": MS,
    "us": US,
    "bytes": BYTE,
    "byte": BYTE,
    "gib": GIB,
    "mib": MIB,
    "gbps": GBPS,
    "1": ONE,
}

# calls that return their (first) argument's unit unchanged
_PASS_THROUGH_FUNCS = {
    "abs", "float", "sum", "max", "min", "round", "sorted",
    "asarray", "array", "cumsum", "maximum", "minimum", "mean", "median",
    "sort", "concatenate", "stack", "abs", "unique", "ravel", "squeeze",
    "full_like", "zeros_like", "ones_like", "transpose", "reshape",
    "segment_sum", "segment_max", "cummax", "unit",
}
# methods whose receiver's unit passes through
_PASS_THROUGH_METHODS = {
    "sum", "max", "min", "mean", "cumsum", "astype", "copy", "reshape",
    "ravel", "squeeze", "item", "tolist", "transpose", "clip", "get",
}
# jnp.where(cond, a, b) unifies a/b; clip passes arg0
_SELECT_FUNCS = {"where"}

# the raw-conversion literal family (values, matched exactly)
_CONVERSION_LITERALS = {1e9, 1e-9, 1e6, 1e-6, 1e3, 1e-3, 2**30, 2**20}


def _is_conversion_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return float(node.value) in _CONVERSION_LITERALS
    # the 2**30 / 2**20 spelled-out powers
    if (
        isinstance(node, ast.BinOp)
        and isinstance(node.op, ast.Pow)
        and isinstance(node.left, ast.Constant)
        and node.left.value == 2
        and isinstance(node.right, ast.Constant)
        and node.right.value in (20, 30)
    ):
        return True
    return False


def _is_scalar_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.USub, ast.UAdd)):
        return _is_scalar_literal(node.operand)
    return False


def _seed_for(name: str) -> Optional[Unit]:
    if name in _EXACT_NAMES:
        return _EXACT_NAMES[name]
    if name in _CONSTANT_UNITS:
        return _CONSTANT_UNITS[name]
    for suf, u in _SUFFIX_UNITS.items():
        if name.endswith(suf) and len(name) > len(suf):
            return u
    return None


def _final_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


# --------------------------------------------------------------------------- #
# per-function flow-sensitive interpreter


class _FuncAnalysis:
    def __init__(
        self,
        sf: SourceFile,
        fn: ast.FunctionDef,
        summaries: Dict[str, Optional[Unit]],
        emit: Optional[List[Finding]],
        checker_name: str,
        exempt_conversions: bool,
        outer_env: Optional[Dict[str, Optional[Unit]]] = None,
    ):
        self.sf = sf
        self.fn = fn
        self.summaries = summaries
        self.emit = emit  # None: inference-only pass (no findings)
        self.checker = checker_name
        self.exempt_conversions = exempt_conversions
        self.env: Dict[str, Optional[Unit]] = dict(outer_env or {})
        for a in list(fn.args.posonlyargs) + list(fn.args.args) + list(
            fn.args.kwonlyargs
        ):
            self.env[a.arg] = _seed_for(a.arg)
        self.return_units: List[Optional[Unit]] = []

    # -- findings -------------------------------------------------------- #

    def _find(self, node: ast.AST, rule: str, msg: str) -> None:
        if self.emit is not None:
            self.emit.append(self.sf.finding(node, rule, msg, self.checker))

    # -- expression units ------------------------------------------------- #

    def unit_of(self, node: ast.AST) -> Optional[Unit]:  # noqa: C901
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            return _seed_for(node.id)
        if isinstance(node, ast.Attribute):
            return _seed_for(node.attr)
        if isinstance(node, ast.Subscript):
            return self.unit_of(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.unit_of(node.operand)
        if isinstance(node, ast.IfExp):
            self.unit_of(node.test)
            return self._unify(node, self.unit_of(node.body), self.unit_of(node.orelse))
        if isinstance(node, ast.Compare):
            u = self.unit_of(node.left)
            for op, right in zip(node.ops, node.comparators):
                v = self.unit_of(right)
                if (
                    isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE, ast.Eq, ast.NotEq))
                    and u is not None
                    and v is not None
                    and u != v
                ):
                    self._find(
                        node,
                        "unit-mismatch",
                        f"comparison of {_fmt(u)} against {_fmt(v)}",
                    )
                u = v
            return None  # bool
        if isinstance(node, ast.BoolOp):
            for v in node.values:
                self.unit_of(v)
            return None
        if isinstance(node, ast.BinOp):
            return self._binop(node)
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            for e in node.elts:
                self.unit_of(e)
            return None
        if isinstance(node, ast.Starred):
            return self.unit_of(node.value)
        if isinstance(node, ast.NamedExpr):
            u = self.unit_of(node.value)
            self.env[node.target.id] = u
            return u
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            for gen in node.generators:
                self.unit_of(gen.iter)
            return self.unit_of(node.elt)
        return None

    def _unify(
        self, node: ast.AST, a: Optional[Unit], b: Optional[Unit]
    ) -> Optional[Unit]:
        if a is not None and b is not None and a != b:
            self._find(
                node, "unit-mismatch", f"mixing {_fmt(a)} with {_fmt(b)}"
            )
            return None
        return a if a is not None else b

    def _binop(self, node: ast.BinOp) -> Optional[Unit]:
        u = self.unit_of(node.left)
        v = self.unit_of(node.right)
        op = node.op
        if isinstance(op, (ast.Add, ast.Sub)):
            return self._unify(node, u, v)
        if isinstance(op, (ast.Mult, ast.Div, ast.FloorDiv)):
            # a bare conversion literal against a united value: the one
            # illegal form.  Routed conversions use repro.core.units.
            for lit, other, other_unit in (
                (node.right, node.left, u),
                (node.left, node.right, v),
            ):
                if (
                    not self.exempt_conversions
                    and _is_conversion_literal(lit)
                    and other_unit is not None
                    and other_unit != ONE
                ):
                    self._find(
                        node,
                        "unit-raw-conversion",
                        f"raw conversion literal "
                        f"{ast.unparse(lit)} applied to a {_fmt(other_unit)} "
                        "value; route it through repro.core.units "
                        "(ns_to_s, NS_PER_S, ...)",
                    )
                    return None
            if u is None and _is_scalar_literal(node.left):
                u = ONE
            if v is None and _is_scalar_literal(node.right):
                v = ONE
            if u is None or v is None:
                return None
            if isinstance(op, ast.Mult):
                return _mul(u, v)
            return _div(u, v)
        if isinstance(op, ast.Mod):
            return self._unify(node, u, v)
        return None

    def _call(self, node: ast.Call) -> Optional[Unit]:  # noqa: C901
        for kw in node.keywords:
            self.unit_of(kw.value)
        name = _final_name(node.func)
        args = node.args

        if name == "unit" and len(args) == 2:
            # annotations.unit("ns", expr): the declaration wins; a known
            # contradicting inner unit is a mismatch
            inner = self.unit_of(args[1])
            if isinstance(args[0], ast.Constant) and isinstance(args[0].value, str):
                declared = _parse_unit_string(args[0].value)
                if declared is not None:
                    if inner is not None and inner != declared:
                        self._find(
                            node,
                            "unit-mismatch",
                            f"unit({args[0].value!r}, ...) wraps a "
                            f"{_fmt(inner)} expression",
                        )
                    return declared
            return inner

        arg_units = [self.unit_of(a) for a in args]

        if name in _HELPERS:
            expect, out = _HELPERS[name]
            if (
                args
                and expect is not None
                and arg_units[0] is not None
                and arg_units[0] != expect
            ):
                self._find(
                    node,
                    "unit-mismatch",
                    f"{name}() expects a {_fmt(expect)} input, got "
                    f"{_fmt(arg_units[0])}",
                )
            return out
        if name in _SELECT_FUNCS and len(args) == 3:
            return self._unify(node, arg_units[1], arg_units[2])
        if name in _PASS_THROUGH_FUNCS and args:
            known = [x for x in arg_units if x is not None]
            if name in ("max", "min", "maximum", "minimum") and len(known) > 1:
                first = known[0]
                for other in known[1:]:
                    if other != first:
                        self._find(
                            node,
                            "unit-mismatch",
                            f"{name}() over mixed units "
                            f"{_fmt(first)} and {_fmt(other)}",
                        )
                        return None
            return arg_units[0] if arg_units else None
        if (
            name in _PASS_THROUGH_METHODS
            and isinstance(node.func, ast.Attribute)
            and not args
        ):
            return self.unit_of(node.func.value)
        if name is not None and name in self.summaries:
            return self.summaries[name]
        return None

    # -- statements ------------------------------------------------------- #

    def run(self) -> None:
        self._block(self.fn.body)

    def _block(self, stmts: Sequence[ast.stmt]) -> None:  # noqa: C901
        for st in stmts:
            if isinstance(st, ast.Assign):
                u = self.unit_of(st.value)
                for tgt in st.targets:
                    self._assign(tgt, u, st)
            elif isinstance(st, ast.AnnAssign):
                if st.value is not None:
                    self._assign(st.target, self.unit_of(st.value), st)
            elif isinstance(st, ast.AugAssign):
                u = self.unit_of(st.value)
                tgt_u = self.unit_of(st.target)
                if isinstance(st.op, (ast.Add, ast.Sub)):
                    self._unify(st, tgt_u, u)
                elif isinstance(st.op, ast.Mult) and tgt_u is not None and u is not None:
                    self._assign(st.target, _mul(tgt_u, u), st, check=False)
                elif isinstance(st.op, ast.Div) and tgt_u is not None and u is not None:
                    self._assign(st.target, _div(tgt_u, u), st, check=False)
            elif isinstance(st, ast.Return):
                if st.value is not None:
                    self.return_units.append(self.unit_of(st.value))
                else:
                    self.return_units.append(None)
            elif isinstance(st, ast.Expr):
                self.unit_of(st.value)
            elif isinstance(st, (ast.If, ast.While)):
                self.unit_of(st.test)
                self._block(st.body)
                self._block(st.orelse)
            elif isinstance(st, ast.For):
                self.unit_of(st.iter)
                self._assign(st.target, None, st, check=False)
                self._block(st.body)
                self._block(st.orelse)
            elif isinstance(st, ast.With):
                for item in st.items:
                    self.unit_of(item.context_expr)
                self._block(st.body)
            elif isinstance(st, ast.Try):
                self._block(st.body)
                for h in st.handlers:
                    self._block(h.body)
                self._block(st.orelse)
                self._block(st.finalbody)
            elif isinstance(st, ast.FunctionDef):
                sub = _FuncAnalysis(
                    self.sf, st, self.summaries, self.emit, self.checker,
                    self.exempt_conversions, outer_env=self.env,
                )
                sub.run()
                sub.check_return_suffix()
            # class defs / imports / pass / etc: nothing to do

    def _assign(
        self, tgt: ast.AST, u: Optional[Unit], st: ast.stmt, check: bool = True
    ) -> None:
        if isinstance(tgt, ast.Name):
            declared = _seed_for(tgt.id)
            if check and declared is not None and u is not None and u != declared:
                self._find(
                    st,
                    "unit-mismatch",
                    f"assigning a {_fmt(u)} value to {tgt.id!r} "
                    f"(declared {_fmt(declared)} by suffix)",
                )
            self.env[tgt.id] = declared if declared is not None else u
        elif isinstance(tgt, ast.Attribute):
            declared = _seed_for(tgt.attr)
            if check and declared is not None and u is not None and u != declared:
                self._find(
                    st,
                    "unit-mismatch",
                    f"assigning a {_fmt(u)} value to attribute "
                    f"{tgt.attr!r} (declared {_fmt(declared)} by suffix)",
                )
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self._assign(e, None, st, check=False)
        elif isinstance(tgt, ast.Starred):
            self._assign(tgt.value, None, st, check=False)

    # -- function-suffix return contract ---------------------------------- #

    def check_return_suffix(self) -> Optional[Unit]:
        """Emit unit-return findings; give back the inferred return unit."""
        declared = _seed_for(self.fn.name)
        inferred: Optional[Unit] = None
        consistent = True
        for u in self.return_units:
            if u is None:
                consistent = False
                continue
            if declared is not None and u != declared:
                self._find(
                    self.fn,
                    "unit-return",
                    f"{self.fn.name}() is declared {_fmt(declared)} by "
                    f"suffix but returns a {_fmt(u)} value",
                )
            if inferred is None:
                inferred = u
            elif inferred != u:
                consistent = False
        if declared is not None:
            return declared
        return inferred if consistent else None


def _parse_unit_string(s: str) -> Optional[Unit]:
    s = s.strip()
    if "/" in s:
        num, _, den = s.partition("/")
        a = _parse_unit_string(num)
        b = _parse_unit_string(den)
        if a is None or b is None:
            return None
        return _div(a, b)
    return _UNIT_TOKENS.get(s)


# --------------------------------------------------------------------------- #
# the checker


def _functions(tree: ast.Module) -> Iterable[ast.FunctionDef]:
    """Module-level functions and methods (not nested functions — those are
    analyzed inline by their enclosing function's walk)."""
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            yield node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, ast.FunctionDef):
                    yield sub


def _is_exempt(sf: SourceFile) -> bool:
    return sf.rel.replace("\\", "/").endswith("repro/core/units.py")


@register
class UnitsChecker(Checker):
    """Physical-unit abstract interpretation (see module docstring)."""

    name = "units"
    rules = ("unit-mismatch", "unit-return", "unit-raw-conversion")

    def check_repo(
        self, files: Sequence[SourceFile], root: Path, config: CheckConfig
    ) -> Iterable[Finding]:
        # pass 1 — interprocedural summaries: every function's return unit,
        # inferred silently with an empty table, merged with name suffixes;
        # name collisions with conflicting units collapse to unknown.
        summaries: Dict[str, Optional[Unit]] = {}
        for sf in files:
            for fn in _functions(sf.tree):
                fa = _FuncAnalysis(
                    sf, fn, {}, None, self.name, _is_exempt(sf)
                )
                fa.run()
                u = fa.check_return_suffix()
                if fn.name in summaries and summaries[fn.name] != u:
                    summaries[fn.name] = None
                else:
                    summaries[fn.name] = u
        summaries.update({name: out for name, (_, out) in _HELPERS.items()})

        # pass 2 — flow-sensitive walk with the summary table; findings on.
        findings: List[Finding] = []
        for sf in files:
            for fn in _functions(sf.tree):
                fa = _FuncAnalysis(
                    sf, fn, summaries, findings, self.name, _is_exempt(sf)
                )
                fa.run()
                fa.check_return_suffix()
            # module-level statements (constants, scripts)
            mod_fn = ast.FunctionDef(
                name="<module>", args=ast.arguments(
                    posonlyargs=[], args=[], kwonlyargs=[], kw_defaults=[],
                    defaults=[],
                ),
                body=[
                    st for st in sf.tree.body
                    if not isinstance(st, (ast.FunctionDef, ast.ClassDef))
                ],
                decorator_list=[],
            )
            fa = _FuncAnalysis(
                sf, mod_fn, summaries, findings, self.name, _is_exempt(sf)
            )
            fa.run()
        return findings
