"""Jit-hygiene checker — the recompile/cache-bypass/host-sync bug class.

Four rules, all rooted in bugs this repo has actually shipped or documented:

* ``jit-host-sync`` — inside a function dispatched through ``jax.jit`` /
  the AOT cache / ``pallas_call``, calling ``np.*``, ``.item()``,
  ``float()`` / ``int()`` / ``bool()`` on a traced value (or branching on
  one) forces a host synchronization or a trace error.  Static
  (``static_argnames``) parameters are not traced and are exempt via a
  per-function taint pass.
* ``jit-aot-bypass`` — ``.lower(...).compile()`` outside the
  :class:`~repro.core.aot.AotDispatchCache` ``build`` convention: AOT
  compilation does not populate jit's own cache, so a bypassing site
  compiles once per call site *and* once per jit path (the documented
  footgun in ``core/aot.py``).
* ``jit-donate`` — pipeline entry points (``CheckConfig.donate_required``)
  take donated staging planes; jitting them without ``donate_argnums``
  silently doubles peak device memory for every dispatch.
* ``jit-f64`` — ``float64`` dtypes inside jitted/kernel functions leak f64
  into the f32 kernel path (x64 is disabled: they quietly downcast, or
  upcast whole intermediates when enabled).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .findings import Finding
from .framework import CheckConfig, Checker, SourceFile, register

__all__ = ["JitHygieneChecker"]

_CAST_BUILTINS = ("float", "int", "bool")
_NP_NAMES = ("np", "numpy")


def _func_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _first_arg_name(call: ast.Call) -> Optional[str]:
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    return None


def _str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    if isinstance(node, (ast.Tuple, ast.List)) and all(
        isinstance(e, ast.Constant) and isinstance(e.value, str) for e in node.elts
    ):
        return tuple(e.value for e in node.elts)
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    return None


# attribute reads that are static under tracing: using them never
# concretizes the traced value, so they don't propagate taint
_STATIC_ATTRS = ("shape", "ndim", "dtype", "size", "sharding")


def _mentions(node: ast.AST, names: Set[str]) -> bool:
    """True when ``node`` references a tainted name through a non-static
    path (``x.shape[0]`` is static metadata, not the traced value)."""
    stack = [node]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.Name) and n.id in names:
            return True
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            continue
        stack.extend(ast.iter_child_nodes(n))
    return False


def _is_noneness_test(node: ast.AST) -> bool:
    """True for tests that only check identity-with-None (not traced)."""
    if isinstance(node, ast.BoolOp):
        return all(_is_noneness_test(v) for v in node.values)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
        return _is_noneness_test(node.operand)
    if isinstance(node, ast.Compare):
        return all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)
    return False


class _JitSite:
    """One function dispatched on device, plus how it was jitted."""

    def __init__(self, fn: ast.FunctionDef, static: Tuple[str, ...], kind: str):
        self.fn = fn
        self.static = static
        self.kind = kind  # 'jit' | 'pallas'


def _collect_sites(sf: SourceFile) -> Tuple[List[_JitSite], List[ast.Call]]:
    """Find jitted/kernel functions defined in this module and every
    ``jax.jit(...)`` call (for the donate rule)."""
    defs: Dict[str, ast.FunctionDef] = {
        n.name: n for n in ast.walk(sf.tree) if isinstance(n, ast.FunctionDef)
    }
    # simple string-tuple assignments anywhere in the module, so
    # ``static_argnames=_static`` resolves through the local alias
    str_tuples: Dict[str, Tuple[str, ...]] = {}
    for n in ast.walk(sf.tree):
        if isinstance(n, ast.Assign) and len(n.targets) == 1:
            t = n.targets[0]
            v = _str_tuple(n.value)
            if isinstance(t, ast.Name) and v is not None:
                str_tuples[t.id] = v

    jit_calls: List[ast.Call] = []
    sites: Dict[str, _JitSite] = {}
    for n in ast.walk(sf.tree):
        if not isinstance(n, ast.Call):
            continue
        fname = _func_name(n)
        if fname == "jit":
            jit_calls.append(n)
            target = _first_arg_name(n)
            if target in defs:
                static: Tuple[str, ...] = ()
                for kw in n.keywords:
                    if kw.arg == "static_argnames":
                        static = _str_tuple(kw.value) or str_tuples.get(
                            getattr(kw.value, "id", ""), ()
                        )
                prev = sites.get(target)
                merged = static if prev is None else tuple(
                    dict.fromkeys(prev.static + static)
                )
                sites[target] = _JitSite(defs[target], merged, "jit")
        elif fname == "pallas_call":
            target = _first_arg_name(n)
            if target in defs:
                sites[target] = _JitSite(defs[target], (), "pallas")
    # decorator forms: @jax.jit / @jit / @partial(jax.jit, ...)
    for name, fn in defs.items():
        for dec in fn.decorator_list:
            dn = None
            static: Tuple[str, ...] = ()
            if isinstance(dec, (ast.Name, ast.Attribute)):
                dn = dec.id if isinstance(dec, ast.Name) else dec.attr
            elif isinstance(dec, ast.Call):
                dn = _func_name(dec)
                if dn == "partial":
                    inner = dec.args[0] if dec.args else None
                    dn = (
                        _func_name(ast.Call(func=inner, args=[], keywords=[]))
                        if isinstance(inner, (ast.Name, ast.Attribute))
                        else None
                    )
                    for kw in dec.keywords:
                        if kw.arg == "static_argnames":
                            static = _str_tuple(kw.value) or ()
            if dn == "jit":
                sites[name] = _JitSite(fn, static, "jit")
    return list(sites.values()), jit_calls


def _taint(fn: ast.FunctionDef, static: Sequence[str]) -> Set[str]:
    """Names (conservatively) carrying traced values inside ``fn``."""
    args = fn.args
    params = [
        a.arg
        for a in (
            args.posonlyargs + args.args + args.kwonlyargs
            + ([args.vararg] if args.vararg else [])
            + ([args.kwarg] if args.kwarg else [])
        )
    ]
    tainted = {p for p in params if p not in static}
    # forward-propagate through assignments until fixpoint (loop-carried
    # names converge in <= depth-of-nesting passes; cap defensively)
    for _ in range(10):
        changed = False
        for n in ast.walk(fn):
            if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = n.value
                if value is None or not _mentions(value, tainted):
                    continue
                targets = (
                    n.targets if isinstance(n, ast.Assign) else [n.target]
                )
                for t in targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Name) and leaf.id not in tainted:
                            tainted.add(leaf.id)
                            changed = True
        if not changed:
            break
    return tainted


@register
class JitHygieneChecker(Checker):
    name = "jit"
    rules = ("jit-host-sync", "jit-aot-bypass", "jit-donate", "jit-f64")

    def check_file(
        self, sf: SourceFile, config: CheckConfig
    ) -> Iterable[Finding]:
        findings: List[Finding] = []
        sites, jit_calls = _collect_sites(sf)

        for site in sites:
            tainted = _taint(site.fn, site.static)
            where = f"{site.kind} function '{site.fn.name}'"
            for n in ast.walk(site.fn):
                if isinstance(n, ast.Call):
                    fname = _func_name(n)
                    base = n.func.value if isinstance(n.func, ast.Attribute) else None
                    if (
                        fname == "item"
                        and base is not None
                        and _mentions(base, tainted)
                    ):
                        findings.append(sf.finding(
                            n, "jit-host-sync",
                            f".item() on a traced value inside {where} "
                            "forces a device sync per trace",
                            checker="jit",
                        ))
                    elif (
                        isinstance(n.func, ast.Name)
                        and n.func.id in _CAST_BUILTINS
                        and any(_mentions(a, tainted) for a in n.args)
                    ):
                        findings.append(sf.finding(
                            n, "jit-host-sync",
                            f"{n.func.id}() on a traced value inside {where} "
                            "concretizes the tracer (TracerConversionError "
                            "or silent host sync)",
                            checker="jit",
                        ))
                    elif (
                        isinstance(n.func, ast.Attribute)
                        and isinstance(base, ast.Name)
                        and base.id in _NP_NAMES
                        and (
                            any(_mentions(a, tainted) for a in n.args)
                            or any(
                                kw.value is not None
                                and _mentions(kw.value, tainted)
                                for kw in n.keywords
                            )
                        )
                    ):
                        findings.append(sf.finding(
                            n, "jit-host-sync",
                            f"np.{n.func.attr}(...) on a traced value inside "
                            f"{where} materializes the array on host; use "
                            "jnp/lax",
                            checker="jit",
                        ))
                elif isinstance(n, (ast.If, ast.While)):
                    if _mentions(n.test, tainted) and not _is_noneness_test(n.test):
                        findings.append(sf.finding(
                            n.test, "jit-host-sync",
                            f"branching on a traced value inside {where}; "
                            "use lax.cond/jnp.where (or mark the argument "
                            "static)",
                            checker="jit",
                        ))
                if (
                    isinstance(n, ast.Attribute)
                    and n.attr == "float64"
                    and isinstance(n.value, ast.Name)
                    and n.value.id in ("np", "numpy", "jnp")
                ) or (
                    isinstance(n, ast.Constant) and n.value == "float64"
                ):
                    findings.append(sf.finding(
                        n, "jit-f64",
                        f"float64 dtype inside {where} leaks f64 into the "
                        "f32 kernel path (accumulate in f64 on host, after "
                        "device_get)",
                        checker="jit",
                    ))

        # .lower(...).compile() outside the AotDispatchCache build convention
        allowed_file = sf.rel.replace("\\", "/").endswith("repro/core/aot.py")
        if not allowed_file:
            # ast.walk is breadth-first, so nested defs overwrite their
            # enclosing def's claim — the map ends up innermost-wins
            enclosing: Dict[int, str] = {}
            for fn in ast.walk(sf.tree):
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for n in ast.walk(fn):
                        enclosing[id(n)] = fn.name
            for n in ast.walk(sf.tree):
                if (
                    isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "compile"
                    and isinstance(n.func.value, ast.Call)
                    and _func_name(n.func.value) == "lower"
                    and enclosing.get(id(n)) != "build"
                ):
                    findings.append(sf.finding(
                        n, "jit-aot-bypass",
                        ".lower().compile() outside an AotDispatchCache "
                        "'build' thunk — AOT executables bypass jit's cache, "
                        "so this site recompiles per call site; route "
                        "through AotDispatchCache.get",
                        checker="jit",
                    ))

        # donate-required pipeline entry points
        for call in jit_calls:
            target = _first_arg_name(call)
            if target in config.donate_required and not any(
                kw.arg == "donate_argnums" for kw in call.keywords
            ):
                findings.append(sf.finding(
                    call, "jit-donate",
                    f"jit({target}) without donate_argnums: its staging "
                    "planes are ring-buffered for donation; not donating "
                    "doubles peak device memory per dispatch",
                    checker="jit",
                ))
        return findings
