"""Contract checker — report key-sets and event-column passthrough.

* ``summary-contract``: the dict-literal keys of ``SimReport.summary`` /
  ``FabricReport.summary`` must equal the set literals their key-lock tests
  assert — catching the recurring "new field added to the report but not the
  summary (or vice versa)" drift *before* the test run, and catching edits
  that relax the test instead of the contract.
* ``event-columns``: a ``MemEvents(...)`` (or ``MemEvents.build(...)``)
  call whose arguments are *derived from existing trace columns* (slicing,
  gathering, arithmetic on ``<x>.t_ns``-style reads) is a trace rebuild —
  it must pass ``weight=``, ``host=`` and ``qos=`` explicitly, or the
  rebuilt trace silently resets PEBS multiplicity to 1, host to 0 and the
  QoS class to 0.  This is the PR-2 ``slice_by_quantum`` bug, shipped
  twice.  Fresh-synthesis sites (``np.full``/``np.zeros`` arguments) are
  not flagged: their defaults are the correct semantics.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Set, Tuple

from .findings import Finding
from .framework import CheckConfig, Checker, SourceFile, register

__all__ = ["ContractChecker"]

COLUMNS = ("t_ns", "pool", "bytes_", "is_write", "region", "weight", "host", "qos")
# constructor positional order; 8 positionals == every column passed
_CTOR_ARITY = len(COLUMNS)
# the trailing default-carrying columns a derived rebuild must thread through
_N_PASSTHROUGH = 3
# column names distinctive enough to signal "this argument reads an existing
# trace" — generic names (pool/region/host) appear on non-trace objects
# (``self.host``, ``region.pool``) and would false-positive
_DERIVED_MARKERS = ("t_ns", "bytes_", "is_write", "weight")


def _dict_literal_keys(fn: ast.FunctionDef) -> Optional[Tuple[ast.Dict, Set[str]]]:
    """The first all-string-keys dict literal in ``fn`` (the summary body)."""
    for n in ast.walk(fn):
        if isinstance(n, ast.Dict) and n.keys and all(
            isinstance(k, ast.Constant) and isinstance(k.value, str)
            for k in n.keys
        ):
            return n, {k.value for k in n.keys}  # type: ignore[union-attr]
    return None


def _test_key_set(fn: ast.FunctionDef) -> Optional[Set[str]]:
    """The key set a key-lock test asserts: the set literal assigned to
    ``base`` when present, else the largest string-set literal."""
    named: Optional[Set[str]] = None
    best: Optional[Set[str]] = None
    for n in ast.walk(fn):
        if isinstance(n, ast.Set) and all(
            isinstance(e, ast.Constant) and isinstance(e.value, str)
            for e in n.elts
        ):
            s = {e.value for e in n.elts}  # type: ignore[union-attr]
            if best is None or len(s) > len(best):
                best = s
        if isinstance(n, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "base" for t in n.targets
        ):
            if isinstance(n.value, ast.Set):
                named = {
                    e.value
                    for e in n.value.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)
                }
    return named or best


def _find_method(
    tree: ast.AST, cls_name: str, method: str
) -> Optional[ast.FunctionDef]:
    for cls in ast.walk(tree):
        if isinstance(cls, ast.ClassDef) and cls.name == cls_name:
            for fn in cls.body:
                if isinstance(fn, ast.FunctionDef) and fn.name == method:
                    return fn
    return None


def _find_function(tree: ast.AST, name: str) -> Optional[ast.FunctionDef]:
    for fn in ast.walk(tree):
        if isinstance(fn, ast.FunctionDef) and fn.name == name:
            return fn
    return None


def _is_memevents_call(call: ast.Call) -> Optional[str]:
    """'ctor' for ``MemEvents(...)``, 'build' for ``MemEvents.build(...)``."""
    f = call.func
    if isinstance(f, ast.Name) and f.id == "MemEvents":
        return "ctor"
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
        if f.value.id == "MemEvents" and f.attr == "build":
            return "build"
    return None


def _reads_columns(call: ast.Call) -> bool:
    exprs = list(call.args) + [kw.value for kw in call.keywords]
    for e in exprs:
        for n in ast.walk(e):
            if isinstance(n, ast.Attribute) and n.attr in _DERIVED_MARKERS:
                return True
    return False


@register
class ContractChecker(Checker):
    name = "contracts"
    rules = ("summary-contract", "event-columns")

    # ------------------------------------------------------------------ #
    # event-columns: per file
    # ------------------------------------------------------------------ #

    def check_file(
        self, sf: SourceFile, config: CheckConfig
    ) -> Iterable[Finding]:
        findings: List[Finding] = []
        for n in ast.walk(sf.tree):
            if not isinstance(n, ast.Call):
                continue
            kind = _is_memevents_call(n)
            if kind is None or not _reads_columns(n):
                continue
            kwargs = {kw.arg for kw in n.keywords}
            missing = [
                c
                for i, c in enumerate(
                    COLUMNS[-_N_PASSTHROUGH:], start=_CTOR_ARITY - _N_PASSTHROUGH
                )
                if c not in kwargs and (kind == "build" or len(n.args) <= i)
            ]
            if kind == "build" and missing:
                findings.append(sf.finding(
                    n, "event-columns",
                    "MemEvents.build() on derived trace columns cannot carry "
                    f"{'/'.join(missing)}; use the MemEvents constructor and "
                    "pass them explicitly",
                    checker="contracts",
                ))
            elif missing:
                findings.append(sf.finding(
                    n, "event-columns",
                    "trace rebuild from existing columns drops "
                    f"{'/'.join(missing)} (resets to exact-weight/host-0); "
                    "thread the source trace's columns through",
                    checker="contracts",
                ))
        return findings

    # ------------------------------------------------------------------ #
    # summary-contract: repo level
    # ------------------------------------------------------------------ #

    def check_repo(
        self, files: Sequence[SourceFile], root: Path, config: CheckConfig
    ) -> Iterable[Finding]:
        findings: List[Finding] = []
        for impl_rel, cls_name, test_rel, test_fn in config.summary_contracts:
            impl_path = root / impl_rel
            test_path = root / test_rel
            if not impl_path.exists() or not test_path.exists():
                continue  # partial checkouts (fixture runs) skip the pair
            impl_tree = ast.parse(impl_path.read_text())
            method = _find_method(impl_tree, cls_name, "summary")
            test_tree = ast.parse(test_path.read_text())
            test = _find_function(test_tree, test_fn)
            if method is None or test is None:
                findings.append(Finding(
                    impl_rel, 1, 1, "summary-contract",
                    f"cannot locate {cls_name}.summary or {test_fn} — the "
                    "key-lock contract pair is broken",
                    "contracts",
                ))
                continue
            got = _dict_literal_keys(method)
            want = _test_key_set(test)
            if got is None or want is None:
                findings.append(Finding(
                    impl_rel, method.lineno, 1, "summary-contract",
                    f"{cls_name}.summary must build a dict literal and "
                    f"{test_fn} must assert a set literal (found neither)",
                    "contracts",
                ))
                continue
            node, keys = got
            extra = keys - want
            lacking = want - keys
            if extra or lacking:
                parts = []
                if extra:
                    parts.append(
                        f"summary has keys the test does not lock: "
                        f"{sorted(extra)}"
                    )
                if lacking:
                    parts.append(
                        f"test locks keys summary does not emit: "
                        f"{sorted(lacking)}"
                    )
                findings.append(Finding(
                    impl_rel, node.lineno, node.col_offset + 1,
                    "summary-contract",
                    f"{cls_name}.summary() vs {test_fn}: " + "; ".join(parts),
                    "contracts",
                ))
        return findings
