"""Architecture registry + assigned input shapes + input_specs.

40 assigned cells = 10 archs × 4 shapes.  ``cells()`` enumerates the
runnable ones and records every skip with its reason (full-attention archs
skip long_500k; the encoder-only arch skips decode shapes) — see DESIGN.md
§Arch-applicability.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import Model, ModelConfig

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "Shape",
    "cells",
    "get_config",
    "get_smoke",
    "input_specs",
]

_MODULES = {
    "mistral-large-123b": "mistral_large_123b",
    "chatglm3-6b": "chatglm3_6b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-0.6b": "qwen3_0_6b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "mamba2-2.7b": "mamba2_2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}

# families whose attention is full/quadratic -> long_500k skipped
_FULL_ATTENTION = ("dense", "moe", "vlm")
# sub-quadratic families run long_500k
_SUBQUADRATIC = ("ssm", "hybrid")


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str, shape: Optional[str] = None) -> ModelConfig:
    mod = _module(arch)
    cfg = mod.CONFIG
    if shape == "long_500k" and hasattr(mod, "LONG"):
        cfg = mod.LONG  # e.g. Jamba enables windowed attention at 500k
    return cfg


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def cells() -> List[Dict[str, Any]]:
    """All 40 (arch × shape) cells with runnable flag + skip reason."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            skip = None
            if shape.kind == "decode" and cfg.family == "audio":
                skip = "encoder-only: no decode step"
            elif sname == "long_500k" and cfg.family in _FULL_ATTENTION:
                skip = "full quadratic attention: 500k decode infeasible by design"
            out.append(
                {"arch": arch, "shape": sname, "runnable": skip is None, "skip": skip}
            )
    return out


# --------------------------------------------------------------------------- #
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------- #


def input_specs(
    cfg: ModelConfig, shape: Shape, batch_override: Optional[int] = None
) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree for one step of (cfg × shape).

    train:   {'tokens'|'embeds', 'labels'}
    prefill: {'tokens'|'embeds'}
    decode:  {'caches', 'token'|'embed', 'cache_len'}
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.embed_inputs:
            inp = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        else:
            inp = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)}
        inp["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return inp
    if shape.kind == "prefill":
        if cfg.embed_inputs:
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)}
    if shape.kind == "decode":
        model = Model(cfg)
        caches = jax.eval_shape(lambda: model.init_caches(B, S))
        if cfg.embed_inputs:
            tok = {"token": jax.ShapeDtypeStruct((B, 1), i32)}
        else:
            tok = {"embed": jax.ShapeDtypeStruct((B, 1, cfg.d_model), cfg.dtype)}
        return {"caches": caches, **tok, "cache_len": jax.ShapeDtypeStruct((), i32)}
    raise ValueError(shape.kind)
