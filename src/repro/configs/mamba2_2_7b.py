"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

Pure Mamba2: no attention, no MLP (d_ff=0); inner width 2·d_model = 5120,
80 heads of 64.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_head=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=80,
    ssm_d_head=64,
    rope_variant="none",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_head=16,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_heads=8,
    ssm_d_head=16,
    ssm_chunk=32,
    rope_variant="none",
    tie_embeddings=True,
)
