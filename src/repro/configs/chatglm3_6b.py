"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d, GQA.  [arXiv:2406.12793; hf]"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_head=128,
    d_ff=13696,
    vocab_size=65024,
    rope_variant="rope2d",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="chatglm3-6b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_head=16,
    d_ff=256,
    vocab_size=512,
    rope_variant="rope2d",
    tie_embeddings=False,
)
