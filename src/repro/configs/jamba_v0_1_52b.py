"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]

Groups of 8 sublayers: 7 Mamba2 + 1 attention (1:7); FFNs alternate dense /
MoE (MoE every other layer, 16 experts top-2).  The ``long`` variant enables
sliding-window attention on the (rare) attention layers so the 500k decode
shape stays sub-quadratic.
"""

import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    moe_d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    attn_every=8,
    ssm_state=128,
    ssm_heads=128,  # inner width 2·d_model = 8192, head dim 64
    ssm_d_head=64,
    rope_variant="rope",
    tie_embeddings=False,
)

# long-context variant: windowed attention on attention sublayers
LONG = dataclasses.replace(CONFIG, window=4096)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    moe_d_ff=128,
    vocab_size=512,
    n_experts=4,
    top_k=2,
    attn_every=4,
    ssm_state=16,
    ssm_heads=8,
    ssm_d_head=16,
    ssm_chunk=32,
    rope_variant="rope",
    tie_embeddings=False,
)
