"""hubert-xlarge [audio] — 48L d_model=1280 16H (GQA kv=16) d_ff=5120
vocab=504 — encoder-only, same arch as w2v2.  [arXiv:2106.07447; unverified]

Backbone only: the CNN feature extractor is a stub (``input_specs`` provides
precomputed frame embeddings).  Encoder-only: bidirectional attention,
LayerNorm + GELU MLP, no decode step (decode shapes are skipped).
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_head=80,
    d_ff=5120,
    vocab_size=504,
    rope_variant="none",
    causal=False,
    norm="ln",
    embed_inputs=False,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=64,
    rope_variant="none",
    causal=False,
    norm="ln",
    embed_inputs=False,
    tie_embeddings=False,
)
