"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Maverick interleaves dense and MoE layers 1:1 (moe_interleave=2) and adds a
shared expert on MoE layers; with 128 routed experts top-1 this lands at
~400B total / ~17B active.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    moe_d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    moe_interleave=2,
    shared_expert=True,
    rope_variant="rope",
    rope_theta=500_000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llama4-maverick-400b-a17b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    moe_d_ff=128,
    vocab_size=512,
    n_experts=8,
    top_k=1,
    moe_interleave=2,
    shared_expert=True,
    rope_variant="rope",
    tie_embeddings=False,
)
