"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Backbone only, per the assignment: the vision frontend is a stub —
``input_specs`` provides precomputed patch embeddings [B, S, d_model];
M-RoPE (3-section rotary: temporal/height/width) runs on stub positions.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab_size=152064,
    rope_variant="mrope",
    rope_theta=1_000_000.0,
    embed_inputs=False,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b-smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_head=16,
    d_ff=256,
    vocab_size=512,
    rope_variant="mrope",
    embed_inputs=False,
    tie_embeddings=False,
)
