"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,  # explicit head dim (16·128 = 2048 > d_model, per Qwen3)
    d_ff=3072,
    vocab_size=151936,
    rope_variant="rope",
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen3-0.6b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,  # head dim decoupled from d_model/n_heads, like the real arch
    d_ff=128,
    vocab_size=512,
    rope_variant="rope",
    qk_norm=True,
    tie_embeddings=True,
)
