"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Every layer is MoE (interleave 1); expert hidden width is 512.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    moe_d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    moe_interleave=1,
    rope_variant="rope",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-moe-3b-a800m-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=64,
    moe_d_ff=64,
    vocab_size=512,
    n_experts=8,
    top_k=2,
    moe_interleave=1,
    rope_variant="rope",
    tie_embeddings=True,
)
