"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173; hf]"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_head=128,
    d_ff=12288,
    vocab_size=49152,
    rope_variant="rope",
    mlp_gated=False,  # StarCoder2 uses a plain GELU MLP
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="starcoder2-3b-smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_head=16,
    d_ff=192,
    vocab_size=512,
    rope_variant="rope",
    mlp_gated=False,
    tie_embeddings=True,
)
