"""Deterministic synthetic data pipeline, sharded + prefetched.

Real corpora are out of scope offline; the pipeline is still a *real*
pipeline: deterministic per-(step, shard) token generation (splittable
counter-based generator, so any host can regenerate any shard — this is what
makes checkpoint-restart and elastic re-sharding trivially consistent),
host-side prefetch queue, and device put with the right sharding.

Targets next-token prediction: labels are tokens shifted left (last label
masked).  For embed-input families it synthesizes embeddings instead.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticPipeline"]


class SyntheticPipeline:
    def __init__(
        self,
        cfg,  # ModelConfig
        batch: int,
        seq_len: int,
        seed: int = 0,
        n_hosts: int = 1,
        host_id: int = 0,
        prefetch: int = 2,
        sharding: Optional[Any] = None,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.n_hosts = n_hosts
        self.host_id = host_id
        assert batch % n_hosts == 0, "global batch must divide across hosts"
        self.local_batch = batch // n_hosts
        self.sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------ #

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for (step, host): restartable anywhere."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        if self.cfg.embed_inputs:
            toks = rng.integers(
                0, self.cfg.vocab_size, (self.local_batch, self.seq_len + 1), dtype=np.int32
            )
            out = {"tokens": toks[:, :-1]}
            labels = toks[:, 1:].copy()
        else:
            out = {
                "embeds": rng.standard_normal(
                    (self.local_batch, self.seq_len, self.cfg.d_model), dtype=np.float32
                )
            }
            labels = rng.integers(
                0, self.cfg.vocab_size, (self.local_batch, self.seq_len), dtype=np.int32
            )
            labels[:, -1] = -1
        out["labels"] = labels
        return out

    def device_batch(self, step: int):
        b = self.batch_at(step)
        if self.sharding is not None:
            return {
                k: jax.device_put(v, self.sharding[k] if isinstance(self.sharding, dict) else self.sharding)
                for k, v in b.items()
            }
        return {k: jnp.asarray(v) for k, v in b.items()}

    # ------------------------------------------------------------------ #
    # background prefetch
    # ------------------------------------------------------------------ #

    def start(self, first_step: int = 0):
        self._stop.clear()

        def worker():
            step = first_step
            while not self._stop.is_set():
                try:
                    self._q.put(self.device_batch(step), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        while True:
            yield self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
