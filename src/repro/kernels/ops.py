"""Jit'd public wrappers for the Pallas kernels with implementation dispatch.

Three implementations per op:

  * ``'pallas'``           — compiled TPU kernel (the production path),
  * ``'pallas_interpret'`` — same kernel body executed by the Pallas
                             interpreter (CPU-correctness path; used by tests),
  * ``'ref'``              — pure-jnp oracle (GSPMD-partitionable; used by the
                             multi-pod dry-run, since Pallas TPU kernels do
                             not lower on the CPU host platform).

Default: ``'pallas'`` when a TPU is present, else ``'ref'``.  Override
globally with :func:`set_implementation` or per-call with ``impl=``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..analysis.annotations import axes
from . import ref
from .congestion import congestion_cascade as _cascade_pallas
from .congestion import congestion_cascade_hosts as _cascade_hosts_pallas
from .congestion import congestion_scan as _congestion_pallas
from .congestion import qos_congestion_cascade as _qos_cascade_pallas
from .flash_attention import flash_attention as _flash_pallas
from .ssd_scan import ssd_scan as _ssd_pallas

__all__ = [
    "attention",
    "chain_cascade",
    "congestion_cascade",
    "congestion_queue",
    "get_implementation",
    "qos_congestion_cascade",
    "set_implementation",
    "ssd",
    "staging_sort",
    "two_run_merge",
]

_IMPL: Optional[str] = None
_VALID = ("pallas", "pallas_interpret", "ref")


def _default_impl() -> str:
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:
        platform = "cpu"
    return "pallas" if platform == "tpu" else "ref"


def get_implementation() -> str:
    global _IMPL
    if _IMPL is None:
        _IMPL = _default_impl()
    return _IMPL


def set_implementation(impl: str) -> None:
    if impl not in _VALID:
        raise ValueError(f"impl must be one of {_VALID}")
    global _IMPL
    _IMPL = impl


def _resolve(impl: Optional[str]) -> str:
    if impl is not None:
        if impl not in _VALID:
            raise ValueError(f"impl must be one of {_VALID}")
        return impl
    return get_implementation()


# --------------------------------------------------------------------------- #


@axes("B,H,Sq,D", k="B,Hk,Sk,D", v="B,Hk,Sk,D")
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_offset: int = 0,
    causal: bool = True,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
    block_q: int = 256,
    block_k: int = 512,
) -> jnp.ndarray:
    """GQA attention: q [B,H,Sq,D] × kv [B,Hk,Sk,D] -> [B,H,Sq,D]."""
    i = _resolve(impl)
    if i == "ref":
        return ref.mha_attention(q, k, v, causal=causal, scale=scale, q_offset=q_offset)
    return _flash_pallas(
        q, k, v,
        q_offset=q_offset, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=(i == "pallas_interpret"),
    )


@axes("B,L,H,P", dt="B,L,H", A="H", Bm="B,L,N", Cm="B,L,N")
def ssd(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    chunk: int = 128,
    impl: Optional[str] = None,
) -> jnp.ndarray:
    """Mamba2 SSD mixer: x [B,L,H,P] -> y [B,L,H,P]."""
    i = _resolve(impl)
    if i == "ref":
        return ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=min(chunk, x.shape[1]))
    return _ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=(i == "pallas_interpret"))


@axes("N", mask="N")
def congestion_queue(
    t_sorted: jnp.ndarray,
    mask: jnp.ndarray,
    stt,
    impl: Optional[str] = None,
    block: int = 2048,
):
    """Serial-queue scan for one switch; returns (start_times, delays)."""
    i = _resolve(impl)
    if i == "ref":
        start = ref.serial_queue(t_sorted, mask, stt)
        return start, jnp.where(mask, start - t_sorted, 0.0)
    return _congestion_pallas(
        t_sorted, mask, stt, block=block, interpret=(i == "pallas_interpret")
    )


@axes("N", route_bits="N", stts="S", hosts="N")
def congestion_cascade(
    t_sorted: jnp.ndarray,
    route_bits: jnp.ndarray,
    stts: jnp.ndarray,
    impl: Optional[str] = None,
    block: int = 2048,
    merge_plan=None,
    hosts: Optional[jnp.ndarray] = None,
    n_hosts: int = 1,
):
    """Fused S-stage congestion cascade over one time-sorted epoch.

    Returns ``(t_final, slot_idx, per_stage_delay)``; see
    :func:`repro.kernels.ref.serial_queue_cascade` for the semantics.
    ``merge_plan`` (static, from :func:`repro.core.analyzer.plan_cascade`)
    prunes inter-stage merges on the ``'ref'`` path; the Pallas kernel
    always runs the conservative (always-valid) schedule.

    With ``hosts`` (per-event host ids, same sorted order as ``t_sorted``),
    ``per_stage_delay`` becomes host-segmented ``[S, n_hosts]`` — the Pallas
    path accumulates the per-host sums in its SMEM stage carries.
    """
    i = _resolve(impl)
    if i == "ref":
        return ref.serial_queue_cascade(
            t_sorted, route_bits, stts, merge_plan, hosts=hosts, n_hosts=n_hosts
        )
    if hosts is None:
        return _cascade_pallas(
            t_sorted, route_bits, stts, block=block,
            interpret=(i == "pallas_interpret"),
        )
    return _cascade_hosts_pallas(
        t_sorted, route_bits, hosts, stts, n_hosts=n_hosts, block=block,
        interpret=(i == "pallas_interpret"),
    )


@axes(
    "N", route_bits="N", stts="S", qos="N", disc_code="S",
    class_weights="S,C", hosts="N",
)
def qos_congestion_cascade(
    t_sorted: jnp.ndarray,
    route_bits: jnp.ndarray,
    stts: jnp.ndarray,
    qos: jnp.ndarray,
    disc_code: jnp.ndarray,
    class_weights: jnp.ndarray,
    impl: Optional[str] = None,
    block: int = 2048,
    hosts: Optional[jnp.ndarray] = None,
    n_hosts: int = 1,
):
    """QoS-arbitrated congestion cascade (priority / WFQ / FIFO per switch).

    Data-driven form: ``disc_code`` ([S] i32, :data:`repro.kernels.ref.DISC_FIFO`
    etc.) and ``class_weights`` ([S, C] f32) are runtime arrays, so one
    lowering serves every discipline/weight mix.  Returns ``(t_final,
    slot_idx, per_stage_delay[S, n_hosts, C])``; see
    :func:`repro.kernels.ref.qos_cascade_dyn` for the semantics.

    The Pallas kernel is single-host (its SMEM carries are per class); the
    host-segmented decomposition routes to the ref, which the shared-fabric
    analyzer uses anyway (``impl='inline'``).
    """
    i = _resolve(impl)
    if i == "ref" or hosts is not None:
        return ref.qos_cascade_dyn(
            t_sorted, route_bits, stts, qos, disc_code, class_weights,
            hosts=hosts, n_hosts=n_hosts,
        )
    t_fin, idx, delay = _qos_cascade_pallas(
        t_sorted, route_bits, qos, stts, disc_code, class_weights,
        block=block, interpret=(i == "pallas_interpret"),
    )
    return t_fin, idx, delay[:, None, :]


@axes("N", lead="N")
def two_run_merge(x, lead, *payloads, impl: Optional[str] = None):
    """Stable merge of two interleaved sorted runs (envelope formulation).

    All implementations route to the XLA ref: the cummax/searchsorted/
    scatter formulation is already a handful of fused elementwise passes, so
    a hand-written Pallas body has nothing left to win on current backends.
    """
    _resolve(impl)
    return ref.two_run_merge(x, lead, *payloads)


@axes("N")
def staging_sort(x, run_caps, *payloads, impl: Optional[str] = None):
    """On-device stable sort of concatenated sorted runs (merge tree of
    :func:`two_run_merge` rounds); bitwise-equal to a host stable argsort of
    the run-major concatenation.  Ref-only, as for :func:`two_run_merge`."""
    _resolve(impl)
    return ref.staging_sort(x, run_caps, *payloads)


@axes("W", idx_pack="W", stts="D")
def chain_cascade(t_pack, idx_pack, stts, seg_caps, impl: Optional[str] = None):
    """Compact suffix cascade over per-stage packed sorted runs — the
    device-resident pipeline's fused merge+scan.  Ref-only, as for
    :func:`two_run_merge`."""
    _resolve(impl)
    return ref.chain_cascade(t_pack, idx_pack, stts, seg_caps)
