"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics the kernels must match bit-for-bit (up to fp
accumulation order).  Tests sweep shapes/dtypes and assert_allclose against
these functions with the kernels run in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "chain_cascade",
    "merge_sorted_runs",
    "serial_queue",
    "serial_queue_cascade",
    "staging_sort",
    "two_run_merge",
    "mha_attention",
    "ssd_naive",
    "ssd_chunked",
]


# --------------------------------------------------------------------------- #
# congestion kernel oracle
# --------------------------------------------------------------------------- #


def serial_queue(t_sorted: jnp.ndarray, mask: jnp.ndarray, stt) -> jnp.ndarray:
    """Start times of a FIFO queue with constant service time over the masked
    subsequence of a time-sorted event stream; unmasked events pass through.

    out_i = max(arr_i, out_{i-1} + stt) over masked events, closed form
    out_i = cummax(arr_i − stt·rank_i) + stt·rank_i.
    """
    f32 = t_sorted.dtype
    stt = jnp.asarray(stt, f32)
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    rankf = rank.astype(f32)
    g = jnp.where(mask, t_sorted - stt * rankf, -big)
    f = jax.lax.cummax(g)
    return jnp.where(mask, f + stt * rankf, t_sorted)


def merge_sorted_runs(
    x: jnp.ndarray,
    changed: jnp.ndarray,
    *payloads: jnp.ndarray,
    within: jnp.ndarray = None,
):
    """Restore sortedness of ``x`` after a masked serial-queue update.

    ``x`` interleaves two individually-sorted runs: the ``changed`` events
    (whose values a queue just rewrote — FIFO start times are non-decreasing
    along the array) and the rest (still in the previously-sorted order).
    Merging two sorted runs needs no sort: each element's merged position is
    its rank within its own run plus a ``searchsorted`` count against the
    other run.  Ties place changed-run elements first.

    With ``within`` (a superset of ``changed``), only the ``within``
    subsequence is merged — its elements are redistributed over the
    ``within`` positions, everything else stays put.  This is how the
    cascade stitches several sorted runs back together piecewise when a
    topology's stage masks overlap only partially.

    Returns ``(x, *payloads)`` permuted into the merged order.
    """
    n = x.shape[0]
    inf = jnp.asarray(jnp.inf, x.dtype)
    w = jnp.ones_like(changed) if within is None else within
    a = changed
    b = w & ~changed
    idx_a = jnp.cumsum(a.astype(jnp.int32)) - 1
    idx_b = jnp.cumsum(b.astype(jnp.int32)) - 1
    drop = jnp.int32(n)  # out-of-bounds index: dropped by scatter mode='drop'
    a_run = jnp.full((n,), inf, x.dtype).at[jnp.where(a, idx_a, drop)].set(
        x, mode="drop"
    )
    b_run = jnp.full((n,), inf, x.dtype).at[jnp.where(b, idx_b, drop)].set(
        x, mode="drop"
    )
    rank = jnp.where(
        a,
        idx_a + jnp.searchsorted(b_run, x, side="left"),
        idx_b + jnp.searchsorted(a_run, x, side="right"),
    )
    iota = jnp.arange(n, dtype=jnp.int32)
    if within is None:
        pos = rank
    else:
        idx_w = jnp.cumsum(w.astype(jnp.int32)) - 1
        w_pos = jnp.full((n,), drop, jnp.int32).at[jnp.where(w, idx_w, drop)].set(
            iota, mode="drop"
        )
        pos = jnp.where(w, jnp.take(w_pos, rank, mode="clip"), iota)
    return tuple(jnp.zeros_like(p).at[pos].set(p) for p in (x,) + payloads)


def two_run_merge(x: jnp.ndarray, lead: jnp.ndarray, *payloads: jnp.ndarray):
    """Merge two interleaved sorted runs by rank arithmetic (no compaction).

    ``x`` holds two individually-sorted runs marked by the boolean ``lead``
    mask; ties place ``lead`` elements first.  Unlike
    :func:`merge_sorted_runs` (which physically compacts each run before
    ``searchsorted``), each run is ranked against the *forward-filled
    cumulative-max envelope* of the other run in place: for a ``lead``
    element the merged rank is its own-run rank plus the count of other-run
    elements strictly below it, read off one ``searchsorted`` against the
    envelope plus a prefix count.  That replaces two scatter compactions
    with two ``cummax`` scans — measurably cheaper on XLA CPU — while
    producing bit-identical merged order.

    Padding contract (the device pipeline's): entries keyed ``+inf`` in
    either run sort to the tail, ``lead``-run pads before the others, and
    never perturb the ranks of finite entries.

    Returns ``(x, *payloads)`` permuted into merged order.
    """
    n = x.shape[0]
    neg = jnp.asarray(-jnp.inf, x.dtype)
    a = lead
    b = ~lead
    ca = jnp.cumsum(a.astype(jnp.int32))
    cb = jnp.cumsum(b.astype(jnp.int32))
    m_a = jax.lax.cummax(jnp.where(a, x, neg))
    m_b = jax.lax.cummax(jnp.where(b, x, neg))
    # a-queries count b-elements strictly below ('left': a first on ties);
    # b-queries count a-elements at-or-below ('right')
    pos_b = jnp.searchsorted(m_b, x, side="left")
    pos_a = jnp.searchsorted(m_a, x, side="right")
    cnt_b = jnp.where(pos_b > 0, cb[jnp.maximum(pos_b - 1, 0)], 0)
    cnt_a = jnp.where(pos_a > 0, ca[jnp.maximum(pos_a - 1, 0)], 0)
    rank = jnp.where(a, (ca - 1) + cnt_b, (cb - 1) + cnt_a)
    iota = jnp.arange(n, dtype=jnp.int32)
    # rank is a permutation of [0, n): invert once, gather every payload
    src = (
        jnp.zeros((n,), jnp.int32)
        .at[rank]
        .set(iota, unique_indices=True, mode="promise_in_bounds")
    )
    return tuple(jnp.take(p, src) for p in (x,) + payloads)


def staging_sort(x: jnp.ndarray, run_caps, *payloads: jnp.ndarray):
    """Sort R concatenated time-sorted runs fully on device.

    ``x`` is the concatenation of ``len(run_caps)`` individually-sorted
    runs, run ``r`` occupying the static slice of width ``run_caps[r]``
    (pad entries keyed ``+inf`` at each run's tail).  A ``ceil(log2 R)``
    round tree of :func:`two_run_merge` calls over adjacent run pairs
    produces the fully-sorted order; ties keep the lower run first, so the
    result is **bitwise identical** to a host stable argsort of the
    run-major concatenation (all pads land at the global tail).

    This is the device half of the staging contract: the host packs runs
    (a stable partition, O(copy), zero argsort) and the merge tree replaces
    the per-epoch host ``np.argsort``.

    Returns ``(x, *payloads)`` fully sorted.
    """
    caps = [int(c) for c in run_caps]
    if sum(caps) != x.shape[0]:
        raise ValueError(f"run_caps {caps} do not tile length {x.shape[0]}")
    arrs = (x,) + payloads
    runs = []
    off = 0
    for c in caps:
        if c:
            runs.append((off, c))
        off += c
    while len(runs) > 1:
        nxt = []
        pieces = [[] for _ in arrs]
        cursor = 0

        def flush_gap(lo, hi):
            if hi > lo:
                for j, p in enumerate(arrs):
                    pieces[j].append(p[lo:hi])

        for i in range(0, len(runs) - 1, 2):
            (s0, w0), (s1, w1) = runs[i], runs[i + 1]
            flush_gap(cursor, s0)
            lead = jnp.arange(w0 + w1, dtype=jnp.int32) < w0
            merged = two_run_merge(
                arrs[0][s0 : s1 + w1], lead, *(p[s0 : s1 + w1] for p in arrs[1:])
            )
            for j, m in enumerate(merged):
                pieces[j].append(m)
            nxt.append((s0, w0 + w1))
            cursor = s1 + w1
        if len(runs) % 2:
            nxt.append(runs[-1])
        flush_gap(cursor, x.shape[0])
        arrs = tuple(jnp.concatenate(ps) for ps in pieces)
        runs = nxt
    return arrs


def chain_cascade(
    t_pack: jnp.ndarray,  # [W] f32 depth-packed times (+inf pads per segment)
    idx_pack: jnp.ndarray,  # [W] i32 original slot of each event (-1 pads)
    stts: jnp.ndarray,  # [D] f32 service times in stage order
    seg_caps,  # static: per-stage entry-segment capacities, sum == W
):
    """Compact suffix cascade for nested-mask (chained) topologies.

    Eligibility (checked by ``plan_chain``): in deepest-first stage order
    every stage's route mask is a subset of the next stage's — the CXL
    multi-level-switching shape, where an event entering the fabric at
    depth ``d`` traverses every shallower switch on its way to the RC.
    Under that nesting the cascade never needs full-width merges: the
    working array ``A`` holds exactly the events that traverse the current
    stage, each stage folds in the (time-sorted) segment of events whose
    *deepest* switch it is with one :func:`two_run_merge`, and the stage
    scan runs **unmasked** — its output start times are non-decreasing, so
    ``A`` stays sorted and never splits back into runs.  Total merge work
    is the sum of the growing compact widths instead of S full-width
    merge+scan passes, and local-DRAM traffic (no routes) never enters at
    all.

    Per-event final times are bitwise identical to
    :func:`serial_queue_cascade` on tie-free inputs: a compact segment is
    the same subsequence the full-width masked scan sees, with identical
    ranks and the identical ``f + stt*rank`` float chain.  (Exact-time ties
    *across* entry depths may resolve in a different — equally valid FIFO —
    order; per-stage delay sums then still agree.)

    Pads ride along keyed ``+inf`` with ``idx < 0``: merges keep them at
    the tail, the unmasked scan maps them ``+inf -> +inf``, and delay sums
    mask them out.

    Returns ``(t_fin [W], idx [W], per_stage_delay [D])``.
    """
    f32 = t_pack.dtype
    caps = [int(c) for c in seg_caps]
    if sum(caps) != t_pack.shape[0]:
        raise ValueError(f"seg_caps {caps} do not tile length {t_pack.shape[0]}")
    a_t = t_pack[:0]
    a_i = idx_pack[:0]
    per_stage = []
    off = 0
    for p, cap in enumerate(caps):
        if cap:
            seg_t = t_pack[off : off + cap]
            seg_i = idx_pack[off : off + cap]
            if a_t.shape[0] == 0:
                a_t, a_i = seg_t, seg_i
            else:
                w0 = a_t.shape[0]
                lead = jnp.arange(w0 + cap, dtype=jnp.int32) < w0
                a_t, a_i = two_run_merge(
                    jnp.concatenate([a_t, seg_t]),
                    lead,
                    jnp.concatenate([a_i, seg_i]),
                )
            off += cap
        if a_t.shape[0] == 0:
            per_stage.append(jnp.zeros((), f32))
            continue
        stt = stts[p]
        rankf = jnp.arange(a_t.shape[0], dtype=f32)
        g = a_t - stt * rankf
        f = jax.lax.cummax(g)
        start = f + stt * rankf
        real = a_i >= 0
        d = jnp.where(real, start - a_t, 0.0)
        per_stage.append(d.sum())
        a_t = jnp.where(real, start, a_t)
    return a_t, a_i, jnp.stack(per_stage)


def serial_queue_cascade(
    t_sorted: jnp.ndarray,  # [N] f32, globally time-sorted arrivals
    route_bits: jnp.ndarray,  # [N] i32, bit s set iff event traverses stage s
    stts: jnp.ndarray,  # [S] f32, service times in stage order
    merge_plan=None,  # static: per-stage tuple of (changed_bit, within_bit|None)
    hosts: jnp.ndarray = None,  # [N] i32 host ids in sorted order (optional)
    n_hosts: int = 1,  # static; only used when hosts is given
):
    """Fused S-stage congestion cascade over one time-sorted epoch.

    Runs every switch's serial queue (deepest stage first, encoded by the
    caller's stage order) over the same array with **one** initial sort: the
    array is kept physically sorted (per stage mask) by *current* time
    throughout, so each stage's scan sees true arrival order.  This
    reproduces the per-stage re-sort of ``analyze_ref`` exactly (up to tie
    attribution at identical float times) without ever re-sorting.

    ``merge_plan`` (static) lists, per stage, the :func:`merge_sorted_runs`
    ops to run *before* that stage's scan: each op names the route-bit of
    the sorted run to fold in and the route-bit of the subsequence to merge
    within (``None`` = whole array).  ``None`` for the whole plan selects
    the conservative schedule — a full two-run merge before every stage,
    folding in the previous stage's events — which is always valid.  The
    epoch analyzer derives a minimal plan from the topology's route matrix
    (nested or disjoint stage masks need no merge at all: a subsequence of
    a sorted run is sorted).  All merges are skipped at runtime while no
    stage has accumulated any delay.

    Returns ``(t_final, slot_idx, per_stage_delay)`` where ``t_final[k]`` is
    the post-congestion time of the event originally at sorted position
    ``slot_idx[k]``, and ``per_stage_delay[s]`` is the summed queueing delay
    at stage ``s``.

    With ``hosts`` (per-event host ids in the same sorted order as
    ``t_sorted``), ``per_stage_delay`` is host-segmented to shape ``[S,
    n_hosts]`` — the shared-fabric decomposition: a stage's queueing delay
    is charged to the host whose event waited.  Hosts are recovered through
    the cascade's live permutation (``hosts[idx]``), so merges need no extra
    payload.

    The cascade never sees latencies: device-cache latency scaling
    (:mod:`repro.core.cache`) happens on the caller's side, which is what
    keeps this oracle — and the Pallas kernel it specifies — identical
    across cache-enabled and cache-free analyses.
    """
    f32 = t_sorted.dtype
    n = t_sorted.shape[0]
    s_stages = stts.shape[0]
    if merge_plan is None:
        merge_plan = tuple(((s - 1, None),) if s else () for s in range(s_stages))
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    ts = t_sorted
    bits = route_bits.astype(jnp.int32)
    idx = jnp.arange(n, dtype=jnp.int32)
    dirty = jnp.zeros((), f32)  # total delay so far; 0 => nothing ever moved
    per_stage = []
    for s in range(s_stages):
        for changed_bit, within_bit in merge_plan[s]:
            changed = (jnp.right_shift(bits, changed_bit) & 1) == 1
            if within_bit is None:
                args = (ts, bits, idx, changed)
                merge = lambda a: merge_sorted_runs(a[0], a[3], a[1], a[2])
            else:
                within = (jnp.right_shift(bits, within_bit) & 1) == 1
                args = (ts, bits, idx, changed, within)
                merge = lambda a: merge_sorted_runs(
                    a[0], a[3], a[1], a[2], within=a[4]
                )
            ts, bits, idx = jax.lax.cond(
                dirty > 0, merge, lambda a: (a[0], a[1], a[2]), args
            )
        m = (jnp.right_shift(bits, s) & 1) == 1
        stt = stts[s]
        rankf = (jnp.cumsum(m.astype(jnp.int32)) - 1).astype(f32)
        g = jnp.where(m, ts - stt * rankf, -big)
        f = jax.lax.cummax(g)
        start = jnp.where(m, f + stt * rankf, ts)
        d = jnp.where(m, start - ts, 0.0)
        dsum = d.sum()
        if hosts is None:
            per_stage.append(dsum)
        else:
            per_stage.append(
                jax.ops.segment_sum(d, hosts[idx], num_segments=n_hosts)
            )
        dirty = dirty + dsum
        ts = jnp.where(m, start, ts)
    return ts, idx, jnp.stack(per_stage)


# --------------------------------------------------------------------------- #
# flash-attention oracle
# --------------------------------------------------------------------------- #


def mha_attention(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,  # [B, Hk, Sk, D]
    v: jnp.ndarray,  # [B, Hk, Sk, D]
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Full-matrix GQA attention in f32 (the flash kernel oracle).

    ``q_offset``: absolute position of q[0] (for decode: Sq=1, offset=cache
    length) so causality is computed on absolute positions.
    """
    B, H, Sq, D = q.shape
    Hk = k.shape[1]
    assert H % Hk == 0
    g = H // Hk
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if causal:
        Sk = k.shape[2]
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
# Mamba2 SSD oracles
# --------------------------------------------------------------------------- #


def ssd_naive(
    x: jnp.ndarray,  # [B, L, H, P]   (P = head dim)
    dt: jnp.ndarray,  # [B, L, H]      (softplus-activated step)
    A: jnp.ndarray,  # [H]            (negative; per-head scalar decay rate)
    Bm: jnp.ndarray,  # [B, L, N]      (input projection onto state, 1 group)
    Cm: jnp.ndarray,  # [B, L, N]      (state readout, 1 group)
) -> jnp.ndarray:
    """Sequential state-space recurrence (the exact semantics):

        h_t = exp(A·dt_t) ⊙ h_{t−1} + dt_t · B_t ⊗ x_t        h ∈ [N, P]
        y_t = C_t · h_t
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def one_head(xh, dth, Ah, Bmh, Cmh):
        # xh [L,P], dth [L], Bmh/Cmh [L,N]
        decay = jnp.exp(Ah * dth)  # [L]

        def step(h, inp):
            xt, dt_t, dec, bt, ct = inp
            h = dec * h + dt_t * (bt[:, None] * xt[None, :])  # [N,P]
            y = ct @ h  # [P]
            return h, y

        h0 = jnp.zeros((N, P), f32)
        _, ys = jax.lax.scan(step, h0, (xh, dth, decay, Bmh, Cmh))
        return ys  # [L,P]

    out = jax.vmap(  # over batch
        jax.vmap(  # over heads
            one_head, in_axes=(1, 1, 0, None, None), out_axes=1
        ),
        in_axes=(0, 0, None, 0, 0),
        out_axes=0,
    )(x.astype(f32), dt.astype(f32), A.astype(f32), Bm.astype(f32), Cm.astype(f32))
    return out.astype(x.dtype)  # [B, L, H, P]


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    chunk: int = 64,
) -> jnp.ndarray:
    """Chunked SSD (state-space duality) — the blocked algorithm the Pallas
    kernel implements: quadratic attention-like math within chunks, linear
    state passing between chunks.  Must agree with :func:`ssd_naive`.
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, "sequence must be divisible by chunk"
    C = L // chunk
    f32 = jnp.float32

    x_ = x.astype(f32).reshape(Bsz, C, chunk, H, P)
    dt_ = dt.astype(f32).reshape(Bsz, C, chunk, H)
    B_ = Bm.astype(f32).reshape(Bsz, C, chunk, N)
    C_ = Cm.astype(f32).reshape(Bsz, C, chunk, N)
    A_ = A.astype(f32)

    # per-position log decay a_t = A·dt_t ; cumulative within chunk
    a = A_[None, None, None, :] * dt_[..., :]  # [B,C,c,H]
    acum = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (quadratic, like masked attention) ------------------- #
    # y_intra[t] = Σ_{s≤t} C_t·B_s dt_s exp(acum_t − acum_s) x_s
    seg = acum[:, :, :, None, :] - acum[:, :, None, :, :]  # [B,C,t,s,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    G = jnp.einsum("bctn,bcsn->bcts", C_, B_)  # [B,C,t,s]
    W = G[..., None] * jnp.exp(seg) * dt_[:, :, None, :, :]  # [B,C,t,s,H]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", W, x_)

    # ---- chunk states ------------------------------------------------------ #
    # state_c = Σ_s B_s dt_s exp(acum_last − acum_s) x_s   ∈ [N,P]
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)  # [B,C,c,H]
    S = jnp.einsum(
        "bcsn,bcsh,bcshp->bchnp", B_, dt_ * decay_to_end, x_
    )  # [B,C,H,N,P]
    chunk_decay = jnp.exp(acum[:, :, -1, :])  # [B,C,H]

    # ---- inter-chunk scan --------------------------------------------------- #
    def scan_fn(h, inp):
        S_c, dec_c = inp  # [B,H,N,P], [B,H]
        h_out = h  # state BEFORE this chunk
        h = dec_c[..., None, None] * h + S_c
        return h, h_out

    h0 = jnp.zeros((Bsz, H, N, P), f32)
    _, h_prev = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [B,C,H,N,P] state entering chunk

    # ---- inter-chunk contribution ------------------------------------------ #
    # y_inter[t] = C_t · (exp(acum_t) ⊙ h_prev)
    y_inter = jnp.einsum(
        "bctn,bcth,bchnp->bcthp", C_, jnp.exp(acum), h_prev
    )

    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y.astype(x.dtype)
