"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics the kernels must match bit-for-bit (up to fp
accumulation order).  Tests sweep shapes/dtypes and assert_allclose against
these functions with the kernels run in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "merge_sorted_runs",
    "serial_queue",
    "serial_queue_cascade",
    "mha_attention",
    "ssd_naive",
    "ssd_chunked",
]


# --------------------------------------------------------------------------- #
# congestion kernel oracle
# --------------------------------------------------------------------------- #


def serial_queue(t_sorted: jnp.ndarray, mask: jnp.ndarray, stt) -> jnp.ndarray:
    """Start times of a FIFO queue with constant service time over the masked
    subsequence of a time-sorted event stream; unmasked events pass through.

    out_i = max(arr_i, out_{i-1} + stt) over masked events, closed form
    out_i = cummax(arr_i − stt·rank_i) + stt·rank_i.
    """
    f32 = t_sorted.dtype
    stt = jnp.asarray(stt, f32)
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    rankf = rank.astype(f32)
    g = jnp.where(mask, t_sorted - stt * rankf, -big)
    f = jax.lax.cummax(g)
    return jnp.where(mask, f + stt * rankf, t_sorted)


def merge_sorted_runs(
    x: jnp.ndarray,
    changed: jnp.ndarray,
    *payloads: jnp.ndarray,
    within: jnp.ndarray = None,
):
    """Restore sortedness of ``x`` after a masked serial-queue update.

    ``x`` interleaves two individually-sorted runs: the ``changed`` events
    (whose values a queue just rewrote — FIFO start times are non-decreasing
    along the array) and the rest (still in the previously-sorted order).
    Merging two sorted runs needs no sort: each element's merged position is
    its rank within its own run plus a ``searchsorted`` count against the
    other run.  Ties place changed-run elements first.

    With ``within`` (a superset of ``changed``), only the ``within``
    subsequence is merged — its elements are redistributed over the
    ``within`` positions, everything else stays put.  This is how the
    cascade stitches several sorted runs back together piecewise when a
    topology's stage masks overlap only partially.

    Returns ``(x, *payloads)`` permuted into the merged order.
    """
    n = x.shape[0]
    inf = jnp.asarray(jnp.inf, x.dtype)
    w = jnp.ones_like(changed) if within is None else within
    a = changed
    b = w & ~changed
    idx_a = jnp.cumsum(a.astype(jnp.int32)) - 1
    idx_b = jnp.cumsum(b.astype(jnp.int32)) - 1
    drop = jnp.int32(n)  # out-of-bounds index: dropped by scatter mode='drop'
    a_run = jnp.full((n,), inf, x.dtype).at[jnp.where(a, idx_a, drop)].set(
        x, mode="drop"
    )
    b_run = jnp.full((n,), inf, x.dtype).at[jnp.where(b, idx_b, drop)].set(
        x, mode="drop"
    )
    rank = jnp.where(
        a,
        idx_a + jnp.searchsorted(b_run, x, side="left"),
        idx_b + jnp.searchsorted(a_run, x, side="right"),
    )
    iota = jnp.arange(n, dtype=jnp.int32)
    if within is None:
        pos = rank
    else:
        idx_w = jnp.cumsum(w.astype(jnp.int32)) - 1
        w_pos = jnp.full((n,), drop, jnp.int32).at[jnp.where(w, idx_w, drop)].set(
            iota, mode="drop"
        )
        pos = jnp.where(w, jnp.take(w_pos, rank, mode="clip"), iota)
    return tuple(jnp.zeros_like(p).at[pos].set(p) for p in (x,) + payloads)


def serial_queue_cascade(
    t_sorted: jnp.ndarray,  # [N] f32, globally time-sorted arrivals
    route_bits: jnp.ndarray,  # [N] i32, bit s set iff event traverses stage s
    stts: jnp.ndarray,  # [S] f32, service times in stage order
    merge_plan=None,  # static: per-stage tuple of (changed_bit, within_bit|None)
    hosts: jnp.ndarray = None,  # [N] i32 host ids in sorted order (optional)
    n_hosts: int = 1,  # static; only used when hosts is given
):
    """Fused S-stage congestion cascade over one time-sorted epoch.

    Runs every switch's serial queue (deepest stage first, encoded by the
    caller's stage order) over the same array with **one** initial sort: the
    array is kept physically sorted (per stage mask) by *current* time
    throughout, so each stage's scan sees true arrival order.  This
    reproduces the per-stage re-sort of ``analyze_ref`` exactly (up to tie
    attribution at identical float times) without ever re-sorting.

    ``merge_plan`` (static) lists, per stage, the :func:`merge_sorted_runs`
    ops to run *before* that stage's scan: each op names the route-bit of
    the sorted run to fold in and the route-bit of the subsequence to merge
    within (``None`` = whole array).  ``None`` for the whole plan selects
    the conservative schedule — a full two-run merge before every stage,
    folding in the previous stage's events — which is always valid.  The
    epoch analyzer derives a minimal plan from the topology's route matrix
    (nested or disjoint stage masks need no merge at all: a subsequence of
    a sorted run is sorted).  All merges are skipped at runtime while no
    stage has accumulated any delay.

    Returns ``(t_final, slot_idx, per_stage_delay)`` where ``t_final[k]`` is
    the post-congestion time of the event originally at sorted position
    ``slot_idx[k]``, and ``per_stage_delay[s]`` is the summed queueing delay
    at stage ``s``.

    With ``hosts`` (per-event host ids in the same sorted order as
    ``t_sorted``), ``per_stage_delay`` is host-segmented to shape ``[S,
    n_hosts]`` — the shared-fabric decomposition: a stage's queueing delay
    is charged to the host whose event waited.  Hosts are recovered through
    the cascade's live permutation (``hosts[idx]``), so merges need no extra
    payload.

    The cascade never sees latencies: device-cache latency scaling
    (:mod:`repro.core.cache`) happens on the caller's side, which is what
    keeps this oracle — and the Pallas kernel it specifies — identical
    across cache-enabled and cache-free analyses.
    """
    f32 = t_sorted.dtype
    n = t_sorted.shape[0]
    s_stages = stts.shape[0]
    if merge_plan is None:
        merge_plan = tuple(((s - 1, None),) if s else () for s in range(s_stages))
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    ts = t_sorted
    bits = route_bits.astype(jnp.int32)
    idx = jnp.arange(n, dtype=jnp.int32)
    dirty = jnp.zeros((), f32)  # total delay so far; 0 => nothing ever moved
    per_stage = []
    for s in range(s_stages):
        for changed_bit, within_bit in merge_plan[s]:
            changed = (jnp.right_shift(bits, changed_bit) & 1) == 1
            if within_bit is None:
                args = (ts, bits, idx, changed)
                merge = lambda a: merge_sorted_runs(a[0], a[3], a[1], a[2])
            else:
                within = (jnp.right_shift(bits, within_bit) & 1) == 1
                args = (ts, bits, idx, changed, within)
                merge = lambda a: merge_sorted_runs(
                    a[0], a[3], a[1], a[2], within=a[4]
                )
            ts, bits, idx = jax.lax.cond(
                dirty > 0, merge, lambda a: (a[0], a[1], a[2]), args
            )
        m = (jnp.right_shift(bits, s) & 1) == 1
        stt = stts[s]
        rankf = (jnp.cumsum(m.astype(jnp.int32)) - 1).astype(f32)
        g = jnp.where(m, ts - stt * rankf, -big)
        f = jax.lax.cummax(g)
        start = jnp.where(m, f + stt * rankf, ts)
        d = jnp.where(m, start - ts, 0.0)
        dsum = d.sum()
        if hosts is None:
            per_stage.append(dsum)
        else:
            per_stage.append(
                jax.ops.segment_sum(d, hosts[idx], num_segments=n_hosts)
            )
        dirty = dirty + dsum
        ts = jnp.where(m, start, ts)
    return ts, idx, jnp.stack(per_stage)


# --------------------------------------------------------------------------- #
# flash-attention oracle
# --------------------------------------------------------------------------- #


def mha_attention(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,  # [B, Hk, Sk, D]
    v: jnp.ndarray,  # [B, Hk, Sk, D]
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Full-matrix GQA attention in f32 (the flash kernel oracle).

    ``q_offset``: absolute position of q[0] (for decode: Sq=1, offset=cache
    length) so causality is computed on absolute positions.
    """
    B, H, Sq, D = q.shape
    Hk = k.shape[1]
    assert H % Hk == 0
    g = H // Hk
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if causal:
        Sk = k.shape[2]
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
# Mamba2 SSD oracles
# --------------------------------------------------------------------------- #


def ssd_naive(
    x: jnp.ndarray,  # [B, L, H, P]   (P = head dim)
    dt: jnp.ndarray,  # [B, L, H]      (softplus-activated step)
    A: jnp.ndarray,  # [H]            (negative; per-head scalar decay rate)
    Bm: jnp.ndarray,  # [B, L, N]      (input projection onto state, 1 group)
    Cm: jnp.ndarray,  # [B, L, N]      (state readout, 1 group)
) -> jnp.ndarray:
    """Sequential state-space recurrence (the exact semantics):

        h_t = exp(A·dt_t) ⊙ h_{t−1} + dt_t · B_t ⊗ x_t        h ∈ [N, P]
        y_t = C_t · h_t
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def one_head(xh, dth, Ah, Bmh, Cmh):
        # xh [L,P], dth [L], Bmh/Cmh [L,N]
        decay = jnp.exp(Ah * dth)  # [L]

        def step(h, inp):
            xt, dt_t, dec, bt, ct = inp
            h = dec * h + dt_t * (bt[:, None] * xt[None, :])  # [N,P]
            y = ct @ h  # [P]
            return h, y

        h0 = jnp.zeros((N, P), f32)
        _, ys = jax.lax.scan(step, h0, (xh, dth, decay, Bmh, Cmh))
        return ys  # [L,P]

    out = jax.vmap(  # over batch
        jax.vmap(  # over heads
            one_head, in_axes=(1, 1, 0, None, None), out_axes=1
        ),
        in_axes=(0, 0, None, 0, 0),
        out_axes=0,
    )(x.astype(f32), dt.astype(f32), A.astype(f32), Bm.astype(f32), Cm.astype(f32))
    return out.astype(x.dtype)  # [B, L, H, P]


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    chunk: int = 64,
) -> jnp.ndarray:
    """Chunked SSD (state-space duality) — the blocked algorithm the Pallas
    kernel implements: quadratic attention-like math within chunks, linear
    state passing between chunks.  Must agree with :func:`ssd_naive`.
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, "sequence must be divisible by chunk"
    C = L // chunk
    f32 = jnp.float32

    x_ = x.astype(f32).reshape(Bsz, C, chunk, H, P)
    dt_ = dt.astype(f32).reshape(Bsz, C, chunk, H)
    B_ = Bm.astype(f32).reshape(Bsz, C, chunk, N)
    C_ = Cm.astype(f32).reshape(Bsz, C, chunk, N)
    A_ = A.astype(f32)

    # per-position log decay a_t = A·dt_t ; cumulative within chunk
    a = A_[None, None, None, :] * dt_[..., :]  # [B,C,c,H]
    acum = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (quadratic, like masked attention) ------------------- #
    # y_intra[t] = Σ_{s≤t} C_t·B_s dt_s exp(acum_t − acum_s) x_s
    seg = acum[:, :, :, None, :] - acum[:, :, None, :, :]  # [B,C,t,s,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    G = jnp.einsum("bctn,bcsn->bcts", C_, B_)  # [B,C,t,s]
    W = G[..., None] * jnp.exp(seg) * dt_[:, :, None, :, :]  # [B,C,t,s,H]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", W, x_)

    # ---- chunk states ------------------------------------------------------ #
    # state_c = Σ_s B_s dt_s exp(acum_last − acum_s) x_s   ∈ [N,P]
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)  # [B,C,c,H]
    S = jnp.einsum(
        "bcsn,bcsh,bcshp->bchnp", B_, dt_ * decay_to_end, x_
    )  # [B,C,H,N,P]
    chunk_decay = jnp.exp(acum[:, :, -1, :])  # [B,C,H]

    # ---- inter-chunk scan --------------------------------------------------- #
    def scan_fn(h, inp):
        S_c, dec_c = inp  # [B,H,N,P], [B,H]
        h_out = h  # state BEFORE this chunk
        h = dec_c[..., None, None] * h + S_c
        return h, h_out

    h0 = jnp.zeros((Bsz, H, N, P), f32)
    _, h_prev = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [B,C,H,N,P] state entering chunk

    # ---- inter-chunk contribution ------------------------------------------ #
    # y_inter[t] = C_t · (exp(acum_t) ⊙ h_prev)
    y_inter = jnp.einsum(
        "bctn,bcth,bchnp->bcthp", C_, jnp.exp(acum), h_prev
    )

    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y.astype(x.dtype)
