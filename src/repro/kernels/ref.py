"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics the kernels must match bit-for-bit (up to fp
accumulation order).  Tests sweep shapes/dtypes and assert_allclose against
these functions with the kernels run in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["serial_queue", "mha_attention", "ssd_naive", "ssd_chunked"]


# --------------------------------------------------------------------------- #
# congestion kernel oracle
# --------------------------------------------------------------------------- #


def serial_queue(t_sorted: jnp.ndarray, mask: jnp.ndarray, stt) -> jnp.ndarray:
    """Start times of a FIFO queue with constant service time over the masked
    subsequence of a time-sorted event stream; unmasked events pass through.

    out_i = max(arr_i, out_{i-1} + stt) over masked events, closed form
    out_i = cummax(arr_i − stt·rank_i) + stt·rank_i.
    """
    f32 = t_sorted.dtype
    stt = jnp.asarray(stt, f32)
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    rankf = rank.astype(f32)
    g = jnp.where(mask, t_sorted - stt * rankf, -big)
    f = jax.lax.cummax(g)
    return jnp.where(mask, f + stt * rankf, t_sorted)


# --------------------------------------------------------------------------- #
# flash-attention oracle
# --------------------------------------------------------------------------- #


def mha_attention(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,  # [B, Hk, Sk, D]
    v: jnp.ndarray,  # [B, Hk, Sk, D]
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Full-matrix GQA attention in f32 (the flash kernel oracle).

    ``q_offset``: absolute position of q[0] (for decode: Sq=1, offset=cache
    length) so causality is computed on absolute positions.
    """
    B, H, Sq, D = q.shape
    Hk = k.shape[1]
    assert H % Hk == 0
    g = H // Hk
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if causal:
        Sk = k.shape[2]
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
# Mamba2 SSD oracles
# --------------------------------------------------------------------------- #


def ssd_naive(
    x: jnp.ndarray,  # [B, L, H, P]   (P = head dim)
    dt: jnp.ndarray,  # [B, L, H]      (softplus-activated step)
    A: jnp.ndarray,  # [H]            (negative; per-head scalar decay rate)
    Bm: jnp.ndarray,  # [B, L, N]      (input projection onto state, 1 group)
    Cm: jnp.ndarray,  # [B, L, N]      (state readout, 1 group)
) -> jnp.ndarray:
    """Sequential state-space recurrence (the exact semantics):

        h_t = exp(A·dt_t) ⊙ h_{t−1} + dt_t · B_t ⊗ x_t        h ∈ [N, P]
        y_t = C_t · h_t
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def one_head(xh, dth, Ah, Bmh, Cmh):
        # xh [L,P], dth [L], Bmh/Cmh [L,N]
        decay = jnp.exp(Ah * dth)  # [L]

        def step(h, inp):
            xt, dt_t, dec, bt, ct = inp
            h = dec * h + dt_t * (bt[:, None] * xt[None, :])  # [N,P]
            y = ct @ h  # [P]
            return h, y

        h0 = jnp.zeros((N, P), f32)
        _, ys = jax.lax.scan(step, h0, (xh, dth, decay, Bmh, Cmh))
        return ys  # [L,P]

    out = jax.vmap(  # over batch
        jax.vmap(  # over heads
            one_head, in_axes=(1, 1, 0, None, None), out_axes=1
        ),
        in_axes=(0, 0, None, 0, 0),
        out_axes=0,
    )(x.astype(f32), dt.astype(f32), A.astype(f32), Bm.astype(f32), Cm.astype(f32))
    return out.astype(x.dtype)  # [B, L, H, P]


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    chunk: int = 64,
) -> jnp.ndarray:
    """Chunked SSD (state-space duality) — the blocked algorithm the Pallas
    kernel implements: quadratic attention-like math within chunks, linear
    state passing between chunks.  Must agree with :func:`ssd_naive`.
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, "sequence must be divisible by chunk"
    C = L // chunk
    f32 = jnp.float32

    x_ = x.astype(f32).reshape(Bsz, C, chunk, H, P)
    dt_ = dt.astype(f32).reshape(Bsz, C, chunk, H)
    B_ = Bm.astype(f32).reshape(Bsz, C, chunk, N)
    C_ = Cm.astype(f32).reshape(Bsz, C, chunk, N)
    A_ = A.astype(f32)

    # per-position log decay a_t = A·dt_t ; cumulative within chunk
    a = A_[None, None, None, :] * dt_[..., :]  # [B,C,c,H]
    acum = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (quadratic, like masked attention) ------------------- #
    # y_intra[t] = Σ_{s≤t} C_t·B_s dt_s exp(acum_t − acum_s) x_s
    seg = acum[:, :, :, None, :] - acum[:, :, None, :, :]  # [B,C,t,s,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    G = jnp.einsum("bctn,bcsn->bcts", C_, B_)  # [B,C,t,s]
    W = G[..., None] * jnp.exp(seg) * dt_[:, :, None, :, :]  # [B,C,t,s,H]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", W, x_)

    # ---- chunk states ------------------------------------------------------ #
    # state_c = Σ_s B_s dt_s exp(acum_last − acum_s) x_s   ∈ [N,P]
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)  # [B,C,c,H]
    S = jnp.einsum(
        "bcsn,bcsh,bcshp->bchnp", B_, dt_ * decay_to_end, x_
    )  # [B,C,H,N,P]
    chunk_decay = jnp.exp(acum[:, :, -1, :])  # [B,C,H]

    # ---- inter-chunk scan --------------------------------------------------- #
    def scan_fn(h, inp):
        S_c, dec_c = inp  # [B,H,N,P], [B,H]
        h_out = h  # state BEFORE this chunk
        h = dec_c[..., None, None] * h + S_c
        return h, h_out

    h0 = jnp.zeros((Bsz, H, N, P), f32)
    _, h_prev = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [B,C,H,N,P] state entering chunk

    # ---- inter-chunk contribution ------------------------------------------ #
    # y_inter[t] = C_t · (exp(acum_t) ⊙ h_prev)
    y_inter = jnp.einsum(
        "bctn,bcth,bchnp->bcthp", C_, jnp.exp(acum), h_prev
    )

    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y.astype(x.dtype)
