"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics the kernels must match bit-for-bit (up to fp
accumulation order).  Tests sweep shapes/dtypes and assert_allclose against
these functions with the kernels run in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..analysis.annotations import axes

__all__ = [
    "chain_cascade",
    "merge_sorted_runs",
    "qos_cascade_dyn",
    "qos_serial_queue_cascade",
    "serial_queue",
    "serial_queue_cascade",
    "staging_sort",
    "two_run_merge",
    "mha_attention",
    "ssd_naive",
    "ssd_chunked",
]

# queue-discipline codes shared with ``topology.DISCIPLINE_CODES`` (kernels
# do not import core; the mapping is part of the kernel ABI)
DISC_FIFO, DISC_PRIORITY, DISC_WFQ = 0, 1, 2


# --------------------------------------------------------------------------- #
# congestion kernel oracle
# --------------------------------------------------------------------------- #


def serial_queue(t_sorted: jnp.ndarray, mask: jnp.ndarray, stt) -> jnp.ndarray:
    """Start times of a FIFO queue with constant service time over the masked
    subsequence of a time-sorted event stream; unmasked events pass through.

    out_i = max(arr_i, out_{i-1} + stt) over masked events, closed form
    out_i = cummax(arr_i − stt·rank_i) + stt·rank_i.
    """
    f32 = t_sorted.dtype
    stt = jnp.asarray(stt, f32)
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    rankf = rank.astype(f32)
    g = jnp.where(mask, t_sorted - stt * rankf, -big)
    f = jax.lax.cummax(g)
    return jnp.where(mask, f + stt * rankf, t_sorted)


def merge_sorted_runs(
    x: jnp.ndarray,
    changed: jnp.ndarray,
    *payloads: jnp.ndarray,
    within: jnp.ndarray = None,
):
    """Restore sortedness of ``x`` after a masked serial-queue update.

    ``x`` interleaves two individually-sorted runs: the ``changed`` events
    (whose values a queue just rewrote — FIFO start times are non-decreasing
    along the array) and the rest (still in the previously-sorted order).
    Merging two sorted runs needs no sort: each element's merged position is
    its rank within its own run plus a ``searchsorted`` count against the
    other run.  Ties place changed-run elements first.

    With ``within`` (a superset of ``changed``), only the ``within``
    subsequence is merged — its elements are redistributed over the
    ``within`` positions, everything else stays put.  This is how the
    cascade stitches several sorted runs back together piecewise when a
    topology's stage masks overlap only partially.

    Returns ``(x, *payloads)`` permuted into the merged order.
    """
    n = x.shape[0]
    inf = jnp.asarray(jnp.inf, x.dtype)
    w = jnp.ones_like(changed) if within is None else within
    a = changed
    b = w & ~changed
    idx_a = jnp.cumsum(a.astype(jnp.int32)) - 1
    idx_b = jnp.cumsum(b.astype(jnp.int32)) - 1
    drop = jnp.int32(n)  # out-of-bounds index: dropped by scatter mode='drop'
    a_run = jnp.full((n,), inf, x.dtype).at[jnp.where(a, idx_a, drop)].set(
        x, mode="drop"
    )
    b_run = jnp.full((n,), inf, x.dtype).at[jnp.where(b, idx_b, drop)].set(
        x, mode="drop"
    )
    rank = jnp.where(
        a,
        idx_a + jnp.searchsorted(b_run, x, side="left"),
        idx_b + jnp.searchsorted(a_run, x, side="right"),
    )
    iota = jnp.arange(n, dtype=jnp.int32)
    if within is None:
        pos = rank
    else:
        idx_w = jnp.cumsum(w.astype(jnp.int32)) - 1
        w_pos = jnp.full((n,), drop, jnp.int32).at[jnp.where(w, idx_w, drop)].set(
            iota, mode="drop"
        )
        pos = jnp.where(w, jnp.take(w_pos, rank, mode="clip"), iota)
    return tuple(jnp.zeros_like(p).at[pos].set(p) for p in (x,) + payloads)


@axes("N", lead="N")
def two_run_merge(x: jnp.ndarray, lead: jnp.ndarray, *payloads: jnp.ndarray):
    """Merge two interleaved sorted runs by rank arithmetic (no compaction).

    ``x`` holds two individually-sorted runs marked by the boolean ``lead``
    mask; ties place ``lead`` elements first.  Unlike
    :func:`merge_sorted_runs` (which physically compacts each run before
    ``searchsorted``), each run is ranked against the *forward-filled
    cumulative-max envelope* of the other run in place: for a ``lead``
    element the merged rank is its own-run rank plus the count of other-run
    elements strictly below it, read off one ``searchsorted`` against the
    envelope plus a prefix count.  That replaces two scatter compactions
    with two ``cummax`` scans — measurably cheaper on XLA CPU — while
    producing bit-identical merged order.

    Padding contract (the device pipeline's): entries keyed ``+inf`` in
    either run sort to the tail, ``lead``-run pads before the others, and
    never perturb the ranks of finite entries.

    Returns ``(x, *payloads)`` permuted into merged order.
    """
    n = x.shape[0]
    neg = jnp.asarray(-jnp.inf, x.dtype)
    a = lead
    b = ~lead
    ca = jnp.cumsum(a.astype(jnp.int32))
    cb = jnp.cumsum(b.astype(jnp.int32))
    m_a = jax.lax.cummax(jnp.where(a, x, neg))
    m_b = jax.lax.cummax(jnp.where(b, x, neg))
    # a-queries count b-elements strictly below ('left': a first on ties);
    # b-queries count a-elements at-or-below ('right')
    pos_b = jnp.searchsorted(m_b, x, side="left")
    pos_a = jnp.searchsorted(m_a, x, side="right")
    cnt_b = jnp.where(pos_b > 0, cb[jnp.maximum(pos_b - 1, 0)], 0)
    cnt_a = jnp.where(pos_a > 0, ca[jnp.maximum(pos_a - 1, 0)], 0)
    rank = jnp.where(a, (ca - 1) + cnt_b, (cb - 1) + cnt_a)
    iota = jnp.arange(n, dtype=jnp.int32)
    # rank is a permutation of [0, n): invert once, gather every payload
    src = (
        jnp.zeros((n,), jnp.int32)
        .at[rank]
        .set(iota, unique_indices=True, mode="promise_in_bounds")
    )
    return tuple(jnp.take(p, src) for p in (x,) + payloads)


@axes("N")
def staging_sort(x: jnp.ndarray, run_caps, *payloads: jnp.ndarray):
    """Sort R concatenated time-sorted runs fully on device.

    ``x`` is the concatenation of ``len(run_caps)`` individually-sorted
    runs, run ``r`` occupying the static slice of width ``run_caps[r]``
    (pad entries keyed ``+inf`` at each run's tail).  A ``ceil(log2 R)``
    round tree of :func:`two_run_merge` calls over adjacent run pairs
    produces the fully-sorted order; ties keep the lower run first, so the
    result is **bitwise identical** to a host stable argsort of the
    run-major concatenation (all pads land at the global tail).

    This is the device half of the staging contract: the host packs runs
    (a stable partition, O(copy), zero argsort) and the merge tree replaces
    the per-epoch host ``np.argsort``.

    Returns ``(x, *payloads)`` fully sorted.
    """
    caps = [int(c) for c in run_caps]
    if sum(caps) != x.shape[0]:
        raise ValueError(f"run_caps {caps} do not tile length {x.shape[0]}")
    arrs = (x,) + payloads
    runs = []
    off = 0
    for c in caps:
        if c:
            runs.append((off, c))
        off += c
    while len(runs) > 1:
        nxt = []
        pieces = [[] for _ in arrs]
        cursor = 0

        def flush_gap(lo, hi):
            if hi > lo:
                for j, p in enumerate(arrs):
                    pieces[j].append(p[lo:hi])

        for i in range(0, len(runs) - 1, 2):
            (s0, w0), (s1, w1) = runs[i], runs[i + 1]
            flush_gap(cursor, s0)
            lead = jnp.arange(w0 + w1, dtype=jnp.int32) < w0
            merged = two_run_merge(
                arrs[0][s0 : s1 + w1], lead, *(p[s0 : s1 + w1] for p in arrs[1:])
            )
            for j, m in enumerate(merged):
                pieces[j].append(m)
            nxt.append((s0, w0 + w1))
            cursor = s1 + w1
        if len(runs) % 2:
            nxt.append(runs[-1])
        flush_gap(cursor, x.shape[0])
        arrs = tuple(jnp.concatenate(ps) for ps in pieces)
        runs = nxt
    return arrs


@axes("W", idx_pack="W", stts="D")
def chain_cascade(
    t_pack: jnp.ndarray,  # [W] f32 depth-packed times (+inf pads per segment)
    idx_pack: jnp.ndarray,  # [W] i32 original slot of each event (-1 pads)
    stts: jnp.ndarray,  # [D] f32 service times in stage order
    seg_caps,  # static: per-stage entry-segment capacities, sum == W
):
    """Compact suffix cascade for nested-mask (chained) topologies.

    Eligibility (checked by ``plan_chain``): in deepest-first stage order
    every stage's route mask is a subset of the next stage's — the CXL
    multi-level-switching shape, where an event entering the fabric at
    depth ``d`` traverses every shallower switch on its way to the RC.
    Under that nesting the cascade never needs full-width merges: the
    working array ``A`` holds exactly the events that traverse the current
    stage, each stage folds in the (time-sorted) segment of events whose
    *deepest* switch it is with one :func:`two_run_merge`, and the stage
    scan runs **unmasked** — its output start times are non-decreasing, so
    ``A`` stays sorted and never splits back into runs.  Total merge work
    is the sum of the growing compact widths instead of S full-width
    merge+scan passes, and local-DRAM traffic (no routes) never enters at
    all.

    Per-event final times are bitwise identical to
    :func:`serial_queue_cascade` on tie-free inputs: a compact segment is
    the same subsequence the full-width masked scan sees, with identical
    ranks and the identical ``f + stt*rank`` float chain.  (Exact-time ties
    *across* entry depths may resolve in a different — equally valid FIFO —
    order; per-stage delay sums then still agree.)

    Pads ride along keyed ``+inf`` with ``idx < 0``: merges keep them at
    the tail, the unmasked scan maps them ``+inf -> +inf``, and delay sums
    mask them out.

    Returns ``(t_fin [W], idx [W], per_stage_delay [D])``.
    """
    f32 = t_pack.dtype
    caps = [int(c) for c in seg_caps]
    if sum(caps) != t_pack.shape[0]:
        raise ValueError(f"seg_caps {caps} do not tile length {t_pack.shape[0]}")
    a_t = t_pack[:0]
    a_i = idx_pack[:0]
    per_stage = []
    off = 0
    for p, cap in enumerate(caps):
        if cap:
            seg_t = t_pack[off : off + cap]
            seg_i = idx_pack[off : off + cap]
            if a_t.shape[0] == 0:
                a_t, a_i = seg_t, seg_i
            else:
                w0 = a_t.shape[0]
                lead = jnp.arange(w0 + cap, dtype=jnp.int32) < w0
                a_t, a_i = two_run_merge(
                    jnp.concatenate([a_t, seg_t]),
                    lead,
                    jnp.concatenate([a_i, seg_i]),
                )
            off += cap
        if a_t.shape[0] == 0:
            per_stage.append(jnp.zeros((), f32))
            continue
        stt = stts[p]
        rankf = jnp.arange(a_t.shape[0], dtype=f32)
        g = a_t - stt * rankf
        f = jax.lax.cummax(g)
        start = f + stt * rankf
        real = a_i >= 0
        d = jnp.where(real, start - a_t, 0.0)
        per_stage.append(d.sum())
        a_t = jnp.where(real, start, a_t)
    return a_t, a_i, jnp.stack(per_stage)


def serial_queue_cascade(
    t_sorted: jnp.ndarray,  # [N] f32, globally time-sorted arrivals
    route_bits: jnp.ndarray,  # [N] i32, bit s set iff event traverses stage s
    stts: jnp.ndarray,  # [S] f32, service times in stage order
    merge_plan=None,  # static: per-stage tuple of (changed_bit, within_bit|None)
    hosts: jnp.ndarray = None,  # [N] i32 host ids in sorted order (optional)
    n_hosts: int = 1,  # static; only used when hosts is given
):
    """Fused S-stage congestion cascade over one time-sorted epoch.

    Runs every switch's serial queue (deepest stage first, encoded by the
    caller's stage order) over the same array with **one** initial sort: the
    array is kept physically sorted (per stage mask) by *current* time
    throughout, so each stage's scan sees true arrival order.  This
    reproduces the per-stage re-sort of ``analyze_ref`` exactly (up to tie
    attribution at identical float times) without ever re-sorting.

    ``merge_plan`` (static) lists, per stage, the :func:`merge_sorted_runs`
    ops to run *before* that stage's scan: each op names the route-bit of
    the sorted run to fold in and the route-bit of the subsequence to merge
    within (``None`` = whole array).  ``None`` for the whole plan selects
    the conservative schedule — a full two-run merge before every stage,
    folding in the previous stage's events — which is always valid.  The
    epoch analyzer derives a minimal plan from the topology's route matrix
    (nested or disjoint stage masks need no merge at all: a subsequence of
    a sorted run is sorted).  All merges are skipped at runtime while no
    stage has accumulated any delay.

    Returns ``(t_final, slot_idx, per_stage_delay)`` where ``t_final[k]`` is
    the post-congestion time of the event originally at sorted position
    ``slot_idx[k]``, and ``per_stage_delay[s]`` is the summed queueing delay
    at stage ``s``.

    With ``hosts`` (per-event host ids in the same sorted order as
    ``t_sorted``), ``per_stage_delay`` is host-segmented to shape ``[S,
    n_hosts]`` — the shared-fabric decomposition: a stage's queueing delay
    is charged to the host whose event waited.  Hosts are recovered through
    the cascade's live permutation (``hosts[idx]``), so merges need no extra
    payload.

    The cascade never sees latencies: device-cache latency scaling
    (:mod:`repro.core.cache`) happens on the caller's side, which is what
    keeps this oracle — and the Pallas kernel it specifies — identical
    across cache-enabled and cache-free analyses.
    """
    f32 = t_sorted.dtype
    n = t_sorted.shape[0]
    s_stages = stts.shape[0]
    if merge_plan is None:
        merge_plan = tuple(((s - 1, None),) if s else () for s in range(s_stages))
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    ts = t_sorted
    bits = route_bits.astype(jnp.int32)
    idx = jnp.arange(n, dtype=jnp.int32)
    dirty = jnp.zeros((), f32)  # total delay so far; 0 => nothing ever moved
    per_stage = []
    for s in range(s_stages):
        for changed_bit, within_bit in merge_plan[s]:
            changed = (jnp.right_shift(bits, changed_bit) & 1) == 1
            if within_bit is None:
                args = (ts, bits, idx, changed)
                merge = lambda a: merge_sorted_runs(a[0], a[3], a[1], a[2])
            else:
                within = (jnp.right_shift(bits, within_bit) & 1) == 1
                args = (ts, bits, idx, changed, within)
                merge = lambda a: merge_sorted_runs(
                    a[0], a[3], a[1], a[2], within=a[4]
                )
            ts, bits, idx = jax.lax.cond(
                dirty > 0, merge, lambda a: (a[0], a[1], a[2]), args
            )
        m = (jnp.right_shift(bits, s) & 1) == 1
        stt = stts[s]
        rankf = (jnp.cumsum(m.astype(jnp.int32)) - 1).astype(f32)
        g = jnp.where(m, ts - stt * rankf, -big)
        f = jax.lax.cummax(g)
        start = jnp.where(m, f + stt * rankf, ts)
        d = jnp.where(m, start - ts, 0.0)
        dsum = d.sum()
        if hosts is None:
            per_stage.append(dsum)
        else:
            per_stage.append(
                jax.ops.segment_sum(d, hosts[idx], num_segments=n_hosts)
            )
        dirty = dirty + dsum
        ts = jnp.where(m, start, ts)
    return ts, idx, jnp.stack(per_stage)


# --------------------------------------------------------------------------- #
# QoS arbitration cascades
# --------------------------------------------------------------------------- #


def _class_scan(ts, M, stt_c, big):
    """Serial-queue start times over the ``M`` subsequence with service time
    ``stt_c`` — the shared primitive of every discipline's per-class scan.
    Values are only meaningful at ``M`` positions."""
    f32 = ts.dtype
    rankf = (jnp.cumsum(M.astype(jnp.int32)) - 1).astype(f32)
    g = jnp.where(M, ts - stt_c * rankf, -big)
    f = jax.lax.cummax(g)
    return f + stt_c * rankf


def _qos_fold(ts, bits, idx, qos, s, n_classes, dirty, fifo_like):
    """Restore sortedness after stage ``s``'s per-class scans.

    A discipline's per-class scans leave up to ``C + 1`` interleaved sorted
    runs: each class's start times are non-decreasing along its own
    subsequence (a serial queue never reorders its arrivals), and the
    unmasked events keep their previous order.  ``C`` sequential
    :func:`merge_sorted_runs` calls fold the runs back together — step ``c``
    merges class ``c``'s run *within* the subsequence that excludes the
    not-yet-folded classes ``> c``, so every step is a true two-sorted-run
    merge.  Masks are recomputed from the live permutation after each step.

    ``fifo_like`` (static, or per-stage data under ``jnp.where`` in the
    dynamic path) collapses the fold to the single conservative full merge
    of :func:`serial_queue_cascade`: with every masked event in class 0 the
    first step is the full two-run merge and the rest are identity
    permutations.
    """
    for c in range(n_classes):
        m_cur = (jnp.right_shift(bits, s) & 1) == 1
        q_cur = jnp.take(qos, idx)
        if fifo_like:
            q_cur = jnp.zeros_like(q_cur)
        changed = m_cur & (q_cur == c)
        within = ~(m_cur & (q_cur > c))
        args = (ts, bits, idx, changed, within)
        ts, bits, idx = jax.lax.cond(
            dirty > 0,
            lambda a: merge_sorted_runs(a[0], a[3], a[1], a[2], within=a[4]),
            lambda a: (a[0], a[1], a[2]),
            args,
        )
    return ts, bits, idx


def qos_serial_queue_cascade(
    t_sorted: jnp.ndarray,  # [N] f32, globally time-sorted arrivals
    route_bits: jnp.ndarray,  # [N] i32, bit s set iff event traverses stage s
    stts: jnp.ndarray,  # [S] f32, service times in stage order
    qos: jnp.ndarray,  # [N] i32 QoS class per event, in sorted order
    class_weights: jnp.ndarray,  # [S, C] f32 per-stage WFQ class weights
    disciplines,  # static: tuple of "fifo" | "priority" | "wfq", one per stage
    merge_plan=None,  # static: forwarded to the FIFO fast path
    hosts: jnp.ndarray = None,  # [N] i32 host ids in sorted order (optional)
    n_hosts: int = 1,  # static; only used when hosts is given
):
    """QoS-arbitrated S-stage congestion cascade (static disciplines).

    Extends :func:`serial_queue_cascade` with per-switch queue disciplines:

    * ``fifo`` — the plain serial queue.
    * ``priority`` — strict priority with FIFO within class (class 0
      highest): an event of class ``c`` takes its start time from the FIFO
      scan over the subsequence of classes ``<= c``, i.e. it waits behind
      every earlier higher-or-equal-priority arrival but is invisible to
      them.
    * ``wfq`` — weighted-fair queueing in virtual-time form: class ``c``
      is served as its own FIFO queue with inflated service time
      ``stt * W / w_c`` (``W`` the stage's total weight), the fluid-limit
      GPS approximation where each class owns a ``w_c / W`` bandwidth
      share.

    When every stage is ``fifo`` this function takes *exactly* the
    :func:`serial_queue_cascade` path — same merge schedule, same scan
    arithmetic — so final times and ``idx`` are bitwise identical; the QoS
    class only affects delay attribution.  Mixed disciplines replace the
    caller's ``merge_plan`` with the always-valid per-class fold of
    :func:`_qos_fold` after every stage but the last.

    Returns ``(t_final, slot_idx, per_stage_delay)`` with ``per_stage_delay``
    shaped ``[S, C]`` (no hosts) or ``[S, n_hosts, C]`` (host-segmented):
    stage delay charged to the (host, class) whose event waited.
    """
    f32 = t_sorted.dtype
    n = t_sorted.shape[0]
    s_stages = stts.shape[0]
    n_classes = class_weights.shape[1]
    disciplines = tuple(disciplines)
    if len(disciplines) != s_stages:
        raise ValueError(
            f"{len(disciplines)} disciplines for {s_stages} stages"
        )
    all_fifo = all(d == "fifo" for d in disciplines)
    if merge_plan is None:
        merge_plan = tuple(
            ((s - 1, None),) if s else () for s in range(s_stages)
        )
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    ts = t_sorted
    bits = route_bits.astype(jnp.int32)
    qos = jnp.clip(qos.astype(jnp.int32), 0, n_classes - 1)
    idx = jnp.arange(n, dtype=jnp.int32)
    dirty = jnp.zeros((), f32)
    per_stage = []
    for s in range(s_stages):
        if all_fifo:
            # bitwise serial_queue_cascade merge schedule
            for changed_bit, within_bit in merge_plan[s]:
                changed = (jnp.right_shift(bits, changed_bit) & 1) == 1
                if within_bit is None:
                    args = (ts, bits, idx, changed)
                    merge = lambda a: merge_sorted_runs(a[0], a[3], a[1], a[2])
                else:
                    within = (jnp.right_shift(bits, within_bit) & 1) == 1
                    args = (ts, bits, idx, changed, within)
                    merge = lambda a: merge_sorted_runs(
                        a[0], a[3], a[1], a[2], within=a[4]
                    )
                ts, bits, idx = jax.lax.cond(
                    dirty > 0, merge, lambda a: (a[0], a[1], a[2]), args
                )
        m = (jnp.right_shift(bits, s) & 1) == 1
        stt = stts[s]
        disc = disciplines[s]
        q_cur = jnp.take(qos, idx)
        if disc == "fifo":
            start = jnp.where(m, _class_scan(ts, m, stt, big), ts)
        elif disc == "priority":
            start = ts
            for lvl in range(n_classes):
                sc = _class_scan(ts, m & (q_cur <= lvl), stt, big)
                start = jnp.where(m & (q_cur == lvl), sc, start)
        elif disc == "wfq":
            w_row = class_weights[s]
            w_total = w_row.sum()
            start = ts
            for c in range(n_classes):
                M = m & (q_cur == c)
                sc = _class_scan(ts, M, stt * w_total / w_row[c], big)
                start = jnp.where(M, sc, start)
        else:
            raise ValueError(f"unknown discipline {disc!r}")
        d = jnp.where(m, start - ts, 0.0)
        dsum = d.sum()
        if hosts is None:
            if n_classes == 1:
                per_stage.append(dsum[None])  # bitwise squeeze to FIFO
            else:
                per_stage.append(
                    jax.ops.segment_sum(d, q_cur, num_segments=n_classes)
                )
        else:
            hs = jnp.take(hosts, idx)
            if n_classes == 1:
                per_stage.append(
                    jax.ops.segment_sum(d, hs, num_segments=n_hosts)[:, None]
                )
            else:
                per_stage.append(
                    jax.ops.segment_sum(
                        d, hs * n_classes + q_cur,
                        num_segments=n_hosts * n_classes,
                    ).reshape(n_hosts, n_classes)
                )
        dirty = dirty + dsum
        ts = jnp.where(m, start, ts)
        if not all_fifo and s < s_stages - 1:
            ts, bits, idx = _qos_fold(
                ts, bits, idx, qos, s, n_classes, dirty,
                fifo_like=(disc == "fifo"),
            )
    return ts, idx, jnp.stack(per_stage)


def _f32_sort_key(ts: jnp.ndarray) -> jnp.ndarray:
    """Order-preserving int32 image of an f32 array (IEEE-754 trick: for
    non-negative floats the bit pattern is already monotone; negatives have
    their magnitude bits flipped so more-negative sorts lower)."""
    x = jax.lax.bitcast_convert_type(ts, jnp.int32)
    return jnp.where(x >= 0, x, x ^ jnp.int32(0x7FFFFFFF))


def _qos_rank_fold(ts, bits, idx, run_id, n_runs):
    """Restore global time order after a stage by ONE stable multi-run merge.

    The array interleaves ``n_runs`` individually-sorted runs (per-class
    start-time runs plus the untouched events).  Each element's merged
    position is its rank within its own run plus, per other run ``j``, the
    count of run-``j`` elements that precede it — read off ``searchsorted``
    against run ``j``'s cummax *key envelope* (no scatter compaction:
    within a run, keys are non-decreasing along array positions, so the
    envelope at position ``p`` IS the last run-``j`` key at ``<= p``).

    The merge is **stable**: equal-key elements keep their current array
    order.  This is load-bearing for DES parity — the oracle's heap breaks
    time ties by push sequence, which is exactly the previous stage's
    processing order, i.e. the pre-fold array order.  Stability per run
    ``j`` is three monotone counts clamped together: with ``a`` = #run-j
    strictly below the key, ``a2`` = #run-j at-or-below, and ``pc`` =
    #run-j at earlier array positions, the stable contribution is
    ``clip(pc, a, a2)`` — the run-j elements below count fully, those
    above not at all, and the tied ones exactly when they sit earlier in
    the array (run-j keys are non-decreasing along positions, so its
    first ``pc`` elements are precisely those at earlier positions).

    The per-position counts are one batched scan + cumsum; the result is a
    strict total order, so the final inverse-permutation scatter never
    collides.  Cost: ``2·n_runs`` searchsorteds, two [N, R] scans and ONE
    scatter, versus the ``C`` sequential :func:`merge_sorted_runs` (each
    with its own scatter compactions and payload scatters) this replaces.
    """
    n = ts.shape[0]
    key = _f32_sort_key(ts)
    neg = jnp.iinfo(jnp.int32).min
    iota = jnp.arange(n, dtype=jnp.int32)
    mj = run_id[:, None] == jnp.arange(n_runs, dtype=run_id.dtype)[None, :]
    env = jax.lax.associative_scan(
        jnp.maximum, jnp.where(mj, key[:, None], neg), axis=0
    )  # [N, R]
    pc = jnp.cumsum(mj.astype(jnp.int32), axis=0)  # [N, R] inclusive
    pos = jnp.zeros((n,), jnp.int32)
    for j in range(n_runs):
        p_lo = jnp.searchsorted(env[:, j], key, side="left")
        p_hi = jnp.searchsorted(env[:, j], key, side="right")
        pcj = pc[:, j]
        a = jnp.where(p_lo > 0, jnp.take(pcj, jnp.maximum(p_lo - 1, 0)), 0)
        a2 = jnp.where(p_hi > 0, jnp.take(pcj, jnp.maximum(p_hi - 1, 0)), 0)
        stable = jnp.clip(pcj, a, a2)
        pos = pos + jnp.where(mj[:, j], pcj - 1, stable)
    inv = jnp.zeros((n,), jnp.int32).at[pos].set(iota, unique_indices=True)
    return jnp.take(ts, inv), jnp.take(bits, inv), jnp.take(idx, inv)


def _tropical_stage(ts, m, q_cur, disc, stt, w_row):
    """Start times for one arbitration stage — ONE max-plus associative scan.

    The DES horizon recurrence for every discipline is a tropical affine
    map per class coordinate ``l``: an event of class ``c`` applies
    ``fin[l] -> max(fin[l], t) + s_l = max(fin[l] + s_l, t + s_l)`` to the
    coordinates it updates (priority: ``l >= c``; WFQ: ``l == c`` with the
    weight-inflated service; FIFO: every ``l`` with ``c_eff = 0``).  Maps of
    the form ``f -> max(f + a, b)`` compose coordinate-wise as
    ``(a1, b1) . (a2, b2) = (a1 + a2, max(b1 + a2, b2))`` — associative, so
    the whole stage is one ``associative_scan`` over an ``[N, C]`` pair
    instead of ``C`` per-class cummax scans.  The event's start is
    ``max(t, fin_prefix[c_read])`` with the *exclusive* prefix (shift by
    one), exactly the event-by-event oracle, vectorized.
    """
    f32 = ts.dtype
    n_classes = w_row.shape[0]
    lv = jnp.arange(n_classes, dtype=q_cur.dtype)
    neg = jnp.asarray(-jnp.inf, f32)
    s_l = jnp.where(disc == DISC_WFQ, stt * w_row.sum() / w_row, stt)  # [C]
    q_eff = jnp.where(disc == DISC_FIFO, 0, q_cur)  # [N] read coordinate
    upd = jnp.where(
        disc == DISC_WFQ,
        lv[None, :] == q_eff[:, None],
        lv[None, :] >= q_eff[:, None],
    ) & m[:, None]  # [N, C] coordinates this event pushes forward
    a = jnp.where(upd, s_l[None, :], jnp.asarray(0.0, f32))
    b = jnp.where(upd, ts[:, None] + s_l[None, :], neg)

    def compose(x, y):
        return (x[0] + y[0], jnp.maximum(x[1] + y[0], y[1]))

    acc_a, acc_b = jax.lax.associative_scan(compose, (a, b), axis=0)
    fin = jnp.maximum(acc_a, acc_b)  # applied to the all-zero initial state
    # exclusive prefix: event i sees the horizons BEFORE itself (row 0 sees
    # the all-zero initial state; t >= 0 makes max(t, 0) = t)
    fin = jnp.concatenate([jnp.zeros((1, n_classes), f32), fin[:-1]], axis=0)
    fin_c = jnp.take_along_axis(fin, q_eff[:, None], axis=1)[:, 0]
    return jnp.maximum(ts, fin_c)


@axes(
    "N", route_bits="N", stts="S", qos="N", disc_code="S",
    class_weights="S,C", hosts="N",
)
def qos_cascade_dyn(
    t_sorted: jnp.ndarray,  # [N] f32, globally time-sorted arrivals
    route_bits: jnp.ndarray,  # [N] i32, bit s set iff event traverses stage s
    stts: jnp.ndarray,  # [S] f32, service times in stage order
    qos: jnp.ndarray,  # [N] i32 QoS class per event, in sorted order
    disc_code: jnp.ndarray,  # [S] i32 DISC_* code per stage (traced)
    class_weights: jnp.ndarray,  # [S, C] f32 per-stage class weights (traced)
    hosts: jnp.ndarray = None,  # [N] i32 host ids in sorted order (optional)
    n_hosts: int = 1,  # static; attribution rows (1 when hosts is None)
):
    """Data-driven QoS cascade: disciplines and weights are *runtime* arrays.

    Same semantics as :func:`qos_serial_queue_cascade`, reformulated so one
    lowering serves every discipline/weight mix — the property that lets a
    ``K``-scenario QoS sweep ride a single vmapped dispatch with zero
    steady-state recompiles.  Two structural optimizations over the static
    spec (identical results on tie-free traces; f32-coincident cross-class
    ties may re-attribute tie-order-ambiguous waiting without changing
    totals):

    * each stage is ONE max-plus associative scan (:func:`_tropical_stage`)
      — the DES horizon recurrence in closed composition form — instead of
      ``C`` per-class cummax scans;
    * the inter-stage fold is ONE *stable* multi-run rank merge
      (:func:`_qos_rank_fold`) instead of ``C`` sequential two-run merges —
      stability reproduces the DES heap's push-sequence tie rule — and is
      *elided* (runtime branch, one lowering) when the NEXT stage is WFQ
      over the same event mask: WFQ events read/update only their own class
      coordinate, and every stage leaves each class subsequence
      non-decreasing in array order, so class-local DES order survives
      without a global re-sort.  The predicate is local and inductive —
      skipped states keep runs = {mask∩class} ∪ {untouched}, exactly what
      the eventual fold's ``run_id`` labels.

    Returns ``(t_final, slot_idx, per_stage_delay[S, H, C])`` where ``H`` is
    ``n_hosts`` (1 when ``hosts`` is None).
    """
    f32 = t_sorted.dtype
    n = t_sorted.shape[0]
    s_stages = stts.shape[0]
    n_classes = class_weights.shape[1]
    ts = t_sorted
    bits = route_bits.astype(jnp.int32)
    qos = jnp.clip(qos.astype(jnp.int32), 0, n_classes - 1)
    idx = jnp.arange(n, dtype=jnp.int32)
    if hosts is None:
        hosts = jnp.zeros((n,), jnp.int32)
        n_hosts = 1
    disc_code = disc_code.astype(jnp.int32)
    dirty = jnp.zeros((), f32)
    per_stage = []
    for s in range(s_stages):
        m = (jnp.right_shift(bits, s) & 1) == 1
        q_cur = jnp.take(qos, idx)
        # a zero-service stage is a DES identity (processed in time order,
        # the horizon never exceeds the current arrival, so start == t and
        # delay == 0 for every discipline) — skip its scan entirely
        start = jax.lax.cond(
            stts[s] > 0,
            lambda a: _tropical_stage(
                a[0], a[1], a[2], disc_code[s], stts[s], class_weights[s]
            ),
            lambda a: a[0],
            (ts, m, q_cur),
        )
        d = jnp.where(m, start - ts, 0.0)
        dsum = d.sum()
        seg = jnp.take(hosts, idx) * n_classes + q_cur
        n_seg = n_hosts * n_classes
        if n_seg <= 32:
            # one-hot matmul: far cheaper than a scatter-based segment_sum
            # at small segment counts (a single fused reduction per column)
            oh = (seg[:, None] == jnp.arange(n_seg, dtype=jnp.int32)[None, :])
            per_stage.append((d @ oh.astype(f32)).reshape(n_hosts, n_classes))
        else:
            per_stage.append(
                jax.ops.segment_sum(d, seg, num_segments=n_seg)
                .reshape(n_hosts, n_classes)
            )
        dirty = dirty + dsum
        ts = jnp.where(m, start, ts)
        if s < s_stages - 1:
            # Elide the fold when the NEXT stage is WFQ over the SAME event
            # mask (traced check — one lowering serves every mix).  WFQ
            # reads/updates only its own class coordinate and every stage
            # leaves each class subsequence non-decreasing in array order,
            # so the class-local DES order (time, then previous-stage
            # processing order) is already the array order.  Inductively the
            # skipped state keeps runs = {mask∩class} ∪ {untouched}, which
            # is exactly what ``run_id`` labels at the eventual fold.
            next_bit = (jnp.right_shift(bits, s + 1) & 1) == 1
            skip = (disc_code[s + 1] == DISC_WFQ) & jnp.all(next_bit == m)
            if s + 1 == s_stages - 1:
                # a trailing zero-service stage is an identity (see above),
                # so it never needs its input re-sorted either
                skip = skip | (stts[s + 1] == 0.0)
            do_fold = (dirty > 0) & jnp.logical_not(skip)
            run_id = jnp.where(
                m, jnp.where(disc_code[s] == DISC_FIFO, 0, q_cur), n_classes
            )
            ts, bits, idx = jax.lax.cond(
                do_fold,
                lambda a: _qos_rank_fold(a[0], a[1], a[2], a[3], n_classes + 1),
                lambda a: (a[0], a[1], a[2]),
                (ts, bits, idx, run_id),
            )
    return ts, idx, jnp.stack(per_stage)


# --------------------------------------------------------------------------- #
# flash-attention oracle
# --------------------------------------------------------------------------- #


def mha_attention(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,  # [B, Hk, Sk, D]
    v: jnp.ndarray,  # [B, Hk, Sk, D]
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Full-matrix GQA attention in f32 (the flash kernel oracle).

    ``q_offset``: absolute position of q[0] (for decode: Sq=1, offset=cache
    length) so causality is computed on absolute positions.
    """
    B, H, Sq, D = q.shape
    Hk = k.shape[1]
    assert H % Hk == 0
    g = H // Hk
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if causal:
        Sk = k.shape[2]
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
# Mamba2 SSD oracles
# --------------------------------------------------------------------------- #


def ssd_naive(
    x: jnp.ndarray,  # [B, L, H, P]   (P = head dim)
    dt: jnp.ndarray,  # [B, L, H]      (softplus-activated step)
    A: jnp.ndarray,  # [H]            (negative; per-head scalar decay rate)
    Bm: jnp.ndarray,  # [B, L, N]      (input projection onto state, 1 group)
    Cm: jnp.ndarray,  # [B, L, N]      (state readout, 1 group)
) -> jnp.ndarray:
    """Sequential state-space recurrence (the exact semantics):

        h_t = exp(A·dt_t) ⊙ h_{t−1} + dt_t · B_t ⊗ x_t        h ∈ [N, P]
        y_t = C_t · h_t
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def one_head(xh, dth, Ah, Bmh, Cmh):
        # xh [L,P], dth [L], Bmh/Cmh [L,N]
        decay = jnp.exp(Ah * dth)  # [L]

        def step(h, inp):
            xt, dt_t, dec, bt, ct = inp
            h = dec * h + dt_t * (bt[:, None] * xt[None, :])  # [N,P]
            y = ct @ h  # [P]
            return h, y

        h0 = jnp.zeros((N, P), f32)
        _, ys = jax.lax.scan(step, h0, (xh, dth, decay, Bmh, Cmh))
        return ys  # [L,P]

    out = jax.vmap(  # over batch
        jax.vmap(  # over heads
            one_head, in_axes=(1, 1, 0, None, None), out_axes=1
        ),
        in_axes=(0, 0, None, 0, 0),
        out_axes=0,
    )(x.astype(f32), dt.astype(f32), A.astype(f32), Bm.astype(f32), Cm.astype(f32))
    return out.astype(x.dtype)  # [B, L, H, P]


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    chunk: int = 64,
) -> jnp.ndarray:
    """Chunked SSD (state-space duality) — the blocked algorithm the Pallas
    kernel implements: quadratic attention-like math within chunks, linear
    state passing between chunks.  Must agree with :func:`ssd_naive`.
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, "sequence must be divisible by chunk"
    C = L // chunk
    f32 = jnp.float32

    x_ = x.astype(f32).reshape(Bsz, C, chunk, H, P)
    dt_ = dt.astype(f32).reshape(Bsz, C, chunk, H)
    B_ = Bm.astype(f32).reshape(Bsz, C, chunk, N)
    C_ = Cm.astype(f32).reshape(Bsz, C, chunk, N)
    A_ = A.astype(f32)

    # per-position log decay a_t = A·dt_t ; cumulative within chunk
    a = A_[None, None, None, :] * dt_[..., :]  # [B,C,c,H]
    acum = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (quadratic, like masked attention) ------------------- #
    # y_intra[t] = Σ_{s≤t} C_t·B_s dt_s exp(acum_t − acum_s) x_s
    seg = acum[:, :, :, None, :] - acum[:, :, None, :, :]  # [B,C,t,s,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    G = jnp.einsum("bctn,bcsn->bcts", C_, B_)  # [B,C,t,s]
    W = G[..., None] * jnp.exp(seg) * dt_[:, :, None, :, :]  # [B,C,t,s,H]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", W, x_)

    # ---- chunk states ------------------------------------------------------ #
    # state_c = Σ_s B_s dt_s exp(acum_last − acum_s) x_s   ∈ [N,P]
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)  # [B,C,c,H]
    S = jnp.einsum(
        "bcsn,bcsh,bcshp->bchnp", B_, dt_ * decay_to_end, x_
    )  # [B,C,H,N,P]
    chunk_decay = jnp.exp(acum[:, :, -1, :])  # [B,C,H]

    # ---- inter-chunk scan --------------------------------------------------- #
    def scan_fn(h, inp):
        S_c, dec_c = inp  # [B,H,N,P], [B,H]
        h_out = h  # state BEFORE this chunk
        h = dec_c[..., None, None] * h + S_c
        return h, h_out

    h0 = jnp.zeros((Bsz, H, N, P), f32)
    _, h_prev = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [B,C,H,N,P] state entering chunk

    # ---- inter-chunk contribution ------------------------------------------ #
    # y_inter[t] = C_t · (exp(acum_t) ⊙ h_prev)
    y_inter = jnp.einsum(
        "bctn,bcth,bchnp->bcthp", C_, jnp.exp(acum), h_prev
    )

    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y.astype(x.dtype)
