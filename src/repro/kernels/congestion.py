"""Pallas TPU kernels for the congestion serial-queue scan (paper §3, delay 2).

The Timing Analyzer's hot loop is, per switch, the FIFO queue
``out_i = max(arr_i, out_{i-1} + STT)`` over the time-sorted events that
traverse the switch.  The closed form

    out_i = cummax(arr_i − STT·rank_i) + STT·rank_i,   rank = cumsum(mask) − 1

turns it into two prefix scans (a cumsum over the mask and a cummax over the
shifted arrivals), which map onto the TPU VPU as log₂(B) lane-shift/max steps
per block plus a scalar carry between sequential grid steps.

Two kernels:

  * :func:`congestion_scan` — one switch's queue over a pre-sorted epoch
    (the original single-stage kernel; kept for the legacy per-stage path).
  * :func:`congestion_cascade` / :func:`congestion_cascade_hosts` — the
    fused S-stage cascade: one kernel launch walks every switch stage
    (deepest first) over the same epoch.  Both wrap the one shared body
    (:func:`_cascade_body`); the hosts variant statically adds a host-id
    row (permuted alongside through every merge) and per-host delay slots
    in the SMEM stage carries — the shared-fabric decomposition — while
    the single-host variant emits exactly the original kernel.
    Grid is ``(S, N/B)``; the per-switch carries (running cummax ``f``,
    masked-event rank, and the stage's delay sum) live in SMEM and are reset
    at the first block of each stage, extending the single-switch scan's
    carry scheme.  The full epoch's current times / route bits / slot
    indices persist in VMEM scratch across sequential grid steps; after each
    stage the last block restores the sorted-by-current-time invariant by
    merging the two sorted runs (queued vs untouched events) with rank
    arithmetic — no re-sort, so the whole cascade needs exactly one host
    sort.  This matches ``analyze_ref``'s per-stage re-sort semantics.

TPU adaptation notes (vs the paper's sequential C++ loop):
  * events live in HBM as (1, N) f32 rows; each grid step pulls a (1, B)
    tile into VMEM (BlockSpec below), B = 2048 lanes;
  * prefix scans are done with jnp.cumsum / lax.cummax inside the block —
    XLA lowers them to log-depth vector ops on the 8×128 VPU;
  * the inter-block carry is kept in an SMEM scratch, exploiting the fact
    that the TPU grid is executed sequentially — this is the idiomatic TPU
    replacement for the GPU-style decoupled-lookback scan;
  * the cascade's inter-stage merge uses dynamic gather/scatter on the VMEM
    scratch; it is validated in interpret mode (the CPU test/bench path).
    On hosts without a TPU the production analyzer path is the fused
    ``inline`` XLA variant (:func:`repro.kernels.ref.serial_queue_cascade`),
    which is semantically identical;
  * the cascade is **latency-agnostic**: it queues arrival times only.
    Device-cache mode (:mod:`repro.core.cache`) reshapes the per-event
    *latency* through a per-(host, pool) scale vector applied outside the
    kernel, in :func:`repro.core.analyzer._analyze_jax` — so this one
    kernel body serves cache-enabled and cache-free analyses alike, and
    hits still contend at every switch (the cache sits on the expander,
    behind the fabric).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..analysis.annotations import axes
from . import ref as _ref

__all__ = [
    "congestion_cascade",
    "congestion_cascade_hosts",
    "congestion_scan",
    "qos_congestion_cascade",
    "DEFAULT_BLOCK",
]

DEFAULT_BLOCK = 2048
_NEG = -1e30  # sentinel "minus infinity" safely inside f32


def _kernel(t_ref, m_ref, stt_ref, out_ref, delay_ref, carry_ref):
    """One (1, B) block of the masked serial-queue scan.

    carry_ref (SMEM, f32[2]): [0] = running max of g over prior blocks,
                              [1] = number of masked events in prior blocks.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = _NEG
        carry_ref[1] = 0.0

    t = t_ref[0, :]
    m = m_ref[0, :]
    stt = stt_ref[0]
    mf = m.astype(t.dtype)

    rank_local = jnp.cumsum(mf) - 1.0  # inclusive cumsum − 1
    rank = rank_local + carry_ref[1]
    g = jnp.where(m, t - stt * rank, _NEG)
    f_local = jax.lax.cummax(g)
    f = jnp.maximum(f_local, carry_ref[0])
    start = jnp.where(m, f + stt * rank, t)

    out_ref[0, :] = start
    delay_ref[0, :] = jnp.where(m, start - t, 0.0)

    carry_ref[0] = jnp.maximum(carry_ref[0], f_local[-1])
    carry_ref[1] = carry_ref[1] + jnp.sum(mf)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def congestion_scan(
    t_sorted: jnp.ndarray,  # [N] f32, time-sorted arrivals
    mask: jnp.ndarray,  # [N] bool, events traversing this switch
    stt,  # scalar f32
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Returns ``(start_times[N], delays[N])`` for one switch's queue."""
    n = t_sorted.shape[0]
    if n % block != 0:
        pad = block - n % block
        t_sorted = jnp.pad(t_sorted, (0, pad), constant_values=jnp.finfo(t_sorted.dtype).max / 8)
        mask = jnp.pad(mask, (0, pad))
    npad = t_sorted.shape[0]
    grid = npad // block

    t2 = t_sorted.reshape(1, npad)
    m2 = mask.reshape(1, npad)
    stt_arr = jnp.asarray([stt], t_sorted.dtype)

    out, delay = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),  # t tile in VMEM
            pl.BlockSpec((1, block), lambda i: (0, i)),  # mask tile
            pl.BlockSpec(memory_space=pl.ANY),  # stt scalar
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), t_sorted.dtype),
            jax.ShapeDtypeStruct((1, npad), t_sorted.dtype),
        ],
        scratch_shapes=[pltpu.SMEM((2,), t_sorted.dtype)],
        interpret=interpret,
    )(t2, m2, stt_arr)
    return out[0, :n], delay[0, :n]


# --------------------------------------------------------------------------- #
# Fused multi-stage cascade
# --------------------------------------------------------------------------- #


def _cascade_body(n_hosts, has_hosts, *refs):
    """One (stage, block) step of the fused cascade — shared kernel body.

    ``has_hosts`` (static) selects the host-segmented variant: the refs
    gain a host-id input tile and a host VMEM row (permuted alongside the
    times through every merge), the SMEM stage carries gain ``n_hosts``
    per-host delay slots, and the per-stage delay output row widens from
    one scalar to ``[n_hosts]``.  With ``has_hosts=False`` the emitted code
    is exactly the single-host cascade — no host tile, no extra scratch,
    no second delay reduction.

    Ref layout (inputs, outputs, scratch):
      t_ref     (1, B) time-sorted arrival tile (read at stage 0 only)
      bits_ref  (1, B) per-event route bits (stage s <-> bit s)
      host_ref  (1, B) per-event host ids                  [has_hosts only]
      stt_ref   (S,)   service times in stage order
      tout_ref  (1, N) final post-congestion times (sorted slot order)
      idx_ref   (1, N) slot -> original sorted position
      delay_ref (1, H or 1) per-stage delay row, block s of the output
      t_buf     VMEM (1, N) current times, kept sorted across stages
      bits_buf  VMEM (1, N) route bits, permuted alongside t_buf
      idx_buf   VMEM (1, N) original sorted position, permuted alongside
      host_buf  VMEM (1, N) host ids, permuted alongside  [has_hosts only]
      carry_ref SMEM f32[3 (+ H)]: [0]=cummax, [1]=rank, [2]=stage delay,
                [3 + h]=host h's delay sum                [has_hosts only]
    """
    if has_hosts:
        (t_ref, bits_ref, host_ref, stt_ref, tout_ref, idx_ref, delay_ref,
         t_buf, bits_buf, idx_buf, host_buf, carry_ref) = refs
    else:
        (t_ref, bits_ref, stt_ref, tout_ref, idx_ref, delay_ref,
         t_buf, bits_buf, idx_buf, carry_ref) = refs
    s = pl.program_id(0)
    b = pl.program_id(1)
    nb = pl.num_programs(1)
    n_stages = pl.num_programs(0)
    block = t_ref.shape[1]
    off = b * block

    @pl.when(s == 0)
    def _load():
        t_buf[0, pl.ds(off, block)] = t_ref[0, :]
        bits_buf[0, pl.ds(off, block)] = bits_ref[0, :]
        if has_hosts:
            host_buf[0, pl.ds(off, block)] = host_ref[0, :]
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
        idx_buf[0, pl.ds(off, block)] = iota[0, :] + off

    @pl.when(b == 0)
    def _reset_stage_carries():
        carry_ref[0] = _NEG
        carry_ref[1] = 0.0
        carry_ref[2] = 0.0
        if has_hosts:
            for h in range(n_hosts):
                carry_ref[3 + h] = 0.0

    t = t_buf[0, pl.ds(off, block)]
    bits = bits_buf[0, pl.ds(off, block)]
    m = (jnp.right_shift(bits, s) & 1) == 1
    stt = stt_ref[s]
    mf = m.astype(t.dtype)

    rank = (jnp.cumsum(mf) - 1.0) + carry_ref[1]
    g = jnp.where(m, t - stt * rank, _NEG)
    f_local = jax.lax.cummax(g)
    f = jnp.maximum(f_local, carry_ref[0])
    start = jnp.where(m, f + stt * rank, t)
    d = jnp.where(m, start - t, 0.0)

    t_buf[0, pl.ds(off, block)] = start
    carry_ref[0] = jnp.maximum(carry_ref[0], f_local[-1])
    carry_ref[1] = carry_ref[1] + jnp.sum(mf)
    carry_ref[2] = carry_ref[2] + jnp.sum(d)
    if has_hosts:
        hv = host_buf[0, pl.ds(off, block)]
        for h in range(n_hosts):
            carry_ref[3 + h] = carry_ref[3 + h] + jnp.sum(
                jnp.where(hv == h, d, 0.0)
            )

    @pl.when(b == nb - 1)
    def _finish_stage():
        if has_hosts:
            for h in range(n_hosts):
                delay_ref[0, h] = carry_ref[3 + h]
        else:
            delay_ref[0, 0] = carry_ref[2]

        @pl.when((s < n_stages - 1) & (carry_ref[2] > 0))
        def _merge():
            # The stage rewrote its masked events: the full row is now two
            # interleaved sorted runs.  Restore the sorted invariant so the
            # next stage's scan sees true arrival order (zero delay => times
            # unchanged => already sorted => skipped).
            x = t_buf[0, :]
            bt = bits_buf[0, :]
            ix = idx_buf[0, :]
            changed = (jnp.right_shift(bt, s) & 1) == 1
            if has_hosts:
                hrow = host_buf[0, :]
                x, bt, ix, hrow = _ref.merge_sorted_runs(x, changed, bt, ix, hrow)
                host_buf[0, :] = hrow
            else:
                x, bt, ix = _ref.merge_sorted_runs(x, changed, bt, ix)
            t_buf[0, :] = x
            bits_buf[0, :] = bt
            idx_buf[0, :] = ix

        @pl.when(s == n_stages - 1)
        def _write_out():
            tout_ref[0, :] = t_buf[0, :]
            idx_ref[0, :] = idx_buf[0, :]


def _pad_to_block(block, t_sorted, route_bits, hosts=None):
    n = t_sorted.shape[0]
    if n % block != 0:
        pad = block - n % block
        t_sorted = jnp.pad(
            t_sorted, (0, pad), constant_values=jnp.finfo(t_sorted.dtype).max / 4
        )
        route_bits = jnp.pad(route_bits, (0, pad))
        if hosts is not None:
            hosts = jnp.pad(hosts, (0, pad))
    return t_sorted, route_bits, hosts


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
@axes("N", route_bits="N", stts="S")
def congestion_cascade(
    t_sorted: jnp.ndarray,  # [N] f32, globally time-sorted arrivals
    route_bits: jnp.ndarray,  # [N] i32, bit s set iff event traverses stage s
    stts: jnp.ndarray,  # [S] f32, service times in stage order
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Fused S-stage congestion cascade in a single kernel launch.

    Returns ``(t_final[N], slot_idx[N], per_stage_delay[S])`` with the same
    semantics as :func:`repro.kernels.ref.serial_queue_cascade`: ``t_final``
    is in final sorted-slot order and ``slot_idx`` maps each slot back to its
    position in the input ``t_sorted``.
    """
    n = t_sorted.shape[0]
    n_stages = int(stts.shape[0])
    t_sorted, route_bits, _ = _pad_to_block(block, t_sorted, route_bits)
    npad = t_sorted.shape[0]
    nb = npad // block

    t2 = t_sorted.reshape(1, npad)
    bits2 = route_bits.astype(jnp.int32).reshape(1, npad)
    stt_arr = jnp.asarray(stts, t_sorted.dtype)

    t_fin, idx, delay = pl.pallas_call(
        functools.partial(_cascade_body, 1, False),
        grid=(n_stages, nb),
        in_specs=[
            pl.BlockSpec((1, block), lambda s, b: (0, b)),  # arrival tile
            pl.BlockSpec((1, block), lambda s, b: (0, b)),  # route-bit tile
            pl.BlockSpec(memory_space=pl.ANY),  # stts vector
        ],
        out_specs=[
            pl.BlockSpec((1, npad), lambda s, b: (0, 0)),  # t_final row
            pl.BlockSpec((1, npad), lambda s, b: (0, 0)),  # slot idx row
            pl.BlockSpec((1, 1), lambda s, b: (0, s)),  # stage delay cell
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), t_sorted.dtype),
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_stages), t_sorted.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, npad), t_sorted.dtype),
            pltpu.VMEM((1, npad), jnp.int32),
            pltpu.VMEM((1, npad), jnp.int32),
            pltpu.SMEM((3,), t_sorted.dtype),
        ],
        interpret=interpret,
    )(t2, bits2, stt_arr)
    return t_fin[0, :n], idx[0, :n], delay[0, :]


# --------------------------------------------------------------------------- #
# Host-segmented cascade (shared-fabric multi-host analysis)
# --------------------------------------------------------------------------- #


@functools.partial(jax.jit, static_argnames=("n_hosts", "block", "interpret"))
@axes("N", route_bits="N", hosts="N", stts="S")
def congestion_cascade_hosts(
    t_sorted: jnp.ndarray,  # [N] f32, globally time-sorted arrivals
    route_bits: jnp.ndarray,  # [N] i32, bit s set iff event traverses stage s
    hosts: jnp.ndarray,  # [N] i32 host ids, same sorted order
    stts: jnp.ndarray,  # [S] f32, service times in stage order
    n_hosts: int = 1,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Fused cascade with per-host delay segmentation in one kernel launch.

    Returns ``(t_final[N], slot_idx[N], per_stage_delay[S, n_hosts])`` —
    the host axis decomposes each stage's queueing delay by the host whose
    event waited, matching
    :func:`repro.kernels.ref.serial_queue_cascade` with ``hosts`` given.
    Shares its kernel body (:func:`_cascade_body`) with the single-host
    :func:`congestion_cascade`, which pays none of the host-axis cost.
    """
    n = t_sorted.shape[0]
    n_stages = int(stts.shape[0])
    t_sorted, route_bits, hosts = _pad_to_block(block, t_sorted, route_bits, hosts)
    npad = t_sorted.shape[0]
    nb = npad // block

    t2 = t_sorted.reshape(1, npad)
    bits2 = route_bits.astype(jnp.int32).reshape(1, npad)
    host2 = hosts.astype(jnp.int32).reshape(1, npad)
    stt_arr = jnp.asarray(stts, t_sorted.dtype)

    t_fin, idx, delay = pl.pallas_call(
        functools.partial(_cascade_body, n_hosts, True),
        grid=(n_stages, nb),
        in_specs=[
            pl.BlockSpec((1, block), lambda s, b: (0, b)),  # arrival tile
            pl.BlockSpec((1, block), lambda s, b: (0, b)),  # route-bit tile
            pl.BlockSpec((1, block), lambda s, b: (0, b)),  # host-id tile
            pl.BlockSpec(memory_space=pl.ANY),  # stts vector
        ],
        out_specs=[
            pl.BlockSpec((1, npad), lambda s, b: (0, 0)),  # t_final row
            pl.BlockSpec((1, npad), lambda s, b: (0, 0)),  # slot idx row
            pl.BlockSpec((1, n_hosts), lambda s, b: (0, s)),  # stage delay row
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), t_sorted.dtype),
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_stages * n_hosts), t_sorted.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, npad), t_sorted.dtype),
            pltpu.VMEM((1, npad), jnp.int32),
            pltpu.VMEM((1, npad), jnp.int32),
            pltpu.VMEM((1, npad), jnp.int32),
            pltpu.SMEM((3 + n_hosts,), t_sorted.dtype),
        ],
        interpret=interpret,
    )(t2, bits2, host2, stt_arr)
    return t_fin[0, :n], idx[0, :n], delay[0, :].reshape(n_stages, n_hosts)


# --------------------------------------------------------------------------- #
# QoS-arbitrated cascade (per-class SMEM carries)
# --------------------------------------------------------------------------- #


def _qos_cascade_body(n_classes, *refs):
    """One (stage, block) step of the QoS-arbitrated cascade.

    Extends :func:`_cascade_body` with per-QoS-class state, in the
    data-driven formulation of :func:`repro.kernels.ref.qos_cascade_dyn`:
    disciplines and class weights are runtime scalars read per stage, so one
    lowering serves every discipline/weight mix.  Each stage runs ``C``
    masked scans over the block — class ``c``'s selector is ``q_eff <= c``
    under strict priority and ``q_eff == c`` otherwise, with WFQ inflating
    the service time to ``stt·W/w_c`` — and each scan owns a (cummax, rank)
    carry pair so the inter-block chaining of the FIFO kernel carries over
    per class unchanged.

    Ref layout (inputs, outputs, scratch):
      t_ref     (1, B) time-sorted arrival tile (read at stage 0 only)
      bits_ref  (1, B) per-event route bits (stage s <-> bit s)
      qos_ref   (1, B) per-event QoS class ids (read at stage 0 only)
      stt_ref   (S,)   service times in stage order
      disc_ref  (S,)   i32 discipline codes (ref.DISC_*)
      w_ref     (S, C) f32 per-stage class weights
      tout_ref  (1, N) final post-congestion times (sorted slot order)
      idx_ref   (1, N) slot -> original sorted position
      delay_ref (1, C) per-stage per-class delay row, block s of the output
      t_buf     VMEM (1, N) current times, kept sorted across stages
      bits_buf  VMEM (1, N) route bits, permuted alongside t_buf
      idx_buf   VMEM (1, N) original sorted position, permuted alongside
      qos_buf   VMEM (1, N) QoS classes, permuted alongside
      carry_ref SMEM f32[3C + 1]: [c]=class cummax, [C + c]=class rank,
                [2C + c]=class delay sum, [3C]=stage delay (merge guard)
    """
    (t_ref, bits_ref, qos_ref, stt_ref, disc_ref, w_ref, tout_ref, idx_ref,
     delay_ref, t_buf, bits_buf, idx_buf, qos_buf, carry_ref) = refs
    s = pl.program_id(0)
    b = pl.program_id(1)
    nb = pl.num_programs(1)
    n_stages = pl.num_programs(0)
    block = t_ref.shape[1]
    off = b * block

    @pl.when(s == 0)
    def _load():
        t_buf[0, pl.ds(off, block)] = t_ref[0, :]
        bits_buf[0, pl.ds(off, block)] = bits_ref[0, :]
        qos_buf[0, pl.ds(off, block)] = qos_ref[0, :]
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
        idx_buf[0, pl.ds(off, block)] = iota[0, :] + off

    @pl.when(b == 0)
    def _reset_stage_carries():
        for c in range(n_classes):
            carry_ref[c] = _NEG
            carry_ref[n_classes + c] = 0.0
            carry_ref[2 * n_classes + c] = 0.0
        carry_ref[3 * n_classes] = 0.0

    t = t_buf[0, pl.ds(off, block)]
    bits = bits_buf[0, pl.ds(off, block)]
    qv = qos_buf[0, pl.ds(off, block)]
    m = (jnp.right_shift(bits, s) & 1) == 1
    stt = stt_ref[s]
    disc = disc_ref[s]
    w_total = jnp.zeros((), t.dtype)
    for c in range(n_classes):
        w_total = w_total + w_ref[s, c]
    q_eff = jnp.where(disc == _ref.DISC_FIFO, 0, qv)

    start = t
    for c in range(n_classes):
        sel = jnp.where(disc == _ref.DISC_PRIORITY, q_eff <= c, q_eff == c)
        stt_c = jnp.where(
            disc == _ref.DISC_WFQ, stt * w_total / w_ref[s, c], stt
        )
        M = m & sel
        mf = M.astype(t.dtype)
        rank = (jnp.cumsum(mf) - 1.0) + carry_ref[n_classes + c]
        g = jnp.where(M, t - stt_c * rank, _NEG)
        f_local = jax.lax.cummax(g)
        f = jnp.maximum(f_local, carry_ref[c])
        start = jnp.where(m & (q_eff == c), f + stt_c * rank, start)
        carry_ref[c] = jnp.maximum(carry_ref[c], f_local[-1])
        carry_ref[n_classes + c] = carry_ref[n_classes + c] + jnp.sum(mf)

    d = jnp.where(m, start - t, 0.0)
    t_buf[0, pl.ds(off, block)] = start
    for c in range(n_classes):
        # attribution uses the event's *actual* class, even under FIFO
        carry_ref[2 * n_classes + c] = carry_ref[2 * n_classes + c] + jnp.sum(
            jnp.where(qv == c, d, 0.0)
        )
    carry_ref[3 * n_classes] = carry_ref[3 * n_classes] + jnp.sum(d)

    @pl.when(b == nb - 1)
    def _finish_stage():
        for c in range(n_classes):
            delay_ref[0, c] = carry_ref[2 * n_classes + c]

        @pl.when((s < n_stages - 1) & (carry_ref[3 * n_classes] > 0))
        def _merge():
            # Up to C + 1 interleaved sorted runs after the per-class scans;
            # fold class by class (ref._qos_fold's schedule) — under FIFO
            # q_eff = 0 makes step 0 the full two-run merge and the rest
            # identity permutations.
            x = t_buf[0, :]
            bt = bits_buf[0, :]
            ix = idx_buf[0, :]
            qr = qos_buf[0, :]
            for c in range(n_classes):
                m_cur = (jnp.right_shift(bt, s) & 1) == 1
                q_f = jnp.where(disc == _ref.DISC_FIFO, 0, qr)
                changed = m_cur & (q_f == c)
                within = ~(m_cur & (q_f > c))
                x, bt, ix, qr = _ref.merge_sorted_runs(
                    x, changed, bt, ix, qr, within=within
                )
            t_buf[0, :] = x
            bits_buf[0, :] = bt
            idx_buf[0, :] = ix
            qos_buf[0, :] = qr

        @pl.when(s == n_stages - 1)
        def _write_out():
            tout_ref[0, :] = t_buf[0, :]
            idx_ref[0, :] = idx_buf[0, :]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
@axes("N", route_bits="N", qos="N", stts="S", disc_code="S", class_weights="S,C")
def qos_congestion_cascade(
    t_sorted: jnp.ndarray,  # [N] f32, globally time-sorted arrivals
    route_bits: jnp.ndarray,  # [N] i32, bit s set iff event traverses stage s
    qos: jnp.ndarray,  # [N] i32 QoS class ids, same sorted order
    stts: jnp.ndarray,  # [S] f32, service times in stage order
    disc_code: jnp.ndarray,  # [S] i32 discipline codes (ref.DISC_*)
    class_weights: jnp.ndarray,  # [S, C] f32 per-stage class weights
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Fused QoS-arbitrated cascade in a single kernel launch.

    Returns ``(t_final[N], slot_idx[N], per_stage_delay[S, C])`` matching
    :func:`repro.kernels.ref.qos_cascade_dyn` (single-host form): per-stage
    queueing delay decomposed by the QoS class whose event waited, under
    runtime per-switch disciplines and class weights.
    """
    n = t_sorted.shape[0]
    n_stages = int(stts.shape[0])
    n_classes = int(class_weights.shape[1])
    t_sorted, route_bits, qos = _pad_to_block(block, t_sorted, route_bits, qos)
    npad = t_sorted.shape[0]
    nb = npad // block

    t2 = t_sorted.reshape(1, npad)
    bits2 = route_bits.astype(jnp.int32).reshape(1, npad)
    qos2 = jnp.clip(qos.astype(jnp.int32), 0, n_classes - 1).reshape(1, npad)
    stt_arr = jnp.asarray(stts, t_sorted.dtype)
    disc_arr = jnp.asarray(disc_code, jnp.int32)
    w_arr = jnp.asarray(class_weights, t_sorted.dtype)

    t_fin, idx, delay = pl.pallas_call(
        functools.partial(_qos_cascade_body, n_classes),
        grid=(n_stages, nb),
        in_specs=[
            pl.BlockSpec((1, block), lambda s, b: (0, b)),  # arrival tile
            pl.BlockSpec((1, block), lambda s, b: (0, b)),  # route-bit tile
            pl.BlockSpec((1, block), lambda s, b: (0, b)),  # qos tile
            pl.BlockSpec(memory_space=pl.ANY),  # stts vector
            pl.BlockSpec(memory_space=pl.ANY),  # discipline codes
            pl.BlockSpec(memory_space=pl.ANY),  # class-weight table
        ],
        out_specs=[
            pl.BlockSpec((1, npad), lambda s, b: (0, 0)),  # t_final row
            pl.BlockSpec((1, npad), lambda s, b: (0, 0)),  # slot idx row
            pl.BlockSpec((1, n_classes), lambda s, b: (0, s)),  # stage row
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), t_sorted.dtype),
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_stages * n_classes), t_sorted.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, npad), t_sorted.dtype),
            pltpu.VMEM((1, npad), jnp.int32),
            pltpu.VMEM((1, npad), jnp.int32),
            pltpu.VMEM((1, npad), jnp.int32),
            pltpu.SMEM((3 * n_classes + 1,), t_sorted.dtype),
        ],
        interpret=interpret,
    )(t2, bits2, qos2, stt_arr, disc_arr, w_arr)
    return t_fin[0, :n], idx[0, :n], delay[0, :].reshape(n_stages, n_classes)
