"""Pallas TPU kernel for the congestion serial-queue scan (paper §3, delay 2).

The Timing Analyzer's hot loop is, per switch, the FIFO queue
``out_i = max(arr_i, out_{i-1} + STT)`` over the time-sorted events that
traverse the switch.  The closed form

    out_i = cummax(arr_i − STT·rank_i) + STT·rank_i,   rank = cumsum(mask) − 1

turns it into two prefix scans (a cumsum over the mask and a cummax over the
shifted arrivals), which map onto the TPU VPU as log₂(B) lane-shift/max steps
per block plus a scalar carry between sequential grid steps.

TPU adaptation notes (vs the paper's sequential C++ loop):
  * events live in HBM as (1, N) f32 rows; each grid step pulls a (1, B)
    tile into VMEM (BlockSpec below), B = 2048 lanes;
  * prefix scans are done with jnp.cumsum / lax.cummax inside the block —
    XLA lowers them to log-depth vector ops on the 8×128 VPU;
  * the inter-block carry (running max f and running rank) is kept in an
    SMEM scratch, exploiting the fact that the TPU grid is executed
    sequentially — this is the idiomatic TPU replacement for the GPU-style
    decoupled-lookback scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["congestion_scan", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 2048
_NEG = -1e30  # sentinel "minus infinity" safely inside f32


def _kernel(t_ref, m_ref, stt_ref, out_ref, delay_ref, carry_ref):
    """One (1, B) block of the masked serial-queue scan.

    carry_ref (SMEM, f32[2]): [0] = running max of g over prior blocks,
                              [1] = number of masked events in prior blocks.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = _NEG
        carry_ref[1] = 0.0

    t = t_ref[0, :]
    m = m_ref[0, :]
    stt = stt_ref[0]
    mf = m.astype(t.dtype)

    rank_local = jnp.cumsum(mf) - 1.0  # inclusive cumsum − 1
    rank = rank_local + carry_ref[1]
    g = jnp.where(m, t - stt * rank, _NEG)
    f_local = jax.lax.cummax(g)
    f = jnp.maximum(f_local, carry_ref[0])
    start = jnp.where(m, f + stt * rank, t)

    out_ref[0, :] = start
    delay_ref[0, :] = jnp.where(m, start - t, 0.0)

    carry_ref[0] = jnp.maximum(carry_ref[0], f_local[-1])
    carry_ref[1] = carry_ref[1] + jnp.sum(mf)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def congestion_scan(
    t_sorted: jnp.ndarray,  # [N] f32, time-sorted arrivals
    mask: jnp.ndarray,  # [N] bool, events traversing this switch
    stt,  # scalar f32
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Returns ``(start_times[N], delays[N])`` for one switch's queue."""
    n = t_sorted.shape[0]
    if n % block != 0:
        pad = block - n % block
        t_sorted = jnp.pad(t_sorted, (0, pad), constant_values=jnp.finfo(t_sorted.dtype).max / 8)
        mask = jnp.pad(mask, (0, pad))
    npad = t_sorted.shape[0]
    grid = npad // block

    t2 = t_sorted.reshape(1, npad)
    m2 = mask.reshape(1, npad)
    stt_arr = jnp.asarray([stt], t_sorted.dtype)

    out, delay = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),  # t tile in VMEM
            pl.BlockSpec((1, block), lambda i: (0, i)),  # mask tile
            pl.BlockSpec(memory_space=pl.ANY),  # stt scalar
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), t_sorted.dtype),
            jax.ShapeDtypeStruct((1, npad), t_sorted.dtype),
        ],
        scratch_shapes=[pltpu.SMEM((2,), t_sorted.dtype)],
        interpret=interpret,
    )(t2, m2, stt_arr)
    return out[0, :n], delay[0, :n]
