"""Pallas TPU flash attention (forward), GQA + causal.

VMEM-tiled online-softmax attention.  Grid is (B, H, nQ, nK) with the KV
axis innermost: the TPU executes the grid sequentially, so the running
(max, sum, accumulator) state for one Q tile lives in VMEM scratch across
the KV steps and is normalized + written out on the last step.

Tiling (defaults, f32):
  q tile   (1, 1, BQ, D)  BQ = 256        ->  BQ·D·4      = 128 KiB  (D=128)
  k/v tile (1, 1, BK, D)  BK = 512        ->  2·BK·D·4    = 512 KiB
  acc      (BQ, D) f32 + m/l (BQ, 128)    ->  ~260 KiB
  total ≈ 0.9 MiB of ~16 MiB VMEM — leaves headroom for double buffering.

MXU alignment: BQ, BK, D are multiples of 128 (8·128 sublane×lane tiles,
128×128 systolic matmuls).  GQA is handled in the BlockSpec index maps:
the KV head index is ``h // (H // Hk)``, so no repeated KV materialization
(the oracle's jnp.repeat) ever touches memory.

Causality: KV tiles entirely above the diagonal are skipped with
``pl.when`` — for long sequences this halves the work, and because it is a
grid-step predicate the skipped tiles still advance the sequential grid
without touching the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG = -1e30


def _kernel(
    q_ref, k_ref, v_ref, qoff_ref, out_ref, acc_ref, m_ref, l_ref, *, scale, causal, block_q, block_k
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)
    q_offset = qoff_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this q/k tile
    q_start = qi * block_q + q_offset
    k_start = ki * block_k

    # skip tiles strictly above the causal diagonal
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(jnp.asarray(run) if isinstance(run, bool) else run)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32)  # [BQ, D]
        k = k_ref[0, 0, :, :].astype(jnp.float32)  # [BK, D]
        v = v_ref[0, 0, :, :].astype(jnp.float32)  # [BK, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [BQ, BK]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, _NEG)
        m_prev = m_ref[:, 0]  # [BQ]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)  # [BQ]
        p = jnp.exp(s - m_cur[:, None])  # [BQ, BK]
        l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[:, 0] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        # rows with no visible keys (fully masked) produce 0, not NaN
        denom = jnp.where(l > 0, l, 1.0)
        out_ref[0, 0, :, :] = (acc_ref[...] / denom[:, None]).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,  # [B, Hk, Sk, D]
    v: jnp.ndarray,  # [B, Hk, Sk, D]
    q_offset: int | jnp.ndarray = 0,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    _, Hk, Sk, _ = k.shape
    assert H % Hk == 0, "GQA requires H % Hk == 0"
    group = H // Hk
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k

    qoff = jnp.asarray([q_offset], jnp.int32)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=block_q, block_k=block_k
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, qoff)
