"""Pallas TPU kernel for the Mamba2 SSD (state-space duality) scan.

The SSD algorithm (Dao & Gu, 2024) splits the linear recurrence

    h_t = exp(A·dt_t)·h_{t−1} + dt_t·B_t⊗x_t ,   y_t = C_t·h_t

into chunks: *within* a chunk the contribution is an attention-like
quadratic form (two MXU matmuls), *between* chunks only the [N, P] state is
passed.  That maps perfectly onto a sequential TPU grid:

  grid = (B, H, n_chunks), chunk axis innermost; the running state lives in
  a VMEM scratch across grid steps (the TPU grid is sequential, so no
  cross-block synchronization is needed — the idiomatic TPU replacement for
  the GPU kernel's inter-block state relay through HBM).

Tiling (chunk=128, N=128, P=64, f32): x/out tiles 32 KiB, B/C tiles 64 KiB,
W matrix 64 KiB, state 32 KiB — well under VMEM, MXU-aligned on the
(chunk × N) and (chunk × chunk) matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, out_ref, h_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # [c, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [c]
    A = a_ref[0]  # scalar (per-head)
    Bm = b_ref[0, :, :].astype(jnp.float32)  # [c, N]
    Cm = c_ref[0, :, :].astype(jnp.float32)  # [c, N]

    a = A * dt  # [c] log-decay per step
    acum = jnp.cumsum(a)  # [c]

    # ---- intra-chunk quadratic part ---------------------------------- #
    seg = acum[:, None] - acum[None, :]  # [c, c]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    decay_mat = jnp.where(tri, jnp.exp(seg), 0.0)
    G = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [c, c] = C·Bᵀ
    W = G * decay_mat * dt[None, :]
    y = jax.lax.dot_general(
        W, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [c, P]

    # ---- inter-chunk contribution from carried state ------------------ #
    h = h_ref[...]  # [N, P]
    y += jnp.exp(acum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # ---- state update -------------------------------------------------- #
    last = acum[-1]
    w_in = dt * jnp.exp(last - acum)  # [c]
    h_ref[...] = jnp.exp(last) * h + jax.lax.dot_general(
        Bm * w_in[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    out_ref[0, :, 0, :] = y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,  # [B, L, H, P]
    dt: jnp.ndarray,  # [B, L, H]  (already softplus-activated)
    A: jnp.ndarray,  # [H]        (negative per-head decay rate)
    Bm: jnp.ndarray,  # [B, L, N]
    Cm: jnp.ndarray,  # [B, L, N]
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0, "L must be divisible by chunk"
    C = L // chunk

    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, C),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
