"""Pallas TPU kernels for the perf-critical compute paths.

  congestion.py       — the paper's Timing-Analyzer hot loop (serial-queue scan)
  flash_attention.py  — blockwise causal GQA attention (VMEM-tiled)
  ssd_scan.py         — Mamba2 SSD chunked scan (sequential-grid state carry)
  ops.py              — jit'd wrappers with pallas/interpret/ref dispatch
  ref.py              — pure-jnp oracles (the correctness contract)
"""

from . import ops, ref
from .congestion import congestion_cascade, congestion_cascade_hosts, congestion_scan
from .flash_attention import flash_attention
from .ssd_scan import ssd_scan

__all__ = [
    "congestion_cascade",
    "congestion_cascade_hosts",
    "congestion_scan",
    "flash_attention",
    "ops",
    "ref",
    "ssd_scan",
]
