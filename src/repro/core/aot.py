"""AOT executable cache for the device-resident epoch pipeline.

``jax.jit`` hides a compile stall inside the first call for every new
(shape, static) combination — fatal for a serving loop that must never
pause mid-stream.  :class:`AotDispatchCache` owns the executables
explicitly: dispatch sites build them with ``jit(...).lower(...).compile()``
under a key of their choosing (dispatch fingerprint + bucketed shapes +
mesh), so

  * a cache hit is a dict lookup — zero lowerings, observable via the
    ``lowerings``/``hits`` counters (the AOT-cache tests and the
    ``epoch_pipeline`` benchmark assert ``lowerings`` stays flat across a
    steady-state serving loop);
  * a miss can be taken *ahead of time* (:meth:`warm`), at attach or
    engine start, so the first real dispatch already finds a compiled
    executable;
  * the compile cost is measured where it happens and reported as
    ``compile_s`` in :class:`~repro.core.analyzer.DispatchStats` instead
    of silently inflating one dispatch's latency.

Note that ``.lower().compile()`` does **not** populate ``jit``'s own
python-level cache — a site that sometimes calls the jitted wrapper and
sometimes the AOT executable would compile twice.  Pipeline dispatch
therefore always routes through this cache.

:func:`install_persistent_cache` additionally wires JAX's on-disk
compilation cache so executables survive process restarts.
"""

from __future__ import annotations

import threading
import weakref
from typing import Any, Callable, Dict, Hashable, Tuple

import jax

__all__ = ["AotDispatchCache", "install_persistent_cache"]


class AotDispatchCache:
    """Thread-safe map from dispatch key to a compiled XLA executable.

    ``get`` returns ``(executable, hit)``; ``lowerings`` counts how many
    times a build actually ran (the steady-state invariant is that it
    stops growing), ``hits`` counts lookups served without one.
    """

    # every live cache, so RecompileSanitizer can snapshot/diff the
    # process-wide lowering count without threading a handle everywhere
    _instances: "weakref.WeakSet[AotDispatchCache]" = weakref.WeakSet()
    _instances_lock = threading.Lock()

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._cache: Dict[Hashable, Any] = {}
        self.lowerings = 0
        self.hits = 0
        with AotDispatchCache._instances_lock:
            AotDispatchCache._instances.add(self)

    @classmethod
    def total_lowerings(cls) -> int:
        """Sum of ``lowerings`` across every live cache (sanitizer probe)."""
        with cls._instances_lock:
            caches = list(cls._instances)
        return sum(c.lowerings for c in caches)

    def __len__(self) -> int:
        return len(self._cache)

    def get(
        self, key: Hashable, build: Callable[[], Any]
    ) -> Tuple[Any, bool]:
        with self._lock:
            exe = self._cache.get(key)
            if exe is not None:
                self.hits += 1
                return exe, True
        # build outside the lock: lowering can take seconds and other
        # dispatch keys must not queue behind it
        exe = build()
        with self._lock:
            won = self._cache.setdefault(key, exe)
            if won is exe:
                self.lowerings += 1
            else:
                self.hits += 1
            return won, won is not exe

    def warm(self, key: Hashable, build: Callable[[], Any]) -> bool:
        """Ensure ``key`` is compiled; returns True if this call built it."""
        _, hit = self.get(key, build)
        return not hit


def install_persistent_cache(path: str) -> bool:
    """Point JAX's persistent compilation cache at ``path``.

    Compiled modules are then written to disk and reloaded across process
    restarts, so even the *first* dispatch of a fresh server skips XLA
    compilation for shapes it has served before.  Returns False (instead
    of raising) on JAX builds without the config knobs.
    """
    try:
        jax.config.update("jax_compilation_cache_dir", str(path))
        # default thresholds skip "cheap" compiles; a serving loop wants
        # every executable persisted
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except (AttributeError, ValueError):
        return False
    return True
