"""Shared-fabric multi-host simulation — the paper's pooling scenario as a
first-class mode.

The headline use case of CXL.mem is *pooling*: several servers attach to the
same expanders to fix memory stranding.  The interesting effects — queueing
at shared switches, noisy-neighbor bandwidth collapse, back-invalidation
storms — only appear when real per-host traces contend on one fabric.
:class:`FabricSession` makes that happen:

  1. **co-attach** N tenants (step functions or trace-only workloads) on a
     single :class:`~repro.core.topology.Topology` with ``n_hosts == N``:
     per-tenant placement onto the shared pools, with a fabric-wide capacity
     check (stranding is a *sum* over tenants);
  2. **align** their epoch streams onto one shared timeline: co-scheduled
     rounds start at the same fabric instant, so epoch ``k`` of every tenant
     merges into one host-tagged, time-sorted trace;
  3. **analyze** each merged timeline in **one** batched shared-timeline
     dispatch per round through the ordinary
     :class:`~repro.core.analyzer.EpochAnalyzer` — contention falls out of
     the (host, pool) route matrix, and the per-host delay decomposition
     comes back host-segmented from the same device pass;
  4. **coherency**: sharer sets and write fractions are derived from the
     actual per-host traces (:meth:`CoherencyModel.fabric_traffic`) and BI
     events are injected into the specific sharers' streams before the merge;
  5. **migration** (``migration=MigrationConfig(...)``): every tenant gets
     its own :class:`~repro.core.migration.MigrationSimulator`, all drawing
     on **one** shared local-DRAM budget, and their copy traffic lands
     host-tagged on the shared timeline — a tenant's promotion storm queues
     at the shared switches and shows up in its neighbors' congestion;
  6. **device cache** (``cache=DeviceCacheConfig(...)``): one expander-side
     DRAM cache per shared pool, warmed by the *merged* stream (co-tenants
     evict each other), feeding per-epoch latency-scale vectors into the
     same batched analysis.

With one tenant the session degenerates to the single-host pipeline: the
merged timeline is the tenant's own trace and the analysis is bit-compatible
with :class:`~repro.core.attach.CXLMemSim` (oracle-checked in the tests).

Reported clocks: per-host native seconds (measured when the tenant has a
real step function, roofline-estimated otherwise), per-host simulated
seconds (native + that host's delay share), and the fabric-wide contention
decomposition (latency / congestion / bandwidth / coherency, per switch,
per pool, per host).

**Overlapped rounds** (default): each round's merged timeline is submitted
to the shared :class:`~repro.core.engine.AnalysisEngine` *before* the
tenants' native steps are dispatched, so the analyzer's device work hides
behind the attached programs' own execution — and concurrently-running
sessions on equal topologies coalesce into one stacked cross-session
dispatch.  The stateful pre-analysis transforms (migration, coherency,
cache) still run on the submitting thread, so async and forced-synchronous
(``async_analysis=False``) rounds produce bit-equal reports (locked in
``tests/test_engine.py``).  ``FabricSession`` is a context manager;
``close()`` (or ``with``) releases its engine handle, and ``run()``
flushes before returning the report.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..analysis.annotations import guarded_by
from .analyzer import DelayBreakdown, EpochAnalyzer
from .cache import DeviceCacheConfig, DeviceCacheModel
from .coherency import CoherencyConfig, CoherencyModel
from .engine import AnalysisEngine, EngineClient, EngineHandle, fold_dispatch_stats
from .events import MemEvents, RegionMap, concat_events
from .migration import LocalBudget, MigrationConfig, MigrationSimulator
from .policy import PlacementPolicy
from .timer import EpochSchedule
from .topology import Topology
from .tracer import HardwareModel, Phase, TPU_V5E, synthesize_step_trace
from .units import ns_to_s

__all__ = ["FabricReport", "FabricSession", "HostClock", "Tenant"]


@dataclasses.dataclass
class Tenant:
    """One attached host's workload: a program (or trace-only load) plus its
    private region map and placement policy."""

    name: str
    phases: Sequence[Phase]
    regions: RegionMap
    policy: PlacementPolicy
    step_fn: Optional[Callable] = None  # None => trace-only (roofline clock)
    step_args: Tuple = ()
    calibration: float = 1.0
    sample_rate: float = 1.0
    qos_class: int = 0  # arbitration class at QoS-disciplined switches


@dataclasses.dataclass
class HostClock:
    """Per-host clocks + delay decomposition (the two clocks of the paper,
    one pair per attached host).

    ``simulated_s`` is *derived* (native + this host's delay share) rather
    than accumulated: native seconds fold on the round-driving thread while
    delay components fold when the engine's dispatcher finishes the round's
    analysis, and keeping the accumulators disjoint makes the overlapped
    and synchronous paths bit-equal regardless of interleaving."""

    host: int
    name: str
    steps: int = 0
    native_s: float = 0.0
    latency_s: float = 0.0
    congestion_s: float = 0.0
    bandwidth_s: float = 0.0
    coherency_s: float = 0.0

    @property
    def simulated_s(self) -> float:
        return self.native_s + self.delay_s

    @property
    def slowdown(self) -> float:
        return self.simulated_s / self.native_s if self.native_s > 0 else float("nan")

    @property
    def delay_s(self) -> float:
        return self.latency_s + self.congestion_s + self.bandwidth_s + self.coherency_s


@dataclasses.dataclass
class FabricReport:
    """Fabric-wide totals + per-host clocks + contention decomposition."""

    hosts: List[HostClock]
    rounds: int = 0
    epochs: int = 0
    latency_s: float = 0.0
    congestion_s: float = 0.0
    bandwidth_s: float = 0.0
    coherency_s: float = 0.0
    analyzer_s: float = 0.0
    bi_messages: float = 0.0
    migration_moved_bytes: float = 0.0
    cache_hit_fraction: float = float("nan")
    dropped_batches: int = 0  # round analyses lost to analyzer failures
    dropped_epochs: int = 0  # their epochs: totals exclude exactly these
    # sharded-dispatch observability (maxima over this session's dispatches)
    devices_used: int = 1
    shard_rows: int = 0
    padded_waste: float = 0.0
    coalesced_group_size: int = 1
    # pipeline-phase timing (sums over this session's dispatches)
    stage_s: float = 0.0
    transfer_s: float = 0.0
    compile_s: float = 0.0
    compute_s: float = 0.0
    donated_dispatches: int = 0
    aot_cache_hits: int = 0
    qos_classes: int = 1
    per_pool_latency_ns: Optional[np.ndarray] = None
    per_switch_congestion_ns: Optional[np.ndarray] = None
    per_switch_bandwidth_ns: Optional[np.ndarray] = None
    per_class_congestion_ns: Optional[np.ndarray] = None

    @property
    def delay_s(self) -> float:
        return self.latency_s + self.congestion_s + self.bandwidth_s + self.coherency_s

    def qos_delay_shares(self) -> List[float]:
        """Fraction of switch queueing delay charged to each QoS class."""
        pcc = self.per_class_congestion_ns
        if pcc is None:
            return [1.0]
        total = float(pcc.sum())
        if total <= 0.0:
            return [0.0] * len(pcc)
        return [float(x) / total for x in pcc]

    def summary(self) -> Dict[str, float]:
        """Fabric-wide scalars + per-host clocks — the full report contract
        for benchmark JSON consumers (key set locked in tests)."""
        out = {
            "rounds": self.rounds,
            "epochs": self.epochs,
            "latency_s": self.latency_s,
            "congestion_s": self.congestion_s,
            "bandwidth_s": self.bandwidth_s,
            "coherency_s": self.coherency_s,
            "bi_messages": self.bi_messages,
            "analyzer_s": self.analyzer_s,
            "migration_moved_bytes": self.migration_moved_bytes,
            "cache_hit_fraction": self.cache_hit_fraction,
            "dropped_batches": self.dropped_batches,
            "dropped_epochs": self.dropped_epochs,
            "devices_used": self.devices_used,
            "shard_rows": self.shard_rows,
            "padded_waste": self.padded_waste,
            "coalesced_group_size": self.coalesced_group_size,
            "stage_s": self.stage_s,
            "transfer_s": self.transfer_s,
            "compile_s": self.compile_s,
            "compute_s": self.compute_s,
            "donated_dispatches": self.donated_dispatches,
            "aot_cache_hits": self.aot_cache_hits,
            "qos_classes": self.qos_classes,
            "qos_delay_shares": self.qos_delay_shares(),
        }
        for hc in self.hosts:
            out[f"host{hc.host}_native_s"] = hc.native_s
            out[f"host{hc.host}_simulated_s"] = hc.simulated_s
            out[f"host{hc.host}_slowdown"] = hc.slowdown
        return out


class FabricSession(EngineClient):
    """Co-attach N tenants on one shared topology; see the module docstring.

    The topology's ``n_hosts`` must match ``len(tenants)``; as a convenience
    a single-host topology is automatically re-declared for N hosts (same
    components, full port visibility), since the fabric layout itself is
    host-count independent.
    """

    # round folds arrive from the engine's dispatcher thread while the
    # round-driving thread accumulates native clocks — every touch locks
    _simlint_guards = guarded_by("_report_lock", "_report")

    def __init__(
        self,
        topology: Topology,
        tenants: Sequence[Tenant],
        epoch: EpochSchedule = EpochSchedule("step"),
        hw: HardwareModel = TPU_V5E,
        coherency: Optional[CoherencyConfig] = None,
        migration: Optional[MigrationConfig] = None,
        cache: Optional[DeviceCacheConfig] = None,
        n_windows: int = 128,
        impl: str = "inline",
        check_capacity: bool = True,
        max_events_per_access: int = 64,
        async_analysis: bool = True,
        engine: Optional[AnalysisEngine] = None,  # None: the shared default
        pipeline: bool = False,  # device-resident epoch pipeline (AOT + donation)
    ):
        if not tenants:
            raise ValueError("need at least one tenant")
        self.tenants = list(tenants)
        H = len(self.tenants)
        if topology.n_hosts not in (1, H):
            # an explicit multi-host declaration that disagrees with the
            # tenant count is a configuration error, not a convenience case
            raise ValueError(
                f"topology declares {topology.n_hosts} hosts but "
                f"{H} tenants were attached"
            )
        if topology.n_hosts != H:
            topology = Topology(
                topology.pools,
                topology.switches,
                rc_latency_ns=topology.rc_latency_ns,
                rc_bandwidth_gbps=topology.rc_bandwidth_gbps,
                rc_stt_ns=topology.rc_stt_ns,
                local_dram_latency_ns=topology.local_dram_latency_ns,
                n_hosts=H,
                host_ports=topology.host_ports or None,
                n_qos_classes=topology.n_qos_classes,
            )
        self.topology = topology
        self.flat = topology.flatten()
        self.epoch = epoch
        self.hw = hw
        self.max_events_per_access = max_events_per_access
        self._analyzer = EpochAnalyzer(
            self.flat, n_windows=n_windows, impl=impl, pipeline=pipeline
        )
        if coherency is not None and H == 1:
            # trace-driven coherency needs a second host to derive sharers
            # from; silently reporting zero BI traffic would look like a
            # coherency-free result.  The analytic single-host fallback
            # lives in CXLMemSim(coherency=CoherencyModel(...)).
            raise ValueError(
                "coherency on a single-tenant fabric has no sharers to "
                "derive from traces — attach via CXLMemSim for the "
                "analytic n_hosts-1 fallback"
            )
        self._coherency = (
            CoherencyModel(coherency) if coherency is not None else None
        )

        for h, t in enumerate(self.tenants):
            if not 0 <= t.qos_class < self.flat.n_qos_classes:
                raise ValueError(
                    f"tenant {t.name!r} declares qos_class={t.qos_class} but the "
                    f"fabric has {self.flat.n_qos_classes} QoS class(es)"
                )
            t.policy.place(t.regions, self.flat)
            for r in t.regions:
                if not self.flat.host_reachable[h, r.pool]:
                    raise ValueError(
                        f"tenant {t.name!r} (host {h}) placed region "
                        f"{r.name!r} in pool {self.flat.pool_names[r.pool]!r}, "
                        "which its ports cannot reach"
                    )
        if check_capacity:
            self._fabric_capacity_check()

        # per-tenant migration simulators drawing on ONE local-DRAM budget:
        # in the pooling rack the local tier is the scarce resource, so
        # co-tenants' promotions compete for it (a policy-study knob); each
        # simulator still owns its tenant's hotness state and emits copy
        # traffic host-tagged onto the shared timeline, where it contends
        # at shared switches like any other traffic.
        self._migration: List[Optional[MigrationSimulator]] = [None] * H
        if migration is not None and migration.mode != "off":
            shared_budget = LocalBudget(migration.local_budget_bytes)
            self._migration = [
                MigrationSimulator(
                    migration, t.regions, self.flat, host=h, budget=shared_budget
                )
                for h, t in enumerate(self.tenants)
            ]
        self._has_migration = any(s is not None for s in self._migration)
        self._cache = (
            DeviceCacheModel(cache, self.flat, [t.regions for t in self.tenants])
            if cache is not None
            else None
        )

        self._trace_cache: List[Optional[tuple]] = [None] * H
        self._native_cache: List[Optional[float]] = [None] * H
        self._round_cache: Optional[tuple] = None
        self._report = FabricReport(
            hosts=[HostClock(h, t.name) for h, t in enumerate(self.tenants)],
            qos_classes=self.flat.n_qos_classes,
            per_pool_latency_ns=np.zeros((self.flat.n_pools,)),
            per_switch_congestion_ns=np.zeros((self.flat.n_switches,)),
            per_switch_bandwidth_ns=np.zeros((self.flat.n_switches,)),
            per_class_congestion_ns=np.zeros((self.flat.n_qos_classes,)),
        )
        self._report_lock = threading.Lock()
        if async_analysis:
            eng = engine if engine is not None else AnalysisEngine.default()
            self._handle: Optional[EngineHandle] = eng.register(self._analyzer)
        else:
            self._handle = None

    @property
    def report(self) -> FabricReport:
        """The accumulated fabric report; flushes in-flight overlapped
        rounds first, so reads never observe partially-folded totals
        (``flush``/``close``/context-manager semantics come from
        :class:`~repro.core.engine.EngineClient`)."""
        self.flush()
        return self._report  # simlint: ignore[lock-discipline] -- post-flush read: no in-flight fold can race the caller's view

    # ------------------------------------------------------------------ #

    def _fabric_capacity_check(self) -> None:
        """Stranding check across tenants: shared pools hold the *sum* of
        every tenant's bytes; local DRAM (pool 0) is private per host.

        Consistent with the coherency model's view of sharing: when a
        coherency config declares shared classes, regions of those classes
        that match by name across tenants are **one** pooled object (the
        shared-kv-cache scenario) and occupy capacity once — the same
        name-matching rule :meth:`CoherencyModel.fabric_traffic` uses to
        derive sharers.  Everything else is a private allocation and sums.
        """
        P = self.flat.n_pools
        shared_classes = (
            self._coherency.cfg.shared_classes if self._coherency else ()
        )
        shared = np.zeros((P,), np.float64)
        pooled_objects: Dict[Tuple[str, int], float] = {}  # (name, pool) -> max bytes
        for h, t in enumerate(self.tenants):
            local = 0.0
            for r in t.regions:
                if r.pool == 0:
                    local += r.nbytes
                elif r.tensor_class in shared_classes:
                    key = (r.name, r.pool)
                    pooled_objects[key] = max(pooled_objects.get(key, 0.0), r.nbytes)
                else:
                    shared[r.pool] += r.nbytes
            if local > self.flat.pool_capacity[0]:
                raise ValueError(
                    f"tenant {t.name!r} overflows its local DRAM: "
                    f"{local:.3e} > {self.flat.pool_capacity[0]:.3e} bytes"
                )
        for (name, p), nbytes in pooled_objects.items():
            shared[p] += nbytes
        for p in range(1, P):
            if shared[p] > self.flat.pool_capacity[p]:
                raise ValueError(
                    f"shared pool {self.flat.pool_names[p]!r} oversubscribed "
                    f"across tenants: {shared[p]:.3e} > "
                    f"{self.flat.pool_capacity[p]:.3e} bytes"
                )

    def _tenant_epochs(self, h: int) -> Tuple[List[MemEvents], float]:
        """Host ``h``'s per-round epoch traces (host-tagged) + native estimate."""
        if self._trace_cache[h] is None:
            t = self.tenants[h]
            mode = "layer" if self.epoch.mode == "layer" else "step"
            traces, native_ns, _ = synthesize_step_trace(
                t.phases,
                t.regions,
                hw=self.hw,
                granularity_bytes=t.policy.granularity_bytes,
                max_events_per_access=self.max_events_per_access,
                calibration=t.calibration,
                epoch_mode=mode,
            )
            if self.epoch.mode == "quantum":
                # dense: slice index k == absolute quantum k, so positional
                # alignment across tenants pairs genuinely co-scheduled time
                cut: List[MemEvents] = []
                for tr in traces:
                    cut.extend(self.epoch.slices(tr, dense=True))
                traces = cut
            if t.sample_rate < 1.0:
                traces = [
                    tr.sample(t.sample_rate, seed=i) for i, tr in enumerate(traces)
                ]
            traces = [tr.with_host(h).with_qos(t.qos_class) for tr in traces]
            if self._native_cache[h] is None:
                # native pacing depends on phase flops/bytes only, never on
                # residency, so it survives migration-forced re-synthesis
                self._native_cache[h] = ns_to_s(float(sum(native_ns)))
            self._trace_cache[h] = (traces, self._native_cache[h])
        return self._trace_cache[h]

    def _merged_round(self) -> Tuple[List[MemEvents], np.ndarray, Optional[List]]:
        """Align every tenant's epoch stream and merge each aligned group.

        Epoch ``k`` of each host starts at the same fabric instant (the
        co-scheduling assumption; DESIGN.md §Fabric discusses the trade).
        Returns the merged shared-timeline epochs, per-host coherency miss
        latency for the round, and (cache mode) per-epoch latency-scale
        vectors.

        Without migration or a device cache, tenant traces are
        round-invariant, so the merged timelines, BI injection, and miss
        latencies are built once and replayed; only the coherency model's
        running totals are advanced per round.  Migration makes rounds
        stateful — each tenant's simulator remaps its stream and injects
        host-tagged copy traffic before the merge, and residency changes
        force next round's traces to be re-synthesized — and the device
        cache's tag state evolves with the merged stream, so either
        disables the replay cache.
        """
        H = len(self.tenants)
        stateful = self._has_migration or self._cache is not None
        if self._round_cache is not None and not stateful:
            merged, miss_total, bi_msgs, bi_bytes, miss_sum = self._round_cache
            if self._coherency is not None:
                self._coherency.bi_messages_total += bi_msgs
                self._coherency.bi_bytes_total += bi_bytes
                self._coherency.coherency_delay_total_ns += miss_sum
            return merged, miss_total, None
        coh0 = (
            (0.0, 0.0)
            if self._coherency is None
            else (self._coherency.bi_messages_total, self._coherency.bi_bytes_total)
        )
        per_host = [self._tenant_epochs(h)[0] for h in range(H)]
        n_epochs = max(len(e) for e in per_host)
        merged: List[MemEvents] = []
        scales: Optional[List] = [] if self._cache is not None else None
        miss_total = np.zeros((H,), np.float64)
        for k in range(n_epochs):
            group = [
                e[k] if k < len(e) else MemEvents.empty() for e in per_host
            ]
            for h, sim in enumerate(self._migration):
                if sim is None or group[h].n == 0:
                    continue
                tr, extra = sim.observe_and_migrate(group[h])
                group[h] = concat_events([tr, extra]) if extra.n else tr
            if self._coherency is not None:
                bi, miss = self._coherency.fabric_traffic(
                    group, [t.regions for t in self.tenants]
                )
                group = [
                    concat_events([g, b]) if b.n else g for g, b in zip(group, bi)
                ]
                miss_total += miss
            # traces are already host-tagged; concat + sort onto one timeline
            epoch = concat_events(group).sorted_by_time()
            if self._cache is not None:
                scales.append(self._cache.observe_scale(epoch))
            merged.append(epoch)
        if self._has_migration:
            # residency moved: next round's structural traces must re-read
            # Region.pool (the attach pipeline's migration contract)
            self._trace_cache = [None] * H
        if not stateful:
            self._round_cache = (
                merged,
                miss_total,
                (self._coherency.bi_messages_total - coh0[0]) if self._coherency else 0.0,
                (self._coherency.bi_bytes_total - coh0[1]) if self._coherency else 0.0,
                float(miss_total.sum()),
            )
        return merged, miss_total, scales

    # ------------------------------------------------------------------ #

    def _round_stats(self) -> Tuple:
        """Snapshot of the stateful models' running totals, captured on the
        submitting thread right after :meth:`_merged_round` advanced them —
        the dispatcher folds the *captured* values, so a later round's
        mutation can never leak into an earlier round's fold."""
        return (
            self._coherency.bi_messages_total if self._coherency is not None else None,
            sum(s.moved_bytes_total for s in self._migration if s is not None)
            if self._has_migration
            else None,
            self._cache.hit_fraction if self._cache is not None else None,
        )

    def _fold_round(
        self,
        bd: DelayBreakdown,
        miss_ns: np.ndarray,
        analyzer_s: float,
        n_epochs: int,
        stats: Tuple,
    ) -> None:
        """Fold one analyzed round into the report (any thread; locks)."""
        bi_messages, moved_bytes, hit_fraction = stats
        with self._report_lock:
            r = self._report
            r.rounds += 1
            r.epochs += n_epochs
            r.analyzer_s += analyzer_s
            r.latency_s += ns_to_s(bd.latency_ns)
            r.congestion_s += ns_to_s(bd.congestion_ns)
            r.bandwidth_s += ns_to_s(bd.bandwidth_ns)
            r.coherency_s += ns_to_s(float(miss_ns.sum()))
            if bi_messages is not None:
                r.bi_messages = bi_messages
            if moved_bytes is not None:
                r.migration_moved_bytes = moved_bytes
            if hit_fraction is not None:
                r.cache_hit_fraction = hit_fraction
            r.per_pool_latency_ns += bd.per_pool_latency_ns
            r.per_switch_congestion_ns += bd.per_switch_congestion_ns
            r.per_switch_bandwidth_ns += bd.per_switch_bandwidth_ns
            if bd.per_class_congestion_ns is not None:
                pcc = np.asarray(bd.per_class_congestion_ns, np.float64)
                if len(pcc) == len(r.per_class_congestion_ns):
                    r.per_class_congestion_ns += pcc
                else:  # qos-off breakdown on a multi-class fabric: all class 0
                    r.per_class_congestion_ns[0] += float(pcc.sum())
            if self._handle is not None:
                fold_dispatch_stats(
                    r, self._handle.last_dispatch, self._handle.last_group_size
                )
            else:
                fold_dispatch_stats(
                    r, getattr(self._analyzer, "last_dispatch", None), 1
                )
            for h, hc in enumerate(r.hosts):
                hc.latency_s += ns_to_s(float(bd.per_host_latency_ns[h]))
                hc.congestion_s += ns_to_s(float(bd.per_host_congestion_ns[h]))
                hc.bandwidth_s += ns_to_s(float(bd.per_host_bandwidth_ns[h]))
                hc.coherency_s += ns_to_s(float(miss_ns[h]))

    def round(self) -> Optional[DelayBreakdown]:
        """Run one co-scheduled round.  In the default overlapped mode the
        merged shared timeline is **submitted to the engine before any
        tenant's native step is dispatched**, so the analyzer's device work
        hides behind the tenants' own execution (and co-running sessions
        coalesce); the round's breakdown folds into :attr:`report` when the
        dispatcher finishes (``flush()``/``run()`` synchronize) and the
        return value is ``None``.  With ``async_analysis=False`` the
        analysis runs inline and the breakdown is returned.

        The analyzer intentionally re-runs every round even though the
        merged timelines are cached: per-round analyzer overhead is a
        reported quantity (the paper's accounting), matching how
        ``CXLMemSim.attach`` re-analyzes its cached trace each step."""
        merged, miss_ns, scales = self._merged_round()
        n_epochs = len(merged)
        stats = self._round_stats()

        bd: Optional[DelayBreakdown] = None
        if self._handle is not None:
            self._handle.submit(
                merged,
                scales,
                fold=lambda b, elapsed: self._fold_round(
                    b, miss_ns, elapsed, n_epochs, stats
                ),
            )
        else:
            a0 = time.perf_counter()
            try:
                bd = self._analyzer.analyze_batch(merged, scales)
            except BaseException:
                with self._report_lock:
                    self._report.dropped_batches += 1
                    self._report.dropped_epochs += n_epochs
                raise
            self._fold_round(bd, miss_ns, time.perf_counter() - a0, n_epochs, stats)

        # tenants' native steps run AFTER the submission: analyzer device
        # work overlaps the attached programs' own execution
        natives: List[float] = []
        for h, tenant in enumerate(self.tenants):
            if tenant.step_fn is not None:
                t0 = time.perf_counter()
                out = tenant.step_fn(*tenant.step_args)
                jax.block_until_ready(out)
                natives.append(time.perf_counter() - t0)
            else:
                natives.append(self._tenant_epochs(h)[1])
        with self._report_lock:
            for hc, native in zip(self._report.hosts, natives):
                hc.steps += 1
                hc.native_s += native
        return bd

    def run(self, n_rounds: int) -> FabricReport:
        for _ in range(n_rounds):
            self.round()
        return self.report  # the property flushes
