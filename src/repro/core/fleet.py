"""FleetSim — rack-scale cluster simulation over the sharded dispatch.

The paper's opening problem is *memory stranding*: datacenter hosts are
provisioned for peak resident demand, so most DRAM sits idle most of the
time, and CXL pooling exists to reclaim it.  A single
:class:`~repro.core.fabric.FabricSession` prices a handful of co-attached
tenants on ONE topology; this module scales that question to a fleet — a
cluster scheduler placing M tenant programs across R racks of pooled
expanders — and answers the capacity-planning trade the ROADMAP asks for:
**how many stranded GB does pooling recover, and what p99 tenant slowdown
does the shared fabric charge for them?**

The lowering reuses every stacked-dispatch invariant the suite already
has:

  * every rack shares one topology *structure* (the same
    :class:`~repro.core.topology.Topology` tree), so the route matrix,
    route-word table and cascade merge plan are planned once; per-rack
    numeric variation (expander latency/bandwidth/STT) rides on
    :class:`~repro.core.topology.TopologyOverride` rows lowered by
    :func:`~repro.core.topology.flatten_stack`;
  * each rack's tenants synthesize placement-independent skeletons once
    (:func:`~repro.core.tracer.synthesize_skeleton`); per-placement pools
    are a region→pool gather; per-host epoch timelines merge onto the
    rack's fabric clock exactly like
    :class:`~repro.core.fabric.FabricSession`'s merged rounds;
  * the R racks stack into ONE ``[R, B, N]`` jitted dispatch
    (:func:`~repro.core.analyzer._analyze_fleet_jax`) whose leading axis
    shards across JAX devices over a ``('data',)`` mesh
    (:func:`~repro.launch.mesh.make_data_mesh`), with per-rack epoch
    reduction on device — one ``[R, ...]`` host transfer for the whole
    fleet, however many devices participate.

:meth:`FleetSim.frontier` stacks F offload fractions × R racks into a
single ``[F·R, B, N]`` dispatch and returns the stranded-GB-recovered vs.
p99-slowdown curve (``benchmarks/fleet_scaling.py`` plots it at 100+
hosts).

The stranding model: a non-pooled cluster provisions every host's DRAM
for its tenants' full resident demand.  Under FleetSim's placement, only
*retained* bytes live in host DRAM; every byte the scheduler offloads to
the rack's shared expander is DRAM the host no longer has to provision —
so ``stranded_recovered_bytes`` is the fleet-wide sum of offloaded bytes,
and the frontier sweeps the offload fraction to trade it against tenant
slowdown.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .analyzer import (
    DelayBreakdown,
    DispatchStats,
    _analyze_fleet_jax,
    bucket_pow2,
    plan_cascade,
)
from .events import EventStager, MemEvents, RegionMap, concat_events
from .topology import (
    QosSpec,
    Topology,
    TopologyOverride,
    flatten_stack,
    pooled_topology,
)
from .tracer import (
    Access,
    HardwareModel,
    Phase,
    TPU_V5E,
    TraceSkeleton,
    skeleton_to_events,
    synthesize_skeleton,
)
from .units import bytes_to_gib, gib_to_bytes

__all__ = [
    "FleetPoint",
    "FleetReport",
    "FleetSim",
    "TenantPlacement",
    "TenantSpec",
    "model_zoo_tenant",
    "synthetic_tenant",
]


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One schedulable tenant program: its phase list and memory demand.

    ``regions``' pool fields are ignored — the fleet scheduler decides
    placement.  Names must be unique within a fleet (they key the skeleton
    cache and the per-tenant results).  ``qos_class`` is the tenant's
    arbitration class at QoS-disciplined switches (priority / WFQ racks).
    """

    name: str
    phases: Tuple[Phase, ...]
    regions: RegionMap
    qos_class: int = 0

    def demand_bytes(self) -> float:
        return float(self.regions.total_bytes())


def synthetic_tenant(
    name: str,
    seed: int = 0,
    gib: float = 1.0,
    read_intensity: float = 0.02,
) -> TenantSpec:
    """A deterministic synthetic tenant around ``~gib`` GiB of demand.

    Mirrors a train/serve step shape: params + activations are pinned
    tensor classes, optimizer state and KV cache are the offloadable bulk
    (together ~60% of demand — the stranding opportunity).  Sizes jitter
    per seed so a fleet of these has heterogeneous demand, which is what
    makes bin-packing and stranding interesting.
    """
    rng = np.random.default_rng(seed)
    total = gib_to_bytes(gib) * float(rng.uniform(0.7, 1.5))
    regions = RegionMap()
    regions.alloc(f"{name}/params", int(total * 0.22), "param")
    regions.alloc(f"{name}/acts", int(total * 0.18), "activation")
    regions.alloc(f"{name}/opt", int(total * 0.35), "opt_state")
    regions.alloc(f"{name}/kv", int(total * 0.25), "kvcache")
    touch = lambda frac: total * frac * read_intensity

    def ph(label, flops_scale, accesses):
        return Phase(
            name=f"{name}/{label}",
            flops=float(rng.uniform(0.5, 1.5)) * flops_scale * 1e12,
            accesses=tuple(
                Access(region=f"{name}/{r}", bytes_=b, is_write=w)
                for r, b, w in accesses
            ),
        )

    phases = (
        ph("fwd", 2.0, [("params", touch(0.22), False), ("acts", touch(0.18), True),
                        ("kv", touch(0.12), False)]),
        ph("bwd", 4.0, [("params", touch(0.22), False), ("acts", touch(0.18), False),
                        ("kv", touch(0.13), True)]),
        ph("opt", 0.5, [("opt", touch(0.35), True), ("params", touch(0.11), True)]),
    )
    return TenantSpec(name=name, phases=phases, regions=regions)


def model_zoo_tenant(
    name: str,
    arch: str = "starcoder2-3b",
    mode: str = "train",
    batch: int = 2,
    seq: int = 64,
) -> TenantSpec:
    """A tenant drawn from the model zoo's phase/region builder."""
    import repro.configs as cfgs
    from repro.models.phases import build_regions_and_phases

    regions, phases = build_regions_and_phases(
        cfgs.get_smoke(arch), mode, batch=batch, seq=seq
    )
    return TenantSpec(name=name, phases=tuple(phases), regions=regions)


@dataclasses.dataclass(frozen=True)
class TenantPlacement:
    """Where one tenant landed and how its bytes split local vs pooled."""

    tenant: TenantSpec
    rack: int
    host: int
    local_bytes: float  # resident in the host's private DRAM
    pooled_bytes: float  # offloaded to the rack's shared expander
    pool_of_region: np.ndarray  # [n_regions] region -> pool id


@dataclasses.dataclass
class FleetReport:
    """One fleet round: per-rack breakdowns + the capacity-planning scalars."""

    n_racks: int
    hosts_per_rack: int
    offload_fraction: float
    placements: List[TenantPlacement]
    breakdowns: List[DelayBreakdown]  # [R]
    native_ns: np.ndarray  # [R, H] per-host roofline-paced native time
    delay_ns: np.ndarray  # [R, H] per-host simulated fabric delay
    stranded_recovered_bytes: float
    devices_used: int = 1
    shard_rows: int = 0
    padded_fraction: float = 0.0
    qos_classes: int = 1

    @property
    def n_hosts(self) -> int:
        return self.n_racks * self.hosts_per_rack

    @property
    def n_tenants(self) -> int:
        return len(self.placements)

    def host_slowdowns(self) -> np.ndarray:
        """[R, H] simulated/native per host (1.0 for idle hosts)."""
        with np.errstate(divide="ignore", invalid="ignore"):
            s = (self.native_ns + self.delay_ns) / self.native_ns
        return np.where(self.native_ns > 0, s, 1.0)

    def tenant_slowdowns(self) -> np.ndarray:
        """[M] each tenant inherits its host's fabric slowdown."""
        s = self.host_slowdowns()
        return np.asarray([s[p.rack, p.host] for p in self.placements])

    def p99_slowdown(self) -> float:
        return float(np.percentile(self.tenant_slowdowns(), 99))

    def mean_slowdown(self) -> float:
        return float(self.tenant_slowdowns().mean())

    def summary(self) -> Dict[str, float]:
        return {
            "n_racks": self.n_racks,
            "n_hosts": self.n_hosts,
            "n_tenants": self.n_tenants,
            "offload_fraction": self.offload_fraction,
            "stranded_recovered_gb": bytes_to_gib(self.stranded_recovered_bytes),
            "p99_slowdown": self.p99_slowdown(),
            "mean_slowdown": self.mean_slowdown(),
            "devices_used": self.devices_used,
            "shard_rows": self.shard_rows,
            "padded_fraction": self.padded_fraction,
            "qos_classes": self.qos_classes,
        }


@dataclasses.dataclass(frozen=True)
class FleetPoint:
    """One frontier point: what an offload fraction buys and costs."""

    offload_fraction: float
    stranded_recovered_gb: float
    p99_slowdown: float
    mean_slowdown: float
    report: FleetReport


class FleetSim:
    """Cluster scheduler + stacked fleet dispatch over R pooled racks.

    ``rack_topology`` (default: the paper's :func:`~repro.core.topology.
    pooled_topology` with ``hosts_per_rack`` hosts) is the structure every
    rack shares; ``rack_overrides`` optionally varies numeric parameters
    per rack (a heterogeneous fleet — e.g. two expander generations).
    ``mesh`` is a ``('data',)`` mesh (:func:`~repro.launch.mesh.
    make_data_mesh`); when given, every fleet dispatch shards its rack
    axis across the mesh's devices.
    """

    def __init__(
        self,
        n_racks: int,
        hosts_per_rack: int = 4,
        rack_topology: Optional[Topology] = None,
        rack_overrides: Optional[Sequence[Optional[TopologyOverride]]] = None,
        hw: HardwareModel = TPU_V5E,
        epoch_mode: str = "step",
        granularity_bytes: float = 4096.0,
        max_events_per_access: int = 64,
        calibration: float = 1.0,
        bw_window_ns: float = 10_000.0,
        n_windows: int = 64,
        dtype=jnp.float32,
        mesh=None,
        offload_classes: Sequence[str] = ("opt_state", "kvcache", "expert"),
        rack_qos: Optional[Sequence[Optional[QosSpec]]] = None,
    ):
        if n_racks < 1:
            raise ValueError("need at least one rack")
        self.n_racks = int(n_racks)
        self.topology = (
            rack_topology
            if rack_topology is not None
            else pooled_topology(n_hosts=hosts_per_rack)
        )
        self.hosts_per_rack = self.topology.n_hosts
        if rack_overrides is not None and len(rack_overrides) != self.n_racks:
            raise ValueError(
                f"{len(rack_overrides)} rack_overrides for {n_racks} racks"
            )
        self.rack_overrides = (
            list(rack_overrides)
            if rack_overrides is not None
            else [None] * self.n_racks
        )
        self.hw = hw
        if epoch_mode not in ("step", "layer"):
            raise ValueError(epoch_mode)
        self.epoch_mode = epoch_mode
        self.granularity_bytes = float(granularity_bytes)
        self.max_events_per_access = int(max_events_per_access)
        self.calibration = float(calibration)
        self.bw_window_ns = float(bw_window_ns)
        self.n_windows = int(n_windows)
        self.dtype = dtype
        self._np_dtype = np.dtype(jnp.dtype(dtype).name)
        self.mesh = mesh
        self.offload_classes = frozenset(offload_classes)

        flat = self.topology.flatten()
        if flat.n_switches > 31:
            raise ValueError("fleet dispatch requires the fused cascade (<= 31 stages)")
        self.flat = flat
        locals_ = [i for i, p in enumerate(self.topology.pools) if p.is_local]
        shared = [i for i, p in enumerate(self.topology.pools) if not p.is_local]
        if not shared:
            raise ValueError(
                "rack topology has no shared pool — nothing to offload to "
                "(add a non-local expander, e.g. pooled_topology())"
            )
        self.local_pool = locals_[0]
        # the offload target: the largest shared expander of the rack
        self.shared_pool = max(
            shared, key=lambda i: self.topology.pools[i].capacity_bytes
        )
        self.local_capacity = float(
            self.topology.pools[self.local_pool].capacity_bytes
        )
        self.shared_capacity = float(
            self.topology.pools[self.shared_pool].capacity_bytes
        )

        bits_pool, self._merge_plan, self._stage_order = plan_cascade(flat)
        self._bits_table = jnp.asarray(bits_pool)
        self._route = jnp.asarray(flat.route, dtype)
        # numeric leaves, one row per rack (structure shared by construction)
        self._leaf_stack = flatten_stack(self.topology, self.rack_overrides)
        # per-rack QoS arbitration policies: disciplines and class weights
        # are NUMERIC leaves on the rack axis (same contract as the stt/bw
        # overrides), so a heterogeneous-QoS fleet still compiles once
        if rack_qos is not None and len(rack_qos) != self.n_racks:
            raise ValueError(f"{len(rack_qos)} rack_qos entries for {n_racks} racks")
        C = flat.n_qos_classes
        if rack_qos is not None:
            C = max([C] + [s.n_classes() for s in rack_qos if s is not None])
        disc = np.tile(
            np.asarray(flat.discipline_codes(), np.int32)[None], (self.n_racks, 1)
        )
        weights = np.ones((self.n_racks, flat.n_switches, C), self._np_dtype)
        base_w = flat.class_weight_table().astype(self._np_dtype)
        weights[:, :, : base_w.shape[1]] = base_w[None]
        if rack_qos is not None:
            for r, spec in enumerate(rack_qos):
                if spec is not None:
                    spec.apply(disc[r], weights[r], flat.switch_names)
        self._disc_stack = disc
        self._weights_stack = weights
        self.n_qos_classes = C
        self.qos_on = bool(flat.has_qos) or bool(
            rack_qos is not None and any(s is not None for s in rack_qos)
        )
        self._fleet_jit = jax.jit(
            _analyze_fleet_jax,
            static_argnames=(
                "stage_order", "n_windows", "n_hosts", "impl", "fused",
                "merge_plan", "qos_on",
            ),
        )
        self._stager = EventStager(self._np_dtype)
        self._skeletons: Dict[str, TraceSkeleton] = {}
        self.dispatch_count = 0
        self.last_dispatch = DispatchStats()

    # ------------------------------------------------------------------ #
    # scheduling + placement
    # ------------------------------------------------------------------ #

    def _skeleton(self, tenant: TenantSpec) -> TraceSkeleton:
        sk = self._skeletons.get(tenant.name)
        if sk is None:
            sk = synthesize_skeleton(
                tenant.phases,
                tenant.regions,
                self.hw,
                granularity_bytes=self.granularity_bytes,
                max_events_per_access=self.max_events_per_access,
                calibration=self.calibration,
                epoch_mode=self.epoch_mode,
            )
            self._skeletons[tenant.name] = sk
        return sk

    def place(
        self,
        tenants: Sequence[TenantSpec],
        policy: str = "least_loaded",
        offload_fraction: float = 1.0,
    ) -> List[TenantPlacement]:
        """Assign tenants to (rack, host) slots and split their bytes.

        ``policy``: ``'round_robin'`` cycles slots in order;
        ``'least_loaded'`` picks the host with the most free local DRAM;
        ``'first_fit'`` packs the first host whose free DRAM holds the
        tenant's resident (post-offload) bytes.  ``offload_fraction`` of
        each tenant's offloadable classes (``offload_classes``, largest
        regions first) moves to the rack's shared expander; more is
        offloaded only if the pinned+retained bytes would not fit the
        host.  Raises with a clear message when a tenant cannot fit
        anywhere or a rack's expander runs out.
        """
        if policy not in ("round_robin", "least_loaded", "first_fit"):
            raise ValueError(policy)
        if not tenants:
            raise ValueError("need at least one tenant")
        if not 0.0 <= offload_fraction <= 1.0:
            raise ValueError("offload_fraction must be in [0, 1]")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError("tenant names must be unique within a fleet")
        for t in tenants:
            if not 0 <= t.qos_class < self.n_qos_classes:
                raise ValueError(
                    f"tenant {t.name!r} declares qos_class={t.qos_class} but "
                    f"the fleet has {self.n_qos_classes} QoS class(es)"
                )
        R, H = self.n_racks, self.hosts_per_rack
        free_local = np.full((R, H), self.local_capacity)
        free_shared = np.full((R,), self.shared_capacity)
        placements: List[TenantPlacement] = []
        rr = 0
        for t in tenants:
            regions = [r for r in t.regions.regions if r.nbytes > 0]
            pinned = [r for r in regions if r.tensor_class not in self.offload_classes]
            off = sorted(
                (r for r in regions if r.tensor_class in self.offload_classes),
                key=lambda r: -r.nbytes,
            )
            pinned_b = float(sum(r.nbytes for r in pinned))
            off_total = float(sum(r.nbytes for r in off))
            # offload the largest regions until the requested fraction is met
            target = offload_fraction * off_total
            spill, spill_b = [], 0.0
            for r in off:
                if spill_b >= target:
                    break
                spill.append(r)
                spill_b += r.nbytes
            retained = [r for r in off if r not in spill]

            def resident() -> float:
                return pinned_b + sum(r.nbytes for r in retained)

            # slot selection against the *resident* footprint
            if policy == "round_robin":
                slot = rr % (R * H)
                rr += 1
                rack, host = divmod(slot, H)
            elif policy == "least_loaded":
                slot = int(np.argmax(free_local))
                rack, host = divmod(slot, H)
            else:  # first_fit
                fits = np.argwhere(free_local.reshape(-1) >= resident())
                slot = int(fits[0, 0]) if fits.size else int(np.argmax(free_local))
                rack, host = divmod(slot, H)
            # spill more (largest retained first) until the host fits
            while retained and resident() > free_local[rack, host]:
                r = retained.pop(0)
                spill.append(r)
                spill_b += r.nbytes
            if resident() > free_local[rack, host]:
                raise ValueError(
                    f"tenant {t.name!r} needs {bytes_to_gib(resident()):.1f} GiB "
                    f"resident but host ({rack}, {host}) has only "
                    f"{bytes_to_gib(free_local[rack, host]):.1f} GiB local DRAM free "
                    "— its pinned classes alone overflow the host"
                )
            if spill_b > free_shared[rack]:
                raise ValueError(
                    f"rack {rack}'s shared expander is out of capacity "
                    f"({bytes_to_gib(spill_b):.1f} GiB needed, "
                    f"{bytes_to_gib(free_shared[rack]):.1f} GiB free) placing "
                    f"tenant {t.name!r}"
                )
            free_local[rack, host] -= resident()
            free_shared[rack] -= spill_b
            pool_of = np.full((len(t.regions),), self.local_pool, np.int32)
            spilled = {r.rid for r in spill}
            for r in regions:
                if r.rid in spilled:
                    pool_of[r.rid] = self.shared_pool
            placements.append(
                TenantPlacement(
                    tenant=t,
                    rack=rack,
                    host=host,
                    local_bytes=resident(),
                    pooled_bytes=spill_b,
                    pool_of_region=pool_of,
                )
            )
        return placements

    # ------------------------------------------------------------------ #
    # the stacked fleet dispatch
    # ------------------------------------------------------------------ #

    def _rack_timelines(
        self, placements: Sequence[TenantPlacement]
    ) -> Tuple[List[List[MemEvents]], np.ndarray]:
        """Per-rack merged epoch timelines + per-host native durations."""
        R, H = self.n_racks, self.hosts_per_rack
        native = np.zeros((R, H), np.float64)
        per_rack_epochs: List[List[List[MemEvents]]] = [[] for _ in range(R)]
        for p in placements:
            sk = self._skeleton(p.tenant)
            epochs = [
                tr.with_host(p.host).with_qos(p.tenant.qos_class)
                for tr in skeleton_to_events(sk, p.pool_of_region)
            ]
            native[p.rack, p.host] += float(sum(sk.native_ns))
            racks = per_rack_epochs[p.rack]
            for e, tr in enumerate(epochs):
                while len(racks) <= e:
                    racks.append([])
                racks[e].append(tr)
        B = max((len(r) for r in per_rack_epochs), default=1) or 1
        rack_traces: List[List[MemEvents]] = []
        for r in range(R):
            rows = []
            for e in range(B):
                parts = per_rack_epochs[r][e] if e < len(per_rack_epochs[r]) else []
                # co-scheduled tenants share the rack's fabric instant:
                # merge onto one time-sorted timeline (FabricSession's
                # merged-round contract)
                rows.append(concat_events(parts).sorted_by_time())
            rack_traces.append(rows)
        return rack_traces, native

    def _dispatch(
        self, rack_traces: List[List[MemEvents]], tiles: int, mesh
    ) -> List[DelayBreakdown]:
        """ONE ``[K, B, N]`` fleet dispatch (K = tiles × n_racks)."""
        from repro.distributed.sharding import (
            pad_to_multiple, replicated, resolve_data_mesh, shard_rows,
        )

        flat = self.flat
        P, S, H = flat.n_pools, flat.n_switches, flat.n_hosts
        V = H * P
        K = len(rack_traces)
        assert K == tiles * self.n_racks
        mesh, n_shards = resolve_data_mesh(
            mesh if mesh is not None else self.mesh, K, what="fleet dispatch"
        )
        n_max = max((tr.n for rows in rack_traces for tr in rows), default=1)
        B = max(len(rows) for rows in rack_traces)
        n_bucket = bucket_pow2(max(n_max, 1))
        b_bucket = bucket_pow2(B, floor=1)
        k_bucket = pad_to_multiple(bucket_pow2(K, floor=1), n_shards)
        t_stage = time.perf_counter()
        buf = self._stager.stage_stack(rack_traces, k_bucket, b_bucket, n_bucket)
        span = np.maximum(buf["span"], self.bw_window_ns)
        bw_window = np.maximum(span / self.n_windows, 1.0)
        scale = np.ones((k_bucket, b_bucket, V), self._np_dtype)
        stage_s = time.perf_counter() - t_stage

        ls = self._leaf_stack

        def pad_k(a: np.ndarray) -> np.ndarray:
            tiled = np.concatenate([a] * tiles, axis=0) if tiles > 1 else a
            if k_bucket == tiled.shape[0]:
                return tiled
            return np.concatenate(
                [tiled, np.repeat(tiled[:1], k_bucket - tiled.shape[0], axis=0)],
                axis=0,
            )

        self.dispatch_count += 1
        put_k = lambda a: shard_rows(mesh, jnp.asarray(a))
        put_r = lambda a: replicated(mesh, a)
        t_put = time.perf_counter()
        dev_args = (
            put_k(buf["t"]),
            put_k(buf["pool"]),
            put_k(buf["bytes"]),
            put_k(buf["weight"]),
            put_k(buf["host"]),
            put_k(buf["qos"]),
            put_k(buf["valid"]),
            put_k(jnp.asarray(bw_window, self.dtype)),
            put_k(scale),
            put_r(self._bits_table),
            put_k(pad_k(np.asarray(ls.pool_latency_ns, self._np_dtype))),
            put_k(pad_k(np.asarray(ls.local_latency_ns, self._np_dtype))),
            put_r(self._route),
            put_k(pad_k(np.asarray(ls.switch_stt_ns, self._np_dtype))),
            put_k(pad_k(np.asarray(ls.switch_bandwidth_gbps, self._np_dtype))),
            put_k(pad_k(self._disc_stack)),
            put_k(pad_k(self._weights_stack)),
        )
        transfer_s = time.perf_counter() - t_put
        self.last_dispatch = DispatchStats(
            devices_used=n_shards,
            shard_rows=k_bucket // n_shards if mesh is not None else 0,
            rows=K,
            padded_fraction=float(k_bucket - K) / k_bucket,
            stage_s=stage_s,
            transfer_s=transfer_s,
            qos_classes=self.n_qos_classes,
        )
        t_run = time.perf_counter()
        out = self._fleet_jit(
            *dev_args,
            stage_order=self._stage_order,
            n_windows=self.n_windows,
            n_hosts=H,
            impl="inline",
            fused=True,
            merge_plan=self._merge_plan,
            qos_on=self.qos_on,
        )
        lat, cong, bw, ppl, psc, psb, phl, phc, phb, pcc = jax.device_get(out)
        self.last_dispatch = dataclasses.replace(
            self.last_dispatch, compute_s=time.perf_counter() - t_run
        )
        return [
            DelayBreakdown(
                float(lat[k]), float(cong[k]), float(bw[k]),
                ppl[k].astype(np.float64),
                psc[k].astype(np.float64),
                psb[k].astype(np.float64),
                phl[k].astype(np.float64),
                phc[k].astype(np.float64),
                phb[k].astype(np.float64),
                pcc[k].astype(np.float64),
            )
            for k in range(K)
        ]

    def _report_from(
        self,
        placements: List[TenantPlacement],
        breakdowns: List[DelayBreakdown],
        native: np.ndarray,
        offload_fraction: float,
    ) -> FleetReport:
        R, H = self.n_racks, self.hosts_per_rack
        delay = np.zeros((R, H), np.float64)
        for r, bd in enumerate(breakdowns):
            delay[r] = bd.per_host_total_ns
        return FleetReport(
            n_racks=R,
            hosts_per_rack=H,
            offload_fraction=float(offload_fraction),
            placements=placements,
            breakdowns=breakdowns,
            native_ns=native,
            delay_ns=delay,
            stranded_recovered_bytes=float(
                sum(p.pooled_bytes for p in placements)
            ),
            devices_used=self.last_dispatch.devices_used,
            shard_rows=self.last_dispatch.shard_rows,
            padded_fraction=self.last_dispatch.padded_fraction,
            qos_classes=self.n_qos_classes,
        )

    def simulate(
        self,
        tenants: Sequence[TenantSpec],
        policy: str = "least_loaded",
        offload_fraction: float = 1.0,
        mesh=None,
    ) -> FleetReport:
        """Schedule the tenants and price one steady-state fleet round."""
        placements = self.place(tenants, policy, offload_fraction)
        rack_traces, native = self._rack_timelines(placements)
        breakdowns = self._dispatch(rack_traces, tiles=1, mesh=mesh)
        return self._report_from(
            placements, breakdowns[: self.n_racks], native, offload_fraction
        )

    def frontier(
        self,
        tenants: Sequence[TenantSpec],
        offload_fractions: Sequence[float] = (0.0, 0.25, 0.5, 0.75, 1.0),
        policy: str = "least_loaded",
        mesh=None,
    ) -> List[FleetPoint]:
        """The stranded-GB-recovered vs. p99-slowdown frontier, in ONE
        ``[F·R, B, N]`` stacked dispatch.

        Every fraction re-places the tenants (skeletons are cached — a new
        placement is only a region→pool gather), all F·R rack planes stack
        on the same leading axis, and the mesh shards fraction and rack
        work together.  Points come back in ``offload_fractions`` order.
        """
        fracs = [float(f) for f in offload_fractions]
        if not fracs:
            raise ValueError("need at least one offload fraction")
        all_traces: List[List[MemEvents]] = []
        per_f: List[Tuple[List[TenantPlacement], np.ndarray]] = []
        for f in fracs:
            placements = self.place(tenants, policy, f)
            traces, native = self._rack_timelines(placements)
            all_traces.extend(traces)
            per_f.append((placements, native))
        breakdowns = self._dispatch(all_traces, tiles=len(fracs), mesh=mesh)
        points: List[FleetPoint] = []
        for i, f in enumerate(fracs):
            placements, native = per_f[i]
            rep = self._report_from(
                placements,
                breakdowns[i * self.n_racks : (i + 1) * self.n_racks],
                native,
                f,
            )
            points.append(
                FleetPoint(
                    offload_fraction=f,
                    stranded_recovered_gb=bytes_to_gib(rep.stranded_recovered_bytes),
                    p99_slowdown=rep.p99_slowdown(),
                    mean_slowdown=rep.mean_slowdown(),
                    report=rep,
                )
            )
        return points
