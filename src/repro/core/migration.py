"""Hot/cold migration and prefetch simulation (paper §1 research uses:
"comparison of software and hardware memory prefetching and migration").

Both mechanisms are simulated **on top of the same trace**: given per-epoch
access statistics per region, a migration policy decides promotions
(pool -> local) and demotions (local -> pool); the migration traffic itself
is injected as extra events so the analyzer charges its latency/bandwidth
cost.

* software migration: decisions at epoch boundaries, page granularity —
  models an OS tiering daemon (e.g. TPP/HeMem-style).
* hardware migration: decisions applied mid-epoch after a short reaction
  time, cacheline granularity — models a device-side HW prefetcher.

The decision engine is **vectorized**: hotness EWMAs, the demotion mask,
and the budget-packed promotion prefix are pure array ops (bincount ->
EWMA update -> stable argsort + cumsum), so an epoch over ~1e5 regions
costs a few numpy passes instead of a Python loop per region.  The
pre-vectorization per-region loop survives as ``impl='loop'`` — the
decision oracle for the equivalence tests and the baseline for
``benchmarks/migration_scaling.py``.

Policy semantics (both impls):

* hotness is a weight-aware EWMA: event counts are accumulated with their
  PEBS ``weight`` multiplicity, so sampled traces drive unbiased decisions;
* every cold region demotes (demotions only free budget).  Regions born
  local (``home == 0``) demote to ``MigrationConfig.demote_pool`` when one
  is configured — without it they can never demote, which pins the local
  budget forever and starves all future promotions;
* promotions are budget-packed hottest-first: the maximal hotness-ordered
  *prefix* of candidates whose cumulative size fits the remaining local
  budget is promoted (cumsum packing; an O(1)-decision daemon's rule, and
  the form that vectorizes).

Several simulators may share one :class:`LocalBudget` — the fabric
session's co-tenant mode, where every tenant's promotions draw on the same
local-DRAM capacity.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import numpy as np

from .events import PAGE_BYTES, MemEvents, RegionMap, concat_events
from .topology import FlatTopology
from .units import BYTES_PER_GIB

__all__ = ["LocalBudget", "MigrationConfig", "MigrationSimulator"]


@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    mode: str = "software"  # 'software' | 'hardware' | 'off'
    promote_threshold: float = 64.0  # accesses/epoch to promote a region
    demote_threshold: float = 4.0  # accesses/epoch below which to demote
    local_budget_bytes: int = 16 * BYTES_PER_GIB
    reaction_ns: float = 0.0  # hardware mode: reaction latency before moves
    granularity_bytes: int = PAGE_BYTES  # sw: pages; hw typically cachelines
    # where cold regions whose home *is* local DRAM demote to (pool name or
    # index).  None preserves the home-pool-only rule: local-born regions
    # then never demote and permanently hold their budget share.
    demote_pool: Optional[Union[int, str]] = None

    def __post_init__(self):
        if self.mode not in ("software", "hardware", "off"):
            raise ValueError(self.mode)


class LocalBudget:
    """Mutable local-DRAM byte budget, shareable across simulators.

    A :class:`MigrationSimulator` owns a private one by default; a fabric
    session passes the same instance to every tenant's simulator so their
    promotions compete for one local tier.
    """

    def __init__(self, limit_bytes: float):
        self.limit = float(limit_bytes)
        self.used = 0.0


class MigrationSimulator:
    """Stateful across epochs: tracks region residency and hotness EWMA.

    ``host`` tags the emitted migration copy traffic (a fabric session
    creates one simulator per tenant, on that tenant's host index).
    ``impl='loop'`` selects the per-region Python reference path — same
    decisions, used as the vectorization oracle and benchmark baseline.
    """

    def __init__(
        self,
        cfg: MigrationConfig,
        regions: RegionMap,
        flat: FlatTopology,
        host: int = 0,
        budget: Optional[LocalBudget] = None,
        impl: str = "vector",
    ):
        if impl not in ("vector", "loop"):
            raise ValueError(impl)
        self.cfg = cfg
        self.regions = regions
        self.flat = flat
        self.host = int(host)
        self.impl = impl
        R = len(regions)
        self._region_list = list(regions)  # rid-indexed (rids are dense)
        self._pool = np.array([r.pool for r in regions], np.int32)
        self._nbytes = np.array([r.nbytes for r in regions], np.float64)
        self._home_pool = self._pool.copy()  # policy-assigned home, [R]
        self._hot_ewma = np.zeros((R,), np.float64)
        self._budget = budget if budget is not None else LocalBudget(cfg.local_budget_bytes)
        self._budget.used += float(self._nbytes[self._pool == 0].sum())
        self._synced = False  # first observe re-reads Region.pool (see below)
        self._demote_pool = self._resolve_demote_pool(cfg.demote_pool)
        self.moved_bytes_total = 0.0
        self.promotions = 0
        self.demotions = 0

    def _resolve_demote_pool(self, dp) -> int:
        if dp is None:
            return -1
        idx = self.flat.pool_names.index(dp) if isinstance(dp, str) else int(dp)
        if not (0 < idx < self.flat.n_pools):
            raise ValueError(f"demote_pool must be a non-local pool, got {dp!r}")
        return idx

    def _resync_residency(self) -> None:
        """Adopt ``Region.pool`` as current residency (first observe only).

        Simulators are often constructed before a placement policy runs
        (``CXLMemSim.attach`` places at attach time); homes stay the
        construction-time snapshot — the policy-assigned home contract —
        but residency and the budget's local-byte accounting must reflect
        where the regions actually ended up when migration starts.  After
        this point the simulator is the sole residency mutator and keeps
        the Region objects in sync eagerly.
        """
        self._nbytes = np.array([r.nbytes for r in self._region_list], np.float64)
        pools_now = np.array([r.pool for r in self._region_list], np.int32)
        self._budget.used += float(
            self._nbytes[pools_now == 0].sum() - self._nbytes[self._pool == 0].sum()
        )
        self._pool = pools_now

    # Region.access_count (the harvested-hotness input of e.g.
    # HotnessTieredPolicy) is refreshed every epoch up to this region count;
    # above it the O(R) Python attribute loop would swamp the vectorized
    # decision pass, so large maps refresh via sync_region_stats() instead.
    _SYNC_STATS_MAX = 4096

    def sync_region_stats(self) -> None:
        """Write the hotness EWMAs back onto ``Region.access_count``.

        Residency (``Region.pool``) is synced eagerly on every move and
        ``access_count`` automatically for maps up to ``_SYNC_STATS_MAX``
        regions; beyond that, call this before reading ``access_count``."""
        for r in self._region_list:
            r.access_count = float(self._hot_ewma[r.rid])

    # ------------------------------------------------------------------ #

    def observe_and_migrate(self, trace: MemEvents) -> Tuple[MemEvents, MemEvents]:
        """Update hotness from this epoch's trace; emit migration traffic.

        Returns ``(remapped_trace, migration_events)``: the input trace with
        pools rewritten to current residency, plus the extra copy traffic.
        Every untouched event column — PEBS ``weight``, fabric ``host``,
        bytes, write flags — rides through the remap unchanged.
        """
        if self.cfg.mode == "off" or trace.n == 0:
            return trace, MemEvents.empty()
        if not self._synced:
            self._resync_residency()
            self._synced = True

        R = len(self._pool)
        counts = np.bincount(
            trace.region, weights=trace.weight, minlength=R
        )[:R]
        self._hot_ewma = 0.5 * self._hot_ewma + 0.5 * counts
        if R <= self._SYNC_STATS_MAX:
            # one loop, both directions: publish hotness to the Region
            # objects and re-read sizes, so mid-run RegionMap.free() (which
            # zeroes nbytes in place) is honored like the old live-reading
            # loop did.  Large maps snapshot at first observe instead.
            for r in self._region_list:
                r.access_count = float(self._hot_ewma[r.rid])
                self._nbytes[r.rid] = float(r.nbytes)

        epoch_end = float(trace.t_ns.max())
        move_t = (
            min(self.cfg.reaction_ns, epoch_end)
            if self.cfg.mode == "hardware"
            else epoch_end  # software migrates at the epoch boundary
        )

        if self.impl == "loop":
            migration = self._migrate_loop(move_t)
        else:
            migration = self._migrate_vector(move_t)

        # remap trace events issued after the (hardware) move point
        if self.cfg.mode == "hardware":
            new_pool = self._pool[trace.region]
            applied = trace.t_ns >= move_t
            new_pool = np.where(applied, new_pool, trace.pool).astype(np.int32)
            remapped = dataclasses.replace(trace, pool=new_pool)
        else:
            remapped = trace  # software: remap takes effect next epoch
        return remapped, migration

    # ------------------------------------------------------------------ #
    # decision engines
    # ------------------------------------------------------------------ #

    def _migrate_vector(self, move_t: float) -> MemEvents:
        """Pure-array decision pass: one demotion mask, one argsort/cumsum
        promotion prefix, one batched copy-traffic build."""
        pool, home, hot, nb = self._pool, self._home_pool, self._hot_ewma, self._nbytes
        b = self._budget

        # demote cold local residents first (frees budget), then promote hot
        cold = (pool == 0) & (hot < self.cfg.demote_threshold)
        dem = cold & ((home != 0) | (self._demote_pool >= 0))
        dem_ids = np.nonzero(dem)[0]
        dem_dst = np.where(home[dem_ids] != 0, home[dem_ids], self._demote_pool)

        b.used -= float(nb[dem_ids].sum())
        pool[dem_ids] = dem_dst
        self.demotions += len(dem_ids)

        cand = np.nonzero((pool != 0) & (hot >= self.cfg.promote_threshold))[0]
        # stable sort on -hotness: ties keep rid order, matching the loop
        order = cand[np.argsort(-hot[cand], kind="stable")]
        fits = b.used + np.cumsum(nb[order]) <= b.limit
        pro_ids = order[fits]

        b.used += float(nb[pro_ids].sum())
        pro_src = pool[pro_ids].copy()
        pool[pro_ids] = 0
        self.promotions += len(pro_ids)

        movers = np.concatenate([dem_ids, pro_ids])
        if not len(movers):
            return MemEvents.empty()
        src = np.concatenate([np.zeros(len(dem_ids), np.int32), pro_src])
        dst = np.concatenate([dem_dst, np.zeros(len(pro_ids), np.int32)]).astype(np.int32)
        for rid in movers:  # eager residency sync; movers are few at steady state
            self._region_list[rid].pool = int(pool[rid])
        return self._copy_events_batch(movers, src, dst, move_t)

    def _migrate_loop(self, move_t: float) -> MemEvents:
        """Per-region Python reference (pre-vectorization shape): identical
        decisions, one :meth:`_copy_events` build per mover."""
        cfg = self.cfg
        b = self._budget
        migration: List[MemEvents] = []
        by_hot = sorted(self._region_list, key=lambda r: self._hot_ewma[r.rid])
        for r in by_hot:
            rid = r.rid
            if self._pool[rid] != 0 or self._hot_ewma[rid] >= cfg.demote_threshold:
                continue
            dst = int(self._home_pool[rid]) if self._home_pool[rid] != 0 else self._demote_pool
            if dst < 0:
                continue
            migration.append(self._copy_events(rid, src=0, dst=dst, t=move_t))
            self._pool[rid] = dst
            r.pool = dst
            b.used -= float(self._nbytes[rid])
            self.demotions += 1
        for r in sorted(self._region_list, key=lambda r: -self._hot_ewma[r.rid]):
            rid = r.rid
            if self._pool[rid] == 0 or self._hot_ewma[rid] < cfg.promote_threshold:
                continue
            if b.used + self._nbytes[rid] > b.limit:
                break  # budget packing is a hotness-ordered prefix
            migration.append(
                self._copy_events(rid, src=int(self._pool[rid]), dst=0, t=move_t)
            )
            self._pool[rid] = 0
            r.pool = 0
            b.used += float(self._nbytes[rid])
            self.promotions += 1
        return concat_events(migration)

    # ------------------------------------------------------------------ #
    # migration copy traffic
    # ------------------------------------------------------------------ #

    def _granules(self, nbytes: np.ndarray) -> np.ndarray:
        g = float(self.cfg.granularity_bytes)
        # batch granules into at most 4096 transactions per region
        return np.clip(np.ceil(nbytes / g), 1, 4096).astype(np.int64)

    def _copy_events_batch(
        self, rids: np.ndarray, src: np.ndarray, dst: np.ndarray, t: float
    ) -> MemEvents:
        """All movers' copy traffic as one build: each migration is a read
        stream from src plus a write stream to dst, carrying unit PEBS
        weight (copies are exact traffic) and this simulator's host tag."""
        nb = self._nbytes[rids]
        n = self._granules(nb)
        per = np.repeat(nb / n, n)
        reg = np.repeat(rids.astype(np.int32), n)
        pool = np.concatenate([np.repeat(src, n), np.repeat(dst, n)]).astype(np.int32)
        tot = 2 * len(per)
        self.moved_bytes_total += float(nb.sum())
        return MemEvents(
            t_ns=np.full((tot,), t, np.float64),
            pool=pool,
            bytes_=np.concatenate([per, per]),
            is_write=np.concatenate([np.zeros(len(per), bool), np.ones(len(per), bool)]),
            region=np.concatenate([reg, reg]),
            host=np.full((tot,), self.host, np.int32),
        )

    def _copy_events(self, rid: int, src: int, dst: int, t: float) -> MemEvents:
        ids = np.array([rid], np.int64)
        return self._copy_events_batch(
            ids, np.array([src], np.int32), np.array([dst], np.int32), t
        )
