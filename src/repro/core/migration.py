"""Hot/cold migration and prefetch simulation (paper §1 research uses:
"comparison of software and hardware memory prefetching and migration").

Both mechanisms are simulated **on top of the same trace**: given per-epoch
access counts per region, a migration policy decides promotions (pool -> local)
and demotions (local -> pool); the migration traffic itself is injected as
extra events so the analyzer charges its latency/bandwidth cost.

* software migration: decisions at epoch boundaries, page granularity —
  models an OS tiering daemon (e.g. TPP/HeMem-style).
* hardware migration: decisions applied mid-epoch after a short reaction
  time, cacheline granularity — models a device-side HW prefetcher.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .events import CACHELINE_BYTES, PAGE_BYTES, MemEvents, RegionMap, concat_events
from .topology import FlatTopology

__all__ = ["MigrationConfig", "MigrationSimulator"]


@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    mode: str = "software"  # 'software' | 'hardware' | 'off'
    promote_threshold: float = 64.0  # accesses/epoch to promote a region
    demote_threshold: float = 4.0  # accesses/epoch below which to demote
    local_budget_bytes: int = 16 * 2**30
    reaction_ns: float = 0.0  # hardware mode: reaction latency before moves
    granularity_bytes: int = PAGE_BYTES  # sw: pages; hw typically cachelines

    def __post_init__(self):
        if self.mode not in ("software", "hardware", "off"):
            raise ValueError(self.mode)


class MigrationSimulator:
    """Stateful across epochs: tracks region residency and hotness EWMA."""

    def __init__(self, cfg: MigrationConfig, regions: RegionMap, flat: FlatTopology):
        self.cfg = cfg
        self.regions = regions
        self.flat = flat
        self._home_pool = {r.rid: r.pool for r in regions}  # policy-assigned home
        self._hot_ewma: Dict[int, float] = {r.rid: 0.0 for r in regions}
        self._local_used = sum(r.nbytes for r in regions if r.pool == 0)
        self.moved_bytes_total = 0.0
        self.promotions = 0
        self.demotions = 0

    def observe_and_migrate(self, trace: MemEvents) -> Tuple[MemEvents, MemEvents]:
        """Update hotness from this epoch's trace; emit migration traffic.

        Returns ``(remapped_trace, migration_events)``: the input trace with
        pools rewritten to current residency, plus the extra copy traffic.
        """
        if self.cfg.mode == "off" or trace.n == 0:
            return trace, MemEvents.empty()

        counts = np.bincount(trace.region, minlength=len(self.regions))
        for r in self.regions:
            c = float(counts[r.rid]) if r.rid < len(counts) else 0.0
            self._hot_ewma[r.rid] = 0.5 * self._hot_ewma[r.rid] + 0.5 * c
            r.access_count = self._hot_ewma[r.rid]

        epoch_end = float(trace.t_ns.max()) if trace.n else 0.0
        move_t = (
            min(self.cfg.reaction_ns, epoch_end)
            if self.cfg.mode == "hardware"
            else epoch_end  # software migrates at the epoch boundary
        )

        migration: List[MemEvents] = []
        # demote cold local residents first (frees budget), then promote hot
        for r in sorted(self.regions, key=lambda r: self._hot_ewma[r.rid]):
            if (
                r.pool == 0
                and self._home_pool[r.rid] != 0
                and self._hot_ewma[r.rid] < self.cfg.demote_threshold
            ):
                migration.append(self._copy_events(r, src=0, dst=self._home_pool[r.rid], t=move_t))
                r.pool = self._home_pool[r.rid]
                self._local_used -= r.nbytes
                self.demotions += 1
        for r in sorted(self.regions, key=lambda r: -self._hot_ewma[r.rid]):
            if (
                r.pool != 0
                and self._hot_ewma[r.rid] >= self.cfg.promote_threshold
                and self._local_used + r.nbytes <= self.cfg.local_budget_bytes
            ):
                migration.append(self._copy_events(r, src=r.pool, dst=0, t=move_t))
                r.pool = 0
                self._local_used += r.nbytes
                self.promotions += 1

        # remap trace events issued after the (hardware) move point
        pool_vec = self.regions.pool_vector()
        new_pool = pool_vec[trace.region]
        if self.cfg.mode == "hardware":
            applied = trace.t_ns >= move_t
            new_pool = np.where(applied, new_pool, trace.pool)
        else:
            new_pool = trace.pool  # software: remap takes effect next epoch
        remapped = MemEvents(trace.t_ns, new_pool.astype(np.int32), trace.bytes_, trace.is_write, trace.region)
        return remapped, concat_events(migration)

    def _copy_events(self, r, src: int, dst: int, t: float) -> MemEvents:
        """A migration is a read stream from src + write stream to dst."""
        g = float(self.cfg.granularity_bytes)
        n = max(int(np.ceil(r.nbytes / g)), 1)
        n = min(n, 4096)  # batch granules into at most 4096 transactions
        per = r.nbytes / n
        tt = np.full((2 * n,), t, np.float64)
        pool = np.concatenate([np.full((n,), src), np.full((n,), dst)]).astype(np.int32)
        by = np.full((2 * n,), per, np.float64)
        wr = np.concatenate([np.zeros((n,), bool), np.ones((n,), bool)])
        reg = np.full((2 * n,), r.rid, np.int32)
        self.moved_bytes_total += float(r.nbytes)
        return MemEvents(tt, pool, by, wr, reg)
