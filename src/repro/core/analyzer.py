"""The Timing Analyzer — the paper's core contribution (§3, component 3).

Given one epoch's memory-event trace and a flattened topology, compute the
three delays the paper defines:

  1. **latency delay**    Σ_events (total latency of target pool − local DRAM
                          latency).  Pure gather + segment-sum.
  2. **congestion delay** per switch, events traversing the same switch must
                          be ≥ STT apart; later events are pushed back and the
                          push cascades through the path (leaf switch → RC).
  3. **bandwidth delay**  per switch, windows whose traffic exceeds BW × window
                          are stretched to bytes/BW ("observed bandwidth after
                          latency and congestion delays are added exceeds the
                          bandwidth of the switch").

Three implementations, in increasing speed order:

  * :class:`FineGrainedSimulator` — event-by-event discrete-event simulation
    walking every transaction through its switch path individually.  This is
    our stand-in for the cycle-level baseline the paper compares against
    (Gem5): exact, Python, deliberately per-event.
  * :func:`analyze_ref` — vectorized numpy epoch analyzer, float64.  The
    correctness oracle for the JAX/Pallas paths.
  * :class:`EpochAnalyzer` — jitted JAX analyzer with bucketed padding so
    repeated epochs hit the compile cache.  This is the production path.

The serial queue ``out_i = max(arr_i, out_{i-1} + STT)`` is solved in closed
form with a cumulative max:  let ``f_i = cummax(arr_i − STT·rank_i)``; then
``out_i = f_i + STT·rank_i``.  That turns the per-switch queue into a sort +
scan, which is what makes the epoch analyzer vectorizable (and, in
:mod:`repro.kernels.congestion`, a Pallas kernel).

The production pipeline (``fused=True``, the default) runs four stages per
batch of epochs, entirely on device, with a single host round-trip:

  1. **sort** — one stable argsort per epoch (padded entries sort last);
  2. **fused cascade** — every switch stage's serial queue in one pass
     (:func:`repro.kernels.ref.serial_queue_cascade` / the multi-stage
     Pallas kernel).  The array stays physically sorted by *current* time:
     after each stage the two sorted runs (queued vs untouched events) are
     re-merged with rank arithmetic, so no further sorts are needed while
     still matching ``analyze_ref``'s per-stage re-sort exactly;
  3. **windowed bandwidth** — segment-sums over static window counts on the
     post-congestion times;
  4. **device accumulation** — per-epoch breakdowns are summed over the
     batch on device; only six scalars/small vectors cross the host
     boundary per ``analyze_batch`` call.

Choosing ``impl``:

  * ``'inline'`` — fused cascade as pure XLA ops; fastest on CPU/GPU, the
    default, and the recommended production path everywhere.
  * ``'pallas'`` — the fused multi-stage TPU kernel (one kernel launch per
    epoch cascade).  Its scan phase follows the proven single-switch kernel,
    but the inter-stage merge uses in-kernel gather/scatter that has only
    been validated in interpret mode (this container has no TPU); treat the
    compiled path as experimental until exercised on TPU hardware.
  * ``'pallas_interpret'`` — same kernel body via the Pallas interpreter;
    slow, used by tests/benchmarks to validate the kernel on CPU.
  * ``'ref'`` (``analyze_ref``) — numpy float64; the oracle, not jitted.

``fused=False`` preserves the pre-fusion per-switch argsort loop; it exists
as the benchmark baseline (``benchmarks/analyzer_scaling.py``) and as a
cross-check, not for production use.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.annotations import axes
from .aot import AotDispatchCache
from .events import EventStager, MemEvents
from .topology import FlatTopology

__all__ = [
    "ChainPlan",
    "DelayBreakdown",
    "DispatchStats",
    "EpochAnalyzer",
    "FineGrainedSimulator",
    "PendingBatch",
    "analyze_any",
    "analyze_ref",
    "bucket_pow2",
    "plan_cascade",
    "plan_chain",
    "serial_queue_ref",
]


@dataclasses.dataclass(frozen=True)
class DispatchStats:
    """Observability record for the most recent stacked dispatch.

    ``devices_used`` is 1 whenever sharding did not engage; ``shard_rows``
    is the per-device slice of the (padded) leading axis, 0 when unsharded;
    ``padded_fraction`` is the fraction of leading-axis rows that were
    bucket/alignment padding — wasted compute the caller can act on.

    The pipeline breakdown splits the dispatch wall clock: ``stage_s``
    host staging (pack/fill, zero argsort on the pipeline path),
    ``transfer_s`` H2D placement, ``compile_s`` AOT lowering (nonzero only
    on a cache miss — steady state is 0), ``compute_s`` time spent blocked
    on device execution (under the engine's overlapped dispatcher this is
    only the *exposed* compute, the part H2D/staging of the next batch
    could not hide).  ``donated`` records whether the dispatch reused the
    staged device buffers in place; ``aot_cache_hit`` whether it ran a
    pre-compiled executable.  Non-pipeline dispatches leave all six at
    their defaults.

    ``qos_classes`` is the number of QoS classes the dispatched graph
    decomposed congestion over (1 = the plain FIFO fabric).
    """

    devices_used: int = 1
    shard_rows: int = 0
    rows: int = 0
    padded_fraction: float = 0.0
    stage_s: float = 0.0
    transfer_s: float = 0.0
    compile_s: float = 0.0
    compute_s: float = 0.0
    donated: bool = False
    aot_cache_hit: bool = False
    qos_classes: int = 1


def _opt_add(a: Optional[np.ndarray], b: Optional[np.ndarray]) -> Optional[np.ndarray]:
    if a is None:
        return None if b is None else np.array(b, copy=True)
    if b is None:
        return np.array(a, copy=True)
    return a + b


@dataclasses.dataclass(frozen=True)
class DelayBreakdown:
    """Per-epoch simulated delays (ns), plus per-component decomposition.

    ``per_pool_latency_ns`` stays indexed by *physical* pool (summed over
    hosts); the optional ``per_host_*`` arrays carry the host-segmented
    decomposition of each delay class for multi-host fabric analyses.  Each
    per-host array sums (within analyzer tolerance) to its fabric total.
    ``per_class_congestion_ns`` decomposes queueing delay by QoS class
    (length ``n_qos_classes``; ``[congestion_ns]`` on plain FIFO fabrics,
    ``None`` when the producing path predates the QoS axis).
    """

    latency_ns: float
    congestion_ns: float
    bandwidth_ns: float
    per_pool_latency_ns: np.ndarray  # [P]
    per_switch_congestion_ns: np.ndarray  # [S]
    per_switch_bandwidth_ns: np.ndarray  # [S]
    per_host_latency_ns: Optional[np.ndarray] = None  # [H]
    per_host_congestion_ns: Optional[np.ndarray] = None  # [H]
    per_host_bandwidth_ns: Optional[np.ndarray] = None  # [H]
    per_class_congestion_ns: Optional[np.ndarray] = None  # [C]

    @property
    def total_ns(self) -> float:
        return self.latency_ns + self.congestion_ns + self.bandwidth_ns

    @property
    def per_host_total_ns(self) -> Optional[np.ndarray]:
        """[H] total delay per host (None when host decomposition is absent)."""
        if self.per_host_latency_ns is None:
            return None
        return (
            self.per_host_latency_ns
            + self.per_host_congestion_ns
            + self.per_host_bandwidth_ns
        )

    def __add__(self, other: "DelayBreakdown") -> "DelayBreakdown":
        return DelayBreakdown(
            self.latency_ns + other.latency_ns,
            self.congestion_ns + other.congestion_ns,
            self.bandwidth_ns + other.bandwidth_ns,
            self.per_pool_latency_ns + other.per_pool_latency_ns,
            self.per_switch_congestion_ns + other.per_switch_congestion_ns,
            self.per_switch_bandwidth_ns + other.per_switch_bandwidth_ns,
            _opt_add(self.per_host_latency_ns, other.per_host_latency_ns),
            _opt_add(self.per_host_congestion_ns, other.per_host_congestion_ns),
            _opt_add(self.per_host_bandwidth_ns, other.per_host_bandwidth_ns),
            _opt_add(
                self.per_class_congestion_ns, other.per_class_congestion_ns
            ),
        )

    @staticmethod
    def zero(n_pools: int, n_switches: int, n_hosts: int = 1) -> "DelayBreakdown":
        return DelayBreakdown(
            0.0,
            0.0,
            0.0,
            np.zeros((n_pools,)),
            np.zeros((n_switches,)),
            np.zeros((n_switches,)),
            np.zeros((n_hosts,)),
            np.zeros((n_hosts,)),
            np.zeros((n_hosts,)),
        )


# --------------------------------------------------------------------------- #
# Closed-form serial queue
# --------------------------------------------------------------------------- #


def bucket_pow2(n: int, floor: int = 16) -> int:
    """Next power-of-two bucket >= n (>= floor) — the shared padding rule
    of the epoch analyzer and the scenario suite, so their staged shapes
    land in the same jit compile-cache entries."""
    b = floor
    while b < n:
        b <<= 1
    return b


def serial_queue_ref(arrival_sorted: np.ndarray, stt: float) -> np.ndarray:
    """Start times of a FIFO queue with constant service time ``stt``.

    out_i = max(arrival_i, out_{i-1} + stt), solved as
    out_i = cummax(arrival_i - i*stt) + i*stt.
    """
    if len(arrival_sorted) == 0:
        return arrival_sorted
    idx = np.arange(len(arrival_sorted), dtype=np.float64)
    return np.maximum.accumulate(arrival_sorted - idx * stt) + idx * stt


def _check_reachable(flat: FlatTopology, events: MemEvents) -> None:
    """Reject events whose (host, pool) pair has no row on this fabric.

    Out-of-range host ids would be silently clamped by the jitted gather
    (routing the event through the wrong virtual-pool row and dropping it
    from the host decomposition), and traffic to a pool the issuing host's
    ports exclude has no fabric route — analyzing it would charge latency
    with zero switch traversal.  Both are attach-time mistakes, so both
    raise.
    """
    if events.n == 0:
        return
    hmax = int(events.host.max())
    if hmax >= flat.n_hosts or int(events.host.min()) < 0:
        raise ValueError(
            f"trace carries host id {hmax} but the topology declares "
            f"{flat.n_hosts} host(s) — flatten a Topology(n_hosts=...) that "
            "covers every merged host"
        )
    reach = flat.host_reachable
    if reach is None or reach.all():
        return
    bad = ~reach[events.host, events.pool]
    if bad.any():
        i = int(np.argmax(bad))
        raise ValueError(
            f"event targets pool {flat.pool_names[events.pool[i]]!r} which "
            f"host {int(events.host[i])}'s ports cannot reach "
            f"({int(bad.sum())} such events)"
        )


# --------------------------------------------------------------------------- #
# Reference (numpy, float64) epoch analyzer
# --------------------------------------------------------------------------- #


def analyze_ref(
    flat: FlatTopology,
    events: MemEvents,
    bw_window_ns: float = 10_000.0,
    lat_scale: Optional[np.ndarray] = None,
    n_windows: Optional[int] = None,
    presorted: bool = False,
) -> DelayBreakdown:
    """Vectorized numpy implementation of the three-delay model (oracle).

    Multi-host fabrics: each event is routed through its virtual pool
    ``vp = host * n_pools + pool`` (shared switch rows, private RC rows);
    every delay class additionally comes back host-segmented.  With
    ``n_hosts == 1`` this is numerically identical to the historical
    single-host oracle (``vp == pool`` and the host segment is the total).

    ``lat_scale`` (``[H*P]``, from
    :meth:`~repro.core.cache.DeviceCacheModel.latency_scale`) multiplies
    each event's added latency — the device-cache epoch summary.  Hits
    still traverse the fabric, so congestion/bandwidth are deliberately
    unscaled; an all-ones vector is bitwise identical to passing None.

    ``n_windows`` pins the bandwidth-window count, with overflow clamped
    into the last window — the jitted analyzers' static-window semantics
    (they cannot grow window counts with the post-congestion span).  Pass
    the analyzer's ``n_windows`` together with its effective per-epoch
    ``bw_window_ns`` to compare against the batched/scenario paths at
    float tolerance instead of window-discretization tolerance.  Default
    (None) keeps the historical behavior: enough windows to cover the
    shifted span.

    ``presorted=True`` promises ``events.t_ns`` is already non-decreasing
    (:func:`~repro.core.events.merge_host_traces` output, staged epochs),
    letting the first cascade stage skip its stable argsort — the
    permutation would be the identity.  Later stages re-sort only after a
    stage actually rewrote times.
    """
    P, S, H = flat.n_pools, flat.n_switches, flat.n_hosts
    if events.n == 0:
        return DelayBreakdown.zero(P, S, H)
    _check_reachable(flat, events)

    t = events.t_ns.astype(np.float64).copy()
    pool = events.pool.astype(np.int64)
    host = events.host.astype(np.int64)
    vp = host * P + pool
    nbytes = events.bytes_.astype(np.float64)

    # -- 1. latency delay ------------------------------------------------- #
    per_event_lat = flat.pool_latency_ns[vp] - flat.local_latency_ns
    per_event_lat = np.maximum(per_event_lat, 0.0)
    if lat_scale is not None:
        per_event_lat = per_event_lat * np.asarray(lat_scale, np.float64)[vp]
    per_event_lat = per_event_lat * events.weight
    per_pool_lat = np.bincount(pool, weights=per_event_lat, minlength=P)[:P]
    per_host_lat = np.bincount(host, weights=per_event_lat, minlength=H)[:H]
    latency_ns = float(per_event_lat.sum())

    # -- 2. congestion delay (cascaded serial queues, deepest switch first) - #
    # QoS fabrics (per-switch priority/WFQ disciplines) replace the single
    # FIFO scan with per-level / per-class scans over the same sorted
    # subsequence; plain FIFO fabrics take the historical path bitwise.
    C = int(flat.n_qos_classes)
    qos_on = flat.has_qos
    qcls = np.clip(events.qos.astype(np.int64), 0, C - 1)
    w_table = flat.class_weight_table().astype(np.float64)
    per_switch_cong = np.zeros((S,), np.float64)
    per_host_cong = np.zeros((H,), np.float64)
    per_class_cong = np.zeros((C,), np.float64)
    sorted_now = bool(presorted)
    for s in flat.stage_order():
        stt = float(flat.switch_stt_ns[s])
        mask = flat.route[vp, s] > 0
        if stt <= 0 or not mask.any():
            continue
        if sorted_now:
            sub = np.nonzero(mask)[0]
        else:
            order = np.argsort(t, kind="stable")
            m_sorted = mask[order]
            sub = order[m_sorted]
        disc = (
            flat.switch_discipline[s]
            if qos_on and flat.switch_discipline
            else "fifo"
        )
        if disc == "fifo":
            start = serial_queue_ref(t[sub], stt)
        elif disc == "priority":
            # event of class c takes its start from the FIFO scan over the
            # subsequence of classes <= c (strict priority, FIFO in class)
            q_sub = qcls[sub]
            start = np.empty((len(sub),), np.float64)
            for lvl in range(C):
                lv = q_sub <= lvl
                st_l = serial_queue_ref(t[sub[lv]], stt)
                start[q_sub == lvl] = st_l[q_sub[lv] == lvl]
        else:  # wfq: per-class virtual time with inflated service stt*W/w_c
            q_sub = qcls[sub]
            w_row = w_table[s]
            w_total = float(w_row.sum())
            start = np.empty((len(sub),), np.float64)
            for c in range(C):
                cm = q_sub == c
                start[cm] = serial_queue_ref(
                    t[sub[cm]], stt * w_total / float(w_row[c])
                )
        delay = start - t[sub]
        t[sub] = start
        sorted_now = False  # this stage rewrote times
        per_switch_cong[s] = delay.sum()
        per_host_cong += np.bincount(host[sub], weights=delay, minlength=H)[:H]
        per_class_cong += np.bincount(qcls[sub], weights=delay, minlength=C)[:C]
    congestion_ns = float(per_switch_cong.sum())

    # -- 3. bandwidth delay (windowed, after latency+congestion shifts) ---- #
    # Paper: observed bandwidth is measured after the earlier delays are
    # applied, so windows are computed on the shifted times plus the latency
    # component of each event's pool.
    t_obs = t + per_event_lat
    if n_windows is None:
        span = max(float(t_obs.max()) + 1.0, bw_window_ns)
        n_win = int(np.ceil(span / bw_window_ns))
    else:
        n_win = int(n_windows)
    win = np.minimum((t_obs / bw_window_ns).astype(np.int64), n_win - 1)
    per_switch_bw = np.zeros((S,), np.float64)
    per_host_bw = np.zeros((H,), np.float64)
    for s in range(S):
        bw = float(flat.switch_bandwidth_gbps[s])  # GB/s == bytes/ns
        if bw <= 0:
            continue
        mask = flat.route[vp, s] > 0
        if not mask.any():
            continue
        # per-(window, host) bytes through this switch; the window stretch is
        # attributed to hosts proportionally to their byte share in it
        key = win[mask] * H + host[mask]
        wb_h = np.bincount(key, weights=nbytes[mask], minlength=n_win * H)
        wb_h = wb_h.reshape(n_win, H)
        wbytes = wb_h.sum(axis=1)
        stretch = np.maximum(wbytes / bw - bw_window_ns, 0.0)
        per_switch_bw[s] = stretch.sum()
        share = np.divide(
            wb_h,
            wbytes[:, None],
            out=np.zeros_like(wb_h),
            where=wbytes[:, None] > 0,
        )
        per_host_bw += (stretch[:, None] * share).sum(axis=0)
    bandwidth_ns = float(per_switch_bw.sum())

    return DelayBreakdown(
        latency_ns,
        congestion_ns,
        bandwidth_ns,
        per_pool_lat,
        per_switch_cong,
        per_switch_bw,
        per_host_lat,
        per_host_cong,
        per_host_bw,
        per_class_cong,
    )


# --------------------------------------------------------------------------- #
# JAX epoch analyzer (production path)
# --------------------------------------------------------------------------- #


def plan_cascade(flat: FlatTopology):
    """Derive the fused cascade's static route bits and merge plan.

    The cascade keeps the event array sorted by current time.  A stage's
    scan only needs *its own masked events* to appear in non-decreasing
    order — and a subsequence of a sorted run is sorted.  Simulating the run
    partition of the array (runs split as stages rewrite their events) tells
    us, per stage, which previously-independent sorted runs its mask spans;
    only those need merging, piecewise, before the scan.  Chains (every pool
    behind the deepest switch) need zero merges; the paper's Figure 1 needs
    exactly one.  Falls back to the conservative merge-every-stage plan when
    the needed masks exceed the 31 bits of an int32 route word.

    Returns ``(bits_pool [V] int32, merge_plan | None, stage_order tuple)``
    where bit ``k`` of an event's route word marks membership in the pool
    set ``k`` (the first ``S`` bits are the stage masks, in stage order).
    Rows are **virtual pools** — one per (host, pool) pair — so a shared
    switch's stage mask spans every host that routes through it while each
    host's RC stage covers only that host's rows; with ``n_hosts == 1``
    virtual and physical pools coincide.
    """
    route = np.asarray(flat.route)
    P = route.shape[0]  # virtual (host, pool) rows
    stage_order = tuple(int(s) for s in flat.stage_order())
    masks = [
        frozenset(int(p) for p in np.nonzero(route[:, s] > 0)[0]) for s in stage_order
    ]
    # pool index P is a pseudo-pool for padded/invalid events: routed nowhere
    all_ids = frozenset(range(P + 1))

    sets: List[frozenset] = list(masks)  # bit k <-> sets[k]; first S are stages

    def bit_of(pool_set: frozenset) -> int:
        for k, existing in enumerate(sets):
            if existing == pool_set:
                return k
        sets.append(pool_set)
        return len(sets) - 1

    runs = [all_ids]
    plan: List[Tuple[Tuple[int, Optional[int]], ...]] = []
    for mask in masks:
        hits = [r & mask for r in runs if r & mask]
        ops: List[Tuple[int, Optional[int]]] = []
        if len(hits) > 1:
            # fold the runs the mask spans into one sorted subsequence; the
            # local pool and the padding pseudo-pool are never routed, so a
            # whole-array (within=None) merge can't arise here — it belongs
            # to the conservative fallback plan only
            acc = hits[0]
            for piece in hits[1:]:
                within = acc | piece
                ops.append((bit_of(piece), bit_of(within)))
                acc = within
            runs = [mask] + [r - mask for r in runs if r - mask]
        else:
            runs = [p for r in runs for p in (r & mask, r - mask) if p]
        plan.append(tuple(ops))

    if len(sets) > 31:  # int32 route word exhausted: conservative plan
        sets = list(masks)
        merge_plan = None
    else:
        merge_plan = tuple(plan)
    if len(sets) > 31:
        raise ValueError(
            f"{len(sets)} cascade stages exceed the 31-bit route word "
            f"(every switch plus one RC pseudo-switch per host is a stage; "
            f"this topology has {flat.n_hosts} hosts) — use "
            f"EpochAnalyzer, which falls back to the unfused path here"
        )
    bits_pool = np.zeros((P,), np.int32)
    for k, pool_set in enumerate(sets):
        for p in pool_set:
            if p < P:
                bits_pool[p] |= np.int32(1) << k
    return bits_pool, merge_plan, stage_order


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """Static routing data for the device-resident pipeline dispatch.

    ``enter_stage[v]`` is the cascade stage position at which events of
    virtual pool ``v`` first enter the fabric (-1 = local, never routed).
    Valid only for *chain* topologies: single host, and every stage mask a
    subset of the next in stage order (deepest-first) — then an event
    entering at position ``p`` traverses exactly stages ``p..S-1``, which
    is what lets :func:`repro.kernels.ref.chain_cascade` process a compact
    growing suffix instead of the full padded plane.
    """

    enter_stage: np.ndarray  # [V] int32
    stage_order: Tuple[int, ...]


def plan_chain(flat: FlatTopology) -> Optional[ChainPlan]:
    """Chain-eligibility check; None when the compact cascade cannot apply.

    Eligible: ``n_hosts == 1`` and nested stage masks (``M_p ⊆ M_{p+1}``
    in stage order).  Every linear expander chain — the paper's Figure 1
    shape, two-tier trees with one leaf switch per level on the path, and
    the deep ``chained_topology`` — qualifies; sibling switches at the
    same depth (disjoint masks) do not, and those dispatches fall back to
    the AOT-compiled full-plane path.
    """
    if flat.n_hosts != 1:
        return None
    route = np.asarray(flat.route)
    stage_order = tuple(int(s) for s in flat.stage_order())
    masks = [route[:, s] > 0 for s in stage_order]
    for p in range(len(masks) - 1):
        if np.any(masks[p] & ~masks[p + 1]):
            return None
    enter = np.full((route.shape[0],), -1, np.int32)
    for p in range(len(masks) - 1, -1, -1):
        enter[masks[p]] = p
    return ChainPlan(enter_stage=enter, stage_order=stage_order)


@axes(
    "B,W", "B,W", "B,N", "B,N", "B,N", "B,N", "B", "B,V", "V", "",
    "V,S", "S", "S",
)
def _analyze_pipeline_jax(
    t_pack: jnp.ndarray,  # [B, W] f32 per-stage packed sorted runs (+inf pads) — DONATED
    idx_pack: jnp.ndarray,  # [B, W] i32 positions into the staged row (-1 pads) — DONATED
    pool: jnp.ndarray,  # [B, N] i32 full plane (staged row order)
    nbytes: jnp.ndarray,  # [B, N] f32
    weight: jnp.ndarray,  # [B, N] f32
    valid: jnp.ndarray,  # [B, N] bool
    bw_window_ns: jnp.ndarray,  # [B]
    lat_scale: jnp.ndarray,  # [B, V]
    pool_latency_ns: jnp.ndarray,  # [V]
    local_latency_ns: jnp.ndarray,  # []
    route: jnp.ndarray,  # [V, S]
    switch_stt_ns: jnp.ndarray,  # [S]
    switch_bw: jnp.ndarray,  # [S]
    stage_order: Tuple[int, ...],  # static
    seg_caps: Tuple[int, ...],  # static packed segment capacities
    n_windows: int,  # static
):
    """Device-resident single-host chain dispatch (the pipeline hot path).

    The merge of per-stage sorted runs into one fabric timeline and every
    serial-queue scan happen **inside this graph**
    (:func:`repro.kernels.ref.chain_cascade` over a compact suffix that
    only ever holds routed events), so staging performed zero host
    argsorts.  Bandwidth windows come straight off the compact array:
    local-DRAM route rows are all zero, so unrouted events could only ever
    contribute zero bytes to every switch — skipping them is exact, and
    ``W`` (sum of per-stage capacity buckets) is typically much smaller
    than padded ``N``.  Latency stays a full-plane gather (it needs no
    times).  Returns the ten breakdown leaves of :func:`_analyze_jax`
    (this path is FIFO-only, so the per-class leaf is the degenerate
    ``[congestion]``) plus ``(t_fin, idx_fin)`` — shaped/typed exactly
    like the two donated inputs, so XLA serves them from the donated
    buffers and steady-state dispatch allocates nothing on device.
    """
    V = pool_latency_ns.shape[0]
    S = switch_stt_ns.shape[0]
    f32 = t_pack.dtype
    stage_arr = jnp.asarray(stage_order, jnp.int32)
    stts = switch_stt_ns[stage_arr]

    def one(tp1, ip1, pool1, nbytes1, weight1, valid1, bww1, scale1):
        # latency: identical to the fused full-plane formulation
        per_event_lat = (
            jnp.maximum(pool_latency_ns[pool1] - local_latency_ns, 0.0)
            * scale1[pool1]
            * weight1
        )
        per_event_lat = jnp.where(valid1, per_event_lat, 0.0)
        pool_onehot = (
            pool1[:, None] == jnp.arange(V, dtype=pool1.dtype)
        ).astype(f32)
        per_pool_lat = jnp.einsum("n,np->p", per_event_lat, pool_onehot)
        latency = per_event_lat.sum()

        # congestion: compact suffix cascade (merge + scan fused)
        from repro.kernels import ops as kops  # deferred: avoid cycles

        t_fin, idx_fin, dsums = kops.chain_cascade(tp1, ip1, stts, seg_caps)
        per_switch_cong = jnp.zeros((S,), f32).at[stage_arr].set(dsums)
        congestion = per_switch_cong.sum()

        # bandwidth from the compact array: payloads gathered through the
        # staged-row positions the cascade carried along
        real = idx_fin >= 0
        safe = jnp.maximum(idx_fin, 0)
        lat_e = jnp.take(per_event_lat, safe)
        vp_e = jnp.take(pool1, safe)
        nbytes_e = jnp.take(nbytes1, safe)
        t_obs = jnp.where(real, t_fin + lat_e, 0.0)
        win = jnp.minimum((t_obs / bww1).astype(jnp.int32), n_windows - 1)
        win = jnp.where(real, win, n_windows - 1)
        key = win * V + vp_e
        wp = jax.ops.segment_sum(
            jnp.where(real, nbytes_e, 0.0), key, num_segments=n_windows * V
        ).reshape(n_windows, V)
        wbytes = wp @ route  # [n_windows, S]
        bw_safe = jnp.where(switch_bw > 0, switch_bw, 1.0)
        stretch = jnp.maximum(wbytes / bw_safe[None, :] - bww1, 0.0)
        stretch = jnp.where(switch_bw[None, :] > 0, stretch, 0.0)
        per_switch_bw_d = stretch.sum(axis=0)
        bandwidth = per_switch_bw_d.sum()

        return (
            latency, congestion, bandwidth,
            per_pool_lat, per_switch_cong, per_switch_bw_d,
            latency[None], congestion[None], bandwidth[None],
            congestion[None],
            t_fin, idx_fin,
        )

    outs = jax.vmap(one)(
        t_pack, idx_pack, pool, nbytes, weight, valid, bw_window_ns, lat_scale
    )
    summed = tuple(x.sum(axis=0) for x in outs[:10])
    return summed + (outs[10], outs[11])


@axes(
    "N", "N", "N", "N", "N", "N", "N", "V", "V", "V", "",
    "V,S", "S", "S", "S", "S,C",
    bw_window_ns="",
)
def _analyze_jax(
    t: jnp.ndarray,  # [N] f32 epoch-relative ns, TIME-SORTED (padded: 0, last)
    pool: jnp.ndarray,  # [N] i32 (padded entries: 0)
    nbytes: jnp.ndarray,  # [N] f32 (padded entries: 0)
    weight: jnp.ndarray,  # [N] f32 statistical multiplicity
    host: jnp.ndarray,  # [N] i32 attached-host index (padded entries: 0)
    qos: jnp.ndarray,  # [N] i32 QoS class ids (padded entries: 0)
    valid: jnp.ndarray,  # [N] bool
    lat_scale: jnp.ndarray,  # [V] device-cache latency scale (ones: no cache)
    bits_table: jnp.ndarray,  # [V] i32 per-virtual-pool route word (plan_cascade)
    pool_latency_ns: jnp.ndarray,  # [V] (V = n_hosts * n_pools)
    local_latency_ns: jnp.ndarray,  # []
    route: jnp.ndarray,  # [V, S]
    switch_stt_ns: jnp.ndarray,  # [S]
    switch_bw: jnp.ndarray,  # [S] bytes/ns
    disc_code: jnp.ndarray,  # [S] i32 per-switch discipline codes
    class_weights: jnp.ndarray,  # [S, C] f32 per-switch class weights
    stage_order: Tuple[int, ...],  # static
    n_windows: int,  # static
    n_hosts: int,  # static
    bw_window_ns: jnp.ndarray,  # []
    impl: str = "inline",  # 'inline' | 'pallas' | 'pallas_interpret'
    fused: bool = True,  # False: legacy per-stage argsort loop (benchmarks)
    merge_plan=None,  # static merge schedule from plan_cascade (fused only)
    qos_on: bool = False,  # static: route congestion through the QoS cascade
):
    """One epoch's three-delay analysis; the fused path (default) assumes
    the events were staged time-sorted with padding at the tail (the
    :class:`~repro.core.events.EventStager` contract — the epoch's one
    stable sort happens host-side during staging, and only when the trace
    isn't already sorted).

    Multi-host fabrics (``n_hosts > 1``, a static branch): every lookup is
    keyed by the virtual pool ``vp = host * P + pool`` so shared switches
    see the merged timeline while per-host RCs stay private, and each delay
    class is additionally host-segmented on device.  The ``n_hosts == 1``
    graph is exactly the historical single-host one.

    ``qos_on`` (static) swaps the FIFO cascade for the data-driven QoS
    cascade (:func:`repro.kernels.ref.qos_cascade_dyn`): per-switch
    disciplines/weights become runtime operands and a tenth output leaf
    decomposes congestion by QoS class.  ``qos_on=False`` leaves the
    congestion graph bitwise identical to the historical one (``qos``,
    ``disc_code`` and ``class_weights`` go unused) with the degenerate
    ``[congestion]`` tenth leaf.
    """
    V = pool_latency_ns.shape[0]
    P = V // n_hosts  # physical pools
    S = switch_stt_ns.shape[0]
    f32 = t.dtype
    vp = pool if n_hosts == 1 else host * P + pool
    if qos_on and not fused:
        raise ValueError(
            "QoS disciplines require the fused cascade (fused=True)"
        )

    # -- latency ----------------------------------------------------------- #
    # device-cache hits are charged at device-DRAM latency via the per-vp
    # scale (core/cache.py); ones => bitwise the historical no-cache graph
    per_event_lat = (
        jnp.maximum(pool_latency_ns[vp] - local_latency_ns, 0.0)
        * lat_scale[vp]
        * weight
    )
    per_event_lat = jnp.where(valid, per_event_lat, 0.0)
    if fused:
        # one-hot contraction: XLA CPU scatter-add (segment_sum) costs ~10x
        # more than an [N, P] einsum at pool counts this small
        pool_onehot = (pool[:, None] == jnp.arange(P, dtype=pool.dtype)).astype(f32)
        per_pool_lat = jnp.einsum("n,np->p", per_event_lat, pool_onehot)
    else:
        per_pool_lat = jax.ops.segment_sum(per_event_lat, pool, num_segments=P)
    latency = per_event_lat.sum()
    if n_hosts == 1:
        per_host_lat = latency[None]
    else:
        host_onehot = (host[:, None] == jnp.arange(n_hosts, dtype=host.dtype)).astype(f32)
        per_host_lat = jnp.einsum("n,nh->h", per_event_lat, host_onehot)

    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    t_cur = jnp.where(valid, t, big)

    if fused:
        # -- congestion: fused single-sort cascade -------------------------- #
        from repro.kernels import ops as kops  # deferred: avoid cycles

        stage_arr = jnp.asarray(stage_order, jnp.int32)
        ev_bits = jnp.where(valid, bits_table[vp], 0)
        if qos_on:
            qos_e = jnp.where(valid, qos, 0)
            t_fin, slot_idx, psd = kops.qos_congestion_cascade(
                t_cur,
                ev_bits,
                switch_stt_ns[stage_arr],
                qos_e,
                disc_code[stage_arr],
                class_weights[stage_arr],
                impl="ref" if impl == "inline" else impl,
                hosts=None if n_hosts == 1 else host,
                n_hosts=n_hosts,
            )
            # psd is [S_stages, H, C]: host- and class-segmented queueing delay
            per_switch_cong = jnp.zeros((S,), f32).at[stage_arr].set(
                psd.sum(axis=(1, 2))
            )
            per_class_cong = psd.sum(axis=(0, 1))
            congestion = per_switch_cong.sum()
            if n_hosts == 1:
                per_host_cong = congestion[None]
            else:
                per_host_cong = psd.sum(axis=(0, 2))
            # the QoS cascade's fold is data-driven (always runs), so slot
            # order never matches input order
            has_merges = True
        else:
            t_fin, slot_idx, psd = kops.congestion_cascade(
                t_cur,
                ev_bits,
                switch_stt_ns[stage_arr],
                impl="ref" if impl == "inline" else impl,
                merge_plan=merge_plan,
                hosts=None if n_hosts == 1 else host,
                n_hosts=n_hosts,
            )
            if n_hosts == 1:
                per_switch_cong = jnp.zeros((S,), f32).at[stage_arr].set(psd)
                congestion = per_switch_cong.sum()
                per_host_cong = congestion[None]
            else:
                # psd is [S_stages, H]: host-segmented per-stage queueing delay
                per_switch_cong = jnp.zeros((S,), f32).at[stage_arr].set(
                    psd.sum(axis=1)
                )
                per_host_cong = psd.sum(axis=0)
                congestion = per_switch_cong.sum()
            per_class_cong = congestion[None]
            # the Pallas kernel always runs the conservative merge schedule, so
            # its slot order never matches input order
            has_merges = impl != "inline" or merge_plan is None or any(
                len(ops) for ops in merge_plan
            )
        if has_merges:
            # bandwidth runs in final slot order; gather payloads through
            # the cascade's permutation (slot k held input event slot_idx[k])
            lat_e = per_event_lat[slot_idx]
            vp_e, nbytes_e = vp[slot_idx], nbytes[slot_idx]
            valid_e = valid[slot_idx]
        else:
            # no merges scheduled: slot order == input order, skip gathers
            lat_e, vp_e, nbytes_e, valid_e = per_event_lat, vp, nbytes, valid
        # -- bandwidth: one segment-sum over (window, vpool), then a tiny
        #    [W, V] @ [V, S] matmul distributes virtual pools onto switches - #
        t_obs = jnp.where(valid_e, t_fin + lat_e, 0.0)
        win = jnp.minimum((t_obs / bw_window_ns).astype(jnp.int32), n_windows - 1)
        win = jnp.where(valid_e, win, n_windows - 1)
        key = win * V + vp_e
        wp = jax.ops.segment_sum(
            jnp.where(valid_e, nbytes_e, 0.0), key, num_segments=n_windows * V
        ).reshape(n_windows, V)
        if n_hosts == 1:
            wbytes = wp @ route  # [W, S]
            wbytes_h = None
        else:
            wph = wp.reshape(n_windows, n_hosts, P)
            route_h = route.reshape(n_hosts, P, S)
            wbytes_h = jnp.einsum("whp,hps->whs", wph, route_h)  # [W, H, S]
            wbytes = wbytes_h.sum(axis=1)
    else:
        # -- congestion: legacy per-stage argsort loop (seed baseline) ------ #
        per_switch_list = [jnp.zeros((), f32)] * S
        per_host_cong = jnp.zeros((n_hosts,), f32)
        for s in stage_order:
            stt = switch_stt_ns[s]
            mask = (route[vp, s] > 0) & valid
            order = jnp.argsort(t_cur, stable=True)
            t_sorted = t_cur[order]
            m_sorted = mask[order]
            if impl == "inline":
                rank = jnp.cumsum(m_sorted.astype(jnp.int32)) - 1
                rankf = rank.astype(f32)
                g = jnp.where(m_sorted, t_sorted - stt * rankf, -big)
                f = jax.lax.cummax(g)
                start = jnp.where(m_sorted, f + stt * rankf, t_sorted)
                delay = jnp.where(m_sorted, start - t_sorted, 0.0)
            else:
                from repro.kernels import ops as kops  # deferred: avoid cycles

                start, delay = kops.congestion_queue(t_sorted, m_sorted, stt, impl=impl)
            t_cur = t_cur.at[order].set(jnp.where(m_sorted, start, t_sorted))
            per_switch_list[s] = delay.sum()
            if n_hosts > 1:
                per_host_cong = per_host_cong + jax.ops.segment_sum(
                    delay, host[order], num_segments=n_hosts
                )
        per_switch_cong = jnp.stack(per_switch_list)
        congestion = per_switch_cong.sum()
        per_class_cong = congestion[None]
        if n_hosts == 1:
            per_host_cong = congestion[None]

        # -- bandwidth: windowed stretch (seed formulation) ----------------- #
        t_obs = jnp.where(valid, t_cur + per_event_lat, 0.0)
        win = jnp.minimum((t_obs / bw_window_ns).astype(jnp.int32), n_windows - 1)
        win = jnp.where(valid, win, n_windows - 1)
        traversed = route[vp, :] * valid[:, None].astype(f32)  # [N, S]
        contrib = traversed * nbytes[:, None]  # [N, S]
        wbytes = jax.ops.segment_sum(contrib, win, num_segments=n_windows)  # [W, S]
        if n_hosts == 1:
            wbytes_h = None
        else:
            key = win * n_hosts + host
            wbytes_h = jax.ops.segment_sum(
                contrib, key, num_segments=n_windows * n_hosts
            ).reshape(n_windows, n_hosts, S)

    # bw <= 0 means an unconstrained component (analyze_ref skips it)
    bw_safe = jnp.where(switch_bw > 0, switch_bw, 1.0)
    stretch = jnp.maximum(wbytes / bw_safe[None, :] - bw_window_ns, 0.0)
    stretch = jnp.where(switch_bw[None, :] > 0, stretch, 0.0)
    per_switch_bw_d = stretch.sum(axis=0)
    bandwidth = per_switch_bw_d.sum()
    if n_hosts == 1:
        per_host_bw = bandwidth[None]
    else:
        # window stretch attributed to hosts by their byte share in the window
        denom = jnp.maximum(wbytes, jnp.asarray(1e-30, f32))
        per_host_bw = jnp.einsum("ws,whs->h", stretch / denom, wbytes_h)

    return (
        latency, congestion, bandwidth,
        per_pool_lat, per_switch_cong, per_switch_bw_d,
        per_host_lat, per_host_cong, per_host_bw,
        per_class_cong,
    )


@axes(
    "B,N", "B,N", "B,N", "B,N", "B,N", "B,N", "B,N", "B", "B,V",
    "V", "V", "", "V,S", "S", "S", "S", "S,C",
)
def _analyze_batch_jax(
    t: jnp.ndarray,  # [B, N]
    pool: jnp.ndarray,  # [B, N]
    nbytes: jnp.ndarray,  # [B, N]
    weight: jnp.ndarray,  # [B, N]
    host: jnp.ndarray,  # [B, N]
    qos: jnp.ndarray,  # [B, N]
    valid: jnp.ndarray,  # [B, N]
    bw_window_ns: jnp.ndarray,  # [B] per-epoch window length
    lat_scale: jnp.ndarray,  # [B, V] per-epoch device-cache latency scale
    bits_table: jnp.ndarray,  # [V]
    pool_latency_ns: jnp.ndarray,
    local_latency_ns: jnp.ndarray,
    route: jnp.ndarray,
    switch_stt_ns: jnp.ndarray,
    switch_bw: jnp.ndarray,
    disc_code: jnp.ndarray,  # [S]
    class_weights: jnp.ndarray,  # [S, C]
    stage_order: Tuple[int, ...],
    n_windows: int,
    n_hosts: int,
    impl: str = "inline",
    fused: bool = True,
    merge_plan=None,
    qos_on: bool = False,
):
    """B stacked epochs -> breakdown totals, accumulated on device.

    The inline path vmaps the whole per-epoch analysis (one batched
    cascade); the Pallas kernel runs epochs sequentially inside one traced
    ``lax.map`` dispatch.  Either way the host sees a single call and a
    single small transfer per batch.
    """

    def one(t1, pool1, nbytes1, weight1, host1, qos1, valid1, bww1, scale1):
        return _analyze_jax(
            t1, pool1, nbytes1, weight1, host1, qos1, valid1, scale1, bits_table,
            pool_latency_ns, local_latency_ns, route, switch_stt_ns, switch_bw,
            disc_code, class_weights,
            stage_order=stage_order, n_windows=n_windows, n_hosts=n_hosts,
            bw_window_ns=bww1, impl=impl, fused=fused, merge_plan=merge_plan,
            qos_on=qos_on,
        )

    xs = (t, pool, nbytes, weight, host, qos, valid, bw_window_ns, lat_scale)
    if impl in ("pallas", "pallas_interpret"):
        outs = jax.lax.map(lambda args: one(*args), xs)
    else:
        outs = jax.vmap(one)(*xs)
    return jax.tree.map(lambda x: x.sum(axis=0), outs)


@axes(
    "K,B,N", "K,B,N", "K,B,N", "K,B,N", "K,B,N", "K,B,N", "K,B,N",
    "K,B", "K,B,V", "V", "V", "", "V,S", "S", "S", "S", "S,C",
)
def _analyze_multi_jax(
    t: jnp.ndarray,  # [K, B, N] K sessions' stacked epoch batches
    pool: jnp.ndarray,  # [K, B, N]
    nbytes: jnp.ndarray,  # [K, B, N]
    weight: jnp.ndarray,  # [K, B, N]
    host: jnp.ndarray,  # [K, B, N]
    qos: jnp.ndarray,  # [K, B, N]
    valid: jnp.ndarray,  # [K, B, N]
    bw_window_ns: jnp.ndarray,  # [K, B]
    lat_scale: jnp.ndarray,  # [K, B, V]
    bits_table: jnp.ndarray,  # [V] shared (same topology across sessions)
    pool_latency_ns: jnp.ndarray,
    local_latency_ns: jnp.ndarray,
    route: jnp.ndarray,
    switch_stt_ns: jnp.ndarray,
    switch_bw: jnp.ndarray,
    disc_code: jnp.ndarray,  # [S] shared
    class_weights: jnp.ndarray,  # [S, C] shared
    stage_order: Tuple[int, ...],
    n_windows: int,
    n_hosts: int,
    impl: str = "inline",
    fused: bool = True,
    merge_plan=None,
    qos_on: bool = False,
):
    """K sessions × B epochs in one dispatch — per-SESSION totals on device.

    The cross-session analogue of :func:`_analyze_batch_jax`: the session
    axis is a plain vmap over the per-batch analysis (sessions share the
    route matrix, merge plan and numeric leaves — the same structural-
    sharing requirement the scenario sweep's ``[K, B, N]`` stack imposes),
    and each session's epochs are reduced on device, so the host sees one
    ``[K, ...]`` transfer however many sessions coalesced."""

    def one(t1, pool1, nbytes1, weight1, host1, qos1, valid1, bww1, scale1):
        return _analyze_batch_jax(
            t1, pool1, nbytes1, weight1, host1, qos1, valid1, bww1, scale1,
            bits_table, pool_latency_ns, local_latency_ns, route,
            switch_stt_ns, switch_bw, disc_code, class_weights,
            stage_order=stage_order, n_windows=n_windows, n_hosts=n_hosts,
            impl=impl, fused=fused, merge_plan=merge_plan, qos_on=qos_on,
        )

    return jax.vmap(one)(
        t, pool, nbytes, weight, host, qos, valid, bw_window_ns, lat_scale
    )


@axes(
    "K,B,N", "K,B,N", "K,B,N", "K,B,N", "K,B,N", "K,B,N", "K,B,N",
    "K,B", "K,B,V", "V", "K,V", "K", "V,S", "K,S", "K,S", "K,S", "K,S,C",
)
def _analyze_fleet_jax(
    t: jnp.ndarray,  # [K, B, N] K racks' stacked epoch batches
    pool: jnp.ndarray,  # [K, B, N]
    nbytes: jnp.ndarray,  # [K, B, N]
    weight: jnp.ndarray,  # [K, B, N]
    host: jnp.ndarray,  # [K, B, N]
    qos: jnp.ndarray,  # [K, B, N]
    valid: jnp.ndarray,  # [K, B, N]
    bw_window_ns: jnp.ndarray,  # [K, B]
    lat_scale: jnp.ndarray,  # [K, B, V]
    bits_table: jnp.ndarray,  # [V] shared (one rack structure)
    pool_latency_ns: jnp.ndarray,  # [K, V] per-rack numeric leaves
    local_latency_ns: jnp.ndarray,  # [K]
    route: jnp.ndarray,  # [V, S] shared (structure)
    switch_stt_ns: jnp.ndarray,  # [K, S]
    switch_bw: jnp.ndarray,  # [K, S]
    disc_code: jnp.ndarray,  # [K, S] per-rack QoS policies (numeric leaves)
    class_weights: jnp.ndarray,  # [K, S, C]
    stage_order: Tuple[int, ...],
    n_windows: int,
    n_hosts: int,
    impl: str = "inline",
    fused: bool = True,
    merge_plan=None,
    qos_on: bool = False,
):
    """K racks × B epochs in one dispatch, per-RACK numeric topologies.

    The fleet-scale variant of :func:`_analyze_multi_jax`: the leading
    axis is a rack (its merged multi-tenant timeline), and the *numeric*
    topology leaves carry the rack axis too — racks may run different
    expander latencies/bandwidths/STTs (:class:`~repro.core.topology.
    FlatTopologyStack` rows) while sharing one structure, so the route
    matrix, route-word table and cascade merge plan stay static and the
    whole fleet compiles once.  Per-rack epoch reduction happens on
    device; sharding the rack axis over a ('data',) mesh keeps the host
    transfer at one ``[K, ...]`` vector.
    """

    def one(t1, pool1, nbytes1, weight1, host1, qos1, valid1, bww1, scale1,
            plat1, llat1, stt1, sbw1, disc1, cw1):
        return _analyze_batch_jax(
            t1, pool1, nbytes1, weight1, host1, qos1, valid1, bww1, scale1,
            bits_table, plat1, llat1, route, stt1, sbw1, disc1, cw1,
            stage_order=stage_order, n_windows=n_windows, n_hosts=n_hosts,
            impl=impl, fused=fused, merge_plan=merge_plan, qos_on=qos_on,
        )

    return jax.vmap(one)(
        t, pool, nbytes, weight, host, qos, valid, bw_window_ns, lat_scale,
        pool_latency_ns, local_latency_ns, switch_stt_ns, switch_bw,
        disc_code, class_weights,
    )


@axes(
    "G,B,N", "G,B,N", "G,B,N", "G,B,N", "G,B,N", "G,B,N", "G,B",
    "U", "U,R", "U,S", "U,S", "U,S,C", "R", "K", "K", "K,R", "K,B,V",
    "K,V", "K", "K,S", "V", "V,S",
)
def _analyze_sweep_jax(
    t: jnp.ndarray,  # [G, B, N] f32 sorted epoch times per granularity group
    nbytes: jnp.ndarray,  # [G, B, N]
    weight: jnp.ndarray,  # [G, B, N]
    host: jnp.ndarray,  # [G, B, N]
    valid: jnp.ndarray,  # [G, B, N]
    region: jnp.ndarray,  # [G, B, N] i32 region ids (skeleton payload)
    bw_window: jnp.ndarray,  # [G, B] per-epoch window lengths
    cas_group: jnp.ndarray,  # [U] i32 cascade -> skeleton group
    cas_assign: jnp.ndarray,  # [U, R] i32 placement rows of unique cascades
    cas_stt: jnp.ndarray,  # [U, S] stt rows of unique cascades
    cas_disc: jnp.ndarray,  # [U, S] i32 discipline rows of unique cascades
    cas_weights: jnp.ndarray,  # [U, S, C] class-weight rows of unique cascades
    qos_of_region: jnp.ndarray,  # [R] i32 QoS class per workload region
    group_of: jnp.ndarray,  # [K] i32 scenario -> skeleton group
    cascade_of: jnp.ndarray,  # [K] i32 scenario -> unique cascade
    assign: jnp.ndarray,  # [K, R] i32 placement matrix
    lat_scale: jnp.ndarray,  # [K, B, V] per-scenario device-cache scales
    pool_latency_ns: jnp.ndarray,  # [K, V] stacked topology leaves
    local_latency_ns: jnp.ndarray,  # [K]
    switch_bw: jnp.ndarray,  # [K, S]
    bits_table: jnp.ndarray,  # [V] shared (structure)
    route: jnp.ndarray,  # [V, S] shared (structure)
    stage_order: Tuple[int, ...],  # static
    n_windows: int,  # static
    n_hosts: int,  # static
    merge_plan=None,  # static
    qos_on: bool = False,  # static: arbitrate cascades by QoS discipline
):
    """K scenarios × B epochs in ONE dispatch, per-scenario totals on device.

    Two phases, both inside the same jitted graph:

    1. **U unique cascades.**  Congestion — and the post-queue times the
       bandwidth windows are computed on — depends only on (trace skeleton,
       per-event route bits, per-stage STT), i.e. on the scenario's
       granularity group, placement row and STT row.  Latency and
       bandwidth-capacity overrides, cache configs, and policy duplicates
       all collapse onto the same cascade, so the expensive fused scan
       (and its inter-stage merges) runs once per *unique* triple: a
       256-scenario latency×policy sweep typically runs a handful of
       cascades.  The host computes the dedup (``cascade_of``); worst case
       ``U == K`` and nothing is lost.
    2. **K scenario reductions.**  Each scenario gathers its cascade's
       slot-ordered outputs, derives per-event pools **on device** from its
       row of the placement matrix (the cheap pool-gather), prices latency
       against its row of the stacked topology leaves (+ cache scale), and
       windows bandwidth on the shared post-congestion times.  Cheap
       elementwise/gather/segment-sum work only — no sorts, no scans.

    Structure — route matrix, route-word table, stage order, merge plan —
    is shared by construction (:class:`~repro.core.topology.
    FlatTopologyStack`), so the whole stack compiles once regardless of K,
    and per-scenario breakdowns are reduced over epochs on device: the
    host sees one ``[K, ...]`` transfer for the entire sweep.
    """
    from repro.kernels import ops as kops  # deferred: avoid cycles

    f32 = t.dtype
    V = pool_latency_ns.shape[1]
    P = V // n_hosts
    S = switch_bw.shape[1]
    stage_arr = jnp.asarray(stage_order, jnp.int32)
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    # the QoS cascade's inter-stage fold is data-driven (always runs)
    has_merges = qos_on or merge_plan is None or any(
        len(ops) for ops in merge_plan
    )

    # -- phase 1: the U unique congestion cascades -------------------------- #
    def one_cascade(g, assign_u, stt_u, disc_u, cw_u):
        tg, vg, rg, hg = t[g], valid[g], region[g], host[g]
        pool_u = jnp.where(vg, assign_u[rg], 0)
        vp_u = pool_u if n_hosts == 1 else hg * P + pool_u
        bits_u = jnp.where(vg, bits_table[vp_u], 0)
        # QoS class rides the region skeleton: derived on device per event
        qg = jnp.where(vg, qos_of_region[rg], 0)

        def per_epoch(t1, bits1, v1, h1, q1):
            t_cur = jnp.where(v1, t1, big)
            if qos_on:
                return kops.qos_congestion_cascade(
                    t_cur, bits1, stt_u[stage_arr], q1,
                    disc_u[stage_arr], cw_u[stage_arr], impl="ref",
                    hosts=None if n_hosts == 1 else h1, n_hosts=n_hosts,
                )
            return kops.congestion_cascade(
                t_cur, bits1, stt_u[stage_arr], impl="ref",
                merge_plan=merge_plan,
                hosts=None if n_hosts == 1 else h1, n_hosts=n_hosts,
            )

        t_fin, slot_idx, psd = jax.vmap(per_epoch)(tg, bits_u, vg, hg, qg)
        if has_merges:
            # slot-order payloads, gathered once per cascade (not per
            # scenario): slot k of epoch b held input event slot_idx[b, k]
            ga = lambda x: jnp.take_along_axis(x, slot_idx, axis=1)
            region_e = ga(rg)
            nbytes_e, weight_e = ga(nbytes[g]), ga(weight[g])
            valid_e, host_e = ga(vg), ga(hg)
        else:  # no merges scheduled: slot order == input order
            region_e, nbytes_e, weight_e = rg, nbytes[g], weight[g]
            valid_e, host_e = vg, hg
        return t_fin, psd, region_e, nbytes_e, weight_e, valid_e, host_e

    cas = jax.vmap(one_cascade)(
        cas_group, cas_assign, cas_stt, cas_disc, cas_weights
    )
    (t_fin_u, psd_u, region_u, nbytes_u, weight_u, valid_u, host_u) = cas

    # -- phase 2: per-scenario latency/bandwidth reductions ----------------- #
    def per_scenario(u, g, assign_k, scale_k, plat_k, llat_k, sbw_k):
        t_fin, region_e = t_fin_u[u], region_u[u]
        nbytes_e, weight_e = nbytes_u[u], weight_u[u]
        valid_e, host_e = valid_u[u], host_u[u]
        bwk = bw_window[g]  # [B]

        pool_e = jnp.where(valid_e, assign_k[region_e], 0)
        vp_e = pool_e if n_hosts == 1 else host_e * P + pool_e

        # latency: pool gather + cache scale (ones => exact no-cache)
        scale_e = jnp.take_along_axis(scale_k, vp_e, axis=1)  # [B, N]
        per_event_lat = (
            jnp.maximum(plat_k[vp_e] - llat_k, 0.0) * scale_e * weight_e
        )
        per_event_lat = jnp.where(valid_e, per_event_lat, 0.0)
        latency = per_event_lat.sum()
        pool_onehot = (pool_e[:, :, None] == jnp.arange(P, dtype=pool_e.dtype)).astype(f32)
        per_pool_lat = jnp.einsum("bn,bnp->p", per_event_lat, pool_onehot)
        if n_hosts == 1:
            per_host_lat = latency[None]
        else:
            host_onehot = (host_e[:, :, None] == jnp.arange(n_hosts, dtype=host_e.dtype)).astype(f32)
            per_host_lat = jnp.einsum("bn,bnh->h", per_event_lat, host_onehot)

        # congestion: shared with every scenario of the same cascade
        psd = psd_u[u]  # [B, Sst] | [B, Sst, H] | [B, Sst, H, C] (qos_on)
        if qos_on:
            per_switch_cong = jnp.zeros((S,), f32).at[stage_arr].set(
                psd.sum(axis=(0, 2, 3))
            )
            congestion = per_switch_cong.sum()
            per_class_cong = psd.sum(axis=(0, 1, 2))
            per_host_cong = (
                congestion[None] if n_hosts == 1 else psd.sum(axis=(0, 1, 3))
            )
        elif n_hosts == 1:
            per_switch_cong = jnp.zeros((S,), f32).at[stage_arr].set(psd.sum(axis=0))
            congestion = per_switch_cong.sum()
            per_host_cong = congestion[None]
            per_class_cong = congestion[None]
        else:
            per_switch_cong = jnp.zeros((S,), f32).at[stage_arr].set(
                psd.sum(axis=(0, 2))
            )
            per_host_cong = psd.sum(axis=(0, 1))
            congestion = per_switch_cong.sum()
            per_class_cong = congestion[None]

        # bandwidth: windows on the shared post-congestion times + this
        # scenario's latency component, one segment-sum per scenario
        t_obs = jnp.where(valid_e, t_fin + per_event_lat, 0.0)
        win = jnp.minimum((t_obs / bwk[:, None]).astype(jnp.int32), n_windows - 1)
        win = jnp.where(valid_e, win, n_windows - 1)
        B = t_obs.shape[0]
        b_ix = jnp.arange(B, dtype=jnp.int32)[:, None]
        key = (b_ix * n_windows + win) * V + vp_e
        wp = jax.ops.segment_sum(
            jnp.where(valid_e, nbytes_e, 0.0).reshape(-1),
            key.reshape(-1),
            num_segments=B * n_windows * V,
        ).reshape(B, n_windows, V)
        if n_hosts == 1:
            wbytes = wp @ route  # [B, W, S]
            wbytes_h = None
        else:
            wph = wp.reshape(B, n_windows, n_hosts, P)
            route_h = route.reshape(n_hosts, P, S)
            wbytes_h = jnp.einsum("bwhp,hps->bwhs", wph, route_h)
            wbytes = wbytes_h.sum(axis=2)
        # bw <= 0 means an unconstrained component (analyze_ref skips it);
        # unguarded 0/0 windows would poison totals with NaN
        sbw_safe = jnp.where(sbw_k > 0, sbw_k, 1.0)
        stretch = jnp.maximum(
            wbytes / sbw_safe[None, None, :] - bwk[:, None, None], 0.0
        )
        stretch = jnp.where(sbw_k[None, None, :] > 0, stretch, 0.0)
        per_switch_bw = stretch.sum(axis=(0, 1))
        bandwidth = per_switch_bw.sum()
        if n_hosts == 1:
            per_host_bw = bandwidth[None]
        else:
            denom = jnp.maximum(wbytes, jnp.asarray(1e-30, f32))
            per_host_bw = jnp.einsum("bws,bwhs->h", stretch / denom, wbytes_h)

        return (
            latency, congestion, bandwidth,
            per_pool_lat, per_switch_cong, per_switch_bw,
            per_host_lat, per_host_cong, per_host_bw,
            per_class_cong,
        )

    return jax.vmap(per_scenario)(
        cascade_of, group_of, assign, lat_scale, pool_latency_ns,
        local_latency_ns, switch_bw,
    )


@dataclasses.dataclass
class PendingBatch:
    """An in-flight epoch dispatch: staged, transferred and launched, but
    not yet resolved.  :meth:`finish` blocks on the device result and
    returns the :class:`DelayBreakdown`; until then the caller is free to
    stage and launch the *next* batch — the engine's overlapped dispatcher
    does exactly that, so batch k+1's staging and H2D run while batch k
    computes.  ``stats.compute_s`` is finalized at finish time with the
    exposed device wait."""

    analyzer: "EpochAnalyzer"
    out: Optional[tuple]
    stats: DispatchStats

    def finish(self) -> DelayBreakdown:
        a = self.analyzer
        P, S, H = a.flat.n_pools, a.flat.n_switches, a.flat.n_hosts
        if self.out is None:
            a.last_dispatch = self.stats
            return DelayBreakdown.zero(P, S, H)
        t0 = time.perf_counter()
        # the single host-boundary crossing for the whole batch; the
        # pipeline dispatch's trailing (t_fin, idx_pack) leaves stay on
        # device and are simply dropped
        lat, cong, bw, ppl, psc, psb, phl, phc, phb, pcc = jax.device_get(
            self.out[:10]
        )
        stats = dataclasses.replace(
            self.stats,
            compute_s=self.stats.compute_s + (time.perf_counter() - t0),
        )
        a.last_dispatch = stats
        self.stats = stats
        self.out = None
        return DelayBreakdown(
            float(lat),
            float(cong),
            float(bw),
            ppl.astype(np.float64),
            psc.astype(np.float64),
            psb.astype(np.float64),
            phl.astype(np.float64),
            phc.astype(np.float64),
            phb.astype(np.float64),
            pcc.astype(np.float64),
        )


class EpochAnalyzer:
    """Jitted epoch analyzer with bucketed padding and epoch batching.

    Event counts vary per epoch; traces are padded up to the next power-of-two
    bucket (via reusable :class:`~repro.core.events.EventStager` buffers, no
    per-epoch allocation) so repeated calls reuse the compile cache.

    :meth:`analyze_batch` stacks B bucketed epochs into ``[B, N]`` arrays and
    runs a single jitted, vmapped dispatch whose per-epoch breakdowns are
    summed **on device** — one host round-trip per batch instead of one per
    epoch.  :meth:`analyze` is the B=1 special case.  See the module
    docstring for the pipeline stages and the ``impl`` / ``fused`` knobs.
    """

    def __init__(
        self,
        flat: FlatTopology,
        bw_window_ns: float = 10_000.0,
        n_windows: int = 128,
        dtype=jnp.float32,
        impl: str = "inline",
        fused: bool = True,
        mesh=None,
        pipeline: bool = False,
        aot: Optional[AotDispatchCache] = None,
    ):
        """``pipeline=True`` enables the device-resident dispatch path:
        chain-eligible topologies (:func:`plan_chain`) run the packed
        compact cascade with on-device sorting and donated staging
        buffers; everything else runs the standard full-plane graph, but
        still through the AOT executable cache (``aot``, private by
        default) with the stage/transfer/compile/compute breakdown in
        :attr:`last_dispatch`.  Requires ``impl='inline'``."""
        self.flat = flat
        self.mesh = mesh
        self.last_dispatch = DispatchStats()
        self.sharded_dispatches = 0
        self.bw_window_ns = float(bw_window_ns)
        self.n_windows = int(n_windows)
        self.dtype = dtype
        self._pool_lat = jnp.asarray(flat.pool_latency_ns, dtype)
        self._local_lat = jnp.asarray(flat.local_latency_ns, dtype)
        self._route = jnp.asarray(flat.route, dtype)
        self._stt = jnp.asarray(flat.switch_stt_ns, dtype)
        self._bw = jnp.asarray(flat.switch_bandwidth_gbps, dtype)
        self._disc = jnp.asarray(flat.discipline_codes(), jnp.int32)
        self._weights = jnp.asarray(flat.class_weight_table(), dtype)
        self.qos_on = bool(flat.has_qos)
        self.impl = impl
        self.fused = bool(fused)
        if self.fused and flat.n_switches > 31:
            # the fused cascade encodes one stage per switch (incl. per-host
            # RCs) in a 31-bit route word; very wide fabrics fall back to
            # the legacy per-stage loop — slower, but any host count works
            self.fused = False
        if self.qos_on and not self.fused:
            raise ValueError(
                "QoS disciplines require the fused cascade: pass fused=True "
                "and keep the fabric within the 31-switch route-word budget"
            )
        if self.fused:
            bits_pool, self._merge_plan, self._stage_order = plan_cascade(flat)
        else:
            bits_pool = np.zeros((flat.route.shape[0],), np.int32)
            self._merge_plan = None
            self._stage_order = tuple(int(s) for s in flat.stage_order())
        self._bits_table = jnp.asarray(bits_pool)
        self._stager = EventStager(np.dtype(jnp.dtype(dtype).name))
        _static = (
            "stage_order", "n_windows", "n_hosts", "impl", "fused",
            "merge_plan", "qos_on",
        )
        self._batch_fn = jax.jit(_analyze_batch_jax, static_argnames=_static)
        self._multi_fn = jax.jit(_analyze_multi_jax, static_argnames=_static)
        self.pipeline = bool(pipeline)
        self._chain_plan: Optional[ChainPlan] = None
        self._aot: Optional[AotDispatchCache] = None
        if self.pipeline:
            if impl != "inline":
                raise ValueError(
                    "pipeline=True requires impl='inline' — the device-"
                    "resident dispatch is a pure-XLA graph"
                )
            self._aot = aot if aot is not None else AotDispatchCache()
            # the packed compact cascade is FIFO-only: QoS fabrics run the
            # full-plane graph (still AOT-cached) instead
            self._chain_plan = None if self.qos_on else plan_chain(flat)

    _bucket = staticmethod(bucket_pow2)

    def analyze(
        self, events: MemEvents, lat_scale: Optional[np.ndarray] = None
    ) -> DelayBreakdown:
        return self.analyze_batch(
            [events], None if lat_scale is None else [lat_scale]
        )

    def _clean_pairs(
        self,
        traces: Sequence[MemEvents],
        lat_scales: Optional[Sequence[Optional[np.ndarray]]],
    ) -> List[Tuple[MemEvents, Optional[np.ndarray]]]:
        """Pair epochs with their scales, drop empties, validate routes."""
        if lat_scales is None:
            lat_scales = [None] * len(traces)
        elif len(lat_scales) != len(traces):
            raise ValueError(
                f"{len(lat_scales)} lat_scales for {len(traces)} traces — "
                "pass one (possibly None) per epoch"
            )
        pairs = [(tr, sc) for tr, sc in zip(traces, lat_scales) if tr.n]
        for tr, _ in pairs:
            _check_reachable(self.flat, tr)
        return pairs

    def _aot_build(self, chain: Optional[ChainPlan], caps, b_bucket, n_bucket, dev_args):
        """(cache key, build thunk) for this dispatch's AOT executable.

        The key carries what varies *within* one analyzer: the dispatch
        kind and the bucketed shapes (chain-path segment capacities
        included — they are static operands of the compact cascade).  The
        topology fingerprint and mesh are fixed per analyzer and its
        private cache, so they need no key bits here; the engine's
        ``dispatch_key`` separates analyzers."""
        sds = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in dev_args)
        topo = (self._pool_lat, self._local_lat, self._route, self._stt, self._bw)
        topo_s = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in topo)
        if chain is not None:
            key = ("chain", b_bucket, n_bucket, caps)

            def build():
                jitted = jax.jit(
                    _analyze_pipeline_jax,
                    static_argnames=("stage_order", "seg_caps", "n_windows"),
                    donate_argnums=(0, 1),
                )
                return jitted.lower(
                    *sds, *topo_s,
                    stage_order=chain.stage_order,
                    seg_caps=caps,
                    n_windows=self.n_windows,
                ).compile()

        else:
            bits_s = jax.ShapeDtypeStruct(
                self._bits_table.shape, self._bits_table.dtype
            )
            topo_b = topo + (self._disc, self._weights)
            topo_bs = tuple(
                jax.ShapeDtypeStruct(a.shape, a.dtype) for a in topo_b
            )
            key = ("batch", b_bucket, n_bucket)

            def build():
                jitted = jax.jit(
                    _analyze_batch_jax,
                    static_argnames=(
                        "stage_order", "n_windows", "n_hosts", "impl",
                        "fused", "merge_plan", "qos_on",
                    ),
                )
                return jitted.lower(
                    *sds, bits_s, *topo_bs,
                    stage_order=self._stage_order,
                    n_windows=self.n_windows,
                    n_hosts=self.flat.n_hosts,
                    impl=self.impl,
                    fused=self.fused,
                    merge_plan=self._merge_plan,
                    qos_on=self.qos_on,
                ).compile()

        return key, build

    def launch_batch(
        self,
        traces: Sequence[MemEvents],
        lat_scales: Optional[Sequence[Optional[np.ndarray]]] = None,
        stager: Optional[EventStager] = None,
    ) -> PendingBatch:
        """Stage, transfer and launch one epoch batch without blocking.

        The non-blocking half of :meth:`analyze_batch` (same arguments,
        same semantics once the returned :class:`PendingBatch` is
        finished).  Pipeline analyzers on chain-eligible topologies run
        the device-resident packed dispatch — on-device sort, donated
        staging buffers, AOT executable; other pipeline dispatches run
        the full-plane graph through the AOT cache; non-pipeline
        analyzers launch the classic jitted path.  All three record the
        stage/transfer/compile/compute split in the pending stats.
        """
        P, S = self.flat.n_pools, self.flat.n_switches
        H = self.flat.n_hosts
        pairs = self._clean_pairs(traces, lat_scales)
        if not pairs:
            return PendingBatch(self, None, DispatchStats(rows=0))
        traces = [tr for tr, _ in pairs]
        t0 = time.perf_counter()
        n_bucket = self._bucket(max(tr.n for tr in traces))
        b_bucket = self._bucket(len(traces), floor=1)
        st = stager if stager is not None else self._stager
        chain = self._chain_plan
        caps = None
        if chain is not None:
            buf, pack, caps = st.stage_packed(
                traces, b_bucket, n_bucket, chain.enter_stage,
                len(chain.stage_order),
            )
        else:
            buf = st.stage(traces, b_bucket, n_bucket)
            pack = None
        np_dtype = np.dtype(jnp.dtype(self.dtype).name)
        scale_buf = np.ones((b_bucket, H * P), np_dtype)
        for row, (_, sc) in enumerate(pairs):
            if sc is not None:
                scale_buf[row] = sc
        span = np.maximum(buf["span"], self.bw_window_ns)
        bw_window = np.maximum(span / self.n_windows, 1.0).astype(np_dtype)
        t1 = time.perf_counter()

        from repro.distributed.sharding import timed_device_put

        if chain is not None:
            host_args = (
                pack["t"], pack["idx"], buf["pool"], buf["bytes"],
                buf["weight"], buf["valid"], bw_window, scale_buf,
            )
        else:
            host_args = (
                buf["t"], buf["pool"], buf["bytes"], buf["weight"],
                buf["host"], buf["qos"], buf["valid"], bw_window, scale_buf,
            )
        dev_args, transfer_s = timed_device_put(list(host_args))

        compile_s = 0.0
        aot_hit = False
        donated = False
        if self.pipeline:
            key, build = self._aot_build(chain, caps, b_bucket, n_bucket, dev_args)
            c0 = time.perf_counter()
            exe, aot_hit = self._aot.get(key, build)
            if not aot_hit:
                compile_s = time.perf_counter() - c0
            t2 = time.perf_counter()
            if chain is not None:
                out = exe(
                    *dev_args, self._pool_lat, self._local_lat, self._route,
                    self._stt, self._bw,
                )
                donated = bool(dev_args[0].is_deleted())
            else:
                out = exe(
                    *dev_args, self._bits_table, self._pool_lat,
                    self._local_lat, self._route, self._stt, self._bw,
                    self._disc, self._weights,
                )
        else:
            t2 = time.perf_counter()
            out = self._batch_fn(
                *dev_args, self._bits_table, self._pool_lat, self._local_lat,
                self._route, self._stt, self._bw, self._disc, self._weights,
                stage_order=self._stage_order,
                n_windows=self.n_windows,
                n_hosts=H,
                impl=self.impl,
                fused=self.fused,
                merge_plan=self._merge_plan,
                qos_on=self.qos_on,
            )
        dispatch_s = time.perf_counter() - t2
        stats = DispatchStats(
            devices_used=1,
            shard_rows=0,
            rows=len(traces),
            padded_fraction=float(b_bucket - len(traces)) / b_bucket,
            stage_s=t1 - t0,
            transfer_s=transfer_s,
            compile_s=compile_s,
            compute_s=dispatch_s,
            donated=donated,
            aot_cache_hit=aot_hit,
            qos_classes=self.flat.n_qos_classes,
        )
        self.last_dispatch = stats
        return PendingBatch(self, tuple(out), stats)

    def warmup(
        self,
        traces: Sequence[MemEvents],
        lat_scales: Optional[Sequence[Optional[np.ndarray]]] = None,
    ) -> bool:
        """Populate the AOT cache for the executable this batch shape would
        dispatch (one throwaway dispatch), so the first *real* dispatch of
        a serving loop finds it compiled.  Returns True if a lowering
        actually happened (False: already warm, empty batch, or a
        non-pipeline analyzer — the jit path warms itself on first call).
        """
        if not self.pipeline:
            return False
        before = self._aot.lowerings
        self.launch_batch(traces, lat_scales).finish()
        return self._aot.lowerings > before

    def analyze_batch(
        self,
        traces: Sequence[MemEvents],
        lat_scales: Optional[Sequence[Optional[np.ndarray]]] = None,
        stager: Optional[EventStager] = None,
    ) -> DelayBreakdown:
        """Analyze B epochs in one device dispatch; returns summed totals.

        ``lat_scales`` optionally pairs each epoch with a ``[H*P]``
        device-cache latency-scale vector
        (:meth:`~repro.core.cache.DeviceCacheModel.latency_scale`); ``None``
        entries (and padded rows) analyze with the exact no-cache ones
        vector.  ``stager`` substitutes the caller's staging buffers for
        the analyzer's own — the shared engine passes its per-engine stager
        so its dispatcher thread never shares mutable buffers with callers
        analyzing synchronously on this analyzer.
        """
        if self.pipeline:
            # the synchronous special case of the overlapped pipeline:
            # launch, then immediately block
            return self.launch_batch(traces, lat_scales, stager=stager).finish()
        P, S = self.flat.n_pools, self.flat.n_switches
        H = self.flat.n_hosts
        pairs = self._clean_pairs(traces, lat_scales)
        if not pairs:
            return DelayBreakdown.zero(P, S, H)
        traces = [tr for tr, _ in pairs]
        n_bucket = self._bucket(max(tr.n for tr in traces))
        b_bucket = self._bucket(len(traces), floor=1)
        st = stager if stager is not None else self._stager
        buf = st.stage(traces, b_bucket, n_bucket)
        scale_buf = np.ones((b_bucket, H * P), np.dtype(jnp.dtype(self.dtype).name))
        for row, (_, sc) in enumerate(pairs):
            if sc is not None:
                scale_buf[row] = sc
        # per-epoch window length: n_windows static windows tile each span
        span = np.maximum(buf["span"], self.bw_window_ns)
        bw_window = np.maximum(span / self.n_windows, 1.0)
        self.last_dispatch = DispatchStats(
            devices_used=1,
            shard_rows=0,
            rows=len(traces),
            padded_fraction=float(b_bucket - len(traces)) / b_bucket,
            qos_classes=self.flat.n_qos_classes,
        )
        out = self._batch_fn(
            jnp.asarray(buf["t"]),
            jnp.asarray(buf["pool"]),
            jnp.asarray(buf["bytes"]),
            jnp.asarray(buf["weight"]),
            jnp.asarray(buf["host"]),
            jnp.asarray(buf["qos"]),
            jnp.asarray(buf["valid"]),
            jnp.asarray(bw_window, self.dtype),
            jnp.asarray(scale_buf),
            self._bits_table,
            self._pool_lat,
            self._local_lat,
            self._route,
            self._stt,
            self._bw,
            self._disc,
            self._weights,
            stage_order=self._stage_order,
            n_windows=self.n_windows,
            n_hosts=H,
            impl=self.impl,
            fused=self.fused,
            merge_plan=self._merge_plan,
            qos_on=self.qos_on,
        )
        # the single host-boundary crossing for the whole batch
        lat, cong, bw, ppl, psc, psb, phl, phc, phb, pcc = jax.device_get(out)
        return DelayBreakdown(
            float(lat),
            float(cong),
            float(bw),
            ppl.astype(np.float64),
            psc.astype(np.float64),
            psb.astype(np.float64),
            phl.astype(np.float64),
            phc.astype(np.float64),
            phb.astype(np.float64),
            pcc.astype(np.float64),
        )

    def analyze_batch_multi(
        self,
        groups: Sequence[Sequence[MemEvents]],
        lat_scale_groups: Optional[Sequence[Optional[Sequence]]] = None,
        stager: Optional[EventStager] = None,
        mesh=None,
    ) -> List[DelayBreakdown]:
        """K sessions' epoch batches → K summed breakdowns, ONE dispatch.

        The multi-session stacked entry point the shared engine coalesces
        through: ``groups[k]`` is session k's epoch list and comes back as
        its own :class:`DelayBreakdown`, all from a single ``[K, B, N]``
        jitted dispatch (sessions vmapped over the per-batch analysis, the
        same stacking discipline as the scenario sweep — shapes bucketed by
        :func:`bucket_pow2` on every axis so repeated coalescings reuse the
        compile cache).  Every session must share this analyzer's topology
        and window config (the engine's dispatch key guarantees it).

        ``mesh`` (defaulting to the analyzer's own) shards the session axis
        with ``NamedSharding`` over ``('data',)``: the K leading axis is
        padded to a multiple of the device count so shards stay uniform,
        stacked inputs are placed pre-sharded, the topology leaves
        replicate, and per-shard epoch reduction still happens on device —
        the host transfer stays one ``[K, ...]`` vector regardless of how
        many devices participate.  With one device (or K == 1) the path is
        bitwise identical to the unsharded dispatch.

        Restricted to ``impl='inline'``: the session axis vmaps the fused
        cascade, and only the pure-XLA path is validated under that second
        vmap (mirroring the scenario suite's restriction).
        """
        if self.impl != "inline":
            raise ValueError(
                "cross-session stacking requires impl='inline' (the Pallas "
                "epoch loop is not validated under a session vmap)"
            )
        P, S = self.flat.n_pools, self.flat.n_switches
        H = self.flat.n_hosts
        K = len(groups)
        if lat_scale_groups is None:
            lat_scale_groups = [None] * K
        elif len(lat_scale_groups) != K:
            raise ValueError(
                f"{len(lat_scale_groups)} lat_scale_groups for {K} groups"
            )
        cleaned = [
            self._clean_pairs(traces, scales)
            for traces, scales in zip(groups, lat_scale_groups)
        ]
        out = [DelayBreakdown.zero(P, S, H) for _ in range(K)]
        rows = [i for i, p in enumerate(cleaned) if p]
        if not rows:
            return out
        if len(rows) == 1:  # degenerate stack: the plain batched path
            i = rows[0]
            out[i] = self.analyze_batch(
                [tr for tr, _ in cleaned[i]],
                [sc for _, sc in cleaned[i]],
                stager=stager,
            )
            return out
        from repro.distributed.sharding import (
            pad_to_multiple, replicated, resolve_data_mesh, shard_rows,
        )

        mesh, n_shards = resolve_data_mesh(
            mesh if mesh is not None else self.mesh,
            len(rows),
            what="coalesced session dispatch",
        )
        n_bucket = self._bucket(
            max(tr.n for i in rows for tr, _ in cleaned[i])
        )
        b_bucket = self._bucket(max(len(cleaned[i]) for i in rows), floor=1)
        k_bucket = pad_to_multiple(self._bucket(len(rows), floor=1), n_shards)
        st = stager if stager is not None else self._stager
        buf = st.stage_stack(
            [[tr for tr, _ in cleaned[i]] for i in rows],
            k_bucket, b_bucket, n_bucket,
        )
        scale_buf = np.ones(
            (k_bucket, b_bucket, H * P), np.dtype(jnp.dtype(self.dtype).name)
        )
        for k, i in enumerate(rows):
            for row, (_, sc) in enumerate(cleaned[i]):
                if sc is not None:
                    scale_buf[k, row] = sc
        span = np.maximum(buf["span"], self.bw_window_ns)
        bw_window = np.maximum(span / self.n_windows, 1.0)
        self.last_dispatch = DispatchStats(
            devices_used=n_shards,
            shard_rows=k_bucket // n_shards if mesh is not None else 0,
            rows=len(rows),
            padded_fraction=float(k_bucket - len(rows)) / k_bucket,
            qos_classes=self.flat.n_qos_classes,
        )
        if mesh is not None:
            self.sharded_dispatches += 1
        put_k = lambda a: shard_rows(mesh, jnp.asarray(a))
        put_r = lambda a: replicated(mesh, a)
        res = self._multi_fn(
            put_k(buf["t"]),
            put_k(buf["pool"]),
            put_k(buf["bytes"]),
            put_k(buf["weight"]),
            put_k(buf["host"]),
            put_k(buf["qos"]),
            put_k(buf["valid"]),
            put_k(jnp.asarray(bw_window, self.dtype)),
            put_k(scale_buf),
            put_r(self._bits_table),
            put_r(self._pool_lat),
            put_r(self._local_lat),
            put_r(self._route),
            put_r(self._stt),
            put_r(self._bw),
            put_r(self._disc),
            put_r(self._weights),
            stage_order=self._stage_order,
            n_windows=self.n_windows,
            n_hosts=H,
            impl=self.impl,
            fused=self.fused,
            merge_plan=self._merge_plan,
            qos_on=self.qos_on,
        )
        # one [K, ...] transfer for every coalesced session
        lat, cong, bw, ppl, psc, psb, phl, phc, phb, pcc = jax.device_get(res)
        for k, i in enumerate(rows):
            out[i] = DelayBreakdown(
                float(lat[k]),
                float(cong[k]),
                float(bw[k]),
                ppl[k].astype(np.float64),
                psc[k].astype(np.float64),
                psb[k].astype(np.float64),
                phl[k].astype(np.float64),
                phc[k].astype(np.float64),
                phb[k].astype(np.float64),
                pcc[k].astype(np.float64),
            )
        return out


def analyze_any(
    analyzer,
    traces: Sequence[MemEvents],
    lat_scales: Optional[Sequence] = None,
    stager: Optional[EventStager] = None,
) -> DelayBreakdown:
    """Run one epoch batch through whichever analyzer a session carries:
    an :class:`EpochAnalyzer` batches on device; DES-style analyzers
    (anything with ``.flat`` and ``.simulate``) run per epoch and sum.
    The single dispatch point shared by the synchronous attach path and
    the engine's solo-submission path."""
    if isinstance(analyzer, EpochAnalyzer):
        return analyzer.analyze_batch(traces, lat_scales, stager=stager)
    flat = analyzer.flat
    bd = DelayBreakdown.zero(flat.n_pools, flat.n_switches, flat.n_hosts)
    for i, tr in enumerate(traces):
        bd = bd + analyzer.simulate(
            tr, None if lat_scales is None else lat_scales[i]
        )
    return bd


# --------------------------------------------------------------------------- #
# Fine-grained discrete-event baseline (the "Gem5" of our Table 1)
# --------------------------------------------------------------------------- #


class FineGrainedSimulator:
    """Event-by-event DES through the switch hierarchy.

    Every transaction is walked individually through its pool's switch path
    (deepest switch -> RC) with per-switch FIFO occupancy.  ``bandwidth_mode``:

      * ``'stt'``      service time = STT only (matches the epoch analyzer's
                       congestion model exactly; used for oracle agreement).
      * ``'per_txn'``  service time = max(STT, bytes/BW): fine-grained
                       bandwidth modelling the epoch analyzer approximates
                       with windows (used for the accuracy benchmark).
    """

    def __init__(self, flat: FlatTopology, bandwidth_mode: str = "per_txn"):
        if bandwidth_mode not in ("stt", "per_txn"):
            raise ValueError(bandwidth_mode)
        self.flat = flat
        self.bandwidth_mode = bandwidth_mode
        # per-(host, pool) switch path, deepest first (the analyzer's stage
        # order); shared switches appear in several hosts' paths, private RCs
        # in exactly one — the same contention structure the epoch analyzer
        # derives from the virtual-pool route matrix
        order = list(flat.stage_order())
        self._paths: List[List[int]] = []
        for v in range(flat.route.shape[0]):
            self._paths.append([s for s in order if flat.route[v, s] > 0])

    def simulate(
        self,
        events: MemEvents,
        lat_scale: Optional[np.ndarray] = None,
        presorted: bool = False,
    ) -> DelayBreakdown:
        bd, _ = self._run(events, lat_scale, presorted)
        return bd

    def final_times(
        self, events: MemEvents, presorted: bool = False
    ) -> np.ndarray:
        """Per-event post-cascade times (the DES decision oracle the
        vectorized QoS cascades are gated against): ``out[i]`` is event
        ``i``'s departure time from its last switch — its service *start*
        under ``bandwidth_mode='stt'``, matching the kernels' final-time
        semantics exactly.  Times align with the simulated (time-sorted)
        event order; pass ``presorted=True`` on an already-sorted trace to
        keep input order."""
        _, t_out = self._run(events, None, presorted)
        return t_out

    def _run(
        self,
        events: MemEvents,
        lat_scale: Optional[np.ndarray],
        presorted: bool,
    ) -> Tuple[DelayBreakdown, np.ndarray]:
        flat = self.flat
        P, S, H = flat.n_pools, flat.n_switches, flat.n_hosts
        C = int(getattr(flat, "n_qos_classes", 1))
        if events.n == 0:
            return DelayBreakdown.zero(P, S, H), np.zeros((0,), np.float64)
        _check_reachable(flat, events)
        # presorted: the caller promises a non-decreasing timeline (e.g.
        # merge_host_traces output), skipping even the monotone check
        ev = events if presorted else events.sorted_by_time()
        pool = ev.pool.astype(np.int64)
        hostv = ev.host.astype(np.int64)
        qcls = np.clip(ev.qos.astype(np.int64), 0, C - 1)
        vpool = hostv * P + pool
        per_event_lat = np.maximum(
            flat.pool_latency_ns[vpool] - flat.local_latency_ns, 0.0
        )
        if lat_scale is not None:
            # device-cache epoch summary, same contract as analyze_ref
            per_event_lat = per_event_lat * np.asarray(lat_scale, np.float64)[vpool]
        per_event_lat = per_event_lat * ev.weight
        per_pool_lat = np.bincount(pool, weights=per_event_lat, minlength=P)[:P]
        per_host_lat = np.bincount(hostv, weights=per_event_lat, minlength=H)[:H]

        # per-(switch, class) horizons: FIFO switches use column 0 (one
        # shared queue), strict-priority ones carve per-level horizons a
        # high-class arrival pushes forward, WFQ ones advance class-private
        # virtual time by the weight-inflated service
        discs = (
            list(flat.switch_discipline)
            if getattr(flat, "switch_discipline", None)
            else ["fifo"] * S
        )
        w_table = flat.class_weight_table().astype(np.float64)
        w_total = w_table.sum(axis=1)
        fin = np.zeros((S, C), np.float64)
        per_switch_cong = np.zeros((S,), np.float64)
        per_switch_bw = np.zeros((S,), np.float64)
        per_host_cong = np.zeros((H,), np.float64)
        per_host_bw = np.zeros((H,), np.float64)
        per_class_cong = np.zeros((C,), np.float64)
        t_out = np.zeros((ev.n,), np.float64)
        # priority queue of (time, seq, event_idx, stage_pos); ``ev`` is
        # time-sorted, so the seed list already satisfies the heap invariant
        # — one O(n) pass instead of n heappushes.
        heap: List[Tuple[float, int, int, int]] = [
            (float(ev.t_ns[i]), i, i, 0) for i in range(ev.n)
        ]
        seq = ev.n
        while heap:
            t_arr, _, i, stage = heapq.heappop(heap)
            path = self._paths[vpool[i]]
            if stage >= len(path):
                t_out[i] = t_arr
                continue
            s = path[stage]
            stt = float(flat.switch_stt_ns[s])
            if self.bandwidth_mode == "per_txn":
                bw = float(flat.switch_bandwidth_gbps[s])
                service = max(stt, float(ev.bytes_[i]) / bw if bw > 0 else stt)
            else:
                service = stt
            disc = discs[s]
            c = int(qcls[i])
            if disc == "priority":
                start = max(t_arr, fin[s, c])
                for lvl in range(c, C):
                    fin[s, lvl] = max(t_arr, fin[s, lvl]) + service
            elif disc == "wfq":
                start = max(t_arr, fin[s, c])
                fin[s, c] = start + service * w_total[s] / w_table[s, c]
            else:  # fifo: one shared horizon
                start = max(t_arr, fin[s, 0])
                fin[s, 0] = start + service
            per_switch_cong[s] += start - t_arr  # queueing delay
            per_host_cong[hostv[i]] += start - t_arr
            per_class_cong[c] += start - t_arr
            if self.bandwidth_mode == "per_txn" and service > stt:
                per_switch_bw[s] += service - stt
                per_host_bw[hostv[i]] += service - stt
            heapq.heappush(heap, (start + service if self.bandwidth_mode == "per_txn" else start, seq, i, stage + 1))
            seq += 1

        return DelayBreakdown(
            float(per_event_lat.sum()),
            float(per_switch_cong.sum()),
            float(per_switch_bw.sum()),
            per_pool_lat,
            per_switch_cong,
            per_switch_bw,
            per_host_lat,
            per_host_cong,
            per_host_bw,
            per_class_cong,
        ), t_out
