"""The Timing Analyzer — the paper's core contribution (§3, component 3).

Given one epoch's memory-event trace and a flattened topology, compute the
three delays the paper defines:

  1. **latency delay**    Σ_events (total latency of target pool − local DRAM
                          latency).  Pure gather + segment-sum.
  2. **congestion delay** per switch, events traversing the same switch must
                          be ≥ STT apart; later events are pushed back and the
                          push cascades through the path (leaf switch → RC).
  3. **bandwidth delay**  per switch, windows whose traffic exceeds BW × window
                          are stretched to bytes/BW ("observed bandwidth after
                          latency and congestion delays are added exceeds the
                          bandwidth of the switch").

Three implementations, in increasing speed order:

  * :class:`FineGrainedSimulator` — event-by-event discrete-event simulation
    walking every transaction through its switch path individually.  This is
    our stand-in for the cycle-level baseline the paper compares against
    (Gem5): exact, Python, deliberately per-event.
  * :func:`analyze_ref` — vectorized numpy epoch analyzer, float64.  The
    correctness oracle for the JAX/Pallas paths.
  * :class:`EpochAnalyzer` — jitted JAX analyzer with bucketed padding so
    repeated epochs hit the compile cache.  This is the production path.

The serial queue ``out_i = max(arr_i, out_{i-1} + STT)`` is solved in closed
form with a cumulative max:  let ``f_i = cummax(arr_i − STT·rank_i)``; then
``out_i = f_i + STT·rank_i``.  That turns the per-switch queue into a sort +
scan, which is what makes the epoch analyzer vectorizable (and, in
:mod:`repro.kernels.congestion`, a Pallas kernel).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .events import MemEvents
from .topology import FlatTopology

__all__ = [
    "DelayBreakdown",
    "EpochAnalyzer",
    "FineGrainedSimulator",
    "analyze_ref",
    "serial_queue_ref",
]


@dataclasses.dataclass(frozen=True)
class DelayBreakdown:
    """Per-epoch simulated delays (ns), plus per-component decomposition."""

    latency_ns: float
    congestion_ns: float
    bandwidth_ns: float
    per_pool_latency_ns: np.ndarray  # [P]
    per_switch_congestion_ns: np.ndarray  # [S]
    per_switch_bandwidth_ns: np.ndarray  # [S]

    @property
    def total_ns(self) -> float:
        return self.latency_ns + self.congestion_ns + self.bandwidth_ns

    def __add__(self, other: "DelayBreakdown") -> "DelayBreakdown":
        return DelayBreakdown(
            self.latency_ns + other.latency_ns,
            self.congestion_ns + other.congestion_ns,
            self.bandwidth_ns + other.bandwidth_ns,
            self.per_pool_latency_ns + other.per_pool_latency_ns,
            self.per_switch_congestion_ns + other.per_switch_congestion_ns,
            self.per_switch_bandwidth_ns + other.per_switch_bandwidth_ns,
        )

    @staticmethod
    def zero(n_pools: int, n_switches: int) -> "DelayBreakdown":
        return DelayBreakdown(
            0.0,
            0.0,
            0.0,
            np.zeros((n_pools,)),
            np.zeros((n_switches,)),
            np.zeros((n_switches,)),
        )


# --------------------------------------------------------------------------- #
# Closed-form serial queue
# --------------------------------------------------------------------------- #


def serial_queue_ref(arrival_sorted: np.ndarray, stt: float) -> np.ndarray:
    """Start times of a FIFO queue with constant service time ``stt``.

    out_i = max(arrival_i, out_{i-1} + stt), solved as
    out_i = cummax(arrival_i - i*stt) + i*stt.
    """
    if len(arrival_sorted) == 0:
        return arrival_sorted
    idx = np.arange(len(arrival_sorted), dtype=np.float64)
    return np.maximum.accumulate(arrival_sorted - idx * stt) + idx * stt


# --------------------------------------------------------------------------- #
# Reference (numpy, float64) epoch analyzer
# --------------------------------------------------------------------------- #


def analyze_ref(
    flat: FlatTopology,
    events: MemEvents,
    bw_window_ns: float = 10_000.0,
) -> DelayBreakdown:
    """Vectorized numpy implementation of the three-delay model (oracle)."""
    P, S = flat.n_pools, flat.n_switches
    if events.n == 0:
        return DelayBreakdown.zero(P, S)

    t = events.t_ns.astype(np.float64).copy()
    pool = events.pool.astype(np.int64)
    nbytes = events.bytes_.astype(np.float64)

    # -- 1. latency delay ------------------------------------------------- #
    per_event_lat = flat.pool_latency_ns[pool] - flat.local_latency_ns
    per_event_lat = np.maximum(per_event_lat, 0.0) * events.weight
    per_pool_lat = np.bincount(pool, weights=per_event_lat, minlength=P)[:P]
    latency_ns = float(per_event_lat.sum())

    # -- 2. congestion delay (cascaded serial queues, deepest switch first) - #
    per_switch_cong = np.zeros((S,), np.float64)
    for s in flat.stage_order():
        stt = float(flat.switch_stt_ns[s])
        mask = flat.route[pool, s] > 0
        if stt <= 0 or not mask.any():
            continue
        order = np.argsort(t, kind="stable")
        m_sorted = mask[order]
        sub = order[m_sorted]
        start = serial_queue_ref(t[sub], stt)
        delay = start - t[sub]
        t[sub] = start
        per_switch_cong[s] = delay.sum()
    congestion_ns = float(per_switch_cong.sum())

    # -- 3. bandwidth delay (windowed, after latency+congestion shifts) ---- #
    # Paper: observed bandwidth is measured after the earlier delays are
    # applied, so windows are computed on the shifted times plus the latency
    # component of each event's pool.
    t_obs = t + per_event_lat
    span = max(float(t_obs.max()) + 1.0, bw_window_ns)
    n_win = int(np.ceil(span / bw_window_ns))
    win = np.minimum((t_obs / bw_window_ns).astype(np.int64), n_win - 1)
    per_switch_bw = np.zeros((S,), np.float64)
    for s in range(S):
        bw = float(flat.switch_bandwidth_gbps[s])  # GB/s == bytes/ns
        if bw <= 0:
            continue
        mask = flat.route[pool, s] > 0
        if not mask.any():
            continue
        wbytes = np.bincount(win[mask], weights=nbytes[mask], minlength=n_win)
        stretch = np.maximum(wbytes / bw - bw_window_ns, 0.0)
        per_switch_bw[s] = stretch.sum()
    bandwidth_ns = float(per_switch_bw.sum())

    return DelayBreakdown(
        latency_ns,
        congestion_ns,
        bandwidth_ns,
        per_pool_lat,
        per_switch_cong,
        per_switch_bw,
    )


# --------------------------------------------------------------------------- #
# JAX epoch analyzer (production path)
# --------------------------------------------------------------------------- #


def _analyze_jax(
    t: jnp.ndarray,  # [N] f32 epoch-relative ns (padded entries: +inf)
    pool: jnp.ndarray,  # [N] i32 (padded entries: 0)
    nbytes: jnp.ndarray,  # [N] f32 (padded entries: 0)
    weight: jnp.ndarray,  # [N] f32 statistical multiplicity
    valid: jnp.ndarray,  # [N] bool
    pool_latency_ns: jnp.ndarray,  # [P]
    local_latency_ns: jnp.ndarray,  # []
    route: jnp.ndarray,  # [P, S]
    switch_stt_ns: jnp.ndarray,  # [S]
    switch_bw: jnp.ndarray,  # [S] bytes/ns
    stage_order: Tuple[int, ...],  # static
    n_windows: int,  # static
    bw_window_ns: jnp.ndarray,  # []
    impl: str = "inline",  # 'inline' | 'pallas' | 'pallas_interpret' | 'ref'
):
    P = pool_latency_ns.shape[0]
    S = switch_stt_ns.shape[0]
    f32 = t.dtype

    # -- latency ----------------------------------------------------------- #
    per_event_lat = jnp.maximum(pool_latency_ns[pool] - local_latency_ns, 0.0) * weight
    per_event_lat = jnp.where(valid, per_event_lat, 0.0)
    per_pool_lat = jax.ops.segment_sum(per_event_lat, pool, num_segments=P)
    latency = per_event_lat.sum()

    # -- congestion: cascaded masked serial queues ------------------------- #
    big = jnp.asarray(jnp.finfo(f32).max / 4, f32)
    t_cur = jnp.where(valid, t, big)
    per_switch_cong = [jnp.zeros((), f32)] * S
    for s in stage_order:
        stt = switch_stt_ns[s]
        mask = (route[pool, s] > 0) & valid
        order = jnp.argsort(t_cur, stable=True)
        t_sorted = t_cur[order]
        m_sorted = mask[order]
        if impl == "inline":
            rank = jnp.cumsum(m_sorted.astype(jnp.int32)) - 1
            rankf = rank.astype(f32)
            g = jnp.where(m_sorted, t_sorted - stt * rankf, -big)
            f = jax.lax.cummax(g)
            start = jnp.where(m_sorted, f + stt * rankf, t_sorted)
            delay = jnp.where(m_sorted, start - t_sorted, 0.0)
        else:
            from repro.kernels import ops as kops  # deferred: avoid cycles

            start, delay = kops.congestion_queue(t_sorted, m_sorted, stt, impl=impl)
        t_cur = t_cur.at[order].set(jnp.where(m_sorted, start, t_sorted))
        per_switch_cong[s] = delay.sum()
    per_switch_cong = jnp.stack(per_switch_cong)
    congestion = per_switch_cong.sum()

    # -- bandwidth: windowed stretch ---------------------------------------- #
    t_obs = jnp.where(valid, t_cur + per_event_lat, 0.0)
    win = jnp.minimum((t_obs / bw_window_ns).astype(jnp.int32), n_windows - 1)
    win = jnp.where(valid, win, n_windows - 1)
    traversed = route[pool, :] * valid[:, None].astype(f32)  # [N, S]
    contrib = traversed * nbytes[:, None]  # [N, S]
    wbytes = jax.ops.segment_sum(contrib, win, num_segments=n_windows)  # [W, S]
    stretch = jnp.maximum(wbytes / switch_bw[None, :] - bw_window_ns, 0.0)
    per_switch_bw_d = stretch.sum(axis=0)
    bandwidth = per_switch_bw_d.sum()

    return latency, congestion, bandwidth, per_pool_lat, per_switch_cong, per_switch_bw_d


class EpochAnalyzer:
    """Jitted epoch analyzer with bucketed padding.

    Event counts vary per epoch; traces are padded up to the next power-of-two
    bucket so repeated ``analyze`` calls reuse the compile cache.
    """

    def __init__(
        self,
        flat: FlatTopology,
        bw_window_ns: float = 10_000.0,
        n_windows: int = 128,
        dtype=jnp.float32,
        impl: str = "inline",
    ):
        self.flat = flat
        self.bw_window_ns = float(bw_window_ns)
        self.n_windows = int(n_windows)
        self.dtype = dtype
        self._pool_lat = jnp.asarray(flat.pool_latency_ns, dtype)
        self._local_lat = jnp.asarray(flat.local_latency_ns, dtype)
        self._route = jnp.asarray(flat.route, dtype)
        self._stt = jnp.asarray(flat.switch_stt_ns, dtype)
        self._bw = jnp.asarray(flat.switch_bandwidth_gbps, dtype)
        self.impl = impl
        self._stage_order = tuple(int(s) for s in flat.stage_order())
        self._fn = jax.jit(
            _analyze_jax, static_argnames=("stage_order", "n_windows", "impl")
        )

    @staticmethod
    def _bucket(n: int) -> int:
        b = 16
        while b < n:
            b <<= 1
        return b

    def analyze(self, events: MemEvents) -> DelayBreakdown:
        P, S = self.flat.n_pools, self.flat.n_switches
        if events.n == 0:
            return DelayBreakdown.zero(P, S)
        n = events.n
        nb = self._bucket(n)
        pad = nb - n
        t = np.pad(events.t_ns.astype(np.float64), (0, pad))
        pool = np.pad(events.pool.astype(np.int32), (0, pad))
        nbytes = np.pad(events.bytes_.astype(np.float64), (0, pad))
        weight = np.pad(events.weight.astype(np.float64), (0, pad))
        valid = np.pad(np.ones((n,), bool), (0, pad))
        span = max(float(events.t_ns.max()) + 1.0, self.bw_window_ns)
        # window length chosen so n_windows static windows tile the epoch span
        bw_window = max(span / self.n_windows, 1.0)
        out = self._fn(
            jnp.asarray(t, self.dtype),
            jnp.asarray(pool),
            jnp.asarray(nbytes, self.dtype),
            jnp.asarray(weight, self.dtype),
            jnp.asarray(valid),
            self._pool_lat,
            self._local_lat,
            self._route,
            self._stt,
            self._bw,
            stage_order=self._stage_order,
            n_windows=self.n_windows,
            bw_window_ns=jnp.asarray(bw_window, self.dtype),
            impl=self.impl,
        )
        lat, cong, bw, ppl, psc, psb = jax.tree.map(np.asarray, out)
        return DelayBreakdown(
            float(lat), float(cong), float(bw), ppl, psc, psb
        )


# --------------------------------------------------------------------------- #
# Fine-grained discrete-event baseline (the "Gem5" of our Table 1)
# --------------------------------------------------------------------------- #


class FineGrainedSimulator:
    """Event-by-event DES through the switch hierarchy.

    Every transaction is walked individually through its pool's switch path
    (deepest switch -> RC) with per-switch FIFO occupancy.  ``bandwidth_mode``:

      * ``'stt'``      service time = STT only (matches the epoch analyzer's
                       congestion model exactly; used for oracle agreement).
      * ``'per_txn'``  service time = max(STT, bytes/BW): fine-grained
                       bandwidth modelling the epoch analyzer approximates
                       with windows (used for the accuracy benchmark).
    """

    def __init__(self, flat: FlatTopology, bandwidth_mode: str = "per_txn"):
        if bandwidth_mode not in ("stt", "per_txn"):
            raise ValueError(bandwidth_mode)
        self.flat = flat
        self.bandwidth_mode = bandwidth_mode
        # per-pool switch path, deepest first (same order the analyzer stages)
        order = list(flat.stage_order())
        self._paths: List[List[int]] = []
        for p in range(flat.n_pools):
            self._paths.append([s for s in order if flat.route[p, s] > 0])

    def simulate(self, events: MemEvents) -> DelayBreakdown:
        flat = self.flat
        P, S = flat.n_pools, flat.n_switches
        if events.n == 0:
            return DelayBreakdown.zero(P, S)
        ev = events.sorted_by_time()
        pool = ev.pool.astype(np.int64)
        per_event_lat = np.maximum(
            flat.pool_latency_ns[pool] - flat.local_latency_ns, 0.0
        ) * ev.weight
        per_pool_lat = np.bincount(pool, weights=per_event_lat, minlength=P)[:P]

        next_free = np.zeros((S,), np.float64)
        per_switch_cong = np.zeros((S,), np.float64)
        per_switch_bw = np.zeros((S,), np.float64)
        # priority queue of (time, seq, event_idx, stage_pos)
        heap: List[Tuple[float, int, int, int]] = []
        seq = 0
        for i in range(ev.n):
            heapq.heappush(heap, (float(ev.t_ns[i]), seq, i, 0))
            seq += 1
        while heap:
            t_arr, _, i, stage = heapq.heappop(heap)
            path = self._paths[pool[i]]
            if stage >= len(path):
                continue
            s = path[stage]
            stt = float(flat.switch_stt_ns[s])
            if self.bandwidth_mode == "per_txn":
                bw = float(flat.switch_bandwidth_gbps[s])
                service = max(stt, float(ev.bytes_[i]) / bw if bw > 0 else stt)
            else:
                service = stt
            start = max(t_arr, next_free[s])
            next_free[s] = start + service
            wait = start - t_arr
            per_switch_cong[s] += min(wait, np.inf)  # queueing delay
            if self.bandwidth_mode == "per_txn" and service > stt:
                per_switch_bw[s] += service - stt
            heapq.heappush(heap, (start + service if self.bandwidth_mode == "per_txn" else start, seq, i, stage + 1))
            seq += 1

        return DelayBreakdown(
            float(per_event_lat.sum()),
            float(per_switch_cong.sum()),
            float(per_switch_bw.sum()),
            per_pool_lat,
            per_switch_cong,
            per_switch_bw,
        )
