"""Multi-host pool-sharing coherency model (paper §1: "evaluation of the
performance impact of CXL.mem pool coherency on applications that share
memory across multiple servers").

CXL 3.0 back-invalidation semantics, modelled analytically per epoch:

  * a write by host h to a shared region whose lines may be cached by other
    hosts triggers a back-invalidate (BI) message to each sharer;
  * BI traffic traverses the pool's switch path, so it is injected into each
    sharer's trace as extra events (charged congestion/bandwidth like any
    other transaction);
  * reads after a remote write pay a coherency miss penalty.

The sharing pattern is summarized by a ``sharers[R]`` count per region and a
per-region write fraction measured from the trace — an analytic model in the
spirit of the paper's epoch batching (no per-line directory is simulated).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .events import CACHELINE_BYTES, MemEvents, RegionMap, concat_events

__all__ = ["CoherencyConfig", "CoherencyModel"]


@dataclasses.dataclass(frozen=True)
class CoherencyConfig:
    n_hosts: int = 2
    bi_message_bytes: float = 64.0  # back-invalidate packet (one line)
    coherency_miss_ns: float = 60.0  # extra latency for a post-invalidate read
    shared_classes: Tuple[str, ...] = ("kvcache", "param")  # shared tensor classes


class CoherencyModel:
    def __init__(self, cfg: CoherencyConfig, regions: RegionMap):
        self.cfg = cfg
        self.regions = regions
        self.bi_messages_total = 0.0
        self.coherency_delay_total_ns = 0.0

    def epoch_traffic(self, trace: MemEvents) -> Tuple[MemEvents, float]:
        """Returns (extra BI events, extra coherency latency ns) for one epoch."""
        if trace.n == 0 or self.cfg.n_hosts <= 1:
            return MemEvents.empty(), 0.0
        shared_rids = {
            r.rid for r in self.regions if r.tensor_class in self.cfg.shared_classes and r.pool != 0
        }
        if not shared_rids:
            return MemEvents.empty(), 0.0
        shared_mask = np.isin(trace.region, list(shared_rids))
        writes = shared_mask & trace.is_write
        n_writes = int(writes.sum())
        if n_writes == 0:
            return MemEvents.empty(), 0.0
        sharers = self.cfg.n_hosts - 1
        # BI packets: one per sharer per written line-granule
        n_bi = n_writes * sharers
        # subsample BI events (keep aggregate bytes) to bound trace growth
        emit = min(n_bi, 8192)
        scale = n_bi / emit
        src_idx = np.nonzero(writes)[0]
        pick = src_idx[np.linspace(0, len(src_idx) - 1, emit).astype(np.int64)]
        bi = MemEvents(
            t_ns=trace.t_ns[pick],
            pool=trace.pool[pick],
            bytes_=np.full((emit,), self.cfg.bi_message_bytes * scale),
            is_write=np.ones((emit,), bool),
            region=trace.region[pick],
        )
        # coherency-miss latency: reads of shared regions that follow a write
        reads = shared_mask & ~trace.is_write
        # fraction of reads that hit an invalidated line ~ writes/(reads+writes)
        frac = n_writes / max(int(shared_mask.sum()), 1)
        extra_lat = float(reads.sum()) * frac * self.cfg.coherency_miss_ns
        self.bi_messages_total += n_bi
        self.coherency_delay_total_ns += extra_lat
        return bi, extra_lat
