"""Multi-host pool-sharing coherency model (paper §1: "evaluation of the
performance impact of CXL.mem pool coherency on applications that share
memory across multiple servers").

CXL 3.0 back-invalidation semantics, modelled per epoch:

  * a write by host h to a shared region whose lines may be cached by other
    hosts triggers a back-invalidate (BI) message to each sharer;
  * BI traffic traverses each *sharer's* path to the pool, so it is injected
    into that sharer's event stream (charged congestion/bandwidth like any
    other transaction — on the sharer's route, which is where the message
    actually travels);
  * reads after a remote write pay a coherency miss penalty.

Two operating modes:

  * :meth:`CoherencyModel.fabric_traffic` — the shared-fabric session path.
    Sharer sets and write fractions are **derived from the actual per-host
    traces**: a region (matched by name across the tenants' region maps) is
    shared iff at least two hosts touch it in the epoch, its sharers are
    exactly the hosts that touched it, and each writer's BI fan-out goes to
    the *other* observed sharers.  No per-line directory is simulated — the
    epoch-granular summary is the same fidelity trade the paper's Timer
    makes — but nothing is assumed about who shares what: the traces decide.
  * :meth:`CoherencyModel.epoch_traffic` — the degenerate single-attach
    path, kept for programs attached outside a fabric session.  With only
    one host's trace there is nothing to derive sharers from, so it falls
    back to the analytic ``sharers = n_hosts - 1`` constant and injects the
    fan-out into the writer's own stream (total fabric BI traffic through
    the shared path).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from .events import MemEvents, RegionMap, concat_events

__all__ = ["CoherencyConfig", "CoherencyModel"]


@dataclasses.dataclass(frozen=True)
class CoherencyConfig:
    n_hosts: int = 2  # analytic fallback sharer count (single-attach mode only)
    bi_message_bytes: float = 64.0  # back-invalidate packet (one line)
    coherency_miss_ns: float = 60.0  # extra latency for a post-invalidate read
    shared_classes: Tuple[str, ...] = ("kvcache", "param")  # shared tensor classes
    max_bi_events: int = 8192  # injected-event cap per stream (bytes preserved)


def _subsample_bi(
    trace: MemEvents,
    src_idx: np.ndarray,
    bi_bytes: float,
    cap: int,
    host: int,
    pool: int,
    region: int,
) -> MemEvents:
    """One BI packet per source write, subsampled to ``cap`` emitted events
    while preserving aggregate BI bytes — weight-aware, so PEBS-sampled
    writer traces keep unbiased BI traffic (the analyzer charges byte-
    proportional delays)."""
    w_total = float(trace.weight[src_idx].sum())
    emit = int(min(len(src_idx), cap))
    pick = src_idx[np.linspace(0, len(src_idx) - 1, emit).astype(np.int64)]
    # like MemEvents.sample: bytes carry their own 1/rate scaling and the
    # statistical multiplicity rides in weight, so both byte-proportional
    # (bandwidth) and weight-proportional (latency) charges stay unbiased
    return MemEvents(
        t_ns=trace.t_ns[pick],
        pool=np.full((emit,), pool, np.int32),
        bytes_=np.full((emit,), bi_bytes * w_total / emit),
        is_write=np.ones((emit,), bool),
        region=np.full((emit,), region, np.int32),
        weight=np.full((emit,), w_total / emit),
        host=np.full((emit,), host, np.int32),
        qos=trace.qos[pick],
    )


class CoherencyModel:
    """Back-invalidation traffic + coherency-miss latency, epoch-granular.

    ``regions`` is the attached program's map (single-attach mode); the
    fabric session passes its per-tenant maps to :meth:`fabric_traffic`
    directly.
    """

    def __init__(self, cfg: CoherencyConfig, regions: RegionMap = None):
        self.cfg = cfg
        self.regions = regions
        self.bi_messages_total = 0.0
        self.bi_bytes_total = 0.0
        self.coherency_delay_total_ns = 0.0

    # ------------------------------------------------------------------ #
    # Shared-fabric path: sharers derived from the traces themselves
    # ------------------------------------------------------------------ #

    def fabric_traffic(
        self,
        traces: Sequence[MemEvents],
        region_maps: Sequence[RegionMap],
    ) -> Tuple[List[MemEvents], np.ndarray]:
        """Coherency traffic for one co-scheduled epoch across all hosts.

        Args:
          traces: per-host epoch traces (``traces[h]`` is host ``h``'s; may
            be empty).  Region ids in each trace index that host's map.
          region_maps: per-host region maps; shared objects are matched by
            region *name* across maps.

        Returns ``(bi_per_host, miss_ns_per_host)``: the BI events to inject
        into each host's stream (already host-tagged) and each host's extra
        coherency-miss latency in ns.
        """
        H = len(traces)
        if len(region_maps) != H:
            raise ValueError("one region map per host trace required")
        bi_out: List[List[MemEvents]] = [[] for _ in range(H)]
        miss_ns = np.zeros((H,), np.float64)
        if H <= 1:
            return [MemEvents.empty() for _ in range(H)], miss_ns

        # shared-candidate regions, matched by name: name -> {host: Region}
        candidates = {}
        for h, rm in enumerate(region_maps):
            for r in rm:
                if r.tensor_class in self.cfg.shared_classes and r.pool != 0:
                    candidates.setdefault(r.name, {})[h] = r

        for name, by_host in candidates.items():
            if len(by_host) < 2:
                continue
            # trace-driven sharer set: hosts that actually touched the region
            acc_mask = {}
            for h, r in by_host.items():
                tr = traces[h]
                if tr.n == 0:
                    continue
                m = tr.region == r.rid
                if m.any():
                    acc_mask[h] = m
            sharers = sorted(acc_mask)
            if len(sharers) < 2:
                continue
            w_weight = {
                h: float((traces[h].weight[acc_mask[h] & traces[h].is_write]).sum())
                for h in sharers
            }
            total_weight = sum(
                float(traces[h].weight[acc_mask[h]].sum()) for h in sharers
            )
            for h in sharers:
                tr = traces[h]
                writes = acc_mask[h] & tr.is_write
                src_idx = np.nonzero(writes)[0]
                if len(src_idx):
                    # one BI packet per sharer per written granule, delivered
                    # on each target sharer's own route to the pool
                    for g in sharers:
                        if g == h:
                            continue
                        bi = _subsample_bi(
                            tr,
                            src_idx,
                            bi_bytes=self.cfg.bi_message_bytes,
                            cap=self.cfg.max_bi_events,
                            host=g,
                            pool=by_host[g].pool,
                            region=by_host[g].rid,
                        )
                        bi_out[g].append(bi)
                        self.bi_messages_total += w_weight[h]
                        self.bi_bytes_total += w_weight[h] * self.cfg.bi_message_bytes
                # coherency misses: host h's shared reads that race remote
                # writes — write fraction measured from the actual traces
                remote_w = sum(w_weight[g] for g in sharers if g != h)
                if remote_w <= 0:
                    continue
                reads_w = float(tr.weight[acc_mask[h] & ~tr.is_write].sum())
                frac = remote_w / max(total_weight, 1.0)
                extra = reads_w * frac * self.cfg.coherency_miss_ns
                miss_ns[h] += extra
                self.coherency_delay_total_ns += extra

        return (
            [concat_events(parts) if parts else MemEvents.empty() for parts in bi_out],
            miss_ns,
        )

    # ------------------------------------------------------------------ #
    # Single-attach fallback: analytic sharer count
    # ------------------------------------------------------------------ #

    def epoch_traffic(self, trace: MemEvents) -> Tuple[MemEvents, float]:
        """Returns (extra BI events, extra coherency latency ns) for one epoch.

        Analytic mode for a program attached outside a fabric session: the
        other ``n_hosts - 1`` sharers are assumed, and their aggregate BI
        traffic is charged to this host's shared path.
        """
        if trace.n == 0 or self.cfg.n_hosts <= 1:
            return MemEvents.empty(), 0.0
        if self.regions is None:
            raise ValueError("single-attach coherency requires a RegionMap")
        shared_rids = {
            r.rid for r in self.regions if r.tensor_class in self.cfg.shared_classes and r.pool != 0
        }
        if not shared_rids:
            return MemEvents.empty(), 0.0
        shared_mask = np.isin(trace.region, list(shared_rids))
        writes = shared_mask & trace.is_write
        n_writes = int(writes.sum())
        if n_writes == 0:
            return MemEvents.empty(), 0.0
        sharers = self.cfg.n_hosts - 1
        # BI packets: one per sharer per written line-granule
        n_bi = n_writes * sharers
        # subsample BI events (keep aggregate bytes) to bound trace growth
        emit = min(n_bi, self.cfg.max_bi_events)
        scale = n_bi / emit
        src_idx = np.nonzero(writes)[0]
        pick = src_idx[np.linspace(0, len(src_idx) - 1, emit).astype(np.int64)]
        # like _bi_for: subsampling scales bytes AND statistical multiplicity,
        # so both byte-proportional (bandwidth) and weight-proportional
        # (latency) charges stay unbiased under the event cap
        bi = MemEvents(
            t_ns=trace.t_ns[pick],
            pool=trace.pool[pick],
            bytes_=np.full((emit,), self.cfg.bi_message_bytes * scale),
            is_write=np.ones((emit,), bool),
            region=trace.region[pick],
            weight=np.full((emit,), scale),
            host=trace.host[pick],
            qos=trace.qos[pick],
        )
        # coherency-miss latency: reads of shared regions that follow a write
        reads = shared_mask & ~trace.is_write
        # fraction of reads that hit an invalidated line ~ writes/(reads+writes)
        frac = n_writes / max(int(shared_mask.sum()), 1)
        extra_lat = float(reads.sum()) * frac * self.cfg.coherency_miss_ns
        self.bi_messages_total += n_bi
        self.bi_bytes_total += n_bi * self.cfg.bi_message_bytes
        self.coherency_delay_total_ns += extra_lat
        return bi, extra_lat
