"""Named unit-conversion constants and helpers — the only legal conversion
points.

Every ns↔s / ns↔ms / GiB↔bytes / GB↔bytes scale change in the simulator
routes through this module.  The simdim units checker
(:mod:`repro.analysis.units`) enforces that: a raw ``* 1e-9`` against a
``_ns`` value anywhere else is a ``unit-raw-conversion`` finding, because
scattered conversion literals are exactly how the shipped ns↔s accounting
slips happened.  This file is the checker's one exempt definition site.

Conventions the constants encode (see ``core/topology.py`` docstrings):

* ``_gbps`` fields are **GB/s == bytes/ns** (the 1e9 cancels), so bandwidth
  math inside the analyzers needs no conversion at all — ``bytes / gbps``
  is already ns.
* Decimal (``GB``, 1e9) is used for link rates; binary (``GiB``/``MiB``,
  2**30/2**20) for memory capacities, matching vendor datasheets.

Each helper keeps the exact arithmetic form (``* 1e-9`` vs ``/ 1e9``) of
the call sites it replaced, so the refactor is bitwise-neutral.  All
helpers are jit-safe: plain float scaling works on Python floats, numpy
arrays and traced jnp values alike.
"""

from __future__ import annotations

__all__ = [
    "BYTES_PER_GB",
    "BYTES_PER_GIB",
    "BYTES_PER_MIB",
    "FLOPS_PER_GFLOP",
    "NS_PER_MS",
    "NS_PER_S",
    "MS_PER_S",
    "NS_PER_US",
    "S_PER_NS",
    "bytes_to_gib",
    "bytes_to_mib",
    "gbps_to_bytes_per_s",
    "gib_to_bytes",
    "mib_to_bytes",
    "ms_to_ns",
    "ns_to_ms",
    "ns_to_s",
    "ns_to_us",
    "s_to_ms",
    "s_to_ns",
    "us_to_ns",
]

# time: the simulator's native clock is nanoseconds; reports are seconds
NS_PER_S = 1e9
S_PER_NS = 1e-9
NS_PER_MS = 1e6
NS_PER_US = 1e3
MS_PER_S = 1e3

# data: decimal GB for rates, binary GiB/MiB for capacities (exact ints)
BYTES_PER_GB = 1e9
BYTES_PER_GIB = 2**30
BYTES_PER_MIB = 2**20

FLOPS_PER_GFLOP = 1e9


def ns_to_s(x):
    """Simulated-nanosecond totals -> report seconds (``* 1e-9`` form)."""
    return x * S_PER_NS


def s_to_ns(x):
    """Wall/roofline seconds -> simulator nanoseconds (``* 1e9`` form)."""
    return x * NS_PER_S


def s_to_ms(x):
    """Report seconds -> milliseconds for human-facing prints (``* 1e3``)."""
    return x * MS_PER_S


def ns_to_ms(x):
    """Nanoseconds -> milliseconds for human-facing tables (``/ 1e6``)."""
    return x / NS_PER_MS


def ms_to_ns(x):
    return x * NS_PER_MS


def ns_to_us(x):
    return x / NS_PER_US


def us_to_ns(x):
    return x * NS_PER_US


def gib_to_bytes(x):
    """Binary-GiB capacities -> bytes; exact for integer inputs."""
    return x * BYTES_PER_GIB


def bytes_to_gib(x):
    return x / BYTES_PER_GIB


def mib_to_bytes(x):
    return x * BYTES_PER_MIB


def bytes_to_mib(x):
    return x / BYTES_PER_MIB


def gbps_to_bytes_per_s(x):
    """Link rate in GB/s (== bytes/ns) -> bytes per *second*."""
    return x * BYTES_PER_GB
