"""Batched scenario-sweep engine: K (topology × policy × cache × granularity)
configurations in one stacked on-device dispatch.

The paper's headline use case is *exploration* — "experimentation with
memory pooling configurations, scheduling policies, data migration
strategies, and caching techniques that were previously infeasible to
evaluate at scale".  The historical sweep surfaces evaluated one scenario
per Python iteration: re-place regions with a per-``Region`` loop,
re-synthesize the trace, one analyzer dispatch — a 500-point sweep paid
500 dispatches.  :class:`ScenarioSuite` folds the whole sweep into one
``[K, B, N]``-stacked jitted dispatch through the existing fused cascade:

  * **Placement** is a ``[K, R]`` matrix (:func:`~repro.core.policy.
    assign_batch` over the vectorized policy ``assign`` paths); per-event
    pools are gathered on device.
  * **Traces** share one structural skeleton per management granule
    (:func:`~repro.core.tracer.synthesize_skeleton`): times/bytes/region
    ids are placement-independent, so K scenarios pay one synthesis + one
    sort, not K.
  * **Topologies** are numeric variants of one structure
    (:class:`~repro.core.topology.TopologyOverride`), lowered to stacked
    ``[K, ...]`` leaves by :func:`~repro.core.topology.flatten_stack`; the
    route matrix and the cascade's static merge plan are shared, so the
    stack compiles once regardless of K.
  * **Caches** lower to per-scenario latency-scale vectors
    (:meth:`~repro.core.cache.DeviceCacheModel.latency_scale`).

One host transfer returns per-scenario latency/congestion/bandwidth totals
(each matching the sequential ``analyze_ref`` oracle; locked at 1e-4
relative in ``tests/test_scenario.py`` and ``benchmarks/scenario_sweep.py``).
:class:`SweepResult` is the frontier API: best config under capacity /
latency constraints, plus :meth:`ScenarioSuite.successive_halving` for
hillclimb-style refinement sweeps.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .analyzer import (
    DelayBreakdown,
    DispatchStats,
    _analyze_sweep_jax,
    bucket_pow2,
    plan_cascade,
)
from .cache import DeviceCacheConfig, DeviceCacheModel
from .events import RegionMap
from .policy import PlacementPolicy, RegionArrays, assign_batch, bytes_per_pool_batch
from .topology import QosSpec, Topology, TopologyOverride, flatten_stack
from .tracer import (
    HardwareModel,
    Phase,
    TPU_V5E,
    TraceSkeleton,
    skeleton_to_events,
    synthesize_skeleton,
)
from .units import bytes_to_gib, bytes_to_mib, ns_to_ms

__all__ = ["Scenario", "ScenarioSuite", "SweepResult"]


def _class_shares(b: DelayBreakdown) -> List[float]:
    """Per-QoS-class share of a breakdown's congestion delay."""
    pcc = b.per_class_congestion_ns
    if pcc is None:
        return [1.0]
    total = float(pcc.sum())
    if total <= 0.0:
        return [0.0] * len(pcc)
    return [float(x) / total for x in pcc]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One point of a sweep: placement policy × topology numeric variant ×
    device-cache config.  The management granularity rides on the policy
    (``policy.granularity_bytes``; see
    :meth:`~repro.core.policy.PlacementPolicy.with_granularity`)."""

    policy: PlacementPolicy
    topology: Optional[TopologyOverride] = None
    cache: Optional[DeviceCacheConfig] = None
    qos: Optional[QosSpec] = None
    name: str = ""

    def label(self) -> str:
        if self.name:
            return self.name
        parts = [self.policy.describe()]
        parts.append(self.topology.describe() if self.topology else "base")
        if self.cache is not None:
            parts.append(f"cache={bytes_to_mib(self.cache.capacity_bytes):g}MiB")
        if self.qos is not None:
            parts.append(self.qos.describe())
        return "|".join(parts)


@dataclasses.dataclass
class SweepResult:
    """Per-scenario outcome of one :meth:`ScenarioSuite.run` dispatch."""

    scenarios: List[Scenario]
    breakdowns: List[DelayBreakdown]
    native_ns: float  # roofline-paced native step time (shared: one workload)
    feasible: np.ndarray  # [K] bool: every pool within capacity
    utilization: np.ndarray  # [K, P] bytes placed / capacity
    # sharded-dispatch observability for THIS run's single dispatch
    devices_used: int = 1  # devices the scenario axis sharded over
    shard_rows: int = 0  # scenarios per device after padding (0 = unsharded)
    padded_fraction: float = 0.0  # padded scenario rows / dispatched rows
    # phase timing of this run's dispatch (host pack / H2D / device compute)
    stage_s: float = 0.0
    transfer_s: float = 0.0
    compute_s: float = 0.0
    qos_classes: int = 1  # QoS class count of this run's dispatch

    @property
    def k(self) -> int:
        return len(self.scenarios)

    def totals_ns(self) -> np.ndarray:
        return np.asarray([b.total_ns for b in self.breakdowns], np.float64)

    def slowdowns(self) -> np.ndarray:
        """Simulated step time over native step time, per scenario."""
        return (self.native_ns + self.totals_ns()) / self.native_ns

    def order(self, require_feasible: bool = True) -> np.ndarray:
        """Scenario indices sorted best-first (lowest total simulated delay);
        infeasible scenarios sort last when ``require_feasible``."""
        key = self.totals_ns().copy()
        if require_feasible:
            key[~self.feasible] = np.inf
        return np.argsort(key, kind="stable")

    def top(self, n: int, require_feasible: bool = True) -> List[int]:
        return [int(i) for i in self.order(require_feasible)[: max(int(n), 1)]]

    def best(
        self,
        max_total_ns: Optional[float] = None,
        max_slowdown: Optional[float] = None,
        require_feasible: bool = True,
    ) -> Optional[int]:
        """Index of the best scenario under the given constraints.

        ``require_feasible`` enforces the capacity constraint (every pool's
        placed bytes within its capacity); ``max_total_ns``/``max_slowdown``
        bound the simulated delay.  Returns None when nothing qualifies.
        """
        totals = self.totals_ns()
        ok = np.ones((self.k,), bool)
        if require_feasible:
            ok &= self.feasible
        if max_total_ns is not None:
            ok &= totals <= max_total_ns
        if max_slowdown is not None:
            ok &= self.slowdowns() <= max_slowdown
        if not ok.any():
            return None
        key = np.where(ok, totals, np.inf)
        return int(np.argmin(key))

    def table(self) -> List[Dict]:
        """One row per scenario — the purchasing-decision table."""
        slow = self.slowdowns()
        return [
            {
                "scenario": s.label(),
                "latency_ms": ns_to_ms(b.latency_ns),
                "congestion_ms": ns_to_ms(b.congestion_ns),
                "bandwidth_ms": ns_to_ms(b.bandwidth_ns),
                "total_ms": ns_to_ms(b.total_ns),
                "slowdown": float(slow[i]),
                "feasible": bool(self.feasible[i]),
                "devices_used": self.devices_used,
                "shard_rows": self.shard_rows,
                "padded_fraction": self.padded_fraction,
                "stage_s": self.stage_s,
                "transfer_s": self.transfer_s,
                "compute_s": self.compute_s,
                "qos_classes": self.qos_classes,
                "qos_delay_shares": _class_shares(b),
            }
            for i, (s, b) in enumerate(zip(self.scenarios, self.breakdowns))
        ]


class ScenarioSuite:
    """Evaluate K scenarios against one workload in one stacked dispatch.

    The workload (``regions`` + ``phases``, e.g. from
    :func:`repro.models.phases.build_regions_and_phases`) and the base
    topology *structure* are fixed per suite; scenarios vary placement,
    numeric topology parameters, device caching and granularity.  Repeated
    :meth:`run` calls at the same ``(K, N)`` bucket reuse the compile cache
    (shapes are bucketed to powers of two like the epoch analyzer's).

    Restricted to the ``'inline'`` analyzer implementation: the scenario
    axis vmaps the fused cascade, and only the pure-XLA path is known to
    vmap on every backend (the Pallas kernel runs epochs via ``lax.map``
    and is still single-topology).
    """

    def __init__(
        self,
        topology: Topology,
        regions: RegionMap,
        phases: Sequence[Phase],
        hw: HardwareModel = TPU_V5E,
        max_events_per_access: int = 64,
        calibration: float = 1.0,
        epoch_mode: str = "step",
        bw_window_ns: float = 10_000.0,
        n_windows: int = 128,
        dtype=jnp.float32,
        mesh=None,
        region_qos: Optional[Mapping[str, int]] = None,
    ):
        """``region_qos`` maps region names to QoS class ids (absent
        regions default to class 0); with it — or a QoS-bearing topology,
        or any scenario carrying a :class:`~repro.core.topology.QosSpec` —
        the sweep routes congestion through the vectorized QoS arbitration
        cascade and reports per-class delay shares."""
        self.topology = topology
        # a ('data',) mesh shards the scenario axis of every run() dispatch
        # (repro.launch.mesh.make_data_mesh); overridable per run
        self.mesh = mesh
        self.regions = regions
        self.phases = list(phases)
        self.hw = hw
        self.max_events_per_access = int(max_events_per_access)
        self.calibration = float(calibration)
        if epoch_mode not in ("step", "layer"):
            raise ValueError(epoch_mode)
        self.epoch_mode = epoch_mode
        self.bw_window_ns = float(bw_window_ns)
        self.n_windows = int(n_windows)
        self.dtype = dtype
        self._np_dtype = np.dtype(jnp.dtype(dtype).name)

        self.base_flat = topology.flatten()
        if self.base_flat.n_switches > 31:
            raise ValueError(
                "scenario sweeps require the fused cascade (<= 31 stages)"
            )
        bits_pool, self._merge_plan, self._stage_order = plan_cascade(self.base_flat)
        self._bits_table = jnp.asarray(bits_pool)
        self._route = jnp.asarray(self.base_flat.route, dtype)
        self.region_arrays = RegionArrays.from_regions(regions)
        self._region_qos = {str(k): int(v) for k, v in (region_qos or {}).items()}
        self._qos_of_region = np.asarray(
            [self._region_qos.get(name, 0) for name in self.region_arrays.names],
            np.int32,
        )
        if (self._qos_of_region < 0).any():
            raise ValueError("region_qos classes must be >= 0")
        self._skeletons: Dict[float, TraceSkeleton] = {}
        self._staged: Dict[Tuple[float, int], Dict[str, np.ndarray]] = {}
        self._sweep_jit = jax.jit(
            _analyze_sweep_jax,
            static_argnames=(
                "stage_order", "n_windows", "n_hosts", "merge_plan", "qos_on",
            ),
        )
        # count at the callable itself so EVERY sweep-kernel dispatch is
        # counted, whatever code path issues it (tests assert 1 per run)
        self.dispatch_count = 0

        def _counted(*args, **kwargs):
            self.dispatch_count += 1
            return self._sweep_jit(*args, **kwargs)

        self._sweep_fn = _counted
        self.last_unique_cascades = 0  # U of the latest run (dedup visibility)
        self.last_dispatch = DispatchStats()  # sharding stats of latest run

    def compile_cache_size(self) -> int:
        """Compiled-graph count of the sweep kernel.  Process-global for
        the underlying function (jit wrappers share caches), so only the
        *delta* across runs is meaningful: a stable value means repeated
        sweeps re-dispatch the same executable — no per-scenario traces
        or compiles."""
        return int(self._sweep_jit._cache_size())

    # ------------------------------------------------------------------ #
    # scenario construction helpers
    # ------------------------------------------------------------------ #

    @staticmethod
    def cartesian(
        policies: Mapping[str, PlacementPolicy],
        overrides: Optional[Mapping[str, Optional[TopologyOverride]]] = None,
        caches: Optional[Mapping[str, Optional[DeviceCacheConfig]]] = None,
        granularities: Optional[Sequence[int]] = None,
    ) -> List[Scenario]:
        """Cartesian scenario grid; names are ``topo/policy[/gN][/cache]``.

        ``granularities`` multiplies every policy by
        :meth:`~repro.core.policy.PlacementPolicy.with_granularity` copies.
        """
        overrides = overrides or {"base": None}
        caches = caches or {"nocache": None}
        pol_items: List[Tuple[str, PlacementPolicy]] = []
        for pname, pol in policies.items():
            if granularities is None:
                pol_items.append((pname, pol))
            else:
                pol_items += [
                    (f"{pname}/g{g}", pol.with_granularity(g)) for g in granularities
                ]
        out = []
        for (tname, ov), (pname, pol), (cname, cache) in itertools.product(
            overrides.items(), pol_items, caches.items()
        ):
            out.append(
                Scenario(
                    policy=pol, topology=ov, cache=cache,
                    name=f"{tname}/{pname}/{cname}",
                )
            )
        return out

    # ------------------------------------------------------------------ #
    # skeleton staging
    # ------------------------------------------------------------------ #

    _bucket = staticmethod(bucket_pow2)

    def skeleton_for(self, granularity_bytes: float) -> TraceSkeleton:
        g = float(granularity_bytes)
        skel = self._skeletons.get(g)
        if skel is None:
            skel = synthesize_skeleton(
                self.phases,
                self.regions,
                self.hw,
                granularity_bytes=g,
                max_events_per_access=self.max_events_per_access,
                calibration=self.calibration,
                epoch_mode=self.epoch_mode,
            )
            self._skeletons[g] = skel
        return skel

    def _staged_group(self, granularity_bytes: float, n_bucket: int):
        """Sorted, padded ``[B, n_bucket]`` arrays for one skeleton —
        built once per (granule, bucket) and reused across runs.

        Deliberately not :class:`~repro.core.events.EventStager`: the
        stager refills mutable per-call buffers from finished
        ``MemEvents`` (pool already resolved), while this stages the
        placement-independent *skeleton* — region ids instead of pools —
        into an immutable cache that whole sweeps alias.  The padding
        contract (bucketing, tail-invalid, span = max t + 1) is shared
        via :func:`~repro.core.analyzer.bucket_pow2` and locked by the
        sweep-vs-``analyze_ref`` oracle tests.
        """
        key = (float(granularity_bytes), int(n_bucket))
        buf = self._staged.get(key)
        if buf is not None:
            return buf
        skel = self.skeleton_for(granularity_bytes)
        B = skel.n_epochs
        fd = self._np_dtype
        buf = {
            "t": np.zeros((B, n_bucket), fd),
            "bytes": np.zeros((B, n_bucket), fd),
            "weight": np.zeros((B, n_bucket), fd),
            "host": np.zeros((B, n_bucket), np.int32),
            "valid": np.zeros((B, n_bucket), bool),
            "region": np.zeros((B, n_bucket), np.int32),
            "span": np.zeros((B,), np.float64),
        }
        for e in range(B):
            lo, hi = int(skel.epoch_ptr[e]), int(skel.epoch_ptr[e + 1])
            n = hi - lo
            if n == 0:
                continue
            t = skel.t_ns[lo:hi]
            if np.all(t[1:] >= t[:-1]):  # single-access epochs stage as-is
                order = slice(None)
            else:
                order = np.argsort(t, kind="stable")  # the group's ONE sort
            buf["t"][e, :n] = t[order]
            buf["bytes"][e, :n] = skel.bytes_[lo:hi][order]
            buf["region"][e, :n] = skel.region[lo:hi][order]
            buf["weight"][e, :n] = 1.0
            buf["valid"][e, :n] = True
            buf["span"][e] = float(buf["t"][e, n - 1]) + 1.0
        self._staged[key] = buf
        return buf

    # ------------------------------------------------------------------ #
    # the stacked dispatch
    # ------------------------------------------------------------------ #

    def run(
        self,
        scenarios: Sequence[Scenario],
        on_overflow: str = "mark",
        mesh=None,
    ) -> SweepResult:
        """Evaluate every scenario in ONE jitted, stacked device dispatch.

        ``on_overflow``: ``'mark'`` records capacity violations in
        ``SweepResult.feasible`` (the frontier API filters on it);
        ``'raise'`` fails fast like :func:`~repro.core.policy.capacity_check`.

        ``mesh`` (defaulting to the suite's) shards the scenario axis over
        the mesh's 'data' devices: K is padded (scenario 0 repeated) to a
        multiple of the device count so shards stay uniform, the K-leading
        arrays are placed pre-sharded, and the skeleton stacks plus the U
        unique cascades replicate — every device runs the (deduped) phase-1
        cascades, then reduces only its own scenario slice, so the host
        transfer stays one ``[K, ...]`` vector.  Padded rows are dropped
        before results are built.  Unsharded runs are bitwise unchanged.
        """
        if on_overflow not in ("mark", "raise"):
            raise ValueError(on_overflow)
        from repro.distributed.sharding import (
            pad_to_multiple, replicated, resolve_data_mesh, shard_rows,
        )
        scenarios = list(scenarios)
        if not scenarios:
            raise ValueError("empty scenario list")
        K = len(scenarios)
        flat = self.base_flat
        P, S, H = flat.n_pools, flat.n_switches, flat.n_hosts
        V = H * P
        ra = self.region_arrays

        # 1. [K, R] placement matrix (vectorized; repeated policies dedup'd)
        assign = assign_batch([s.policy for s in scenarios], ra, flat)
        util_bytes = bytes_per_pool_batch(assign, ra.nbytes, P)
        cap = np.asarray(flat.pool_capacity, np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            utilization = np.where(cap[None, :] > 0, util_bytes / cap[None, :], 0.0)
        feasible = (util_bytes <= cap[None, :]).all(axis=1)
        if on_overflow == "raise" and not feasible.all():
            k = int(np.argmin(feasible))
            over = int(np.argmax(util_bytes[k] - cap))
            raise ValueError(
                f"scenario {scenarios[k].label()!r}: pool "
                f"{flat.pool_names[over]} over capacity "
                f"({bytes_to_gib(util_bytes[k, over]):.1f} GiB placed, "
                f"{bytes_to_gib(cap[over]):.1f} GiB available)"
            )
        if flat.host_reachable is not None and not flat.host_reachable.all():
            bad = ~flat.host_reachable[0, assign]
            if bad.any():
                k, r = np.argwhere(bad)[0]
                raise ValueError(
                    f"scenario {scenarios[k].label()!r} places region "
                    f"{ra.names[r]!r} on a pool host 0 cannot reach"
                )

        # 2. granularity groups share one skeleton + one sort each
        grans = sorted({float(s.policy.granularity_bytes) for s in scenarios})
        group_of = np.asarray(
            [grans.index(float(s.policy.granularity_bytes)) for s in scenarios],
            np.int32,
        )
        skels = [self.skeleton_for(g) for g in grans]
        B = skels[0].n_epochs
        n_bucket = self._bucket(
            max(
                (int(np.diff(sk.epoch_ptr).max()) if sk.n else 1)
                for sk in skels
            )
        )
        groups = [self._staged_group(g, n_bucket) for g in grans]
        stack_np = lambda f: np.stack([gr[f] for gr in groups])
        span = np.maximum(stack_np("span"), self.bw_window_ns)  # [G, B]
        bw_window = np.maximum(span / self.n_windows, 1.0)

        # 3. stacked topology leaves (structure shared -> one compiled graph)
        topo_stack = flatten_stack(self.topology, [s.topology for s in scenarios])

        # 3a. the qos axis: per-scenario discipline/weight rows.  Disciplines
        # are numeric data under the vectorized QoS cascade, so K
        # discipline×weight mixes still compile ONE graph; qos_on itself is
        # the only static bit, and all-FIFO suites keep the historical path.
        qos_specs = [s.qos for s in scenarios]
        qos_on = bool(
            flat.has_qos
            or self._qos_of_region.any()
            or any(sp is not None for sp in qos_specs)
        )
        C = int(flat.n_qos_classes)
        if qos_on:
            C = max(
                C,
                int(self._qos_of_region.max(initial=0)) + 1,
                max((sp.n_classes() for sp in qos_specs if sp), default=1),
            )
        disc_base = flat.discipline_codes()  # [S] i32
        w_base = np.ones((S, C), self._np_dtype)
        w_base[:, : flat.n_qos_classes] = flat.class_weight_table()
        disc_np = np.tile(disc_base, (K, 1))
        w_np = np.tile(w_base, (K, 1, 1))
        for k, sp in enumerate(qos_specs):
            if sp is not None:
                sp.apply(disc_np[k], w_np[k], flat.switch_names)

        # 3b. cascade dedup: congestion (and the post-queue times bandwidth
        # windows see) depends only on (granularity group, placement row,
        # STT row — plus the discipline/weight rows when QoS is on) —
        # scenarios differing only in latency/bandwidth/cache share one
        # cascade on device
        stt_np = topo_stack.switch_stt_ns.astype(self._np_dtype)
        cas_index: Dict[Tuple, int] = {}
        cascade_of = np.empty((K,), np.int32)
        cas_rows: List[int] = []
        for k in range(K):
            ck = (int(group_of[k]), assign[k].tobytes(), stt_np[k].tobytes())
            if qos_on:
                ck += (disc_np[k].tobytes(), w_np[k].tobytes())
            u = cas_index.get(ck)
            if u is None:
                u = len(cas_rows)
                cas_index[ck] = u
                cas_rows.append(k)
            cascade_of[k] = u
        cas_rows_np = np.asarray(cas_rows, np.int64)
        cas_group = group_of[cas_rows_np]
        cas_assign = assign[cas_rows_np]
        cas_stt = stt_np[cas_rows_np]
        cas_disc = disc_np[cas_rows_np]
        cas_weights = w_np[cas_rows_np]
        self.last_unique_cascades = len(cas_rows)

        # 4. per-scenario device-cache latency scales (host-side tag model),
        # dedup'd like the cascades: the scale depends only on (granularity
        # group, placement row, cache config, scenario latency leaves), so
        # bandwidth/STT variants share one tag simulation
        lat_scale = np.ones((K, B, V), self._np_dtype)
        scale_cache: Dict[Tuple, np.ndarray] = {}
        for k, s in enumerate(scenarios):
            if s.cache is None:
                continue
            sk = (
                int(group_of[k]),
                assign[k].tobytes(),
                s.cache,
                topo_stack.pool_latency_ns[k].tobytes(),
                topo_stack.pool_media_latency_ns[k].tobytes(),
                float(topo_stack.local_latency_ns[k]),
            )
            rows = scale_cache.get(sk)
            if rows is None:
                model = DeviceCacheModel(s.cache, topo_stack.member(k), [self.regions])
                epochs = skeleton_to_events(
                    self.skeleton_for(s.policy.granularity_bytes), assign[k]
                )
                rows = np.ones((B, V), self._np_dtype)
                for e, tr in enumerate(epochs):
                    sc = model.observe_scale(tr)
                    if sc is not None:
                        rows[e] = sc
                scale_cache[sk] = rows
            lat_scale[k] = rows

        # 5. ONE stacked dispatch; per-scenario totals come back together.
        # With a mesh, the scenario axis is padded to a device multiple
        # (repeating scenario 0 — its cascade/group indices stay valid) and
        # sharded over 'data'; everything per-cascade or structural
        # replicates.
        mesh, n_shards = resolve_data_mesh(
            mesh if mesh is not None else self.mesh, K, what="scenario sweep"
        )
        Kp = pad_to_multiple(K, n_shards)

        def pad_k(a: np.ndarray) -> np.ndarray:
            if Kp == a.shape[0]:
                return a
            return np.concatenate(
                [a, np.repeat(a[:1], Kp - a.shape[0], axis=0)], axis=0
            )

        put_k = lambda a: shard_rows(mesh, jnp.asarray(pad_k(np.asarray(a))))
        put_r = lambda a: replicated(mesh, a)
        fd = self.dtype
        # host staging (pack), H2D transfer, then the dispatch proper — the
        # same phase split DispatchStats reports for the epoch pipeline
        t0 = time.perf_counter()
        host_r = [
            stack_np("t"), stack_np("bytes"), stack_np("weight"),
            stack_np("host"), stack_np("valid"), stack_np("region"),
            np.asarray(bw_window, self._np_dtype),
        ]
        host_k = [
            group_of, cascade_of, assign, lat_scale,
            np.asarray(topo_stack.pool_latency_ns, self._np_dtype),
            np.asarray(topo_stack.local_latency_ns, self._np_dtype),
            np.asarray(topo_stack.switch_bandwidth_gbps, self._np_dtype),
        ]
        stage_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        dev_r = [put_r(jnp.asarray(a, fd) if a.dtype.kind == "f" else jnp.asarray(a)) for a in host_r]
        dev_cas = [
            put_r(jnp.asarray(cas_group)), put_r(jnp.asarray(cas_assign)),
            put_r(jnp.asarray(cas_stt)), put_r(jnp.asarray(cas_disc)),
            put_r(jnp.asarray(cas_weights)),
            put_r(jnp.asarray(self._qos_of_region)),
        ]
        dev_k = [put_k(a) for a in host_k]
        transfer_s = time.perf_counter() - t0
        self.last_dispatch = DispatchStats(
            devices_used=n_shards,
            shard_rows=Kp // n_shards if mesh is not None else 0,
            rows=K,
            padded_fraction=float(Kp - K) / Kp,
            stage_s=stage_s,
            transfer_s=transfer_s,
            qos_classes=C,
        )
        t0 = time.perf_counter()
        out = self._sweep_fn(
            *dev_r,
            *dev_cas,
            *dev_k,
            put_r(self._bits_table),
            put_r(self._route),
            stage_order=self._stage_order,
            n_windows=self.n_windows,
            n_hosts=H,
            merge_plan=self._merge_plan,
            qos_on=qos_on,
        )
        lat, cong, bw, ppl, psc, psb, phl, phc, phb, pcc = jax.device_get(out)
        self.last_dispatch = dataclasses.replace(
            self.last_dispatch, compute_s=time.perf_counter() - t0
        )
        breakdowns = [
            DelayBreakdown(
                float(lat[k]), float(cong[k]), float(bw[k]),
                ppl[k].astype(np.float64),
                psc[k].astype(np.float64),
                psb[k].astype(np.float64),
                phl[k].astype(np.float64),
                phc[k].astype(np.float64),
                phb[k].astype(np.float64),
                pcc[k].astype(np.float64),
            )
            for k in range(K)
        ]
        native = float(sum(skels[0].native_ns))
        return SweepResult(
            scenarios=scenarios,
            breakdowns=breakdowns,
            native_ns=native,
            feasible=feasible,
            utilization=utilization,
            devices_used=self.last_dispatch.devices_used,
            shard_rows=self.last_dispatch.shard_rows,
            padded_fraction=self.last_dispatch.padded_fraction,
            stage_s=self.last_dispatch.stage_s,
            transfer_s=self.last_dispatch.transfer_s,
            compute_s=self.last_dispatch.compute_s,
            qos_classes=C,
        )

    # ------------------------------------------------------------------ #
    # hillclimb-style refinement
    # ------------------------------------------------------------------ #

    def successive_halving(
        self,
        scenarios: Sequence[Scenario],
        refine: Callable[[Scenario, int], Iterable[Scenario]],
        rounds: int = 2,
        keep: float = 0.5,
        on_overflow: str = "mark",
    ) -> Tuple[SweepResult, int]:
        """Batched hillclimb: evaluate, keep the best ``keep`` fraction,
        expand survivors via ``refine(scenario, round)``, repeat.

        Every round is one stacked dispatch, so a whole search costs
        ``rounds + 1`` dispatches regardless of population size.  Returns
        the final round's :class:`SweepResult` and its best index.

        Capacity-infeasible scenarios never survive a round while at
        least one feasible scenario exists (``top`` pads with infeasible
        entries only to fill its quota — they are filtered here, so
        refinement budget is not spent expanding capacity violations).
        If the *entire* final population is infeasible the returned index
        is the lowest-delay infeasible scenario; check
        ``result.feasible[index]`` before acting on it.
        """
        pop = list(scenarios)
        res = self.run(pop, on_overflow=on_overflow)
        for r in range(int(rounds)):
            n_keep = int(np.ceil(len(pop) * keep))
            survivors = [
                pop[i] for i in res.top(n_keep) if res.feasible[i]
            ] or [pop[i] for i in res.top(n_keep)]
            children, seen = [], {s.label() for s in survivors}
            for s in survivors:
                for c in refine(s, r):
                    if c.label() not in seen:
                        seen.add(c.label())
                        children.append(c)
            pop = survivors + children
            res = self.run(pop, on_overflow=on_overflow)
        best = res.best()
        if best is None:  # nothing feasible anywhere: least-bad, flagged
            best = int(res.order(require_feasible=False)[0])
        return res, int(best)
