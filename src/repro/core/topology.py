"""CXL.mem topology model (paper §2, Figure 1).

A topology is a tree: a CXL Root Complex (RC) at the root, CXL switches as
internal nodes, and memory pools (expanders) as leaves.  Local DRAM is pool 0
and hangs directly off the memory controller (empty switch path).  Every
component is annotated with the paper's three quantities:

  * ``latency_ns``  — added round-trip latency of traversing the component,
  * ``bandwidth_gbps`` — sustained bandwidth (GB/s) through the component,
  * ``stt_ns``      — serial transmission time: minimum spacing between two
                      transactions through the same component (switches only).

``FlatTopology`` lowers the tree to dense arrays so the timing analyzer
(:mod:`repro.core.analyzer`) can be vectorized / jitted.

**Multi-host fabrics** (the paper's pooling scenario): a topology may declare
``n_hosts`` attached servers.  Switches and expanders are *shared* fabric
components; each host brings its own private Root Complex (and its own local
DRAM — pool 0 is per-host private, so local traffic never crosses hosts).
The lowering emits one route row per ``(host, pool)`` pair: two hosts
reaching the same expander share every switch row on its path — which is
what creates cross-host contention — but each traverses its *own* RC row.
``host_ports`` restricts which top-level components a host's RC is cabled
to, modelling partial fabrics (a host that cannot see an expander at all).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .units import BYTES_PER_GIB, bytes_to_gib, gib_to_bytes

__all__ = [
    "DISCIPLINES",
    "DISCIPLINE_CODES",
    "Pool",
    "QosSpec",
    "Switch",
    "Topology",
    "TopologyOverride",
    "FlatTopology",
    "FlatTopologyStack",
    "flatten_stack",
    "chained_topology",
    "figure1_topology",
    "local_only_topology",
    "pooled_topology",
    "two_tier_topology",
]

# queue disciplines a switch's arbiter can run; codes are the traced-integer
# encoding the vectorized QoS cascade consumes (DESIGN.md §QoS arbitration)
DISCIPLINES: Tuple[str, ...] = ("fifo", "priority", "wfq")
DISCIPLINE_CODES: Dict[str, int] = {d: i for i, d in enumerate(DISCIPLINES)}


@dataclasses.dataclass(frozen=True)
class QosSpec:
    """A hashable QoS arbitration policy — one value of a sweep's ``qos``
    axis, applied on top of a topology's own per-switch settings.

    ``discipline``/``class_weights`` set every switch; ``switch_disciplines``
    / ``switch_weights`` override individual switches by name (a bare name
    also matches its ECMP replicas ``name@r``).  Disciplines and weights are
    *numeric data* under the vectorized QoS cascade, so scenarios differing
    only in a :class:`QosSpec` share one compiled graph.
    """

    discipline: Optional[str] = None
    class_weights: Optional[Tuple[float, ...]] = None
    switch_disciplines: Tuple[Tuple[str, str], ...] = ()
    switch_weights: Tuple[Tuple[str, Tuple[float, ...]], ...] = ()

    def __post_init__(self) -> None:
        for d in (self.discipline, *(d for _, d in self.switch_disciplines)):
            if d is not None and d not in DISCIPLINE_CODES:
                raise ValueError(f"unknown discipline {d!r} (use {DISCIPLINES})")
        for w in (self.class_weights, *(w for _, w in self.switch_weights)):
            if w is not None and (len(w) == 0 or any(x <= 0 for x in w)):
                raise ValueError("class weights must be non-empty and positive")

    def n_classes(self) -> int:
        n = len(self.class_weights) if self.class_weights else 1
        for _, w in self.switch_weights:
            n = max(n, len(w))
        return n

    def apply(
        self,
        disc_row: np.ndarray,  # [S] i32, mutated in place
        w_row: np.ndarray,  # [S, C] float, mutated in place
        switch_names: Sequence[str],
    ) -> None:
        base = [n.split("@")[0] for n in switch_names]

        def select(name: str) -> List[int]:
            sel = [
                i for i, b in enumerate(base)
                if b == name or switch_names[i] == name
            ]
            if not sel:
                raise ValueError(f"QosSpec names unknown switch {name!r}")
            return sel

        if self.discipline is not None:
            disc_row[:] = DISCIPLINE_CODES[self.discipline]
        if self.class_weights is not None:
            w = np.asarray(self.class_weights, w_row.dtype)
            w_row[:, : len(w)] = w
        for name, d in self.switch_disciplines:
            disc_row[select(name)] = DISCIPLINE_CODES[d]
        for name, ws in self.switch_weights:
            w_row[np.ix_(select(name), range(len(ws)))] = np.asarray(
                ws, w_row.dtype
            )

    def describe(self) -> str:
        parts = []
        if self.discipline is not None:
            parts.append(self.discipline)
        if self.class_weights is not None:
            parts.append(":".join(f"{w:g}" for w in self.class_weights))
        parts += [f"{n}={d}" for n, d in self.switch_disciplines]
        parts += [
            f"{n}={':'.join(f'{x:g}' for x in ws)}"
            for n, ws in self.switch_weights
        ]
        return "qos[" + ",".join(parts or ["base"]) + "]"


@dataclasses.dataclass(frozen=True)
class Switch:
    """A CXL switch (or the Root Complex, which behaves like one)."""

    name: str
    latency_ns: float  # added latency per transaction through this switch
    bandwidth_gbps: float  # GB/s through the switch
    stt_ns: float  # serial transmission time (min gap between transactions)
    parent: Optional[str] = None  # parent switch name; None => attached to RC
    # QoS arbitration: 'fifo' (arrival order), 'priority' (strict, class 0
    # highest), or 'wfq' (weighted fair, per-class virtual finish times)
    discipline: str = "fifo"
    # per-QoS-class weights ('wfq' only; None = equal); length must equal the
    # topology's n_qos_classes
    class_weights: Optional[Tuple[float, ...]] = None
    # ECMP-style multipath: lower this switch to ``multipath`` parallel route
    # columns; each (host, pool) flow deterministically picks one replica
    multipath: int = 1


@dataclasses.dataclass(frozen=True)
class Pool:
    """A memory pool / expander (leaf of the topology tree)."""

    name: str
    latency_ns: float  # device media latency (round trip, added)
    bandwidth_gbps: float  # device-side bandwidth
    capacity_bytes: int
    parent: Optional[str] = None  # switch it hangs off; None => direct to RC
    is_local: bool = False  # True only for local DRAM


class Topology:
    """A validated CXL.mem topology tree.

    Construction order does not matter; ``validate()`` checks the tree is
    acyclic, parents exist, and there is exactly one local DRAM pool.
    """

    def __init__(
        self,
        pools: Sequence[Pool],
        switches: Sequence[Switch] = (),
        rc_latency_ns: float = 10.0,
        rc_bandwidth_gbps: float = 256.0,
        rc_stt_ns: float = 0.5,
        local_dram_latency_ns: float = 88.9,  # paper's measured platform latency
        n_hosts: int = 1,
        host_ports: Optional[Mapping[int, Sequence[str]]] = None,
        n_qos_classes: Optional[int] = None,  # None: derive from class_weights
    ) -> None:
        self.pools: List[Pool] = list(pools)
        self.switches: List[Switch] = list(switches)
        self.rc_latency_ns = float(rc_latency_ns)
        self.rc_bandwidth_gbps = float(rc_bandwidth_gbps)
        self.rc_stt_ns = float(rc_stt_ns)
        self.local_dram_latency_ns = float(local_dram_latency_ns)
        self.n_hosts = int(n_hosts)
        derived = max(
            (len(s.class_weights) for s in self.switches if s.class_weights),
            default=1,
        )
        self.n_qos_classes = derived if n_qos_classes is None else int(n_qos_classes)
        # host -> top-level component names (parentless switches/pools) the
        # host's RC is attached to; hosts absent from the map see everything
        self.host_ports: Dict[int, Tuple[str, ...]] = {
            int(h): tuple(names) for h, names in (host_ports or {}).items()
        }
        self._switch_by_name: Dict[str, Switch] = {s.name: s for s in self.switches}
        self._pool_index: Dict[str, int] = {p.name: i for i, p in enumerate(self.pools)}
        self.validate()

    # ------------------------------------------------------------------ #

    def validate(self) -> None:
        if len({p.name for p in self.pools}) != len(self.pools):
            raise ValueError("duplicate pool names")
        if len(self._switch_by_name) != len(self.switches):
            raise ValueError("duplicate switch names")
        locals_ = [p for p in self.pools if p.is_local]
        if len(locals_) != 1:
            raise ValueError(f"need exactly one local DRAM pool, got {len(locals_)}")
        if self.pools.index(locals_[0]) != 0:
            raise ValueError("local DRAM must be pool index 0")
        if locals_[0].parent is not None:
            raise ValueError("local DRAM must attach directly (parent=None)")
        for s in self.switches:
            if s.parent is not None and s.parent not in self._switch_by_name:
                raise ValueError(f"switch {s.name}: unknown parent {s.parent}")
        for p in self.pools:
            if p.parent is not None and p.parent not in self._switch_by_name:
                raise ValueError(f"pool {p.name}: unknown parent {p.parent}")
        # acyclicity: walk each switch to the RC with a step bound
        for s in self.switches:
            seen = set()
            cur: Optional[str] = s.name
            while cur is not None:
                if cur in seen:
                    raise ValueError(f"cycle through switch {cur}")
                seen.add(cur)
                cur = self._switch_by_name[cur].parent
        if self.n_hosts < 1:
            raise ValueError("n_hosts must be >= 1")
        if self.n_qos_classes < 1:
            raise ValueError("n_qos_classes must be >= 1")
        for s in self.switches:
            if s.discipline not in DISCIPLINES:
                raise ValueError(
                    f"switch {s.name}: unknown discipline {s.discipline!r} "
                    f"(one of {DISCIPLINES})"
                )
            if s.multipath < 1:
                raise ValueError(f"switch {s.name}: multipath must be >= 1")
            if s.class_weights is not None:
                if len(s.class_weights) != self.n_qos_classes:
                    raise ValueError(
                        f"switch {s.name}: {len(s.class_weights)} class "
                        f"weights for {self.n_qos_classes} QoS classes"
                    )
                if any(w <= 0 for w in s.class_weights):
                    raise ValueError(
                        f"switch {s.name}: class weights must be > 0"
                    )
        top_level = {s.name for s in self.switches if s.parent is None} | {
            p.name for p in self.pools if p.parent is None and not p.is_local
        }
        for h, names in self.host_ports.items():
            if not (0 <= h < self.n_hosts):
                raise ValueError(f"host_ports host {h} out of range [0, {self.n_hosts})")
            for name in names:
                if name not in top_level:
                    raise ValueError(
                        f"host {h} port {name!r} is not a top-level component"
                    )

    # ------------------------------------------------------------------ #

    def host_reaches(self, host: int, pool: Pool) -> bool:
        """Whether ``host``'s RC has a fabric path to ``pool``.

        Local DRAM is always reachable (it is the host's own).  Remote pools
        are reachable iff the top-level component of their path is among the
        host's declared ports (all of them when the host declares none).
        """
        if pool.is_local:
            return True
        ports = self.host_ports.get(int(host))
        if ports is None:
            return True
        top = pool.name
        cur = pool.parent
        while cur is not None:
            top = cur
            cur = self._switch_by_name[cur].parent
        return top in ports

    def pool_index(self, name: str) -> int:
        return self._pool_index[name]

    def switch_path(self, pool: Pool) -> List[Switch]:
        """Switches traversed from the pool up to (not including) the RC."""
        path: List[Switch] = []
        cur = pool.parent
        while cur is not None:
            sw = self._switch_by_name[cur]
            path.append(sw)
            cur = sw.parent
        return path

    def pool_total_latency_ns(self, pool: Pool) -> float:
        """End-to-end added latency of one access to ``pool``.

        Local DRAM: its media latency only.  Remote pools: media latency +
        every switch on the path + the RC.
        """
        if pool.is_local:
            return pool.latency_ns
        lat = pool.latency_ns + self.rc_latency_ns
        for sw in self.switch_path(pool):
            lat += sw.latency_ns
        return lat

    def pool_path_bandwidth_gbps(self, pool: Pool) -> float:
        """Min bandwidth along the path (bottleneck link)."""
        bw = pool.bandwidth_gbps
        if not pool.is_local:
            bw = min(bw, self.rc_bandwidth_gbps)
            for sw in self.switch_path(pool):
                bw = min(bw, sw.bandwidth_gbps)
        return bw

    def flatten(self) -> "FlatTopology":
        return FlatTopology.from_topology(self)

    def flatten_stack(
        self, overrides: Sequence[Optional["TopologyOverride"]]
    ) -> "FlatTopologyStack":
        """Lower K numeric parameter variants in one pass; see
        :func:`flatten_stack`."""
        return flatten_stack(self, overrides)

    def describe(self) -> str:
        hosts = "" if self.n_hosts == 1 else f", {self.n_hosts} hosts"
        lines = [
            f"Topology: {len(self.pools)} pools, {len(self.switches)} switches"
            f"{hosts} "
            f"(RC lat={self.rc_latency_ns}ns bw={self.rc_bandwidth_gbps}GB/s "
            f"stt={self.rc_stt_ns}ns; local DRAM lat={self.local_dram_latency_ns}ns)"
        ]
        for p in self.pools:
            path = " -> ".join(s.name for s in self.switch_path(p)) or "(direct)"
            lines.append(
                f"  pool[{self.pool_index(p.name)}] {p.name}: lat={p.latency_ns}ns "
                f"bw={p.bandwidth_gbps}GB/s cap={bytes_to_gib(p.capacity_bytes):.1f}GiB "
                f"path={path} total_lat={self.pool_total_latency_ns(p):.1f}ns"
            )
        for s in self.switches:
            lines.append(
                f"  switch {s.name}: lat={s.latency_ns}ns bw={s.bandwidth_gbps}GB/s "
                f"stt={s.stt_ns}ns parent={s.parent or 'RC'}"
            )
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class FlatTopology:
    """Dense-array lowering of a :class:`Topology` for the analyzer.

    The analyzer routes each event through its **virtual pool**
    ``vp = host * n_pools + pool``: route/latency/bandwidth arrays have one
    row per (host, pool) pair.  Shared fabric switches keep one row each —
    every host's traffic lands on the same row, which is where cross-host
    contention comes from — while each host gets a private RC pseudo-switch.
    Switch arrays therefore have ``n_switches + n_hosts`` entries, host
    ``h``'s RC at index ``n_switches + h``.

    With ``n_hosts == 1`` every array is bit-identical to the historical
    single-host lowering (one RC, ``route`` is ``[P, S]``), so all existing
    single-host consumers and oracles are unchanged.
    """

    n_pools: int  # physical pools (per host)
    n_switches: int  # shared switches + one RC pseudo-switch per host
    pool_latency_ns: np.ndarray  # [H*P] total added latency per access
    pool_bandwidth_gbps: np.ndarray  # [H*P] bottleneck bandwidth on path
    pool_capacity: np.ndarray  # [P] bytes (physical device capacity)
    # [P] device media latency alone (the leaf component of pool_latency_ns);
    # the device-cache model (core/cache.py) replaces this component with
    # the expander's DRAM-cache hit latency on cache hits
    pool_media_latency_ns: np.ndarray
    local_latency_ns: float
    # route[H*P, S] == 1 iff accesses by host H to pool P traverse switch S
    route: np.ndarray
    switch_stt_ns: np.ndarray  # [S]
    switch_bandwidth_gbps: np.ndarray  # [S]
    # depth of each switch in the tree (RC = 0, children of RC = 1, ...).
    # The analyzer cascades serial queues deepest-first so an event's shift at
    # a leaf switch is visible when it merges at its parent — matching the
    # event-by-event fine-grained simulator.
    switch_depth: np.ndarray
    pool_names: Tuple[str, ...]
    switch_names: Tuple[str, ...]
    n_hosts: int = 1
    # host_reachable[H, P]: False where the host's ports exclude the pool
    host_reachable: Optional[np.ndarray] = None
    # QoS arbitration (empty/None => every stage is a plain FIFO):
    # per-column queue discipline (ECMP replicas and RCs included) ...
    switch_discipline: Tuple[str, ...] = ()
    # ... per-column class weights [S, C] (wfq rows; ones elsewhere) ...
    qos_class_weights: Optional[np.ndarray] = None
    # ... and the class count every weight row shares
    n_qos_classes: int = 1

    @property
    def n_vpools(self) -> int:
        """Virtual (host, pool) row count of ``route`` / latency tables."""
        return self.n_hosts * self.n_pools

    def vp_index(self, host: int, pool: int) -> int:
        return int(host) * self.n_pools + int(pool)

    def stage_order(self) -> np.ndarray:
        """Switch indices ordered deepest-first (RCs last)."""
        return np.argsort(-self.switch_depth, kind="stable")

    @property
    def has_qos(self) -> bool:
        """True when any stage arbitrates (non-FIFO) or classes exist."""
        return self.n_qos_classes > 1 or any(
            d != "fifo" for d in self.switch_discipline
        )

    def discipline_codes(self) -> np.ndarray:
        """[S] int32 discipline codes (``DISCIPLINE_CODES``; all-FIFO when
        the topology declares no disciplines)."""
        if not self.switch_discipline:
            return np.zeros((self.n_switches,), np.int32)
        return np.array(
            [DISCIPLINE_CODES[d] for d in self.switch_discipline], np.int32
        )

    def class_weight_table(self) -> np.ndarray:
        """[S, C] per-stage class weights (ones where undeclared)."""
        if self.qos_class_weights is None:
            return np.ones((self.n_switches, self.n_qos_classes), np.float64)
        return self.qos_class_weights

    @staticmethod
    def from_topology(t: Topology) -> "FlatTopology":
        P = len(t.pools)
        H = t.n_hosts
        # ECMP expansion: a multipath-m switch lowers to m route columns
        # (replicas share every numeric parameter; names 'sw', 'sw@1', ...)
        rep_src = _multipath_columns(t.switches)
        n_sw = len(rep_src)
        col_of: Dict[Tuple[str, int], int] = {}
        exp_names: List[str] = []
        for col, i in enumerate(rep_src):
            s = t.switches[i]
            r = len([c for c in rep_src[:col] if c == i])
            col_of[(s.name, r)] = col
            exp_names.append(s.name if r == 0 else f"{s.name}@{r}")
        S = n_sw + H  # + one RC pseudo-switch per host
        C = t.n_qos_classes
        pool_lat = np.zeros((H * P,), np.float64)
        pool_bw = np.zeros((H * P,), np.float64)
        pool_cap = np.zeros((P,), np.float64)
        pool_media = np.array([p.latency_ns for p in t.pools], np.float64)
        route = np.zeros((H * P, S), np.float64)
        reach = np.ones((H, P), bool)
        for i, p in enumerate(t.pools):
            pool_cap[i] = p.capacity_bytes
            for h in range(H):
                vp = h * P + i
                pool_lat[vp] = t.pool_total_latency_ns(p)
                pool_bw[vp] = t.pool_path_bandwidth_gbps(p)
                if p.is_local:
                    continue
                if not t.host_reaches(h, p):
                    reach[h, i] = False
                    continue  # no route: the host's ports exclude this pool
                route[vp, n_sw + h] = 1.0  # the host's private RC
                for sw in t.switch_path(p):
                    # each flow hashes onto one replica of a multipath switch
                    route[vp, col_of[(sw.name, vp % max(1, sw.multipath))]] = 1.0
        exp_sw = [t.switches[i] for i in rep_src]
        stt = np.array(
            [s.stt_ns for s in exp_sw] + [t.rc_stt_ns] * H, np.float64
        )
        sw_bw = np.array(
            [s.bandwidth_gbps for s in exp_sw] + [t.rc_bandwidth_gbps] * H,
            np.float64,
        )

        def depth(sw: Switch) -> int:
            d = 1
            cur = sw.parent
            while cur is not None:
                d += 1
                cur = t._switch_by_name[cur].parent
            return d

        sw_depth = np.array([depth(s) for s in exp_sw] + [0] * H, np.int32)
        rc_names = ("RC",) if H == 1 else tuple(f"RC{h}" for h in range(H))
        disc = tuple(s.discipline for s in exp_sw) + ("fifo",) * H
        weights = np.ones((S, C), np.float64)
        for col, s in enumerate(exp_sw):
            if s.class_weights is not None:
                weights[col] = s.class_weights
        return FlatTopology(
            n_pools=P,
            n_switches=S,
            pool_latency_ns=pool_lat,
            pool_bandwidth_gbps=pool_bw,
            pool_capacity=pool_cap,
            pool_media_latency_ns=pool_media,
            local_latency_ns=t.local_dram_latency_ns,
            route=route,
            switch_stt_ns=stt,
            switch_bandwidth_gbps=sw_bw,
            switch_depth=sw_depth,
            pool_names=tuple(p.name for p in t.pools),
            switch_names=tuple(exp_names) + rc_names,
            n_hosts=H,
            host_reachable=reach,
            switch_discipline=disc,
            qos_class_weights=weights,
            n_qos_classes=C,
        )


def _multipath_columns(switches: Sequence[Switch]) -> List[int]:
    """Expanded-column -> original-switch index for the ECMP lowering.

    Replicas of switch ``i`` occupy consecutive columns; the same layout is
    used by :meth:`FlatTopology.from_topology` and :func:`flatten_stack`, so
    per-column numeric leaves always line up with the route matrix.
    """
    src: List[int] = []
    for i, s in enumerate(switches):
        src.extend([i] * max(1, int(s.multipath)))
    return src


# --------------------------------------------------------------------------- #
# Parameterized stacked lowering (the scenario sweep's topology axis)
# --------------------------------------------------------------------------- #

_POOL_FIELDS = ("latency_ns", "bandwidth_gbps")
_SWITCH_FIELDS = ("latency_ns", "bandwidth_gbps", "stt_ns")


@dataclasses.dataclass(frozen=True)
class TopologyOverride:
    """Numeric parameter overrides against a base :class:`Topology`.

    Overrides never change *structure* (which components exist, who parents
    whom, pool capacities): a whole override stack shares the base
    topology's route matrix, stage order and cascade merge plan, which is
    what lets :func:`flatten_stack` lower K scenarios to ``[K, ...]`` leaf
    arrays under one compiled analyzer graph.  Structural variation (pool
    count, switch depth, capacity) is a different base topology — sweep it
    as an outer loop of suites (see ``examples/topology_explorer.py``).

    ``pools``/``switches`` map component name -> field -> value; pool
    fields: ``latency_ns``/``bandwidth_gbps``, switch fields those plus
    ``stt_ns``.  Scalar fields override the RC / local-DRAM constants.

    Bandwidth semantics: the three-delay model prices bandwidth at
    *switch* rows (windowed stretch) — a pool's ``bandwidth_gbps`` feeds
    only the reported path-bottleneck figure
    (``FlatTopology.pool_bandwidth_gbps``), never a delay.  To sweep an
    expander's link rate, override the switch it hangs off (as
    ``examples/topology_explorer.py`` does); sweeping pool bandwidth
    alone yields identical delay totals by design.  A bandwidth of 0
    means "unconstrained" — every analyzer skips the component's
    bandwidth charge (no division happens).
    """

    pools: Mapping[str, Mapping[str, float]] = dataclasses.field(default_factory=dict)
    switches: Mapping[str, Mapping[str, float]] = dataclasses.field(default_factory=dict)
    rc_latency_ns: Optional[float] = None
    rc_bandwidth_gbps: Optional[float] = None
    rc_stt_ns: Optional[float] = None
    local_dram_latency_ns: Optional[float] = None

    def validate_against(self, t: "Topology") -> None:
        pool_names = {p.name for p in t.pools}
        switch_names = {s.name for s in t.switches}
        for name, fields in self.pools.items():
            if name not in pool_names:
                raise ValueError(f"override names unknown pool {name!r}")
            for f, v in fields.items():
                if f not in _POOL_FIELDS:
                    raise ValueError(f"pool {name}: unknown field {f!r}")
                if v < 0:
                    raise ValueError(f"pool {name}.{f} must be >= 0")
        for name, fields in self.switches.items():
            if name not in switch_names:
                raise ValueError(f"override names unknown switch {name!r}")
            for f, v in fields.items():
                if f not in _SWITCH_FIELDS:
                    raise ValueError(f"switch {name}: unknown field {f!r}")
                if v < 0:
                    raise ValueError(f"switch {name}.{f} must be >= 0")

    def describe(self) -> str:
        parts = []
        for name, fields in self.pools.items():
            parts += [f"{name}.{f}={v:g}" for f, v in fields.items()]
        for name, fields in self.switches.items():
            parts += [f"{name}.{f}={v:g}" for f, v in fields.items()]
        for f in ("rc_latency_ns", "rc_bandwidth_gbps", "rc_stt_ns", "local_dram_latency_ns"):
            v = getattr(self, f)
            if v is not None:
                parts.append(f"{f}={v:g}")
        return ",".join(parts) or "base"


@dataclasses.dataclass(frozen=True)
class FlatTopologyStack:
    """K parameter variants of one topology, lowered to stacked leaves.

    ``base`` carries everything structural — route matrix, switch depths,
    names, capacities, reachability — shared by every scenario (so
    :func:`~repro.core.analyzer.plan_cascade` runs once for the stack).
    The numeric leaves get a leading scenario axis, exactly the arrays the
    analyzer's scenario vmap maps over.
    """

    base: FlatTopology
    pool_latency_ns: np.ndarray  # [K, H*P]
    pool_bandwidth_gbps: np.ndarray  # [K, H*P]
    pool_media_latency_ns: np.ndarray  # [K, P]
    local_latency_ns: np.ndarray  # [K]
    switch_stt_ns: np.ndarray  # [K, S]
    switch_bandwidth_gbps: np.ndarray  # [K, S]

    @property
    def k(self) -> int:
        return int(self.pool_latency_ns.shape[0])

    def member(self, k: int) -> FlatTopology:
        """Materialize scenario ``k`` as a plain :class:`FlatTopology`
        (sequential oracles, cache models, and spot-checks run on this)."""
        return dataclasses.replace(
            self.base,
            pool_latency_ns=self.pool_latency_ns[k],
            pool_bandwidth_gbps=self.pool_bandwidth_gbps[k],
            pool_media_latency_ns=self.pool_media_latency_ns[k],
            local_latency_ns=float(self.local_latency_ns[k]),
            switch_stt_ns=self.switch_stt_ns[k],
            switch_bandwidth_gbps=self.switch_bandwidth_gbps[k],
        )


def flatten_stack(
    t: Topology, overrides: Sequence[Optional[TopologyOverride]]
) -> FlatTopologyStack:
    """Lower ``len(overrides)`` parameter variants of ``t`` in one pass.

    Per-component leaf values are overridden per scenario, then the
    path-derived aggregates (total pool latency, bottleneck bandwidth) are
    recomputed vectorized across the whole stack; ``None`` entries are the
    unmodified base.  Row k agrees with ``Topology``-level lowering of the
    same parameters (``member(k)`` vs a rebuilt tree) to float tolerance.
    """
    base_flat = t.flatten()
    P, H, n_sw = len(t.pools), t.n_hosts, len(t.switches)
    K = len(overrides)
    if K == 0:
        raise ValueError("empty override stack")

    pool_media = np.tile([p.latency_ns for p in t.pools], (K, 1))
    pool_leaf_bw = np.tile([p.bandwidth_gbps for p in t.pools], (K, 1))
    sw_lat = np.tile([s.latency_ns for s in t.switches], (K, 1)).reshape(K, n_sw)
    sw_bw = np.tile([s.bandwidth_gbps for s in t.switches], (K, 1)).reshape(K, n_sw)
    sw_stt = np.tile([s.stt_ns for s in t.switches], (K, 1)).reshape(K, n_sw)
    rc_lat = np.full((K,), t.rc_latency_ns)
    rc_bw = np.full((K,), t.rc_bandwidth_gbps)
    rc_stt = np.full((K,), t.rc_stt_ns)
    local_lat = np.full((K,), t.local_dram_latency_ns)

    pool_idx = {p.name: i for i, p in enumerate(t.pools)}
    sw_idx = {s.name: i for i, s in enumerate(t.switches)}
    leaf = {
        ("pool", "latency_ns"): pool_media,
        ("pool", "bandwidth_gbps"): pool_leaf_bw,
        ("switch", "latency_ns"): sw_lat,
        ("switch", "bandwidth_gbps"): sw_bw,
        ("switch", "stt_ns"): sw_stt,
    }
    for k, ov in enumerate(overrides):
        if ov is None:
            continue
        ov.validate_against(t)
        for name, fields in ov.pools.items():
            for f, v in fields.items():
                leaf[("pool", f)][k, pool_idx[name]] = v
        for name, fields in ov.switches.items():
            for f, v in fields.items():
                leaf[("switch", f)][k, sw_idx[name]] = v
        if ov.rc_latency_ns is not None:
            rc_lat[k] = ov.rc_latency_ns
        if ov.rc_bandwidth_gbps is not None:
            rc_bw[k] = ov.rc_bandwidth_gbps
        if ov.rc_stt_ns is not None:
            rc_stt[k] = ov.rc_stt_ns
        if ov.local_dram_latency_ns is not None:
            local_lat[k] = ov.local_dram_latency_ns

    # path membership from the tree (structure: shared by the whole stack)
    pathm = np.zeros((P, n_sw), np.float64)
    nonlocal_ = np.zeros((P,), bool)
    for i, p in enumerate(t.pools):
        if p.is_local:
            continue
        nonlocal_[i] = True
        for sw in t.switch_path(p):
            pathm[i, sw_idx[sw.name]] = 1.0

    # total added latency per (scenario, pool): media + RC + path switches
    path_lat = sw_lat @ pathm.T if n_sw else np.zeros((K, P))
    pool_lat = pool_media + nonlocal_[None, :] * (rc_lat[:, None] + path_lat)
    # bottleneck bandwidth: min(leaf, RC, switches on path)
    if n_sw:
        masked = np.where(pathm[None, :, :] > 0, sw_bw[:, None, :], np.inf)
        path_bw = masked.min(axis=-1)
    else:
        path_bw = np.full((K, P), np.inf)
    pool_bw = np.where(
        nonlocal_[None, :],
        np.minimum(np.minimum(pool_leaf_bw, rc_bw[:, None]), path_bw),
        pool_leaf_bw,
    )

    # expand to virtual (host, pool) rows, duplicate multipath replica
    # columns (replicas share their switch's numbers, so overriding the
    # switch overrides every replica), and append per-host RC columns —
    # the same layout FlatTopology.from_topology emits
    rep_src = _multipath_columns(t.switches)
    return FlatTopologyStack(
        base=base_flat,
        pool_latency_ns=np.tile(pool_lat, (1, H)),
        pool_bandwidth_gbps=np.tile(pool_bw, (1, H)),
        pool_media_latency_ns=pool_media,
        local_latency_ns=local_lat,
        switch_stt_ns=np.concatenate(
            [sw_stt[:, rep_src], np.repeat(rc_stt[:, None], H, axis=1)], axis=1
        ),
        switch_bandwidth_gbps=np.concatenate(
            [sw_bw[:, rep_src], np.repeat(rc_bw[:, None], H, axis=1)], axis=1
        ),
    )


# --------------------------------------------------------------------------- #
# Canonical topologies
# --------------------------------------------------------------------------- #


def local_only_topology(capacity_gib: float = 96.0) -> Topology:
    """Degenerate topology: local DRAM only (native execution baseline)."""
    return Topology(
        pools=[
            Pool(
                "local_dram",
                latency_ns=88.9,
                bandwidth_gbps=76.8,  # DDR5-4800 dual channel
                capacity_bytes=int(gib_to_bytes(capacity_gib)),
                is_local=True,
            )
        ]
    )


def figure1_topology() -> Topology:
    """The paper's Figure 1: two CXL switches, three memory pools.

    The figure annotates BW/Lat/STT per component; the published text embeds
    them in an image, so we use representative CXL 2.0 numbers (x8 PCIe 5.0
    links, ~70 ns switch traversal) consistent with the paper's prose.

        RC ── switch0 ── pool1 (near pool, direct expander)
              └─ switch1 ── pool2, pool3 (far pools behind 2nd-level switch)
    """
    return Topology(
        pools=[
            Pool("local_dram", 88.9, 76.8, 96 * BYTES_PER_GIB, is_local=True),
            Pool("cxl_pool1", 150.0, 32.0, 128 * BYTES_PER_GIB, parent="switch0"),
            Pool("cxl_pool2", 180.0, 32.0, 256 * BYTES_PER_GIB, parent="switch1"),
            Pool("cxl_pool3", 180.0, 32.0, 256 * BYTES_PER_GIB, parent="switch1"),
        ],
        switches=[
            Switch("switch0", latency_ns=70.0, bandwidth_gbps=64.0, stt_ns=2.0),
            Switch(
                "switch1",
                latency_ns=70.0,
                bandwidth_gbps=32.0,
                stt_ns=4.0,
                parent="switch0",
            ),
        ],
        rc_latency_ns=10.0,
        rc_bandwidth_gbps=128.0,
        rc_stt_ns=0.5,
    )


def chained_topology(depth: int = 8, attach_bw: float = 32.0) -> Topology:
    """A daisy-chained expander string: ``depth`` switches in series, one
    expander hanging off each.

    The strictly nested switch masks (every event through ``sw{d}`` also
    traverses ``sw0..sw{d-1}``) make this the canonical chain-eligible
    topology for the device-resident epoch pipeline
    (:func:`repro.core.analyzer.plan_chain`), and the deep cascade is what
    stresses the congestion stages — the pipeline benchmark's workhorse.
    """
    if depth < 1:
        raise ValueError("chained_topology needs depth >= 1")
    pools = [Pool("local_dram", 88.9, 76.8, 96 * BYTES_PER_GIB, is_local=True)]
    switches = []
    for d in range(depth):
        switches.append(
            Switch(
                f"sw{d}",
                latency_ns=70.0,
                bandwidth_gbps=64.0,
                stt_ns=2.0 + 0.25 * d,
                parent=f"sw{d - 1}" if d else None,
            )
        )
        pools.append(
            Pool(
                f"exp{d}",
                170.0,
                attach_bw,
                256 * BYTES_PER_GIB,
                parent=f"sw{d}",
            )
        )
    return Topology(pools=pools, switches=switches)


def two_tier_topology(
    cxl_latency_ns: float = 170.0,
    cxl_bandwidth_gbps: float = 32.0,
    cxl_capacity_gib: float = 512.0,
) -> Topology:
    """Simple two-tier topology: local DRAM + one direct CXL expander."""
    return Topology(
        pools=[
            Pool("local_dram", 88.9, 76.8, 96 * BYTES_PER_GIB, is_local=True),
            Pool(
                "cxl_pool",
                cxl_latency_ns,
                cxl_bandwidth_gbps,
                int(gib_to_bytes(cxl_capacity_gib)),
                parent="sw",
            ),
        ],
        switches=[Switch("sw", latency_ns=70.0, bandwidth_gbps=cxl_bandwidth_gbps, stt_ns=2.0)],
    )


def pooled_topology(
    n_hosts: int = 2,
    cxl_latency_ns: float = 170.0,
    cxl_bandwidth_gbps: float = 32.0,
    cxl_capacity_gib: float = 1024.0,
    switch_stt_ns: float = 2.0,
    host_ports: Optional[Mapping[int, Sequence[str]]] = None,
    discipline: str = "fifo",
    class_weights: Optional[Sequence[float]] = None,
    multipath: int = 1,
) -> Topology:
    """The paper's pooling scenario: N hosts sharing one CXL expander.

    Each host keeps its private local DRAM (pool 0) and private RC; the
    expander and its switch are shared fabric components, so co-attached
    hosts contend there.  This is the canonical noisy-neighbor /
    memory-stranding topology.  ``discipline``/``class_weights`` set the
    shared switch's QoS arbitration policy (the per-rack policy knob);
    ``multipath`` lowers it to that many ECMP route columns.
    """
    weights = tuple(class_weights) if class_weights is not None else None
    return Topology(
        pools=[
            Pool("local_dram", 88.9, 76.8, 96 * BYTES_PER_GIB, is_local=True),
            Pool(
                "shared_pool",
                cxl_latency_ns,
                cxl_bandwidth_gbps,
                int(gib_to_bytes(cxl_capacity_gib)),
                parent="fabric_sw",
            ),
        ],
        switches=[
            Switch(
                "fabric_sw",
                latency_ns=70.0,
                bandwidth_gbps=cxl_bandwidth_gbps,
                stt_ns=switch_stt_ns,
                discipline=discipline,
                class_weights=weights,
                multipath=multipath,
            )
        ],
        n_hosts=n_hosts,
        host_ports=host_ports,
    )
